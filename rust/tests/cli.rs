//! CLI smoke tests: run the built `compiled-nn` binary end to end on every
//! subcommand and assert on its output (the user-facing launcher surface).

use std::path::Path;
use std::process::Command;

use compiled_nn::engine::EngineKind;

fn bin() -> Command {
    // cargo builds the binary in the test run's own profile
    let exe = Path::new(env!("CARGO_BIN_EXE_compiled-nn"));
    Command::new(exe)
}

fn have_artifacts() -> bool {
    Path::new("artifacts/manifest.json").exists()
}

fn run_ok(args: &[&str]) -> String {
    let out = bin().args(args).output().expect("spawn compiled-nn");
    assert!(
        out.status.success(),
        "`compiled-nn {}` failed:\n{}",
        args.join(" "),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).to_string()
}

#[test]
fn help_lists_commands() {
    let out = run_ok(&["help"]);
    for c in ["compile", "infer", "compare", "inspect", "explain", "precision", "table1", "serve"] {
        assert!(out.contains(c), "help missing `{c}`:\n{out}");
    }
}

#[test]
fn unknown_command_fails_with_help() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("frobnicate"));
}

#[test]
fn precision_prints_the_three_approximations() {
    let out = run_ok(&["precision"]);
    assert!(out.contains("tanh (Eq. 5)"));
    assert!(out.contains("sigmoid (Eq. 4)"));
    assert!(out.contains("exp (Schraudolph)"));
}

#[test]
fn inspect_reports_all_three_analyses() {
    if !have_artifacts() {
        return;
    }
    let out = run_ok(&["inspect", "--model", "c_bh"]);
    assert!(out.contains("§3.5 folding"), "{out}");
    assert!(out.contains("§3.2 memory"), "{out}");
    assert!(out.contains("total MACs"), "{out}");
    // c_bh has 2 foldable BNs
    assert!(out.contains("2 batchnorm layers → 0"), "{out}");
}

#[test]
fn explain_renders_the_lowering_report_without_artifacts() {
    // no --model → builtin demo net, so this runs on artifact-less CI
    let out = run_ok(&["explain"]);
    assert!(out.contains("lowering report"), "{out}");
    assert!(out.contains("cost-model"), "{out}");
    assert!(out.contains("im2col"), "{out}");
    assert!(out.contains("predicted total"), "{out}");

    // the batch hint is recorded in the report header
    let out = run_ok(&["explain", "--batch", "8"]);
    assert!(out.contains("batch hint 8"), "{out}");
}

#[test]
fn explain_runs_on_manifest_models() {
    if !have_artifacts() {
        return;
    }
    let out = run_ok(&["explain", "--model", "c_bh"]);
    assert!(out.contains("lowering report"), "{out}");
    assert!(out.contains("predicted total"), "{out}");
}

#[test]
fn infer_runs_each_engine() {
    if !have_artifacts() {
        return;
    }
    // registry-driven: only exercise the kinds this build provides
    for kind in EngineKind::all().iter().filter(|k| k.available()) {
        let out = run_ok(&["infer", "--model", "c_htwk", "--engine", kind.as_str()]);
        assert!(out.contains("output[0] shape [1, 2]"), "{kind}: {out}");
    }
}

#[test]
fn infer_names_unknown_engines() {
    let out = bin()
        .args(["infer", "--model", "c_htwk", "--engine", "frob"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("frob") && err.contains("optimized"), "{err}");
}

#[test]
fn compare_reports_small_deltas() {
    if !have_artifacts() {
        return;
    }
    let out = run_ok(&["compare", "--model", "c_bh"]);
    assert!(out.contains("compiled  vs naive-exact"), "{out}");
    // parse the exponents: all deltas must be < 1e-2 for the sigmoid head
    for line in out.lines().filter(|l| l.contains("max |Δ|")) {
        let v: f64 = line.split("= ").nth(1).unwrap().trim().parse::<f64>().unwrap_or_else(|_| {
            // format like 2.98e-8
            line.split("= ").nth(1).unwrap().trim().parse().unwrap()
        });
        assert!(v < 1e-2, "{line}");
    }
}

#[test]
fn missing_model_flag_is_a_clean_error() {
    let out = bin().args(["infer"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--model"));
}
