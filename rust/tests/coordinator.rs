//! Coordinator integration: correctness of the batched serving path against
//! direct execution, concurrency from multiple client threads, registry
//! idempotency, metrics accounting, and shutdown semantics.

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use compiled_nn::coordinator::server::{Coordinator, CoordinatorConfig};
use compiled_nn::engine::{build_engine, Engine, EngineKind, EngineOptions};
use compiled_nn::nn::tensor::Tensor;
use compiled_nn::runtime::artifact::Manifest;
use compiled_nn::util::rng::SplitMix64;

fn manifest() -> Option<Manifest> {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping coordinator tests: run `make artifacts` first");
        return None;
    }
    Some(Manifest::load_default().unwrap())
}

fn patches(n: usize, seed: u64) -> Vec<Tensor> {
    let mut rng = SplitMix64::new(seed);
    (0..n)
        .map(|_| Tensor::from_vec(&[32, 32, 1], rng.uniform_vec(32 * 32)))
        .collect()
}

#[test]
fn batched_results_match_direct_execution() {
    let Some(m) = manifest() else { return };
    let coord = Coordinator::start(
        m.clone(),
        CoordinatorConfig {
            max_wait: Duration::from_micros(500),
            queue_depth: 256,
            ..CoordinatorConfig::default()
        },
    )
    .unwrap();
    let client = coord.register("c_bh").unwrap();

    let inputs = patches(20, 5);
    let rxs: Vec<_> = inputs.iter().map(|x| client.infer_async(x.clone()).unwrap()).collect();
    let served: Vec<Tensor> = rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
    assert_eq!(client.info.engine, EngineKind::preferred().as_str());

    // direct, unbatched reference — same engine kind the coordinator used
    let mut direct_engine =
        build_engine(EngineKind::preferred(), &m, "c_bh", &EngineOptions::default()).unwrap();
    for (x, got) in inputs.iter().zip(&served) {
        let mut shape = vec![1usize];
        shape.extend_from_slice(x.shape());
        let direct = direct_engine
            .infer(&Tensor::from_vec(&shape, x.data().to_vec()))
            .unwrap();
        let d = got.max_abs_diff(&direct[0]);
        assert!(d < 1e-5, "served vs direct: {d}");
    }
    coord.shutdown();
}

#[test]
fn concurrent_clients_all_served() {
    let Some(m) = manifest() else { return };
    let coord = Coordinator::start(m, CoordinatorConfig::default()).unwrap();
    let client = coord.register("c_bh").unwrap();

    let n_threads = 4;
    let per_thread = 25;
    let mut handles = Vec::new();
    for t in 0..n_threads {
        let c = client.clone();
        handles.push(std::thread::spawn(move || {
            let mut ok = 0;
            for x in patches(per_thread, 100 + t as u64) {
                let out = c.infer(x).unwrap();
                assert_eq!(out.shape(), &[1, 1]);
                let v = out.data()[0];
                assert!((0.0..=1.0).contains(&v), "sigmoid out of range: {v}");
                ok += 1;
            }
            ok
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, n_threads * per_thread);

    let metrics = coord.metrics("c_bh").unwrap();
    assert_eq!(metrics.requests.get(), total as u64);
    assert!(metrics.batches.get() <= total as u64);
    assert_eq!(metrics.errors.get(), 0);
    assert!(metrics.mean_batch_fill() >= 1.0);
    coord.shutdown();
}

#[test]
fn register_is_idempotent() {
    let Some(m) = manifest() else { return };
    let coord = Coordinator::start(m, CoordinatorConfig::default()).unwrap();
    let a = coord.register("c_htwk").unwrap();
    let b = coord.register("c_htwk").unwrap();
    assert_eq!(a.info.buckets, b.info.buckets);
    assert_eq!(coord.models(), vec!["c_htwk".to_string()]);
    // both clients funnel to the same queue/metrics
    let x = patches(1, 1).remove(0);
    a.infer(Tensor::from_vec(&[16, 16, 1], x.data()[..256].to_vec())).unwrap();
    b.infer(Tensor::from_vec(&[16, 16, 1], x.data()[..256].to_vec())).unwrap();
    assert_eq!(a.metrics.requests.get(), 2);
    coord.shutdown();
}

#[test]
fn wrong_item_shape_rejected_before_queueing() {
    let Some(m) = manifest() else { return };
    let coord = Coordinator::start(m, CoordinatorConfig::default()).unwrap();
    let client = coord.register("c_bh").unwrap();
    let bad = Tensor::zeros(&[16, 16, 1]);
    let err = client.infer_async(bad).unwrap_err().to_string();
    assert!(err.contains("item shape"), "{err}");
    coord.shutdown();
}

#[test]
fn unknown_model_registration_fails() {
    let Some(m) = manifest() else { return };
    let coord = Coordinator::start(m, CoordinatorConfig::default()).unwrap();
    assert!(coord.register("not_a_model").is_err());
    coord.shutdown();
}

#[test]
fn shutdown_then_infer_errors_cleanly() {
    let Some(m) = manifest() else { return };
    let coord = Coordinator::start(m, CoordinatorConfig::default()).unwrap();
    let client = coord.register("c_htwk").unwrap();
    coord.shutdown();
    std::thread::sleep(Duration::from_millis(50));
    let x = Tensor::zeros(&[16, 16, 1]);
    // either the queue is closed or the reply channel errors — never a hang
    match client.infer_async(x) {
        Err(_) => {}
        Ok(rx) => {
            let r = rx.recv_timeout(Duration::from_secs(5));
            assert!(matches!(r, Ok(Err(_)) | Err(_)), "should not succeed after shutdown");
        }
    }
}

#[test]
fn two_models_serve_side_by_side() {
    let Some(m) = manifest() else { return };
    let coord = Coordinator::start(m, CoordinatorConfig::default()).unwrap();
    let bh = coord.register("c_bh").unwrap();
    let htwk = coord.register("c_htwk").unwrap();
    let mut rng = SplitMix64::new(9);
    let out_bh = bh.infer(Tensor::from_vec(&[32, 32, 1], rng.uniform_vec(1024))).unwrap();
    let out_htwk = htwk.infer(Tensor::from_vec(&[16, 16, 1], rng.uniform_vec(256))).unwrap();
    assert_eq!(out_bh.shape(), &[1, 1]);
    assert_eq!(out_htwk.shape(), &[1, 2]);
    let s: f32 = out_htwk.data().iter().sum();
    assert!((s - 1.0).abs() < 1e-2); // softmax head
    let names = {
        let mut v = coord.models();
        v.sort();
        v
    };
    assert_eq!(names, vec!["c_bh".to_string(), "c_htwk".to_string()]);
    coord.shutdown();
}

#[test]
fn coordinator_is_shareable_across_threads() {
    let Some(m) = manifest() else { return };
    let coord: Arc<Coordinator> = Coordinator::start(m, CoordinatorConfig::default()).unwrap();
    let c2 = coord.clone();
    let h = std::thread::spawn(move || c2.register("c_htwk").map(|c| c.info.buckets.clone()));
    let buckets = h.join().unwrap().unwrap();
    assert_eq!(buckets, vec![1, 8, 32]);
    coord.shutdown();
}
