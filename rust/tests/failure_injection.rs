//! Failure injection: corrupted artifacts, truncated blobs, malformed specs
//! and manifests must produce *clean, named* errors — never panics, wrong
//! numbers, or hangs. (The paper's robot loads models at boot; a bad file
//! must not take the process down.)

use std::fs;
use std::path::{Path, PathBuf};

use compiled_nn::engine::{build_engine, EngineKind, EngineOptions};
use compiled_nn::model::load::load_model;
use compiled_nn::runtime::artifact::Manifest;

fn have_artifacts() -> bool {
    Path::new("artifacts/manifest.json").exists()
}

/// Copy the real model files into a scratch dir we can corrupt.
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cnn_fail_{name}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    for f in [format!("{name}.json"), format!("{name}.weights.bin")] {
        fs::copy(Path::new("models").join(&f), dir.join(&f)).unwrap();
    }
    dir
}

#[test]
fn truncated_weight_blob_is_detected() {
    if !have_artifacts() {
        return;
    }
    let dir = scratch("c_htwk");
    let blob = dir.join("c_htwk.weights.bin");
    let bytes = fs::read(&blob).unwrap();
    fs::write(&blob, &bytes[..bytes.len() / 2]).unwrap();
    let err = load_model(&dir, "c_htwk").unwrap_err().to_string();
    assert!(err.contains("length") || err.contains("declared"), "{err}");
}

#[test]
fn misaligned_weight_blob_is_detected() {
    if !have_artifacts() {
        return;
    }
    let dir = scratch("c_htwk");
    let blob = dir.join("c_htwk.weights.bin");
    let mut bytes = fs::read(&blob).unwrap();
    bytes.pop(); // no longer a multiple of 4
    fs::write(&blob, &bytes).unwrap();
    let err = load_model(&dir, "c_htwk").unwrap_err().to_string();
    assert!(err.contains("multiple-of-4"), "{err}");
}

#[test]
fn spec_json_garbage_is_a_parse_error_with_offset() {
    if !have_artifacts() {
        return;
    }
    let dir = scratch("c_htwk");
    let json = dir.join("c_htwk.json");
    let text = fs::read_to_string(&json).unwrap();
    // drop a brace in the middle of the structure
    let pos = text.find("\"layers\"").unwrap();
    let mut broken = text.clone();
    broken.insert(pos, '}');
    fs::write(&json, broken).unwrap();
    let err = format!("{:#}", load_model(&dir, "c_htwk").unwrap_err());
    assert!(err.contains("parse error"), "{err}");
}

#[test]
fn out_of_bounds_weight_ref_is_detected() {
    if !have_artifacts() {
        return;
    }
    let dir = scratch("c_htwk");
    let json = dir.join("c_htwk.json");
    let text = fs::read_to_string(&json).unwrap();
    // blow up the first offset far past the blob
    let text = text.replacen("\"offset\": 0", "\"offset\": 99999999", 1);
    fs::write(&json, text).unwrap();
    let err = load_model(&dir, "c_htwk").unwrap_err().to_string();
    assert!(err.contains("exceeds blob"), "{err}");
}

#[test]
fn corrupted_hlo_text_fails_compile_not_process() {
    if !have_artifacts() {
        return;
    }
    let m = Manifest::load_default().unwrap();
    // build a manifest view over a scratch artifacts dir with corrupt HLO
    let dir = std::env::temp_dir().join(format!("cnn_fail_hlo_{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    fs::copy("artifacts/manifest.json", dir.join("manifest.json")).unwrap();
    let entry = m.entry("c_htwk").unwrap();
    let f = &entry.artifacts[&1].file;
    let text = fs::read_to_string(Path::new("artifacts").join(f)).unwrap();
    fs::write(dir.join(f), &text[..text.len() / 3]).unwrap();
    // other buckets don't exist in the scratch dir at all
    let scratch_manifest = Manifest::load(&dir, Path::new("models")).unwrap();
    // Without the pjrt feature this errors as "engine unavailable"; with it
    // the HLO parse fails — either way: a clean Err, never a crash.
    let err = build_engine(
        EngineKind::Compiled,
        &scratch_manifest,
        "c_htwk",
        &EngineOptions::with_buckets(&[1]),
    );
    assert!(err.is_err(), "corrupt HLO must not load");
}

#[test]
fn missing_manifest_names_the_fix() {
    let dir = std::env::temp_dir().join("cnn_no_manifest");
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    let err = format!(
        "{:#}",
        Manifest::load(&dir, Path::new("models")).unwrap_err()
    );
    assert!(err.contains("make artifacts"), "{err}");
}

#[test]
fn manifest_missing_model_lists_available() {
    if !have_artifacts() {
        return;
    }
    let m = Manifest::load_default().unwrap();
    let err = m.entry("resnet152").unwrap_err().to_string();
    assert!(err.contains("resnet152") && err.contains("c_bh"), "{err}");
}
