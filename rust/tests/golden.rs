//! Cross-language golden tests: every engine vs the exact JAX oracle
//! recorded at artifact-build time (`artifacts/golden/<name>.json`).
//! The input regenerates bit-identically from the shared SplitMix64 stream.
//!
//! Engines are obtained exclusively through the `EngineKind` registry, so
//! the same tests cover whichever execution paths this build provides:
//! without the `pjrt` feature (or without a real PJRT plugin) the compiled
//! engine reports unavailable and its cases skip instead of failing.
//!
//! Tolerances: exact engines ≤ 1e-3 (f32 accumulation-order drift across
//! conv implementations); compiled/optimized outputs additionally carry the
//! §3.4 approximation error on softmax/sigmoid heads.

use std::path::Path;

use compiled_nn::engine::{
    build_engine, build_engine_from_spec, Engine, EngineKind, EngineOptions,
};
use compiled_nn::model::builder::tiny_cnn;
use compiled_nn::nn::tensor::Tensor;
use compiled_nn::runtime::artifact::Manifest;
use compiled_nn::util::json::Json;
use compiled_nn::util::rng::{golden_seed, SplitMix64};

struct Golden {
    shape: Vec<usize>,
    sample: Vec<f32>,
    sum: f64,
    absmax: f64,
}

fn load_golden(name: &str) -> Option<Golden> {
    let path = Path::new("artifacts/golden").join(format!("{name}.json"));
    let text = std::fs::read_to_string(path).ok()?;
    let j = Json::parse(&text).unwrap();
    let o = &j.req_arr("outputs").unwrap()[0];
    Some(Golden {
        shape: o.req("shape").unwrap().as_usize_vec().unwrap(),
        sample: o
            .req_arr("sample")
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect(),
        sum: o.req_f64("sum").unwrap(),
        absmax: o.req_f64("absmax").unwrap(),
    })
}

fn golden_input(seed: u64, shape: &[usize]) -> Tensor {
    let mut full = vec![1];
    full.extend_from_slice(shape);
    let n: usize = full.iter().product();
    let mut rng = SplitMix64::new(golden_seed(seed));
    Tensor::from_vec(&full, rng.uniform_vec(n))
}

fn check(out: &Tensor, g: &Golden, tol: f32, label: &str) {
    assert_eq!(out.shape(), &g.shape[..], "{label}: shape");
    for (i, (&got, &want)) in out.data().iter().zip(&g.sample).enumerate() {
        assert!(
            (got - want).abs() < tol,
            "{label}: sample[{i}] {got} vs {want} (tol {tol})"
        );
    }
    let sum: f64 = out.data().iter().map(|&v| v as f64).sum();
    // sum over up to ~12k outputs; scale tolerance with count
    let sum_tol = tol as f64 * out.len() as f64;
    assert!((sum - g.sum).abs() < sum_tol.max(1e-3), "{label}: sum {sum} vs {}", g.sum);
    let absmax = out.data().iter().fold(0.0f32, |m, &v| m.max(v.abs())) as f64;
    assert!((absmax - g.absmax).abs() < tol as f64 * 10.0, "{label}: absmax");
}

/// (exact-engine tol, approx-engine tol) per model head type.
fn tolerances(name: &str) -> (f32, f32) {
    match name {
        "c_htwk" | "segmenter" => (1e-3, 0.06), // softmax head → fast-exp error
        "c_bh" | "detector" => (1e-3, 3e-3),    // sigmoid head → Eq. 4/5 error
        "vgg19" => (1e-3, 0.06),                // softmax head
        _ => (1e-3, 3e-3),
    }
}

fn manifest() -> Option<Manifest> {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping golden tests: run `make artifacts` first");
        return None;
    }
    Some(Manifest::load_default().unwrap())
}

/// Registry helper: build `kind` for `model`, or `None` when this host
/// cannot provide it (feature off, stub xla, missing plugin). A kind that
/// *is* available but fails to build is a real regression — fail loudly
/// instead of silently skipping the oracle-parity signal.
fn engine_or_skip(
    m: &Manifest,
    kind: EngineKind,
    model: &str,
    opts: &EngineOptions,
) -> Option<Box<dyn Engine>> {
    if !kind.available() {
        eprintln!("skipping {model}/{kind}: engine unavailable on this host");
        return None;
    }
    match build_engine(kind, m, model, opts) {
        Ok(e) => Some(e),
        Err(err) => panic!("{model}/{kind}: engine available on this host but failed to build: {err:#}"),
    }
}

#[test]
fn naive_interpreter_matches_jax_oracle() {
    let Some(m) = manifest() else { return };
    for name in ["c_htwk", "c_bh", "detector", "segmenter"] {
        let g = load_golden(name).unwrap();
        let entry = m.entry(name).unwrap();
        let mut e =
            build_engine(EngineKind::Naive, &m, name, &EngineOptions::default()).unwrap();
        let out = e.infer(&golden_input(entry.seed, &entry.input_shape)).unwrap();
        check(&out[0], &g, tolerances(name).0, &format!("{name}/naive"));
    }
}

#[test]
fn optimized_interpreter_matches_jax_oracle() {
    let Some(m) = manifest() else { return };
    for name in ["c_htwk", "c_bh", "detector", "segmenter"] {
        let g = load_golden(name).unwrap();
        let entry = m.entry(name).unwrap();
        let mut e =
            build_engine(EngineKind::Optimized, &m, name, &EngineOptions::default()).unwrap();
        let out = e.infer(&golden_input(entry.seed, &entry.input_shape)).unwrap();
        check(&out[0], &g, tolerances(name).1, &format!("{name}/optimized"));
    }
}

#[test]
fn compiled_engine_matches_jax_oracle_small_nets() {
    let Some(m) = manifest() else { return };
    for name in ["c_htwk", "c_bh", "detector", "segmenter"] {
        let g = load_golden(name).unwrap();
        let entry = m.entry(name).unwrap();
        let Some(mut e) =
            engine_or_skip(&m, EngineKind::Compiled, name, &EngineOptions::with_buckets(&[1]))
        else {
            continue;
        };
        let out = e.infer(&golden_input(entry.seed, &entry.input_shape)).unwrap();
        check(&out[0], &g, tolerances(name).1, &format!("{name}/compiled"));
    }
}

#[test]
fn compiled_engine_matches_jax_oracle_big_nets() {
    // MobileNetV2 + VGG19 exercise the weights-as-args path.
    let Some(m) = manifest() else { return };
    for name in ["mobilenetv2", "vgg19"] {
        let g = load_golden(name).unwrap();
        let entry = m.entry(name).unwrap();
        let Some(mut e) =
            engine_or_skip(&m, EngineKind::Compiled, name, &EngineOptions::with_buckets(&[1]))
        else {
            continue;
        };
        let out = e.infer(&golden_input(entry.seed, &entry.input_shape)).unwrap();
        check(&out[0], &g, tolerances(name).1, &format!("{name}/compiled"));
    }
}

/// Registry-driven engine parity: iterate every `EngineKind`, build what
/// this host supports, and assert all outputs agree with the naive oracle
/// within the documented tolerances. Runs on a plain CI runner against the
/// built-in `tiny_cnn` (no artifacts needed) and additionally against every
/// manifest model when artifacts are present.
#[test]
fn every_available_engine_agrees_with_the_oracle() {
    // Part 1: programmatic spec — always runs.
    let spec = tiny_cnn(77);
    let mut rng = SplitMix64::new(3);
    let x = Tensor::from_vec(&[2, 8, 8, 3], rng.uniform_vec(2 * 8 * 8 * 3));
    let mut oracle =
        build_engine_from_spec(EngineKind::Naive, &spec, &EngineOptions::default()).unwrap();
    let want = oracle.infer(&x).unwrap();
    let mut covered = 0;
    for &kind in EngineKind::all() {
        // exact math so every engine shares the naive tolerance
        let Ok(mut e) = build_engine_from_spec(kind, &spec, &EngineOptions::exact()) else {
            continue; // compiled: artifact-backed only
        };
        assert_eq!(e.name(), kind.as_str());
        assert!(e.supports(&spec), "{kind} must support tiny_cnn");
        let got = e.infer(&x).unwrap();
        let d = want[0].max_abs_diff(&got[0]);
        assert!(d < 1e-4, "{kind}: tiny_cnn max |Δ| = {d}");
        covered += 1;
    }
    assert!(covered >= 2, "expected naive + optimized at minimum");

    // Part 2: every small manifest model, every available engine (the big
    // nets would take minutes under the scalar oracle; their compiled
    // parity is covered by `compiled_engine_matches_jax_oracle_big_nets`).
    let Some(m) = manifest() else { return };
    let names: Vec<String> = m
        .models
        .iter()
        .filter(|(_, e)| e.params <= 1_000_000)
        .map(|(n, _)| n.clone())
        .collect();
    for name in names {
        let entry = m.entry(&name).unwrap();
        let x = golden_input(entry.seed, &entry.input_shape);
        let mut oracle =
            build_engine(EngineKind::Naive, &m, &name, &EngineOptions::default()).unwrap();
        let want = oracle.infer(&x).unwrap();
        for &kind in EngineKind::all() {
            if kind == EngineKind::Naive {
                continue; // the oracle itself — part 1 covers the naive path
            }
            let opts = EngineOptions::with_buckets(&[1]);
            let Some(mut e) = engine_or_skip(&m, kind, &name, &opts) else { continue };
            let got = e.infer(&x).unwrap();
            let d = want[0].max_abs_diff(&got[0]);
            let tol = tolerances(&name).1;
            assert!(d < tol, "{name}/{kind}: max |Δ| = {d} (tol {tol})");
        }
    }
}

/// The `Program`-backed optimized engine must match the naive oracle
/// **bit-for-bit** with `approx: false` once the value-reassociating
/// lowering transforms are also off (`EngineOptions::bit_exact`): the §3.2
/// memory plan, arena spans, in-place aliasing and fused epilogues may
/// never change a single ulp. Runs on the built-in `tiny_cnn` always and
/// on the keras fixtures when the model files are present.
#[test]
fn program_backed_optimized_is_bit_exact_vs_naive() {
    fn assert_bits(spec: &compiled_nn::model::spec::ModelSpec, x: &Tensor) {
        let mut naive =
            build_engine_from_spec(EngineKind::Naive, spec, &EngineOptions::default()).unwrap();
        let mut opt =
            build_engine_from_spec(EngineKind::Optimized, spec, &EngineOptions::bit_exact())
                .unwrap();
        let a = naive.infer(x).unwrap();
        let b = opt.infer(x).unwrap();
        assert_eq!(a.len(), b.len(), "{}", spec.name);
        for (ta, tb) in a.iter().zip(&b) {
            assert_eq!(ta.shape(), tb.shape(), "{}", spec.name);
            assert_eq!(ta.data(), tb.data(), "bit drift on {}", spec.name);
        }
    }

    let spec = tiny_cnn(123);
    let mut rng = SplitMix64::new(41);
    let x = Tensor::from_vec(&[3, 8, 8, 3], rng.uniform_vec(3 * 8 * 8 * 3));
    assert_bits(&spec, &x);

    if !Path::new("models/c_bh.keras.json").exists() {
        eprintln!("skipping keras-fixture bit-exact cases: models/ absent");
        return;
    }
    for name in ["c_htwk", "c_bh", "detector", "segmenter"] {
        let spec =
            compiled_nn::model::keras::load_keras_model(Path::new("models"), name).unwrap();
        let mut shape = vec![1usize];
        shape.extend_from_slice(&spec.input_shape);
        let n: usize = shape.iter().product();
        let mut rng = SplitMix64::new(7);
        let x = Tensor::from_vec(&shape, rng.uniform_vec(n));
        assert_bits(&spec, &x);
    }
}

#[test]
fn batched_buckets_agree_with_batch1() {
    let Some(m) = manifest() else { return };
    let Some(mut e) = engine_or_skip(&m, EngineKind::Compiled, "c_bh", &EngineOptions::default())
    else {
        return;
    };
    let buckets = e.batch_buckets().expect("compiled engine has buckets");
    assert!(buckets.contains(&1) && buckets.contains(&8), "{buckets:?}");
    let mut rng = SplitMix64::new(77);
    let x8 = Tensor::from_vec(&[8, 32, 32, 1], rng.uniform_vec(8 * 32 * 32));
    let out8 = e.infer(&x8).unwrap();
    for i in 0..8 {
        let xi = x8.slice_batch(i, i + 1);
        let oi = e.infer(&xi).unwrap();
        let d = oi[0].max_abs_diff(&out8[0].slice_batch(i, i + 1));
        assert!(d < 1e-5, "row {i}: {d}");
    }
}

#[test]
fn wrong_batch_is_a_clean_error() {
    let Some(m) = manifest() else { return };
    let Some(mut e) =
        engine_or_skip(&m, EngineKind::Compiled, "c_bh", &EngineOptions::with_buckets(&[1]))
    else {
        return;
    };
    let x = Tensor::zeros(&[2, 32, 32, 1]);
    let err = e.infer(&x).unwrap_err().to_string();
    assert!(err.contains("buckets"), "{err}");
}
