//! Cross-language golden tests: every engine vs the exact JAX oracle
//! recorded at artifact-build time (`artifacts/golden/<name>.json`).
//! The input regenerates bit-identically from the shared SplitMix64 stream.
//!
//! Tolerances: exact engines ≤ 1e-3 (f32 accumulation-order drift across
//! conv implementations); compiled/optimized outputs additionally carry the
//! §3.4 approximation error on softmax/sigmoid heads.

use std::path::Path;

use compiled_nn::compiler::exec::{CompileOptions, OptInterp};
use compiled_nn::model::load::load_model;
use compiled_nn::nn::interp::NaiveInterp;
use compiled_nn::nn::tensor::Tensor;
use compiled_nn::runtime::artifact::Manifest;
use compiled_nn::runtime::executor::{CompiledModel, Runtime};
use compiled_nn::util::json::Json;
use compiled_nn::util::rng::{golden_seed, SplitMix64};

struct Golden {
    shape: Vec<usize>,
    sample: Vec<f32>,
    sum: f64,
    absmax: f64,
}

fn load_golden(name: &str) -> Option<Golden> {
    let path = Path::new("artifacts/golden").join(format!("{name}.json"));
    let text = std::fs::read_to_string(path).ok()?;
    let j = Json::parse(&text).unwrap();
    let o = &j.req_arr("outputs").unwrap()[0];
    Some(Golden {
        shape: o.req("shape").unwrap().as_usize_vec().unwrap(),
        sample: o
            .req_arr("sample")
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect(),
        sum: o.req_f64("sum").unwrap(),
        absmax: o.req_f64("absmax").unwrap(),
    })
}

fn golden_input(seed: u64, shape: &[usize]) -> Tensor {
    let mut full = vec![1];
    full.extend_from_slice(shape);
    let n: usize = full.iter().product();
    let mut rng = SplitMix64::new(golden_seed(seed));
    Tensor::from_vec(&full, rng.uniform_vec(n))
}

fn check(out: &Tensor, g: &Golden, tol: f32, label: &str) {
    assert_eq!(out.shape(), &g.shape[..], "{label}: shape");
    for (i, (&got, &want)) in out.data().iter().zip(&g.sample).enumerate() {
        assert!(
            (got - want).abs() < tol,
            "{label}: sample[{i}] {got} vs {want} (tol {tol})"
        );
    }
    let sum: f64 = out.data().iter().map(|&v| v as f64).sum();
    // sum over up to ~12k outputs; scale tolerance with count
    let sum_tol = tol as f64 * out.len() as f64;
    assert!((sum - g.sum).abs() < sum_tol.max(1e-3), "{label}: sum {sum} vs {}", g.sum);
    let absmax = out.data().iter().fold(0.0f32, |m, &v| m.max(v.abs())) as f64;
    assert!((absmax - g.absmax).abs() < tol as f64 * 10.0, "{label}: absmax");
}

/// (exact-engine tol, approx-engine tol) per model head type.
fn tolerances(name: &str) -> (f32, f32) {
    match name {
        "c_htwk" | "segmenter" => (1e-3, 0.06), // softmax head → fast-exp error
        "c_bh" | "detector" => (1e-3, 3e-3),    // sigmoid head → Eq. 4/5 error
        _ => (1e-3, 3e-3),
    }
}

fn manifest() -> Option<Manifest> {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping golden tests: run `make artifacts` first");
        return None;
    }
    Some(Manifest::load_default().unwrap())
}

#[test]
fn naive_interpreter_matches_jax_oracle() {
    let Some(m) = manifest() else { return };
    for name in ["c_htwk", "c_bh", "detector", "segmenter"] {
        let g = load_golden(name).unwrap();
        let entry = m.entry(name).unwrap();
        let spec = load_model(&m.models_dir, name).unwrap();
        let out = NaiveInterp::new(spec).unwrap().infer(&golden_input(entry.seed, &entry.input_shape)).unwrap();
        check(&out[0], &g, tolerances(name).0, &format!("{name}/naive"));
    }
}

#[test]
fn optimized_interpreter_matches_jax_oracle() {
    let Some(m) = manifest() else { return };
    for name in ["c_htwk", "c_bh", "detector", "segmenter"] {
        let g = load_golden(name).unwrap();
        let entry = m.entry(name).unwrap();
        let spec = load_model(&m.models_dir, name).unwrap();
        let mut e = OptInterp::new(&spec, CompileOptions::default()).unwrap();
        let out = e.infer(&golden_input(entry.seed, &entry.input_shape)).unwrap();
        check(&out[0], &g, tolerances(name).1, &format!("{name}/optimized"));
    }
}

#[test]
fn compiled_engine_matches_jax_oracle_small_nets() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::new().unwrap();
    for name in ["c_htwk", "c_bh", "detector", "segmenter"] {
        let g = load_golden(name).unwrap();
        let entry = m.entry(name).unwrap();
        let model = CompiledModel::load_buckets(&rt, &m, entry, &[1]).unwrap();
        let out = model.execute(&rt, &golden_input(entry.seed, &entry.input_shape)).unwrap();
        check(&out[0], &g, tolerances(name).1, &format!("{name}/compiled"));
    }
}

#[test]
fn compiled_engine_matches_jax_oracle_big_nets() {
    // MobileNetV2 + VGG19 exercise the weights-as-args path.
    let Some(m) = manifest() else { return };
    let rt = Runtime::new().unwrap();
    for name in ["mobilenetv2", "vgg19"] {
        let g = load_golden(name).unwrap();
        let entry = m.entry(name).unwrap();
        let model = CompiledModel::load_buckets(&rt, &m, entry, &[1]).unwrap();
        let out = model.execute(&rt, &golden_input(entry.seed, &entry.input_shape)).unwrap();
        let tol = if name == "vgg19" { 0.06 } else { 3e-3 }; // vgg19 → softmax
        check(&out[0], &g, tol, &format!("{name}/compiled"));
    }
}

#[test]
fn batched_buckets_agree_with_batch1() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::new().unwrap();
    let entry = m.entry("c_bh").unwrap();
    let model = CompiledModel::load(&rt, &m, "c_bh").unwrap();
    let mut rng = SplitMix64::new(77);
    let x8 = Tensor::from_vec(&[8, 32, 32, 1], rng.uniform_vec(8 * 32 * 32));
    let out8 = model.execute(&rt, &x8).unwrap();
    for i in 0..8 {
        let xi = x8.slice_batch(i, i + 1);
        let oi = model.execute(&rt, &xi).unwrap();
        let d = oi[0].max_abs_diff(&out8[0].slice_batch(i, i + 1));
        assert!(d < 1e-5, "row {i}: {d}");
    }
    let _ = entry;
}

#[test]
fn wrong_batch_is_a_clean_error() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::new().unwrap();
    let model = CompiledModel::load_buckets(&rt, &m, m.entry("c_bh").unwrap(), &[1]).unwrap();
    let x = Tensor::zeros(&[2, 32, 32, 1]);
    let err = model.execute(&rt, &x).unwrap_err().to_string();
    assert!(err.contains("buckets"), "{err}");
}
