//! Engine-equivalence properties on the real evaluation networks: the
//! optimized interpreter (with exact math) must agree with the naive
//! interpreter on random inputs, folding must agree with the Python pass's
//! artifacts, and the capability flags must reproduce Table 1's `-` cells.
//!
//! All engines are constructed through the `EngineKind` registry
//! (`build_engine_from_spec`), never by hand.

use std::path::Path;

use compiled_nn::compiler::exec::CompileOptions;
use compiled_nn::compiler::{fuse, memory};
use compiled_nn::engine::{build_engine_from_spec, Engine, EngineKind, EngineOptions};
use compiled_nn::model::load::load_model;
use compiled_nn::nn::interp::Capabilities;
use compiled_nn::nn::tensor::Tensor;
use compiled_nn::util::propcheck::check;
use compiled_nn::util::rng::SplitMix64;

fn have_models() -> bool {
    Path::new("models/c_bh.json").exists()
}

/// Optimized-interpreter options with every approximation disabled.
fn exact_opts(fold_bn: bool) -> EngineOptions {
    EngineOptions {
        compile: CompileOptions { fold_bn, approx: false, ..CompileOptions::default() },
        buckets: None,
    }
}

#[test]
fn optimized_exact_equals_naive_on_random_inputs() {
    if !have_models() {
        return;
    }
    for name in ["c_htwk", "c_bh", "segmenter", "detector"] {
        let spec = load_model(Path::new("models"), name).unwrap();
        let naive = std::cell::RefCell::new(
            build_engine_from_spec(EngineKind::Naive, &spec, &EngineOptions::default()).unwrap(),
        );
        let opt = std::cell::RefCell::new(
            build_engine_from_spec(EngineKind::Optimized, &spec, &exact_opts(true)).unwrap(),
        );
        let item: usize = spec.input_shape.iter().product();
        check(
            &format!("engines_agree_{name}"),
            5,
            |r: &mut SplitMix64| {
                let mut shape = vec![1usize];
                shape.extend_from_slice(&spec.input_shape);
                Tensor::from_vec(&shape, r.uniform_vec(item))
            },
            |x| {
                let a = naive.borrow_mut().infer(x).map_err(|e| e.to_string())?;
                let b = opt.borrow_mut().infer(x).map_err(|e| e.to_string())?;
                let d = a[0].max_abs_diff(&b[0]);
                if d < 1e-3 {
                    Ok(())
                } else {
                    Err(format!("max |Δ| = {d}"))
                }
            },
        );
    }
}

#[test]
fn capability_flags_reproduce_table1_dashes() {
    if !have_models() {
        return;
    }
    // Paper: RoboDNN and tiny-dnn "do not support upsampling and depthwise
    // separable convolution layers", so Detector/Segmenter/MobileNetV2 show
    // `-` while the classifiers and VGG19 have numbers.
    let expect = [
        ("c_htwk", true),
        ("c_bh", true),
        ("detector", true), // our detector uses plain convs — supported
        ("segmenter", false), // upsampling
        ("mobilenetv2", false), // depthwise
        ("vgg19", true),
    ];
    for (name, supported) in expect {
        let spec = load_model(Path::new("models"), name).unwrap();
        assert_eq!(
            Capabilities::LEGACY.supports(&spec),
            supported,
            "{name} legacy support"
        );
        assert!(Capabilities::FULL.supports(&spec), "{name} full support");
        // Engine::supports must mirror the FULL capability set.
        for kind in [EngineKind::Naive, EngineKind::Optimized] {
            let e = build_engine_from_spec(kind, &spec, &EngineOptions::default()).unwrap();
            assert!(e.supports(&spec), "{name}/{kind}");
        }
    }
}

#[test]
fn rust_fold_agrees_with_python_folded_blob() {
    if !have_models() {
        return;
    }
    // aot.py saved mobilenetv2's *folded* blob for the runtime; our fold of
    // the original spec must produce a functionally identical network.
    let spec = load_model(Path::new("models"), "mobilenetv2").unwrap();
    let folded = fuse::fold_batchnorm(&spec);
    assert_eq!(fuse::bn_count(&folded), 0);
    // run both through the optimized interpreter (exact) on one input
    let mut a = build_engine_from_spec(EngineKind::Optimized, &spec, &exact_opts(false)).unwrap();
    let mut b =
        build_engine_from_spec(EngineKind::Optimized, &folded, &exact_opts(false)).unwrap();
    let mut rng = SplitMix64::new(4);
    let x = Tensor::from_vec(&[1, 96, 96, 3], rng.uniform_vec(96 * 96 * 3));
    let oa = a.infer(&x).unwrap();
    let ob = b.infer(&x).unwrap();
    let d = oa[0].max_abs_diff(&ob[0]);
    assert!(d < 1e-2, "folded mobilenetv2 drifted: {d}");
}

#[test]
fn memory_plan_savings_on_real_models() {
    if !have_models() {
        return;
    }
    // §3.2's claim: lifetime sharing + in-place reuse cut the working set.
    for name in ["c_bh", "segmenter", "mobilenetv2", "vgg19"] {
        let spec = load_model(Path::new("models"), name).unwrap();
        let folded = fuse::fold_batchnorm(&spec);
        let with = memory::plan(&folded, true).unwrap();
        let without = memory::plan(&folded, false).unwrap();
        assert!(
            with.peak_elements() < without.naive_total,
            "{name}: no savings ({} vs {})",
            with.peak_elements(),
            without.naive_total
        );
        let ratio = with.peak_elements() as f64 / without.naive_total as f64;
        assert!(ratio < 0.8, "{name}: only {:.2}× saved", 1.0 - ratio);
    }
}

#[test]
fn memory_reuse_visible_through_engine_trait() {
    if !have_models() {
        return;
    }
    // The Engine::memory_bytes hook exposes the §3.2 arena for ablations.
    let spec = load_model(Path::new("models"), "c_bh").unwrap();
    let mut with =
        build_engine_from_spec(EngineKind::Optimized, &spec, &EngineOptions::default()).unwrap();
    let mut without = build_engine_from_spec(
        EngineKind::Optimized,
        &spec,
        &EngineOptions {
            compile: CompileOptions { reuse_memory: false, ..CompileOptions::default() },
            buckets: None,
        },
    )
    .unwrap();
    let mut rng = SplitMix64::new(6);
    let x = Tensor::from_vec(&[1, 32, 32, 1], rng.uniform_vec(32 * 32));
    with.infer(&x).unwrap();
    without.infer(&x).unwrap();
    let a = with.memory_bytes().unwrap();
    let b = without.memory_bytes().unwrap();
    assert!(a < b, "reuse arena {a} must undercut no-reuse {b}");
}

#[test]
fn plan_summary_reports_real_model_lowering() {
    if !have_models() {
        return;
    }
    // mobilenetv2: 34 BNs to fold, depthwise towers, residual adds — the
    // plan_summary hook must surface what the Program lowering did.
    let spec = load_model(Path::new("models"), "mobilenetv2").unwrap();
    let e = build_engine_from_spec(EngineKind::Optimized, &spec, &EngineOptions::default())
        .unwrap();
    let s = e.plan_summary().expect("optimized engine lowers a program");
    assert!(s.folded_bn >= 30, "{s}");
    assert!(s.in_place_steps + s.elided_steps >= 1, "{s}");
    assert!(s.steps.iter().any(|l| l.contains("dwconv")), "{s}");
    // the naive oracle has no lowering stage
    let naive =
        build_engine_from_spec(EngineKind::Naive, &spec, &EngineOptions::default()).unwrap();
    assert!(naive.plan_summary().is_none());
}

#[test]
fn skip_connection_network_survives_planning() {
    if !have_models() {
        return;
    }
    // segmenter has a concat skip — lifetimes overlap across the decoder.
    let spec = load_model(Path::new("models"), "segmenter").unwrap();
    let mut e =
        build_engine_from_spec(EngineKind::Optimized, &spec, &EngineOptions::default()).unwrap();
    let mut naive =
        build_engine_from_spec(EngineKind::Naive, &spec, &EngineOptions::default()).unwrap();
    let mut rng = SplitMix64::new(12);
    let x = Tensor::from_vec(&[1, 80, 80, 3], rng.uniform_vec(80 * 80 * 3));
    let a = naive.infer(&x).unwrap();
    let b = e.infer(&x).unwrap();
    assert!(a[0].max_abs_diff(&b[0]) < 0.06);
}

#[test]
fn residual_network_survives_planning() {
    if !have_models() {
        return;
    }
    // mobilenetv2 has residual adds — the in-place planner must not clobber
    // the saved branch.
    let spec = load_model(Path::new("models"), "mobilenetv2").unwrap();
    let mut opt_exact =
        build_engine_from_spec(EngineKind::Optimized, &spec, &exact_opts(true)).unwrap();
    let mut naive =
        build_engine_from_spec(EngineKind::Naive, &spec, &EngineOptions::default()).unwrap();
    let mut rng = SplitMix64::new(13);
    let x = Tensor::from_vec(&[1, 96, 96, 3], rng.uniform_vec(96 * 96 * 3));
    let a = naive.infer(&x).unwrap();
    let b = opt_exact.infer(&x).unwrap();
    let d = a[0].max_abs_diff(&b[0]);
    assert!(d < 1e-2, "mobilenetv2 optimized drifted: {d}");
}
