//! Persistent compiled-artifact cache behavior, end to end: two serving
//! rounds in ONE process against the same cache directory. The first round
//! populates the cache (every registration lowers and saves an artifact);
//! the second round must come up **without a single `Program::lower`
//! call** — every registration mmap-loads its artifact — and the cache
//! hits must land in the per-model serving metrics.
//!
//! This is its own test binary because it sets `COMPILED_NN_CACHE_DIR`
//! before the global `ProgramCache` initializes; sharing a process with
//! tests that assert exact uncached `lower_count()` deltas (serving_stress)
//! would poison their accounting. An operator/CI-exported
//! `COMPILED_NN_CACHE_DIR` is honored; otherwise a per-process temp dir is
//! used so local runs start cold.

use std::time::Duration;

use compiled_nn::compiler::artifact::ProgramCache;
use compiled_nn::compiler::program::lower_count;
use compiled_nn::coordinator::server::{Coordinator, CoordinatorConfig};
use compiled_nn::engine::EngineKind;
use compiled_nn::model::builder::tiny_cnn;
use compiled_nn::model::spec::ModelSpec;
use compiled_nn::nn::simd::WeightDtype;
use compiled_nn::nn::tensor::Tensor;
use compiled_nn::runtime::artifact::Manifest;
use compiled_nn::util::rng::SplitMix64;

const ITEM: usize = 8 * 8 * 3;

fn model(name: &str, seed: u64) -> ModelSpec {
    let mut spec = tiny_cnn(seed);
    spec.name = name.to_string();
    spec
}

fn config() -> CoordinatorConfig {
    CoordinatorConfig {
        max_wait: Duration::from_micros(300),
        queue_depth: 512,
        engine: EngineKind::Optimized,
        workers: 2,
        intra_threads: 1,
        weight_dtype: WeightDtype::F32,
    }
}

/// One serving round: start a coordinator, register both models, push a
/// little traffic through each, and return the outputs for a fixed input
/// plus each model's (cache_hits, cache_misses) metric counters.
fn serving_round(x0: &Tensor) -> (Vec<Vec<f32>>, Vec<(u64, u64)>) {
    let coord = Coordinator::start(Manifest::empty(), config()).unwrap();
    let mut outs = Vec::new();
    let mut cache = Vec::new();
    let mut rng = SplitMix64::new(9);
    for (name, seed) in [("cache_a", 91), ("cache_b", 92)] {
        let client = coord.register_spec(&model(name, seed), &[1, 4]).unwrap();
        for _ in 0..8 {
            let x = Tensor::from_vec(&[8, 8, 3], rng.uniform_vec(ITEM));
            let out = client.infer(x).unwrap();
            assert_eq!(out.shape(), &[1, 10]);
        }
        outs.push(client.infer(x0.clone()).unwrap().data().to_vec());
        let m = coord.metrics(name).unwrap();
        assert_eq!(m.errors.get(), 0, "{name} had errors");
        cache.push((m.cache_hits.get(), m.cache_misses.get()));
    }
    coord.shutdown();
    (outs, cache)
}

#[test]
fn second_round_serves_from_cache_with_zero_lowerings() {
    // Point the global cache at a directory BEFORE its first use. CI may
    // export the var itself (the cache-behavior leg does); locally, fall
    // back to a per-process temp dir so the first round is genuinely cold.
    if std::env::var_os("COMPILED_NN_CACHE_DIR").is_none() {
        let dir = std::env::temp_dir().join(format!("cnn-cache-{}", std::process::id()));
        std::env::set_var("COMPILED_NN_CACHE_DIR", &dir);
    }
    assert!(ProgramCache::global().dir().is_some(), "cache did not pick up the env var");

    let x0 = Tensor::from_vec(&[8, 8, 3], SplitMix64::new(424242).uniform_vec(ITEM));

    // Round 1: populate. Each registration either lowers + saves (cold
    // dir) or hits an artifact a previous CI round left behind — either
    // way every registration is accounted for in lowers + hits.
    let lowers0 = lower_count();
    let c0 = ProgramCache::global().counters();
    let (outs1, _) = serving_round(&x0);
    let round1_lowers = lower_count() - lowers0;
    let c1 = ProgramCache::global().counters();
    assert_eq!(
        round1_lowers + (c1.hits - c0.hits),
        2,
        "each registration must either lower once or hit the cache"
    );

    // Round 2: a fresh coordinator over the now-warm cache. Zero
    // lowerings — both programs come off the mmap — and the hits show up
    // in both the global counters and the per-model serving metrics.
    let lowers1 = lower_count();
    let (outs2, cache2) = serving_round(&x0);
    assert_eq!(lower_count() - lowers1, 0, "warm cache still re-lowered");
    let c2 = ProgramCache::global().counters();
    assert!(c2.hits >= c1.hits + 2, "expected 2 more cache hits, got {:?}", c2);
    for (name, (hits, misses)) in ["cache_a", "cache_b"].iter().zip(&cache2) {
        assert_eq!(*hits, 1, "{name}: registration cache hit not recorded in metrics");
        assert_eq!(*misses, 0, "{name}: warm registration counted a miss");
    }

    // and the cached artifacts serve bitwise-identical results
    assert_eq!(outs1, outs2, "cache round-trip changed served outputs");
}
