//! Concurrent-serving stress: one coordinator, two spec-registered models
//! (no artifact manifest needed, so this runs on every CI runner), worker
//! pools over a shared `Program`, and ≥8 client threads hammering the TCP
//! front end — including straight through shutdown.
//!
//! Locks down the three coordinator bugs that the old single executor
//! thread masked:
//!   * dropped batcher `JoinHandle`s (teardown raced in-flight replies)
//!   * the `register` check-then-insert race (two batchers, leaked queue)
//!   * the TCP accept thread's one-shot `models()` snapshot (models
//!     registered after server start were "unknown" forever)

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use compiled_nn::compiler::program::lower_count;
use compiled_nn::coordinator::server::{Coordinator, CoordinatorConfig};
use compiled_nn::coordinator::tcp::{TcpClient, TcpServer};
use compiled_nn::engine::EngineKind;
use compiled_nn::model::builder::tiny_cnn;
use compiled_nn::model::spec::ModelSpec;
use compiled_nn::nn::tensor::Tensor;
use compiled_nn::runtime::artifact::Manifest;
use compiled_nn::util::rng::SplitMix64;

/// Serializes the tests in this binary so the global `lower_count()`
/// deltas are exact per test.
static SERIAL: Mutex<()> = Mutex::new(());

const ITEM: usize = 8 * 8 * 3;

fn model(name: &str, seed: u64) -> ModelSpec {
    let mut spec = tiny_cnn(seed);
    spec.name = name.to_string();
    spec
}

fn config(workers: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        max_wait: Duration::from_micros(300),
        queue_depth: 512,
        engine: EngineKind::Optimized,
        workers,
    }
}

#[test]
fn two_models_eight_tcp_threads_exact_accounting() {
    let _serial = SERIAL.lock().unwrap();
    let lowers_before = lower_count();
    let coord = Coordinator::start(Manifest::empty(), config(4)).unwrap();
    let a = coord.register_spec(&model("stress_a", 11), &[1, 4, 8]).unwrap();
    let b = coord.register_spec(&model("stress_b", 12), &[1, 4, 8]).unwrap();
    assert_eq!(a.info.workers, 4);
    assert_eq!(a.info.engine, "optimized");
    // one lowering per model, shared by all 4 workers — never one per worker
    assert_eq!(lower_count() - lowers_before, 2, "Program::lower ran per worker");

    let server = TcpServer::start(coord.clone(), "127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();

    let threads = 8;
    let per_thread = 40;
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let name = if t % 2 == 0 { "stress_a" } else { "stress_b" };
                let mut client = TcpClient::connect(&addr).unwrap();
                let mut rng = SplitMix64::new(7000 + t as u64);
                for _ in 0..per_thread {
                    // TcpClient checks the response id against the request
                    // id, so a duplicated or crossed reply fails loudly
                    let out = client.infer(name, rng.uniform_vec(ITEM)).unwrap();
                    assert_eq!(out.shape(), &[1, 10]);
                    let s: f32 = out.data().iter().sum();
                    assert!((s - 1.0).abs() < 1e-3, "softmax head sums to {s}");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    // exact accounting: every request sent is counted exactly once
    let sent_per_model = (threads / 2 * per_thread) as u64;
    for name in ["stress_a", "stress_b"] {
        let m = coord.metrics(name).unwrap();
        assert_eq!(m.requests.get(), sent_per_model, "{name} lost/duplicated requests");
        assert_eq!(m.errors.get(), 0, "{name} had errors");
        assert_eq!(m.inflight.get(), 0, "{name} leaked in-flight batches");
        assert!(m.latency.count() == sent_per_model, "{name} latency samples");
    }
    drop(server);
    coord.shutdown();
}

#[test]
fn concurrent_same_name_registration_spawns_one_lane() {
    let _serial = SERIAL.lock().unwrap();
    let lowers_before = lower_count();
    let coord = Coordinator::start(Manifest::empty(), config(2)).unwrap();

    let spec = model("race", 21);
    let handles: Vec<_> = (0..8)
        .map(|_| {
            let coord = coord.clone();
            let spec = spec.clone();
            std::thread::spawn(move || coord.register_spec(&spec, &[1, 4]).unwrap())
        })
        .collect();
    let clients: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    // one engine, one lowering, one batcher: every caller got the same lane
    let lowers = lower_count() - lowers_before;
    assert_eq!(lowers, 1, "registration raced into {lowers} lowerings");
    for c in &clients[1..] {
        assert!(
            Arc::ptr_eq(&clients[0].metrics, &c.metrics),
            "two registrations of one name produced distinct serving lanes"
        );
    }

    // and the lane works: traffic through any client lands in one counter
    let mut rng = SplitMix64::new(3);
    for c in &clients {
        c.infer(Tensor::from_vec(&[8, 8, 3], rng.uniform_vec(ITEM))).unwrap();
    }
    assert_eq!(clients[0].metrics.requests.get(), clients.len() as u64);
    coord.shutdown();
}

#[test]
fn models_registered_after_server_start_are_served() {
    let _serial = SERIAL.lock().unwrap();
    let coord = Coordinator::start(Manifest::empty(), config(2)).unwrap();
    // server comes up with NO models registered
    let server = TcpServer::start(coord.clone(), "127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();
    let mut client = TcpClient::connect(&addr).unwrap();
    let mut rng = SplitMix64::new(5);

    // unknown model: a clean error response, not a dead connection
    let err = client.infer("late", rng.uniform_vec(ITEM)).unwrap_err().to_string();
    assert!(err.contains("not registered"), "{err}");

    // register AFTER the accept thread started — a startup snapshot of
    // `coord.models()` would answer "unknown model" forever
    coord.register_spec(&model("late", 31), &[1, 4]).unwrap();
    let out = client.infer("late", rng.uniform_vec(ITEM)).unwrap();
    assert_eq!(out.shape(), &[1, 10]);

    // and a second model, on a connection that already resolved the first
    coord.register_spec(&model("later", 32), &[1, 4]).unwrap();
    assert_eq!(client.infer("later", rng.uniform_vec(ITEM)).unwrap().shape(), &[1, 10]);
    drop(server);
    coord.shutdown();
}

#[test]
fn hammering_through_shutdown_loses_no_replies() {
    let _serial = SERIAL.lock().unwrap();
    let coord = Coordinator::start(Manifest::empty(), config(4)).unwrap();
    let a = coord.register_spec(&model("teardown_a", 41), &[1, 4, 8]).unwrap();
    let b = coord.register_spec(&model("teardown_b", 42), &[1, 4, 8]).unwrap();

    let metrics = [a.metrics.clone(), b.metrics.clone()];
    let stop = Arc::new(AtomicBool::new(false));
    let handles: Vec<_> = (0..8)
        .map(|t| {
            let client = if t % 2 == 0 { a.clone() } else { b.clone() };
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut rng = SplitMix64::new(9000 + t as u64);
                let (mut oks, mut errs) = (0u64, 0u64);
                while !stop.load(Ordering::SeqCst) {
                    let x = Tensor::from_vec(&[8, 8, 3], rng.uniform_vec(ITEM));
                    // every call must complete — Ok, or the designed
                    // shutdown error — never hang on a dropped reply
                    match client.infer(x) {
                        Ok(out) => {
                            assert_eq!(out.shape(), &[1, 10]);
                            oks += 1;
                        }
                        Err(_) => {
                            // teardown reached this model's queue; it
                            // never re-opens, so stop offering
                            errs += 1;
                            break;
                        }
                    }
                }
                (oks, errs)
            })
        })
        .collect();

    // let traffic build, then tear down while requests are in flight.
    // shutdown() joins batchers and workers, so when it returns every
    // in-flight reply has been delivered — nothing is raced at teardown.
    std::thread::sleep(Duration::from_millis(150));
    coord.shutdown();
    stop.store(true, Ordering::SeqCst);

    let mut total_ok = 0;
    for h in handles {
        let (oks, _errs) = h.join().expect("client thread hung on a lost reply");
        total_ok += oks;
    }
    assert!(total_ok > 0, "stress produced no successful traffic");
    // every successful reply was executed and counted exactly once; the
    // executed count may exceed it only by batches whose replies raced the
    // *client loop* stopping, never by lost work
    let executed: u64 = metrics.iter().map(|m| m.requests.get()).sum();
    assert!(executed >= total_ok, "metrics lost requests: {executed} < {total_ok}");
    for m in &metrics {
        assert_eq!(m.inflight.get(), 0, "in-flight batches leaked through shutdown");
    }
}
