//! Concurrent-serving stress: one coordinator, spec-registered models (no
//! artifact manifest needed, so this runs on every CI runner), worker
//! pools over a shared `Program`, and the event-loop TCP front end under
//! pipelined bursts, overload, hot-swap, and shutdown.
//!
//! Locks down the serving lifecycle guarantees:
//!   * exact reply accounting across ≥8 threads and 64 pipelined
//!     connections (no lost, duplicated, or crossed replies)
//!   * admission control: under synthetic overload every request gets a
//!     result or a structured `overloaded` error — nothing vanishes
//!   * hot-swap under fire: zero lost replies, the lane converges to the
//!     new artifact, generation bumps
//!   * shutdown: idle open connections neither hang `shutdown()` nor
//!     outlive it; hammering straight through coordinator teardown loses
//!     no replies
//!   * the active/total connection gauges track disconnects

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use compiled_nn::compiler::artifact::{save_program, spec_content_hash};
use compiled_nn::compiler::exec::OptInterp;
use compiled_nn::compiler::program::{lower_count, CompileOptions, Program};
use compiled_nn::coordinator::protocol::Response;
use compiled_nn::coordinator::server::{Coordinator, CoordinatorConfig};
use compiled_nn::coordinator::tcp::{TcpClient, TcpOptions, TcpServer};
use compiled_nn::engine::EngineKind;
use compiled_nn::model::builder::tiny_cnn;
use compiled_nn::model::spec::ModelSpec;
use compiled_nn::nn::simd::WeightDtype;
use compiled_nn::nn::tensor::Tensor;
use compiled_nn::runtime::artifact::Manifest;
use compiled_nn::util::rng::SplitMix64;

/// Serializes the tests in this binary so the global `lower_count()`
/// deltas are exact per test.
static SERIAL: Mutex<()> = Mutex::new(());

const ITEM: usize = 8 * 8 * 3;

fn model(name: &str, seed: u64) -> ModelSpec {
    let mut spec = tiny_cnn(seed);
    spec.name = name.to_string();
    spec
}

fn config(workers: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        max_wait: Duration::from_micros(300),
        queue_depth: 512,
        engine: EngineKind::Optimized,
        workers,
        intra_threads: 1,
        weight_dtype: WeightDtype::F32,
    }
}

/// Spin until `cond` holds (the event loop observes connects/disconnects
/// asynchronously); panics after 5s.
fn wait_for(mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(5);
    while !cond() {
        assert!(Instant::now() < deadline, "condition not reached within 5s");
        std::thread::sleep(Duration::from_millis(5));
    }
}

#[test]
fn two_models_eight_tcp_threads_exact_accounting() {
    let _serial = SERIAL.lock().unwrap();
    let lowers_before = lower_count();
    let coord = Coordinator::start(Manifest::empty(), config(4)).unwrap();
    let a = coord.register_spec(&model("stress_a", 11), &[1, 4, 8]).unwrap();
    let b = coord.register_spec(&model("stress_b", 12), &[1, 4, 8]).unwrap();
    assert_eq!(a.info.workers, 4);
    assert_eq!(a.info.engine, "optimized");
    assert_eq!(b.info.generation, 1);
    // one lowering per model, shared by all 4 workers — never one per worker
    assert_eq!(lower_count() - lowers_before, 2, "Program::lower ran per worker");

    let server = TcpServer::start(coord.clone(), "127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();

    let threads = 8;
    let per_thread = 40;
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let name = if t % 2 == 0 { "stress_a" } else { "stress_b" };
                let mut client = TcpClient::connect(&addr).unwrap();
                let mut rng = SplitMix64::new(7000 + t as u64);
                for _ in 0..per_thread {
                    // TcpClient checks the response id against the request
                    // id, so a duplicated or crossed reply fails loudly
                    let out = client.infer(name, rng.uniform_vec(ITEM)).unwrap();
                    assert_eq!(out.shape(), &[1, 10]);
                    let s: f32 = out.data().iter().sum();
                    assert!((s - 1.0).abs() < 1e-3, "softmax head sums to {s}");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    // exact accounting: every request sent is counted exactly once
    let sent_per_model = (threads / 2 * per_thread) as u64;
    for name in ["stress_a", "stress_b"] {
        let m = coord.metrics(name).unwrap();
        assert_eq!(m.requests.get(), sent_per_model, "{name} lost/duplicated requests");
        assert_eq!(m.errors.get(), 0, "{name} had errors");
        assert_eq!(m.shed.get(), 0, "{name} shed without overload");
        assert_eq!(m.inflight.get(), 0, "{name} leaked in-flight batches");
        assert!(m.latency.count() == sent_per_model, "{name} latency samples");
    }
    drop(server);
    coord.shutdown();
}

#[test]
fn pipelined_burst_replies_all_arrive() {
    let _serial = SERIAL.lock().unwrap();
    let coord = Coordinator::start(Manifest::empty(), config(4)).unwrap();
    coord.register_spec(&model("pipe", 15), &[1, 4, 8]).unwrap();
    let server = TcpServer::start(coord.clone(), "127.0.0.1:0").unwrap();
    let mut client = TcpClient::connect(&server.addr().to_string()).unwrap();

    // write the whole burst before reading anything: the event loop must
    // keep consuming requests while responses pile into its write buffer
    let n = 100usize;
    let mut rng = SplitMix64::new(44);
    let mut ids: HashSet<u64> = HashSet::new();
    for _ in 0..n {
        ids.insert(client.send("pipe", rng.uniform_vec(ITEM)).unwrap());
    }
    client.flush().unwrap();

    // responses come back in completion order; every id exactly once
    for _ in 0..n {
        let resp = client.recv().unwrap();
        assert!(ids.remove(&resp.id()), "duplicate or unknown id {}", resp.id());
        match resp {
            Response::Ok { shape, .. } => assert_eq!(shape, vec![1, 10]),
            other => panic!("pipelined request failed: {other:?}"),
        }
    }
    assert!(ids.is_empty());
    let m = coord.metrics("pipe").unwrap();
    assert_eq!(m.requests.get(), n as u64);
    assert_eq!(m.errors.get(), 0);
    assert_eq!(m.shed.get(), 0);
    drop(server);
    coord.shutdown();
}

#[test]
fn sixty_four_pipelined_connections_exact_accounting() {
    let _serial = SERIAL.lock().unwrap();
    let coord = Coordinator::start(Manifest::empty(), config(4)).unwrap();
    coord.register_spec(&model("wide_a", 17), &[1, 4, 8]).unwrap();
    coord.register_spec(&model("wide_b", 18), &[1, 4, 8]).unwrap();
    let server = TcpServer::start(coord.clone(), "127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();

    // 64 concurrent connections, all pipelined from one driver thread —
    // only the event loop's multiplexing keeps this from deadlocking
    let conns = 64usize;
    let per_conn = 20usize;
    let mut clients: Vec<TcpClient> =
        (0..conns).map(|_| TcpClient::connect(&addr).unwrap()).collect();
    let mut rng = SplitMix64::new(55);
    let mut expected: Vec<HashSet<u64>> = Vec::new();
    for (i, client) in clients.iter_mut().enumerate() {
        let name = if i % 2 == 0 { "wide_a" } else { "wide_b" };
        let mut ids = HashSet::new();
        for _ in 0..per_conn {
            ids.insert(client.send(name, rng.uniform_vec(ITEM)).unwrap());
        }
        client.flush().unwrap();
        expected.push(ids);
    }
    for (client, ids) in clients.iter_mut().zip(expected.iter_mut()) {
        for _ in 0..per_conn {
            let resp = client.recv().unwrap();
            assert!(ids.remove(&resp.id()), "duplicate or unknown id {}", resp.id());
            assert!(matches!(resp, Response::Ok { .. }), "request failed: {resp:?}");
        }
        assert!(ids.is_empty(), "connection lost replies");
    }

    let sent_per_model = (conns / 2 * per_conn) as u64;
    for name in ["wide_a", "wide_b"] {
        let m = coord.metrics(name).unwrap();
        assert_eq!(m.requests.get(), sent_per_model, "{name} lost/duplicated requests");
        assert_eq!(m.errors.get(), 0);
    }
    assert_eq!(server.stats.total_connections(), conns as u64);
    drop(server);
    coord.shutdown();
}

#[test]
fn shed_under_overload_exact_accounting() {
    let _serial = SERIAL.lock().unwrap();
    let coord = Coordinator::start(Manifest::empty(), config(2)).unwrap();
    coord.register_spec(&model("ovl", 51), &[1, 4, 8]).unwrap();
    // synthetic overload: a tiny global in-flight cap against a big burst
    let opts = TcpOptions { max_inflight: 4, slo_p99_ms: 0.0 };
    let server = TcpServer::start_with(coord.clone(), "127.0.0.1:0", opts).unwrap();
    let addr = server.addr().to_string();

    let conns = 4usize;
    let per_conn = 125usize;
    let mut clients: Vec<TcpClient> =
        (0..conns).map(|_| TcpClient::connect(&addr).unwrap()).collect();
    let mut rng = SplitMix64::new(77);
    let mut expected: Vec<HashSet<u64>> = Vec::new();
    for client in clients.iter_mut() {
        let mut ids = HashSet::new();
        for _ in 0..per_conn {
            ids.insert(client.send("ovl", rng.uniform_vec(ITEM)).unwrap());
        }
        client.flush().unwrap();
        expected.push(ids);
    }

    // exact accounting: every single request gets exactly one response —
    // a result, or a structured `overloaded` error; nothing vanishes
    let (mut oks, mut sheds, mut other) = (0u64, 0u64, 0u64);
    for (client, ids) in clients.iter_mut().zip(expected.iter_mut()) {
        for _ in 0..per_conn {
            let resp = client.recv().unwrap();
            assert!(ids.remove(&resp.id()), "duplicate or unknown id {}", resp.id());
            if resp.is_overloaded() {
                sheds += 1;
            } else if matches!(resp, Response::Ok { .. }) {
                oks += 1;
            } else {
                other += 1;
            }
        }
        assert!(ids.is_empty(), "connection lost replies under overload");
    }
    let sent = (conns * per_conn) as u64;
    assert_eq!(oks + sheds + other, sent);
    assert_eq!(other, 0, "only results or structured `overloaded` are allowed");
    assert!(sheds > 0, "a 500-request burst against max_inflight=4 never shed");
    assert!(oks > 0, "admission control starved the lane completely");

    // counters agree with the wire, exactly: executed == ok replies,
    // shed == overloaded replies, and shed requests were never executed
    let m = coord.metrics("ovl").unwrap();
    assert_eq!(m.requests.get(), oks);
    assert_eq!(m.shed.get(), sheds);
    assert_eq!(m.errors.get(), 0);
    assert_eq!(server.stats.shed(), sheds);
    assert_eq!(server.stats.inflight(), 0, "in-flight gauge leaked");
    drop(server);
    coord.shutdown();
}

#[test]
fn hot_swap_under_fire_loses_no_replies() {
    let _serial = SERIAL.lock().unwrap();
    let lowers_before = lower_count();
    let coord = Coordinator::start(Manifest::empty(), config(4)).unwrap();
    let v1 = coord.register_spec(&model("swap_m", 61), &[1, 4, 8]).unwrap();
    assert_eq!(v1.info.generation, 1);

    let stop = Arc::new(AtomicBool::new(false));
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let client = v1.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut rng = SplitMix64::new(6000 + t as u64);
                let mut oks = 0u64;
                while !stop.load(Ordering::SeqCst) {
                    let x = Tensor::from_vec(&[8, 8, 3], rng.uniform_vec(ITEM));
                    // zero lost / failed replies across the swap
                    let out = client.infer(x).expect("request lost across hot-swap");
                    assert_eq!(out.shape(), &[1, 10]);
                    oks += 1;
                }
                oks
            })
        })
        .collect();

    // let traffic build, swap mid-fire, keep firing
    std::thread::sleep(Duration::from_millis(100));
    let x0 = Tensor::from_vec(&[8, 8, 3], SplitMix64::new(1234).uniform_vec(ITEM));
    let before = v1.infer(x0.clone()).unwrap();
    let v2 = coord.hot_swap_spec(&model("swap_m", 62), &[1, 4, 8]).unwrap();
    assert_eq!(v2.info.generation, 2, "hot-swap must bump the generation");
    std::thread::sleep(Duration::from_millis(100));
    stop.store(true, Ordering::SeqCst);
    let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert!(total > 0, "stress produced no traffic");

    // the lane now serves the new weights (requests dispatched after the
    // swap run the new artifact)…
    let after = v2.infer(x0.clone()).unwrap();
    assert!(before.max_abs_diff(&after) > 1e-6, "swap did not change the served artifact");
    // …and they are exactly the weights a fresh seed-62 registration serves
    let reference = coord.register_spec(&model("swap_ref", 62), &[1, 4, 8]).unwrap();
    let expect = reference.infer(x0).unwrap();
    assert!(after.max_abs_diff(&expect) < 1e-6, "swapped artifact differs from seed-62");

    let m = coord.metrics("swap_m").unwrap();
    assert_eq!(m.errors.get(), 0, "hot-swap caused request errors");
    // lowerings: swap_m v1 + the swap rebuild + swap_ref — never per worker
    assert_eq!(lower_count() - lowers_before, 3);

    // a shape-changing swap is refused and the lane keeps serving
    let mut wider = model("swap_m", 63);
    wider.input_shape = vec![16, 16, 3];
    let err = coord.hot_swap_spec(&wider, &[1, 4, 8]).unwrap_err().to_string();
    assert!(err.contains("input shape"), "{err}");
    let still = v2.infer(Tensor::from_vec(&[8, 8, 3], vec![0.1; ITEM])).unwrap();
    assert_eq!(still.shape(), &[1, 10]);
    coord.shutdown();
}

/// The dtype half of hot-swap: a live f32 model is requantized to its i8
/// twin under fire. Zero lost replies, the generation bumps, and the lane
/// converges to exactly what a directly-compiled i8 engine produces.
#[test]
fn hot_swap_to_quantized_twin_under_fire() {
    let _serial = SERIAL.lock().unwrap();
    let lowers_before = lower_count();
    let coord = Coordinator::start(Manifest::empty(), config(4)).unwrap();
    let v1 = coord.register_spec(&model("quant_m", 71), &[1, 4, 8]).unwrap();
    assert_eq!(v1.info.generation, 1);

    let x0 = Tensor::from_vec(&[8, 8, 3], SplitMix64::new(4321).uniform_vec(ITEM));
    let f32_out = v1.infer(x0.clone()).unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let client = v1.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut rng = SplitMix64::new(6100 + t as u64);
                let mut oks = 0u64;
                while !stop.load(Ordering::SeqCst) {
                    let x = Tensor::from_vec(&[8, 8, 3], rng.uniform_vec(ITEM));
                    // zero lost / failed replies across the requantization
                    let out = client.infer(x).expect("request lost across dtype hot-swap");
                    assert_eq!(out.shape(), &[1, 10]);
                    oks += 1;
                }
                oks
            })
        })
        .collect();

    // requantize the live lane mid-fire: same spec, i8 weight storage
    std::thread::sleep(Duration::from_millis(100));
    let v2 = coord
        .hot_swap_spec_dtype(&model("quant_m", 71), &[1, 4, 8], WeightDtype::I8)
        .unwrap();
    assert_eq!(v2.info.generation, 2, "dtype hot-swap must bump the generation");
    std::thread::sleep(Duration::from_millis(100));
    stop.store(true, Ordering::SeqCst);
    let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert!(total > 0, "stress produced no traffic");

    let m = coord.metrics("quant_m").unwrap();
    assert_eq!(m.errors.get(), 0, "dtype hot-swap caused request errors");
    // lowerings so far: the f32 registration + the i8 rebuild — never one
    // per worker (asserted before the reference engine below lowers again)
    assert_eq!(lower_count() - lowers_before, 2);

    // the lane now serves the quantized artifact: identical to a
    // directly-compiled i8 engine over the same spec and options …
    let after = v2.infer(x0.clone()).unwrap();
    let mut reference = OptInterp::new(
        &model("quant_m", 71),
        CompileOptions {
            intra_threads: 1,
            weight_dtype: WeightDtype::I8,
            ..CompileOptions::default()
        },
    )
    .unwrap();
    let expect = reference
        .infer(&Tensor::from_vec(&[1, 8, 8, 3], x0.data().to_vec()))
        .unwrap();
    let conv = after.max_abs_diff(&expect[0]);
    assert!(conv < 1e-6, "lane diverged from the i8 reference by {conv}");
    // … visibly different from the f32 artifact it replaced, yet inside
    // the i8 accuracy envelope
    let moved = f32_out.max_abs_diff(&after);
    assert!(moved > 1e-7, "i8 swap left the served outputs bit-identical to f32");
    assert!(moved < 0.15, "i8 artifact drifted past the quantization envelope: {moved}");
    coord.shutdown();
}

/// The persistent-artifact half of hot-swap: a live lane is swapped to a
/// twin **loaded from a serialized artifact file** under fire. Zero lost
/// replies, the generation bumps exactly like `hot_swap_spec`, the swap
/// itself lowers nothing (the program comes off the mmap), and a
/// shape-changing artifact is refused while the lane keeps serving.
#[test]
fn hot_swap_to_artifact_twin_under_fire() {
    let _serial = SERIAL.lock().unwrap();
    let lowers_before = lower_count();
    let coord = Coordinator::start(Manifest::empty(), config(4)).unwrap();
    let v1 = coord.register_spec(&model("art_m", 81), &[1, 4, 8]).unwrap();
    assert_eq!(v1.info.generation, 1);

    // compile the seed-82 twin to an artifact file up front (1 lowering)
    let opts = CompileOptions { intra_threads: 1, ..CompileOptions::default() };
    let twin = model("art_m", 82);
    let program = Program::lower(&twin, opts).unwrap();
    let dir = std::env::temp_dir().join(format!("cnn-swap-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("art_m-82.cnnprog");
    save_program(&program, spec_content_hash(&twin), opts, &path).unwrap();

    let x0 = Tensor::from_vec(&[8, 8, 3], SplitMix64::new(8123).uniform_vec(ITEM));
    let before = v1.infer(x0.clone()).unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let client = v1.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut rng = SplitMix64::new(6200 + t as u64);
                let mut oks = 0u64;
                while !stop.load(Ordering::SeqCst) {
                    let x = Tensor::from_vec(&[8, 8, 3], rng.uniform_vec(ITEM));
                    // zero lost / failed replies across the artifact swap
                    let out = client.infer(x).expect("request lost across artifact hot-swap");
                    assert_eq!(out.shape(), &[1, 10]);
                    oks += 1;
                }
                oks
            })
        })
        .collect();

    // swap the live lane to the artifact-loaded twin mid-fire
    std::thread::sleep(Duration::from_millis(100));
    let v2 = coord.hot_swap_artifact("art_m", &path, &[1, 4, 8]).unwrap();
    assert_eq!(v2.info.generation, 2, "artifact hot-swap must bump the generation");
    std::thread::sleep(Duration::from_millis(100));
    stop.store(true, Ordering::SeqCst);
    let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert!(total > 0, "stress produced no traffic");

    let m = coord.metrics("art_m").unwrap();
    assert_eq!(m.errors.get(), 0, "artifact hot-swap caused request errors");
    // lowerings: the v1 registration + the twin compiled above — the swap
    // itself deserialized the program instead of lowering it
    assert_eq!(lower_count() - lowers_before, 2, "artifact swap re-lowered");

    // the lane serves the seed-82 weights the artifact carries …
    let after = v2.infer(x0.clone()).unwrap();
    assert!(before.max_abs_diff(&after) > 1e-6, "swap did not change the served artifact");
    // … exactly: the mmap-loaded program is a bitwise twin of the one
    // serialized above
    let mut reference = OptInterp::from_program(program);
    let expect = reference
        .infer(&Tensor::from_vec(&[1, 8, 8, 3], x0.data().to_vec()))
        .unwrap();
    assert!(
        after.max_abs_diff(&expect[0]) < 1e-6,
        "swapped lane diverged from the serialized program"
    );

    // a shape-changing artifact is refused and the lane keeps serving
    let wide = compiled_nn::model::builder::wide_cnn(7);
    let wide_prog = Program::lower(&wide, opts).unwrap();
    let wide_path = dir.join("wide.cnnprog");
    save_program(&wide_prog, spec_content_hash(&wide), opts, &wide_path).unwrap();
    let err = coord.hot_swap_artifact("art_m", &wide_path, &[1, 4, 8]).unwrap_err();
    assert!(err.to_string().contains("input shape"), "{err}");
    let still = v2.infer(Tensor::from_vec(&[8, 8, 3], vec![0.1; ITEM])).unwrap();
    assert_eq!(still.shape(), &[1, 10]);
    let _ = std::fs::remove_dir_all(&dir);
    coord.shutdown();
}

#[test]
fn idle_connection_does_not_outlive_shutdown() {
    let _serial = SERIAL.lock().unwrap();
    let coord = Coordinator::start(Manifest::empty(), config(1)).unwrap();
    let mut server = TcpServer::start(coord.clone(), "127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();

    // an idle connection: opened, never written to
    let mut idle = TcpClient::connect(&addr).unwrap();
    wait_for(|| server.stats.active_connections() == 1);

    // shutdown must close it and join the I/O thread promptly — the old
    // thread-per-connection server leaked threads blocked in read here
    let t = Instant::now();
    server.shutdown();
    assert!(t.elapsed() < Duration::from_secs(10), "shutdown hung on an idle connection");
    assert_eq!(server.stats.active_connections(), 0, "connection outlived shutdown");

    // client side observes the close (EOF or reset), not a hang
    let err = idle.recv().unwrap_err().to_string().to_lowercase();
    assert!(
        err.contains("server closed connection") || err.contains("reset"),
        "expected a closed connection, got: {err}"
    );
    coord.shutdown();
}

#[test]
fn connection_gauges_track_disconnects() {
    let _serial = SERIAL.lock().unwrap();
    let coord = Coordinator::start(Manifest::empty(), config(1)).unwrap();
    let server = TcpServer::start(coord.clone(), "127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();

    let c1 = TcpClient::connect(&addr).unwrap();
    let c2 = TcpClient::connect(&addr).unwrap();
    let c3 = TcpClient::connect(&addr).unwrap();
    wait_for(|| server.stats.active_connections() == 3);
    assert_eq!(server.stats.total_connections(), 3);

    drop(c1);
    drop(c2);
    wait_for(|| server.stats.active_connections() == 1);
    assert_eq!(server.stats.total_connections(), 3, "total is monotonic");

    drop(c3);
    wait_for(|| server.stats.active_connections() == 0);
    assert_eq!(server.stats.total_connections(), 3);
    drop(server);
    coord.shutdown();
}

#[test]
fn concurrent_same_name_registration_spawns_one_lane() {
    let _serial = SERIAL.lock().unwrap();
    let lowers_before = lower_count();
    let coord = Coordinator::start(Manifest::empty(), config(2)).unwrap();

    let spec = model("race", 21);
    let handles: Vec<_> = (0..8)
        .map(|_| {
            let coord = coord.clone();
            let spec = spec.clone();
            std::thread::spawn(move || coord.register_spec(&spec, &[1, 4]).unwrap())
        })
        .collect();
    let clients: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    // one engine, one lowering, one batcher: every caller got the same lane
    let lowers = lower_count() - lowers_before;
    assert_eq!(lowers, 1, "registration raced into {lowers} lowerings");
    for c in &clients[1..] {
        assert!(
            Arc::ptr_eq(&clients[0].metrics, &c.metrics),
            "two registrations of one name produced distinct serving lanes"
        );
    }

    // and the lane works: traffic through any client lands in one counter
    let mut rng = SplitMix64::new(3);
    for c in &clients {
        c.infer(Tensor::from_vec(&[8, 8, 3], rng.uniform_vec(ITEM))).unwrap();
    }
    assert_eq!(clients[0].metrics.requests.get(), clients.len() as u64);
    coord.shutdown();
}

#[test]
fn models_registered_after_server_start_are_served() {
    let _serial = SERIAL.lock().unwrap();
    let coord = Coordinator::start(Manifest::empty(), config(2)).unwrap();
    // server comes up with NO models registered
    let server = TcpServer::start(coord.clone(), "127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();
    let mut client = TcpClient::connect(&addr).unwrap();
    let mut rng = SplitMix64::new(5);

    // unknown model: a clean error response, not a dead connection
    let err = client.infer("late", rng.uniform_vec(ITEM)).unwrap_err().to_string();
    assert!(err.contains("not registered"), "{err}");

    // register AFTER the I/O thread started — a startup snapshot of
    // `coord.models()` would answer "unknown model" forever
    coord.register_spec(&model("late", 31), &[1, 4]).unwrap();
    let out = client.infer("late", rng.uniform_vec(ITEM)).unwrap();
    assert_eq!(out.shape(), &[1, 10]);

    // and a second model, on a connection that already resolved the first
    coord.register_spec(&model("later", 32), &[1, 4]).unwrap();
    assert_eq!(client.infer("later", rng.uniform_vec(ITEM)).unwrap().shape(), &[1, 10]);
    drop(server);
    coord.shutdown();
}

#[test]
fn hammering_through_shutdown_loses_no_replies() {
    let _serial = SERIAL.lock().unwrap();
    let coord = Coordinator::start(Manifest::empty(), config(4)).unwrap();
    let a = coord.register_spec(&model("teardown_a", 41), &[1, 4, 8]).unwrap();
    let b = coord.register_spec(&model("teardown_b", 42), &[1, 4, 8]).unwrap();

    let metrics = [a.metrics.clone(), b.metrics.clone()];
    let stop = Arc::new(AtomicBool::new(false));
    let handles: Vec<_> = (0..8)
        .map(|t| {
            let client = if t % 2 == 0 { a.clone() } else { b.clone() };
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut rng = SplitMix64::new(9000 + t as u64);
                let (mut oks, mut errs) = (0u64, 0u64);
                while !stop.load(Ordering::SeqCst) {
                    let x = Tensor::from_vec(&[8, 8, 3], rng.uniform_vec(ITEM));
                    // every call must complete — Ok, or the designed
                    // shutdown error — never hang on a dropped reply
                    match client.infer(x) {
                        Ok(out) => {
                            assert_eq!(out.shape(), &[1, 10]);
                            oks += 1;
                        }
                        Err(_) => {
                            // teardown reached this model's queue; it
                            // never re-opens, so stop offering
                            errs += 1;
                            break;
                        }
                    }
                }
                (oks, errs)
            })
        })
        .collect();

    // let traffic build, then tear down while requests are in flight.
    // shutdown() joins batchers and workers, so when it returns every
    // in-flight reply has been delivered — nothing is raced at teardown.
    std::thread::sleep(Duration::from_millis(150));
    coord.shutdown();
    stop.store(true, Ordering::SeqCst);

    let mut total_ok = 0;
    for h in handles {
        let (oks, _errs) = h.join().expect("client thread hung on a lost reply");
        total_ok += oks;
    }
    assert!(total_ok > 0, "stress produced no successful traffic");
    // every successful reply was executed and counted exactly once; the
    // executed count may exceed it only by batches whose replies raced the
    // *client loop* stopping, never by lost work
    let executed: u64 = metrics.iter().map(|m| m.requests.get()).sum();
    assert!(executed >= total_ok, "metrics lost requests: {executed} < {total_ok}");
    for m in &metrics {
        assert_eq!(m.inflight.get(), 0, "in-flight batches leaked through shutdown");
    }
}
