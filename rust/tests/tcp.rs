//! TCP front-end integration: full wire round-trip against the batched
//! serving path, concurrent connections, protocol error handling.

use std::path::Path;
use std::time::Duration;

use compiled_nn::coordinator::config::ServingConfig;
use compiled_nn::coordinator::server::{Coordinator, CoordinatorConfig};
use compiled_nn::coordinator::tcp::{TcpClient, TcpServer};
use compiled_nn::engine::{build_engine, Engine, EngineKind, EngineOptions};
use compiled_nn::nn::tensor::Tensor;
use compiled_nn::runtime::artifact::Manifest;
use compiled_nn::util::rng::SplitMix64;

fn start_server(models: &[&str]) -> Option<(TcpServer, std::sync::Arc<Coordinator>)> {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping tcp tests: run `make artifacts` first");
        return None;
    }
    let manifest = Manifest::load_default().unwrap();
    let coord = Coordinator::start(
        manifest,
        CoordinatorConfig {
            max_wait: Duration::from_micros(300),
            queue_depth: 512,
            ..CoordinatorConfig::default()
        },
    )
    .unwrap();
    for m in models {
        coord.register(m).unwrap();
    }
    let server = TcpServer::start(coord.clone(), "127.0.0.1:0").unwrap();
    Some((server, coord))
}

#[test]
fn wire_roundtrip_matches_direct_execution() {
    let Some((mut server, coord)) = start_server(&["c_bh"]) else { return };
    let addr = server.addr().to_string();
    let mut client = TcpClient::connect(&addr).unwrap();

    let mut rng = SplitMix64::new(21);
    let input = rng.uniform_vec(32 * 32);
    let via_wire = client.infer("c_bh", input.clone()).unwrap();

    let manifest = Manifest::load_default().unwrap();
    let mut engine =
        build_engine(EngineKind::preferred(), &manifest, "c_bh", &EngineOptions::default())
            .unwrap();
    let direct = engine
        .infer(&Tensor::from_vec(&[1, 32, 32, 1], input))
        .unwrap();
    // f32 → f64 JSON → f32 is exact, so the wire adds no error
    assert!(via_wire.max_abs_diff(&direct[0]) < 1e-6);

    server.shutdown();
    coord.shutdown();
}

#[test]
fn concurrent_connections_share_batches() {
    let Some((mut server, coord)) = start_server(&["c_bh"]) else { return };
    let addr = server.addr().to_string();

    let mut handles = Vec::new();
    for t in 0..3 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = TcpClient::connect(&addr).unwrap();
            let mut rng = SplitMix64::new(50 + t);
            for _ in 0..10 {
                let out = client.infer("c_bh", rng.uniform_vec(32 * 32)).unwrap();
                assert_eq!(out.shape(), &[1, 1]);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = coord.metrics("c_bh").unwrap();
    assert_eq!(m.requests.get(), 30);
    assert_eq!(m.errors.get(), 0);

    server.shutdown();
    coord.shutdown();
}

#[test]
fn protocol_errors_are_reported_not_fatal() {
    let Some((mut server, coord)) = start_server(&["c_htwk"]) else { return };
    let addr = server.addr().to_string();
    let mut client = TcpClient::connect(&addr).unwrap();

    // unknown model
    let err = client.infer("nope", vec![0.0; 4]).unwrap_err().to_string();
    assert!(err.contains("not registered"), "{err}");
    // wrong input size
    let err = client.infer("c_htwk", vec![0.0; 3]).unwrap_err().to_string();
    assert!(err.contains("floats"), "{err}");
    // connection still usable afterwards
    let mut rng = SplitMix64::new(1);
    let ok = client.infer("c_htwk", rng.uniform_vec(16 * 16)).unwrap();
    assert_eq!(ok.shape(), &[1, 2]);

    server.shutdown();
    coord.shutdown();
}

#[test]
fn serving_config_drives_deployment() {
    if !Path::new("artifacts/manifest.json").exists() {
        return;
    }
    let cfg = ServingConfig::parse(
        r#"{"listen": "127.0.0.1:0", "max_wait_us": 300, "models": ["c_htwk", "c_bh"]}"#,
    )
    .unwrap();
    let manifest = Manifest::load_default().unwrap();
    let coord = Coordinator::start(manifest, cfg.coordinator_config()).unwrap();
    for m in &cfg.models {
        coord.register(m).unwrap();
    }
    let mut server = TcpServer::start(coord.clone(), &cfg.listen).unwrap();
    let mut client = TcpClient::connect(&server.addr().to_string()).unwrap();
    let mut rng = SplitMix64::new(2);
    assert_eq!(client.infer("c_htwk", rng.uniform_vec(256)).unwrap().shape(), &[1, 2]);
    assert_eq!(client.infer("c_bh", rng.uniform_vec(1024)).unwrap().shape(), &[1, 1]);
    server.shutdown();
    coord.shutdown();
}
