//! Keras front-end integration: the `.keras.json` exports must load into
//! specs that are layer-for-layer and numerically identical to the nnspec
//! versions of the same networks (§3.1 front-end parity).

use std::path::Path;

use compiled_nn::engine::{build_engine_from_spec, Engine, EngineKind, EngineOptions};
use compiled_nn::model::keras::load_keras_model;
use compiled_nn::model::load::load_model;
use compiled_nn::nn::tensor::Tensor;
use compiled_nn::util::rng::SplitMix64;

fn have_models() -> bool {
    Path::new("models/c_bh.keras.json").exists()
}

#[test]
fn keras_import_structurally_identical() {
    if !have_models() {
        return;
    }
    for name in ["c_htwk", "c_bh", "detector", "segmenter", "mobilenetv2", "vgg19"] {
        let a = load_model(Path::new("models"), name).unwrap();
        let b = load_keras_model(Path::new("models"), name).unwrap();
        assert_eq!(a.input_shape, b.input_shape, "{name}");
        assert_eq!(a.layers.len(), b.layers.len(), "{name}");
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            assert_eq!(la.name, lb.name, "{name}");
            assert_eq!(la.op, lb.op, "{name}/{}", la.name);
            assert_eq!(la.inputs, lb.inputs, "{name}/{}", la.name);
            assert_eq!(la.activation, lb.activation, "{name}/{}", la.name);
            assert_eq!(
                la.weights.keys().collect::<Vec<_>>(),
                lb.weights.keys().collect::<Vec<_>>(),
                "{name}/{}",
                la.name
            );
        }
        assert_eq!(a.outputs, b.outputs, "{name}");
        assert_eq!(a.weights, b.weights, "{name} blob");
    }
}

#[test]
fn keras_import_numerically_identical() {
    if !have_models() {
        return;
    }
    for name in ["c_htwk", "c_bh", "segmenter"] {
        let a = load_model(Path::new("models"), name).unwrap();
        let b = load_keras_model(Path::new("models"), name).unwrap();
        let mut rng = SplitMix64::new(8);
        let mut shape = vec![1usize];
        shape.extend_from_slice(&a.input_shape);
        let n: usize = shape.iter().product();
        let x = Tensor::from_vec(&shape, rng.uniform_vec(n));
        let oa = build_engine_from_spec(EngineKind::Naive, &a, &EngineOptions::default())
            .unwrap()
            .infer(&x)
            .unwrap();
        let ob = build_engine_from_spec(EngineKind::Naive, &b, &EngineOptions::default())
            .unwrap()
            .infer(&x)
            .unwrap();
        // identical weights + identical graph → bit-identical outputs
        assert_eq!(oa[0].data(), ob[0].data(), "{name}");
    }
}

#[test]
fn missing_keras_file_is_clean_error() {
    if !have_models() {
        return;
    }
    let err = load_keras_model(Path::new("models"), "no_such_model")
        .unwrap_err()
        .to_string();
    assert!(err.contains("no_such_model"), "{err}");
}
