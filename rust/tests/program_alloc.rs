//! Proves the `Program` acceptance property at the allocator: after
//! lowering and arena creation, `Program::run` (and `load_input`) perform
//! **zero heap allocations** — every shape, arena offset, kernel variant
//! and weight slice was resolved at lowering time. A counting allocator
//! wraps the system one; this file intentionally holds a single `#[test]`
//! so no concurrently running test can touch the counter mid-measurement.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use compiled_nn::compiler::program::{CompileOptions, Program};
use compiled_nn::model::builder::{square_mlp, tiny_cnn};
use compiled_nn::nn::tensor::Tensor;
use compiled_nn::util::rng::SplitMix64;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn program_run_is_allocation_free() {
    let spec = tiny_cnn(55);
    let program = Program::lower(&spec, CompileOptions::default()).unwrap();
    let mut arena = program.new_arena(2);
    let mut rng = SplitMix64::new(7);
    let x = Tensor::from_vec(&[2, 8, 8, 3], rng.uniform_vec(2 * 8 * 8 * 3));

    // warm-up (nothing lazily allocates, but keep the window symmetric)
    program.load_input(&mut arena, &x);
    program.run(&mut arena);

    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..16 {
        program.load_input(&mut arena, &x);
        program.run(&mut arena);
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after, before,
        "Program::run allocated on the hot path ({} allocations over 16 runs)",
        after - before
    );

    // Reading outputs allocates owned tensors — that is the engine API
    // boundary, outside `run`.
    let outs = program.read_outputs(&arena);
    assert_eq!(outs[0].shape(), &[2, 10]);

    // The §3.3 rotated-dense path (owned doubled-x scratch) must be just
    // as clean as the conv/pool path above.
    let mlp = square_mlp(9, 16, 2);
    let mlp_program = Program::lower(&mlp, CompileOptions::default()).unwrap();
    assert!(mlp_program.summary().rotated_dense > 0);
    let mut mlp_arena = mlp_program.new_arena(1);
    let mx = Tensor::from_vec(&[1, 16], rng.uniform_vec(16));
    mlp_program.load_input(&mut mlp_arena, &mx);
    mlp_program.run(&mut mlp_arena);
    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..16 {
        mlp_program.load_input(&mut mlp_arena, &mx);
        mlp_program.run(&mut mlp_arena);
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(after, before, "rotated-dense Program::run allocated on the hot path");
}
