//! Cross-engine differential fuzzing: random conv/dwconv/pool/dense graphs
//! (odd spatial dims, stride 2, SAME and VALID padding, channel counts off
//! the 4-lane grid, bias on/off — see `model::builder::random_conv_net`)
//! **and** random dense-only MLPs (`model::builder::random_mlp` — widths on
//! and off the 4-lane grid, square layers for the matvec tails) run through
//! **every available `EngineKind` × every `CompileOptions` scheme
//! combination** at batch sizes {1, 3, 8} — covering the all-tail matvec
//! path, full GEMM tiles, tiles + tail, and the per-batch arena spans —
//! and must match the `NaiveInterp` oracle within a per-dtype tolerance
//! (1e-4 of the output magnitude for f32 — see `tolerance_for` for the
//! bf16/i8 bounds). Since PR 7 the grid also forces every SIMD lane
//! width (scalar/4/8, 16 where detected) and the intra-op parallel split,
//! alone and combined with wide lanes; the dtype-generic weight pipeline
//! re-instantiates the whole scheme × lane × thread grid at bf16 and i8
//! weight storage. The bit-exact combo (pinned to scalar lanes, a single
//! task, and f32 storage) is additionally held to bit-for-bit equality on
//! the MLPs, batched included. The artifact round-trip test reuses the
//! same combo grid to prove `save_program`/`load_program` reproduce every
//! lowered program bitwise from the mmap'd file.
//!
//! Failures print the propcheck seed (`PROPCHECK_SEED=0x… cargo test
//! fuzz_`) plus the failing spec's own seed, so any case replays exactly.
//! CI pins `PROPCHECK_SEED` so the suite is deterministic in the pipeline.

use compiled_nn::compiler::exec::{
    CompileOptions, ConvScheme, DenseScheme, LaneSelect, WeightDtype,
};
use compiled_nn::engine::{build_engine_from_spec, Engine, EngineKind, EngineOptions};
use compiled_nn::model::builder::{random_conv_net, random_mlp};
use compiled_nn::model::spec::ModelSpec;
use compiled_nn::nn::tensor::Tensor;
use compiled_nn::util::propcheck::check;
use compiled_nn::util::rng::SplitMix64;

/// Every lowering-option combination the differential suite covers: all
/// four conv schemes, pool fusion on/off, the non-conv axes that change
/// kernel selection (dense scheme, folding, memory reuse), the fully
/// pinned bit-exact reference path, every forced lane width (16-lane only
/// where detected — all widths are *portable*, the gate just keeps the
/// suite representative of real dispatch), and the intra-op parallel
/// split on its own and combined with wide lanes. Approximations stay off
/// so every combo shares the oracle tolerance.
///
/// Since the dtype-generic weight pipeline, the scheme/lane/thread grid is
/// additionally instantiated at bf16 and i8 weight storage (the f32 rows
/// above already cover the full-precision axis); `tolerance_for` widens the
/// oracle bound per dtype. Bit-exact stays f32-only by construction.
fn combos() -> Vec<(String, CompileOptions)> {
    let base = CompileOptions { approx: false, ..CompileOptions::default() };
    let mut v: Vec<(String, CompileOptions)> = vec![
        ("auto".into(), base),
        ("bit-exact".into(), CompileOptions::bit_exact()),
        (
            "direct-nofuse".into(),
            CompileOptions { conv: ConvScheme::Direct, fuse_pool: false, ..base },
        ),
        (
            "im2col-nofuse".into(),
            CompileOptions { conv: ConvScheme::Im2col, fuse_pool: false, ..base },
        ),
        ("no-reuse".into(), CompileOptions { reuse_memory: false, ..base }),
        ("no-fold".into(), CompileOptions { fold_bn: false, ..base }),
    ];
    // the dtype axis: every conv/dense scheme, every forced lane width,
    // and the intra-op split, at every weight storage dtype
    for dtype in WeightDtype::ALL {
        let d = CompileOptions { weight_dtype: dtype, ..base };
        if dtype == WeightDtype::F32 {
            // "auto" above is the f32 default; skip the duplicate row
        } else {
            v.push((dtype.label().to_string(), d));
        }
        let rows = [
            ("direct", CompileOptions { conv: ConvScheme::Direct, ..d }),
            ("im2col", CompileOptions { conv: ConvScheme::Im2col, ..d }),
            ("generic", CompileOptions { conv: ConvScheme::Generic, ..d }),
            ("dense-rotated", CompileOptions { dense: DenseScheme::Rotated, ..d }),
            ("dense-broadcast", CompileOptions { dense: DenseScheme::Broadcast, ..d }),
            ("dense-generic", CompileOptions { dense: DenseScheme::Generic, ..d }),
            ("lanes-scalar", CompileOptions { lanes: LaneSelect::Scalar, ..d }),
            ("lanes-4", CompileOptions { lanes: LaneSelect::W4, ..d }),
            ("lanes-8", CompileOptions { lanes: LaneSelect::W8, ..d }),
            ("parallel", CompileOptions { intra_threads: 4, ..d }),
            (
                "lanes-8-parallel",
                CompileOptions { lanes: LaneSelect::W8, intra_threads: 4, ..d },
            ),
        ];
        for (tag, o) in rows {
            v.push((format!("{}-{tag}", dtype.label()), o));
        }
        if compiled_nn::cpu::Features::detect().avx512f {
            v.push((
                format!("{}-lanes-16", dtype.label()),
                CompileOptions { lanes: LaneSelect::W16, ..d },
            ));
        }
    }
    v
}

/// Oracle tolerance per weight dtype, as a multiple of the output scale.
///
/// * f32 panels are a reordering of the oracle's math: 1e-4 covers the
///   reassociated accumulation alone.
/// * bf16 rounds each weight to 8 mantissa bits (relative error ≤ 2⁻⁹);
///   through these ≤5-layer generated nets that stays well under 1%, so
///   2e-2 is tight while never flaking.
/// * i8 is scale-aware by construction: per-channel scales are max|w|/127,
///   so each weight carries ≤ scale/2 absolute error and a K-tap
///   accumulation over O(1) activations is bounded by K·max|w|/254 —
///   a few percent of the output scale for the generated shapes. 1.5e-1
///   leaves margin for layer compounding while still failing loudly on any
///   packing/dequantization bug (those are O(scale) wrong).
fn tolerance_for(dtype: WeightDtype) -> f32 {
    match dtype {
        WeightDtype::F32 => 1e-4,
        WeightDtype::Bf16 => 2e-2,
        WeightDtype::I8 => 1.5e-1,
    }
}

/// Batch sizes the suite draws: 1 (the serving fast path, all-tail
/// matvec), 3 (below the GEMM tile width — still all-tail), 8 (two full
/// register tiles, exercising the blocked GEMM paths and per-batch arena
/// spans).
const BATCHES: [usize; 3] = [1, 3, 8];

/// One differential case: run `spec` at a seed-drawn batch size through
/// every engine × combo and compare against the oracle. `strict_bit_exact`
/// additionally requires the bit-exact combo on the optimized engine to be
/// bit-for-bit (the MLP generator's ops all share the oracle's exact
/// accumulation order; conv nets keep the tolerance check only).
fn differential_case(
    spec: &ModelSpec,
    input_seed: u64,
    strict_bit_exact: bool,
) -> Result<(), String> {
    let mut rng = SplitMix64::new(input_seed);
    let batch = BATCHES[(input_seed % BATCHES.len() as u64) as usize];
    let item: usize = spec.input_shape.iter().product();
    let mut shape = vec![batch];
    shape.extend_from_slice(&spec.input_shape);
    let x = Tensor::from_vec(&shape, rng.uniform_vec(batch * item));

    let mut oracle =
        build_engine_from_spec(EngineKind::Naive, spec, &EngineOptions::default())
            .map_err(|e| e.to_string())?;
    let want = oracle.infer(&x).map_err(|e| e.to_string())?;
    let scale = want[0].data().iter().fold(1.0f32, |m, &v| m.max(v.abs()));

    for &kind in EngineKind::all() {
        if !kind.available() {
            continue; // compiled: needs a pjrt build + PJRT plugin
        }
        if kind == EngineKind::Naive {
            continue; // the oracle itself — already run above
        }
        for (label, opts) in combos() {
            let eopts = EngineOptions { compile: opts, buckets: None };
            let mut e = match build_engine_from_spec(kind, spec, &eopts) {
                Ok(e) => e,
                // only the compiled engine may beg off (it executes
                // AOT artifacts); an interpreter failing to lower a
                // generated graph is a real regression
                Err(_) if kind == EngineKind::Compiled => continue,
                Err(err) => {
                    return Err(format!(
                        "spec seed {}: {kind}/{label} failed to build: {err}",
                        spec.seed
                    ))
                }
            };
            let got = e.infer(&x).map_err(|e| {
                format!("spec seed {}: batch {batch}: {kind}/{label}: {e}", spec.seed)
            })?;
            if got.len() != want.len() {
                return Err(format!(
                    "spec seed {}: {kind}/{label}: {} outputs vs {}",
                    spec.seed,
                    got.len(),
                    want.len()
                ));
            }
            if strict_bit_exact && label == "bit-exact" && kind == EngineKind::Optimized {
                if want[0].data() != got[0].data() {
                    let d = want[0].max_abs_diff(&got[0]);
                    return Err(format!(
                        "spec seed {}: batch {batch}: {kind}/{label}: \
                         not bit-exact (max |Δ| = {d})",
                        spec.seed
                    ));
                }
                continue;
            }
            let d = want[0].max_abs_diff(&got[0]);
            let tol = tolerance_for(opts.weight_dtype) * scale;
            if d > tol {
                return Err(format!(
                    "spec seed {}: batch {batch}: {kind}/{label}: \
                     max |Δ| = {d} (scale {scale}, {} tol {tol})",
                    spec.seed,
                    opts.weight_dtype
                ));
            }
        }
    }
    Ok(())
}

#[test]
fn fuzz_every_engine_and_scheme_matches_naive() {
    check(
        "fuzz_engines_differential",
        48,
        |r: &mut SplitMix64| (random_conv_net(r), r.next_u64()),
        |(spec, input_seed)| differential_case(spec, *input_seed, false),
    );
}

/// The dense-path suite: random MLPs through the same engine × combo grid.
/// This is where the batch-blocked GEMM tiles, the rotated/broadcast/panel
/// tails and the vectorized dense epilogues get differentially hammered —
/// and where bit-exact is held to bitwise equality even at batch 8.
#[test]
fn fuzz_dense_gemm_mlps_match_naive() {
    check(
        "fuzz_mlp_differential",
        48,
        |r: &mut SplitMix64| (random_mlp(r), r.next_u64()),
        |(spec, input_seed)| differential_case(spec, *input_seed, true),
    );
}

/// Artifact round-trip axis: every scheme × lane × dtype combo above must
/// survive `save_program` → `load_program` **bitwise** — the loaded
/// program's weight panels borrow straight out of the mmap'd file, so any
/// codec slip (wrong tag, misaligned blob window, truncated scale vector)
/// shows up as a hard diff here, not as a tolerance flake. Runs a fixed
/// conv net and a fixed MLP through each combo at every batch size and
/// requires the serialized twin to reproduce the in-memory program's
/// outputs exactly.
#[test]
fn fuzz_artifact_roundtrip_is_bitwise_identical() {
    use compiled_nn::compiler::artifact::{load_program, save_program, spec_content_hash};
    use compiled_nn::compiler::program::{ArenaPool, Program};

    let dir = std::env::temp_dir().join(format!("cnn-artifact-fuzz-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create temp artifact dir");

    // fixed seeds → deterministic specs; one conv net, one dense MLP
    let mut gen = SplitMix64::new(0xA57F_AC70_5EED_0001);
    let specs = [random_conv_net(&mut gen), random_mlp(&mut gen)];

    for spec in &specs {
        let hash = spec_content_hash(spec);
        let item: usize = spec.input_shape.iter().product();
        for (label, opts) in combos() {
            let program = Program::lower(spec, opts).unwrap_or_else(|e| {
                panic!("spec seed {}: {label}: lowering failed: {e}", spec.seed)
            });
            let path = dir.join(format!("{}-{label}.cnnprog", spec.seed));
            save_program(&program, hash, opts, &path).unwrap_or_else(|e| {
                panic!("spec seed {}: {label}: save failed: {e}", spec.seed)
            });
            let (loaded, info) = load_program(&path).unwrap_or_else(|e| {
                panic!("spec seed {}: {label}: load failed: {e}", spec.seed)
            });
            assert_eq!(info.spec_hash, hash, "{label}: header spec hash drifted");

            let mut pool_a = ArenaPool::new();
            let mut pool_b = ArenaPool::new();
            for &batch in &BATCHES {
                let mut rng = SplitMix64::new(spec.seed ^ (batch as u64));
                let mut shape = vec![batch];
                shape.extend_from_slice(&spec.input_shape);
                let x = Tensor::from_vec(&shape, rng.uniform_vec(batch * item));
                let a = program.infer_pooled(&x, &mut pool_a).unwrap_or_else(|e| {
                    panic!("spec seed {}: {label}: in-memory run: {e}", spec.seed)
                });
                let b = loaded.infer_pooled(&x, &mut pool_b).unwrap_or_else(|e| {
                    panic!("spec seed {}: {label}: loaded run: {e}", spec.seed)
                });
                assert_eq!(a.len(), b.len(), "{label}: output count");
                if a[0].data() != b[0].data() {
                    let d = a[0].max_abs_diff(&b[0]);
                    panic!(
                        "spec seed {}: batch {batch}: {label}: loaded artifact \
                         is not bitwise identical (max |Δ| = {d})",
                        spec.seed
                    );
                }
            }
            let _ = std::fs::remove_file(&path);
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The §3.4 merged store loops must hold up under repeated inference over
/// pooled arenas too (state carried in kernel scratch would show up here).
#[test]
fn fuzz_fused_programs_are_stable_across_repeated_inference() {
    check(
        "fuzz_fused_repeat_stability",
        12,
        |r: &mut SplitMix64| (random_conv_net(r), r.next_u64()),
        |(spec, input_seed)| {
            let mut rng = SplitMix64::new(*input_seed);
            let batch = BATCHES[(input_seed % BATCHES.len() as u64) as usize];
            let item: usize = spec.input_shape.iter().product();
            let mut shape = vec![batch];
            shape.extend_from_slice(&spec.input_shape);
            let x = Tensor::from_vec(&shape, rng.uniform_vec(batch * item));
            let eopts = EngineOptions::exact();
            let mut e = build_engine_from_spec(EngineKind::Optimized, spec, &eopts)
                .map_err(|e| e.to_string())?;
            let first = e.infer(&x).map_err(|e| e.to_string())?;
            for round in 0..3 {
                let again = e.infer(&x).map_err(|e| e.to_string())?;
                let d = first[0].max_abs_diff(&again[0]);
                if d != 0.0 {
                    return Err(format!(
                        "spec seed {}: round {round} drifted by {d}",
                        spec.seed
                    ));
                }
            }
            Ok(())
        },
    );
}
