//! Accuracy deltas of the narrowed weight-storage dtypes (bf16 / i8)
//! against the f32 lowering, on the builtin nets (always) and the keras
//! fixture models (when `models/` is present, same gate as `tests/keras.rs`).
//!
//! The documented per-dtype envelopes, as multiples of the output scale:
//!
//! * **bf16** (`BF16_TOL` = 2e-2): each weight is rounded to 8 mantissa
//!   bits (relative error ≤ 2⁻⁹ per weight, round-to-nearest-even at pack
//!   time); through the fixture depths that stays well under 1%.
//! * **i8** (`I8_TOL` = 1.5e-1): per-output-channel scales are max|w|/127,
//!   so each weight carries ≤ scale/2 absolute error; a K-tap accumulation
//!   is bounded by K·max|w|/254 and compounds per layer — a few percent of
//!   the output scale in practice, and any packing/requantization bug
//!   overshoots this envelope by orders of magnitude.
//!
//! Run with `--nocapture` to see the measured deltas per model.

use std::path::Path;

use compiled_nn::compiler::exec::{CompileOptions, OptInterp, WeightDtype};
use compiled_nn::model::builder::{square_mlp, tiny_cnn, wide_cnn};
use compiled_nn::model::load::load_model;
use compiled_nn::model::spec::ModelSpec;
use compiled_nn::nn::tensor::Tensor;
use compiled_nn::util::rng::SplitMix64;

/// bf16 envelope (× output scale); see the module docs for the derivation.
const BF16_TOL: f32 = 2e-2;
/// i8 envelope (× output scale); see the module docs for the derivation.
const I8_TOL: f32 = 1.5e-1;

/// Max-abs output delta of `dtype` storage vs the f32 lowering of the same
/// spec (approximations off in both, so the dtype is the only difference),
/// plus the f32 output scale the bounds are relative to.
fn dtype_delta(spec: &ModelSpec, dtype: WeightDtype, batch: usize, seed: u64) -> (f32, f32) {
    let item: usize = spec.input_shape.iter().product();
    let mut shape = vec![batch];
    shape.extend_from_slice(&spec.input_shape);
    let x = Tensor::from_vec(&shape, SplitMix64::new(seed).uniform_vec(batch * item));
    let base = CompileOptions { approx: false, ..CompileOptions::default() };
    let a = OptInterp::new(spec, base).unwrap().infer(&x).unwrap();
    let b = OptInterp::new(spec, CompileOptions { weight_dtype: dtype, ..base })
        .unwrap()
        .infer(&x)
        .unwrap();
    let scale = a[0].data().iter().fold(1.0f32, |m, &v| m.max(v.abs()));
    (a[0].max_abs_diff(&b[0]), scale)
}

fn assert_deltas(spec: &ModelSpec, batch: usize, seed: u64) {
    let (d_bf16, scale) = dtype_delta(spec, WeightDtype::Bf16, batch, seed);
    let (d_i8, _) = dtype_delta(spec, WeightDtype::I8, batch, seed);
    println!(
        "{:>12}: bf16 Δ = {d_bf16:.3e}  i8 Δ = {d_i8:.3e}  (output scale {scale:.3e})",
        spec.name
    );
    assert!(
        d_bf16 <= BF16_TOL * scale,
        "{}: bf16 delta {d_bf16} exceeds {BF16_TOL} × scale {scale}",
        spec.name
    );
    assert!(
        d_i8 <= I8_TOL * scale,
        "{}: i8 delta {d_i8} exceeds {I8_TOL} × scale {scale}",
        spec.name
    );
}

#[test]
fn builtin_nets_stay_inside_documented_dtype_bounds() {
    for spec in [tiny_cnn(81), wide_cnn(82), square_mlp(83, 32, 3)] {
        assert_deltas(&spec, 2, 910);
    }
    // sanity that the narrowed artifact is actually narrowed: conv panels
    // always store the requested dtype, so i8 must move the outputs
    let (d_i8, _) = dtype_delta(&tiny_cnn(81), WeightDtype::I8, 2, 910);
    assert!(d_i8 > 0.0, "i8 quantization produced bit-identical outputs");
}

fn have_models() -> bool {
    Path::new("models/c_bh.keras.json").exists()
}

#[test]
fn keras_fixtures_stay_inside_documented_dtype_bounds() {
    if !have_models() {
        return;
    }
    for name in ["c_htwk", "c_bh", "segmenter"] {
        let spec = load_model(Path::new("models"), name).unwrap();
        assert_deltas(&spec, 1, 911);
    }
}
