//! The model graph IR — the Rust-side view of the nnspec interchange format
//! (see `python/compile/spec.py`). This is what the paper's `Model` class
//! holds after reading a Keras HDF5 file: a computational graph of layers
//! plus the weight tensors referenced by offset into a flat blob.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Elementwise activation, possibly fused into a producing layer (§3.4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    Linear,
    Relu,
    Relu6,
    LeakyRelu,
    Sigmoid,
    Tanh,
}

impl Activation {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "linear" => Activation::Linear,
            "relu" => Activation::Relu,
            "relu6" => Activation::Relu6,
            "leaky_relu" => Activation::LeakyRelu,
            "sigmoid" => Activation::Sigmoid,
            "tanh" => Activation::Tanh,
            _ => bail!("unknown activation `{s}`"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Activation::Linear => "linear",
            Activation::Relu => "relu",
            Activation::Relu6 => "relu6",
            Activation::LeakyRelu => "leaky_relu",
            Activation::Sigmoid => "sigmoid",
            Activation::Tanh => "tanh",
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Padding {
    Same,
    Valid,
}

impl Padding {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "same" => Padding::Same,
            "valid" => Padding::Valid,
            _ => bail!("unknown padding `{s}`"),
        })
    }
}

/// Layer operation with its static attributes — everything the compiler
/// needs is known before any input arrives (the paper's core premise).
#[derive(Debug, Clone, PartialEq)]
pub enum LayerOp {
    Conv2d { kh: usize, kw: usize, out_ch: usize, stride: usize, padding: Padding, use_bias: bool },
    DepthwiseConv2d { kh: usize, kw: usize, stride: usize, padding: Padding, use_bias: bool },
    Dense { units: usize },
    BatchNorm { epsilon: f32 },
    MaxPool { kh: usize, kw: usize, stride: usize },
    AvgPool { kh: usize, kw: usize, stride: usize },
    GlobalAvgPool,
    Upsample { factor: usize },
    ZeroPad { pad: [usize; 4] }, // top, bottom, left, right
    Activation,
    Softmax,
    Add,
    Concat,
    Flatten,
}

impl LayerOp {
    pub fn name(&self) -> &'static str {
        match self {
            LayerOp::Conv2d { .. } => "conv2d",
            LayerOp::DepthwiseConv2d { .. } => "depthwise_conv2d",
            LayerOp::Dense { .. } => "dense",
            LayerOp::BatchNorm { .. } => "batchnorm",
            LayerOp::MaxPool { .. } => "maxpool",
            LayerOp::AvgPool { .. } => "avgpool",
            LayerOp::GlobalAvgPool => "globalavgpool",
            LayerOp::Upsample { .. } => "upsample",
            LayerOp::ZeroPad { .. } => "zeropad",
            LayerOp::Activation => "activation",
            LayerOp::Softmax => "softmax",
            LayerOp::Add => "add",
            LayerOp::Concat => "concat",
            LayerOp::Flatten => "flatten",
        }
    }
}

/// A named weight tensor: offset (in floats) + shape into the flat blob.
#[derive(Debug, Clone, PartialEq)]
pub struct WeightRef {
    pub offset: usize,
    pub shape: Vec<usize>,
}

impl WeightRef {
    pub fn size(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct Layer {
    pub name: String,
    pub op: LayerOp,
    pub inputs: Vec<String>,
    pub weights: BTreeMap<String, WeightRef>,
    pub activation: Activation,
    /// §3.5 fused post-activation affine (BN merged across a nonlinearity);
    /// weights `post_scale_w` / `post_shift_w` hold the channel vectors.
    pub post_scale: bool,
}

/// A complete model: graph + weights, as loaded from `models/<name>.json`.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    /// HWC input shape; the batch dimension is implicit (shape-specialized
    /// code is generated per batch size, like the paper's).
    pub input_shape: Vec<usize>,
    pub layers: Vec<Layer>,
    pub outputs: Vec<String>,
    pub seed: u64,
    pub weights: Vec<f32>,
}

impl ModelSpec {
    pub fn layer(&self, name: &str) -> Result<&Layer> {
        self.layers
            .iter()
            .find(|l| l.name == name)
            .with_context(|| format!("no layer `{name}`"))
    }

    pub fn weight(&self, layer: &Layer, key: &str) -> Result<&[f32]> {
        let r = layer
            .weights
            .get(key)
            .with_context(|| format!("layer `{}` has no weight `{key}`", layer.name))?;
        self.weights
            .get(r.offset..r.offset + r.size())
            .with_context(|| format!("weight `{key}` of `{}` out of blob bounds", layer.name))
    }

    pub fn weight_ref<'a>(&self, layer: &'a Layer, key: &str) -> Result<&'a WeightRef> {
        layer
            .weights
            .get(key)
            .with_context(|| format!("layer `{}` has no weight `{key}`", layer.name))
    }

    pub fn param_count(&self) -> usize {
        self.weights.len()
    }

    /// Static shape inference for every tensor (HWC / flat, batch implicit).
    /// Mirrors the Python Builder; `validate()` checks structural sanity.
    pub fn infer_shapes(&self) -> Result<BTreeMap<String, Vec<usize>>> {
        let mut shapes: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        shapes.insert("input".into(), self.input_shape.clone());
        for l in &self.layers {
            let input = shapes
                .get(&l.inputs[0])
                .with_context(|| format!("layer `{}` input `{}` not yet defined", l.name, l.inputs[0]))?
                .clone();
            let out = match &l.op {
                LayerOp::Conv2d { kh, kw, out_ch, stride, padding, .. } => {
                    let (h, w) = hw(&input, &l.name)?;
                    let (oh, ow) = conv_out(h, w, *kh, *kw, *stride, *padding);
                    vec![oh, ow, *out_ch]
                }
                LayerOp::DepthwiseConv2d { kh, kw, stride, padding, .. } => {
                    let (h, w) = hw(&input, &l.name)?;
                    let (oh, ow) = conv_out(h, w, *kh, *kw, *stride, *padding);
                    vec![oh, ow, input[2]]
                }
                LayerOp::Dense { units } => {
                    if input.len() != 1 {
                        bail!("dense `{}` needs flat input, got {:?}", l.name, input);
                    }
                    vec![*units]
                }
                LayerOp::BatchNorm { .. } | LayerOp::Activation | LayerOp::Softmax => input,
                LayerOp::MaxPool { kh, kw, stride } | LayerOp::AvgPool { kh, kw, stride } => {
                    let (h, w) = hw(&input, &l.name)?;
                    if h < *kh || w < *kw {
                        bail!("pool `{}` window {kh}x{kw} larger than input {h}x{w}", l.name);
                    }
                    // VALID pooling dims; identical to h/stride when the
                    // stride equals the window, correct when it does not.
                    vec![(h - kh) / stride + 1, (w - kw) / stride + 1, input[2]]
                }
                LayerOp::GlobalAvgPool => {
                    let (_, _) = hw(&input, &l.name)?;
                    vec![input[2]]
                }
                LayerOp::Upsample { factor } => {
                    let (h, w) = hw(&input, &l.name)?;
                    vec![h * factor, w * factor, input[2]]
                }
                LayerOp::ZeroPad { pad } => {
                    let (h, w) = hw(&input, &l.name)?;
                    vec![h + pad[0] + pad[1], w + pad[2] + pad[3], input[2]]
                }
                LayerOp::Add => {
                    let b = shapes
                        .get(&l.inputs[1])
                        .with_context(|| format!("add `{}` second input missing", l.name))?;
                    if *b != input {
                        bail!("add `{}` shape mismatch {:?} vs {:?}", l.name, input, b);
                    }
                    input
                }
                LayerOp::Concat => {
                    let b = shapes
                        .get(&l.inputs[1])
                        .with_context(|| format!("concat `{}` second input missing", l.name))?;
                    if b[..b.len() - 1] != input[..input.len() - 1] {
                        bail!("concat `{}` shape mismatch {:?} vs {:?}", l.name, input, b);
                    }
                    let mut out = input.clone();
                    *out.last_mut().unwrap() += b.last().unwrap();
                    out
                }
                LayerOp::Flatten => vec![input.iter().product()],
            };
            shapes.insert(l.name.clone(), out);
        }
        Ok(shapes)
    }

    /// Structural validation: unique names, topological input order, weight
    /// refs inside the blob, outputs defined, shapes inferable.
    pub fn validate(&self) -> Result<()> {
        let mut seen = std::collections::BTreeSet::new();
        seen.insert("input".to_string());
        for l in &self.layers {
            if !seen.insert(l.name.clone()) {
                bail!("duplicate layer name `{}`", l.name);
            }
            for i in &l.inputs {
                if !seen.contains(i) {
                    bail!("layer `{}` uses undefined input `{i}` (graph must be topologically ordered)", l.name);
                }
            }
            for (k, w) in &l.weights {
                if w.offset + w.size() > self.weights.len() {
                    bail!("weight `{k}` of `{}` exceeds blob ({} > {})",
                        l.name, w.offset + w.size(), self.weights.len());
                }
            }
            let arity = match l.op {
                LayerOp::Add | LayerOp::Concat => 2,
                _ => 1,
            };
            if l.inputs.len() != arity {
                bail!("layer `{}` ({}) expects {arity} inputs, has {}",
                    l.name, l.op.name(), l.inputs.len());
            }
        }
        for o in &self.outputs {
            if !seen.contains(o) {
                bail!("output `{o}` is not a layer");
            }
        }
        self.infer_shapes()?;
        Ok(())
    }
}

fn hw(shape: &[usize], name: &str) -> Result<(usize, usize)> {
    if shape.len() != 3 {
        bail!("layer `{name}` needs an HWC input, got {shape:?}");
    }
    Ok((shape[0], shape[1]))
}

/// SAME/VALID output spatial dims (stride ≥ 1), matching Keras/jax.
pub fn conv_out(h: usize, w: usize, kh: usize, kw: usize, stride: usize, padding: Padding) -> (usize, usize) {
    match padding {
        Padding::Same => (h.div_ceil(stride), w.div_ceil(stride)),
        Padding::Valid => ((h - kh) / stride + 1, (w - kw) / stride + 1),
    }
}

/// Paddings (top, bottom, left, right) for SAME conv, matching XLA.
pub fn same_pads(in_dim: usize, k: usize, stride: usize) -> (usize, usize) {
    let out = in_dim.div_ceil(stride);
    let total = ((out - 1) * stride + k).saturating_sub(in_dim);
    (total / 2, total - total / 2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_out_same_valid() {
        assert_eq!(conv_out(32, 32, 3, 3, 1, Padding::Same), (32, 32));
        assert_eq!(conv_out(32, 32, 3, 3, 2, Padding::Same), (16, 16));
        assert_eq!(conv_out(32, 32, 3, 3, 1, Padding::Valid), (30, 30));
        assert_eq!(conv_out(9, 9, 3, 3, 2, Padding::Same), (5, 5));
    }

    #[test]
    fn same_pads_matches_xla() {
        // 32 wide, k=3, s=1 → pad 1/1 ; s=2 → out 16, total (15*2+3)-32 = 1 → 0/1
        assert_eq!(same_pads(32, 3, 1), (1, 1));
        assert_eq!(same_pads(32, 3, 2), (0, 1));
        assert_eq!(same_pads(60, 3, 2), (0, 1));
    }

    #[test]
    fn activation_roundtrip() {
        for n in ["linear", "relu", "relu6", "leaky_relu", "sigmoid", "tanh"] {
            assert_eq!(Activation::parse(n).unwrap().name(), n);
        }
        assert!(Activation::parse("swish").is_err());
    }
}
