//! Programmatic model construction — the Rust-native way to define a network
//! without going through a spec file (mirrors `python/compile/spec.Builder`).
//! Weights are He-normal from a SplitMix64 stream, so a given (architecture,
//! seed) pair is fully deterministic.

use std::collections::BTreeMap;

use crate::util::rng::SplitMix64;

use super::spec::{conv_out, Activation, Layer, LayerOp, ModelSpec, Padding, WeightRef};

pub struct Builder {
    name: String,
    input_shape: Vec<usize>,
    seed: u64,
    rng: SplitMix64,
    layers: Vec<Layer>,
    blob: Vec<f32>,
    shapes: BTreeMap<String, Vec<usize>>,
    counter: usize,
}

impl Builder {
    pub fn new(name: &str, input_shape: &[usize], seed: u64) -> Self {
        let mut shapes = BTreeMap::new();
        shapes.insert("input".to_string(), input_shape.to_vec());
        Self {
            name: name.to_string(),
            input_shape: input_shape.to_vec(),
            seed,
            rng: SplitMix64::new(seed),
            layers: Vec::new(),
            blob: Vec::new(),
            shapes,
            counter: 0,
        }
    }

    pub fn shape_of(&self, name: &str) -> &[usize] {
        &self.shapes[name]
    }

    fn fresh(&mut self, prefix: &str) -> String {
        self.counter += 1;
        format!("{prefix}{}", self.counter)
    }

    fn alloc_he(&mut self, shape: &[usize], fan_in: usize) -> WeightRef {
        let n: usize = shape.iter().product();
        let scale = (2.0 / fan_in as f32).sqrt();
        let offset = self.blob.len();
        // Box–Muller over SplitMix64 uniforms (approximate normal is fine
        // for test weights; python builds its own weights via numpy).
        for _ in 0..n {
            let u1 = (self.rng.next_uniform() * 0.5 + 0.5).max(1e-7);
            let u2 = self.rng.next_uniform() * 0.5 + 0.5;
            let z = (-2.0 * u1.ln()).sqrt() * (std::f32::consts::TAU * u2).cos();
            self.blob.push(z * scale);
        }
        WeightRef { offset, shape: shape.to_vec() }
    }

    fn alloc_zeros(&mut self, n: usize) -> WeightRef {
        let offset = self.blob.len();
        self.blob.resize(offset + n, 0.0);
        WeightRef { offset, shape: vec![n] }
    }

    fn push(&mut self, layer: Layer, out_shape: Vec<usize>) -> String {
        let name = layer.name.clone();
        self.shapes.insert(name.clone(), out_shape);
        self.layers.push(layer);
        name
    }

    pub fn conv2d(&mut self, x: &str, out_ch: usize, k: usize, stride: usize, act: Activation) -> String {
        let in_shape = self.shapes[x].clone();
        let (h, w, c) = (in_shape[0], in_shape[1], in_shape[2]);
        let kernel = self.alloc_he(&[k, k, c, out_ch], k * k * c);
        let bias = self.alloc_zeros(out_ch);
        let (oh, ow) = conv_out(h, w, k, k, stride, Padding::Same);
        let name = self.fresh("conv");
        let mut weights = BTreeMap::new();
        weights.insert("kernel".into(), kernel);
        weights.insert("bias".into(), bias);
        self.push(
            Layer {
                name,
                op: LayerOp::Conv2d { kh: k, kw: k, out_ch, stride, padding: Padding::Same, use_bias: true },
                inputs: vec![x.to_string()],
                weights,
                activation: act,
                post_scale: false,
            },
            vec![oh, ow, out_ch],
        )
    }

    /// Full-control conv2d: padding mode and bias on/off (the differential
    /// fuzz generator exercises every combination).
    #[allow(clippy::too_many_arguments)]
    pub fn conv2d_cfg(
        &mut self,
        x: &str,
        out_ch: usize,
        k: usize,
        stride: usize,
        padding: Padding,
        use_bias: bool,
        act: Activation,
    ) -> String {
        let in_shape = self.shapes[x].clone();
        let (h, w, c) = (in_shape[0], in_shape[1], in_shape[2]);
        assert!(
            padding == Padding::Same || (h >= k && w >= k),
            "VALID conv kernel {k} larger than input {h}x{w}"
        );
        let kernel = self.alloc_he(&[k, k, c, out_ch], k * k * c);
        let (oh, ow) = conv_out(h, w, k, k, stride, padding);
        let name = self.fresh("conv");
        let mut weights = BTreeMap::new();
        weights.insert("kernel".into(), kernel);
        if use_bias {
            // uniform (not zero) bias so use_bias=true is observable
            let offset = self.blob.len();
            for _ in 0..out_ch {
                let v = self.rng.next_uniform() * 0.1;
                self.blob.push(v);
            }
            weights.insert("bias".into(), WeightRef { offset, shape: vec![out_ch] });
        }
        self.push(
            Layer {
                name,
                op: LayerOp::Conv2d { kh: k, kw: k, out_ch, stride, padding, use_bias },
                inputs: vec![x.to_string()],
                weights,
                activation: act,
                post_scale: false,
            },
            vec![oh, ow, out_ch],
        )
    }

    /// Depthwise conv2d (`[k, k, C, 1]` kernel, Keras layout).
    pub fn dwconv2d(
        &mut self,
        x: &str,
        k: usize,
        stride: usize,
        padding: Padding,
        act: Activation,
    ) -> String {
        let in_shape = self.shapes[x].clone();
        let (h, w, c) = (in_shape[0], in_shape[1], in_shape[2]);
        assert!(
            padding == Padding::Same || (h >= k && w >= k),
            "VALID dwconv kernel {k} larger than input {h}x{w}"
        );
        let kernel = self.alloc_he(&[k, k, c, 1], k * k);
        let bias = self.alloc_zeros(c);
        let (oh, ow) = conv_out(h, w, k, k, stride, padding);
        let name = self.fresh("dwconv");
        let mut weights = BTreeMap::new();
        weights.insert("kernel".into(), kernel);
        weights.insert("bias".into(), bias);
        self.push(
            Layer {
                name,
                op: LayerOp::DepthwiseConv2d { kh: k, kw: k, stride, padding, use_bias: true },
                inputs: vec![x.to_string()],
                weights,
                activation: act,
                post_scale: false,
            },
            vec![oh, ow, c],
        )
    }

    pub fn batchnorm(&mut self, x: &str) -> String {
        let shape = self.shapes[x].clone();
        let c = *shape.last().unwrap();
        let mut weights = BTreeMap::new();
        // Non-identity statistics so folding tests exercise real math.
        let offset = self.blob.len();
        for _ in 0..c {
            self.blob.push(0.1); // beta (same constant for every seed)
        }
        weights.insert("beta".into(), WeightRef { offset, shape: vec![c] });
        let g0 = self.blob.len();
        for i in 0..c {
            self.blob.push(1.0 + 0.05 * (i as f32 % 3.0));
        }
        weights.insert("gamma".into(), WeightRef { offset: g0, shape: vec![c] });
        let m0 = self.blob.len();
        for i in 0..c {
            self.blob.push(0.02 * i as f32);
        }
        weights.insert("mean".into(), WeightRef { offset: m0, shape: vec![c] });
        let v0 = self.blob.len();
        for i in 0..c {
            self.blob.push(1.0 + 0.1 * (i as f32 % 5.0));
        }
        weights.insert("var".into(), WeightRef { offset: v0, shape: vec![c] });
        let name = self.fresh("bn");
        self.push(
            Layer {
                name,
                op: LayerOp::BatchNorm { epsilon: 1e-3 },
                inputs: vec![x.to_string()],
                weights,
                activation: Activation::Linear,
                post_scale: false,
            },
            shape,
        )
    }

    pub fn maxpool(&mut self, x: &str, k: usize) -> String {
        self.maxpool_with_stride(x, k, k)
    }

    /// MaxPool with stride ≠ window (stride < k makes windows overlap,
    /// which gates the §3.4 conv+pool fusion off).
    pub fn maxpool_with_stride(&mut self, x: &str, k: usize, stride: usize) -> String {
        let s = self.shapes[x].clone();
        assert!(s[0] >= k && s[1] >= k, "maxpool window {k} larger than input");
        let name = self.fresh("maxpool");
        self.push(
            Layer {
                name,
                op: LayerOp::MaxPool { kh: k, kw: k, stride },
                inputs: vec![x.to_string()],
                weights: BTreeMap::new(),
                activation: Activation::Linear,
                post_scale: false,
            },
            vec![(s[0] - k) / stride + 1, (s[1] - k) / stride + 1, s[2]],
        )
    }

    pub fn avgpool(&mut self, x: &str, k: usize) -> String {
        let s = self.shapes[x].clone();
        assert!(s[0] >= k && s[1] >= k, "avgpool window {k} larger than input");
        let name = self.fresh("avgpool");
        self.push(
            Layer {
                name,
                op: LayerOp::AvgPool { kh: k, kw: k, stride: k },
                inputs: vec![x.to_string()],
                weights: BTreeMap::new(),
                activation: Activation::Linear,
                post_scale: false,
            },
            vec![s[0] / k, s[1] / k, s[2]],
        )
    }

    pub fn flatten(&mut self, x: &str) -> String {
        let n: usize = self.shapes[x].iter().product();
        let name = self.fresh("flatten");
        self.push(
            Layer {
                name,
                op: LayerOp::Flatten,
                inputs: vec![x.to_string()],
                weights: BTreeMap::new(),
                activation: Activation::Linear,
                post_scale: false,
            },
            vec![n],
        )
    }

    pub fn dense(&mut self, x: &str, units: usize, act: Activation) -> String {
        let in_dim = self.shapes[x][0];
        let kernel = self.alloc_he(&[in_dim, units], in_dim);
        let bias = self.alloc_zeros(units);
        let name = self.fresh("dense");
        let mut weights = BTreeMap::new();
        weights.insert("kernel".into(), kernel);
        weights.insert("bias".into(), bias);
        self.push(
            Layer {
                name,
                op: LayerOp::Dense { units },
                inputs: vec![x.to_string()],
                weights,
                activation: act,
                post_scale: false,
            },
            vec![units],
        )
    }

    /// Elementwise residual add of two same-shaped tensors.
    pub fn add(&mut self, a: &str, b: &str) -> String {
        let shape = self.shapes[a].clone();
        assert_eq!(shape, self.shapes[b], "add shape mismatch");
        let name = self.fresh("add");
        self.push(
            Layer {
                name,
                op: LayerOp::Add,
                inputs: vec![a.to_string(), b.to_string()],
                weights: BTreeMap::new(),
                activation: Activation::Linear,
                post_scale: false,
            },
            shape,
        )
    }

    /// Channel-axis concatenation of two spatially identical tensors.
    pub fn concat(&mut self, a: &str, b: &str) -> String {
        let sa = self.shapes[a].clone();
        let sb = self.shapes[b].clone();
        assert_eq!(sa[..sa.len() - 1], sb[..sb.len() - 1], "concat spatial mismatch");
        let mut out = sa;
        *out.last_mut().unwrap() += *sb.last().unwrap();
        let name = self.fresh("concat");
        self.push(
            Layer {
                name,
                op: LayerOp::Concat,
                inputs: vec![a.to_string(), b.to_string()],
                weights: BTreeMap::new(),
                activation: Activation::Linear,
                post_scale: false,
            },
            out,
        )
    }

    pub fn softmax(&mut self, x: &str) -> String {
        let shape = self.shapes[x].clone();
        let name = self.fresh("softmax");
        self.push(
            Layer {
                name,
                op: LayerOp::Softmax,
                inputs: vec![x.to_string()],
                weights: BTreeMap::new(),
                activation: Activation::Linear,
                post_scale: false,
            },
            shape,
        )
    }

    pub fn finish(self, outputs: &[&str]) -> ModelSpec {
        let spec = ModelSpec {
            name: self.name,
            input_shape: self.input_shape,
            layers: self.layers,
            outputs: outputs.iter().map(|s| s.to_string()).collect(),
            seed: self.seed,
            weights: self.blob,
        };
        spec.validate().expect("builder produced invalid spec");
        spec
    }
}

/// A small CNN used across unit tests and benches (conv→bn→pool→dense).
pub fn tiny_cnn(seed: u64) -> ModelSpec {
    let mut b = Builder::new("tiny_cnn", &[8, 8, 3], seed);
    let c = b.conv2d("input", 4, 3, 1, Activation::Relu);
    let bn = b.batchnorm(&c);
    let p = b.maxpool(&bn, 2);
    let f = b.flatten(&p);
    let d = b.dense(&f, 10, Activation::Linear);
    let s = b.softmax(&d);
    b.finish(&[&s])
}

/// A wider CNN (32×32×8 → 32ch → 64ch → dense head) whose conv layers do
/// millions of MACs each — big enough that the §3.3 cost model plans
/// multi-task intra-op splits and the lane-width choice is visible in
/// benches, while tiny_cnn stays firmly under the parallel threshold.
pub fn wide_cnn(seed: u64) -> ModelSpec {
    let mut b = Builder::new("wide_cnn", &[32, 32, 8], seed);
    let c1 = b.conv2d("input", 32, 3, 1, Activation::Relu);
    let p1 = b.maxpool(&c1, 2);
    let c2 = b.conv2d(&p1, 64, 3, 1, Activation::Relu);
    let p2 = b.maxpool(&c2, 2);
    let f = b.flatten(&p2);
    let d = b.dense(&f, 10, Activation::Linear);
    let s = b.softmax(&d);
    b.finish(&[&s])
}

/// An MLP of square `n×n` dense layers (`depth` hidden + 1 head + softmax)
/// — every layer is eligible for the §3.3 matvec schemes, which makes it
/// the rotated-vs-broadcast ablation vehicle.
pub fn square_mlp(seed: u64, n: usize, depth: usize) -> ModelSpec {
    let mut b = Builder::new("square_mlp", &[n], seed);
    let mut cur = "input".to_string();
    for _ in 0..depth {
        cur = b.dense(&cur, n, Activation::Relu);
    }
    let d = b.dense(&cur, n, Activation::Linear);
    let s = b.softmax(&d);
    b.finish(&[&s])
}

/// Random conv/pool/bn/act chain with occasional residual adds/concats —
/// the propcheck workhorse behind the §3.2 planner and `Program` lowering
/// properties (shared by `compiler::memory` and `compiler::program` tests).
pub fn random_chain(r: &mut SplitMix64) -> ModelSpec {
    let mut b = Builder::new("rand", &[8, 8, 2], r.next_u64());
    let mut cur = "input".to_string();
    let mut spatial = true;
    let mut residual: Option<String> = None;
    let n = 2 + r.below(6);
    for _ in 0..n {
        if !spatial {
            break;
        }
        match r.below(5) {
            0 => {
                let ch = b.shape_of(&cur)[2];
                cur = b.conv2d(&cur, ch, 3, 1, Activation::Relu);
                if let Some(res) = residual.take() {
                    // merge the saved branch — exercises the binary-op
                    // lowerings (in-place add + 3-way concat borrows)
                    if b.shape_of(&res) == b.shape_of(&cur) {
                        cur = if r.below(2) == 0 {
                            b.add(&cur, &res)
                        } else {
                            b.concat(&cur, &res)
                        };
                    }
                } else if r.below(2) == 0 {
                    residual = Some(cur.clone());
                }
            }
            1 => cur = b.batchnorm(&cur),
            2 => {
                if b.shape_of(&cur)[0] >= 4 {
                    cur = b.maxpool(&cur, 2);
                    residual = None; // shapes diverge
                }
            }
            3 => {
                let ch = 1 + r.below(4);
                cur = b.conv2d(&cur, ch, 1, 1, Activation::Linear);
                residual = None;
            }
            _ => {
                let f = b.flatten(&cur);
                let d = b.dense(&f, 4 + r.below(8), Activation::Relu);
                cur = d;
                spatial = false;
                residual = None;
            }
        }
    }
    let out = cur.clone();
    b.finish(&[&out])
}

/// Random dense-only networks for the GEMM/matvec differential fuzz
/// (`tests/fuzz_engines.rs`): widths on and off the 4-lane grid,
/// occasional square layers (the rotated/broadcast tail paths), every
/// activation, softmax head or not — the shapes where a batch-blocked
/// dense tile, its tail handoff, or a vectorized epilogue can go wrong.
pub fn random_mlp(r: &mut SplitMix64) -> ModelSpec {
    // half the time a 4-multiple input so square layers hit the
    // rotated/broadcast eligibility gate (`units % 4 == 0`)
    let in_dim = if r.below(2) == 0 { 4 * (1 + r.below(4)) } else { 3 + r.below(14) };
    let mut b = Builder::new("fuzz_mlp", &[in_dim], r.next_u64());
    let acts = [Activation::Relu, Activation::Linear, Activation::Tanh, Activation::Sigmoid];
    let mut cur = "input".to_string();
    for _ in 0..1 + r.below(3) {
        let cur_dim = b.shape_of(&cur)[0];
        // every third layer square (keeps its matvec tail), else random
        let units = if r.below(3) == 0 { cur_dim } else { 2 + r.below(15) };
        cur = b.dense(&cur, units, acts[r.below(acts.len())]);
    }
    if r.below(2) == 0 {
        cur = b.softmax(&cur);
    }
    let out = cur.clone();
    b.finish(&[&out])
}

/// Random conv/dwconv/pool/dense graphs for the cross-engine differential
/// fuzz suite (`tests/fuzz_engines.rs`): odd spatial dims, stride 2, SAME
/// *and* VALID padding, channel counts off the 4-lane grid, bias on/off,
/// overlapping and non-overlapping pools — the shapes where a blocked SIMD
/// conv kernel or a fused store loop can go wrong.
pub fn random_conv_net(r: &mut SplitMix64) -> ModelSpec {
    let h = 5 + 2 * r.below(3); // 5 | 7 | 9 — always odd
    let w = 4 + r.below(6); // 4..=9 — odd and even
    let c = 1 + r.below(5); // 1..=5 — rarely a multiple of 4
    let mut b = Builder::new("fuzz", &[h, w, c], r.next_u64());
    let mut cur = "input".to_string();
    let acts = [Activation::Relu, Activation::Linear, Activation::Tanh, Activation::Sigmoid];
    for _ in 0..1 + r.below(4) {
        let s = b.shape_of(&cur).to_vec();
        match r.below(6) {
            0 | 1 => {
                let k = 1 + r.below(3); // 1..=3
                let stride = 1 + r.below(2); // 1..=2
                let padding = if r.below(2) == 0 || s[0] < k || s[1] < k {
                    Padding::Same
                } else {
                    Padding::Valid
                };
                let oc = 1 + r.below(6); // 1..=6
                let act = acts[r.below(acts.len())];
                cur = b.conv2d_cfg(&cur, oc, k, stride, padding, r.below(2) == 0, act);
            }
            2 => {
                let k = 1 + r.below(3);
                let stride = 1 + r.below(2);
                let padding = if r.below(2) == 0 || s[0] < k || s[1] < k {
                    Padding::Same
                } else {
                    Padding::Valid
                };
                let act = acts[r.below(acts.len())];
                cur = b.dwconv2d(&cur, k, stride, padding, act);
            }
            3 => {
                if s[0] >= 2 && s[1] >= 2 {
                    // stride 1 overlaps (fusion gated off), stride 2 fuses
                    cur = b.maxpool_with_stride(&cur, 2, 1 + r.below(2));
                }
            }
            4 => {
                if s[0] >= 2 && s[1] >= 2 {
                    cur = b.avgpool(&cur, 2);
                }
            }
            _ => cur = b.batchnorm(&cur),
        }
    }
    if r.below(2) == 0 {
        let f = b.flatten(&cur);
        cur = b.dense(&f, 3 + r.below(8), acts[r.below(acts.len())]);
    }
    let out = cur.clone();
    b.finish(&[&out])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_builds_valid_specs() {
        let spec = tiny_cnn(3);
        assert_eq!(spec.layers.len(), 6);
        let shapes = spec.infer_shapes().unwrap();
        assert_eq!(shapes["softmax6"], vec![10]);
    }

    #[test]
    fn deterministic_per_seed() {
        assert_eq!(tiny_cnn(3).weights, tiny_cnn(3).weights);
        assert_ne!(tiny_cnn(3).weights, tiny_cnn(4).weights);
    }

    #[test]
    fn random_conv_net_always_validates_and_covers_the_edge_cases() {
        use crate::model::spec::LayerOp;
        let mut r = SplitMix64::new(33);
        let (mut valid_pad, mut strided, mut biasless, mut dw) = (0, 0, 0, 0);
        for _ in 0..200 {
            let spec = random_conv_net(&mut r);
            spec.validate().unwrap();
            for l in &spec.layers {
                match l.op {
                    LayerOp::Conv2d { stride, padding, use_bias, .. } => {
                        valid_pad += usize::from(padding == Padding::Valid);
                        strided += usize::from(stride > 1);
                        biasless += usize::from(!use_bias);
                    }
                    LayerOp::DepthwiseConv2d { .. } => dw += 1,
                    _ => {}
                }
            }
        }
        // the generator must actually reach the hard cases it exists for
        assert!(valid_pad > 0 && strided > 0 && biasless > 0 && dw > 0,
            "coverage: valid={valid_pad} strided={strided} biasless={biasless} dw={dw}");
    }
}
