//! Programmatic model construction — the Rust-native way to define a network
//! without going through a spec file (mirrors `python/compile/spec.Builder`).
//! Weights are He-normal from a SplitMix64 stream, so a given (architecture,
//! seed) pair is fully deterministic.

use std::collections::BTreeMap;

use crate::util::rng::SplitMix64;

use super::spec::{conv_out, Activation, Layer, LayerOp, ModelSpec, Padding, WeightRef};

pub struct Builder {
    name: String,
    input_shape: Vec<usize>,
    seed: u64,
    rng: SplitMix64,
    layers: Vec<Layer>,
    blob: Vec<f32>,
    shapes: BTreeMap<String, Vec<usize>>,
    counter: usize,
}

impl Builder {
    pub fn new(name: &str, input_shape: &[usize], seed: u64) -> Self {
        let mut shapes = BTreeMap::new();
        shapes.insert("input".to_string(), input_shape.to_vec());
        Self {
            name: name.to_string(),
            input_shape: input_shape.to_vec(),
            seed,
            rng: SplitMix64::new(seed),
            layers: Vec::new(),
            blob: Vec::new(),
            shapes,
            counter: 0,
        }
    }

    pub fn shape_of(&self, name: &str) -> &[usize] {
        &self.shapes[name]
    }

    fn fresh(&mut self, prefix: &str) -> String {
        self.counter += 1;
        format!("{prefix}{}", self.counter)
    }

    fn alloc_he(&mut self, shape: &[usize], fan_in: usize) -> WeightRef {
        let n: usize = shape.iter().product();
        let scale = (2.0 / fan_in as f32).sqrt();
        let offset = self.blob.len();
        // Box–Muller over SplitMix64 uniforms (approximate normal is fine
        // for test weights; python builds its own weights via numpy).
        for _ in 0..n {
            let u1 = (self.rng.next_uniform() * 0.5 + 0.5).max(1e-7);
            let u2 = self.rng.next_uniform() * 0.5 + 0.5;
            let z = (-2.0 * u1.ln()).sqrt() * (std::f32::consts::TAU * u2).cos();
            self.blob.push(z * scale);
        }
        WeightRef { offset, shape: shape.to_vec() }
    }

    fn alloc_zeros(&mut self, n: usize) -> WeightRef {
        let offset = self.blob.len();
        self.blob.resize(offset + n, 0.0);
        WeightRef { offset, shape: vec![n] }
    }

    fn push(&mut self, layer: Layer, out_shape: Vec<usize>) -> String {
        let name = layer.name.clone();
        self.shapes.insert(name.clone(), out_shape);
        self.layers.push(layer);
        name
    }

    pub fn conv2d(&mut self, x: &str, out_ch: usize, k: usize, stride: usize, act: Activation) -> String {
        let in_shape = self.shapes[x].clone();
        let (h, w, c) = (in_shape[0], in_shape[1], in_shape[2]);
        let kernel = self.alloc_he(&[k, k, c, out_ch], k * k * c);
        let bias = self.alloc_zeros(out_ch);
        let (oh, ow) = conv_out(h, w, k, k, stride, Padding::Same);
        let name = self.fresh("conv");
        let mut weights = BTreeMap::new();
        weights.insert("kernel".into(), kernel);
        weights.insert("bias".into(), bias);
        self.push(
            Layer {
                name,
                op: LayerOp::Conv2d { kh: k, kw: k, out_ch, stride, padding: Padding::Same, use_bias: true },
                inputs: vec![x.to_string()],
                weights,
                activation: act,
                post_scale: false,
            },
            vec![oh, ow, out_ch],
        )
    }

    pub fn batchnorm(&mut self, x: &str) -> String {
        let shape = self.shapes[x].clone();
        let c = *shape.last().unwrap();
        let mut weights = BTreeMap::new();
        // Non-identity statistics so folding tests exercise real math.
        let offset = self.blob.len();
        for _ in 0..c {
            self.blob.push(0.1); // beta (same constant for every seed)
        }
        weights.insert("beta".into(), WeightRef { offset, shape: vec![c] });
        let g0 = self.blob.len();
        for i in 0..c {
            self.blob.push(1.0 + 0.05 * (i as f32 % 3.0));
        }
        weights.insert("gamma".into(), WeightRef { offset: g0, shape: vec![c] });
        let m0 = self.blob.len();
        for i in 0..c {
            self.blob.push(0.02 * i as f32);
        }
        weights.insert("mean".into(), WeightRef { offset: m0, shape: vec![c] });
        let v0 = self.blob.len();
        for i in 0..c {
            self.blob.push(1.0 + 0.1 * (i as f32 % 5.0));
        }
        weights.insert("var".into(), WeightRef { offset: v0, shape: vec![c] });
        let name = self.fresh("bn");
        self.push(
            Layer {
                name,
                op: LayerOp::BatchNorm { epsilon: 1e-3 },
                inputs: vec![x.to_string()],
                weights,
                activation: Activation::Linear,
                post_scale: false,
            },
            shape,
        )
    }

    pub fn maxpool(&mut self, x: &str, k: usize) -> String {
        let s = self.shapes[x].clone();
        let name = self.fresh("maxpool");
        self.push(
            Layer {
                name,
                op: LayerOp::MaxPool { kh: k, kw: k, stride: k },
                inputs: vec![x.to_string()],
                weights: BTreeMap::new(),
                activation: Activation::Linear,
                post_scale: false,
            },
            vec![s[0] / k, s[1] / k, s[2]],
        )
    }

    pub fn flatten(&mut self, x: &str) -> String {
        let n: usize = self.shapes[x].iter().product();
        let name = self.fresh("flatten");
        self.push(
            Layer {
                name,
                op: LayerOp::Flatten,
                inputs: vec![x.to_string()],
                weights: BTreeMap::new(),
                activation: Activation::Linear,
                post_scale: false,
            },
            vec![n],
        )
    }

    pub fn dense(&mut self, x: &str, units: usize, act: Activation) -> String {
        let in_dim = self.shapes[x][0];
        let kernel = self.alloc_he(&[in_dim, units], in_dim);
        let bias = self.alloc_zeros(units);
        let name = self.fresh("dense");
        let mut weights = BTreeMap::new();
        weights.insert("kernel".into(), kernel);
        weights.insert("bias".into(), bias);
        self.push(
            Layer {
                name,
                op: LayerOp::Dense { units },
                inputs: vec![x.to_string()],
                weights,
                activation: act,
                post_scale: false,
            },
            vec![units],
        )
    }

    pub fn softmax(&mut self, x: &str) -> String {
        let shape = self.shapes[x].clone();
        let name = self.fresh("softmax");
        self.push(
            Layer {
                name,
                op: LayerOp::Softmax,
                inputs: vec![x.to_string()],
                weights: BTreeMap::new(),
                activation: Activation::Linear,
                post_scale: false,
            },
            shape,
        )
    }

    pub fn finish(self, outputs: &[&str]) -> ModelSpec {
        let spec = ModelSpec {
            name: self.name,
            input_shape: self.input_shape,
            layers: self.layers,
            outputs: outputs.iter().map(|s| s.to_string()).collect(),
            seed: self.seed,
            weights: self.blob,
        };
        spec.validate().expect("builder produced invalid spec");
        spec
    }
}

/// A small CNN used across unit tests and benches (conv→bn→pool→dense).
pub fn tiny_cnn(seed: u64) -> ModelSpec {
    let mut b = Builder::new("tiny_cnn", &[8, 8, 3], seed);
    let c = b.conv2d("input", 4, 3, 1, Activation::Relu);
    let bn = b.batchnorm(&c);
    let p = b.maxpool(&bn, 2);
    let f = b.flatten(&p);
    let d = b.dense(&f, 10, Activation::Linear);
    let s = b.softmax(&d);
    b.finish(&[&s])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_builds_valid_specs() {
        let spec = tiny_cnn(3);
        assert_eq!(spec.layers.len(), 6);
        let shapes = spec.infer_shapes().unwrap();
        assert_eq!(shapes["softmax6"], vec![10]);
    }

    #[test]
    fn deterministic_per_seed() {
        assert_eq!(tiny_cnn(3).weights, tiny_cnn(3).weights);
        assert_ne!(tiny_cnn(3).weights, tiny_cnn(4).weights);
    }
}
