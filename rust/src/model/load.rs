//! nnspec loader: `models/<name>.json` + `models/<name>.weights.bin` →
//! `ModelSpec`. The JSON is parsed with our own parser (util/json.rs), the
//! blob is raw little-endian f32 — the same two files aot.py writes.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::spec::{Activation, Layer, LayerOp, ModelSpec, Padding, WeightRef};

/// Load `models_dir/<name>.json` (+ its weight blob) and validate.
pub fn load_model(models_dir: &Path, name: &str) -> Result<ModelSpec> {
    let json_path = models_dir.join(format!("{name}.json"));
    let text = std::fs::read_to_string(&json_path)
        .with_context(|| format!("reading {}", json_path.display()))?;
    let j = Json::parse(&text).with_context(|| format!("parsing {}", json_path.display()))?;
    let spec = from_json(&j, models_dir)?;
    spec.validate()?;
    Ok(spec)
}

/// Raw little-endian f32 blob reader (shared with runtime weight feeding).
pub fn load_weights_blob(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 4 != 0 {
        bail!("weight blob {} has non-multiple-of-4 length", path.display());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

pub fn from_json(j: &Json, models_dir: &Path) -> Result<ModelSpec> {
    let format = j.req_str("format")?;
    if format != "nnspec-v1" {
        bail!("unsupported spec format `{format}`");
    }
    let name = j.req_str("name")?.to_string();
    let input_shape = j
        .req("input")?
        .req("shape")?
        .as_usize_vec()
        .context("input.shape must be an int array")?;

    let mut layers = Vec::new();
    for lj in j.req_arr("layers")? {
        layers.push(parse_layer(lj)?);
    }
    let outputs = j
        .req_arr("outputs")?
        .iter()
        .map(|o| o.as_str().map(str::to_string).context("output not a string"))
        .collect::<Result<Vec<_>>>()?;

    let weights_file = j.req_str("weights_file")?;
    let weights = load_weights_blob(&models_dir.join(weights_file))?;
    let expect = j.req_usize("weights_len")?;
    if weights.len() != expect {
        bail!("weight blob length {} != declared {expect}", weights.len());
    }

    Ok(ModelSpec {
        name,
        input_shape,
        layers,
        outputs,
        seed: j.req_usize("seed")? as u64,
        weights,
    })
}

fn parse_layer(lj: &Json) -> Result<Layer> {
    let name = lj.req_str("name")?.to_string();
    let op_name = lj.req_str("op")?;
    let inputs = lj
        .req_arr("inputs")?
        .iter()
        .map(|i| i.as_str().map(str::to_string).context("input not a string"))
        .collect::<Result<Vec<_>>>()?;

    let op = match op_name {
        "conv2d" => LayerOp::Conv2d {
            kh: lj.req_usize("kh")?,
            kw: lj.req_usize("kw")?,
            out_ch: lj.req_usize("out_ch")?,
            stride: lj.req_usize("stride")?,
            padding: Padding::parse(lj.req_str("padding")?)?,
            use_bias: lj.get("use_bias").and_then(Json::as_bool).unwrap_or(false),
        },
        "depthwise_conv2d" => LayerOp::DepthwiseConv2d {
            kh: lj.req_usize("kh")?,
            kw: lj.req_usize("kw")?,
            stride: lj.req_usize("stride")?,
            padding: Padding::parse(lj.req_str("padding")?)?,
            use_bias: lj.get("use_bias").and_then(Json::as_bool).unwrap_or(false),
        },
        "dense" => LayerOp::Dense { units: lj.req_usize("units")? },
        "batchnorm" => LayerOp::BatchNorm {
            epsilon: lj.get("epsilon").and_then(Json::as_f64).unwrap_or(1e-3) as f32,
        },
        "maxpool" => LayerOp::MaxPool {
            kh: lj.req_usize("kh")?,
            kw: lj.req_usize("kw")?,
            stride: lj.req_usize("stride")?,
        },
        "avgpool" => LayerOp::AvgPool {
            kh: lj.req_usize("kh")?,
            kw: lj.req_usize("kw")?,
            stride: lj.req_usize("stride")?,
        },
        "globalavgpool" => LayerOp::GlobalAvgPool,
        "upsample" => LayerOp::Upsample { factor: lj.req_usize("factor")? },
        "zeropad" => {
            let p = lj.req("pad")?.as_usize_vec().context("pad must be ints")?;
            if p.len() != 4 {
                bail!("zeropad `{name}` pad must have 4 entries");
            }
            LayerOp::ZeroPad { pad: [p[0], p[1], p[2], p[3]] }
        }
        "activation" => LayerOp::Activation,
        "softmax" => LayerOp::Softmax,
        "add" => LayerOp::Add,
        "concat" => LayerOp::Concat,
        "flatten" => LayerOp::Flatten,
        other => bail!("unknown op `{other}` in layer `{name}`"),
    };

    let mut weights = BTreeMap::new();
    if let Some(wj) = lj.get("weights") {
        let obj = wj.as_obj().context("weights must be an object")?;
        for (k, w) in obj {
            weights.insert(
                k.clone(),
                WeightRef {
                    offset: w.req_usize("offset")?,
                    shape: w.req("shape")?.as_usize_vec().context("weight shape")?,
                },
            );
        }
    }

    let activation = match lj.get("activation").and_then(Json::as_str) {
        Some(a) => Activation::parse(a)?,
        None => Activation::Linear,
    };

    Ok(Layer {
        name,
        op,
        inputs,
        weights,
        activation,
        post_scale: lj.get("post_scale").and_then(Json::as_bool).unwrap_or(false),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_json() -> String {
        r#"{
 "format": "nnspec-v1", "name": "t", "seed": 1,
 "input": {"shape": [4, 4, 1]},
 "layers": [
  {"name": "c1", "op": "conv2d", "inputs": ["input"], "kh": 3, "kw": 3,
   "out_ch": 2, "stride": 1, "padding": "same", "use_bias": true,
   "weights": {"kernel": {"offset": 0, "shape": [3, 3, 1, 2]},
               "bias": {"offset": 18, "shape": [2]}},
   "activation": "relu"},
  {"name": "f", "op": "flatten", "inputs": ["c1"]},
  {"name": "d", "op": "dense", "inputs": ["f"], "units": 3,
   "weights": {"kernel": {"offset": 20, "shape": [32, 3]},
               "bias": {"offset": 116, "shape": [3]}}}
 ],
 "outputs": ["d"], "weights_file": "t.weights.bin", "weights_len": 119
}"#
        .to_string()
    }

    #[test]
    fn parses_tiny_spec() {
        let dir = std::env::temp_dir().join("nnspec_test_load");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("t.json"), tiny_json()).unwrap();
        let blob: Vec<u8> = (0..119u32).flat_map(|i| (i as f32).to_le_bytes()).collect();
        std::fs::write(dir.join("t.weights.bin"), blob).unwrap();

        let spec = load_model(&dir, "t").unwrap();
        assert_eq!(spec.layers.len(), 3);
        assert_eq!(spec.input_shape, vec![4, 4, 1]);
        let shapes = spec.infer_shapes().unwrap();
        assert_eq!(shapes["c1"], vec![4, 4, 2]);
        assert_eq!(shapes["f"], vec![32]);
        assert_eq!(shapes["d"], vec![3]);
        let c1 = spec.layer("c1").unwrap();
        assert_eq!(c1.activation, Activation::Relu);
        assert_eq!(spec.weight(c1, "bias").unwrap(), &[18.0, 19.0]);
    }

    #[test]
    fn rejects_bad_format() {
        let j = Json::parse(r#"{"format": "nope"}"#).unwrap();
        assert!(from_json(&j, Path::new("/tmp")).is_err());
    }
}
