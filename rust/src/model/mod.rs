//! Model interchange (nnspec): graph IR, loader, programmatic builder.
pub mod builder;
pub mod keras;
pub mod load;
pub mod spec;
