//! Keras front end — imports the subset of the Keras *Functional*
//! architecture JSON schema (`model.to_json()`) that CompiledNN supports,
//! the same role as the paper's HDF5 reader (§3.1: "the Model class allows
//! to load a network only from an HDF5 file as written by … Keras"; HDF5 is
//! substituted per DESIGN.md — weights live in the nnspec blob, located via
//! the `weights_map` table the exporter appends).
//!
//! Supported layer classes: InputLayer, Conv2D, DepthwiseConv2D, Dense,
//! BatchNormalization, MaxPooling2D, AveragePooling2D,
//! GlobalAveragePooling2D, UpSampling2D, ZeroPadding2D, Activation,
//! Softmax, Add, Concatenate, Flatten.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::load::load_weights_blob;
use super::spec::{Activation, Layer, LayerOp, ModelSpec, Padding, WeightRef};

/// Load `<dir>/<name>.keras.json` (+ the blob it references) and validate.
pub fn load_keras_model(models_dir: &Path, name: &str) -> Result<ModelSpec> {
    let path = models_dir.join(format!("{name}.keras.json"));
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
    let spec = from_keras_json(&j, models_dir)?;
    spec.validate()?;
    Ok(spec)
}

pub fn from_keras_json(j: &Json, models_dir: &Path) -> Result<ModelSpec> {
    if j.req_str("class_name")? != "Functional" {
        bail!("only Functional Keras models are supported");
    }
    let cfg = j.req("config")?;
    let name = cfg.req_str("name")?.to_string();
    let weights_map = j.req("weights_map")?;
    let weights_file = j.req_str("weights_file")?;
    let weights = load_weights_blob(&models_dir.join(weights_file))?;

    let mut input_shape = None;
    let mut layers = Vec::new();
    for lj in cfg.req_arr("layers")? {
        let class = lj.req_str("class_name")?;
        let lname = lj.req_str("name")?.to_string();
        let lcfg = lj.req("config")?;
        if class == "InputLayer" {
            let bis = lcfg.req_arr("batch_input_shape")?;
            let dims: Vec<usize> = bis[1..]
                .iter()
                .map(|d| d.as_usize().context("input dim"))
                .collect::<Result<_>>()?;
            input_shape = Some(dims);
            if lname != "input" {
                bail!("input layer must be named `input`");
            }
            continue;
        }
        let inputs = parse_inbound(lj)?;
        let (op, activation) = parse_class(class, lcfg, &lname)?;
        let lweights = parse_weights(weights_map, &lname)?;
        layers.push(Layer {
            name: lname,
            op,
            inputs,
            weights: lweights,
            activation,
            post_scale: false,
        });
    }

    let outputs = cfg
        .req_arr("output_layers")?
        .iter()
        .map(|o| {
            o.as_arr()
                .and_then(|a| a.first())
                .and_then(Json::as_str)
                .map(str::to_string)
                .context("bad output_layers entry")
        })
        .collect::<Result<Vec<_>>>()?;

    Ok(ModelSpec {
        name,
        input_shape: input_shape.context("no InputLayer found")?,
        layers,
        outputs,
        seed: 0,
        weights,
    })
}

fn parse_inbound(lj: &Json) -> Result<Vec<String>> {
    let nodes = lj.req_arr("inbound_nodes")?;
    let first = nodes
        .first()
        .and_then(Json::as_arr)
        .context("layer has no inbound nodes")?;
    first
        .iter()
        .map(|n| {
            n.as_arr()
                .and_then(|a| a.first())
                .and_then(Json::as_str)
                .map(str::to_string)
                .context("bad inbound node")
        })
        .collect()
}

fn act(cfg: &Json) -> Result<Activation> {
    match cfg.get("activation").and_then(Json::as_str) {
        None => Ok(Activation::Linear),
        Some(s) => Activation::parse(s),
    }
}

fn int2(cfg: &Json, key: &str) -> Result<(usize, usize)> {
    let v = cfg.req(key)?.as_usize_vec().with_context(|| format!("{key} ints"))?;
    anyhow::ensure!(v.len() == 2, "{key} must have 2 entries");
    Ok((v[0], v[1]))
}

fn parse_class(class: &str, cfg: &Json, lname: &str) -> Result<(LayerOp, Activation)> {
    Ok(match class {
        "Conv2D" => {
            let (kh, kw) = int2(cfg, "kernel_size")?;
            let (sh, sw) = int2(cfg, "strides")?;
            anyhow::ensure!(sh == sw, "anisotropic strides unsupported");
            (
                LayerOp::Conv2d {
                    kh,
                    kw,
                    out_ch: cfg.req_usize("filters")?,
                    stride: sh,
                    padding: Padding::parse(cfg.req_str("padding")?)?,
                    use_bias: cfg.get("use_bias").and_then(Json::as_bool).unwrap_or(true),
                },
                act(cfg)?,
            )
        }
        "DepthwiseConv2D" => {
            let (kh, kw) = int2(cfg, "kernel_size")?;
            let (sh, _) = int2(cfg, "strides")?;
            let dm = cfg.get("depth_multiplier").and_then(Json::as_usize).unwrap_or(1);
            anyhow::ensure!(dm == 1, "depth_multiplier > 1 unsupported");
            (
                LayerOp::DepthwiseConv2d {
                    kh,
                    kw,
                    stride: sh,
                    padding: Padding::parse(cfg.req_str("padding")?)?,
                    use_bias: cfg.get("use_bias").and_then(Json::as_bool).unwrap_or(true),
                },
                act(cfg)?,
            )
        }
        "Dense" => (LayerOp::Dense { units: cfg.req_usize("units")? }, act(cfg)?),
        "BatchNormalization" => (
            LayerOp::BatchNorm {
                epsilon: cfg.get("epsilon").and_then(Json::as_f64).unwrap_or(1e-3) as f32,
            },
            Activation::Linear,
        ),
        "MaxPooling2D" | "AveragePooling2D" => {
            let (kh, kw) = int2(cfg, "pool_size")?;
            let (sh, _) = int2(cfg, "strides")?;
            let op = if class == "MaxPooling2D" {
                LayerOp::MaxPool { kh, kw, stride: sh }
            } else {
                LayerOp::AvgPool { kh, kw, stride: sh }
            };
            (op, Activation::Linear)
        }
        "GlobalAveragePooling2D" => (LayerOp::GlobalAvgPool, Activation::Linear),
        "UpSampling2D" => {
            let (fh, fw) = int2(cfg, "size")?;
            anyhow::ensure!(fh == fw, "anisotropic upsampling unsupported");
            if let Some(interp) = cfg.get("interpolation").and_then(Json::as_str) {
                anyhow::ensure!(interp == "nearest", "only nearest upsampling");
            }
            (LayerOp::Upsample { factor: fh }, Activation::Linear)
        }
        "ZeroPadding2D" => {
            let p = cfg.req_arr("padding")?;
            let row = p[0].as_usize_vec().context("pad rows")?;
            let col = p[1].as_usize_vec().context("pad cols")?;
            (
                LayerOp::ZeroPad { pad: [row[0], row[1], col[0], col[1]] },
                Activation::Linear,
            )
        }
        "Activation" => (LayerOp::Activation, act(cfg)?),
        "Softmax" => (LayerOp::Softmax, Activation::Linear),
        "Add" => (LayerOp::Add, Activation::Linear),
        "Concatenate" => (LayerOp::Concat, Activation::Linear),
        "Flatten" => (LayerOp::Flatten, Activation::Linear),
        other => bail!("Keras layer class `{other}` (layer `{lname}`) is not supported"),
    })
}

fn parse_weights(weights_map: &Json, lname: &str) -> Result<BTreeMap<String, WeightRef>> {
    let mut out = BTreeMap::new();
    if let Some(entry) = weights_map.get(lname) {
        let obj = entry.as_obj().context("weights_map entry")?;
        for (k, w) in obj {
            out.insert(
                k.clone(),
                WeightRef {
                    offset: w.req_usize("offset")?,
                    shape: w.req("shape")?.as_usize_vec().context("weight shape")?,
                },
            );
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_sequential() {
        let j = Json::parse(r#"{"class_name": "Sequential", "config": {}}"#).unwrap();
        assert!(from_keras_json(&j, Path::new("/tmp")).is_err());
    }

    #[test]
    fn unsupported_class_named_in_error() {
        let doc = r#"{
          "class_name": "Functional",
          "config": {"name": "t", "layers": [
            {"class_name": "InputLayer", "name": "input",
             "config": {"batch_input_shape": [null, 4, 4, 1]}, "inbound_nodes": []},
            {"class_name": "LSTM", "name": "l",
             "config": {}, "inbound_nodes": [[["input", 0, 0, {}]]]}
          ], "input_layers": [["input", 0, 0]], "output_layers": [["l", 0, 0]]},
          "weights_file": "t.weights.bin", "weights_map": {}
        }"#;
        let dir = std::env::temp_dir().join("keras_t1");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("t.weights.bin"), []).unwrap();
        let err = from_keras_json(&Json::parse(doc).unwrap(), &dir)
            .unwrap_err()
            .to_string();
        assert!(err.contains("LSTM"), "{err}");
    }
}
