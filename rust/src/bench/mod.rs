//! From-scratch benchmark harness (criterion is unavailable offline).
//!
//! Methodology matches the paper's §4: "runtimes are the average over
//! multiple successive calls to the inference routine, after doing some
//! unmeasured initial runs". Each measured iteration is timed individually
//! so percentiles are real, not modeled.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub min_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub max_ms: f64,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "{:<38} {:>8} iters  mean {:>10.4} ms  min {:>10.4}  p50 {:>10.4}  p95 {:>10.4}",
            self.name, self.iters, self.mean_ms, self.min_ms, self.p50_ms, self.p95_ms
        )
    }
}

/// Run `f` `warmup` times unmeasured, then `iters` measured times.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    summarize(name, samples)
}

/// Time-budgeted variant: at least `min_iters`, then keep iterating until
/// `budget` is spent (one warmup call included). For workloads whose cost
/// spans five orders of magnitude across models (Table 1).
pub fn bench_budget(
    name: &str,
    budget: Duration,
    min_iters: usize,
    mut f: impl FnMut(),
) -> BenchResult {
    f(); // warmup
    let start = Instant::now();
    let mut samples = Vec::new();
    while samples.len() < min_iters || (start.elapsed() < budget && samples.len() < 10_000) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    summarize(name, samples)
}

fn summarize(name: &str, mut samples: Vec<f64>) -> BenchResult {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let q = |p: f64| samples[((p * (n - 1) as f64).round() as usize).min(n - 1)];
    BenchResult {
        name: name.to_string(),
        iters: n,
        mean_ms: samples.iter().sum::<f64>() / n as f64,
        min_ms: samples[0],
        p50_ms: q(0.5),
        p95_ms: q(0.95),
        max_ms: samples[n - 1],
    }
}

/// Pretty table printing for grids of (row, col) → value.
pub fn print_grid(title: &str, cols: &[&str], rows: &[(String, Vec<Option<f64>>)]) {
    println!("\n== {title}");
    print!("{:<14}", "");
    for c in cols {
        print!(" {c:>12}");
    }
    println!();
    for (name, vals) in rows {
        print!("{name:<14}");
        for v in vals {
            match v {
                Some(v) => print!(" {v:>12.4}"),
                None => print!(" {:>12}", "-"),
            }
        }
        println!();
    }
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0;
        let r = bench("t", 2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(r.iters, 5);
        assert!(r.min_ms <= r.p50_ms && r.p50_ms <= r.p95_ms);
    }

    #[test]
    fn budget_respects_min_iters() {
        let r = bench_budget("t", Duration::ZERO, 3, || {});
        assert!(r.iters >= 3);
    }
}
