//! `compiled-nn` — CLI over the three-layer stack. Subcommands:
//!
//! ```text
//! compiled-nn compile                      # PJRT-compile all models, print Table-1 compile row
//! compiled-nn infer --model c_bh [--engine compiled|naive|optimized] [--batch N]
//! compiled-nn compare --model c_bh        # all engines vs the golden oracle
//! compiled-nn inspect --model c_bh        # §3.3 cost table + §3.2 memory plan + §3.5 folding
//! compiled-nn explain [--model c_bh] [--batch N]   # cost-model lowering report (builtin demo net without --model)
//! compiled-nn precision                   # §3.4 approximation error table
//! compiled-nn table1 [--iters N]          # quick Table-1 analog (benches do it properly)
//! compiled-nn serve --model c_bh --seconds 5 [--offered RPS] [--engine KIND] [--workers N]
//! compiled-nn serve --config serving.json [--seconds N] [--max-inflight N] [--slo-ms MS]
//! ```
//!
//! Engines are never constructed directly here: every subcommand goes
//! through the `engine::EngineKind` registry, so the CLI degrades cleanly
//! when the `pjrt` feature (the compiled engine) is absent.
//!
//! Argument parsing is hand-rolled (clap is unavailable offline; the paper
//! hand-rolled its JSON parser in the same spirit).

use std::collections::HashMap;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use compiled_nn::compiler::{cost, fuse, memory};
use compiled_nn::coordinator::server::{Coordinator, CoordinatorConfig};
use compiled_nn::engine::{build_engine, build_engine_from_spec, Engine, EngineKind, EngineOptions};
use compiled_nn::model::load::load_model;
use compiled_nn::nn::tensor::Tensor;
use compiled_nn::runtime::artifact::Manifest;
use compiled_nn::util::rng::{golden_seed, SplitMix64};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal flag parser: `--key value` pairs after the subcommand.
struct Args {
    cmd: String,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse() -> Result<Args> {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".into());
        let mut flags = HashMap::new();
        while let Some(k) = it.next() {
            let key = k
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got `{k}`"))?
                .to_string();
            let v = it.next().with_context(|| format!("--{key} needs a value"))?;
            flags.insert(key, v);
        }
        Ok(Args { cmd, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    fn req(&self, key: &str) -> Result<&str> {
        self.get(key).with_context(|| format!("missing --{key}"))
    }

    fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
            None => Ok(default),
        }
    }
}

fn run() -> Result<()> {
    let args = Args::parse()?;
    match args.cmd.as_str() {
        "compile" => cmd_compile(),
        "infer" => cmd_infer(&args),
        "compare" => cmd_compare(&args),
        "inspect" => cmd_inspect(&args),
        "explain" => cmd_explain(&args),
        "precision" => cmd_precision(),
        "table1" => cmd_table1(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command `{other}`\n{HELP}"),
    }
}

const HELP: &str = "compiled-nn — JIT-compiled NN inference (paper reproduction)
commands: compile | infer | compare | inspect | explain | precision | table1 | serve
engines (--engine): compiled (needs the `pjrt` build feature) | optimized | naive
see the module docs in rust/src/main.rs for flags";

/// Deterministic golden input, bit-identical to aot.py's.
fn golden_input(seed: u64, batch: usize, item_shape: &[usize]) -> Tensor {
    let mut shape = vec![batch];
    shape.extend_from_slice(item_shape);
    let n: usize = shape.iter().product();
    let mut rng = SplitMix64::new(golden_seed(seed));
    Tensor::from_vec(&shape, rng.uniform_vec(n))
}

fn cmd_compile() -> Result<()> {
    if !EngineKind::Compiled.available() {
        bail!(
            "`compile` needs the compiled engine, which is unavailable on this \
             host (requires the `pjrt` build feature and a working PJRT plugin)"
        );
    }
    let manifest = Manifest::load_default()?;
    println!("{:<14} {:>10} {:>7} {:>14}", "model", "params", "baked", "compile ms");
    for name in manifest.models.keys() {
        let entry = manifest.entry(name)?;
        let engine = build_engine(EngineKind::Compiled, &manifest, name, &EngineOptions::default())?;
        println!(
            "{:<14} {:>10} {:>7} {:>14.1}",
            name, entry.params, entry.baked, engine.compile_ms()
        );
    }
    Ok(())
}

fn cmd_infer(args: &Args) -> Result<()> {
    let name = args.req("model")?;
    // default to the best engine this build provides (compiled on pjrt
    // builds, optimized otherwise) so the bare command works everywhere
    let kind = match args.get("engine") {
        Some(s) => EngineKind::parse(s)?,
        None => EngineKind::preferred(),
    };
    let batch = args.usize_or("batch", 1)?;
    let manifest = Manifest::load_default()?;
    let entry = manifest.entry(name)?;
    let x = golden_input(entry.seed, batch, &entry.input_shape);

    let t0 = Instant::now();
    let opts = if kind == EngineKind::Compiled {
        // only specialize the bucket we are about to run
        EngineOptions::with_buckets(&[batch])
    } else {
        EngineOptions::default()
    };
    let mut engine = build_engine(kind, &manifest, name, &opts)?;
    if engine.compile_ms() > 0.0 {
        println!("compile: {:.1} ms", engine.compile_ms());
    }
    let t = Instant::now();
    let out = engine.infer(&x)?;
    println!("execute: {:.3} ms", t.elapsed().as_secs_f64() * 1e3);
    println!("load+infer total: {:.3} ms", t0.elapsed().as_secs_f64() * 1e3);
    for (i, o) in out.iter().enumerate() {
        let head: Vec<f32> = o.data().iter().take(8).copied().collect();
        println!("output[{i}] shape {:?} head {:?}", o.shape(), head);
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let name = args.req("model")?;
    let manifest = Manifest::load_default()?;
    let entry = manifest.entry(name)?;
    let x = golden_input(entry.seed, 1, &entry.input_shape);

    // one spec parse shared by the oracle and the optimized interpreter
    let spec = load_model(&manifest.models_dir, name)?;
    let mut oracle = build_engine_from_spec(EngineKind::Naive, &spec, &EngineOptions::default())?;
    let exact = oracle.infer(&x)?;

    for kind in [EngineKind::Optimized, EngineKind::Compiled] {
        if !kind.available() {
            println!("{:<9} vs naive-exact: unavailable on this host", kind.as_str());
            continue;
        }
        let built = if kind == EngineKind::Compiled {
            build_engine(kind, &manifest, name, &EngineOptions::with_buckets(&[1]))
        } else {
            build_engine_from_spec(kind, &spec, &EngineOptions::default())
        };
        let mut engine = match built {
            Ok(e) => e,
            Err(e) => {
                println!("{:<9} vs naive-exact: skipped ({e})", kind.as_str());
                continue;
            }
        };
        let out = engine.infer(&x)?;
        println!(
            "{:<9} vs naive-exact: max |Δ| = {:.2e}",
            kind.as_str(),
            exact[0].max_abs_diff(&out[0])
        );
    }
    println!("(approx activations bound the differences; see `precision`)");
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let name = args.req("model")?;
    let manifest = Manifest::load_default()?;
    let spec = load_model(&manifest.models_dir, name)?;
    println!("== {name}: {} layers, {} params", spec.layers.len(), spec.param_count());

    let folded = fuse::fold_batchnorm(&spec);
    println!(
        "§3.5 folding: {} batchnorm layers → {} (layers {} → {})",
        fuse::bn_count(&spec),
        fuse::bn_count(&folded),
        spec.layers.len(),
        folded.layers.len()
    );

    // Plan with the same §3.4 pool-fusion elision the default lowering
    // applies, so the reported peak matches the arena the engine allocates.
    let elided: std::collections::BTreeSet<String> =
        fuse::fusible_maxpool_pairs(&folded).into_keys().collect();
    let plan = memory::plan_elided(&folded, true, &elided)?;
    let no_reuse = memory::plan_elided(&folded, false, &elided)?;
    println!(
        "§3.2 memory: {} buffers, {} elements peak vs {} naive ({:.1}% saved), \
         {} in-place aliases, {} fused intermediates elided",
        plan.buffer_sizes.len(),
        plan.peak_elements(),
        no_reuse.naive_total,
        100.0 * (1.0 - plan.peak_elements() as f64 / no_reuse.naive_total as f64),
        plan.in_place_hits,
        elided.len()
    );

    println!("§3.3 cost model:");
    print!("{}", cost::render_table(&cost::analyze(&folded)?));

    // Lower from the already-folded spec (fold_bn off — the §3.5 line above
    // reports folding) so inspect pays one fold, not two.
    let program = compiled_nn::compiler::program::Program::lower(
        &folded,
        compiled_nn::compiler::program::CompileOptions {
            fold_bn: false,
            ..Default::default()
        },
    )?;
    println!("lowered program (folded spec → plan → lower):");
    print!("{}", program.summary());
    Ok(())
}

/// `explain [--model NAME] [--batch N]`: lower under the default
/// (cost-model `Auto`) options and print the per-layer lowering report —
/// every candidate the estimator priced, the chosen scheme, and why.
/// Without `--model` it explains the builtin demo net, so the command
/// works even before any artifacts are baked.
fn cmd_explain(args: &Args) -> Result<()> {
    use compiled_nn::compiler::program::{CompileOptions, Program};

    let batch = args.usize_or("batch", 1)?.max(1);
    let spec = match args.get("model") {
        Some(name) => {
            let manifest = Manifest::load_default()?;
            load_model(&manifest.models_dir, name)?
        }
        None => {
            println!("(no --model given: explaining the builtin tiny_cnn demo net)");
            compiled_nn::model::builder::tiny_cnn(7)
        }
    };
    let program = Program::lower(
        &spec,
        CompileOptions { batch_hint: batch, ..Default::default() },
    )?;
    print!("{}", program.summary().report.render_table());
    Ok(())
}

fn cmd_precision() -> Result<()> {
    println!("§3.4 activation approximations vs exact (4001-point sweeps):");
    println!("{:<20} {:>14} {:>14} {:>14} {:>14}", "function", "range", "max abs err", "mean abs err", "max rel err");
    for r in compiled_nn::approx::report(4001) {
        println!(
            "{:<20} {:>14} {:>14.3e} {:>14.3e} {:>14.3e}",
            r.name,
            format!("[{}, {}]", r.range.0, r.range.1),
            r.max_abs_err,
            r.mean_abs_err,
            r.max_rel_err
        );
    }
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    let iters = args.usize_or("iters", 5)?;
    let manifest = Manifest::load_default()?;
    println!("Table 1 analog (ms per batch-1 inference, {iters} iters after warmup; see cargo bench --bench table1 for the full run)");
    println!("{:<14} {:>12} {:>12} {:>12} {:>14}", "model", "compiled", "optimized", "naive", "compile ms");
    for name in manifest.models.keys() {
        let entry = manifest.entry(name)?;
        let x = golden_input(entry.seed, 1, &entry.input_shape);
        // one spec parse per model, shared by both interpreter kinds
        let spec = load_model(&manifest.models_dir, name)?;
        let mut cells: Vec<String> = Vec::new();
        let mut compile_ms: Option<f64> = None;
        for kind in EngineKind::ALL {
            if !kind.available() {
                cells.push(format!("{:>12}", "-"));
                continue;
            }
            let built = match kind {
                EngineKind::Compiled => {
                    build_engine(kind, &manifest, name, &EngineOptions::with_buckets(&[1]))
                }
                _ => build_engine_from_spec(kind, &spec, &EngineOptions::default()),
            };
            let mut engine = match built {
                Ok(e) => e,
                Err(err) => {
                    eprintln!("  {name}/{kind}: {err}");
                    cells.push(format!("{:>12}", "-"));
                    continue;
                }
            };
            // big nets: single iteration for the interpreters
            let n = if entry.params > 1_000_000 && kind != EngineKind::Compiled { 1 } else { iters };
            match time_ms(n, || engine.infer(&x).map(|_| ())) {
                Ok(ms) => {
                    cells.push(format!("{ms:>12.3}"));
                    if kind == EngineKind::Compiled {
                        compile_ms = Some(engine.compile_ms());
                    }
                }
                Err(err) => {
                    // keep rendering the rest of the table
                    eprintln!("  {name}/{kind}: {err}");
                    cells.push(format!("{:>12}", "-"));
                }
            }
        }
        // `-` (not 0.0) whenever no compiled engine was actually measured
        let compile_cell = match compile_ms {
            Some(ms) => format!("{ms:>14.1}"),
            None => format!("{:>14}", "-"),
        };
        println!("{:<14} {} {} {} {}", name, cells[0], cells[1], cells[2], compile_cell);
    }
    Ok(())
}

fn time_ms(iters: usize, mut f: impl FnMut() -> Result<()>) -> Result<f64> {
    f()?; // warmup
    let t0 = Instant::now();
    for _ in 0..iters {
        f()?;
    }
    Ok(t0.elapsed().as_secs_f64() * 1e3 / iters as f64)
}

fn cmd_serve(args: &Args) -> Result<()> {
    // --config path → TCP deployment; --model name → synthetic local load
    if let Some(cfg_path) = args.get("config") {
        return cmd_serve_tcp(cfg_path, args);
    }
    let name = args.req("model")?.to_string();
    let seconds = args.usize_or("seconds", 5)?;
    let offered = args.usize_or("offered", 2000)?; // requests/second
    let mut cfg = CoordinatorConfig::default();
    if let Some(engine) = args.get("engine") {
        cfg.engine = EngineKind::parse(engine)?;
    }
    cfg.workers = args.usize_or("workers", cfg.workers)?.max(1);
    let manifest = Manifest::load_default()?;
    let coord = Coordinator::start(manifest.clone(), cfg)?;
    let client = coord.register(&name)?;
    println!(
        "registered `{name}` on `{}` × {} worker(s): buckets {:?}, compile {:.1} ms \
         (cache hit: {})",
        client.info.engine,
        client.info.workers,
        client.info.buckets,
        client.info.compile_ms,
        client.info.cache_hit
    );

    let entry = manifest.entry(&name)?;
    let item: usize = entry.input_shape.iter().product();
    let mut rng = SplitMix64::new(99);
    let deadline = Instant::now() + Duration::from_secs(seconds as u64);
    let gap = Duration::from_secs_f64(1.0 / offered as f64);
    let mut pending = Vec::new();
    let mut sent = 0u64;
    while Instant::now() < deadline {
        let x = Tensor::from_vec(&entry.input_shape.clone(), rng.uniform_vec(item));
        pending.push(client.infer_async(x)?);
        sent += 1;
        if pending.len() >= 256 {
            for rx in pending.drain(..) {
                rx.recv().map_err(|_| anyhow::anyhow!("dropped"))??;
            }
        }
        std::thread::sleep(gap);
    }
    for rx in pending.drain(..) {
        rx.recv().map_err(|_| anyhow::anyhow!("dropped"))??;
    }
    println!("offered {offered} rps for {seconds}s → {sent} requests");
    print!("{}", coord.render_metrics());
    coord.shutdown();
    Ok(())
}

/// `serve --config serving.json [--seconds N] [--max-inflight N]
/// [--slo-ms MS]`: full TCP deployment — the launcher path. Runs until the
/// duration elapses (0 = forever). `--max-inflight` and `--slo-ms`
/// override the config file's admission-control keys (`max_inflight`,
/// `slo_p99_ms`) for the run.
fn cmd_serve_tcp(cfg_path: &str, args: &Args) -> Result<()> {
    use compiled_nn::coordinator::config::ServingConfig;
    use compiled_nn::coordinator::tcp::TcpServer;

    let cfg = ServingConfig::load(std::path::Path::new(cfg_path))?;
    let seconds = args.usize_or("seconds", 0)?;
    let mut opts = cfg.tcp_options();
    if let Some(v) = args.get("max-inflight") {
        opts.max_inflight =
            v.parse().with_context(|| "--max-inflight must be an integer".to_string())?;
    }
    if let Some(v) = args.get("slo-ms") {
        let slo: f64 = v.parse().with_context(|| "--slo-ms must be a number".to_string())?;
        anyhow::ensure!(slo >= 0.0, "--slo-ms must be >= 0 (0 disables SLO shedding)");
        opts.slo_p99_ms = slo;
    }
    let manifest = Manifest::load_default()?;
    let coord = Coordinator::start(manifest, cfg.coordinator_config())?;
    for m in &cfg.models {
        let client = coord.register(m)?;
        println!(
            "registered `{m}` on `{}` × {} worker(s): buckets {:?}, compile {:.1} ms",
            client.info.engine, client.info.workers, client.info.buckets, client.info.compile_ms
        );
    }
    let (max_inflight, slo_p99_ms) = (opts.max_inflight, opts.slo_p99_ms);
    let mut server = TcpServer::start_with(coord.clone(), &cfg.listen, opts)?;
    println!(
        "serving {} models on {} (max_inflight {max_inflight}, slo_p99_ms {slo_p99_ms})",
        cfg.models.len(),
        server.addr(),
    );
    if seconds == 0 {
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs(seconds as u64));
    print!("{}", coord.render_metrics());
    println!("{}", server.stats.render());
    server.shutdown();
    coord.shutdown();
    Ok(())
}

/// `client --addr host:port --model NAME [--count N]`: drive a running TCP
/// server with seeded random inputs and report latency.
fn cmd_client(args: &Args) -> Result<()> {
    use compiled_nn::coordinator::tcp::TcpClient;

    let addr = args.req("addr")?;
    let model = args.req("model")?;
    let count = args.usize_or("count", 10)?;
    let manifest = Manifest::load_default()?;
    let entry = manifest.entry(model)?;
    let item: usize = entry.input_shape.iter().product();
    let mut rng = SplitMix64::new(7);
    let mut client = TcpClient::connect(addr)?;
    let mut total_ms = 0.0;
    for i in 0..count {
        let t = Instant::now();
        let out = client.infer(model, rng.uniform_vec(item))?;
        let ms = t.elapsed().as_secs_f64() * 1e3;
        total_ms += ms;
        if i < 3 {
            let head: Vec<f32> = out.data().iter().take(4).copied().collect();
            println!("[{i}] {:.3} ms  shape {:?} head {:?}", ms, out.shape(), head);
        }
    }
    println!("{count} requests, mean {:.3} ms over the wire", total_ms / count as f64);
    Ok(())
}
