//! `compiled-nn` — CLI over the three-layer stack. Subcommands:
//!
//! ```text
//! compiled-nn compile                      # PJRT-compile all models, print Table-1 compile row
//! compiled-nn compile --model c_bh --out m.cnnprog [--dtype f32|bf16|i8] [--tune-reps N]
//!                                          # lower offline into a mmap-able compiled artifact
//! compiled-nn infer --model c_bh [--engine compiled|naive|optimized] [--batch N]
//! compiled-nn compare --model c_bh        # all engines vs the golden oracle
//! compiled-nn inspect --model c_bh        # §3.3 cost table + §3.2 memory plan + §3.5 folding
//! compiled-nn inspect --artifact m.cnnprog # validate + dump a compiled artifact's header/summary
//! compiled-nn explain [--model c_bh] [--batch N]   # cost-model lowering report (builtin demo net without --model)
//! compiled-nn precision                   # §3.4 approximation error table
//! compiled-nn table1 [--iters N]          # quick Table-1 analog (benches do it properly)
//! compiled-nn serve --model c_bh --seconds 5 [--offered RPS] [--engine KIND] [--workers N]
//! compiled-nn serve --config serving.json [--seconds N] [--max-inflight N] [--slo-ms MS]
//! ```
//!
//! Engines are never constructed directly here: every subcommand goes
//! through the `engine::EngineKind` registry, so the CLI degrades cleanly
//! when the `pjrt` feature (the compiled engine) is absent.
//!
//! Argument parsing is hand-rolled (clap is unavailable offline; the paper
//! hand-rolled its JSON parser in the same spirit).

use std::collections::HashMap;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use compiled_nn::compiler::{cost, fuse, memory};
use compiled_nn::coordinator::server::{Coordinator, CoordinatorConfig};
use compiled_nn::engine::{build_engine, build_engine_from_spec, Engine, EngineKind, EngineOptions};
use compiled_nn::model::load::load_model;
use compiled_nn::nn::tensor::Tensor;
use compiled_nn::runtime::artifact::Manifest;
use compiled_nn::util::rng::{golden_seed, SplitMix64};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal flag parser: `--key value` pairs after the subcommand.
struct Args {
    cmd: String,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse() -> Result<Args> {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".into());
        let mut flags = HashMap::new();
        while let Some(k) = it.next() {
            let key = k
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got `{k}`"))?
                .to_string();
            let v = it.next().with_context(|| format!("--{key} needs a value"))?;
            flags.insert(key, v);
        }
        Ok(Args { cmd, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    fn req(&self, key: &str) -> Result<&str> {
        self.get(key).with_context(|| format!("missing --{key}"))
    }

    fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
            None => Ok(default),
        }
    }
}

fn run() -> Result<()> {
    let args = Args::parse()?;
    match args.cmd.as_str() {
        "compile" => cmd_compile(&args),
        "infer" => cmd_infer(&args),
        "compare" => cmd_compare(&args),
        "inspect" => cmd_inspect(&args),
        "explain" => cmd_explain(&args),
        "precision" => cmd_precision(),
        "table1" => cmd_table1(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command `{other}`\n{HELP}"),
    }
}

const HELP: &str = "compiled-nn — JIT-compiled NN inference (paper reproduction)
commands: compile | infer | compare | inspect | explain | precision | table1 | serve
engines (--engine): compiled (needs the `pjrt` build feature) | optimized | naive
artifacts: compile --model NAME --out FILE [--dtype f32|bf16|i8] [--tune-reps N]
           inspect --artifact FILE
cache: export COMPILED_NN_CACHE_DIR (or the serving config's `cache_dir` key) to
       mmap-load cached artifacts instead of re-lowering on every start
see the module docs in rust/src/main.rs for flags";

/// Deterministic golden input, bit-identical to aot.py's.
fn golden_input(seed: u64, batch: usize, item_shape: &[usize]) -> Tensor {
    let mut shape = vec![batch];
    shape.extend_from_slice(item_shape);
    let n: usize = shape.iter().product();
    let mut rng = SplitMix64::new(golden_seed(seed));
    Tensor::from_vec(&shape, rng.uniform_vec(n))
}

/// `compile` without `--model` keeps the original PJRT Table-1 behavior;
/// with `--model NAME --out FILE` it lowers offline into a versioned,
/// mmap-able compiled artifact (the fleet cold-start path).
fn cmd_compile(args: &Args) -> Result<()> {
    if args.get("model").is_some() {
        return cmd_compile_artifact(args);
    }
    if !EngineKind::Compiled.available() {
        bail!(
            "`compile` needs the compiled engine, which is unavailable on this \
             host (requires the `pjrt` build feature and a working PJRT plugin)"
        );
    }
    let manifest = Manifest::load_default()?;
    println!("{:<14} {:>10} {:>7} {:>14}", "model", "params", "baked", "compile ms");
    for name in manifest.models.keys() {
        let entry = manifest.entry(name)?;
        let engine = build_engine(EngineKind::Compiled, &manifest, name, &EngineOptions::default())?;
        println!(
            "{:<14} {:>10} {:>7} {:>14.1}",
            name, entry.params, entry.baked, engine.compile_ms()
        );
    }
    Ok(())
}

/// Resolve a model name for the artifact commands: the manifest wins when
/// it resolves and lists the name; otherwise the builtin demo nets work
/// with no baked artifacts at all.
fn resolve_spec(name: &str) -> Result<compiled_nn::model::spec::ModelSpec> {
    if let Ok(manifest) = Manifest::load_default() {
        if manifest.models.contains_key(name) {
            return load_model(&manifest.models_dir, name);
        }
    }
    match name {
        "tiny_cnn" => Ok(compiled_nn::model::builder::tiny_cnn(7)),
        "wide_cnn" => Ok(compiled_nn::model::builder::wide_cnn(7)),
        "square_mlp" => Ok(compiled_nn::model::builder::square_mlp(7, 64, 3)),
        other => bail!(
            "unknown model `{other}`: not in the manifest and not a builtin \
             (tiny_cnn | wide_cnn | square_mlp)"
        ),
    }
}

/// `compile --model NAME --out FILE [--dtype f32|bf16|i8] [--tune-reps N]`:
/// lower once (optionally with measured autotuning) and serialize the
/// program to a compiled artifact that `inspect --artifact`, the serving
/// cache, and `Coordinator::hot_swap_artifact` consume.
fn cmd_compile_artifact(args: &Args) -> Result<()> {
    use compiled_nn::compiler::artifact::{save_program, spec_content_hash};
    use compiled_nn::compiler::program::{CompileOptions, Program, TuneMode};

    let name = args.req("model")?;
    let out = args.req("out")?;
    let spec = resolve_spec(name)?;
    let mut opts = CompileOptions::default();
    if let Some(d) = args.get("dtype") {
        opts.weight_dtype = compiled_nn::nn::simd::WeightDtype::parse(d)
            .with_context(|| format!("unknown --dtype `{d}` (expected f32|bf16|i8)"))?;
    }
    if let Some(r) = args.get("tune-reps") {
        let reps: u32 = r.parse().context("--tune-reps must be an integer")?;
        opts.tune = TuneMode::Measured { reps: reps.max(1) };
    }
    let t0 = Instant::now();
    let program = Program::lower(&spec, opts)?;
    let lower_ms = t0.elapsed().as_secs_f64() * 1e3;
    let path = std::path::Path::new(out);
    save_program(&program, spec_content_hash(&spec), opts, path)?;
    let bytes = std::fs::metadata(path)?.len();
    println!(
        "compiled `{name}` → {} ({bytes} bytes, lowered in {lower_ms:.1} ms)",
        path.display()
    );
    Ok(())
}

fn cmd_infer(args: &Args) -> Result<()> {
    let name = args.req("model")?;
    // default to the best engine this build provides (compiled on pjrt
    // builds, optimized otherwise) so the bare command works everywhere
    let kind = match args.get("engine") {
        Some(s) => EngineKind::parse(s)?,
        None => EngineKind::preferred(),
    };
    let batch = args.usize_or("batch", 1)?;
    let manifest = Manifest::load_default()?;
    let entry = manifest.entry(name)?;
    let x = golden_input(entry.seed, batch, &entry.input_shape);

    let t0 = Instant::now();
    let opts = if kind == EngineKind::Compiled {
        // only specialize the bucket we are about to run
        EngineOptions::with_buckets(&[batch])
    } else {
        EngineOptions::default()
    };
    let mut engine = build_engine(kind, &manifest, name, &opts)?;
    if engine.compile_ms() > 0.0 {
        println!("compile: {:.1} ms", engine.compile_ms());
    }
    let t = Instant::now();
    let out = engine.infer(&x)?;
    println!("execute: {:.3} ms", t.elapsed().as_secs_f64() * 1e3);
    println!("load+infer total: {:.3} ms", t0.elapsed().as_secs_f64() * 1e3);
    for (i, o) in out.iter().enumerate() {
        let head: Vec<f32> = o.data().iter().take(8).copied().collect();
        println!("output[{i}] shape {:?} head {:?}", o.shape(), head);
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let name = args.req("model")?;
    let manifest = Manifest::load_default()?;
    let entry = manifest.entry(name)?;
    let x = golden_input(entry.seed, 1, &entry.input_shape);

    // one spec parse shared by the oracle and the optimized interpreter
    let spec = load_model(&manifest.models_dir, name)?;
    let mut oracle = build_engine_from_spec(EngineKind::Naive, &spec, &EngineOptions::default())?;
    let exact = oracle.infer(&x)?;

    for kind in [EngineKind::Optimized, EngineKind::Compiled] {
        if !kind.available() {
            println!("{:<9} vs naive-exact: unavailable on this host", kind.as_str());
            continue;
        }
        let built = if kind == EngineKind::Compiled {
            build_engine(kind, &manifest, name, &EngineOptions::with_buckets(&[1]))
        } else {
            build_engine_from_spec(kind, &spec, &EngineOptions::default())
        };
        let mut engine = match built {
            Ok(e) => e,
            Err(e) => {
                println!("{:<9} vs naive-exact: skipped ({e})", kind.as_str());
                continue;
            }
        };
        let out = engine.infer(&x)?;
        println!(
            "{:<9} vs naive-exact: max |Δ| = {:.2e}",
            kind.as_str(),
            exact[0].max_abs_diff(&out[0])
        );
    }
    println!("(approx activations bound the differences; see `precision`)");
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    if let Some(path) = args.get("artifact") {
        return cmd_inspect_artifact(std::path::Path::new(path));
    }
    let name = args.req("model")?;
    let manifest = Manifest::load_default()?;
    let spec = load_model(&manifest.models_dir, name)?;
    println!("== {name}: {} layers, {} params", spec.layers.len(), spec.param_count());

    let folded = fuse::fold_batchnorm(&spec);
    println!(
        "§3.5 folding: {} batchnorm layers → {} (layers {} → {})",
        fuse::bn_count(&spec),
        fuse::bn_count(&folded),
        spec.layers.len(),
        folded.layers.len()
    );

    // Plan with the same §3.4 pool-fusion elision the default lowering
    // applies, so the reported peak matches the arena the engine allocates.
    let elided: std::collections::BTreeSet<String> =
        fuse::fusible_maxpool_pairs(&folded).into_keys().collect();
    let plan = memory::plan_elided(&folded, true, &elided)?;
    let no_reuse = memory::plan_elided(&folded, false, &elided)?;
    println!(
        "§3.2 memory: {} buffers, {} elements peak vs {} naive ({:.1}% saved), \
         {} in-place aliases, {} fused intermediates elided",
        plan.buffer_sizes.len(),
        plan.peak_elements(),
        no_reuse.naive_total,
        100.0 * (1.0 - plan.peak_elements() as f64 / no_reuse.naive_total as f64),
        plan.in_place_hits,
        elided.len()
    );

    println!("§3.3 cost model:");
    print!("{}", cost::render_table(&cost::analyze(&folded)?));

    // Lower from the already-folded spec (fold_bn off — the §3.5 line above
    // reports folding) so inspect pays one fold, not two.
    let program = compiled_nn::compiler::program::Program::lower(
        &folded,
        compiled_nn::compiler::program::CompileOptions {
            fold_bn: false,
            ..Default::default()
        },
    )?;
    println!("lowered program (folded spec → plan → lower):");
    print!("{}", program.summary());
    Ok(())
}

/// `inspect --artifact FILE`: validate + mmap-load a compiled artifact and
/// dump its header fields, the lowered-program summary, and the persisted
/// per-layer lowering report (including any measured-tuning winners).
fn cmd_inspect_artifact(path: &std::path::Path) -> Result<()> {
    use compiled_nn::compiler::artifact::load_program;

    let t0 = Instant::now();
    let (program, info) = load_program(path)
        .map_err(|e| anyhow::anyhow!("loading artifact {}: {e}", path.display()))?;
    let load_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!("== artifact {}", path.display());
    println!(
        "format v{}, spec hash {:016x}, cpu features {:#06x}, required lanes {}",
        info.version, info.spec_hash, info.features, info.required_lanes
    );
    println!(
        "meta {} B + weight blob {} B = {} B total; validated + mapped in {load_ms:.2} ms",
        info.meta_bytes, info.blob_bytes, info.total_bytes
    );
    println!("options: {:?}", info.options);
    println!("lowered program:");
    print!("{}", program.summary());
    print!("{}", program.summary().report.render_table());
    Ok(())
}

/// `explain [--model NAME] [--batch N]`: lower under the default
/// (cost-model `Auto`) options and print the per-layer lowering report —
/// every candidate the estimator priced, the chosen scheme, and why.
/// Without `--model` it explains the builtin demo net, so the command
/// works even before any artifacts are baked. The lowering goes through
/// the artifact cache when `COMPILED_NN_CACHE_DIR` is set, and the cache's
/// global hit/miss/invalidation counters print either way.
fn cmd_explain(args: &Args) -> Result<()> {
    use compiled_nn::compiler::artifact::ProgramCache;
    use compiled_nn::compiler::program::CompileOptions;

    let batch = args.usize_or("batch", 1)?.max(1);
    let spec = match args.get("model") {
        Some(name) => {
            let manifest = Manifest::load_default()?;
            load_model(&manifest.models_dir, name)?
        }
        None => {
            println!("(no --model given: explaining the builtin tiny_cnn demo net)");
            compiled_nn::model::builder::tiny_cnn(7)
        }
    };
    let cache = ProgramCache::global();
    let program =
        cache.lower_or_load(&spec, CompileOptions { batch_hint: batch, ..Default::default() })?;
    print!("{}", program.summary().report.render_table());
    let c = cache.counters();
    match cache.dir() {
        Some(dir) => println!(
            "artifact cache {}: {} hit(s), {} miss(es), {} invalidated",
            dir.display(),
            c.hits,
            c.misses,
            c.invalidated
        ),
        None => println!("artifact cache disabled (set COMPILED_NN_CACHE_DIR to enable)"),
    }
    Ok(())
}

fn cmd_precision() -> Result<()> {
    println!("§3.4 activation approximations vs exact (4001-point sweeps):");
    println!("{:<20} {:>14} {:>14} {:>14} {:>14}", "function", "range", "max abs err", "mean abs err", "max rel err");
    for r in compiled_nn::approx::report(4001) {
        println!(
            "{:<20} {:>14} {:>14.3e} {:>14.3e} {:>14.3e}",
            r.name,
            format!("[{}, {}]", r.range.0, r.range.1),
            r.max_abs_err,
            r.mean_abs_err,
            r.max_rel_err
        );
    }
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    let iters = args.usize_or("iters", 5)?;
    let manifest = Manifest::load_default()?;
    println!("Table 1 analog (ms per batch-1 inference, {iters} iters after warmup; see cargo bench --bench table1 for the full run)");
    println!("{:<14} {:>12} {:>12} {:>12} {:>14}", "model", "compiled", "optimized", "naive", "compile ms");
    for name in manifest.models.keys() {
        let entry = manifest.entry(name)?;
        let x = golden_input(entry.seed, 1, &entry.input_shape);
        // one spec parse per model, shared by both interpreter kinds
        let spec = load_model(&manifest.models_dir, name)?;
        let mut cells: Vec<String> = Vec::new();
        let mut compile_ms: Option<f64> = None;
        for kind in EngineKind::ALL {
            if !kind.available() {
                cells.push(format!("{:>12}", "-"));
                continue;
            }
            let built = match kind {
                EngineKind::Compiled => {
                    build_engine(kind, &manifest, name, &EngineOptions::with_buckets(&[1]))
                }
                _ => build_engine_from_spec(kind, &spec, &EngineOptions::default()),
            };
            let mut engine = match built {
                Ok(e) => e,
                Err(err) => {
                    eprintln!("  {name}/{kind}: {err}");
                    cells.push(format!("{:>12}", "-"));
                    continue;
                }
            };
            // big nets: single iteration for the interpreters
            let n = if entry.params > 1_000_000 && kind != EngineKind::Compiled { 1 } else { iters };
            match time_ms(n, || engine.infer(&x).map(|_| ())) {
                Ok(ms) => {
                    cells.push(format!("{ms:>12.3}"));
                    if kind == EngineKind::Compiled {
                        compile_ms = Some(engine.compile_ms());
                    }
                }
                Err(err) => {
                    // keep rendering the rest of the table
                    eprintln!("  {name}/{kind}: {err}");
                    cells.push(format!("{:>12}", "-"));
                }
            }
        }
        // `-` (not 0.0) whenever no compiled engine was actually measured
        let compile_cell = match compile_ms {
            Some(ms) => format!("{ms:>14.1}"),
            None => format!("{:>14}", "-"),
        };
        println!("{:<14} {} {} {} {}", name, cells[0], cells[1], cells[2], compile_cell);
    }
    Ok(())
}

fn time_ms(iters: usize, mut f: impl FnMut() -> Result<()>) -> Result<f64> {
    f()?; // warmup
    let t0 = Instant::now();
    for _ in 0..iters {
        f()?;
    }
    Ok(t0.elapsed().as_secs_f64() * 1e3 / iters as f64)
}

fn cmd_serve(args: &Args) -> Result<()> {
    // --config path → TCP deployment; --model name → synthetic local load
    if let Some(cfg_path) = args.get("config") {
        return cmd_serve_tcp(cfg_path, args);
    }
    let name = args.req("model")?.to_string();
    let seconds = args.usize_or("seconds", 5)?;
    let offered = args.usize_or("offered", 2000)?; // requests/second
    let mut cfg = CoordinatorConfig::default();
    if let Some(engine) = args.get("engine") {
        cfg.engine = EngineKind::parse(engine)?;
    }
    cfg.workers = args.usize_or("workers", cfg.workers)?.max(1);
    let manifest = Manifest::load_default()?;
    let coord = Coordinator::start(manifest.clone(), cfg)?;
    let client = coord.register(&name)?;
    println!(
        "registered `{name}` on `{}` × {} worker(s): buckets {:?}, compile {:.1} ms \
         (cache hit: {})",
        client.info.engine,
        client.info.workers,
        client.info.buckets,
        client.info.compile_ms,
        client.info.cache_hit
    );

    let entry = manifest.entry(&name)?;
    let item: usize = entry.input_shape.iter().product();
    let mut rng = SplitMix64::new(99);
    let deadline = Instant::now() + Duration::from_secs(seconds as u64);
    let gap = Duration::from_secs_f64(1.0 / offered as f64);
    let mut pending = Vec::new();
    let mut sent = 0u64;
    while Instant::now() < deadline {
        let x = Tensor::from_vec(&entry.input_shape.clone(), rng.uniform_vec(item));
        pending.push(client.infer_async(x)?);
        sent += 1;
        if pending.len() >= 256 {
            for rx in pending.drain(..) {
                rx.recv().map_err(|_| anyhow::anyhow!("dropped"))??;
            }
        }
        std::thread::sleep(gap);
    }
    for rx in pending.drain(..) {
        rx.recv().map_err(|_| anyhow::anyhow!("dropped"))??;
    }
    println!("offered {offered} rps for {seconds}s → {sent} requests");
    print!("{}", coord.render_metrics());
    coord.shutdown();
    Ok(())
}

/// `serve --config serving.json [--seconds N] [--max-inflight N]
/// [--slo-ms MS]`: full TCP deployment — the launcher path. Runs until the
/// duration elapses (0 = forever). `--max-inflight` and `--slo-ms`
/// override the config file's admission-control keys (`max_inflight`,
/// `slo_p99_ms`) for the run.
fn cmd_serve_tcp(cfg_path: &str, args: &Args) -> Result<()> {
    use compiled_nn::coordinator::config::ServingConfig;
    use compiled_nn::coordinator::tcp::TcpServer;

    let cfg = ServingConfig::load(std::path::Path::new(cfg_path))?;
    // The global artifact cache reads the env var at first use, which is
    // the first registration below — export the config key before the
    // coordinator starts. An operator-exported var wins over the config.
    if let Some(dir) = &cfg.cache_dir {
        if std::env::var_os("COMPILED_NN_CACHE_DIR").is_none() {
            std::env::set_var("COMPILED_NN_CACHE_DIR", dir);
        }
    }
    let seconds = args.usize_or("seconds", 0)?;
    let mut opts = cfg.tcp_options();
    if let Some(v) = args.get("max-inflight") {
        opts.max_inflight =
            v.parse().with_context(|| "--max-inflight must be an integer".to_string())?;
    }
    if let Some(v) = args.get("slo-ms") {
        let slo: f64 = v.parse().with_context(|| "--slo-ms must be a number".to_string())?;
        anyhow::ensure!(slo >= 0.0, "--slo-ms must be >= 0 (0 disables SLO shedding)");
        opts.slo_p99_ms = slo;
    }
    let manifest = Manifest::load_default()?;
    let coord = Coordinator::start(manifest, cfg.coordinator_config())?;
    for m in &cfg.models {
        let client = coord.register(m)?;
        println!(
            "registered `{m}` on `{}` × {} worker(s): buckets {:?}, compile {:.1} ms",
            client.info.engine, client.info.workers, client.info.buckets, client.info.compile_ms
        );
    }
    let (max_inflight, slo_p99_ms) = (opts.max_inflight, opts.slo_p99_ms);
    let mut server = TcpServer::start_with(coord.clone(), &cfg.listen, opts)?;
    println!(
        "serving {} models on {} (max_inflight {max_inflight}, slo_p99_ms {slo_p99_ms})",
        cfg.models.len(),
        server.addr(),
    );
    if seconds == 0 {
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs(seconds as u64));
    print!("{}", coord.render_metrics());
    println!("{}", server.stats.render());
    server.shutdown();
    coord.shutdown();
    Ok(())
}

/// `client --addr host:port --model NAME [--count N]`: drive a running TCP
/// server with seeded random inputs and report latency.
fn cmd_client(args: &Args) -> Result<()> {
    use compiled_nn::coordinator::tcp::TcpClient;

    let addr = args.req("addr")?;
    let model = args.req("model")?;
    let count = args.usize_or("count", 10)?;
    let manifest = Manifest::load_default()?;
    let entry = manifest.entry(model)?;
    let item: usize = entry.input_shape.iter().product();
    let mut rng = SplitMix64::new(7);
    let mut client = TcpClient::connect(addr)?;
    let mut total_ms = 0.0;
    for i in 0..count {
        let t = Instant::now();
        let out = client.infer(model, rng.uniform_vec(item))?;
        let ms = t.elapsed().as_secs_f64() * 1e3;
        total_ms += ms;
        if i < 3 {
            let head: Vec<f32> = out.data().iter().take(4).copied().collect();
            println!("[{i}] {:.3} ms  shape {:?} head {:?}", ms, out.shape(), head);
        }
    }
    println!("{count} requests, mean {:.3} ms over the wire", total_ms / count as f64);
    Ok(())
}
