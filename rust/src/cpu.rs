//! Host CPU feature probing and the lane-width dispatch policy.
//!
//! Every width-generic microkernel in [`crate::nn::simd`] is *correct* on
//! any CPU — the `[f32; W]` forms are plain Rust that LLVM lowers onto
//! whatever vector unit exists (or scalar code). Which width is *fast* is
//! a per-host question: 8-lane groups only pay off when the host has
//! 256-bit units (AVX2), 16-lane groups need AVX-512F. This module answers
//! that question once, and `Program::lower` treats the answer as an input
//! to the §3.3 cost model rather than a hard override — a tail-dominated
//! layer can still legitimately prefer 4 lanes on an AVX-512 host.
//!
//! Dispatch precedence (widest to run by default, narrowest to debug):
//!
//! 1. an explicit width forced via `CompileOptions::lanes`,
//! 2. the `COMPILED_NN_FORCE_LANES` environment variable
//!    (`scalar`/`1`/`4`/`8`/`16`) — how CI exercises every dispatch path
//!    on runners without AVX-512,
//! 3. the widest width the probed [`Features`] support.

/// The ISA features the lane dispatch cares about. Probed with
/// `is_x86_feature_detected!` on x86-64; conservatively all-false on every
/// other architecture (the portable 4-lane kernels remain the default
/// there).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Features {
    /// 256-bit vector units (AVX2 implies AVX + FMA-capable cores in
    /// practice; the kernels don't emit intrinsics, so AVX2 alone is the
    /// signal that 8-lane groups map onto one register).
    pub avx2: bool,
    /// 512-bit vector units (AVX-512 Foundation).
    pub avx512f: bool,
}

impl Features {
    /// Probe the host. Cheap enough to call per lowering (the macro caches
    /// its CPUID results internally), and deterministic for a given host.
    #[cfg(target_arch = "x86_64")]
    pub fn detect() -> Features {
        Features {
            avx2: std::arch::is_x86_feature_detected!("avx2"),
            avx512f: std::arch::is_x86_feature_detected!("avx512f"),
        }
    }

    /// Non-x86 hosts: no wide-vector claim, 4-lane kernels stay default.
    #[cfg(not(target_arch = "x86_64"))]
    pub fn detect() -> Features {
        Features::default()
    }

    /// The widest profitable lane width for these features: 16 on
    /// AVX-512F, 8 on AVX2, else the 4-lane SSE baseline (x86-64 always
    /// has SSE2; other ISAs get the same portable 4-lane code).
    pub fn max_lanes(self) -> usize {
        if self.avx512f {
            16
        } else if self.avx2 {
            8
        } else {
            4
        }
    }
}

/// Parse a `COMPILED_NN_FORCE_LANES` value. Accepts `scalar` (or `1`),
/// `4`, `8`, `16`; anything else is `None` (ignored, auto-detect wins).
pub fn parse_force_lanes(s: &str) -> Option<usize> {
    match s.trim() {
        "scalar" | "1" => Some(1),
        "4" => Some(4),
        "8" => Some(8),
        "16" => Some(16),
        _ => None,
    }
}

/// The environment override, if set and valid.
pub fn env_force_lanes() -> Option<usize> {
    std::env::var("COMPILED_NN_FORCE_LANES").ok().and_then(|v| parse_force_lanes(&v))
}

/// The lane width `Auto` dispatch resolves to on this host: the
/// environment override when present, else the widest detected width.
/// This is the *candidate ceiling* for the cost model — lowering prices
/// every width up to this and may still pick a narrower one.
pub fn auto_lanes() -> usize {
    env_force_lanes().unwrap_or_else(|| Features::detect().max_lanes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_lanes_orders_the_feature_ladder() {
        assert_eq!(Features { avx2: false, avx512f: false }.max_lanes(), 4);
        assert_eq!(Features { avx2: true, avx512f: false }.max_lanes(), 8);
        assert_eq!(Features { avx2: true, avx512f: true }.max_lanes(), 16);
        // a (hypothetical) avx512f-without-avx2 report still takes 16
        assert_eq!(Features { avx2: false, avx512f: true }.max_lanes(), 16);
    }

    #[test]
    fn force_lanes_parses_the_documented_values_only() {
        assert_eq!(parse_force_lanes("scalar"), Some(1));
        assert_eq!(parse_force_lanes("1"), Some(1));
        assert_eq!(parse_force_lanes("4"), Some(4));
        assert_eq!(parse_force_lanes(" 8 "), Some(8));
        assert_eq!(parse_force_lanes("16"), Some(16));
        assert_eq!(parse_force_lanes("32"), None);
        assert_eq!(parse_force_lanes("avx2"), None);
        assert_eq!(parse_force_lanes(""), None);
    }

    #[test]
    fn detect_reports_a_supported_width() {
        // whatever the host, the resolved width must be one the kernels
        // are instantiated at
        let w = Features::detect().max_lanes();
        assert!(crate::nn::simd::LANE_WIDTHS.contains(&w));
        assert!(crate::nn::simd::LANE_WIDTHS.contains(&auto_lanes()));
    }
}
