//! In-process compile cache: artifact sha256 → compiled executable handle.
//!
//! The paper pays its JIT cost once per model load; we additionally memoize
//! by content hash so re-registering an identical artifact (same sha in the
//! manifest) skips parse + codegen entirely — the `serve` path re-registers
//! models on config reload.

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::Result;

use super::artifact::Manifest;
use super::executor::{CompiledModel, Runtime};

/// Not `Send` (PJRT confinement) — lives on the executor thread.
#[derive(Default)]
pub struct CompileCache {
    by_sha: HashMap<String, Rc<CompiledModel>>,
    pub hits: usize,
    pub misses: usize,
}

impl CompileCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Cache key: concatenated shas of every bucket artifact of the model.
    fn key(manifest: &Manifest, name: &str) -> Result<String> {
        let e = manifest.entry(name)?;
        let mut k = String::new();
        for f in e.artifacts.values() {
            k.push_str(&f.sha256);
        }
        Ok(k)
    }

    pub fn get_or_load(
        &mut self,
        rt: &Runtime,
        manifest: &Manifest,
        name: &str,
    ) -> Result<Rc<CompiledModel>> {
        let key = Self::key(manifest, name)?;
        if let Some(m) = self.by_sha.get(&key) {
            self.hits += 1;
            return Ok(m.clone());
        }
        self.misses += 1;
        let m = Rc::new(CompiledModel::load(rt, manifest, name)?);
        self.by_sha.insert(key, m.clone());
        Ok(m)
    }

    pub fn len(&self) -> usize {
        self.by_sha.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_sha.is_empty()
    }
}
