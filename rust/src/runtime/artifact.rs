//! Artifact manifest (`artifacts/manifest.json`) — the contract between
//! `python/compile/aot.py` and the Rust runtime. Describes, per model: batch
//! buckets, HLO files (+ sha256), whether weights are baked into the HLO as
//! constants or fed as runtime arguments, and the argument order/offsets for
//! the latter.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct WeightArg {
    pub layer: String,
    pub key: String,
    pub offset: usize,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct ArtifactFile {
    pub file: String,
    pub sha256: String,
    pub bytes: usize,
}

#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: String,
    pub input_shape: Vec<usize>,
    /// Output shapes at batch 1 (leading dim replaced by the actual batch).
    pub output_shapes_b1: Vec<Vec<usize>>,
    pub batches: Vec<usize>,
    pub baked: bool,
    pub approx: bool,
    pub params: usize,
    pub seed: u64,
    pub artifacts: BTreeMap<usize, ArtifactFile>,
    pub spec_file: String,
    /// For unbaked models: the folded blob + argument order.
    pub weights_file: Option<String>,
    pub weight_args: Vec<WeightArg>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub models: BTreeMap<String, ModelEntry>,
    pub artifacts_dir: PathBuf,
    pub models_dir: PathBuf,
}

impl Manifest {
    /// Load from `artifacts_dir/manifest.json`; `models_dir` holds specs and
    /// weight blobs.
    pub fn load(artifacts_dir: &Path, models_dir: &Path) -> Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        if j.req_str("format")? != "manifest-v1" {
            bail!("unsupported manifest format");
        }
        let mut models = BTreeMap::new();
        for (name, mj) in j.req("models")?.as_obj().context("models must be an object")? {
            models.insert(name.clone(), parse_entry(name, mj)?);
        }
        Ok(Manifest {
            models,
            artifacts_dir: artifacts_dir.to_path_buf(),
            models_dir: models_dir.to_path_buf(),
        })
    }

    /// An artifact-less manifest: no models, placeholder directories. The
    /// coordinator accepts this when every model is registered from an
    /// in-memory spec (`Coordinator::register_spec`) — serving benches and
    /// stress tests run on runners that never ran `make artifacts`.
    pub fn empty() -> Manifest {
        Manifest {
            models: BTreeMap::new(),
            artifacts_dir: PathBuf::from("."),
            models_dir: PathBuf::from("."),
        }
    }

    /// Default locations relative to the repo root (or `COMPILED_NN_ROOT`).
    pub fn load_default() -> Result<Manifest> {
        let root = std::env::var("COMPILED_NN_ROOT").unwrap_or_else(|_| ".".into());
        let root = Path::new(&root);
        Manifest::load(&root.join("artifacts"), &root.join("models"))
    }

    pub fn entry(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .with_context(|| format!("model `{name}` not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()))
    }

    pub fn hlo_path(&self, entry: &ModelEntry, batch: usize) -> Result<PathBuf> {
        let f = entry
            .artifacts
            .get(&batch)
            .with_context(|| format!("model `{}` has no batch-{batch} artifact (buckets {:?})",
                entry.name, entry.batches))?;
        Ok(self.artifacts_dir.join(&f.file))
    }
}

fn parse_entry(name: &str, j: &Json) -> Result<ModelEntry> {
    let output_shapes_b1 = j
        .req_arr("output_shapes_b1")?
        .iter()
        .map(|s| s.as_usize_vec().context("bad output shape"))
        .collect::<Result<Vec<_>>>()?;
    let batches = j.req("batches")?.as_usize_vec().context("bad batches")?;
    let mut artifacts = BTreeMap::new();
    for (b, fj) in j.req("artifacts")?.as_obj().context("artifacts")? {
        artifacts.insert(
            b.parse::<usize>().context("artifact batch key")?,
            ArtifactFile {
                file: fj.req_str("file")?.to_string(),
                sha256: fj.req_str("sha256")?.to_string(),
                bytes: fj.req_usize("bytes")?,
            },
        );
    }
    let mut weight_args = Vec::new();
    if let Some(wa) = j.get("weight_args") {
        for w in wa.as_arr().context("weight_args")? {
            weight_args.push(WeightArg {
                layer: w.req_str("layer")?.to_string(),
                key: w.req_str("key")?.to_string(),
                offset: w.req_usize("offset")?,
                shape: w.req("shape")?.as_usize_vec().context("weight shape")?,
            });
        }
    }
    Ok(ModelEntry {
        name: name.to_string(),
        input_shape: j.req("input_shape")?.as_usize_vec().context("input_shape")?,
        output_shapes_b1,
        batches,
        baked: j.req("baked")?.as_bool().context("baked")?,
        approx: j.get("approx").and_then(Json::as_bool).unwrap_or(false),
        params: j.req_usize("params")?,
        seed: j.req_usize("seed")? as u64,
        artifacts,
        spec_file: j.req_str("spec_file")?.to_string(),
        weights_file: j.get("weights_file").and_then(Json::as_str).map(str::to_string),
        weight_args,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_real_manifest_if_present() {
        let art = Path::new("artifacts");
        if !art.join("manifest.json").exists() {
            return; // unit-test environments without `make artifacts`
        }
        let m = Manifest::load(art, Path::new("models")).unwrap();
        assert!(m.models.contains_key("c_bh"));
        let e = m.entry("c_bh").unwrap();
        assert!(e.baked);
        assert_eq!(e.input_shape, vec![32, 32, 1]);
        assert_eq!(e.batches, vec![1, 8, 32]);
        for b in &e.batches {
            assert!(m.hlo_path(e, *b).unwrap().exists());
        }
        let v = m.entry("vgg19").unwrap();
        assert!(!v.baked);
        assert!(!v.weight_args.is_empty());
        assert!(v.weights_file.is_some());
    }

    #[test]
    fn unknown_model_is_error() {
        let art = Path::new("artifacts");
        if !art.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(art, Path::new("models")).unwrap();
        assert!(m.entry("nope").is_err());
    }
}
