//! `CompiledModel` — the runtime-JIT analog of the paper's `CompiledNN`
//! class. Loading a model = parse HLO text + PJRT-compile to native code
//! (this *is* the compilation step Table 1's last row times); `execute` then
//! runs the specialized executable with zero Python anywhere near the path.
//!
//! Weights-as-args models upload their (folded) weight blob to device
//! buffers once at load; per-call traffic is the input tensor only.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::engine::EngineOptions;
use crate::model::load::load_weights_blob;
use crate::model::spec::ModelSpec;
use crate::nn::tensor::Tensor;

use super::artifact::{Manifest, ModelEntry};

/// Thin owner of the PJRT CPU client. NOT `Send` — PJRT wrapper types hold
/// raw pointers; the coordinator confines all of this to one executor
/// thread (see `coordinator::server`).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn new() -> Result<Self> {
        Ok(Self { client: xla::PjRtClient::cpu().context("creating PJRT CPU client")? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Parse + compile one HLO text file; returns the executable and the
    /// wall-clock compile time in ms (parse and codegen separately).
    pub fn compile_hlo(&self, path: &Path) -> Result<(xla::PjRtLoadedExecutable, CompileTiming)> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let parse_ms = t0.elapsed().as_secs_f64() * 1e3;
        let comp = xla::XlaComputation::from_proto(&proto);
        let t1 = Instant::now();
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("PJRT compile of {}", path.display()))?;
        let compile_ms = t1.elapsed().as_secs_f64() * 1e3;
        Ok((exe, CompileTiming { parse_ms, compile_ms }))
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct CompileTiming {
    /// HLO text → HloModuleProto (the paper's "read model" share).
    pub parse_ms: f64,
    /// XLA:CPU codegen (the paper's "generate machine code" share).
    pub compile_ms: f64,
}

impl CompileTiming {
    pub fn total_ms(&self) -> f64 {
        self.parse_ms + self.compile_ms
    }
}

/// A fully loaded model: one specialized executable per batch bucket
/// (shape-specialized code, exactly like the paper's generated functions).
pub struct CompiledModel {
    pub entry: ModelEntry,
    exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    weight_bufs: Vec<xla::PjRtBuffer>,
    pub timings: BTreeMap<usize, CompileTiming>,
    /// Device upload time for the weights-as-args blob (0 for baked).
    pub weights_upload_ms: f64,
}

impl CompiledModel {
    /// Load every batch bucket of `name` from the manifest.
    pub fn load(rt: &Runtime, manifest: &Manifest, name: &str) -> Result<Self> {
        let entry = manifest.entry(name)?.clone();
        Self::load_buckets(rt, manifest, &entry, &entry.batches.clone())
    }

    /// Load a subset of batch buckets (benches use this to time each).
    pub fn load_buckets(
        rt: &Runtime,
        manifest: &Manifest,
        entry: &ModelEntry,
        buckets: &[usize],
    ) -> Result<Self> {
        let mut exes = BTreeMap::new();
        let mut timings = BTreeMap::new();
        for &b in buckets {
            let path = manifest.hlo_path(entry, b)?;
            let (exe, t) = rt.compile_hlo(&path)?;
            exes.insert(b, exe);
            timings.insert(b, t);
        }

        // Weights-as-args: upload the folded blob once, device-resident.
        let mut weight_bufs = Vec::new();
        let mut weights_upload_ms = 0.0;
        if !entry.baked {
            let file = entry
                .weights_file
                .as_ref()
                .context("unbaked model without weights_file")?;
            let blob = load_weights_blob(&manifest.models_dir.join(file))?;
            let t0 = Instant::now();
            for wa in &entry.weight_args {
                let n: usize = wa.shape.iter().product();
                let data = blob
                    .get(wa.offset..wa.offset + n)
                    .with_context(|| format!("weight arg {}/{} out of blob", wa.layer, wa.key))?;
                weight_bufs.push(
                    rt.client()
                        .buffer_from_host_buffer::<f32>(data, &wa.shape, None)
                        .with_context(|| format!("uploading {}/{}", wa.layer, wa.key))?,
                );
            }
            weights_upload_ms = t0.elapsed().as_secs_f64() * 1e3;
        }

        Ok(Self {
            entry: entry.clone(),
            exes,
            weight_bufs,
            timings,
            weights_upload_ms,
        })
    }

    pub fn batch_buckets(&self) -> Vec<usize> {
        self.exes.keys().copied().collect()
    }

    /// Smallest bucket that fits `n` requests (None if n exceeds the max).
    pub fn bucket_for(&self, n: usize) -> Option<usize> {
        self.exes.keys().copied().find(|&b| b >= n)
    }

    /// Total compile time across buckets (Table 1 last-row analog).
    pub fn total_compile_ms(&self) -> f64 {
        self.timings.values().map(|t| t.total_ms()).sum::<f64>() + self.weights_upload_ms
    }

    /// Run inference on `[B, ...]` input; B must be a loaded bucket.
    pub fn execute(&self, rt: &Runtime, input: &Tensor) -> Result<Vec<Tensor>> {
        let batch = input.shape()[0];
        let exe = match self.exes.get(&batch) {
            Some(e) => e,
            None => bail!(
                "model `{}` compiled for buckets {:?}, got batch {batch}",
                self.entry.name,
                self.batch_buckets()
            ),
        };
        if input.shape()[1..] != self.entry.input_shape[..] {
            bail!(
                "input shape {:?} does not match model {:?}",
                input.shape(),
                self.entry.input_shape
            );
        }
        let in_buf = rt
            .client()
            .buffer_from_host_buffer::<f32>(input.data(), input.shape(), None)?;

        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.weight_bufs.len());
        args.push(&in_buf);
        args.extend(self.weight_bufs.iter());

        let result = exe.execute_b(&args)?;
        let lit = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True.
        let parts = lit.to_tuple()?;
        if parts.len() != self.entry.output_shapes_b1.len() {
            bail!(
                "model `{}` returned {} outputs, manifest says {}",
                self.entry.name,
                parts.len(),
                self.entry.output_shapes_b1.len()
            );
        }
        let mut outs = Vec::new();
        for (p, s1) in parts.into_iter().zip(&self.entry.output_shapes_b1) {
            let mut shape = s1.clone();
            shape[0] = batch;
            let v = p.to_vec::<f32>()?;
            if v.len() != shape.iter().product::<usize>() {
                bail!("output element count {} != shape {:?}", v.len(), shape);
            }
            outs.push(Tensor::from_vec(&shape, v));
        }
        Ok(outs)
    }
}

thread_local! {
    /// One PJRT client per thread: the wrapper types are not `Send`, and a
    /// process should not multiply clients per model (the pre-registry
    /// coordinator shared a single `Runtime` the same way).
    static THREAD_RUNTIME: std::cell::RefCell<Option<std::rc::Rc<Runtime>>> =
        const { std::cell::RefCell::new(None) };
    /// Artifact-sha compile cache shared by every engine built on this
    /// thread (re-registering an identical artifact skips parse + codegen).
    static THREAD_CACHE: std::cell::RefCell<super::cache::CompileCache> =
        std::cell::RefCell::new(super::cache::CompileCache::new());
}

fn thread_runtime() -> Result<std::rc::Rc<Runtime>> {
    THREAD_RUNTIME.with(|slot| {
        let mut slot = slot.borrow_mut();
        if let Some(rt) = slot.as_ref() {
            return Ok(rt.clone());
        }
        let rt = std::rc::Rc::new(Runtime::new()?);
        *slot = Some(rt.clone());
        Ok(rt)
    })
}

/// Whether a PJRT client can actually be created in this process — false
/// when the vendored `xla` stub is linked or the real plugin is missing.
/// Probed once with a throwaway client that is dropped immediately (NOT
/// cached in the probing thread's `THREAD_RUNTIME` — engines built later
/// on the executor thread own the one long-lived client).
/// `EngineKind::Compiled.available()` reports this, which is how every
/// caller degrades gracefully instead of erroring per use.
pub fn runtime_available() -> bool {
    static AVAILABLE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *AVAILABLE.get_or_init(|| Runtime::new().is_ok())
}

/// The `compiled` entry of the engine registry: the thread's [`Runtime`]
/// (PJRT client) paired with the [`CompiledModel`] it executes. Constructed
/// only through `engine::build_engine` — NOT `Send`, like everything PJRT;
/// the serving coordinator confines it to the executor thread.
pub struct CompiledEngine {
    rt: std::rc::Rc<Runtime>,
    model: std::rc::Rc<CompiledModel>,
}

impl CompiledEngine {
    /// Compile the model's artifacts (all manifest buckets, or the subset
    /// in `opts.buckets`) on this thread's shared PJRT client. Full loads
    /// go through the sha-keyed compile cache.
    pub fn build(manifest: &Manifest, name: &str, opts: &EngineOptions) -> Result<CompiledEngine> {
        let rt = thread_runtime()?;
        let model = match &opts.buckets {
            Some(buckets) => {
                let entry = manifest.entry(name)?.clone();
                std::rc::Rc::new(CompiledModel::load_buckets(&rt, manifest, &entry, buckets)?)
            }
            None => THREAD_CACHE.with(|c| c.borrow_mut().get_or_load(&rt, manifest, name))?,
        };
        Ok(CompiledEngine { rt, model })
    }

    pub fn model(&self) -> &CompiledModel {
        &self.model
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }
}

impl crate::engine::Engine for CompiledEngine {
    fn name(&self) -> &str {
        "compiled"
    }

    fn infer(&mut self, input: &Tensor) -> Result<Vec<Tensor>> {
        self.model.execute(&self.rt, input)
    }

    fn supports(&self, spec: &ModelSpec) -> bool {
        // Specialized code: this engine only runs the network it was
        // compiled for.
        spec.name == self.model.entry.name
    }

    fn batch_buckets(&self) -> Option<Vec<usize>> {
        Some(self.model.batch_buckets())
    }

    fn compile_ms(&self) -> f64 {
        self.model.total_compile_ms()
    }
}
