//! PJRT runtime: loads AOT HLO-text artifacts and executes them as native
//! code. `Runtime::compile_hlo` at model registration is this repo's analog
//! of the paper's AsmJit codegen at model-load time.
//!
//! The artifact manifest (`artifact`) is plain JSON and always available;
//! the PJRT-backed executor and compile cache are behind the `pjrt` cargo
//! feature so plain builds (no XLA plugin) still compile and test — the
//! engine registry reports `EngineKind::Compiled` unavailable instead.
pub mod artifact;
#[cfg(feature = "pjrt")]
pub mod cache;
#[cfg(feature = "pjrt")]
pub mod executor;
