//! PJRT runtime: loads AOT HLO-text artifacts and executes them as native
//! code. `Runtime::compile_hlo` at model registration is this repo's analog
//! of the paper's AsmJit codegen at model-load time.
pub mod artifact;
pub mod cache;
pub mod executor;
