//! Dynamic batching policy — pure decision logic, unit- and property-tested
//! separately from the threaded plumbing in `server.rs`.
//!
//! Compiled executables are shape-specialized per batch bucket (the paper's
//! generated code is fixed-shape), so the batcher packs pending requests
//! into the smallest bucket that fits and zero-pads the remainder. A batch
//! is flushed when (a) the largest bucket is full, or (b) the oldest request
//! has waited `max_wait`, or (c) the queue is closing.

use std::time::Duration;

#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Ascending batch buckets the model was compiled for, e.g. [1, 8, 32].
    pub buckets: Vec<usize>,
    /// Deadline: flush once the oldest request has waited this long.
    pub max_wait: Duration,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Flush {
    /// Execute now with this bucket size (≥ queued count; pad the rest).
    Now(usize),
    /// Wait at most this long for more requests.
    Wait(Duration),
    /// Nothing queued.
    Idle,
}

impl BatchPolicy {
    pub fn new(mut buckets: Vec<usize>, max_wait: Duration) -> Self {
        buckets.sort_unstable();
        buckets.dedup();
        assert!(!buckets.is_empty(), "need at least one bucket");
        Self { buckets, max_wait }
    }

    pub fn max_bucket(&self) -> usize {
        *self.buckets.last().unwrap()
    }

    /// Smallest bucket holding `n` requests (max bucket if n exceeds all —
    /// the caller then flushes a full batch and keeps the rest queued).
    pub fn bucket_for(&self, n: usize) -> usize {
        self.buckets
            .iter()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or_else(|| self.max_bucket())
    }

    /// Decide given the queue length and the oldest request's wait time.
    pub fn decide(&self, queued: usize, oldest_wait: Duration) -> Flush {
        if queued == 0 {
            return Flush::Idle;
        }
        if queued >= self.max_bucket() {
            return Flush::Now(self.max_bucket());
        }
        if oldest_wait >= self.max_wait {
            return Flush::Now(self.bucket_for(queued));
        }
        Flush::Wait(self.max_wait - oldest_wait)
    }

    /// Padding slots wasted when flushing `queued` requests.
    pub fn padding(&self, queued: usize) -> usize {
        self.bucket_for(queued).saturating_sub(queued)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::check;
    use crate::util::rng::SplitMix64;

    fn policy() -> BatchPolicy {
        BatchPolicy::new(vec![1, 8, 32], Duration::from_millis(2))
    }

    #[test]
    fn empty_is_idle() {
        assert_eq!(policy().decide(0, Duration::ZERO), Flush::Idle);
    }

    #[test]
    fn full_flushes_immediately() {
        assert_eq!(policy().decide(32, Duration::ZERO), Flush::Now(32));
        assert_eq!(policy().decide(40, Duration::ZERO), Flush::Now(32));
    }

    #[test]
    fn deadline_flushes_partial() {
        let p = policy();
        assert_eq!(p.decide(3, Duration::from_millis(5)), Flush::Now(8));
        assert_eq!(p.decide(1, Duration::from_millis(5)), Flush::Now(1));
        assert_eq!(p.decide(9, Duration::from_millis(5)), Flush::Now(32));
    }

    #[test]
    fn young_queue_waits_remaining_time() {
        match policy().decide(3, Duration::from_millis(1)) {
            Flush::Wait(d) => assert_eq!(d, Duration::from_millis(1)),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn bucket_selection() {
        let p = policy();
        assert_eq!(p.bucket_for(1), 1);
        assert_eq!(p.bucket_for(2), 8);
        assert_eq!(p.bucket_for(8), 8);
        assert_eq!(p.bucket_for(9), 32);
        assert_eq!(p.padding(3), 5);
        assert_eq!(p.padding(8), 0);
    }

    #[test]
    fn property_decisions_sound() {
        check(
            "batcher_sound",
            200,
            |r: &mut SplitMix64| {
                let nb = 1 + r.below(4);
                let buckets: Vec<usize> = (0..nb).map(|_| 1 + r.below(64)).collect();
                let queued = r.below(100);
                let wait_us = r.below(10_000) as u64;
                (buckets, queued, wait_us)
            },
            |(buckets, queued, wait_us)| {
                let p = BatchPolicy::new(buckets.clone(), Duration::from_millis(2));
                match p.decide(*queued, Duration::from_micros(*wait_us)) {
                    Flush::Idle => {
                        if *queued != 0 {
                            return Err("idle with nonempty queue".into());
                        }
                    }
                    Flush::Now(b) => {
                        if *queued == 0 {
                            return Err("flush with empty queue".into());
                        }
                        if !p.buckets.contains(&b) {
                            return Err(format!("bucket {b} not compiled"));
                        }
                        // must fit all queued or be the max bucket
                        if b < (*queued).min(p.max_bucket()) {
                            return Err(format!("bucket {b} < queued {queued}"));
                        }
                    }
                    Flush::Wait(d) => {
                        if d > p.max_wait {
                            return Err("wait beyond deadline".into());
                        }
                        if *queued >= p.max_bucket() {
                            return Err("waiting with a full batch".into());
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
