//! Lock-free serving metrics: counters and log₂-bucketed latency histograms
//! (no external metrics crate in the offline build).

use std::sync::atomic::{AtomicU64, Ordering};

/// Histogram over microseconds with power-of-two buckets: bucket i counts
/// samples in [2^i, 2^(i+1)) µs; 40 buckets cover > 12 days.
pub struct Histogram {
    buckets: [AtomicU64; 40],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    pub fn record_us(&self, us: u64) {
        let b = (64 - us.max(1).leading_zeros() as usize - 1).min(39);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn record(&self, d: std::time::Duration) {
        self.record_us(d.as_micros() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Zero every bucket and counter. Used by windowed histograms (the SLO
    /// shedding window): one owner resets periodically while recorders keep
    /// writing. Racing records may land on either side of the reset — fine
    /// for an advisory p99 window, which is the only use.
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_us.store(0, Ordering::Relaxed);
        self.max_us.store(0, Ordering::Relaxed);
    }

    /// Upper bound of the bucket containing quantile `q` (0..1).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max_us()
    }
}

#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// An up/down gauge (in-flight batches). Tracks a high-water mark so the
/// stress tests can assert the worker pool actually overlapped batches.
#[derive(Default)]
pub struct Gauge {
    value: AtomicU64,
    peak: AtomicU64,
}

impl Gauge {
    pub fn inc(&self) {
        let now = self.value.fetch_add(1, Ordering::Relaxed) + 1;
        self.peak.fetch_max(now, Ordering::Relaxed);
    }
    pub fn dec(&self) {
        self.value.fetch_sub(1, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
    /// Highest simultaneous value ever observed.
    pub fn peak(&self) -> u64 {
        self.peak.load(Ordering::Relaxed)
    }
}

/// Per-model serving metrics.
#[derive(Default)]
pub struct ModelMetrics {
    /// end-to-end request latency (enqueue → reply)
    pub latency: Histogram,
    /// dispatch → reply time per batch (includes lane-queue wait)
    pub exec: Histogram,
    /// time requests wait in the batcher queue
    pub queue_wait: Histogram,
    /// end-to-end latency over the **current SLO window only** — the TCP
    /// front end resets it periodically and compares its p99 against the
    /// configured SLO to decide shedding (`latency` above is cumulative)
    pub latency_window: Histogram,
    pub requests: Counter,
    pub batches: Counter,
    pub padded_slots: Counter,
    pub errors: Counter,
    /// Requests refused by admission control with an `overloaded` response
    /// (queue full / in-flight cap / SLO breach). Never executed, so they
    /// appear here and **not** in `requests`.
    pub shed: Counter,
    /// Batches currently dispatched to the execution lane; the peak shows
    /// how many the worker pool actually overlapped.
    pub inflight: Gauge,
    /// Lowerings this model skipped via a compiled-artifact cache hit
    /// (register + hot-swap paths).
    pub cache_hits: Counter,
    /// Lowerings that ran because no cached artifact existed for the key.
    pub cache_misses: Counter,
    /// Cached artifacts rejected (version/feature/hash mismatch or a
    /// corrupt file) and silently replaced by a re-lowering.
    pub cache_invalidated: Counter,
}

impl ModelMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Mean requests per executed batch.
    pub fn mean_batch_fill(&self) -> f64 {
        let b = self.batches.get();
        if b == 0 {
            0.0
        } else {
            self.requests.get() as f64 / b as f64
        }
    }

    pub fn render(&self, name: &str, workers: usize) -> String {
        format!(
            "{name} [{workers} worker{}]: {} reqs in {} batches (fill {:.2}, padded {}, \
             peak inflight {}), latency mean {:.0}µs p50 {}µs p95 {}µs max {}µs, \
             exec mean {:.0}µs, queue mean {:.0}µs, errors {}, shed {}, \
             cache {}h/{}m/{}i",
            if workers == 1 { "" } else { "s" },
            self.requests.get(),
            self.batches.get(),
            self.mean_batch_fill(),
            self.padded_slots.get(),
            self.inflight.peak(),
            self.latency.mean_us(),
            self.latency.quantile_us(0.5),
            self.latency.quantile_us(0.95),
            self.latency.max_us(),
            self.exec.mean_us(),
            self.queue_wait.mean_us(),
            self.errors.get(),
            self.shed.get(),
            self.cache_hits.get(),
            self.cache_misses.get(),
            self.cache_invalidated.get(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let h = Histogram::new();
        for us in [10u64, 20, 30, 100, 1000, 5000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 6);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.95));
        assert!(h.max_us() == 5000);
        assert!((h.mean_us() - 1026.66).abs() < 1.0);
    }

    #[test]
    fn histogram_bucket_bounds() {
        let h = Histogram::new();
        h.record_us(1);
        assert!(h.quantile_us(1.0) >= 1);
        let h2 = Histogram::new();
        h2.record_us(1u64 << 45); // clamps to last bucket
        assert_eq!(h2.count(), 1);
    }

    #[test]
    fn gauge_tracks_peak() {
        let g = Gauge::default();
        g.inc();
        g.inc();
        g.dec();
        g.inc();
        assert_eq!(g.get(), 2);
        assert_eq!(g.peak(), 2);
        g.dec();
        g.dec();
        assert_eq!(g.get(), 0);
        assert_eq!(g.peak(), 2);
    }

    #[test]
    fn histogram_reset_zeroes_everything() {
        let h = Histogram::new();
        for us in [5u64, 50, 500] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 3);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.max_us(), 0);
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
        // and it keeps recording after the reset
        h.record_us(7);
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn batch_fill() {
        let m = ModelMetrics::new();
        m.requests.add(10);
        m.batches.add(4);
        assert!((m.mean_batch_fill() - 2.5).abs() < 1e-9);
    }
}
