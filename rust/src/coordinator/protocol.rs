//! Line-delimited JSON wire protocol for the TCP serving front end.
//!
//! Request (one line):
//!   {"id": 7, "model": "c_bh", "input": [0.1, -0.2, …]}    // flattened HWC
//! Response (one line):
//!   {"id": 7, "ok": true, "shape": [1, 1], "output": [0.42]}
//!   {"id": 7, "ok": false, "error": "model `x` not in manifest"}
//!
//! JSON is hand-parsed/serialized via `util::json` (same parser the model
//! specs use). Floats round-trip through f64, lossless for f32 payloads.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::nn::tensor::Tensor;
use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    pub model: String,
    pub input: Vec<f32>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Ok { id: u64, shape: Vec<usize>, output: Vec<f32> },
    Err { id: u64, error: String },
}

impl Request {
    pub fn parse(line: &str) -> Result<Request> {
        let j = Json::parse(line).context("request is not valid JSON")?;
        let input = j
            .req_arr("input")?
            .iter()
            .map(|v| v.as_f64().map(|f| f as f32).context("input must be numbers"))
            .collect::<Result<Vec<_>>>()?;
        Ok(Request {
            id: j.req_usize("id")? as u64,
            model: j.req_str("model")?.to_string(),
            input,
        })
    }

    pub fn to_line(&self) -> String {
        let mut obj = BTreeMap::new();
        obj.insert("id".into(), Json::Num(self.id as f64));
        obj.insert("model".into(), Json::Str(self.model.clone()));
        obj.insert(
            "input".into(),
            Json::Arr(self.input.iter().map(|&v| Json::Num(v as f64)).collect()),
        );
        Json::Obj(obj).to_string()
    }
}

impl Response {
    pub fn ok(id: u64, out: &Tensor) -> Response {
        Response::Ok {
            id,
            shape: out.shape().to_vec(),
            output: out.data().to_vec(),
        }
    }

    pub fn parse(line: &str) -> Result<Response> {
        let j = Json::parse(line).context("response is not valid JSON")?;
        let id = j.req_usize("id")? as u64;
        if j.req("ok")?.as_bool().context("ok must be bool")? {
            Ok(Response::Ok {
                id,
                shape: j.req("shape")?.as_usize_vec().context("shape")?,
                output: j
                    .req_arr("output")?
                    .iter()
                    .map(|v| v.as_f64().map(|f| f as f32).context("output numbers"))
                    .collect::<Result<Vec<_>>>()?,
            })
        } else {
            Ok(Response::Err { id, error: j.req_str("error")?.to_string() })
        }
    }

    pub fn to_line(&self) -> String {
        let mut obj = BTreeMap::new();
        match self {
            Response::Ok { id, shape, output } => {
                obj.insert("id".into(), Json::Num(*id as f64));
                obj.insert("ok".into(), Json::Bool(true));
                obj.insert(
                    "shape".into(),
                    Json::Arr(shape.iter().map(|&d| Json::Num(d as f64)).collect()),
                );
                obj.insert(
                    "output".into(),
                    Json::Arr(output.iter().map(|&v| Json::Num(v as f64)).collect()),
                );
            }
            Response::Err { id, error } => {
                obj.insert("id".into(), Json::Num(*id as f64));
                obj.insert("ok".into(), Json::Bool(false));
                obj.insert("error".into(), Json::Str(error.clone()));
            }
        }
        Json::Obj(obj).to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let r = Request { id: 7, model: "c_bh".into(), input: vec![0.5, -1.25, 3.0] };
        let back = Request::parse(&r.to_line()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn response_roundtrip_ok() {
        let t = Tensor::from_vec(&[1, 2], vec![0.25, 0.75]);
        let r = Response::ok(9, &t);
        let back = Response::parse(&r.to_line()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn response_roundtrip_err() {
        let r = Response::Err { id: 3, error: "no such model".into() };
        assert_eq!(Response::parse(&r.to_line()).unwrap(), r);
    }

    #[test]
    fn malformed_requests_rejected() {
        assert!(Request::parse("not json").is_err());
        assert!(Request::parse("{\"id\": 1}").is_err());
        assert!(Request::parse("{\"id\": 1, \"model\": \"m\", \"input\": [\"x\"]}").is_err());
    }
}
