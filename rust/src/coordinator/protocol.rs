//! Line-delimited JSON wire protocol for the TCP serving front end.
//!
//! Request (one line):
//!   {"id": 7, "model": "c_bh", "input": [0.1, -0.2, …]}    // flattened HWC
//! Response (one line):
//!   {"id": 7, "ok": true, "shape": [1, 1], "output": [0.42]}
//!   {"id": 7, "ok": false, "error": "model `x` not in manifest"}
//!   {"id": 7, "ok": false, "error": "…", "code": "overloaded"}
//!
//! JSON is hand-parsed/serialized via `util::json` (same parser the model
//! specs use). Floats round-trip through f64, lossless for f32 payloads.
//! Ids are u64 and round-trip **losslessly** over the full range: they
//! serialize as bare integers (`Json::UInt`, never through f64, which
//! corrupts values ≥ 2^53) and non-integral incoming ids are rejected.
//!
//! Connections are pipelined: a client may write any number of request
//! lines before reading; responses stream back in **completion order**
//! (batches finish out of order), correlated by `id`. Ids are
//! client-chosen; the server never interprets them beyond echoing.
//!
//! Error responses carry an optional machine-readable `code`:
//!
//! * `"overloaded"` — admission control shed the request (queue full,
//!   in-flight cap, or latency SLO breach). Retry later, ideally with
//!   backoff; the request was **not** executed.
//!
//! `id: 0` in an error response means **unattributable**: the request line
//! was too malformed to recover an id from (not even `salvage_id` could).
//! Pipelining clients should avoid 0 as a request id so unattributable
//! errors are distinguishable from real replies.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::nn::tensor::Tensor;
use crate::util::json::Json;

/// The machine-readable `code` on shed responses.
pub const CODE_OVERLOADED: &str = "overloaded";

#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    pub model: String,
    pub input: Vec<f32>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Ok {
        id: u64,
        shape: Vec<usize>,
        output: Vec<f32>,
    },
    Err {
        id: u64,
        error: String,
        /// Machine-readable error class (`"overloaded"`); `None` for
        /// plain failures (unknown model, bad input, execution error).
        code: Option<String>,
    },
}

impl Request {
    pub fn parse(line: &str) -> Result<Request> {
        let j = Json::parse(line).context("request is not valid JSON")?;
        let input = j
            .req_arr("input")?
            .iter()
            .map(|v| v.as_f64().map(|f| f as f32).context("input must be numbers"))
            .collect::<Result<Vec<_>>>()?;
        Ok(Request {
            id: j.req_u64("id")?,
            model: j.req_str("model")?.to_string(),
            input,
        })
    }

    pub fn to_line(&self) -> String {
        let mut obj = BTreeMap::new();
        obj.insert("id".into(), Json::UInt(self.id));
        obj.insert("model".into(), Json::Str(self.model.clone()));
        obj.insert(
            "input".into(),
            Json::Arr(self.input.iter().map(|&v| Json::Num(v as f64)).collect()),
        );
        Json::Obj(obj).to_string()
    }
}

/// Best-effort id recovery from a request line that failed `Request::parse`,
/// so a pipelined client can still correlate the error. Works whenever the
/// line is valid JSON with a well-formed integer `id` (the common failure
/// modes: missing `model`, non-numeric `input`, …). Returns 0 — the
/// documented "unattributable" id — when nothing can be recovered.
pub fn salvage_id(line: &str) -> u64 {
    if let Ok(j) = Json::parse(line) {
        if let Some(id) = j.get("id").and_then(Json::as_u64) {
            return id;
        }
    }
    0
}

impl Response {
    pub fn ok(id: u64, out: &Tensor) -> Response {
        Response::Ok {
            id,
            shape: out.shape().to_vec(),
            output: out.data().to_vec(),
        }
    }

    /// A plain (uncoded) error response.
    pub fn err(id: u64, error: impl Into<String>) -> Response {
        Response::Err { id, error: error.into(), code: None }
    }

    /// A structured load-shed response (`code: "overloaded"`).
    pub fn overloaded(id: u64, error: impl Into<String>) -> Response {
        Response::Err { id, error: error.into(), code: Some(CODE_OVERLOADED.into()) }
    }

    /// The echoed request id (0 = unattributable error).
    pub fn id(&self) -> u64 {
        match self {
            Response::Ok { id, .. } | Response::Err { id, .. } => *id,
        }
    }

    /// True when this is a shed response (`code: "overloaded"`).
    pub fn is_overloaded(&self) -> bool {
        matches!(self, Response::Err { code: Some(c), .. } if c == CODE_OVERLOADED)
    }

    pub fn parse(line: &str) -> Result<Response> {
        let j = Json::parse(line).context("response is not valid JSON")?;
        let id = j.req_u64("id")?;
        if j.req("ok")?.as_bool().context("ok must be bool")? {
            Ok(Response::Ok {
                id,
                shape: j.req("shape")?.as_usize_vec().context("shape")?,
                output: j
                    .req_arr("output")?
                    .iter()
                    .map(|v| v.as_f64().map(|f| f as f32).context("output numbers"))
                    .collect::<Result<Vec<_>>>()?,
            })
        } else {
            Ok(Response::Err {
                id,
                error: j.req_str("error")?.to_string(),
                code: j.get("code").and_then(Json::as_str).map(str::to_string),
            })
        }
    }

    pub fn to_line(&self) -> String {
        let mut obj = BTreeMap::new();
        match self {
            Response::Ok { id, shape, output } => {
                obj.insert("id".into(), Json::UInt(*id));
                obj.insert("ok".into(), Json::Bool(true));
                obj.insert(
                    "shape".into(),
                    Json::Arr(shape.iter().map(|&d| Json::Num(d as f64)).collect()),
                );
                obj.insert(
                    "output".into(),
                    Json::Arr(output.iter().map(|&v| Json::Num(v as f64)).collect()),
                );
            }
            Response::Err { id, error, code } => {
                obj.insert("id".into(), Json::UInt(*id));
                obj.insert("ok".into(), Json::Bool(false));
                obj.insert("error".into(), Json::Str(error.clone()));
                if let Some(code) = code {
                    obj.insert("code".into(), Json::Str(code.clone()));
                }
            }
        }
        Json::Obj(obj).to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let r = Request { id: 7, model: "c_bh".into(), input: vec![0.5, -1.25, 3.0] };
        let back = Request::parse(&r.to_line()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn response_roundtrip_ok() {
        let t = Tensor::from_vec(&[1, 2], vec![0.25, 0.75]);
        let r = Response::ok(9, &t);
        let back = Response::parse(&r.to_line()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn response_roundtrip_err() {
        let r = Response::err(3, "no such model");
        assert_eq!(Response::parse(&r.to_line()).unwrap(), r);
        assert!(!r.is_overloaded());
    }

    #[test]
    fn overloaded_code_roundtrips() {
        let r = Response::overloaded(11, "queue full for `m`");
        let line = r.to_line();
        assert!(line.contains("\"code\":\"overloaded\""), "{line}");
        let back = Response::parse(&line).unwrap();
        assert!(back.is_overloaded());
        assert_eq!(back, r);
        // uncoded errors don't serialize a code key at all
        assert!(!Response::err(1, "x").to_line().contains("code"));
    }

    #[test]
    fn ids_roundtrip_losslessly_past_2_53() {
        // the old path (id as f64) collapses 2^53 and 2^53 + 1 into the
        // same wire value — these must stay distinct
        for id in [(1u64 << 53) - 1, 1u64 << 53, (1u64 << 53) + 1, u64::MAX] {
            let req = Request { id, model: "m".into(), input: vec![0.0] };
            assert_eq!(Request::parse(&req.to_line()).unwrap().id, id);
            let resp = Response::err(id, "e");
            assert_eq!(Response::parse(&resp.to_line()).unwrap().id(), id);
            assert!(req.to_line().contains(&format!("\"id\":{id}")), "bare integer id");
        }
    }

    #[test]
    fn non_integral_ids_rejected() {
        let e = Request::parse(r#"{"id": 1.5, "model": "m", "input": [0.0]}"#);
        assert!(e.is_err(), "fractional ids must be rejected");
        let e = Request::parse(r#"{"id": -1, "model": "m", "input": [0.0]}"#);
        assert!(e.is_err(), "negative ids must be rejected");
        // integral float spelling is fine — it IS an integer
        let r = Request::parse(r#"{"id": 7.0, "model": "m", "input": [0.0]}"#).unwrap();
        assert_eq!(r.id, 7);
    }

    #[test]
    fn malformed_requests_rejected() {
        assert!(Request::parse("not json").is_err());
        assert!(Request::parse("{\"id\": 1}").is_err());
        assert!(Request::parse("{\"id\": 1, \"model\": \"m\", \"input\": [\"x\"]}").is_err());
    }

    #[test]
    fn salvage_recovers_ids_from_malformed_lines() {
        // parseable JSON, unparseable request: id recovered
        assert_eq!(salvage_id(r#"{"id": 42}"#), 42);
        assert_eq!(salvage_id(r#"{"id": 9007199254740993, "input": 3}"#), (1 << 53) + 1);
        // hopeless lines: the documented unattributable id
        assert_eq!(salvage_id("not json at all"), 0);
        assert_eq!(salvage_id(r#"{"id": "seven"}"#), 0);
        assert_eq!(salvage_id(r#"{"id": 1.5}"#), 0);
    }
}
