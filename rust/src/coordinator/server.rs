//! The serving coordinator (L3): a model registry, per-model batcher
//! threads implementing the `BatchPolicy`, and two execution lanes:
//!
//! * **Worker pools** — engines that expose a shared-inference artifact
//!   ([`Engine::shareable`], e.g. the optimized interpreter's immutable
//!   `Arc<Program>`) get `workers` threads per model. The program is
//!   lowered **once**; each worker owns only its scratch (arena pool), so
//!   adding a core costs one arena, not one engine.
//! * **The pinned executor thread** — engines whose state is not `Send`
//!   (the PJRT wrapper types) or that don't opt into sharing (the naive
//!   interpreter) are built *and* executed on one dedicated thread,
//!   exactly the pre-pool behavior.
//!
//! Request path (Python nowhere in sight):
//!   client → `ModelClient::infer` → batcher thread (dynamic batching, §4's
//!   many-candidates-per-frame workload) → worker pool / executor thread →
//!   per-request replies, sent from the execution site so a batcher can
//!   keep `workers + 1` batches in flight instead of round-tripping one.

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{
    self, Receiver, RecvTimeoutError, Sender, SyncSender, TryRecvError, TrySendError,
};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::compiler::artifact::{load_program, CacheCounters, ProgramCache};
use crate::compiler::program::Program;
use crate::coordinator::batcher::{BatchPolicy, Flush};
use crate::coordinator::metrics::ModelMetrics;
use crate::engine::{
    build_engine, build_engine_from_spec, Engine, EngineKind, EngineOptions, SharedInfer,
    SwapCell,
};
use crate::model::spec::ModelSpec;
use crate::nn::tensor::Tensor;
use crate::runtime::artifact::Manifest;

/// How long an idle batcher sleeps between shutdown-flag checks. Clients
/// may hold their queue sender past `shutdown()`, so the batcher can never
/// rely on channel disconnection alone to exit.
const IDLE_TICK: Duration = Duration::from_millis(25);

/// How long a batcher at its in-flight cap waits for a ticket to return
/// before presuming the ticket died with a crashed lane (worker panic) and
/// minting a replacement. Orders of magnitude above any sane batch time,
/// so a merely slow lane never breaks the cap.
const TICKET_PATIENCE: Duration = Duration::from_secs(5);

/// Completion callback for one request: invoked exactly once with the
/// inference result, from whichever thread executed the batch (a pool
/// worker or the pinned executor). `FnOnce` so the reply can move its
/// payload (a socket token, a channel sender) without cloning; `Send` so
/// execution lanes can carry it. The event-loop front end passes callbacks
/// that serialize the response and wake the I/O thread; `infer_async`
/// passes one that forwards into a channel.
pub type ReplyFn = Box<dyn FnOnce(Result<Tensor>) + Send>;

/// A single inference request: one item (no batch dim); the batcher stacks.
struct Request {
    input: Tensor,
    enqueued: Instant,
    reply: ReplyFn,
}

/// A stacked batch in flight from a batcher to an execution lane. The lane
/// that runs it also fans the replies out and returns the stacking buffer,
/// so the batcher never blocks on a round-trip.
struct Job {
    bucket: usize,
    /// `[bucket, item…]`, zero-padded past `requests.len()`.
    batch: Tensor,
    requests: Vec<Request>,
    t_exec: Instant,
    metrics: Arc<ModelMetrics>,
    /// Returns the consumed stacking buffer to the batcher (its ticket to
    /// stack another batch — the in-flight cap and the recycling pool).
    done: Sender<Vec<f32>>,
}

/// Work sent to the pinned executor thread. `replace: true` on the
/// register messages is the hot-swap path: rebuild the engine even when
/// one is cached, replacing it. The executor channel is FIFO, so for a
/// pinned lane every batch dispatched before the swap still executes on
/// the old engine — in-flight work drains, nothing is lost.
enum ExecMsg {
    Register {
        name: String,
        replace: bool,
        reply: SyncSender<Result<Registration>>,
    },
    RegisterSpec {
        spec: Box<ModelSpec>,
        buckets: Vec<usize>,
        replace: bool,
        /// Per-registration override of the coordinator's configured
        /// weight dtype (`None` = inherit). This is how a live lane flips
        /// f32 → i8: `hot_swap_spec_dtype` re-lowers under the override
        /// and publishes through the lane's `SwapCell`.
        weight_dtype: Option<crate::nn::simd::WeightDtype>,
        reply: SyncSender<Result<Registration>>,
    },
    /// Publish an **already-lowered** program (loaded from a compiled
    /// artifact file) as this name's engine — the hot-swap-from-artifact
    /// path. The program was validated and mmap-loaded on the caller's
    /// thread; the executor only wraps it in an `OptInterp` so both lane
    /// kinds go through the one registry code path.
    RegisterProgram {
        name: String,
        program: Box<Program>,
        buckets: Vec<usize>,
        replace: bool,
        reply: SyncSender<Result<Registration>>,
    },
    InferBatch {
        name: String,
        job: Job,
    },
    Shutdown,
}

/// What engine registration produced: the client-visible info plus the
/// shared artifact when the engine opts into pool serving.
struct Registration {
    info: RegisterInfo,
    shared: Option<Arc<dyn SharedInfer>>,
    /// How the global [`ProgramCache`] counters moved while this engine
    /// was built (the executor thread builds serially, so the delta is
    /// exactly this registration's cache activity). Lands in the lane's
    /// `ModelMetrics`.
    cache_delta: CacheCounters,
}

/// What a client learns from registering a model: the serving contract
/// (buckets, item shape) plus compile provenance.
#[derive(Debug, Clone)]
pub struct RegisterInfo {
    /// Registered model name.
    pub name: String,
    /// Batch sizes the batcher packs to (ascending).
    pub buckets: Vec<usize>,
    /// Per-item input shape (no batch dim) `infer` expects.
    pub input_shape: Vec<usize>,
    /// Engine build/lowering time for this registration, milliseconds.
    pub compile_ms: f64,
    /// True when the engine was already built (re-registration).
    pub cache_hit: bool,
    /// Model parameter count.
    pub params: usize,
    /// Registry name of the engine serving this model.
    pub engine: String,
    /// Threads executing this model: the pool size for shared engines, 1
    /// for engines pinned to the executor thread.
    pub workers: usize,
    /// Artifact generation serving this name: 1 on first registration,
    /// bumped by every hot-swap (`Coordinator::hot_swap_spec`).
    pub generation: u64,
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Longest a request may wait for its batch to fill before the batcher
    /// flushes a partial bucket (the dynamic-batching latency bound).
    pub max_wait: Duration,
    /// Bounded queue per model (backpressure: senders block).
    pub queue_depth: usize,
    /// Engine the executor thread builds for every registered model.
    /// Defaults to the best kind this build supports (compiled with the
    /// `pjrt` feature, optimized interpreter otherwise).
    pub engine: EngineKind,
    /// Worker threads per model for engines with a shared-inference
    /// artifact. Engines without one (naive, PJRT) always get the single
    /// pinned executor thread regardless of this setting.
    pub workers: usize,
    /// Intra-op task budget compiled into each lowered program
    /// (`CompileOptions::intra_threads`). Default 1: the pool spends cores
    /// across concurrent batches; raising this instead splits each large
    /// conv/GEMM into that many bands within a single inference, which is
    /// the better trade for single-stream big-net serving.
    pub intra_threads: usize,
    /// Weight storage dtype compiled into every lowered program
    /// (`CompileOptions::weight_dtype`). Default f32; `bf16`/`i8` trade a
    /// bounded accuracy delta for weight bandwidth. A live model can flip
    /// dtype without dropping requests via
    /// [`Coordinator::hot_swap_spec_dtype`] — the lane's `SwapCell`
    /// publishes the re-lowered artifact atomically.
    pub weight_dtype: crate::nn::simd::WeightDtype,
}

/// Default per-model pool size: `min(4, cores)`.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(4)
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            max_wait: Duration::from_millis(2),
            queue_depth: 1024,
            engine: EngineKind::preferred(),
            workers: default_workers(),
            intra_threads: 1,
            weight_dtype: crate::nn::simd::WeightDtype::F32,
        }
    }
}

/// A registered model's published serving state: the bounded request
/// queue, metrics handle, client-visible info, and — for pool lanes — the
/// epoch-versioned artifact cell that hot-swap replaces.
struct Lane {
    tx: SyncSender<Request>,
    metrics: Arc<ModelMetrics>,
    info: RegisterInfo,
    /// `Some` for pool lanes (workers re-load it per job, so `hot_swap_spec`
    /// can replace the artifact under live traffic); `None` for pinned
    /// lanes, where the executor thread owns the engine and swaps it via a
    /// `replace: true` register message instead.
    cell: Option<Arc<SwapCell>>,
}

/// The serving coordinator: model registry, batcher threads, and the two
/// execution lanes (per-model worker pools over a shared lowered artifact,
/// and the pinned executor thread for non-`Send` engines). See the module
/// docs for the request path.
pub struct Coordinator {
    exec_tx: Sender<ExecMsg>,
    exec_thread: Mutex<Option<JoinHandle<()>>>,
    /// One batcher handle per registered model, joined at drop so replies
    /// in flight at teardown are delivered, not raced.
    batchers: Mutex<Vec<JoinHandle<()>>>,
    /// Pool worker handles across all models, joined after the batchers
    /// (workers exit once their model's batcher drops the job sender).
    workers: Mutex<Vec<JoinHandle<()>>>,
    /// Serializes the whole register sequence (lookup → engine build →
    /// insert), so two threads registering one name can never spawn two
    /// batchers or leak a queue. The `queues` lock alone can't: engine
    /// construction must happen outside it, re-opening the race.
    reg_lock: Mutex<()>,
    queues: Mutex<HashMap<String, Lane>>,
    /// Model names the manifest can register. Unknown names are rejected
    /// here, O(1) under `reg_lock`, without a round-trip through the
    /// executor thread — a client spamming bad names must not queue work
    /// behind pinned-engine inference.
    manifest_models: std::collections::HashSet<String>,
    /// Bumped on every successful registration. Lets callers (the TCP
    /// front end) cache *failed* model resolutions and retry only once the
    /// registry has actually changed, instead of paying the registry lock
    /// + executor round-trip per request for a misspelled name.
    epoch: AtomicU64,
    cfg: CoordinatorConfig,
    stopping: Arc<AtomicBool>,
}

impl Coordinator {
    /// Start the executor thread over the given artifact manifest. Engine
    /// construction happens lazily at `register`, which is where failures
    /// (unavailable engine, bad artifact) surface.
    pub fn start(manifest: Manifest, cfg: CoordinatorConfig) -> Result<Arc<Self>> {
        let (exec_tx, exec_rx) = mpsc::channel::<ExecMsg>();
        let engine_kind = cfg.engine;
        let intra_threads = cfg.intra_threads.max(1);
        let weight_dtype = cfg.weight_dtype;
        let manifest_models = manifest.models.keys().cloned().collect();
        let exec_thread = std::thread::Builder::new()
            .name("engine-executor".into())
            .spawn(move || {
                executor_main(manifest, engine_kind, intra_threads, weight_dtype, exec_rx)
            })
            .context("spawning executor thread")?;
        Ok(Arc::new(Self {
            exec_tx,
            exec_thread: Mutex::new(Some(exec_thread)),
            batchers: Mutex::new(Vec::new()),
            workers: Mutex::new(Vec::new()),
            reg_lock: Mutex::new(()),
            queues: Mutex::new(HashMap::new()),
            manifest_models,
            epoch: AtomicU64::new(0),
            cfg,
            stopping: Arc::new(AtomicBool::new(false)),
        }))
    }

    /// Load + compile a model from the manifest (the runtime-JIT step) and
    /// start its serving lane. Idempotent: re-registering returns the
    /// existing client, even under concurrent callers.
    pub fn register(self: &Arc<Self>, name: &str) -> Result<ModelClient> {
        let _reg = self.reg_lock.lock().unwrap();
        self.register_locked(name)
    }

    /// Body of [`register`](Self::register); caller holds `reg_lock`.
    fn register_locked(&self, name: &str) -> Result<ModelClient> {
        if self.stopping.load(Ordering::SeqCst) {
            bail!("coordinator is shut down");
        }
        if let Some(client) = self.lookup(name) {
            return Ok(client);
        }
        // O(1) rejection of unknown names; only manifest models may queue
        // an engine build on the executor thread
        if !self.manifest_models.contains(name) {
            bail!(
                "model `{name}` not in manifest (have: {:?})",
                self.manifest_models.iter().collect::<Vec<_>>()
            );
        }
        let reg = self.exec_round_trip(|reply| ExecMsg::Register {
            name: name.into(),
            replace: false,
            reply,
        })?;
        self.finish_register(reg)
    }

    /// Register a model from an in-memory spec (no artifact manifest
    /// needed): the executor builds the configured interpreter engine over
    /// it and the serving lane comes up exactly as for manifest models.
    /// `buckets` are the batch sizes the batcher packs to.
    pub fn register_spec(
        self: &Arc<Self>,
        spec: &ModelSpec,
        buckets: &[usize],
    ) -> Result<ModelClient> {
        if buckets.is_empty() {
            bail!("register_spec needs at least one batch bucket");
        }
        let _reg = self.reg_lock.lock().unwrap();
        self.register_spec_locked(spec, buckets, None)
    }

    /// Body of [`register_spec`](Self::register_spec); caller holds
    /// `reg_lock`. `weight_dtype` overrides the coordinator's configured
    /// dtype for this registration when `Some`.
    fn register_spec_locked(
        &self,
        spec: &ModelSpec,
        buckets: &[usize],
        weight_dtype: Option<crate::nn::simd::WeightDtype>,
    ) -> Result<ModelClient> {
        if self.stopping.load(Ordering::SeqCst) {
            bail!("coordinator is shut down");
        }
        if let Some(client) = self.lookup(&spec.name) {
            return Ok(client);
        }
        let spec = Box::new(spec.clone());
        let buckets = buckets.to_vec();
        let reg = self.exec_round_trip(move |reply| ExecMsg::RegisterSpec {
            spec,
            buckets,
            replace: false,
            weight_dtype,
            reply,
        })?;
        self.finish_register(reg)
    }

    /// Hot-swap: re-register a **live** model name with a new artifact
    /// built from `spec`, without dropping a single request. The serving
    /// lane (queue, batcher, workers, metrics) stays up; only the lowered
    /// artifact is replaced:
    ///
    /// * **Pool lanes** bump the lane's [`SwapCell`] epoch. Workers load
    ///   the cell per job, so every batch dispatched before the swap runs
    ///   to completion on the old artifact (it drains; the old `Arc` frees
    ///   once the last in-flight batch finishes), and every later batch
    ///   executes the new one.
    /// * **Pinned lanes** rebuild in place on the executor thread; its
    ///   FIFO channel orders the rebuild after all previously dispatched
    ///   batches.
    ///
    /// The new spec must keep the input shape (queued requests are already
    /// shaped); a changed shape is an error and the old artifact keeps
    /// serving. If the name is not live yet this is a plain registration.
    /// On success `RegisterInfo::generation` is bumped and the refreshed
    /// client is returned.
    pub fn hot_swap_spec(
        self: &Arc<Self>,
        spec: &ModelSpec,
        buckets: &[usize],
    ) -> Result<ModelClient> {
        self.hot_swap_spec_as(spec, buckets, None)
    }

    /// [`hot_swap_spec`](Self::hot_swap_spec) with an explicit weight-dtype
    /// override: re-lower the **same** spec under a different storage dtype
    /// and publish it through the lane's [`SwapCell`] — the live
    /// f32 → i8 requantization path (and its inverse). Everything the plain
    /// hot-swap guarantees holds: zero dropped requests, in-flight batches
    /// drain on the old artifact, the generation bumps.
    pub fn hot_swap_spec_dtype(
        self: &Arc<Self>,
        spec: &ModelSpec,
        buckets: &[usize],
        weight_dtype: crate::nn::simd::WeightDtype,
    ) -> Result<ModelClient> {
        self.hot_swap_spec_as(spec, buckets, Some(weight_dtype))
    }

    fn hot_swap_spec_as(
        self: &Arc<Self>,
        spec: &ModelSpec,
        buckets: &[usize],
        weight_dtype: Option<crate::nn::simd::WeightDtype>,
    ) -> Result<ModelClient> {
        let _reg = self.reg_lock.lock().unwrap();
        if self.stopping.load(Ordering::SeqCst) {
            bail!("coordinator is shut down");
        }
        let live = {
            let queues = self.queues.lock().unwrap();
            queues.get(&spec.name).map(|lane| (lane.info.clone(), lane.cell.clone()))
        };
        let Some((info, cell)) = live else {
            if buckets.is_empty() {
                bail!("register_spec needs at least one batch bucket");
            }
            return self.register_spec_locked(spec, buckets, weight_dtype);
        };
        if spec.input_shape != info.input_shape {
            bail!(
                "hot-swap for `{}` would change the input shape {:?} -> {:?}; queued \
                 requests are already shaped, register the new artifact under a new \
                 name instead",
                spec.name,
                info.input_shape,
                spec.input_shape
            );
        }
        // Rebuild on the executor thread (same code path as registration,
        // `replace` forces a fresh build past the engine cache). Keep the
        // lane's existing buckets: the batcher's packing policy is fixed.
        let boxed = Box::new(spec.clone());
        let lane_buckets = info.buckets.clone();
        let reg = self.exec_round_trip(move |reply| ExecMsg::RegisterSpec {
            spec: boxed,
            buckets: lane_buckets,
            replace: true,
            weight_dtype,
            reply,
        })?;
        match (&cell, reg.shared) {
            // Pool lane: publish the new artifact; workers pick it up on
            // their next job and rebuild scratch for the new epoch.
            (Some(cell), Some(shared)) => {
                cell.swap(shared);
            }
            // Pinned lane: the executor already replaced its engine.
            (None, None) => {}
            (Some(_), None) => bail!(
                "hot-swap for `{}` produced a non-shareable engine for a pooled lane",
                spec.name
            ),
            (None, Some(_)) => bail!(
                "hot-swap for `{}` produced a shareable engine for a pinned lane",
                spec.name
            ),
        }
        let client = {
            let mut queues = self.queues.lock().unwrap();
            let lane = queues
                .get_mut(&spec.name)
                .ok_or_else(|| anyhow!("lane for `{}` vanished during hot-swap", spec.name))?;
            lane.info.generation += 1;
            lane.info.compile_ms = reg.info.compile_ms;
            lane.info.params = reg.info.params;
            record_cache_delta(&lane.metrics, reg.cache_delta);
            ModelClient {
                tx: lane.tx.clone(),
                metrics: lane.metrics.clone(),
                info: lane.info.clone(),
            }
        };
        self.epoch.fetch_add(1, Ordering::SeqCst);
        Ok(client)
    }

    /// Hot-swap a **live** model to a pre-compiled artifact file: the
    /// artifact is validated and mmap-loaded on this thread (a corrupt or
    /// mismatched file fails here and the old artifact keeps serving), then
    /// published exactly like [`hot_swap_spec`](Self::hot_swap_spec) — the
    /// input shape is pinned against the lane's registration and
    /// `RegisterInfo::generation` bumps. A name that is not live yet is a
    /// plain registration from the artifact (`buckets` must be non-empty).
    pub fn hot_swap_artifact(
        self: &Arc<Self>,
        name: &str,
        path: &Path,
        buckets: &[usize],
    ) -> Result<ModelClient> {
        let _reg = self.reg_lock.lock().unwrap();
        if self.stopping.load(Ordering::SeqCst) {
            bail!("coordinator is shut down");
        }
        let (program, _info) = load_program(path)
            .map_err(|e| anyhow!("loading artifact {}: {e}", path.display()))?;
        let live = {
            let queues = self.queues.lock().unwrap();
            queues.get(name).map(|lane| (lane.info.clone(), lane.cell.clone()))
        };
        let Some((info, cell)) = live else {
            if buckets.is_empty() {
                bail!("registering from an artifact needs at least one batch bucket");
            }
            let boxed = Box::new(program);
            let owned_name = name.to_string();
            let buckets = buckets.to_vec();
            let reg = self.exec_round_trip(move |reply| ExecMsg::RegisterProgram {
                name: owned_name,
                program: boxed,
                buckets,
                replace: false,
                reply,
            })?;
            return self.finish_register(reg);
        };
        // Shape pin BEFORE any executor traffic: queued requests are
        // already shaped, so a mismatched artifact must leave the lane
        // untouched — identical contract to `hot_swap_spec`.
        if program.input_shape() != &info.input_shape[..] {
            bail!(
                "artifact hot-swap for `{name}` would change the input shape {:?} -> {:?}; \
                 queued requests are already shaped, register the artifact under a new \
                 name instead",
                info.input_shape,
                program.input_shape()
            );
        }
        let boxed = Box::new(program);
        let owned_name = name.to_string();
        let lane_buckets = info.buckets.clone();
        let reg = self.exec_round_trip(move |reply| ExecMsg::RegisterProgram {
            name: owned_name,
            program: boxed,
            buckets: lane_buckets,
            replace: true,
            reply,
        })?;
        match (&cell, reg.shared) {
            (Some(cell), Some(shared)) => {
                cell.swap(shared);
            }
            (None, None) => {}
            (Some(_), None) => bail!(
                "artifact hot-swap for `{name}` produced a non-shareable engine for a \
                 pooled lane"
            ),
            (None, Some(_)) => bail!(
                "artifact hot-swap for `{name}` produced a shareable engine for a \
                 pinned lane"
            ),
        }
        let client = {
            let mut queues = self.queues.lock().unwrap();
            let lane = queues
                .get_mut(name)
                .ok_or_else(|| anyhow!("lane for `{name}` vanished during hot-swap"))?;
            lane.info.generation += 1;
            lane.info.compile_ms = reg.info.compile_ms;
            lane.info.params = reg.info.params;
            record_cache_delta(&lane.metrics, reg.cache_delta);
            ModelClient {
                tx: lane.tx.clone(),
                metrics: lane.metrics.clone(),
                info: lane.info.clone(),
            }
        };
        self.epoch.fetch_add(1, Ordering::SeqCst);
        Ok(client)
    }

    fn lookup(&self, name: &str) -> Option<ModelClient> {
        let queues = self.queues.lock().unwrap();
        queues.get(name).map(|lane| ModelClient {
            tx: lane.tx.clone(),
            metrics: lane.metrics.clone(),
            info: lane.info.clone(),
        })
    }

    fn exec_round_trip(
        &self,
        msg: impl FnOnce(SyncSender<Result<Registration>>) -> ExecMsg,
    ) -> Result<Registration> {
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        self.exec_tx.send(msg(reply_tx)).map_err(|_| anyhow!("executor thread gone"))?;
        reply_rx.recv().map_err(|_| anyhow!("executor thread gone"))?
    }

    /// Spawn the model's execution lane (pool or pinned dispatch) and its
    /// batcher, then publish the queue. Caller holds `reg_lock`.
    fn finish_register(&self, reg: Registration) -> Result<ModelClient> {
        let Registration { mut info, shared, cache_delta } = reg;
        let metrics = Arc::new(ModelMetrics::new());
        record_cache_delta(&metrics, cache_delta);

        let (dispatch, cell) = match shared {
            Some(shared) => {
                let pool = self.cfg.workers.max(1);
                info.workers = pool;
                // The epoch-versioned artifact slot `hot_swap_spec` writes;
                // every worker re-loads it per job.
                let cell = Arc::new(SwapCell::new(shared));
                // Rendezvous-ish bounded job queue: the ticket pool below
                // (stacking buffers) is the real in-flight cap; this bound
                // just keeps teardown prompt.
                let (work_tx, work_rx) = mpsc::sync_channel::<Job>(pool);
                let work_rx = Arc::new(Mutex::new(work_rx));
                let mut handles = self.workers.lock().unwrap();
                for i in 0..pool {
                    let cell = cell.clone();
                    let buckets = info.buckets.clone();
                    let rx = work_rx.clone();
                    handles.push(
                        std::thread::Builder::new()
                            .name(format!("worker-{}-{i}", info.name))
                            .spawn(move || worker_main(cell, buckets, rx))
                            .context("spawning pool worker")?,
                    );
                }
                (Dispatch::Pool { work_tx }, Some(cell))
            }
            None => {
                info.workers = 1;
                (
                    Dispatch::Pinned { exec_tx: self.exec_tx.clone(), name: info.name.clone() },
                    None,
                )
            }
        };

        let (req_tx, req_rx) = mpsc::sync_channel::<Request>(self.cfg.queue_depth);
        let policy = BatchPolicy::new(info.buckets.clone(), self.cfg.max_wait);
        let m2 = metrics.clone();
        let info2 = info.clone();
        let stopping = self.stopping.clone();
        let max_inflight = info.workers + 1;
        let handle = std::thread::Builder::new()
            .name(format!("batcher-{}", info.name))
            .spawn(move || {
                batcher_main(info2, policy, req_rx, dispatch, m2, stopping, max_inflight)
            })
            .context("spawning batcher")?;
        self.batchers.lock().unwrap().push(handle);

        let client =
            ModelClient { tx: req_tx.clone(), metrics: metrics.clone(), info: info.clone() };
        self.queues
            .lock()
            .unwrap()
            .insert(info.name.clone(), Lane { tx: req_tx, metrics, info, cell });
        self.epoch.fetch_add(1, Ordering::SeqCst);
        Ok(client)
    }

    /// Monotonic registration counter; changes exactly when a new model
    /// becomes servable (see `epoch` field).
    pub fn registration_epoch(&self) -> u64 {
        self.epoch.load(Ordering::SeqCst)
    }

    /// Registered model names.
    pub fn models(&self) -> Vec<String> {
        self.queues.lock().unwrap().keys().cloned().collect()
    }

    /// Live metrics handle for a registered model, if any.
    pub fn metrics(&self, name: &str) -> Option<Arc<ModelMetrics>> {
        self.queues.lock().unwrap().get(name).map(|lane| lane.metrics.clone())
    }

    /// Every registered model's live metrics handle. The TCP front end
    /// walks these to tick the per-model SLO latency windows.
    pub fn model_metrics(&self) -> Vec<(String, Arc<ModelMetrics>)> {
        let queues = self.queues.lock().unwrap();
        queues.iter().map(|(name, lane)| (name.clone(), lane.metrics.clone())).collect()
    }

    /// Render every registered model's metrics block (the `serve` report).
    pub fn render_metrics(&self) -> String {
        let queues = self.queues.lock().unwrap();
        let mut out = String::new();
        for (name, lane) in queues.iter() {
            out.push_str(&lane.metrics.render(name, lane.info.workers));
            out.push('\n');
        }
        out
    }

    /// Stop batchers and the executor. Outstanding requests get errors;
    /// every *dispatched* batch is still executed and replied to.
    pub fn shutdown(&self) {
        // Under `reg_lock`: a registration in flight completes (its lane
        // lands in the handle vectors below and is joined); any later one
        // sees `stopping` under the same lock and fails cleanly instead of
        // re-spawning lanes on a torn-down coordinator.
        {
            let _reg = self.reg_lock.lock().unwrap();
            self.stopping.store(true, Ordering::SeqCst);
            // Close request queues so batchers drain and exit.
            self.queues.lock().unwrap().clear();
        }
        // Join in dependency order: batchers finish dispatching, workers
        // drain the remaining jobs (delivering their replies). Only THEN
        // tell the executor to stop — its channel is FIFO, so every pinned
        // job a batcher managed to send is ahead of the Shutdown message
        // and completes normally instead of being dropped reply-less.
        // Safe to call from multiple threads / again from drop.
        for h in self.batchers.lock().unwrap().drain(..) {
            let _ = h.join();
        }
        for h in self.workers.lock().unwrap().drain(..) {
            let _ = h.join();
        }
        let _ = self.exec_tx.send(ExecMsg::Shutdown);
        if let Some(h) = self.exec_thread.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Per-model handle: submit single-item inputs, get single-item outputs.
#[derive(Clone)]
pub struct ModelClient {
    tx: SyncSender<Request>,
    /// Live serving metrics for this model (shared with the batcher).
    pub metrics: Arc<ModelMetrics>,
    /// The registration contract: buckets, item shape, compile provenance.
    pub info: RegisterInfo,
}

/// What [`ModelClient::try_submit`] did with a request.
pub enum SubmitOutcome {
    /// The request was queued — or terminally answered through the
    /// callback already (shape errors are *delivered*, not returned).
    /// Either way the callback will fire (or has fired) exactly once.
    Accepted,
    /// The model's bounded queue is full. The callback comes back
    /// un-invoked so the caller can shed with a structured error.
    Full(ReplyFn),
    /// The model's queue is gone (coordinator shut down). The callback
    /// comes back un-invoked.
    Closed(ReplyFn),
}

impl ModelClient {
    /// Blocking inference of one item (`[H, W, C]`-shaped, no batch dim).
    pub fn infer(&self, input: Tensor) -> Result<Tensor> {
        let rx = self.infer_async(input)?;
        rx.recv().map_err(|_| anyhow!("coordinator dropped request"))?
    }

    /// Fire-and-collect-later variant; returns the reply channel.
    pub fn infer_async(&self, input: Tensor) -> Result<Receiver<Result<Tensor>>> {
        self.check_shape(&input)?;
        let (tx, rx) = mpsc::sync_channel(1);
        let reply: ReplyFn = Box::new(move |r| {
            let _ = tx.send(r);
        });
        self.tx
            .send(Request { input, enqueued: Instant::now(), reply })
            .map_err(|_| anyhow!("model queue closed"))?;
        Ok(rx)
    }

    /// Nonblocking submission with an arbitrary completion callback — the
    /// event-loop front end's path, where blocking the I/O thread on a
    /// full queue is not an option. Shape mismatches are delivered through
    /// the callback and count as `Accepted` (the request terminated, just
    /// not with an `Ok`); a full or closed queue hands the callback back
    /// un-invoked so the caller can shed or fail it.
    pub fn try_submit(&self, input: Tensor, reply: ReplyFn) -> SubmitOutcome {
        if let Err(e) = self.check_shape(&input) {
            reply(Err(e));
            return SubmitOutcome::Accepted;
        }
        match self.tx.try_send(Request { input, enqueued: Instant::now(), reply }) {
            Ok(()) => SubmitOutcome::Accepted,
            Err(TrySendError::Full(req)) => SubmitOutcome::Full(req.reply),
            Err(TrySendError::Disconnected(req)) => SubmitOutcome::Closed(req.reply),
        }
    }

    fn check_shape(&self, input: &Tensor) -> Result<()> {
        if input.shape() != &self.info.input_shape[..] {
            bail!(
                "expected item shape {:?}, got {:?} (submit single items; the \
                 coordinator batches)",
                self.info.input_shape,
                input.shape()
            );
        }
        Ok(())
    }
}

// ---------------------------------------------------------------- dispatch

/// Where a batcher sends its stacked jobs.
enum Dispatch {
    /// The single executor thread (engines that are not `Send`/shareable).
    Pinned { exec_tx: Sender<ExecMsg>, name: String },
    /// This model's worker pool over one shared artifact.
    Pool { work_tx: SyncSender<Job> },
}

impl Dispatch {
    /// Hand a job to the execution lane; on a closed lane the job comes
    /// back so the batcher can fail its requests.
    fn send(&self, job: Job) -> std::result::Result<(), Job> {
        match self {
            Dispatch::Pinned { exec_tx, name } => exec_tx
                .send(ExecMsg::InferBatch { name: name.clone(), job })
                .map_err(|e| match e.0 {
                    ExecMsg::InferBatch { job, .. } => job,
                    _ => unreachable!("we sent an InferBatch"),
                }),
            Dispatch::Pool { work_tx } => work_tx.send(job).map_err(|e| e.0),
        }
    }
}

// ---------------------------------------------------------------- threads

/// A pool worker: one clone of the shared artifact, one private scratch.
/// Workers race on the job queue (`Mutex<Receiver>` — exactly one waiter
/// gets each job) and exit when the batcher drops the sender. The artifact
/// comes from the lane's [`SwapCell`]: the worker re-loads it before every
/// job and rebuilds its scratch when the epoch moved (hot-swap), so a
/// swapped-out artifact finishes its in-flight batches and is then
/// released.
fn worker_main(cell: Arc<SwapCell>, buckets: Vec<usize>, rx: Arc<Mutex<Receiver<Job>>>) {
    let (mut epoch, mut shared) = cell.load();
    let mut scratch = shared.new_scratch(&buckets);
    loop {
        // The guard is a temporary of this statement: the lock is held
        // only while *waiting*, and inference below runs unlocked so the
        // other workers execute concurrently.
        let msg = rx.lock().unwrap().recv();
        let Ok(job) = msg else { return };
        let (now, artifact) = cell.load();
        if now != epoch {
            epoch = now;
            shared = artifact;
            scratch = shared.new_scratch(&buckets);
        }
        let result = shared.infer_shared(&job.batch, &mut scratch).map(|mut o| o.remove(0));
        complete(job, result);
    }
}

/// The pinned executor thread: owns every non-shareable engine (the
/// compiled engine's PJRT state is not `Send`, so construction *and*
/// execution are confined here). Engines are built once per model through
/// the registry and kept for the coordinator's lifetime — re-registering
/// is a cache hit. Shareable engines are also *built* here (one code
/// path), but their inference traffic never arrives: the worker pool owns
/// it.
fn executor_main(
    manifest: Manifest,
    kind: EngineKind,
    intra_threads: usize,
    weight_dtype: crate::nn::simd::WeightDtype,
    rx: Receiver<ExecMsg>,
) {
    let compile = crate::compiler::exec::CompileOptions {
        intra_threads,
        weight_dtype,
        ..crate::compiler::exec::CompileOptions::default()
    };
    let opts = EngineOptions { compile, ..EngineOptions::default() };
    let mut engines: HashMap<String, Box<dyn Engine>> = HashMap::new();

    while let Ok(msg) = rx.recv() {
        match msg {
            ExecMsg::Shutdown => break,
            ExecMsg::Register { name, replace, reply } => {
                let res = register_engine(&manifest, kind, &opts, &mut engines, &name, replace);
                let _ = reply.send(res);
            }
            ExecMsg::RegisterSpec { spec, buckets, replace, weight_dtype, reply } => {
                // Per-registration dtype override (the hot-requantization
                // path); `None` inherits the coordinator's configured dtype.
                let mut msg_opts = opts.clone();
                if let Some(dt) = weight_dtype {
                    msg_opts.compile.weight_dtype = dt;
                }
                let res =
                    register_spec_engine(kind, &msg_opts, &mut engines, &spec, buckets, replace);
                let _ = reply.send(res);
            }
            ExecMsg::RegisterProgram { name, program, buckets, replace, reply } => {
                let res = register_program_engine(&mut engines, &name, *program, buckets, replace);
                let _ = reply.send(res);
            }
            ExecMsg::InferBatch { name, job } => {
                let result = match engines.get_mut(&name) {
                    Some(e) => e.infer(&job.batch).map(|mut outs| outs.remove(0)),
                    None => Err(anyhow!("model `{name}` not registered")),
                };
                complete(job, result);
            }
        }
    }
}

/// How the global [`ProgramCache`] counters moved across `build` — the
/// executor thread builds engines serially, so the delta is exactly the
/// cache activity of the one registration being processed.
fn with_cache_delta<T>(build: impl FnOnce() -> Result<T>) -> Result<(T, CacheCounters)> {
    let before = ProgramCache::global().counters();
    let built = build()?;
    let after = ProgramCache::global().counters();
    Ok((
        built,
        CacheCounters {
            hits: after.hits - before.hits,
            misses: after.misses - before.misses,
            invalidated: after.invalidated - before.invalidated,
        },
    ))
}

/// Fold a registration's cache delta into the lane's metrics.
fn record_cache_delta(metrics: &ModelMetrics, delta: CacheCounters) {
    metrics.cache_hits.add(delta.hits);
    metrics.cache_misses.add(delta.misses);
    metrics.cache_invalidated.add(delta.invalidated);
}

fn register_engine(
    manifest: &Manifest,
    kind: EngineKind,
    opts: &EngineOptions,
    engines: &mut HashMap<String, Box<dyn Engine>>,
    name: &str,
    replace: bool,
) -> Result<Registration> {
    let entry = manifest.entry(name)?.clone();
    let cache_hit = !replace && engines.contains_key(name);
    let mut cache_delta = CacheCounters::default();
    if !cache_hit {
        // On `replace`, a build failure propagates *before* the insert:
        // the cached engine stays and the lane keeps serving the old
        // artifact.
        let (engine, delta) = with_cache_delta(|| build_engine(kind, manifest, name, opts))?;
        cache_delta = delta;
        let buckets = engine.batch_buckets().unwrap_or_else(|| entry.batches.clone());
        finish_engine(engines, name, engine, &buckets);
    }
    let engine = engines.get(name).expect("engine registered above");
    Ok(Registration {
        shared: engine.shareable(),
        cache_delta,
        info: RegisterInfo {
            name: name.to_string(),
            // Interpreters take any batch size; they still advertise the
            // manifest buckets so the batcher packs identically across
            // engines.
            buckets: engine.batch_buckets().unwrap_or_else(|| entry.batches.clone()),
            input_shape: entry.input_shape.clone(),
            compile_ms: engine.compile_ms(),
            cache_hit,
            params: entry.params,
            engine: engine.name().to_string(),
            workers: 1, // finalized by the coordinator once the lane exists
            generation: 1,
        },
    })
}

fn register_spec_engine(
    kind: EngineKind,
    opts: &EngineOptions,
    engines: &mut HashMap<String, Box<dyn Engine>>,
    spec: &ModelSpec,
    buckets: Vec<usize>,
    replace: bool,
) -> Result<Registration> {
    let cache_hit = !replace && engines.contains_key(&spec.name);
    let mut cache_delta = CacheCounters::default();
    if !cache_hit {
        let (engine, delta) = with_cache_delta(|| build_engine_from_spec(kind, spec, opts))?;
        cache_delta = delta;
        finish_engine(engines, &spec.name, engine, &buckets);
    }
    let engine = engines.get(&spec.name).expect("engine registered above");
    Ok(Registration {
        shared: engine.shareable(),
        cache_delta,
        info: RegisterInfo {
            name: spec.name.clone(),
            buckets: engine.batch_buckets().unwrap_or(buckets),
            input_shape: spec.input_shape.clone(),
            compile_ms: engine.compile_ms(),
            cache_hit,
            params: spec.param_count(),
            engine: engine.name().to_string(),
            workers: 1,
            generation: 1,
        },
    })
}

/// Registry tail for a program that was already lowered (artifact load):
/// wrap it in the optimized interpreter and publish it exactly like a
/// spec-built engine. No lowering happens here, so the cache delta is zero
/// by construction — the artifact *is* the cache's payload.
fn register_program_engine(
    engines: &mut HashMap<String, Box<dyn Engine>>,
    name: &str,
    program: Program,
    buckets: Vec<usize>,
    replace: bool,
) -> Result<Registration> {
    let input_shape = program.input_shape().to_vec();
    // packed panel elements — the artifact does not carry the original
    // spec, so the resident weight footprint stands in for param count
    let params = program.summary().weight_elems;
    let cache_hit = !replace && engines.contains_key(name);
    if !cache_hit {
        let engine: Box<dyn Engine> =
            Box::new(crate::compiler::exec::OptInterp::from_program(program));
        finish_engine(engines, name, engine, &buckets);
    }
    let engine = engines.get(name).expect("engine registered above");
    Ok(Registration {
        shared: engine.shareable(),
        cache_delta: CacheCounters::default(),
        info: RegisterInfo {
            name: name.to_string(),
            buckets: engine.batch_buckets().unwrap_or(buckets),
            input_shape,
            compile_ms: engine.compile_ms(),
            cache_hit,
            params,
            engine: engine.name().to_string(),
            workers: 1,
            generation: 1,
        },
    })
}

/// Shared tail of both register paths: warm the engine's own arenas only
/// when it will actually execute (pinned lane) — pool workers pre-size
/// their private scratch instead — then publish it in the cache.
fn finish_engine(
    engines: &mut HashMap<String, Box<dyn Engine>>,
    name: &str,
    mut engine: Box<dyn Engine>,
    buckets: &[usize],
) {
    if engine.shareable().is_none() {
        for &b in buckets {
            engine.prepare(b);
        }
    }
    engines.insert(name.to_string(), engine);
}

/// Deliver a finished job: record metrics, fan replies out per request,
/// and return the stacking buffer to the batcher.
fn complete(job: Job, result: Result<Tensor>) {
    let Job { bucket, batch, requests, t_exec, metrics, done } = job;
    let n = requests.len();
    metrics.exec.record(t_exec.elapsed());
    metrics.batches.add(1);
    metrics.requests.add(n as u64);
    metrics.padded_slots.add((bucket - n) as u64);
    metrics.inflight.dec();

    match result {
        Ok(out) => {
            for (i, r) in requests.into_iter().enumerate() {
                let item = out.slice_batch(i, i + 1);
                let waited = r.enqueued.elapsed();
                metrics.latency.record(waited);
                metrics.latency_window.record(waited);
                (r.reply)(Ok(item));
            }
        }
        Err(e) => {
            metrics.errors.add(n as u64);
            let msg = e.to_string();
            for r in requests {
                (r.reply)(Err(anyhow!("{msg}")));
            }
        }
    }
    let _ = done.send(batch.into_vec());
}

fn batcher_main(
    info: RegisterInfo,
    policy: BatchPolicy,
    rx: Receiver<Request>,
    dispatch: Dispatch,
    metrics: Arc<ModelMetrics>,
    stopping: Arc<AtomicBool>,
    max_inflight: usize,
) {
    let (done_tx, done_rx) = mpsc::channel::<Vec<f32>>();
    let mut queue: Vec<Request> = Vec::new();
    let mut stacker = Stacker {
        item_elems: info.input_shape.iter().product(),
        info,
        dispatch,
        metrics,
        done_tx,
        done_rx,
        issued: 0,
        max_inflight,
        stopping: stopping.clone(),
    };

    loop {
        if stopping.load(Ordering::SeqCst) {
            fail_all(&mut queue, "coordinator shutting down");
            return;
        }
        let oldest = queue.first().map(|r| r.enqueued.elapsed()).unwrap_or(Duration::ZERO);
        match policy.decide(queue.len(), oldest) {
            // recv_timeout, not recv: clients may hold the queue sender
            // forever, and only this loop observes the stopping flag.
            Flush::Idle => match rx.recv_timeout(IDLE_TICK) {
                Ok(r) => queue.push(r),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => return, // nothing pending
            },
            Flush::Wait(d) => match rx.recv_timeout(d.min(IDLE_TICK)) {
                Ok(r) => queue.push(r),
                Err(RecvTimeoutError::Timeout) => {} // deadline → next decide flushes
                Err(RecvTimeoutError::Disconnected) => {
                    stacker.drain(&policy, &mut queue);
                    return;
                }
            },
            Flush::Now(bucket) => {
                let take = queue.len().min(bucket);
                let batch: Vec<Request> = queue.drain(..take).collect();
                stacker.run_batch(bucket, batch);
            }
        }
    }
}

/// The batcher's stacking state: the ticket pool of recycled stacking
/// buffers (each dispatched job carries one away; `complete` sends it
/// back), which caps in-flight batches at `max_inflight` and makes the
/// steady state allocation-free.
struct Stacker {
    info: RegisterInfo,
    dispatch: Dispatch,
    metrics: Arc<ModelMetrics>,
    item_elems: usize,
    done_tx: Sender<Vec<f32>>,
    done_rx: Receiver<Vec<f32>>,
    issued: usize,
    max_inflight: usize,
    stopping: Arc<AtomicBool>,
}

impl Stacker {
    /// Acquire a stacking buffer: a recycled one if available, a fresh one
    /// while under the in-flight cap, otherwise block until a job returns
    /// its ticket — a merely *slow* lane keeps the cap honored (we wait).
    /// Two bounded escapes keep the batcher live: teardown (`stopping`),
    /// and a ticket missing for [`TICKET_PATIENCE`] — presumed lost with a
    /// crashed lane, so a replacement is minted and the batcher keeps
    /// serving (the dead lane then fails the requests fast) instead of
    /// wedging with a full request queue forever.
    fn acquire(&mut self) -> Vec<f32> {
        match self.done_rx.try_recv() {
            Ok(buf) => buf,
            Err(TryRecvError::Empty) if self.issued >= self.max_inflight => {
                let patience = Instant::now() + TICKET_PATIENCE;
                loop {
                    match self.done_rx.recv_timeout(Duration::from_millis(100)) {
                        Ok(buf) => break buf,
                        Err(_) => {
                            if self.stopping.load(Ordering::SeqCst)
                                || Instant::now() >= patience
                            {
                                // mint a replacement and ACCOUNT for it:
                                // if the missing ticket ever returns, the
                                // cap still holds from then on instead of
                                // growing by one per escape
                                self.issued += 1;
                                break Vec::new();
                            }
                            // slow lane: keep waiting, keep the cap
                        }
                    }
                }
            }
            Err(_) => {
                self.issued += 1;
                Vec::new()
            }
        }
    }

    /// Dispatch everything still queued (teardown path) — the same
    /// bucket/take/stack steps the steady-state `Flush::Now` arm performs.
    fn drain(&mut self, policy: &BatchPolicy, queue: &mut Vec<Request>) {
        while !queue.is_empty() {
            let bucket = policy.bucket_for(queue.len());
            let take = queue.len().min(bucket);
            let batch: Vec<Request> = queue.drain(..take).collect();
            self.run_batch(bucket, batch);
        }
    }

    /// Stack a bucket and hand it to the execution lane — fire and forget;
    /// the lane fans replies out, so this returns as soon as the job is
    /// queued and the batcher keeps batching while workers execute.
    fn run_batch(&mut self, bucket: usize, batch: Vec<Request>) {
        let n = batch.len();
        debug_assert!(n <= bucket);
        for r in &batch {
            self.metrics.queue_wait.record(r.enqueued.elapsed());
        }

        // Stack into [bucket, item…] on a recycled ticket buffer:
        // clear+resize zero-fills (covering the padded slots) without
        // reallocating once every ticket has reached the largest bucket.
        let mut shape = vec![bucket];
        shape.extend_from_slice(&self.info.input_shape);
        let mut data = self.acquire();
        data.clear();
        data.resize(bucket * self.item_elems, 0.0);
        for (i, r) in batch.iter().enumerate() {
            let dst = &mut data[i * self.item_elems..(i + 1) * self.item_elems];
            dst.copy_from_slice(r.input.data());
        }
        let input = Tensor::from_vec(&shape, data);

        self.metrics.inflight.inc();
        let job = Job {
            bucket,
            batch: input,
            requests: batch,
            t_exec: Instant::now(),
            metrics: self.metrics.clone(),
            done: self.done_tx.clone(),
        };
        if let Err(job) = self.dispatch.send(job) {
            // dead lane: same delivery + accounting as an executed batch
            // that errored (metrics, replies, gauge, ticket reclaim), so
            // the requests/errors counters stay exact even in this path
            complete(job, Err(anyhow!("execution lane gone")));
        }
    }
}

fn fail_all(queue: &mut Vec<Request>, msg: &str) {
    for r in queue.drain(..) {
        (r.reply)(Err(anyhow!("{msg}")));
    }
}
