//! The serving coordinator (L3): a model registry with an executor thread
//! that owns all PJRT state (the wrapper types are not `Send`), per-model
//! batcher threads implementing the `BatchPolicy`, and shared metrics.
//!
//! Request path (Python nowhere in sight):
//!   client → `ModelClient::infer` → batcher thread (dynamic batching, §4's
//!   many-candidates-per-frame workload) → executor thread (PJRT execute of
//!   the AOT artifact) → reply channel → client.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::batcher::{BatchPolicy, Flush};
use crate::coordinator::metrics::ModelMetrics;
use crate::engine::{build_engine, Engine, EngineKind, EngineOptions};
use crate::nn::tensor::Tensor;
use crate::runtime::artifact::Manifest;

/// A single inference request: one item (no batch dim); the batcher stacks.
struct Request {
    input: Tensor,
    enqueued: Instant,
    reply: SyncSender<Result<Tensor>>,
}

/// Work sent to the executor thread.
enum ExecMsg {
    Register {
        name: String,
        reply: SyncSender<Result<RegisterInfo>>,
    },
    InferBatch {
        name: String,
        batch: Tensor,
        /// Replies with the result AND the input buffer, which the batcher
        /// recycles as its next stacking scratch — the batch path allocates
        /// nothing once capacities have grown to the largest bucket.
        reply: SyncSender<(Result<Tensor>, Vec<f32>)>,
    },
    Shutdown,
}

#[derive(Debug, Clone)]
pub struct RegisterInfo {
    pub name: String,
    pub buckets: Vec<usize>,
    pub input_shape: Vec<usize>,
    pub compile_ms: f64,
    pub cache_hit: bool,
    pub params: usize,
    /// Registry name of the engine serving this model.
    pub engine: String,
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub max_wait: Duration,
    /// Bounded queue per model (backpressure: senders block).
    pub queue_depth: usize,
    /// Engine the executor thread builds for every registered model.
    /// Defaults to the best kind this build supports (compiled with the
    /// `pjrt` feature, optimized interpreter otherwise).
    pub engine: EngineKind,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            max_wait: Duration::from_millis(2),
            queue_depth: 1024,
            engine: EngineKind::preferred(),
        }
    }
}

pub struct Coordinator {
    exec_tx: Sender<ExecMsg>,
    exec_thread: Option<JoinHandle<()>>,
    batchers: Vec<JoinHandle<()>>,
    queues: Mutex<HashMap<String, (SyncSender<Request>, Arc<ModelMetrics>, RegisterInfo)>>,
    cfg: CoordinatorConfig,
    stopping: Arc<AtomicBool>,
}

impl Coordinator {
    /// Start the executor thread over the given artifact manifest. Engine
    /// construction happens lazily at `register`, which is where failures
    /// (unavailable engine, bad artifact) surface.
    pub fn start(manifest: Manifest, cfg: CoordinatorConfig) -> Result<Arc<Self>> {
        let (exec_tx, exec_rx) = mpsc::channel::<ExecMsg>();
        let engine_kind = cfg.engine;
        let exec_thread = std::thread::Builder::new()
            .name("engine-executor".into())
            .spawn(move || executor_main(manifest, engine_kind, exec_rx))
            .context("spawning executor thread")?;
        Ok(Arc::new(Self {
            exec_tx,
            exec_thread: Some(exec_thread),
            batchers: Vec::new(),
            queues: Mutex::new(HashMap::new()),
            cfg,
            stopping: Arc::new(AtomicBool::new(false)),
        }))
    }

    /// Load + PJRT-compile a model (the runtime-JIT step) and start its
    /// batcher. Idempotent: re-registering returns the existing client.
    pub fn register(self: &Arc<Self>, name: &str) -> Result<ModelClient> {
        {
            let queues = self.queues.lock().unwrap();
            if let Some((tx, metrics, info)) = queues.get(name) {
                return Ok(ModelClient {
                    tx: tx.clone(),
                    metrics: metrics.clone(),
                    info: info.clone(),
                });
            }
        }
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        self.exec_tx
            .send(ExecMsg::Register { name: name.into(), reply: reply_tx })
            .map_err(|_| anyhow!("executor thread gone"))?;
        let info = reply_rx.recv().map_err(|_| anyhow!("executor thread gone"))??;

        let (req_tx, req_rx) = mpsc::sync_channel::<Request>(self.cfg.queue_depth);
        let metrics = Arc::new(ModelMetrics::new());
        let policy = BatchPolicy::new(info.buckets.clone(), self.cfg.max_wait);
        let exec_tx = self.exec_tx.clone();
        let m2 = metrics.clone();
        let info2 = info.clone();
        let stopping = self.stopping.clone();
        let handle = std::thread::Builder::new()
            .name(format!("batcher-{name}"))
            .spawn(move || batcher_main(info2, policy, req_rx, exec_tx, m2, stopping))
            .context("spawning batcher")?;

        let client = ModelClient { tx: req_tx.clone(), metrics: metrics.clone(), info: info.clone() };
        let mut queues = self.queues.lock().unwrap();
        queues.insert(name.to_string(), (req_tx, metrics, info));
        // Store the join handle (interior mutability not needed; we only
        // join in shutdown where we have &mut via Arc::try_unwrap fallback).
        drop(queues);
        // batcher handles are detached on purpose; they exit when their
        // request queue closes or `stopping` flips.
        let _ = handle;
        Ok(client)
    }

    /// Registered model names.
    pub fn models(&self) -> Vec<String> {
        self.queues.lock().unwrap().keys().cloned().collect()
    }

    pub fn metrics(&self, name: &str) -> Option<Arc<ModelMetrics>> {
        self.queues.lock().unwrap().get(name).map(|(_, m, _)| m.clone())
    }

    pub fn render_metrics(&self) -> String {
        let queues = self.queues.lock().unwrap();
        let mut out = String::new();
        for (name, (_, m, _)) in queues.iter() {
            out.push_str(&m.render(name));
            out.push('\n');
        }
        out
    }

    /// Stop batchers and the executor. Outstanding requests get errors.
    pub fn shutdown(&self) {
        self.stopping.store(true, Ordering::SeqCst);
        // Close request queues so batchers drain and exit.
        self.queues.lock().unwrap().clear();
        let _ = self.exec_tx.send(ExecMsg::Shutdown);
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(h) = self.exec_thread.take() {
            let _ = h.join();
        }
        for h in self.batchers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Per-model handle: submit single-item inputs, get single-item outputs.
#[derive(Clone)]
pub struct ModelClient {
    tx: SyncSender<Request>,
    pub metrics: Arc<ModelMetrics>,
    pub info: RegisterInfo,
}

impl ModelClient {
    /// Blocking inference of one item (`[H, W, C]`-shaped, no batch dim).
    pub fn infer(&self, input: Tensor) -> Result<Tensor> {
        let rx = self.infer_async(input)?;
        rx.recv().map_err(|_| anyhow!("coordinator dropped request"))?
    }

    /// Fire-and-collect-later variant; returns the reply channel.
    pub fn infer_async(&self, input: Tensor) -> Result<Receiver<Result<Tensor>>> {
        if input.shape() != &self.info.input_shape[..] {
            bail!(
                "expected item shape {:?}, got {:?} (submit single items; the \
                 coordinator batches)",
                self.info.input_shape,
                input.shape()
            );
        }
        let (reply, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request { input, enqueued: Instant::now(), reply })
            .map_err(|_| anyhow!("model queue closed"))?;
        Ok(rx)
    }
}

// ---------------------------------------------------------------- threads

/// The executor thread: owns every engine (the compiled engine's PJRT
/// state is not `Send`, so construction *and* execution are confined
/// here). Engines are built once per model through the registry and kept
/// for the coordinator's lifetime — re-registering is a cache hit.
fn executor_main(manifest: Manifest, kind: EngineKind, rx: Receiver<ExecMsg>) {
    let opts = EngineOptions::default();
    let mut engines: HashMap<String, Box<dyn Engine>> = HashMap::new();

    while let Ok(msg) = rx.recv() {
        match msg {
            ExecMsg::Shutdown => break,
            ExecMsg::Register { name, reply } => {
                let res = register_engine(&manifest, kind, &opts, &mut engines, &name);
                let _ = reply.send(res);
            }
            ExecMsg::InferBatch { name, batch, reply } => {
                let res = match engines.get_mut(&name) {
                    Some(e) => e.infer(&batch).map(|mut outs| outs.remove(0)),
                    None => Err(anyhow!("model `{name}` not registered")),
                };
                // hand the input buffer back for the batcher to recycle
                let _ = reply.send((res, batch.into_vec()));
            }
        }
    }
}

fn register_engine(
    manifest: &Manifest,
    kind: EngineKind,
    opts: &EngineOptions,
    engines: &mut HashMap<String, Box<dyn Engine>>,
    name: &str,
) -> Result<RegisterInfo> {
    let entry = manifest.entry(name)?.clone();
    let cache_hit = engines.contains_key(name);
    if !cache_hit {
        let mut engine = build_engine(kind, manifest, name, opts)?;
        // Pool one arena per advertised batch bucket up front (cheap: just
        // allocation, no inference) so steady-state serving never allocates
        // engine-side — the §3.2 plan fixed every buffer size at lowering.
        let buckets = engine.batch_buckets().unwrap_or_else(|| entry.batches.clone());
        for &b in &buckets {
            engine.prepare(b);
        }
        engines.insert(name.to_string(), engine);
    }
    let engine = engines.get(name).expect("engine registered above");
    Ok(RegisterInfo {
        name: name.to_string(),
        // Interpreters take any batch size; they still advertise the
        // manifest buckets so the batcher packs identically across engines.
        buckets: engine.batch_buckets().unwrap_or_else(|| entry.batches.clone()),
        input_shape: entry.input_shape.clone(),
        compile_ms: engine.compile_ms(),
        cache_hit,
        params: entry.params,
        engine: engine.name().to_string(),
    })
}

fn batcher_main(
    info: RegisterInfo,
    policy: BatchPolicy,
    rx: Receiver<Request>,
    exec_tx: Sender<ExecMsg>,
    metrics: Arc<ModelMetrics>,
    stopping: Arc<AtomicBool>,
) {
    let item_elems: usize = info.input_shape.iter().product();
    let mut queue: Vec<Request> = Vec::new();
    // Stacking scratch, recycled through the executor round-trip: after the
    // first max-bucket flush its capacity never grows again.
    let mut scratch: Vec<f32> = Vec::new();

    loop {
        if stopping.load(Ordering::SeqCst) {
            fail_all(&mut queue, "coordinator shutting down");
            return;
        }
        let oldest = queue.first().map(|r| r.enqueued.elapsed()).unwrap_or(Duration::ZERO);
        match policy.decide(queue.len(), oldest) {
            Flush::Idle => match rx.recv() {
                Ok(r) => queue.push(r),
                Err(_) => return, // queue closed, nothing pending
            },
            Flush::Wait(d) => match rx.recv_timeout(d) {
                Ok(r) => queue.push(r),
                Err(RecvTimeoutError::Timeout) => {} // deadline → next decide flushes
                Err(RecvTimeoutError::Disconnected) => {
                    flush(&info, &policy, &mut queue, &exec_tx, &metrics, item_elems, &mut scratch);
                    return;
                }
            },
            Flush::Now(bucket) => {
                let take = queue.len().min(bucket);
                let batch: Vec<Request> = queue.drain(..take).collect();
                run_batch(&info, bucket, batch, &exec_tx, &metrics, item_elems, &mut scratch);
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn flush(
    info: &RegisterInfo,
    policy: &BatchPolicy,
    queue: &mut Vec<Request>,
    exec_tx: &Sender<ExecMsg>,
    metrics: &ModelMetrics,
    item_elems: usize,
    scratch: &mut Vec<f32>,
) {
    while !queue.is_empty() {
        let bucket = policy.bucket_for(queue.len());
        let take = queue.len().min(bucket);
        let batch: Vec<Request> = queue.drain(..take).collect();
        run_batch(info, bucket, batch, exec_tx, metrics, item_elems, scratch);
    }
}

fn fail_all(queue: &mut Vec<Request>, msg: &str) {
    for r in queue.drain(..) {
        let _ = r.reply.send(Err(anyhow!("{msg}")));
    }
}

#[allow(clippy::too_many_arguments)]
fn run_batch(
    info: &RegisterInfo,
    bucket: usize,
    batch: Vec<Request>,
    exec_tx: &Sender<ExecMsg>,
    metrics: &ModelMetrics,
    item_elems: usize,
    scratch: &mut Vec<f32>,
) {
    let n = batch.len();
    debug_assert!(n <= bucket);
    let t_exec = Instant::now();
    for r in &batch {
        metrics.queue_wait.record(r.enqueued.elapsed());
    }

    // Stack into [bucket, item…] on the recycled scratch: clear+resize
    // zero-fills (covering the padded slots) without reallocating once the
    // capacity has reached the largest bucket.
    let mut shape = vec![bucket];
    shape.extend_from_slice(&info.input_shape);
    let mut data = std::mem::take(scratch);
    data.clear();
    data.resize(bucket * item_elems, 0.0);
    for (i, r) in batch.iter().enumerate() {
        data[i * item_elems..(i + 1) * item_elems].copy_from_slice(r.input.data());
    }
    let input = Tensor::from_vec(&shape, data);

    let (reply_tx, reply_rx) = mpsc::sync_channel(1);
    if let Err(send_err) =
        exec_tx.send(ExecMsg::InferBatch { name: info.name.clone(), batch: input, reply: reply_tx })
    {
        if let ExecMsg::InferBatch { batch: unsent, .. } = send_err.0 {
            *scratch = unsent.into_vec();
        }
        let mut q: Vec<Request> = batch;
        fail_all(&mut q, "executor gone");
        return;
    }
    let (result, recycled) =
        reply_rx.recv().unwrap_or_else(|_| (Err(anyhow!("executor gone")), Vec::new()));
    *scratch = recycled;
    metrics.exec.record(t_exec.elapsed());
    metrics.batches.add(1);
    metrics.requests.add(n as u64);
    metrics.padded_slots.add((bucket - n) as u64);

    match result {
        Ok(out) => {
            for (i, r) in batch.into_iter().enumerate() {
                let item = out.slice_batch(i, i + 1);
                metrics.latency.record(r.enqueued.elapsed());
                let _ = r.reply.send(Ok(item));
            }
        }
        Err(e) => {
            metrics.errors.add(n as u64);
            let msg = e.to_string();
            for r in batch {
                let _ = r.reply.send(Err(anyhow!("{msg}")));
            }
        }
    }
}
