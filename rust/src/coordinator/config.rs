//! Serving deployment configuration — the launcher's input file.
//!
//! ```json
//! {
//!   "listen": "127.0.0.1:7878",
//!   "max_wait_us": 500,
//!   "queue_depth": 2048,
//!   "workers": 4,
//!   "max_inflight": 4096,
//!   "slo_p99_ms": 25.0,
//!   "models": ["c_bh", "c_htwk"]
//! }
//! ```
//!
//! `compiled-nn serve --config serving.json` starts the coordinator,
//! registers (JIT-compiles) every listed model, and brings up the TCP
//! front end.

use std::path::Path;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::engine::EngineKind;
use crate::nn::simd::WeightDtype;
use crate::util::json::Json;

use super::server::{CoordinatorConfig, default_workers};
use super::tcp::TcpOptions;

#[derive(Debug, Clone, PartialEq)]
pub struct ServingConfig {
    pub listen: String,
    pub models: Vec<String>,
    pub max_wait: Duration,
    pub queue_depth: usize,
    /// Engine kind to serve with (`"engine": "optimized"`); default is the
    /// best kind the build supports.
    pub engine: EngineKind,
    /// Worker threads per model for engines with a shared-inference
    /// artifact (`"workers": 4`); default `min(4, cores)`. Engines without
    /// one (naive, PJRT) stay pinned to the executor thread.
    pub workers: usize,
    /// Intra-op task budget compiled into each lowered program
    /// (`"intra_threads": 4` → `CompileOptions::intra_threads`): how many
    /// bands one inference may split a large conv/GEMM across. Default 1 —
    /// the worker pool already spends the cores across requests; raise it
    /// for latency-critical single-stream serving of big nets.
    pub intra_threads: usize,
    /// Weight storage dtype compiled into each lowered program
    /// (`"weight_dtype": "i8"` → `CompileOptions::weight_dtype`): `"f32"`
    /// (default), `"bf16"`, or `"i8"`. Serving the same model under a new
    /// dtype goes through the live `swap` path — registrations carry their
    /// own artifact generation, so a flip from f32 to i8 is atomic.
    pub weight_dtype: WeightDtype,
    /// Global cap on requests admitted by the TCP front end but not yet
    /// answered (`"max_inflight": 4096`); past it, requests shed with a
    /// structured `overloaded` error. 0 = unlimited.
    pub max_inflight: u64,
    /// Per-model p99 latency SLO in milliseconds (`"slo_p99_ms": 25.0`):
    /// while a model's windowed p99 exceeds it, the front end sheds that
    /// model's new requests with `overloaded`. Default 0 = disabled.
    pub slo_p99_ms: f64,
    /// Directory for the persistent compiled-artifact cache
    /// (`"cache_dir": "/var/cache/compiled-nn"`). When set, the launcher
    /// exports `COMPILED_NN_CACHE_DIR` before the coordinator starts, so
    /// every registration mmap-loads a valid cached artifact instead of
    /// re-lowering. `None` (default) leaves the env var alone — an
    /// already-exported `COMPILED_NN_CACHE_DIR` still wins.
    pub cache_dir: Option<String>,
}

impl Default for ServingConfig {
    fn default() -> Self {
        Self {
            listen: "127.0.0.1:7878".into(),
            models: vec![],
            max_wait: Duration::from_micros(500),
            queue_depth: 1024,
            engine: EngineKind::preferred(),
            workers: default_workers(),
            intra_threads: 1,
            weight_dtype: WeightDtype::F32,
            max_inflight: 4096,
            slo_p99_ms: 0.0,
            cache_dir: None,
        }
    }
}

impl ServingConfig {
    pub fn parse(text: &str) -> Result<ServingConfig> {
        let j = Json::parse(text).context("serving config is not valid JSON")?;
        let d = ServingConfig::default();
        let models = j
            .req_arr("models")?
            .iter()
            .map(|m| m.as_str().map(str::to_string).context("model names must be strings"))
            .collect::<Result<Vec<_>>>()?;
        if models.is_empty() {
            bail!("serving config lists no models");
        }
        Ok(ServingConfig {
            listen: j
                .get("listen")
                .and_then(Json::as_str)
                .unwrap_or(&d.listen)
                .to_string(),
            models,
            max_wait: Duration::from_micros(
                j.get("max_wait_us").and_then(Json::as_f64).unwrap_or(500.0) as u64,
            ),
            queue_depth: j
                .get("queue_depth")
                .and_then(Json::as_usize)
                .unwrap_or(d.queue_depth),
            engine: match j.get("engine").and_then(Json::as_str) {
                Some(s) => EngineKind::parse(s)?,
                None => d.engine,
            },
            workers: j.get("workers").and_then(Json::as_usize).unwrap_or(d.workers).max(1),
            intra_threads: j
                .get("intra_threads")
                .and_then(Json::as_usize)
                .unwrap_or(d.intra_threads)
                .max(1),
            weight_dtype: match j.get("weight_dtype").and_then(Json::as_str) {
                Some(s) => match WeightDtype::parse(s) {
                    Some(dt) => dt,
                    None => bail!("unknown weight_dtype `{s}` (expected f32|bf16|i8)"),
                },
                None => d.weight_dtype,
            },
            max_inflight: j
                .get("max_inflight")
                .and_then(Json::as_u64)
                .unwrap_or(d.max_inflight),
            slo_p99_ms: {
                let v = j.get("slo_p99_ms").and_then(Json::as_f64).unwrap_or(d.slo_p99_ms);
                if v < 0.0 {
                    bail!("slo_p99_ms must be >= 0 (0 disables SLO shedding)");
                }
                v
            },
            cache_dir: j.get("cache_dir").and_then(Json::as_str).map(str::to_string),
        })
    }

    pub fn load(path: &Path) -> Result<ServingConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn coordinator_config(&self) -> CoordinatorConfig {
        CoordinatorConfig {
            max_wait: self.max_wait,
            queue_depth: self.queue_depth,
            engine: self.engine,
            workers: self.workers,
            intra_threads: self.intra_threads,
            weight_dtype: self.weight_dtype,
        }
    }

    /// The TCP front end's admission-control knobs.
    pub fn tcp_options(&self) -> TcpOptions {
        TcpOptions { max_inflight: self.max_inflight, slo_p99_ms: self.slo_p99_ms }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let c = ServingConfig::parse(
            r#"{"listen": "0.0.0.0:9000", "max_wait_us": 1500,
                "queue_depth": 64, "models": ["c_bh", "segmenter"]}"#,
        )
        .unwrap();
        assert_eq!(c.listen, "0.0.0.0:9000");
        assert_eq!(c.max_wait, Duration::from_micros(1500));
        assert_eq!(c.queue_depth, 64);
        assert_eq!(c.models, vec!["c_bh", "segmenter"]);
    }

    #[test]
    fn defaults_fill_in() {
        let c = ServingConfig::parse(r#"{"models": ["c_bh"]}"#).unwrap();
        assert_eq!(c.listen, "127.0.0.1:7878");
        assert_eq!(c.queue_depth, 1024);
    }

    #[test]
    fn engine_key_selects_kind() {
        let c = ServingConfig::parse(r#"{"models": ["c_bh"], "engine": "naive"}"#).unwrap();
        assert_eq!(c.engine, EngineKind::Naive);
        let d = ServingConfig::parse(r#"{"models": ["c_bh"]}"#).unwrap();
        assert_eq!(d.engine, EngineKind::preferred());
        assert!(ServingConfig::parse(r#"{"models": ["c_bh"], "engine": "jit"}"#).is_err());
    }

    #[test]
    fn workers_key_parses_and_defaults() {
        let c = ServingConfig::parse(r#"{"models": ["c_bh"], "workers": 7}"#).unwrap();
        assert_eq!(c.workers, 7);
        assert_eq!(c.coordinator_config().workers, 7);
        let d = ServingConfig::parse(r#"{"models": ["c_bh"]}"#).unwrap();
        assert_eq!(d.workers, default_workers());
        assert!(d.workers >= 1 && d.workers <= 4);
        // 0 would mean "no execution lane"; clamp to 1
        let z = ServingConfig::parse(r#"{"models": ["c_bh"], "workers": 0}"#).unwrap();
        assert_eq!(z.workers, 1);
    }

    #[test]
    fn intra_threads_key_parses_and_defaults() {
        let c =
            ServingConfig::parse(r#"{"models": ["c_bh"], "intra_threads": 4}"#).unwrap();
        assert_eq!(c.intra_threads, 4);
        assert_eq!(c.coordinator_config().intra_threads, 4);
        let d = ServingConfig::parse(r#"{"models": ["c_bh"]}"#).unwrap();
        assert_eq!(d.intra_threads, 1);
        // 0 would disable the kernels' band loop entirely; clamp to 1
        let z = ServingConfig::parse(r#"{"models": ["c_bh"], "intra_threads": 0}"#).unwrap();
        assert_eq!(z.intra_threads, 1);
    }

    #[test]
    fn weight_dtype_key_parses_and_defaults() {
        let c =
            ServingConfig::parse(r#"{"models": ["c_bh"], "weight_dtype": "i8"}"#).unwrap();
        assert_eq!(c.weight_dtype, WeightDtype::I8);
        assert_eq!(c.coordinator_config().weight_dtype, WeightDtype::I8);
        let b =
            ServingConfig::parse(r#"{"models": ["c_bh"], "weight_dtype": "bf16"}"#).unwrap();
        assert_eq!(b.weight_dtype, WeightDtype::Bf16);
        let d = ServingConfig::parse(r#"{"models": ["c_bh"]}"#).unwrap();
        assert_eq!(d.weight_dtype, WeightDtype::F32);
        assert!(
            ServingConfig::parse(r#"{"models": ["c_bh"], "weight_dtype": "fp8"}"#).is_err()
        );
    }

    #[test]
    fn admission_keys_parse_and_default() {
        let c = ServingConfig::parse(
            r#"{"models": ["c_bh"], "max_inflight": 128, "slo_p99_ms": 12.5}"#,
        )
        .unwrap();
        assert_eq!(c.max_inflight, 128);
        assert!((c.slo_p99_ms - 12.5).abs() < 1e-12);
        let o = c.tcp_options();
        assert_eq!(o.max_inflight, 128);
        assert!((o.slo_p99_ms - 12.5).abs() < 1e-12);

        let d = ServingConfig::parse(r#"{"models": ["c_bh"]}"#).unwrap();
        assert_eq!(d.max_inflight, 4096);
        assert_eq!(d.slo_p99_ms, 0.0);

        // 0 is meaningful for both: unlimited in-flight, SLO disabled
        let z = ServingConfig::parse(
            r#"{"models": ["c_bh"], "max_inflight": 0, "slo_p99_ms": 0}"#,
        )
        .unwrap();
        assert_eq!(z.max_inflight, 0);
        assert_eq!(z.slo_p99_ms, 0.0);

        assert!(ServingConfig::parse(r#"{"models": ["c_bh"], "slo_p99_ms": -1}"#).is_err());
    }

    #[test]
    fn cache_dir_key_parses_and_defaults() {
        let c = ServingConfig::parse(
            r#"{"models": ["c_bh"], "cache_dir": "/tmp/compiled-nn-cache"}"#,
        )
        .unwrap();
        assert_eq!(c.cache_dir.as_deref(), Some("/tmp/compiled-nn-cache"));
        let d = ServingConfig::parse(r#"{"models": ["c_bh"]}"#).unwrap();
        assert_eq!(d.cache_dir, None);
    }

    #[test]
    fn rejects_empty_models() {
        assert!(ServingConfig::parse(r#"{"models": []}"#).is_err());
        assert!(ServingConfig::parse(r#"{}"#).is_err());
        assert!(ServingConfig::parse("nope").is_err());
    }
}
