//! A minimal readiness shim over `poll(2)` for the event-loop TCP front
//! end — no mio/tokio in the offline build, just one libc call declared by
//! hand. Level-triggered: an entry reports readable/writable as long as
//! the condition holds, which pairs naturally with nonblocking sockets
//! drained until `WouldBlock`.
//!
//! On non-unix targets the shim degrades to "sleep briefly, report
//! everything ready": with nonblocking sockets a spurious readiness is
//! harmless (the read/write just returns `WouldBlock`), so the event loop
//! stays correct and merely burns a few syscalls per tick.

use std::io;
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

#[cfg(unix)]
use std::os::unix::io::{AsRawFd, RawFd};

/// Anything the shim can wait on. On unix this is "has a raw fd"; the
/// non-unix fallback needs nothing (everything is always "ready").
pub trait Pollable {
    /// The raw file descriptor `poll(2)` watches.
    #[cfg(unix)]
    fn raw_fd(&self) -> RawFd;
}

impl Pollable for TcpStream {
    #[cfg(unix)]
    fn raw_fd(&self) -> RawFd {
        self.as_raw_fd()
    }
}

impl Pollable for TcpListener {
    #[cfg(unix)]
    fn raw_fd(&self) -> RawFd {
        self.as_raw_fd()
    }
}

/// One waited-on socket: interest in (input parameters to [`poll`]) and
/// readiness out. Rebuilt per poll round — it's three words; the win from
/// persisting interest sets is epoll territory, deliberately out of scope.
pub struct PollEntry {
    #[cfg(unix)]
    fd: RawFd,
    want_write: bool,
    /// Out: the socket has bytes to read (or an error/hangup to observe —
    /// reading surfaces it, which is how the loop learns of closes).
    pub readable: bool,
    /// Out: the socket would accept a write.
    pub writable: bool,
    /// Out: the peer hung up or the socket errored.
    pub hangup: bool,
}

impl PollEntry {
    /// Watch `source` for readability, and for writability too when
    /// `want_write` (set only while a write buffer is non-empty, else
    /// level-triggered POLLOUT busy-spins the loop).
    pub fn new(source: &impl Pollable, want_write: bool) -> PollEntry {
        #[cfg(not(unix))]
        let _ = source;
        PollEntry {
            #[cfg(unix)]
            fd: source.raw_fd(),
            want_write,
            readable: false,
            writable: false,
            hangup: false,
        }
    }
}

/// Block until at least one entry is ready or `timeout` elapses, filling
/// each entry's readiness flags. Returns the number of ready entries
/// (0 on timeout). EINTR retries internally.
pub fn poll(entries: &mut [PollEntry], timeout: Duration) -> io::Result<usize> {
    sys::poll_impl(entries, timeout)
}

#[cfg(unix)]
mod sys {
    use super::PollEntry;
    use std::io;
    use std::os::raw::{c_int, c_short};
    use std::time::Duration;

    /// `struct pollfd` from `<poll.h>` — identical layout on every unix
    /// libc this builds against.
    #[repr(C)]
    struct PollFd {
        fd: c_int,
        events: c_short,
        revents: c_short,
    }

    const POLLIN: c_short = 0x001;
    const POLLOUT: c_short = 0x004;
    const POLLERR: c_short = 0x008;
    const POLLHUP: c_short = 0x010;
    const POLLNVAL: c_short = 0x020;

    #[cfg(target_os = "linux")]
    type NFds = std::os::raw::c_ulong;
    #[cfg(not(target_os = "linux"))]
    type NFds = std::os::raw::c_uint;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: NFds, timeout: c_int) -> c_int;
    }

    pub fn poll_impl(entries: &mut [PollEntry], timeout: Duration) -> io::Result<usize> {
        let timeout_ms = timeout.as_millis().min(i32::MAX as u128) as c_int;
        let mut fds: Vec<PollFd> = entries
            .iter()
            .map(|e| PollFd {
                fd: e.fd,
                events: POLLIN | if e.want_write { POLLOUT } else { 0 },
                revents: 0,
            })
            .collect();
        loop {
            // SAFETY: `fds` is a live, correctly-sized buffer of
            // `#[repr(C)]` pollfd structs; poll(2) writes only `revents`.
            let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as NFds, timeout_ms) };
            if rc < 0 {
                let err = io::Error::last_os_error();
                if err.kind() == io::ErrorKind::Interrupted {
                    continue; // EINTR: retry (timeout precision is advisory)
                }
                return Err(err);
            }
            let mut ready = 0;
            for (e, f) in entries.iter_mut().zip(&fds) {
                // Fold errors into readable: the next read returns the
                // error (or EOF), which is exactly how the loop handles it.
                e.readable = f.revents & (POLLIN | POLLERR | POLLHUP | POLLNVAL) != 0;
                e.writable = f.revents & (POLLOUT | POLLERR | POLLNVAL) != 0;
                e.hangup = f.revents & (POLLERR | POLLHUP | POLLNVAL) != 0;
                if f.revents != 0 {
                    ready += 1;
                }
            }
            return Ok(ready);
        }
    }
}

#[cfg(not(unix))]
mod sys {
    use super::PollEntry;
    use std::io;
    use std::time::Duration;

    /// Degraded mode: nap briefly, then report everything ready. Spurious
    /// readiness is safe — nonblocking reads/writes just `WouldBlock`.
    pub fn poll_impl(entries: &mut [PollEntry], timeout: Duration) -> io::Result<usize> {
        std::thread::sleep(timeout.min(Duration::from_millis(1)));
        for e in entries.iter_mut() {
            e.readable = true;
            e.writable = e.want_write;
            e.hangup = false;
        }
        Ok(entries.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};

    #[test]
    fn listener_becomes_readable_on_pending_accept() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();

        // nothing pending: poll times out
        let mut entries = [PollEntry::new(&listener, false)];
        let n = poll(&mut entries, Duration::from_millis(10)).unwrap();
        #[cfg(unix)]
        {
            assert_eq!(n, 0);
            assert!(!entries[0].readable);
        }
        let _ = n;

        // a connect makes the listener readable within the timeout
        let _client = TcpStream::connect(addr).unwrap();
        let mut entries = [PollEntry::new(&listener, false)];
        let n = poll(&mut entries, Duration::from_millis(2000)).unwrap();
        assert!(n >= 1);
        assert!(entries[0].readable);
    }

    #[test]
    fn stream_readability_follows_data() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();

        client.write_all(b"ping").unwrap();
        client.flush().unwrap();
        let mut entries = [PollEntry::new(&server, true)];
        let n = poll(&mut entries, Duration::from_millis(2000)).unwrap();
        assert!(n >= 1);
        assert!(entries[0].readable, "pending bytes → readable");
        assert!(entries[0].writable, "empty send buffer → writable");

        let mut buf = [0u8; 16];
        let got = (&server).read(&mut buf).unwrap();
        assert_eq!(&buf[..got], b"ping");
    }

    #[cfg(unix)]
    #[test]
    fn peer_close_reports_readable_hangup() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        drop(client);

        // give the FIN a moment, then poll: must be readable (EOF) —
        // exactly the signal the event loop uses to reap the connection
        let mut entries = [PollEntry::new(&server, false)];
        let n = poll(&mut entries, Duration::from_millis(2000)).unwrap();
        assert!(n >= 1);
        assert!(entries[0].readable);
    }
}
