//! TCP serving front end: newline-delimited JSON requests over plain
//! sockets (std::net — tokio is unavailable offline), served by a single
//! nonblocking, readiness-driven I/O thread over the `poll(2)` shim in
//! [`super::poll`].
//!
//! One `tcp-io` thread owns the listener and every connection. Each
//! connection is a small state machine: a recycled read buffer that
//! complete request lines are parsed straight out of, and a write buffer
//! that finished responses are appended to and drained as the socket
//! accepts them — no per-line `flush()`, no thread per connection.
//! Connections are **pipelined**: a client may write any number of
//! requests before reading; responses stream back in completion order
//! (the batcher packs concurrent requests from *all* connections into
//! shared buckets, and batches finish out of order), correlated by `id`.
//!
//! Completed inferences re-enter the loop through a completion channel:
//! the per-request reply callback (executed on whichever worker finished
//! the batch) serializes the response, sends `(connection token, line)`
//! over the channel, and wakes the poll via a loopback socket pair.
//!
//! Admission control sheds with a structured `overloaded` error (see the
//! protocol docs) in three cases: the model's bounded queue is full, the
//! global in-flight cap is reached, or the model's p99 latency over the
//! current SLO window exceeds the configured SLO. Shed requests are never
//! executed and are counted in [`TcpStats`] and `ModelMetrics::shed`.
//!
//! `shutdown()` closes every socket — including idle connections parked
//! in the poll set — and joins the I/O thread; nothing leaks past it.

use std::collections::{HashMap, HashSet};
use std::io::{self, BufRead, BufReader, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::nn::tensor::Tensor;

use super::poll::{poll, PollEntry};
use super::protocol::{salvage_id, Request, Response};
use super::server::{Coordinator, ModelClient, ReplyFn, SubmitOutcome};

/// Upper bound on one poll wait: bounds shutdown latency even if a wake
/// byte is lost, and paces the SLO-window refresh.
const POLL_TICK: Duration = Duration::from_millis(50);

/// How often the per-model SLO latency windows are inspected and reset.
const SLO_REFRESH: Duration = Duration::from_millis(250);

/// Longest accepted request line; a connection exceeding it is dropped
/// (it is either broken or hostile — there is no frame to resync to).
const MAX_LINE: usize = 8 << 20;

/// Read chunk size per `read()` call on a readable socket.
const READ_CHUNK: usize = 64 * 1024;

/// Front-end admission-control knobs (`ServingConfig::tcp_options`).
#[derive(Debug, Clone)]
pub struct TcpOptions {
    /// Global cap on requests admitted but not yet answered, across all
    /// connections and models; past it, new requests shed with
    /// `overloaded`. 0 = unlimited.
    pub max_inflight: u64,
    /// Per-model p99 latency SLO in milliseconds, measured over the
    /// current SLO window (`ModelMetrics::latency_window`, reset every
    /// [`SLO_REFRESH`]); while a model's windowed p99 exceeds it, new
    /// requests for that model shed. 0 disables SLO shedding.
    pub slo_p99_ms: f64,
}

impl Default for TcpOptions {
    fn default() -> Self {
        Self { max_inflight: 4096, slo_p99_ms: 0.0 }
    }
}

/// Live front-end counters, shared between the I/O thread and callers.
#[derive(Default)]
pub struct TcpStats {
    active: AtomicU64,
    total: AtomicU64,
    shed: AtomicU64,
    inflight: AtomicU64,
}

impl TcpStats {
    /// Connections currently open.
    pub fn active_connections(&self) -> u64 {
        self.active.load(Ordering::Relaxed)
    }
    /// Connections ever accepted (monotonic).
    pub fn total_connections(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }
    /// Requests shed by admission control (queue full / in-flight cap /
    /// SLO breach) with a structured `overloaded` response.
    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }
    /// Requests admitted but not yet answered.
    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed)
    }
    /// One-line render for the `serve` report.
    pub fn render(&self) -> String {
        format!(
            "tcp: active_connections {}, total_connections {}, inflight {}, shed {}",
            self.active_connections(),
            self.total_connections(),
            self.inflight(),
            self.shed(),
        )
    }
}

/// Decrements the global in-flight gauge exactly once, whether the reply
/// callback carrying it runs or is dropped un-invoked (teardown).
struct InflightGuard(Arc<TcpStats>);

impl InflightGuard {
    /// Try to admit one request under `cap` (0 = unlimited).
    fn admit(stats: &Arc<TcpStats>, cap: u64) -> Option<InflightGuard> {
        let prev = stats.inflight.fetch_add(1, Ordering::Relaxed);
        if cap != 0 && prev >= cap {
            stats.inflight.fetch_sub(1, Ordering::Relaxed);
            return None;
        }
        Some(InflightGuard(stats.clone()))
    }
}

impl Drop for InflightGuard {
    fn drop(&mut self) {
        self.0.inflight.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Wakes the I/O thread's poll from any thread: one byte down a loopback
/// socket pair (portable — no self-pipe or eventfd needed). Nonblocking;
/// a full pipe means a wake is already pending, which is just as good.
#[derive(Clone)]
struct WakeHandle {
    tx: Arc<TcpStream>,
}

impl WakeHandle {
    fn wake(&self) {
        let _ = (&*self.tx).write(&[1u8]);
    }
}

/// Build the waker socket pair (write side, read side).
fn wake_pair() -> Result<(TcpStream, TcpStream)> {
    let listener = TcpListener::bind("127.0.0.1:0").context("binding waker listener")?;
    let addr = listener.local_addr()?;
    let tx = TcpStream::connect(addr).context("connecting waker")?;
    let (rx, _) = listener.accept().context("accepting waker")?;
    tx.set_nonblocking(true)?;
    tx.set_nodelay(true).ok();
    rx.set_nonblocking(true)?;
    Ok((tx, rx))
}

/// Per-connection request-side state, split from the socket + read buffer
/// so parsed lines (borrowing `rbuf`) and state mutation can coexist.
struct ConnState {
    /// Responses not yet fully written; `wpos` is the sent prefix.
    wbuf: Vec<u8>,
    wpos: usize,
    /// Requests admitted to the coordinator whose responses have not yet
    /// come back over the completion channel.
    pending: usize,
    /// Read half hit EOF: drain remaining responses, then drop.
    peer_closed: bool,
    /// Model-resolution caches (per connection, same policy as the old
    /// thread-per-connection server): resolved clients, and failed names
    /// remembered with the registry epoch so a misspelled model costs one
    /// lookup per registry change, not one per request.
    clients: HashMap<String, ModelClient>,
    failed: HashMap<String, (u64, String)>,
}

impl ConnState {
    fn new() -> ConnState {
        ConnState {
            wbuf: Vec::new(),
            wpos: 0,
            pending: 0,
            peer_closed: false,
            clients: HashMap::new(),
            failed: HashMap::new(),
        }
    }

    fn push_response(&mut self, resp: &Response) {
        self.wbuf.extend_from_slice(resp.to_line().as_bytes());
        self.wbuf.push(b'\n');
    }

    /// All responses delivered and written, peer gone: safe to drop.
    fn drained(&self) -> bool {
        self.peer_closed && self.pending == 0 && self.wpos == self.wbuf.len()
    }
}

/// One live connection owned by the I/O thread.
struct Conn {
    stream: TcpStream,
    /// Unparsed request bytes (recycled: complete lines are parsed out and
    /// the tail is compacted in place).
    rbuf: Vec<u8>,
    state: ConnState,
}

/// Shared context of the I/O thread, passed alongside the connection map
/// (separate so a `&mut Conn` and `&mut Io` can be held at once).
struct Io {
    coord: Arc<Coordinator>,
    stats: Arc<TcpStats>,
    opts: TcpOptions,
    done_tx: Sender<(u64, String)>,
    wake: WakeHandle,
    /// Models currently shedding because their windowed p99 exceeds the
    /// SLO; refreshed every [`SLO_REFRESH`].
    slo_shed: HashSet<String>,
}

/// The event-loop TCP server handle.
pub struct TcpServer {
    addr: SocketAddr,
    stopping: Arc<AtomicBool>,
    io_thread: Option<std::thread::JoinHandle<()>>,
    wake: WakeHandle,
    /// Live front-end counters (connections, in-flight, shed).
    pub stats: Arc<TcpStats>,
}

impl TcpServer {
    /// Bind and start serving with default [`TcpOptions`]. Models are
    /// resolved **lazily per request** (with a per-connection cache), so
    /// anything registered on the coordinator after the server starts —
    /// or registrable from the manifest — is immediately servable.
    pub fn start(coord: Arc<Coordinator>, bind: &str) -> Result<TcpServer> {
        Self::start_with(coord, bind, TcpOptions::default())
    }

    /// [`start`](Self::start) with explicit admission-control options.
    pub fn start_with(
        coord: Arc<Coordinator>,
        bind: &str,
        opts: TcpOptions,
    ) -> Result<TcpServer> {
        let listener = TcpListener::bind(bind).with_context(|| format!("binding {bind}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stopping = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(TcpStats::default());
        let (wake_tx, wake_rx) = wake_pair()?;
        let wake = WakeHandle { tx: Arc::new(wake_tx) };
        let (done_tx, done_rx) = mpsc::channel::<(u64, String)>();

        let io = Io {
            coord,
            stats: stats.clone(),
            opts,
            done_tx,
            wake: wake.clone(),
            slo_shed: HashSet::new(),
        };
        let stop2 = stopping.clone();
        let io_thread = std::thread::Builder::new()
            .name("tcp-io".into())
            .spawn(move || io_main(io, listener, wake_rx, done_rx, stop2))
            .context("spawning tcp-io thread")?;

        Ok(TcpServer { addr, stopping, io_thread: Some(io_thread), wake, stats })
    }

    /// The bound address (useful with a `:0` bind).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop serving: closes the listener and **every** connection —
    /// including idle ones parked in the poll set — and joins the I/O
    /// thread. Responses already in flight from the coordinator may be
    /// dropped (their callbacks write into a closed completion channel).
    /// Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        self.stopping.store(true, Ordering::SeqCst);
        self.wake.wake();
        if let Some(h) = self.io_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The I/O thread: accept, read, parse, admit, and write — all driven by
/// one poll set, never blocking on any single socket.
fn io_main(
    mut io: Io,
    listener: TcpListener,
    wake_rx: TcpStream,
    done_rx: Receiver<(u64, String)>,
    stopping: Arc<AtomicBool>,
) {
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_token: u64 = 1;
    let mut scratch = vec![0u8; READ_CHUNK];
    let mut last_slo = Instant::now();

    while !stopping.load(Ordering::SeqCst) {
        // Deliver finished inferences into their connections' write
        // buffers (responses for connections that died in the meantime
        // are dropped — the peer is gone).
        while let Ok((token, line)) = done_rx.try_recv() {
            if let Some(conn) = conns.get_mut(&token) {
                conn.state.pending = conn.state.pending.saturating_sub(1);
                conn.state.wbuf.extend_from_slice(line.as_bytes());
                conn.state.wbuf.push(b'\n');
            }
        }

        // Opportunistic flush: most responses fit the socket buffer, so
        // they leave on this round instead of waiting one poll for the
        // writability report.
        let mut dead: Vec<u64> = Vec::new();
        for (&token, conn) in conns.iter_mut() {
            if conn.state.wpos < conn.state.wbuf.len() && flush_writes(conn).is_err() {
                dead.push(token);
            }
        }
        reap(&mut conns, &mut dead, &io.stats);

        if io.opts.slo_p99_ms > 0.0 && last_slo.elapsed() >= SLO_REFRESH {
            refresh_slo(&mut io);
            last_slo = Instant::now();
        }

        // Poll set: [listener, waker, connections…].
        let mut entries = Vec::with_capacity(conns.len() + 2);
        let mut tokens = Vec::with_capacity(conns.len());
        entries.push(PollEntry::new(&listener, false));
        entries.push(PollEntry::new(&wake_rx, false));
        for (&token, conn) in conns.iter() {
            tokens.push(token);
            entries.push(PollEntry::new(&conn.stream, conn.state.wpos < conn.state.wbuf.len()));
        }
        if poll(&mut entries, POLL_TICK).is_err() {
            // A torn-down fd (racing close) yields one failed round; the
            // next rebuild drops it. Avoid a hot error loop regardless.
            std::thread::sleep(Duration::from_millis(1));
            continue;
        }

        if entries[0].readable {
            accept_ready(&listener, &mut conns, &mut next_token, &io.stats);
        }
        if entries[1].readable {
            // Drain wake bytes; the actual work happens above/below.
            while matches!((&wake_rx).read(&mut scratch), Ok(n) if n > 0) {}
        }

        let mut dead: Vec<u64> = Vec::new();
        for (i, &token) in tokens.iter().enumerate() {
            let entry = &entries[i + 2];
            let Some(conn) = conns.get_mut(&token) else { continue };
            if entry.readable
                && !conn.state.peer_closed
                && read_ready(&mut io, token, conn, &mut scratch).is_err()
            {
                dead.push(token);
                continue;
            }
            if (entry.writable || conn.state.wpos < conn.state.wbuf.len())
                && flush_writes(conn).is_err()
            {
                dead.push(token);
                continue;
            }
            if entry.hangup && conn.state.drained() {
                dead.push(token);
            }
        }
        reap(&mut conns, &mut dead, &io.stats);

        // Graceful closes: peer sent EOF and everything owed is delivered.
        let mut done: Vec<u64> =
            conns.iter().filter(|(_, c)| c.state.drained()).map(|(&t, _)| t).collect();
        reap(&mut conns, &mut done, &io.stats);
    }

    // Teardown: closing the sockets here (by dropping them) is what lets
    // `shutdown()` guarantee no connection outlives it.
    for _ in conns.drain() {
        io.stats.active.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Accept every pending connection (level-triggered readiness).
fn accept_ready(
    listener: &TcpListener,
    conns: &mut HashMap<u64, Conn>,
    next_token: &mut u64,
    stats: &Arc<TcpStats>,
) {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                stream.set_nodelay(true).ok();
                let token = *next_token;
                *next_token += 1;
                conns.insert(token, Conn { stream, rbuf: Vec::new(), state: ConnState::new() });
                stats.active.fetch_add(1, Ordering::Relaxed);
                stats.total.fetch_add(1, Ordering::Relaxed);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return,
        }
    }
}

/// Remove dead connections, keeping the active gauge exact.
fn reap(conns: &mut HashMap<u64, Conn>, dead: &mut Vec<u64>, stats: &Arc<TcpStats>) {
    for token in dead.drain(..) {
        if conns.remove(&token).is_some() {
            stats.active.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

/// Drain a readable socket into the connection's read buffer and process
/// every complete request line in it. An `Err` means the connection is
/// broken (or abusive: an unterminated line past [`MAX_LINE`]) and must
/// be dropped.
fn read_ready(io: &mut Io, token: u64, conn: &mut Conn, scratch: &mut [u8]) -> io::Result<()> {
    loop {
        match (&conn.stream).read(scratch) {
            Ok(0) => {
                conn.state.peer_closed = true;
                process_buffer(io, token, conn)?;
                return Ok(());
            }
            Ok(n) => {
                conn.rbuf.extend_from_slice(&scratch[..n]);
                process_buffer(io, token, conn)?;
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(()),
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
}

/// Parse complete lines straight out of the read buffer, then compact the
/// unparsed tail to the front (the buffer is recycled across reads).
fn process_buffer(io: &mut Io, token: u64, conn: &mut Conn) -> io::Result<()> {
    let mut start = 0;
    while let Some(pos) = conn.rbuf[start..].iter().position(|&b| b == b'\n') {
        let end = start + pos;
        match std::str::from_utf8(&conn.rbuf[start..end]) {
            Ok(line) => {
                let line = line.trim();
                if !line.is_empty() {
                    process_line(io, token, line, &mut conn.state);
                }
            }
            Err(_) => {
                conn.state.push_response(&Response::err(0, "bad request: line is not UTF-8"));
            }
        }
        start = end + 1;
    }
    if start > 0 {
        conn.rbuf.drain(..start);
    }
    if conn.rbuf.len() > MAX_LINE {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "request line exceeds the size limit",
        ));
    }
    Ok(())
}

/// One request line: parse, resolve the model, admission-check, and
/// either hand it to the batcher (reply comes back over the completion
/// channel) or append an error/shed response directly.
fn process_line(io: &mut Io, token: u64, line: &str, state: &mut ConnState) {
    let req = match Request::parse(line) {
        Ok(r) => r,
        Err(e) => {
            // Salvage the id when the line is JSON with a sane `id`, so
            // pipelining clients can still correlate; 0 = unattributable.
            state.push_response(&Response::err(salvage_id(line), format!("bad request: {e}")));
            return;
        }
    };
    if !state.clients.contains_key(&req.model) {
        if let Some((epoch, error)) = state.failed.get(&req.model) {
            if *epoch == io.coord.registration_epoch() {
                state.push_response(&Response::err(req.id, error.clone()));
                return;
            }
        }
        // Epoch sampled *before* the attempt: if a registration races in
        // after the failure, the cached epoch is stale and we retry.
        let epoch = io.coord.registration_epoch();
        match io.coord.register(&req.model) {
            Ok(c) => {
                state.failed.remove(&req.model);
                state.clients.insert(req.model.clone(), c);
            }
            Err(e) => {
                let error = format!("model `{}` not registered ({e})", req.model);
                // bounded: a client cycling through unique bad names must
                // not grow this map forever; clearing only costs a retry
                if state.failed.len() >= 64 {
                    state.failed.clear();
                }
                state.failed.insert(req.model.clone(), (epoch, error.clone()));
                state.push_response(&Response::err(req.id, error));
                return;
            }
        }
    }
    let client = &state.clients[&req.model];
    let item: usize = client.info.input_shape.iter().product();
    if req.input.len() != item {
        state.push_response(&Response::err(
            req.id,
            format!("input has {} floats, model needs {item}", req.input.len()),
        ));
        return;
    }

    // Admission control, cheapest check first. Every shed is structured
    // (`code: "overloaded"`) and counted; the request is never executed.
    if io.slo_shed.contains(&req.model) {
        client.metrics.shed.add(1);
        io.stats.shed.fetch_add(1, Ordering::Relaxed);
        state.push_response(&Response::overloaded(
            req.id,
            format!("model `{}` over its p99 latency SLO; retry later", req.model),
        ));
        return;
    }
    let Some(guard) = InflightGuard::admit(&io.stats, io.opts.max_inflight) else {
        client.metrics.shed.add(1);
        io.stats.shed.fetch_add(1, Ordering::Relaxed);
        state.push_response(&Response::overloaded(
            req.id,
            format!("server at its in-flight cap ({}); retry later", io.opts.max_inflight),
        ));
        return;
    };

    let id = req.id;
    let done_tx = io.done_tx.clone();
    let wake = io.wake.clone();
    let reply: ReplyFn = Box::new(move |result: anyhow::Result<Tensor>| {
        // Serialize on the execution thread (keeps the I/O thread lean),
        // then hand the finished line to the event loop and wake it.
        let resp = match result {
            Ok(out) => Response::ok(id, &out),
            Err(e) => Response::err(id, e.to_string()),
        };
        // Settle the in-flight gauge *before* publishing the response:
        // anyone who has seen the reply sees the slot free too. The guard
        // still settles on the un-invoked path via its Drop.
        drop(guard);
        let _ = done_tx.send((token, resp.to_line()));
        wake.wake();
    });
    let input = Tensor::from_vec(&client.info.input_shape.clone(), req.input);
    match client.try_submit(input, reply) {
        SubmitOutcome::Accepted => {
            state.pending += 1;
        }
        SubmitOutcome::Full(reply) => {
            client.metrics.shed.add(1);
            io.stats.shed.fetch_add(1, Ordering::Relaxed);
            state.push_response(&Response::overloaded(
                req.id,
                format!("queue full for model `{}`; retry later", req.model),
            ));
            drop(reply); // un-invoked: the guard inside settles the gauge
        }
        SubmitOutcome::Closed(reply) => {
            state.push_response(&Response::err(req.id, "coordinator is shutting down"));
            drop(reply);
        }
    }
}

/// Write as much buffered response data as the socket accepts.
fn flush_writes(conn: &mut Conn) -> io::Result<()> {
    while conn.state.wpos < conn.state.wbuf.len() {
        match (&conn.stream).write(&conn.state.wbuf[conn.state.wpos..]) {
            Ok(0) => return Err(io::ErrorKind::WriteZero.into()),
            Ok(n) => conn.state.wpos += n,
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    if conn.state.wpos == conn.state.wbuf.len() {
        conn.state.wbuf.clear();
        conn.state.wpos = 0;
    } else if conn.state.wpos > READ_CHUNK {
        // keep the buffer from growing unboundedly under a slow reader
        conn.state.wbuf.drain(..conn.state.wpos);
        conn.state.wpos = 0;
    }
    Ok(())
}

/// Inspect every model's SLO latency window: models whose windowed p99
/// exceeds the SLO shed until the next refresh. Windows are reset each
/// time, so recovery is automatic once latency subsides. A handful of
/// samples is required before shedding — one slow cold-start request
/// must not blackhole a model.
fn refresh_slo(io: &mut Io) {
    io.slo_shed.clear();
    for (name, m) in io.coord.model_metrics() {
        let samples = m.latency_window.count();
        let p99_ms = m.latency_window.quantile_us(0.99) as f64 / 1000.0;
        if samples >= 8 && p99_ms > io.opts.slo_p99_ms {
            io.slo_shed.insert(name);
        }
        m.latency_window.reset();
    }
}

/// Minimal blocking client for the wire protocol (used by the CLI `client`
/// command, the load generator, and the integration tests). Supports
/// pipelining: `send` queues request lines, `flush` pushes them out, and
/// `recv` reads responses back in the server's completion order.
pub struct TcpClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    next_id: u64,
}

impl TcpClient {
    pub fn connect(addr: &str) -> Result<TcpClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(TcpClient {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
            next_id: 1,
        })
    }

    /// Queue one request line (buffered; `flush` to actually send) and
    /// return its auto-assigned id for correlating the pipelined reply.
    pub fn send(&mut self, model: &str, input: Vec<f32>) -> Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let req = Request { id, model: model.into(), input };
        self.writer.write_all(req.to_line().as_bytes())?;
        self.writer.write_all(b"\n")?;
        Ok(id)
    }

    /// Push every queued request line to the socket.
    pub fn flush(&mut self) -> Result<()> {
        self.writer.flush()?;
        Ok(())
    }

    /// Block for the next response line (the server's completion order,
    /// not send order — correlate by id). Errors once the server closes
    /// the connection.
    pub fn recv(&mut self) -> Result<Response> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        if n == 0 {
            anyhow::bail!("server closed connection");
        }
        Response::parse(&line)
    }

    /// One blocking request/response round-trip.
    pub fn infer(&mut self, model: &str, input: Vec<f32>) -> Result<Tensor> {
        let id = self.send(model, input)?;
        self.flush()?;
        match self.recv()? {
            Response::Ok { id: rid, shape, output } => {
                anyhow::ensure!(rid == id, "response id mismatch");
                Ok(Tensor::from_vec(&shape, output))
            }
            Response::Err { error, .. } => anyhow::bail!("server error: {error}"),
        }
    }
}
