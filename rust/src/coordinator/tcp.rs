//! TCP serving front end: newline-delimited JSON requests over plain
//! sockets (std::net — tokio is unavailable offline; a thread per
//! connection matches the deployment scale of the paper's robot anyway).
//!
//! Each connection thread parses requests, routes them to the registered
//! `ModelClient` (the dynamic batcher then packs concurrent requests from
//! *all* connections into shared buckets), and streams responses back in
//! completion order per connection.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::nn::tensor::Tensor;

use super::protocol::{Request, Response};
use super::server::{Coordinator, ModelClient};

pub struct TcpServer {
    addr: SocketAddr,
    stopping: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    pub connections: Arc<AtomicU64>,
}

impl TcpServer {
    /// Bind and start accepting. Models are resolved **lazily per
    /// request** (with a per-connection cache), so anything registered on
    /// the coordinator after the server starts — or registrable from the
    /// manifest — is immediately servable; a startup snapshot would return
    /// "unknown model" forever for late registrations.
    pub fn start(coord: Arc<Coordinator>, bind: &str) -> Result<TcpServer> {
        let listener = TcpListener::bind(bind).with_context(|| format!("binding {bind}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stopping = Arc::new(AtomicBool::new(false));
        let connections = Arc::new(AtomicU64::new(0));

        let stop2 = stopping.clone();
        let conns2 = connections.clone();
        let accept_thread = std::thread::Builder::new()
            .name("tcp-accept".into())
            .spawn(move || loop {
                if stop2.load(Ordering::SeqCst) {
                    return;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        conns2.fetch_add(1, Ordering::Relaxed);
                        let coord = coord.clone();
                        let stop3 = stop2.clone();
                        let _ = std::thread::Builder::new()
                            .name("tcp-conn".into())
                            .spawn(move || {
                                let _ = serve_connection(stream, &coord, &stop3);
                            });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => return,
                }
            })?;

        Ok(TcpServer { addr, stopping, accept_thread: Some(accept_thread), connections })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn shutdown(&mut self) {
        self.stopping.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn serve_connection(
    stream: TcpStream,
    coord: &Arc<Coordinator>,
    stopping: &AtomicBool,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    // Per-connection caches: resolved clients (the coordinator round-trip
    // — a registry lock + possibly an engine build — happens once per
    // (connection, model)) and failed names, remembered with the registry
    // epoch so a misspelled model costs one lookup per registry change,
    // not one per request, while late registrations are still picked up.
    let mut clients: HashMap<String, ModelClient> = HashMap::new();
    let mut failed: HashMap<String, (u64, String)> = HashMap::new();
    for line in reader.lines() {
        if stopping.load(Ordering::SeqCst) {
            break;
        }
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = handle_line(&line, coord, &mut clients, &mut failed);
        writer.write_all(resp.to_line().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
    Ok(())
}

fn handle_line(
    line: &str,
    coord: &Arc<Coordinator>,
    clients: &mut HashMap<String, ModelClient>,
    failed: &mut HashMap<String, (u64, String)>,
) -> Response {
    let req = match Request::parse(line) {
        Ok(r) => r,
        Err(e) => return Response::Err { id: 0, error: format!("bad request: {e}") },
    };
    if !clients.contains_key(&req.model) {
        if let Some((epoch, error)) = failed.get(&req.model) {
            if *epoch == coord.registration_epoch() {
                return Response::Err { id: req.id, error: error.clone() };
            }
        }
        // Epoch sampled *before* the attempt: if a registration races in
        // after the failure, the cached epoch is stale and we retry.
        let epoch = coord.registration_epoch();
        match coord.register(&req.model) {
            Ok(c) => {
                failed.remove(&req.model);
                clients.insert(req.model.clone(), c);
            }
            Err(e) => {
                let error = format!("model `{}` not registered ({e})", req.model);
                // bounded: a client cycling through unique bad names must
                // not grow this map forever; clearing only costs a retry
                if failed.len() >= 64 {
                    failed.clear();
                }
                failed.insert(req.model.clone(), (epoch, error.clone()));
                return Response::Err { id: req.id, error };
            }
        }
    }
    let client = &clients[&req.model];
    let item: usize = client.info.input_shape.iter().product();
    if req.input.len() != item {
        return Response::Err {
            id: req.id,
            error: format!("input has {} floats, model needs {item}", req.input.len()),
        };
    }
    let x = Tensor::from_vec(&client.info.input_shape.clone(), req.input);
    match client.infer(x) {
        Ok(out) => Response::ok(req.id, &out),
        Err(e) => Response::Err { id: req.id, error: e.to_string() },
    }
}

/// Minimal blocking client for the wire protocol (used by the CLI `client`
/// command, the load generator, and the integration tests).
pub struct TcpClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    next_id: u64,
}

impl TcpClient {
    pub fn connect(addr: &str) -> Result<TcpClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(TcpClient {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
            next_id: 1,
        })
    }

    pub fn infer(&mut self, model: &str, input: Vec<f32>) -> Result<Tensor> {
        let id = self.next_id;
        self.next_id += 1;
        let req = Request { id, model: model.into(), input };
        self.writer.write_all(req.to_line().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        match Response::parse(&line)? {
            Response::Ok { id: rid, shape, output } => {
                anyhow::ensure!(rid == id, "response id mismatch");
                Ok(Tensor::from_vec(&shape, output))
            }
            Response::Err { error, .. } => anyhow::bail!("server error: {error}"),
        }
    }
}
