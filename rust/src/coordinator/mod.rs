//! L3 serving coordinator: model registry + compile cache front (via the
//! executor thread), dynamic batcher, metrics, TCP front end + config.
#[allow(missing_docs)]
pub mod batcher;
#[allow(missing_docs)]
pub mod config;
#[allow(missing_docs)]
pub mod metrics;
#[allow(missing_docs)]
pub mod poll;
#[allow(missing_docs)]
pub mod protocol;
pub mod server;
#[allow(missing_docs)]
pub mod tcp;
