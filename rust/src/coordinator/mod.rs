//! L3 serving coordinator: model registry + compile cache front (via the
//! executor thread), dynamic batcher, metrics, TCP front end + config.
pub mod batcher;
pub mod config;
pub mod metrics;
pub mod protocol;
pub mod server;
pub mod tcp;
