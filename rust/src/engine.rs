//! The unified engine abstraction: one trait over all three execution
//! paths and a registry that constructs them by [`EngineKind`].
//!
//! The paper's comparison (Table 1) only works if the naive interpreter,
//! the optimized interpreter and the PJRT-compiled runtime are swappable
//! behind one seam. Everything above the engines — the CLI, the serving
//! coordinator, the golden tests, the benches — selects engines through
//! [`build_engine`] / [`build_engine_from_spec`] instead of constructing
//! `NaiveInterp` / `OptInterp` / `CompiledModel` by hand:
//!
//! ```text
//! EngineKind::Naive     → nn::interp::NaiveInterp      (exact oracle)
//! EngineKind::Optimized → compiler::exec::OptInterp    (pre-lowered
//!                         compiler::program::Program — folded/fused/arena)
//! EngineKind::Compiled  → runtime::executor::CompiledEngine  (PJRT, `pjrt`
//!                         cargo feature; unavailable on plain runners)
//! ```
//!
//! Later scaling work (sharding, new backends, batching policies) plugs in
//! here: add a kind, implement [`Engine`], extend the registry match.

use std::any::Any;
use std::fmt;
use std::sync::{Arc, RwLock};

use anyhow::{bail, Result};

use crate::compiler::exec::CompileOptions;
use crate::compiler::program::PlanSummary;
use crate::model::load::load_model;
use crate::model::spec::ModelSpec;
use crate::nn::tensor::Tensor;
use crate::runtime::artifact::Manifest;

/// Opaque per-worker mutable state for a [`SharedInfer`] artifact (arena
/// pools, gather rows, …). The artifact that created it is the only one
/// that knows the concrete type; workers just own it and hand it back on
/// every call. `Send` so a worker thread can carry it; deliberately not
/// `Sync` — scratch belongs to exactly one worker.
pub struct WorkerScratch(Box<dyn Any + Send>);

impl WorkerScratch {
    /// Box a concrete scratch value (the artifact's own state type).
    pub fn new<T: Any + Send>(state: T) -> WorkerScratch {
        WorkerScratch(Box::new(state))
    }

    /// Downcast back to the concrete scratch type; `None` if this scratch
    /// came from a different artifact type.
    pub fn get_mut<T: Any + Send>(&mut self) -> Option<&mut T> {
        self.0.downcast_mut::<T>()
    }
}

/// A shared, immutable inference artifact: `infer_shared` takes `&self`
/// plus caller-owned scratch, so **one `Arc<dyn SharedInfer>` serves N
/// worker threads** — the paper's fixed lowered network as a concurrency
/// primitive. Engines opt in via [`Engine::shareable`]; per the RTNeural
/// observation, concurrency then costs one scratch allocation per worker,
/// never a second lowering.
pub trait SharedInfer: Send + Sync {
    /// Allocate this worker's mutable state, pre-sized (and pinned) for the
    /// serving batch buckets so steady-state inference is allocation-free.
    fn new_scratch(&self, buckets: &[usize]) -> WorkerScratch;

    /// Run a forward pass on a `[B, ...]` input over the worker's scratch.
    fn infer_shared(&self, input: &Tensor, scratch: &mut WorkerScratch) -> Result<Vec<Tensor>>;

    /// The lowered plan, if this artifact has one (tests/benches assert on
    /// it — e.g. that N workers report the *same* plan, lowered once).
    fn plan_summary(&self) -> Option<&PlanSummary> {
        None
    }
}

/// An epoch-versioned slot holding a model's **current** shared artifact —
/// the hot-swap primitive for live model re-registration.
///
/// The serving coordinator publishes one `Arc<SwapCell>` per pooled model.
/// Pool workers `load()` it per job and compare the epoch against the one
/// their scratch was built for; on a change they rebuild scratch and carry
/// on — no worker restarts, no queue teardown. `swap()` bumps the epoch
/// and replaces the artifact atomically (a short write lock; `load()` is a
/// clone under a read lock, so the swap never blocks inference for longer
/// than an `Arc` clone). The **old** artifact stays alive inside any job
/// already dispatched with it — in-flight batches drain on the old
/// version, new batches pick up the new one, and no request is lost.
pub struct SwapCell {
    slot: RwLock<(u64, Arc<dyn SharedInfer>)>,
}

impl SwapCell {
    /// Wrap the initial artifact at epoch 1.
    pub fn new(artifact: Arc<dyn SharedInfer>) -> SwapCell {
        SwapCell { slot: RwLock::new((1, artifact)) }
    }

    /// The current `(epoch, artifact)` pair.
    pub fn load(&self) -> (u64, Arc<dyn SharedInfer>) {
        let g = self.slot.read().unwrap();
        (g.0, g.1.clone())
    }

    /// Replace the artifact, bump the epoch, return the new epoch.
    pub fn swap(&self, artifact: Arc<dyn SharedInfer>) -> u64 {
        let mut g = self.slot.write().unwrap();
        g.0 += 1;
        g.1 = artifact;
        g.0
    }

    /// The current artifact epoch (1 = never swapped).
    pub fn epoch(&self) -> u64 {
        self.slot.read().unwrap().0
    }
}

/// A ready-to-run inference engine over a fixed model.
///
/// `infer` takes `[B, ...item_shape]` input and returns the model outputs
/// with the same leading batch dimension. Interpreters accept any batch
/// size; the compiled engine only accepts batch sizes it was specialized
/// for (see [`Engine::batch_buckets`]) — callers batch/pad accordingly,
/// exactly like the paper's fixed-shape generated code.
///
/// ```
/// use compiled_nn::engine::{build_engine_from_spec, EngineKind, EngineOptions};
/// use compiled_nn::model::builder::tiny_cnn;
/// use compiled_nn::nn::tensor::Tensor;
///
/// let spec = tiny_cnn(41);
/// let mut engine =
///     build_engine_from_spec(EngineKind::Optimized, &spec, &EngineOptions::default()).unwrap();
/// let out = engine.infer(&Tensor::filled(&[2, 8, 8, 3], 0.25)).unwrap();
/// assert_eq!(out[0].shape(), &[2, 10]);
/// // the optimized engine exposes its lowering decisions
/// let summary = engine.plan_summary().expect("optimized engines lower a program");
/// assert!(summary.report.predicted_total_cycles() > 0.0);
/// ```
pub trait Engine {
    /// Registry name of this engine (`naive` / `optimized` / `compiled`).
    fn name(&self) -> &str;

    /// Run a forward pass on a `[B, ...]` input tensor.
    fn infer(&mut self, input: &Tensor) -> Result<Vec<Tensor>>;

    /// Whether this engine can execute the given model graph.
    fn supports(&self, spec: &ModelSpec) -> bool;

    /// Batch sizes this engine is specialized for (`None` = any batch).
    fn batch_buckets(&self) -> Option<Vec<usize>> {
        None
    }

    /// Engine-side compile/plan time in ms (0 when not applicable).
    fn compile_ms(&self) -> f64 {
        0.0
    }

    /// Working-set bytes currently held (arena/buffers), if tracked.
    fn memory_bytes(&self) -> Option<usize> {
        None
    }

    /// Pre-size engine state for a batch bucket (arena pooling). The
    /// serving coordinator calls this once per advertised bucket at
    /// registration so steady-state inference is allocation-free. No-op
    /// for engines without poolable state.
    fn prepare(&mut self, _batch: usize) {}

    /// What the engine's compile/lowering stage produced — step kinds,
    /// kernel variants, arena footprint — so tests and benches can assert
    /// on the lowered form. `None` for engines without a lowering stage.
    fn plan_summary(&self) -> Option<&PlanSummary> {
        None
    }

    /// The engine's shared-inference artifact, if it has one. `Some` means
    /// the coordinator may serve this model from a worker *pool*: every
    /// worker gets a clone of the `Arc` plus its own [`WorkerScratch`].
    /// `None` (the default — naive interpreter, PJRT engine with its
    /// non-`Send` handles) keeps the model pinned to the single executor
    /// thread, exactly the pre-pool behavior.
    fn shareable(&self) -> Option<Arc<dyn SharedInfer>> {
        None
    }
}

/// The engine registry's keys — every execution path the repo compares.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EngineKind {
    /// Exact scalar interpreter (the paper's `SimpleNN` baseline).
    Naive,
    /// Folded/fused/arena-planned interpreter (TFLite/RoboDNN analog).
    Optimized,
    /// PJRT-compiled AOT artifacts (the paper's JIT analog).
    Compiled,
}

impl EngineKind {
    /// Every kind, in Table 1 column order (fastest path first).
    pub const ALL: [EngineKind; 3] =
        [EngineKind::Compiled, EngineKind::Optimized, EngineKind::Naive];

    /// [`EngineKind::ALL`] as a slice (registry iteration).
    pub fn all() -> &'static [EngineKind] {
        &Self::ALL
    }

    /// Parse a CLI/registry name (`naive` / `optimized` / `compiled`).
    pub fn parse(s: &str) -> Result<EngineKind> {
        Ok(match s {
            "naive" => EngineKind::Naive,
            "optimized" => EngineKind::Optimized,
            "compiled" => EngineKind::Compiled,
            other => bail!(
                "unknown engine `{other}` (have: naive | optimized | compiled)"
            ),
        })
    }

    /// The kind's registry name (inverse of [`EngineKind::parse`]).
    pub fn as_str(self) -> &'static str {
        match self {
            EngineKind::Naive => "naive",
            EngineKind::Optimized => "optimized",
            EngineKind::Compiled => "compiled",
        }
    }

    /// Whether this kind can actually be constructed on this host. The
    /// compiled engine is behind the `pjrt` cargo feature *and* needs a
    /// working PJRT client (the vendored `xla` stub never provides one);
    /// both cases report unavailable instead of erroring per use.
    pub fn available(self) -> bool {
        match self {
            EngineKind::Compiled => compiled_available(),
            _ => true,
        }
    }

    /// The best engine this build can construct: compiled when the PJRT
    /// runtime is linked, otherwise the optimized interpreter. The serving
    /// coordinator defaults to this.
    pub fn preferred() -> EngineKind {
        if EngineKind::Compiled.available() {
            EngineKind::Compiled
        } else {
            EngineKind::Optimized
        }
    }
}

impl fmt::Display for EngineKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Construction options shared by every engine kind.
#[derive(Debug, Clone, Default)]
pub struct EngineOptions {
    /// Graph-pass toggles for the optimized interpreter (folding, approx
    /// activations, arena reuse) — each is an ablation axis.
    pub compile: CompileOptions,
    /// Batch buckets to specialize the compiled engine for
    /// (`None` = every bucket in the manifest entry).
    pub buckets: Option<Vec<usize>>,
}

impl EngineOptions {
    /// Default options but restricted to the given compiled-engine buckets.
    pub fn with_buckets(buckets: &[usize]) -> EngineOptions {
        EngineOptions { buckets: Some(buckets.to_vec()), ..EngineOptions::default() }
    }

    /// Default options with exact math (no §3.4 approximations) — what
    /// parity tests use when comparing against the naive oracle.
    pub fn exact() -> EngineOptions {
        EngineOptions {
            compile: CompileOptions { approx: false, ..CompileOptions::default() },
            buckets: None,
        }
    }

    /// Options under which the optimized engine's lowered program is
    /// **bit-identical** to the naive oracle (approximations off and every
    /// value-reassociating lowering transform disabled — see
    /// [`CompileOptions::bit_exact`]).
    pub fn bit_exact() -> EngineOptions {
        EngineOptions { compile: CompileOptions::bit_exact(), buckets: None }
    }
}

/// Build an engine for a model registered in the artifact [`Manifest`].
///
/// This is the single constructor every caller goes through: interpreters
/// load the nnspec from `manifest.models_dir`, the compiled engine loads
/// and PJRT-compiles the AOT artifacts. Fails with a named error when the
/// kind is unavailable in this build (see [`EngineKind::available`]).
pub fn build_engine(
    kind: EngineKind,
    manifest: &Manifest,
    model: &str,
    opts: &EngineOptions,
) -> Result<Box<dyn Engine>> {
    match kind {
        EngineKind::Naive | EngineKind::Optimized => {
            let spec = load_model(&manifest.models_dir, model)?;
            build_engine_from_spec(kind, &spec, opts)
        }
        EngineKind::Compiled => build_compiled(manifest, model, opts),
    }
}

#[cfg(feature = "pjrt")]
fn compiled_available() -> bool {
    crate::runtime::executor::runtime_available()
}

#[cfg(not(feature = "pjrt"))]
fn compiled_available() -> bool {
    false
}

#[cfg(feature = "pjrt")]
fn build_compiled(
    manifest: &Manifest,
    model: &str,
    opts: &EngineOptions,
) -> Result<Box<dyn Engine>> {
    let engine = crate::runtime::executor::CompiledEngine::build(manifest, model, opts)?;
    Ok(Box::new(engine))
}

#[cfg(not(feature = "pjrt"))]
fn build_compiled(
    _manifest: &Manifest,
    _model: &str,
    _opts: &EngineOptions,
) -> Result<Box<dyn Engine>> {
    bail!(
        "engine `compiled` requires a build with `--features pjrt` \
         (the PJRT runtime is feature-gated off on plain runners)"
    )
}

/// Build an interpreter engine directly from an in-memory [`ModelSpec`]
/// (programmatic models, e.g. `model::builder::tiny_cnn`). The compiled
/// engine executes AOT artifacts and therefore needs [`build_engine`].
pub fn build_engine_from_spec(
    kind: EngineKind,
    spec: &ModelSpec,
    opts: &EngineOptions,
) -> Result<Box<dyn Engine>> {
    match kind {
        EngineKind::Naive => Ok(Box::new(crate::nn::interp::NaiveInterp::new(spec.clone())?)),
        EngineKind::Optimized => {
            Ok(Box::new(crate::compiler::exec::OptInterp::new(spec, opts.compile)?))
        }
        EngineKind::Compiled => bail!(
            "engine `compiled` executes AOT artifacts; construct it from a \
             manifest via build_engine()"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builder::tiny_cnn;

    #[test]
    fn kind_roundtrip_and_display() {
        for kind in EngineKind::all() {
            assert_eq!(EngineKind::parse(kind.as_str()).unwrap(), *kind);
            assert_eq!(kind.to_string(), kind.as_str());
        }
        assert!(EngineKind::parse("jit").is_err());
    }

    #[test]
    fn interpreters_always_available() {
        assert!(EngineKind::Naive.available());
        assert!(EngineKind::Optimized.available());
        assert!(EngineKind::ALL.contains(&EngineKind::preferred()));
        assert_ne!(EngineKind::preferred(), EngineKind::Naive);
    }

    #[test]
    fn registry_builds_interpreters_from_spec() {
        let spec = tiny_cnn(41);
        let x = crate::nn::tensor::Tensor::filled(&[2, 8, 8, 3], 0.25);
        for kind in [EngineKind::Naive, EngineKind::Optimized] {
            let mut e = build_engine_from_spec(kind, &spec, &EngineOptions::default()).unwrap();
            assert_eq!(e.name(), kind.as_str());
            assert!(e.supports(&spec));
            let out = e.infer(&x).unwrap();
            assert_eq!(out[0].shape(), &[2, 10]);
        }
    }

    #[test]
    fn spec_construction_of_compiled_is_a_named_error() {
        let err = build_engine_from_spec(
            EngineKind::Compiled,
            &tiny_cnn(1),
            &EngineOptions::default(),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("compiled"), "{err}");
    }

    #[test]
    fn exact_options_disable_approx() {
        assert!(!EngineOptions::exact().compile.approx);
        assert_eq!(EngineOptions::with_buckets(&[1, 8]).buckets, Some(vec![1, 8]));
        let bits = EngineOptions::bit_exact().compile;
        assert!(!bits.approx && !bits.fold_bn);
    }

    #[test]
    fn shareable_is_an_opt_in() {
        let spec = tiny_cnn(44);
        let naive =
            build_engine_from_spec(EngineKind::Naive, &spec, &EngineOptions::default()).unwrap();
        assert!(naive.shareable().is_none(), "naive stays pinned to the executor thread");
        let opt = build_engine_from_spec(EngineKind::Optimized, &spec, &EngineOptions::default())
            .unwrap();
        assert!(opt.shareable().is_some(), "optimized shares its lowered program");
    }

    #[test]
    fn shared_artifact_serves_many_workers_from_one_lowering() {
        let spec = tiny_cnn(45);
        let mut opt =
            build_engine_from_spec(EngineKind::Optimized, &spec, &EngineOptions::exact()).unwrap();
        let x = crate::nn::tensor::Tensor::filled(&[1, 8, 8, 3], 0.125);
        let want = opt.infer(&x).unwrap();

        let shared = opt.shareable().expect("optimized is shareable");
        assert!(shared.plan_summary().is_some());
        let workers: Vec<_> = (0..3)
            .map(|_| {
                let shared = shared.clone();
                let x = x.clone();
                let want = want[0].clone();
                std::thread::spawn(move || {
                    let mut scratch = shared.new_scratch(&[1, 4]);
                    for _ in 0..4 {
                        let got = shared.infer_shared(&x, &mut scratch).unwrap();
                        assert_eq!(want.data(), got[0].data(), "worker diverged from engine");
                    }
                })
            })
            .collect();
        for w in workers {
            w.join().unwrap();
        }
    }

    #[test]
    fn swap_cell_bumps_epoch_and_replaces_artifact() {
        let x = crate::nn::tensor::Tensor::filled(&[1, 8, 8, 3], 0.25);
        let mk = |seed| {
            let opts = EngineOptions::default();
            build_engine_from_spec(EngineKind::Optimized, &tiny_cnn(seed), &opts)
                .unwrap()
                .shareable()
                .unwrap()
        };
        let cell = SwapCell::new(mk(47));
        assert_eq!(cell.epoch(), 1);
        let (e1, v1) = cell.load();
        let mut s1 = v1.new_scratch(&[1]);
        let out1 = v1.infer_shared(&x, &mut s1).unwrap();

        assert_eq!(cell.swap(mk(48)), 2);
        let (e2, v2) = cell.load();
        assert!(e2 > e1, "swap must bump the epoch");
        let mut s2 = v2.new_scratch(&[1]);
        let out2 = v2.infer_shared(&x, &mut s2).unwrap();
        assert!(
            out1[0].max_abs_diff(&out2[0]) > 1e-6,
            "swap did not change the served artifact"
        );
        // the pre-swap clone keeps working: in-flight batches drain on v1
        assert_eq!(v1.infer_shared(&x, &mut s1).unwrap()[0].data(), out1[0].data());
    }

    #[test]
    fn foreign_scratch_is_rejected_not_ub() {
        let spec = tiny_cnn(46);
        let opt = build_engine_from_spec(EngineKind::Optimized, &spec, &EngineOptions::default())
            .unwrap();
        let shared = opt.shareable().unwrap();
        let mut wrong = WorkerScratch::new(42usize);
        let x = crate::nn::tensor::Tensor::filled(&[1, 8, 8, 3], 0.5);
        let err = shared.infer_shared(&x, &mut wrong).unwrap_err().to_string();
        assert!(err.contains("scratch"), "{err}");
    }

    #[test]
    fn plan_summary_only_on_lowering_engines() {
        let spec = tiny_cnn(43);
        let naive =
            build_engine_from_spec(EngineKind::Naive, &spec, &EngineOptions::default()).unwrap();
        assert!(naive.plan_summary().is_none());
        let opt = build_engine_from_spec(EngineKind::Optimized, &spec, &EngineOptions::default())
            .unwrap();
        let s = opt.plan_summary().expect("optimized engine lowers a program");
        assert!(!s.steps.is_empty());
    }
}
