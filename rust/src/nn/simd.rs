//! Blocked 4-wide matrix–vector and convolution microkernels implementing
//! the paper's §3.3 schemes on the CPU side (the Pallas twins live in
//! `python/compile/kernels/matvec.py`).
//!
//! Both operate on a square `n×n` matrix (n multiple of 4) against `x[n]`:
//!
//! * `matvec_broadcast` — Eq. 2: for each column j, broadcast `x[j]` and FMA
//!   with column j. The broadcast temporary is the extra live register the
//!   paper's layout eliminates.
//! * `matvec_rotated` — Eq. 3: weights pre-permuted into rotated diagonals
//!   (`D[j][i] = W[i][(i+j) % n]`, done once at "compile" time), so the hot
//!   loop is `acc[i] += D[j][i] * x[(i+j) % n]` — x stays resident, the lane
//!   rotation replaces the shuffle, one register is freed.
//!
//! Written with 4-lane arrays ([f32; 4]) so LLVM autovectorizes to SSE — the
//! offline image has no `std::simd`/`wide`; benches/matvec.rs measures both.
//!
//! Since PR 7 the conv/GEMM microkernels are **width-generic**: the
//! `_w::<W>` forms below instantiate the same algorithms over a const lane
//! width `W ∈ {1, 4, 8, 16}` (scalar reference, SSE, AVX2, AVX-512F vector
//! shapes — all expressed as fixed-size `[f32; W]` arrays LLVM maps onto
//! whatever the host ISA offers, so every width is *correct* everywhere;
//! [`crate::cpu`] decides which width is *fast* here). The historical
//! 4-wide names are retained as `W = 4` wrappers.

/// Largest `n` for which [`matvec_rotated`] stays on its stack-resident
/// doubled-`x` window. The `Program` lowering only selects the rotated
/// scheme at or below this bound, keeping the hot path allocation-free.
pub const ROTATED_STACK_MAX: usize = 512;

/// Output-channel block width of the conv microkernel — 4 f32 lanes, the
/// same SSE-sized unit the matvec schemes use.
pub const CONV_BLOCK: usize = 4;

/// Output-dimension block height of the dense GEMM microkernel (MR): each
/// register tile holds 4 f32 output lanes per batch item.
pub const GEMM_MR: usize = 4;

/// Batch-tile width of the dense GEMM microkernel (NR): 4 batch items
/// share one pass over each packed weight panel, so the weight matrix is
/// streamed once per NR items instead of once per item — the §3.3
/// "statically known shapes" argument applied to the batch axis.
pub const GEMM_NR: usize = 4;

// The dense panels reuse the conv panel packer; both block the output
// axis by the same 4-lane unit.
const _: () = assert!(CONV_BLOCK == GEMM_MR);

/// Every lane width the microkernels are instantiated at: the scalar
/// reference (1), SSE (4), AVX2 (8) and AVX-512F (16) vector shapes.
/// Lowering dispatches among these; [`crate::cpu::auto_lanes`] picks the
/// default for the host.
pub const LANE_WIDTHS: [usize; 4] = [1, 4, 8, 16];

/// Element type of packed weight panels. Weights are converted **once at
/// pack time** (the §3.3 "memory layout is free" argument applied to the
/// element type); the microkernels widen each lane group back to f32 and
/// accumulate in f32, so narrowing the storage halves (bf16) or quarters
/// (i8) the weight bytes streamed per output without changing the
/// accumulation order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum WeightDtype {
    /// Full-precision storage — bit-identical to the pre-dtype pipeline.
    #[default]
    F32,
    /// bfloat16 panels: round-to-nearest-even truncation of the high 16
    /// mantissa/exponent bits at pack time, widened back by a 16-bit shift
    /// in the microkernel. Half the weight bandwidth, ~3 decimal digits.
    Bf16,
    /// Post-training 8-bit integers with per-output-channel scales
    /// (`q = round(w / scale)`, `scale = maxabs / 127`). The dot product
    /// runs over widened i8 lanes in f32; the store loop folds the scale
    /// (and bias) back before the activation — dequantization rides the
    /// existing fused epilogue.
    I8,
}

impl WeightDtype {
    /// Every dtype the pipeline supports, widest first.
    pub const ALL: [WeightDtype; 3] = [WeightDtype::F32, WeightDtype::Bf16, WeightDtype::I8];

    /// Bytes one stored weight element occupies.
    pub fn bytes_per_elem(self) -> usize {
        match self {
            WeightDtype::F32 => 4,
            WeightDtype::Bf16 => 2,
            WeightDtype::I8 => 1,
        }
    }

    /// CLI / config / report spelling.
    pub fn label(self) -> &'static str {
        match self {
            WeightDtype::F32 => "f32",
            WeightDtype::Bf16 => "bf16",
            WeightDtype::I8 => "i8",
        }
    }

    /// Parse the [`label`](Self::label) spelling (config files, CLI).
    pub fn parse(s: &str) -> Option<WeightDtype> {
        match s {
            "f32" => Some(WeightDtype::F32),
            "bf16" => Some(WeightDtype::Bf16),
            "i8" | "int8" => Some(WeightDtype::I8),
            _ => None,
        }
    }
}

impl std::fmt::Display for WeightDtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// f32 → bf16 with round-to-nearest-even (the pack-time conversion).
/// NaNs keep their sign and are forced quiet so the narrowed bits can
/// never round a payload down to an infinity.
pub fn f32_to_bf16(v: f32) -> u16 {
    let bits = v.to_bits();
    if v.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round = 0x7fff + ((bits >> 16) & 1);
    ((bits.wrapping_add(round)) >> 16) as u16
}

/// bf16 → f32: exact (every bf16 value is representable), one shift.
#[inline(always)]
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Post-training per-output-channel i8 quantization of a `[taps, oc]`
/// kernel: `scales[o] = maxabs(channel o) / 127` (1.0 for an all-zero
/// channel so dequantization is always well-defined), `q = round(w /
/// scale)` clamped to ±127. Symmetric, zero-point-free — the dot product
/// needs no correction term, only the per-channel scale folded into the
/// store loop exactly like a BN multiplier. Caller must reject nonfinite
/// kernels first (a NaN would cast to 0 silently).
pub fn quantize_i8_per_channel(kernel: &[f32], taps: usize, oc: usize) -> (Vec<i8>, Vec<f32>) {
    assert_eq!(kernel.len(), taps * oc);
    let mut scales = vec![1.0f32; oc];
    for o in 0..oc {
        let mut maxabs = 0.0f32;
        for t in 0..taps {
            maxabs = maxabs.max(kernel[t * oc + o].abs());
        }
        if maxabs > 0.0 {
            scales[o] = maxabs / 127.0;
        }
    }
    let mut q = vec![0i8; kernel.len()];
    for t in 0..taps {
        for o in 0..oc {
            let v = (kernel[t * oc + o] / scales[o]).round();
            q[t * oc + o] = v.clamp(-127.0, 127.0) as i8;
        }
    }
    (q, scales)
}

/// A packed-panel element the microkernels can widen to f32. The f32 impl
/// widens by identity, so the dtype-generic kernels instantiated at
/// `E = f32` are the exact pre-dtype code path (bit-exactness preserved).
pub trait PanelElem: Copy + Default + Send + Sync + 'static {
    /// Widen one stored element back to f32 for accumulation.
    fn widen(self) -> f32;
}

impl PanelElem for f32 {
    #[inline(always)]
    fn widen(self) -> f32 {
        self
    }
}

/// `u16` carries bf16 bit patterns (the pipeline's only u16 panels).
impl PanelElem for u16 {
    #[inline(always)]
    fn widen(self) -> f32 {
        bf16_to_f32(self)
    }
}

impl PanelElem for i8 {
    #[inline(always)]
    fn widen(self) -> f32 {
        self as f32
    }
}

/// Dtype-generic [`pack_conv_panels_w`]: identical layout, element type
/// `E`. Tail lanes are `E::default()` (the zero of every panel dtype).
pub fn pack_conv_panels_we<const W: usize, E: PanelElem>(
    kernel: &[E],
    taps: usize,
    oc: usize,
) -> Vec<E> {
    assert!(W > 0);
    assert_eq!(kernel.len(), taps * oc);
    let blocks = oc.div_ceil(W);
    let mut panels = vec![E::default(); blocks * taps * W];
    for ob in 0..blocks {
        for t in 0..taps {
            for l in 0..W {
                let o = ob * W + l;
                if o < oc {
                    panels[(ob * taps + t) * W + l] = kernel[t * oc + o];
                }
            }
        }
    }
    panels
}

/// Runtime-width dispatch over [`pack_conv_panels_we`] — the dtype-generic
/// sibling of [`pack_conv_panels_any`].
pub fn pack_conv_panels_any_e<E: PanelElem>(
    kernel: &[E],
    taps: usize,
    oc: usize,
    lanes: usize,
) -> Vec<E> {
    match lanes {
        1 => pack_conv_panels_we::<1, E>(kernel, taps, oc),
        8 => pack_conv_panels_we::<8, E>(kernel, taps, oc),
        16 => pack_conv_panels_we::<16, E>(kernel, taps, oc),
        _ => pack_conv_panels_we::<4, E>(kernel, taps, oc),
    }
}

/// Dense spelling of [`pack_conv_panels_any_e`] (`in_dim` taps).
pub fn pack_dense_panels_any_e<E: PanelElem>(
    kernel: &[E],
    in_dim: usize,
    out_dim: usize,
    lanes: usize,
) -> Vec<E> {
    pack_conv_panels_any_e(kernel, in_dim, out_dim, lanes)
}

/// Dtype-generic [`conv_fma_run_w`]: widen each stored lane to f32 and
/// accumulate in f32 — identical per-lane order at every `(W, E)`, so
/// `E = f32` is bit-identical to the historical kernel and every narrowed
/// dtype differs only by its pack-time rounding.
#[inline(always)]
pub fn conv_fma_run_we<const W: usize, E: PanelElem>(
    panel: &[E],
    x: &[f32],
    acc: &mut [f32; W],
) {
    debug_assert_eq!(panel.len(), x.len() * W);
    for (lanes, &xv) in panel.chunks_exact(W).zip(x) {
        for l in 0..W {
            acc[l] += xv * lanes[l].widen();
        }
    }
}

/// Dtype-generic [`gemm_fma_run_w`]: the MR×NR register tile over widened
/// panels, accumulation in f32.
#[inline(always)]
pub fn gemm_fma_run_we<const W: usize, E: PanelElem>(
    panel: &[E],
    x4: &[f32],
    in_dim: usize,
    acc: &mut [[f32; W]; GEMM_NR],
) {
    debug_assert_eq!(panel.len(), in_dim * W);
    debug_assert_eq!(x4.len(), GEMM_NR * in_dim);
    for (i, lanes) in panel.chunks_exact(W).enumerate() {
        for n in 0..GEMM_NR {
            let xv = x4[n * in_dim + i];
            for l in 0..W {
                acc[n][l] += xv * lanes[l].widen();
            }
        }
    }
}

/// Width-generic [`pack_conv_panels`]: block the output-channel axis by
/// `W` lanes instead of 4 —
///
/// ```text
/// panels[(ob * taps + t) * W + l] = kernel[t * oc + ob * W + l]
/// ```
///
/// Tail lanes (oc not a multiple of `W`) are zero and never stored back,
/// so a wider block trades tail waste for fewer passes — exactly the
/// trade `compiler::cost` prices per layer.
pub fn pack_conv_panels_w<const W: usize>(kernel: &[f32], taps: usize, oc: usize) -> Vec<f32> {
    assert!(W > 0);
    assert_eq!(kernel.len(), taps * oc);
    let blocks = oc.div_ceil(W);
    let mut panels = vec![0.0; blocks * taps * W];
    for ob in 0..blocks {
        for t in 0..taps {
            for l in 0..W {
                let o = ob * W + l;
                if o < oc {
                    panels[(ob * taps + t) * W + l] = kernel[t * oc + o];
                }
            }
        }
    }
    panels
}

/// Width-generic [`conv_fma_run`]: `acc[l] += Σ_i x[i] * panel[i*W + l]`.
/// At `W = 1` this is the scalar reference loop; at 4/8/16 LLVM
/// autovectorizes the lane loop to the host's widest available unit. The
/// per-lane accumulation order is identical at every width, so a lane
/// computed at `W = 16` is bit-identical to the same output channel
/// computed at `W = 1`.
#[inline(always)]
pub fn conv_fma_run_w<const W: usize>(panel: &[f32], x: &[f32], acc: &mut [f32; W]) {
    conv_fma_run_we::<W, f32>(panel, x, acc)
}

/// Width-generic [`pack_dense_panels`] (same layout with `taps = in_dim`).
pub fn pack_dense_panels_w<const W: usize>(
    kernel: &[f32],
    in_dim: usize,
    out_dim: usize,
) -> Vec<f32> {
    pack_conv_panels_w::<W>(kernel, in_dim, out_dim)
}

/// Width-generic [`gemm_fma_run`]: a `W × GEMM_NR` register tile (`W`
/// output lanes × 4 batch items). Accumulation over `i` is ascending per
/// (item, lane) — the same order as a 1-wide [`conv_fma_run_w`] pass, so
/// tiles and tails agree bit-for-bit at every width.
#[inline(always)]
pub fn gemm_fma_run_w<const W: usize>(
    panel: &[f32],
    x4: &[f32],
    in_dim: usize,
    acc: &mut [[f32; W]; GEMM_NR],
) {
    gemm_fma_run_we::<W, f32>(panel, x4, in_dim, acc)
}

/// Pre-pack an HWIO conv kernel (flattened `[taps, oc]`, `taps = kh*kw*c`)
/// into output-channel-blocked panels:
///
/// ```text
/// panels[(ob * taps + t) * 4 + l] = kernel[t * oc + ob * 4 + l]
/// ```
///
/// so the hot loop reads one contiguous 4-float lane group per tap while
/// the accumulators stay register-resident. Tail lanes (oc not a multiple
/// of 4) are zero and never stored back. O(taps·oc), done once at lowering
/// — "the memory layout of the matrix can be chosen arbitrarily" (§3.3).
pub fn pack_conv_panels(kernel: &[f32], taps: usize, oc: usize) -> Vec<f32> {
    pack_conv_panels_w::<CONV_BLOCK>(kernel, taps, oc)
}

/// Pack conv panels at a runtime-chosen lane width — the lowering-side
/// dispatch over [`pack_conv_panels_w`]. `lanes` must be one of
/// [`LANE_WIDTHS`] and must match the width recorded in the kernel algo
/// that will consume the panels (unlisted widths fall back to 4, mirroring
/// the kernels' own dispatch).
pub fn pack_conv_panels_any(kernel: &[f32], taps: usize, oc: usize, lanes: usize) -> Vec<f32> {
    match lanes {
        1 => pack_conv_panels_w::<1>(kernel, taps, oc),
        8 => pack_conv_panels_w::<8>(kernel, taps, oc),
        16 => pack_conv_panels_w::<16>(kernel, taps, oc),
        _ => pack_conv_panels_w::<4>(kernel, taps, oc),
    }
}

/// Dense-layer spelling of [`pack_conv_panels_any`] (`in_dim` taps,
/// `out_dim` channels).
pub fn pack_dense_panels_any(
    kernel: &[f32],
    in_dim: usize,
    out_dim: usize,
    lanes: usize,
) -> Vec<f32> {
    pack_conv_panels_any(kernel, in_dim, out_dim, lanes)
}

/// The 4-lane FMA microkernel: `acc[l] += Σ_i x[i] * panel[i*4 + l]` over a
/// run of taps whose input values are contiguous (a channel vector of one
/// in-bounds pixel, or a whole im2col row). `panel` is a
/// [`pack_conv_panels`] slice of the same tap run. The accumulators live in
/// the caller's registers across runs, so one output-channel block costs
/// one store per pixel regardless of kernel size.
#[inline(always)]
pub fn conv_fma_run(panel: &[f32], x: &[f32], acc: &mut [f32; CONV_BLOCK]) {
    conv_fma_run_w::<CONV_BLOCK>(panel, x, acc)
}

/// Pre-pack a Dense kernel (row-major `[in_dim, out_dim]`, Keras
/// orientation `y[o] = Σ_i x[i] * K[i][o]`) into output-dim-blocked
/// 4-lane panels:
///
/// ```text
/// panels[(ob * in_dim + i) * GEMM_MR + l] = K[i][ob * GEMM_MR + l]
/// ```
///
/// — the same layout as [`pack_conv_panels`] with `taps = in_dim`, so the
/// GEMM hot loop reads one contiguous 4-float lane group per input while
/// the MR×NR accumulator tile stays register-resident. Tail lanes
/// (`out_dim` not a multiple of 4) are zero and never stored back.
/// O(in_dim·out_dim), done once at lowering.
pub fn pack_dense_panels(kernel: &[f32], in_dim: usize, out_dim: usize) -> Vec<f32> {
    pack_conv_panels(kernel, in_dim, out_dim)
}

/// The register-tiled GEMM microkernel: an MR×NR tile (4 output lanes ×
/// 4 batch items) held in `acc` across one pass over a packed panel.
/// `x4` is `GEMM_NR` consecutive batch rows (`len == GEMM_NR * in_dim`,
/// item `n` at `x4[n * in_dim..]`); `panel` is a [`pack_dense_panels`]
/// block covering the same `in_dim` inputs. Each panel lane group is read
/// once and FMA'd against all four items, which is what amortizes the
/// weight bandwidth a per-item matvec pays `NR` times. Accumulation over
/// `i` is ascending per (item, lane) — the same order as a 1-wide
/// [`conv_fma_run`] pass, so tile and tail results agree bit-for-bit.
#[inline(always)]
pub fn gemm_fma_run(
    panel: &[f32],
    x4: &[f32],
    in_dim: usize,
    acc: &mut [[f32; GEMM_MR]; GEMM_NR],
) {
    gemm_fma_run_w::<GEMM_MR>(panel, x4, in_dim, acc)
}

/// Pre-permute W (row-major `[n, n]`, `y = W x` orientation) into stacked
/// rotated diagonals. O(n²), done once — "the memory layout of the matrix
/// can be chosen arbitrarily without any impact on performance" (§3.3).
pub fn rotate_diagonals(w: &[f32], n: usize) -> Vec<f32> {
    assert_eq!(w.len(), n * n);
    let mut d = vec![0.0; n * n];
    for j in 0..n {
        for i in 0..n {
            d[j * n + i] = w[i * n + (i + j) % n];
        }
    }
    d
}

/// Eq. 2 (broadcast scheme): `y[i] = Σ_j W[i][j] * x[j]`, W column-major
/// blocks of 4 rows. `w` row-major `[n, n]`.
pub fn matvec_broadcast(w: &[f32], x: &[f32], y: &mut [f32]) {
    let n = x.len();
    debug_assert!(n % 4 == 0 && w.len() == n * n && y.len() == n);
    for yi in (0..n).step_by(4) {
        let mut acc = [0.0f32; 4];
        for j in 0..n {
            let xj = x[j]; // broadcast temp (the third register of Eq. 2)
            let col = [
                w[yi * n + j],
                w[(yi + 1) * n + j],
                w[(yi + 2) * n + j],
                w[(yi + 3) * n + j],
            ];
            for l in 0..4 {
                acc[l] += col[l] * xj;
            }
        }
        y[yi..yi + 4].copy_from_slice(&acc);
    }
}

/// Eq. 3 (rotated-diagonal scheme) over `rotate_diagonals` output: x is
/// walked as contiguous rotations; no broadcast needed.
///
/// Perf note (§Perf log in EXPERIMENTS.md): the rotation is realized by
/// reading a length-n window at offset j of a doubled copy `[x, x]` — one
/// contiguous stream per step instead of a wrap-split pair of loops, which
/// LLVM vectorizes cleanly even at small n. The doubled copy is the CPU
/// stand-in for the free lane rotation of the resident register/tile.
pub fn matvec_rotated(d: &[f32], x: &[f32], y: &mut [f32]) {
    let n = x.len();
    if n <= ROTATED_STACK_MAX {
        // stack buffer for the common small-n case
        let mut buf = [0.0f32; 2 * ROTATED_STACK_MAX];
        matvec_rotated_with(d, x, &mut buf[..2 * n], y);
    } else {
        // rare path; allocation amortized away by caller loops in practice
        let mut xx = vec![0.0f32; 2 * n];
        matvec_rotated_with(d, x, &mut xx, y);
    }
}

/// Eq. 3 with a caller-provided doubled-`x` scratch (`len == 2n`) — the
/// zero-setup form the `Program` Dense kernel uses: its scratch is sized
/// once at lowering, so the hot path neither allocates nor zero-fills.
pub fn matvec_rotated_with(d: &[f32], x: &[f32], scratch: &mut [f32], y: &mut [f32]) {
    let n = x.len();
    debug_assert!(d.len() == n * n && y.len() == n && scratch.len() == 2 * n);
    scratch[..n].copy_from_slice(x);
    scratch[n..2 * n].copy_from_slice(x);
    y.fill(0.0);
    for j in 0..n {
        let dj = &d[j * n..(j + 1) * n];
        let xw = &scratch[j..j + n];
        for i in 0..n {
            y[i] += dj[i] * xw[i];
        }
    }
}

/// Reference exact matvec for the tests.
pub fn matvec_naive(w: &[f32], x: &[f32], y: &mut [f32]) {
    let n = x.len();
    for i in 0..n {
        let mut acc = 0.0;
        for j in 0..n {
            acc += w[i * n + j] * x[j];
        }
        y[i] = acc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::check;
    use crate::util::rng::SplitMix64;

    fn close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
        let worst = a
            .iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        if worst < tol {
            Ok(())
        } else {
            Err(format!("max diff {worst}"))
        }
    }

    #[test]
    fn schemes_agree_with_naive() {
        check(
            "matvec_schemes",
            40,
            |r: &mut SplitMix64| {
                let n = 4 * (1 + r.below(16)); // 4..64
                let w = r.uniform_vec(n * n);
                let x = r.uniform_vec(n);
                (n, w, x)
            },
            |(n, w, x)| {
                let mut y0 = vec![0.0; *n];
                let mut y1 = vec![0.0; *n];
                let mut y2 = vec![0.0; *n];
                matvec_naive(w, x, &mut y0);
                matvec_broadcast(w, x, &mut y1);
                let d = rotate_diagonals(w, *n);
                matvec_rotated(&d, x, &mut y2);
                close(&y0, &y1, 1e-4)?;
                close(&y0, &y2, 1e-4)
            },
        );
    }

    #[test]
    fn rotation_layout_pinned() {
        // D[j][i] = W[i][(i+j) % n] on a 4x4 counter matrix.
        let w: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let d = rotate_diagonals(&w, 4);
        assert_eq!(&d[0..4], &[0.0, 5.0, 10.0, 15.0]); // main diagonal
        assert_eq!(&d[4..8], &[1.0, 6.0, 11.0, 12.0]); // rotated by 1
    }

    #[test]
    fn conv_panel_layout_pinned() {
        // taps = 2, oc = 6 → 2 blocks, second block half-padded.
        let kernel: Vec<f32> = (0..12).map(|v| v as f32).collect(); // K[t][o] = 6t + o
        let p = pack_conv_panels(&kernel, 2, 6);
        assert_eq!(p.len(), 2 * 2 * CONV_BLOCK);
        // block 0: taps 0,1 × lanes 0..4
        assert_eq!(&p[0..4], &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(&p[4..8], &[6.0, 7.0, 8.0, 9.0]);
        // block 1: lanes 4,5 real, 6,7 zero-padded
        assert_eq!(&p[8..12], &[4.0, 5.0, 0.0, 0.0]);
        assert_eq!(&p[12..16], &[10.0, 11.0, 0.0, 0.0]);
    }

    #[test]
    fn dense_panel_layout_pinned() {
        // in_dim = 2, out_dim = 6 → 2 blocks, second block half-padded —
        // identical layout to the conv panels with taps = in_dim.
        let kernel: Vec<f32> = (0..12).map(|v| v as f32).collect(); // K[i][o] = 6i + o
        let p = pack_dense_panels(&kernel, 2, 6);
        assert_eq!(p, pack_conv_panels(&kernel, 2, 6));
        assert_eq!(&p[0..4], &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(&p[8..12], &[4.0, 5.0, 0.0, 0.0]);
    }

    #[test]
    fn gemm_tile_matches_per_item_dots() {
        check(
            "gemm_fma_run",
            30,
            |r: &mut SplitMix64| {
                let in_dim = 1 + r.below(24); // 1..24, mostly off the 4 grid
                let out_block = 4usize;
                let kernel = r.uniform_vec(in_dim * out_block);
                let x4 = r.uniform_vec(GEMM_NR * in_dim);
                (in_dim, kernel, x4)
            },
            |(in_dim, kernel, x4)| {
                let p = pack_dense_panels(kernel, *in_dim, 4);
                let mut acc = [[0.0f32; GEMM_MR]; GEMM_NR];
                gemm_fma_run(&p, x4, *in_dim, &mut acc);
                for n in 0..GEMM_NR {
                    for o in 0..4 {
                        let want: f32 = (0..*in_dim)
                            .map(|i| x4[n * in_dim + i] * kernel[i * 4 + o])
                            .sum();
                        if (acc[n][o] - want).abs() > 1e-4 {
                            return Err(format!(
                                "item {n} lane {o}: {} vs {want}",
                                acc[n][o]
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn gemm_tile_bit_matches_one_wide_fma_pass() {
        // The tile must accumulate in the same order as a per-item
        // conv_fma_run pass, so GEMM blocks and matvec tails never
        // disagree bitwise.
        let mut r = SplitMix64::new(23);
        let in_dim = 13;
        let kernel = r.uniform_vec(in_dim * 4);
        let x4 = r.uniform_vec(GEMM_NR * in_dim);
        let p = pack_dense_panels(&kernel, in_dim, 4);
        let mut acc = [[0.0f32; GEMM_MR]; GEMM_NR];
        gemm_fma_run(&p, &x4, in_dim, &mut acc);
        for n in 0..GEMM_NR {
            let mut one = [0.0f32; CONV_BLOCK];
            conv_fma_run(&p, &x4[n * in_dim..(n + 1) * in_dim], &mut one);
            for l in 0..4 {
                assert_eq!(acc[n][l].to_bits(), one[l].to_bits(), "item {n} lane {l}");
            }
        }
    }

    #[test]
    fn wide_panels_and_fma_runs_bit_match_the_scalar_reference() {
        // Every instantiated width must produce bit-identical output
        // channels to the W = 1 scalar reference — the property the
        // runtime dispatch relies on to change *speed only*.
        fn per_width<const W: usize>(kernel: &[f32], x: &[f32], taps: usize, oc: usize) {
            let p = pack_conv_panels_w::<W>(kernel, taps, oc);
            assert_eq!(p.len(), oc.div_ceil(W) * taps * W);
            for o in 0..oc {
                let mut one = [0.0f32; 1];
                let p1 = pack_conv_panels_w::<1>(kernel, taps, oc);
                conv_fma_run_w::<1>(&p1[o * taps..(o + 1) * taps], x, &mut one);
                let mut acc = [0.0f32; W];
                let ob = o / W;
                conv_fma_run_w::<W>(&p[ob * taps * W..(ob + 1) * taps * W], x, &mut acc);
                assert_eq!(acc[o % W].to_bits(), one[0].to_bits(), "W={W} chan {o}");
            }
        }
        let mut r = SplitMix64::new(71);
        for (taps, oc) in [(9, 6), (5, 4), (12, 17), (3, 1)] {
            let kernel = r.uniform_vec(taps * oc);
            let x = r.uniform_vec(taps);
            per_width::<4>(&kernel, &x, taps, oc);
            per_width::<8>(&kernel, &x, taps, oc);
            per_width::<16>(&kernel, &x, taps, oc);
        }
    }

    #[test]
    fn wide_gemm_tiles_bit_match_their_one_item_fma_pass() {
        fn per_width<const W: usize>(kernel: &[f32], x4: &[f32], in_dim: usize) {
            let p = pack_dense_panels_w::<W>(kernel, in_dim, W);
            let mut acc = [[0.0f32; W]; GEMM_NR];
            gemm_fma_run_w::<W>(&p, x4, in_dim, &mut acc);
            for n in 0..GEMM_NR {
                let mut one = [0.0f32; W];
                conv_fma_run_w::<W>(&p, &x4[n * in_dim..(n + 1) * in_dim], &mut one);
                for l in 0..W {
                    assert_eq!(acc[n][l].to_bits(), one[l].to_bits(), "W={W} item {n} lane {l}");
                }
            }
        }
        let mut r = SplitMix64::new(72);
        let in_dim = 11;
        let kernel16 = r.uniform_vec(in_dim * 16);
        let x4 = r.uniform_vec(GEMM_NR * in_dim);
        per_width::<8>(&kernel16[..in_dim * 8], &x4, in_dim);
        per_width::<16>(&kernel16, &x4, in_dim);
    }

    #[test]
    fn bf16_round_to_nearest_even_pinned() {
        // exactly representable values survive the round trip
        for v in [0.0f32, -0.0, 1.0, -2.5, 0.15625, 65280.0] {
            assert_eq!(bf16_to_f32(f32_to_bf16(v)), v, "{v}");
        }
        // exact midpoint below an even mantissa rounds down (RNE), the
        // midpoint below an odd mantissa rounds up, one ulp past a
        // midpoint always rounds up
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::from_bits(0x3F80_8000))), 1.0);
        assert_eq!(
            bf16_to_f32(f32_to_bf16(f32::from_bits(0x3F81_8000))),
            f32::from_bits(0x3F82_0000)
        );
        assert_eq!(
            bf16_to_f32(f32_to_bf16(f32::from_bits(0x3F80_8001))),
            f32::from_bits(0x3F81_0000)
        );
        // next representable above 1.0 rounds to itself
        assert_eq!(bf16_to_f32(f32_to_bf16(1.0078125)), 1.0078125);
        // infinities pass through; NaN stays NaN (quiet), never an inf
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::INFINITY)), f32::INFINITY);
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::NEG_INFINITY)), f32::NEG_INFINITY);
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        // relative error of the round trip is bounded by 2^-8
        let mut r = SplitMix64::new(5);
        for v in r.uniform_vec(1000) {
            let rt = bf16_to_f32(f32_to_bf16(v));
            assert!((rt - v).abs() <= v.abs() * 0.00390625 + 1e-38, "{v} -> {rt}");
        }
    }

    #[test]
    fn i8_quantization_bounds_and_scales() {
        let mut r = SplitMix64::new(9);
        let (taps, oc) = (7, 5);
        let kernel: Vec<f32> = r.uniform_vec(taps * oc).iter().map(|v| v * 2.0 - 1.0).collect();
        let (q, scales) = quantize_i8_per_channel(&kernel, taps, oc);
        assert_eq!(scales.len(), oc);
        for o in 0..oc {
            let maxabs = (0..taps).map(|t| kernel[t * oc + o].abs()).fold(0.0f32, f32::max);
            assert!((scales[o] - maxabs / 127.0).abs() < 1e-7);
            for t in 0..taps {
                let deq = q[t * oc + o] as f32 * scales[o];
                // rounding error ≤ scale/2 per element
                assert!(
                    (deq - kernel[t * oc + o]).abs() <= scales[o] * 0.5 + 1e-7,
                    "chan {o} tap {t}: {} vs {}",
                    deq,
                    kernel[t * oc + o]
                );
            }
        }
        // all-zero channels quantize to zero with scale 1 (no 0/0)
        let (qz, sz) = quantize_i8_per_channel(&vec![0.0; 6], 3, 2);
        assert!(qz.iter().all(|&v| v == 0) && sz.iter().all(|&s| s == 1.0));
    }

    #[test]
    fn dtype_generic_runs_bit_match_their_scalar_reference() {
        // For every panel dtype, the wide kernels must bit-match the W = 1
        // instantiation of the SAME dtype — runtime lane dispatch stays a
        // speed-only choice under narrowed weights too.
        fn per_width<const W: usize, E: PanelElem>(kernel: &[E], x: &[f32], taps: usize, oc: usize) {
            let p1 = pack_conv_panels_we::<1, E>(kernel, taps, oc);
            let p = pack_conv_panels_we::<W, E>(kernel, taps, oc);
            for o in 0..oc {
                let mut one = [0.0f32; 1];
                conv_fma_run_we::<1, E>(&p1[o * taps..(o + 1) * taps], x, &mut one);
                let mut acc = [0.0f32; W];
                let ob = o / W;
                conv_fma_run_we::<W, E>(&p[ob * taps * W..(ob + 1) * taps * W], x, &mut acc);
                assert_eq!(acc[o % W].to_bits(), one[0].to_bits(), "W={W} chan {o}");
            }
        }
        let mut r = SplitMix64::new(81);
        for (taps, oc) in [(9, 6), (5, 4), (12, 17)] {
            let kernel = r.uniform_vec(taps * oc);
            let x = r.uniform_vec(taps);
            let kb: Vec<u16> = kernel.iter().map(|&v| f32_to_bf16(v)).collect();
            let (ki, _) = quantize_i8_per_channel(&kernel, taps, oc);
            per_width::<8, u16>(&kb, &x, taps, oc);
            per_width::<16, u16>(&kb, &x, taps, oc);
            per_width::<8, i8>(&ki, &x, taps, oc);
            per_width::<16, i8>(&ki, &x, taps, oc);
        }
    }

    #[test]
    fn widened_gemm_tile_matches_widened_per_item_pass() {
        let mut r = SplitMix64::new(82);
        let in_dim = 11;
        let kernel = r.uniform_vec(in_dim * 8);
        let x4 = r.uniform_vec(GEMM_NR * in_dim);
        let kb: Vec<u16> = kernel.iter().map(|&v| f32_to_bf16(v)).collect();
        let p = pack_dense_panels_any_e(&kb, in_dim, 8, 8);
        let mut acc = [[0.0f32; 8]; GEMM_NR];
        gemm_fma_run_we::<8, u16>(&p, &x4, in_dim, &mut acc);
        for n in 0..GEMM_NR {
            let mut one = [0.0f32; 8];
            conv_fma_run_we::<8, u16>(&p, &x4[n * in_dim..(n + 1) * in_dim], &mut one);
            for l in 0..8 {
                assert_eq!(acc[n][l].to_bits(), one[l].to_bits(), "item {n} lane {l}");
            }
        }
    }

    #[test]
    fn weight_dtype_parse_and_labels_roundtrip() {
        for d in WeightDtype::ALL {
            assert_eq!(WeightDtype::parse(d.label()), Some(d));
            assert_eq!(d.to_string(), d.label());
        }
        assert_eq!(WeightDtype::parse("int8"), Some(WeightDtype::I8));
        assert_eq!(WeightDtype::parse("fp64"), None);
        assert_eq!(WeightDtype::default(), WeightDtype::F32);
        assert_eq!(
            WeightDtype::ALL.map(WeightDtype::bytes_per_elem),
            [4, 2, 1]
        );
    }

    #[test]
    fn conv_fma_run_matches_scalar_dot() {
        let mut r = SplitMix64::new(17);
        let taps = 9;
        let oc = 4;
        let kernel = r.uniform_vec(taps * oc);
        let x = r.uniform_vec(taps);
        let p = pack_conv_panels(&kernel, taps, oc);
        let mut acc = [0.0f32; CONV_BLOCK];
        conv_fma_run(&p, &x, &mut acc);
        for o in 0..oc {
            let want: f32 = (0..taps).map(|t| x[t] * kernel[t * oc + o]).sum();
            assert!((acc[o] - want).abs() < 1e-5, "lane {o}: {} vs {want}", acc[o]);
        }
    }
}
