//! Dense row-major f32 tensor, NHWC for images — the memory layout the
//! paper's generated code operates on (channels innermost, so per-pixel
//! channel vectors are contiguous for the matvec-style conv inner loop).

use std::fmt;

/// Dense f32 tensor with explicit shape; data is row-major (last dim fastest).
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match {} elements",
            data.len()
        );
        Self { shape: shape.to_vec(), data }
    }

    /// Copying constructor from a borrowed slice (how `Program` turns an
    /// arena view into an owned output tensor).
    pub fn from_slice(shape: &[usize], data: &[f32]) -> Self {
        Self::from_vec(shape, data.to_vec())
    }

    pub fn filled(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![v; n] }
    }

    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshaped(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Strides in elements (row-major).
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    // -- NHWC accessors ----------------------------------------------------
    /// Index into an NHWC rank-4 tensor.
    #[inline]
    pub fn at4(&self, n: usize, h: usize, w: usize, c: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 4);
        let (sh, sw, sc) = (self.shape[1], self.shape[2], self.shape[3]);
        debug_assert!(h < sh && w < sw && c < sc);
        self.data[((n * sh + h) * sw + w) * sc + c]
    }

    #[inline]
    pub fn at4_mut(&mut self, n: usize, h: usize, w: usize, c: usize) -> &mut f32 {
        debug_assert_eq!(self.shape.len(), 4);
        let (sh, sw, sc) = (self.shape[1], self.shape[2], self.shape[3]);
        &mut self.data[((n * sh + h) * sw + w) * sc + c]
    }

    /// The contiguous channel vector at pixel (n, h, w) of an NHWC tensor.
    #[inline]
    pub fn pixel(&self, n: usize, h: usize, w: usize) -> &[f32] {
        let (sh, sw, sc) = (self.shape[1], self.shape[2], self.shape[3]);
        let base = ((n * sh + h) * sw + w) * sc;
        &self.data[base..base + sc]
    }

    #[inline]
    pub fn pixel_mut(&mut self, n: usize, h: usize, w: usize) -> &mut [f32] {
        let (sh, sw, sc) = (self.shape[1], self.shape[2], self.shape[3]);
        let base = ((n * sh + h) * sw + w) * sc;
        &mut self.data[base..base + sc]
    }

    /// Max |a - b| over two same-shaped tensors.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Batch slice of the leading dimension: rows [lo, hi).
    pub fn slice_batch(&self, lo: usize, hi: usize) -> Tensor {
        assert!(!self.shape.is_empty() && lo <= hi && hi <= self.shape[0]);
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = hi - lo;
        Tensor::from_vec(&shape, self.data[lo * row..hi * row].to_vec())
    }

    /// Concatenate along the leading (batch) dimension.
    pub fn concat_batch(parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let tail = &parts[0].shape[1..];
        let mut shape = parts[0].shape.clone();
        shape[0] = parts.iter().map(|p| p.shape[0]).sum();
        let mut data = Vec::with_capacity(shape.iter().product());
        for p in parts {
            assert_eq!(&p.shape[1..], tail);
            data.extend_from_slice(&p.data);
        }
        Tensor::from_vec(&shape, data)
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[", self.shape)?;
        for (i, v) in self.data.iter().take(8).enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{v:.4}")?;
        }
        if self.data.len() > 8 {
            write!(f, ", …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_strides() {
        let t = Tensor::zeros(&[2, 3, 4, 5]);
        assert_eq!(t.len(), 120);
        assert_eq!(t.strides(), vec![60, 20, 5, 1]);
    }

    #[test]
    fn nhwc_indexing_channels_contiguous() {
        let mut t = Tensor::zeros(&[1, 2, 2, 3]);
        *t.at4_mut(0, 1, 0, 2) = 7.0;
        assert_eq!(t.at4(0, 1, 0, 2), 7.0);
        assert_eq!(t.pixel(0, 1, 0), &[0.0, 0.0, 7.0]);
    }

    #[test]
    fn batch_slice_concat_roundtrip() {
        let t = Tensor::from_vec(&[4, 2], (0..8).map(|v| v as f32).collect());
        let a = t.slice_batch(0, 1);
        let b = t.slice_batch(1, 4);
        let back = Tensor::concat_batch(&[&a, &b]);
        assert_eq!(back, t);
    }

    #[test]
    #[should_panic]
    fn from_vec_checks_len() {
        Tensor::from_vec(&[2, 2], vec![1.0; 3]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let r = t.clone().reshaped(&[6]);
        assert_eq!(r.data(), t.data());
    }
}
