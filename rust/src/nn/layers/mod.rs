//! Exact reference layer implementations (the `SimpleNN` substrate).
pub mod conv;
pub mod dense;
pub mod norm_act;
pub mod pool;
pub mod shape_ops;
