//! Batch normalization (inference form) and exact activation functions.
//! The *approximated* activations live in `approx/`; these are the oracles.

use crate::model::spec::Activation;
use crate::nn::tensor::Tensor;

/// Inference-time batchnorm over the channel (last) axis:
/// `y = gamma * (x - mean) / sqrt(var + eps) + beta`.
pub fn batchnorm(
    x: &Tensor,
    gamma: &[f32],
    beta: &[f32],
    mean: &[f32],
    var: &[f32],
    eps: f32,
) -> Tensor {
    let c = *x.shape().last().unwrap();
    assert!(gamma.len() == c && beta.len() == c && mean.len() == c && var.len() == c);
    let scale: Vec<f32> = (0..c).map(|i| gamma[i] / (var[i] + eps).sqrt()).collect();
    let shift: Vec<f32> = (0..c).map(|i| beta[i] - mean[i] * scale[i]).collect();
    affine_channels(x, &scale, &shift)
}

/// Per-channel affine `y = x * scale + shift` (also the §3.5 fused form).
pub fn affine_channels(x: &Tensor, scale: &[f32], shift: &[f32]) -> Tensor {
    let c = *x.shape().last().unwrap();
    assert!(scale.len() == c && shift.len() == c);
    let mut out = x.clone();
    for (i, v) in out.data_mut().iter_mut().enumerate() {
        let ci = i % c;
        *v = *v * scale[ci] + shift[ci];
    }
    out
}

/// Exact scalar activation.
#[inline]
pub fn activate_exact(a: Activation, v: f32) -> f32 {
    match a {
        Activation::Linear => v,
        Activation::Relu => v.max(0.0),
        Activation::Relu6 => v.clamp(0.0, 6.0),
        Activation::LeakyRelu => {
            if v >= 0.0 {
                v
            } else {
                0.1 * v
            }
        }
        Activation::Sigmoid => 1.0 / (1.0 + (-v).exp()),
        Activation::Tanh => v.tanh(),
    }
}

/// Apply an exact activation in place.
pub fn apply_activation(x: &mut Tensor, a: Activation) {
    if a == Activation::Linear {
        return;
    }
    for v in x.data_mut() {
        *v = activate_exact(a, *v);
    }
}

/// Exact softmax over the last axis (max-shifted).
pub fn softmax(x: &Tensor) -> Tensor {
    let c = *x.shape().last().unwrap();
    let mut out = x.clone();
    for row in out.data_mut().chunks_exact_mut(c) {
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batchnorm_identity() {
        let x = Tensor::from_vec(&[1, 1, 1, 2], vec![3.0, -1.0]);
        let y = batchnorm(&x, &[1., 1.], &[0., 0.], &[0., 0.], &[1., 1.], 0.0);
        assert!(y.max_abs_diff(&x) < 1e-6);
    }

    #[test]
    fn batchnorm_standardizes() {
        let x = Tensor::from_vec(&[1, 1, 1, 1], vec![5.0]);
        // (5 - 3)/sqrt(4) * 2 + 1 = 3
        let y = batchnorm(&x, &[2.], &[1.], &[3.], &[4.], 0.0);
        assert!((y.data()[0] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn activations_exact() {
        assert_eq!(activate_exact(Activation::Relu, -2.0), 0.0);
        assert_eq!(activate_exact(Activation::Relu6, 7.5), 6.0);
        assert_eq!(activate_exact(Activation::LeakyRelu, -1.0), -0.1);
        assert!((activate_exact(Activation::Sigmoid, 0.0) - 0.5).abs() < 1e-7);
        assert!((activate_exact(Activation::Tanh, 1.0) - 0.7615942).abs() < 1e-6);
    }

    #[test]
    fn softmax_normalizes() {
        let x = Tensor::from_vec(&[2, 3], vec![1., 2., 3., -1., 0., 1.]);
        let y = softmax(&x);
        for row in y.data().chunks_exact(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
            assert!(row[2] > row[1] && row[1] > row[0]);
        }
    }

    #[test]
    fn softmax_shift_invariant() {
        let a = softmax(&Tensor::from_vec(&[1, 2], vec![1., 2.]));
        let b = softmax(&Tensor::from_vec(&[1, 2], vec![101., 102.]));
        assert!(a.max_abs_diff(&b) < 1e-6);
    }
}
