//! Pooling layers (VALID windows, stride = window unless given), exact math.

use crate::nn::tensor::Tensor;

use super::conv::dims4;

pub fn maxpool(x: &Tensor, kh: usize, kw: usize, stride: usize) -> Tensor {
    let (b, h, w, c) = dims4(x);
    let (oh, ow) = ((h - kh) / stride + 1, (w - kw) / stride + 1);
    let mut out = Tensor::filled(&[b, oh, ow, c], f32::NEG_INFINITY);
    for n in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let dst = out.pixel_mut(n, oy, ox);
                for ky in 0..kh {
                    for kx in 0..kw {
                        let px = x.pixel(n, oy * stride + ky, ox * stride + kx);
                        for ci in 0..c {
                            if px[ci] > dst[ci] {
                                dst[ci] = px[ci];
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

pub fn avgpool(x: &Tensor, kh: usize, kw: usize, stride: usize) -> Tensor {
    let (b, h, w, c) = dims4(x);
    let (oh, ow) = ((h - kh) / stride + 1, (w - kw) / stride + 1);
    let inv = 1.0 / (kh * kw) as f32;
    let mut out = Tensor::zeros(&[b, oh, ow, c]);
    for n in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let dst = out.pixel_mut(n, oy, ox);
                for ky in 0..kh {
                    for kx in 0..kw {
                        let px = x.pixel(n, oy * stride + ky, ox * stride + kx);
                        for ci in 0..c {
                            dst[ci] += px[ci];
                        }
                    }
                }
                for v in dst.iter_mut() {
                    *v *= inv;
                }
            }
        }
    }
    out
}

/// Global average pool: `[B, H, W, C]` → `[B, C]`.
pub fn globalavgpool(x: &Tensor) -> Tensor {
    let (b, h, w, c) = dims4(x);
    let inv = 1.0 / (h * w) as f32;
    let mut out = Tensor::zeros(&[b, c]);
    for n in 0..b {
        for y in 0..h {
            for xx in 0..w {
                let px = x.pixel(n, y, xx);
                let dst = &mut out.data_mut()[n * c..(n + 1) * c];
                for ci in 0..c {
                    dst[ci] += px[ci];
                }
            }
        }
    }
    for v in out.data_mut() {
        *v *= inv;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maxpool_2x2() {
        let x = Tensor::from_vec(&[1, 2, 2, 1], vec![1., 5., 3., 2.]);
        let y = maxpool(&x, 2, 2, 2);
        assert_eq!(y.shape(), &[1, 1, 1, 1]);
        assert_eq!(y.data(), &[5.0]);
    }

    #[test]
    fn maxpool_negative_values() {
        let x = Tensor::from_vec(&[1, 2, 2, 1], vec![-1., -5., -3., -2.]);
        assert_eq!(maxpool(&x, 2, 2, 2).data(), &[-1.0]);
    }

    #[test]
    fn avgpool_2x2() {
        let x = Tensor::from_vec(&[1, 2, 2, 1], vec![1., 5., 3., 3.]);
        assert_eq!(avgpool(&x, 2, 2, 2).data(), &[3.0]);
    }

    #[test]
    fn global_avg() {
        let x = Tensor::from_vec(&[1, 2, 2, 2], vec![1., 10., 2., 20., 3., 30., 4., 40.]);
        let y = globalavgpool(&x);
        assert_eq!(y.shape(), &[1, 2]);
        assert_eq!(y.data(), &[2.5, 25.0]);
    }

    #[test]
    fn pool_channels_independent() {
        let x = Tensor::from_vec(&[1, 2, 2, 2], vec![1., 8., 5., 2., 3., 6., 7., 4.]);
        let y = maxpool(&x, 2, 2, 2);
        assert_eq!(y.data(), &[7., 8.]);
    }
}
