//! Exact (scalar, f32) convolution layers — the reference semantics every
//! other engine is checked against. Kernel layout HWIO, tensors NHWC,
//! SAME/VALID padding matching XLA/Keras.

use crate::model::spec::{same_pads, Padding};
use crate::nn::tensor::Tensor;

/// Standard 2-D convolution. `kernel` is `[kh, kw, in_ch, out_ch]` (HWIO).
pub fn conv2d(
    x: &Tensor,
    kernel: &[f32],
    kshape: &[usize],
    bias: Option<&[f32]>,
    stride: usize,
    padding: Padding,
) -> Tensor {
    let (b, h, w, c) = dims4(x);
    let (kh, kw, kc, oc) = (kshape[0], kshape[1], kshape[2], kshape[3]);
    assert_eq!(kc, c, "kernel in_ch {kc} != input channels {c}");
    let ((pt, _pb), (pl, _pr)) = pads(h, w, kh, kw, stride, padding);
    let (oh, ow) = out_dims(h, w, kh, kw, stride, padding);

    let mut out = Tensor::zeros(&[b, oh, ow, oc]);
    for n in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let dst = out.pixel_mut(n, oy, ox);
                if let Some(bs) = bias {
                    dst.copy_from_slice(bs);
                }
                for ky in 0..kh {
                    let iy = (oy * stride + ky) as isize - pt as isize;
                    if iy < 0 || iy as usize >= h {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = (ox * stride + kx) as isize - pl as isize;
                        if ix < 0 || ix as usize >= w {
                            continue;
                        }
                        let px = x.pixel(n, iy as usize, ix as usize);
                        let kbase = (ky * kw + kx) * c * oc;
                        // Inner product per output channel: this is the
                        // matrix-vector product the paper identifies as the
                        // core operation (§3.3) — naive scalar form here.
                        for (ci, &xv) in px.iter().enumerate() {
                            let krow = &kernel[kbase + ci * oc..kbase + (ci + 1) * oc];
                            for (o, &kv) in krow.iter().enumerate() {
                                dst[o] += xv * kv;
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// Depthwise 2-D convolution, `kernel` `[kh, kw, ch, 1]` (Keras layout).
pub fn depthwise_conv2d(
    x: &Tensor,
    kernel: &[f32],
    kshape: &[usize],
    bias: Option<&[f32]>,
    stride: usize,
    padding: Padding,
) -> Tensor {
    let (b, h, w, c) = dims4(x);
    let (kh, kw, kc) = (kshape[0], kshape[1], kshape[2]);
    assert_eq!(kc, c);
    assert_eq!(kshape[3], 1, "depth multiplier > 1 unsupported");
    let ((pt, _), (pl, _)) = pads(h, w, kh, kw, stride, padding);
    let (oh, ow) = out_dims(h, w, kh, kw, stride, padding);

    let mut out = Tensor::zeros(&[b, oh, ow, c]);
    for n in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let dst = out.pixel_mut(n, oy, ox);
                if let Some(bs) = bias {
                    dst.copy_from_slice(bs);
                }
                for ky in 0..kh {
                    let iy = (oy * stride + ky) as isize - pt as isize;
                    if iy < 0 || iy as usize >= h {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = (ox * stride + kx) as isize - pl as isize;
                        if ix < 0 || ix as usize >= w {
                            continue;
                        }
                        let px = x.pixel(n, iy as usize, ix as usize);
                        let kbase = (ky * kw + kx) * c;
                        for ci in 0..c {
                            dst[ci] += px[ci] * kernel[kbase + ci];
                        }
                    }
                }
            }
        }
    }
    out
}

pub(crate) fn dims4(x: &Tensor) -> (usize, usize, usize, usize) {
    let s = x.shape();
    assert_eq!(s.len(), 4, "expected NHWC, got {s:?}");
    (s[0], s[1], s[2], s[3])
}

fn pads(
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    padding: Padding,
) -> ((usize, usize), (usize, usize)) {
    match padding {
        Padding::Same => (same_pads(h, kh, stride), same_pads(w, kw, stride)),
        Padding::Valid => ((0, 0), (0, 0)),
    }
}

fn out_dims(h: usize, w: usize, kh: usize, kw: usize, stride: usize, padding: Padding) -> (usize, usize) {
    crate::model::spec::conv_out(h, w, kh, kw, stride, padding)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_kernel_1x1() {
        // 1x1 conv with identity matrix kernel = passthrough
        let x = Tensor::from_vec(&[1, 2, 2, 2], vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        let kernel = vec![1., 0., 0., 1.]; // [1,1,2,2] identity
        let y = conv2d(&x, &kernel, &[1, 1, 2, 2], None, 1, Padding::Same);
        assert_eq!(y.data(), x.data());
    }

    #[test]
    fn valid_3x3_sum_kernel() {
        // 3x3 all-ones kernel over a 3x3 ones image, VALID → single 9.0
        let x = Tensor::filled(&[1, 3, 3, 1], 1.0);
        let kernel = vec![1.0; 9];
        let y = conv2d(&x, &kernel, &[3, 3, 1, 1], None, 1, Padding::Valid);
        assert_eq!(y.shape(), &[1, 1, 1, 1]);
        assert_eq!(y.data()[0], 9.0);
    }

    #[test]
    fn same_padding_border() {
        // SAME keeps shape; corners see only 4 of 9 taps.
        let x = Tensor::filled(&[1, 3, 3, 1], 1.0);
        let kernel = vec![1.0; 9];
        let y = conv2d(&x, &kernel, &[3, 3, 1, 1], None, 1, Padding::Same);
        assert_eq!(y.shape(), &[1, 3, 3, 1]);
        assert_eq!(y.at4(0, 1, 1, 0), 9.0);
        assert_eq!(y.at4(0, 0, 0, 0), 4.0);
        assert_eq!(y.at4(0, 0, 1, 0), 6.0);
    }

    #[test]
    fn stride2_shape() {
        let x = Tensor::filled(&[1, 8, 8, 1], 1.0);
        let y = conv2d(&x, &vec![1.0; 9], &[3, 3, 1, 1], None, 2, Padding::Same);
        assert_eq!(y.shape(), &[1, 4, 4, 1]);
    }

    #[test]
    fn bias_applies() {
        let x = Tensor::zeros(&[1, 2, 2, 1]);
        let y = conv2d(&x, &[0.0], &[1, 1, 1, 1], Some(&[2.5]), 1, Padding::Same);
        assert!(y.data().iter().all(|&v| v == 2.5));
    }

    #[test]
    fn conv_1x1_is_a_per_pixel_matvec() {
        // 1×1 conv ≡ channel matvec: hand-computed against K = [[1,2],[3,4]]
        // (HWIO: k[ci*oc + o]). Pixel [1,2] → [1·1+2·3, 1·2+2·4] = [7,10].
        let x = Tensor::from_vec(&[1, 1, 2, 2], vec![1., 2., 3., 4.]);
        let kernel = vec![1., 2., 3., 4.];
        let y = conv2d(&x, &kernel, &[1, 1, 2, 2], None, 1, Padding::Same);
        assert_eq!(y.shape(), &[1, 1, 2, 2]);
        assert_eq!(y.data(), &[7., 10., 15., 22.]);
    }

    #[test]
    fn same_padding_kernel_larger_than_input() {
        // 5×5 kernel over a 3×3 input under SAME: every window covers the
        // whole input (pads (2,2) both axes), so with an all-ones kernel
        // every output pixel is the full input sum = 9.
        let x = Tensor::filled(&[1, 3, 3, 1], 1.0);
        let kernel = vec![1.0; 25];
        let y = conv2d(&x, &kernel, &[5, 5, 1, 1], None, 1, Padding::Same);
        assert_eq!(y.shape(), &[1, 3, 3, 1]);
        assert!(y.data().iter().all(|&v| v == 9.0), "{:?}", y.data());
    }

    #[test]
    fn stride2_output_rounding_same_vs_valid() {
        // 5-wide input, 2×2 kernel, stride 2:
        //   SAME  → ceil(5/2) = 3 columns (XLA pads (0,1): the last window
        //           hangs one column off the edge)
        //   VALID → (5-2)/2+1 = 2 columns
        let data: Vec<f32> = (1..=25).map(|v| v as f32).collect(); // row-major 1..25
        let x = Tensor::from_vec(&[1, 5, 5, 1], data);
        let kernel = vec![1.0; 4]; // 2×2 sum

        let same = conv2d(&x, &kernel, &[2, 2, 1, 1], None, 2, Padding::Same);
        assert_eq!(same.shape(), &[1, 3, 3, 1]);
        // window at (0,0): rows 0-1 × cols 0-1 = 1+2+6+7 = 16
        assert_eq!(same.at4(0, 0, 0, 0), 16.0);
        // (0,2): cols 4-5, right column padded → 5+10 = 15
        assert_eq!(same.at4(0, 0, 2, 0), 15.0);
        // (2,0): rows 4-5, bottom row padded → 21+22 = 43
        assert_eq!(same.at4(0, 2, 0, 0), 43.0);
        // (2,2): only pixel 25 in bounds
        assert_eq!(same.at4(0, 2, 2, 0), 25.0);

        let valid = conv2d(&x, &kernel, &[2, 2, 1, 1], None, 2, Padding::Valid);
        assert_eq!(valid.shape(), &[1, 2, 2, 1]);
        assert_eq!(valid.at4(0, 0, 0, 0), 16.0); // 1+2+6+7
        assert_eq!(valid.at4(0, 1, 1, 0), 13.0 + 14.0 + 18.0 + 19.0);
    }

    #[test]
    fn depthwise_independent_channels() {
        // channel 0 kernel = 1, channel 1 kernel = 2 (1x1 taps)
        let x = Tensor::from_vec(&[1, 1, 2, 2], vec![1., 10., 2., 20.]);
        let y = depthwise_conv2d(&x, &[1., 2.], &[1, 1, 2, 1], None, 1, Padding::Same);
        assert_eq!(y.data(), &[1., 20., 2., 40.]);
    }
}
