//! Shape/combination layers: upsample (nearest), zero padding, flatten,
//! concat (channel axis), elementwise add.

use crate::nn::tensor::Tensor;

use super::conv::dims4;

/// Nearest-neighbour upsampling by an integer factor.
pub fn upsample(x: &Tensor, factor: usize) -> Tensor {
    let (b, h, w, c) = dims4(x);
    let mut out = Tensor::zeros(&[b, h * factor, w * factor, c]);
    for n in 0..b {
        for y in 0..h * factor {
            for xx in 0..w * factor {
                let src = x.pixel(n, y / factor, xx / factor).to_vec();
                out.pixel_mut(n, y, xx).copy_from_slice(&src);
            }
        }
    }
    out
}

/// Zero padding `[top, bottom, left, right]` on the spatial dims.
pub fn zeropad(x: &Tensor, pad: [usize; 4]) -> Tensor {
    let (b, h, w, c) = dims4(x);
    let [t, bo, l, r] = pad;
    let mut out = Tensor::zeros(&[b, h + t + bo, w + l + r, c]);
    for n in 0..b {
        for y in 0..h {
            for xx in 0..w {
                let src = x.pixel(n, y, xx).to_vec();
                out.pixel_mut(n, y + t, xx + l).copy_from_slice(&src);
            }
        }
    }
    out
}

/// `[B, ...]` → `[B, prod(...)]` (NHWC row-major keeps data order).
pub fn flatten(x: &Tensor) -> Tensor {
    let b = x.shape()[0];
    let rest: usize = x.shape()[1..].iter().product();
    x.clone().reshaped(&[b, rest])
}

/// Concatenate along the channel (last) axis.
pub fn concat(a: &Tensor, b: &Tensor) -> Tensor {
    let (ba, ha, wa, ca) = dims4(a);
    let (bb, hb, wb, cb) = dims4(b);
    assert_eq!((ba, ha, wa), (bb, hb, wb), "concat spatial mismatch");
    let mut out = Tensor::zeros(&[ba, ha, wa, ca + cb]);
    for n in 0..ba {
        for y in 0..ha {
            for x_ in 0..wa {
                let dst = out.pixel_mut(n, y, x_);
                dst[..ca].copy_from_slice(a.pixel(n, y, x_));
                dst[ca..].copy_from_slice(b.pixel(n, y, x_));
            }
        }
    }
    out
}

/// Elementwise addition of same-shaped tensors.
pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape(), b.shape(), "add shape mismatch");
    let mut out = a.clone();
    for (o, &v) in out.data_mut().iter_mut().zip(b.data()) {
        *o += v;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn upsample_2x() {
        let x = Tensor::from_vec(&[1, 1, 2, 1], vec![1., 2.]);
        let y = upsample(&x, 2);
        assert_eq!(y.shape(), &[1, 2, 4, 1]);
        assert_eq!(y.data(), &[1., 1., 2., 2., 1., 1., 2., 2.]);
    }

    #[test]
    fn zeropad_border() {
        let x = Tensor::filled(&[1, 1, 1, 1], 5.0);
        let y = zeropad(&x, [1, 0, 0, 1]);
        assert_eq!(y.shape(), &[1, 2, 2, 1]);
        assert_eq!(y.data(), &[0., 0., 5., 0.]);
    }

    #[test]
    fn concat_channels() {
        let a = Tensor::from_vec(&[1, 1, 1, 2], vec![1., 2.]);
        let b = Tensor::from_vec(&[1, 1, 1, 1], vec![9.]);
        assert_eq!(concat(&a, &b).data(), &[1., 2., 9.]);
    }

    #[test]
    fn add_elementwise() {
        let a = Tensor::from_vec(&[1, 1, 1, 2], vec![1., 2.]);
        let b = Tensor::from_vec(&[1, 1, 1, 2], vec![10., 20.]);
        assert_eq!(add(&a, &b).data(), &[11., 22.]);
    }

    #[test]
    fn flatten_keeps_order() {
        let x = Tensor::from_vec(&[2, 1, 1, 2], vec![1., 2., 3., 4.]);
        let y = flatten(&x);
        assert_eq!(y.shape(), &[2, 2]);
        assert_eq!(y.data(), &[1., 2., 3., 4.]);
    }
}
