//! Exact dense (fully connected) layer: `y = x W + b`, kernel `[in, out]`.

use crate::nn::tensor::Tensor;

/// `x` is `[batch, in]`; returns `[batch, out]`.
pub fn dense(x: &Tensor, kernel: &[f32], kshape: &[usize], bias: Option<&[f32]>) -> Tensor {
    let s = x.shape();
    assert_eq!(s.len(), 2, "dense expects [batch, in], got {s:?}");
    let (b, in_dim) = (s[0], s[1]);
    let (ki, ko) = (kshape[0], kshape[1]);
    assert_eq!(ki, in_dim, "dense kernel in {ki} != input {in_dim}");

    let mut out = Tensor::zeros(&[b, ko]);
    for n in 0..b {
        let xrow = &x.data()[n * in_dim..(n + 1) * in_dim];
        let orow = &mut out.data_mut()[n * ko..(n + 1) * ko];
        if let Some(bs) = bias {
            orow.copy_from_slice(bs);
        }
        // No zero-input skip: it was a data-dependent branch in the hot
        // loop, and 0·Inf = NaN must propagate (IEEE 754) for the oracle
        // to agree with the compiled engines on non-finite weights.
        for (i, &xv) in xrow.iter().enumerate() {
            let krow = &kernel[i * ko..(i + 1) * ko];
            for (o, &kv) in krow.iter().enumerate() {
                orow[o] += xv * kv;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_2x3() {
        // x = [1, 2], W = [[1, 2, 3], [4, 5, 6]] → y = [9, 12, 15]
        let x = Tensor::from_vec(&[1, 2], vec![1., 2.]);
        let y = dense(&x, &[1., 2., 3., 4., 5., 6.], &[2, 3], None);
        assert_eq!(y.data(), &[9., 12., 15.]);
    }

    #[test]
    fn bias_and_batch() {
        let x = Tensor::from_vec(&[2, 2], vec![1., 0., 0., 1.]);
        let y = dense(&x, &[1., 2., 3., 4.], &[2, 2], Some(&[10., 20.]));
        assert_eq!(y.data(), &[11., 22., 13., 24.]);
    }
}
