//! `NaiveInterp` — the exact, scalar, dynamically-dispatched graph
//! interpreter. This is the `SimpleNN` class from the paper (§3.1): "a
//! straightforward, but slow implementation … written to be as exact in its
//! calculations as possible, it can be used to benchmark the compiler in
//! terms of numeric precision". It doubles as our analog of the
//! interpreter-style libraries in Table 1 (tiny-dnn / frugally-deep).

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::model::spec::{Activation, Layer, LayerOp, ModelSpec};
use crate::nn::layers::{conv, dense, norm_act, pool, shape_ops};
use crate::nn::tensor::Tensor;

pub struct NaiveInterp {
    spec: ModelSpec,
}

impl NaiveInterp {
    pub fn new(spec: ModelSpec) -> Result<Self> {
        spec.validate()?;
        Ok(Self { spec })
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    /// Run the forward pass on `[B, H, W, C]` (or `[B, n]`) input.
    pub fn infer(&self, input: &Tensor) -> Result<Vec<Tensor>> {
        let mut env: HashMap<&str, Tensor> = HashMap::new();
        env.insert("input", input.clone());
        for l in &self.spec.layers {
            let out = self.run_layer(l, &env)?;
            env.insert(l.name.as_str(), out);
        }
        self.spec
            .outputs
            .iter()
            .map(|o| {
                env.get(o.as_str())
                    .cloned()
                    .ok_or_else(|| anyhow::anyhow!("missing output `{o}`"))
            })
            .collect()
    }

    fn run_layer(&self, l: &Layer, env: &HashMap<&str, Tensor>) -> Result<Tensor> {
        let x = match env.get(l.inputs[0].as_str()) {
            Some(t) => t,
            None => bail!("layer `{}` input `{}` missing", l.name, l.inputs[0]),
        };
        let spec = &self.spec;
        let mut y = match &l.op {
            LayerOp::Conv2d { stride, padding, use_bias, .. } => {
                let k = spec.weight_ref(l, "kernel")?;
                let bias = if *use_bias { Some(spec.weight(l, "bias")?) } else { None };
                conv::conv2d(x, spec.weight(l, "kernel")?, &k.shape, bias, *stride, *padding)
            }
            LayerOp::DepthwiseConv2d { stride, padding, use_bias, .. } => {
                let k = spec.weight_ref(l, "kernel")?;
                let bias = if *use_bias { Some(spec.weight(l, "bias")?) } else { None };
                conv::depthwise_conv2d(x, spec.weight(l, "kernel")?, &k.shape, bias, *stride, *padding)
            }
            LayerOp::Dense { .. } => {
                let k = spec.weight_ref(l, "kernel")?;
                dense::dense(x, spec.weight(l, "kernel")?, &k.shape, spec.weight(l, "bias").ok())
            }
            LayerOp::BatchNorm { epsilon } => norm_act::batchnorm(
                x,
                spec.weight(l, "gamma")?,
                spec.weight(l, "beta")?,
                spec.weight(l, "mean")?,
                spec.weight(l, "var")?,
                *epsilon,
            ),
            LayerOp::MaxPool { kh, kw, stride } => pool::maxpool(x, *kh, *kw, *stride),
            LayerOp::AvgPool { kh, kw, stride } => pool::avgpool(x, *kh, *kw, *stride),
            LayerOp::GlobalAvgPool => pool::globalavgpool(x),
            LayerOp::Upsample { factor } => shape_ops::upsample(x, *factor),
            LayerOp::ZeroPad { pad } => shape_ops::zeropad(x, *pad),
            LayerOp::Activation => x.clone(),
            LayerOp::Softmax => norm_act::softmax(x),
            LayerOp::Add => shape_ops::add(x, env[l.inputs[1].as_str()].borrow_tensor()),
            LayerOp::Concat => shape_ops::concat(x, env[l.inputs[1].as_str()].borrow_tensor()),
            LayerOp::Flatten => shape_ops::flatten(x),
        };
        norm_act::apply_activation(&mut y, l.activation);
        if l.post_scale {
            // §3.5: BN folded across the activation → affine after it.
            y = norm_act::affine_channels(
                &y,
                spec.weight(l, "post_scale_w")?,
                spec.weight(l, "post_shift_w")?,
            );
        }
        Ok(y)
    }
}

impl crate::engine::Engine for NaiveInterp {
    fn name(&self) -> &str {
        "naive"
    }

    fn infer(&mut self, input: &Tensor) -> Result<Vec<Tensor>> {
        NaiveInterp::infer(self, input)
    }

    fn supports(&self, spec: &ModelSpec) -> bool {
        Capabilities::FULL.supports(spec)
    }
}

// Small helper so env lookups above read uniformly.
trait BorrowTensor {
    fn borrow_tensor(&self) -> &Tensor;
}
impl BorrowTensor for Tensor {
    fn borrow_tensor(&self) -> &Tensor {
        self
    }
}

/// Which ops an engine supports; used to reproduce the `–` cells of Table 1
/// (RoboDNN / tiny-dnn lack upsampling and depthwise-separable convolution).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Capabilities {
    pub upsample: bool,
    pub depthwise: bool,
}

impl Capabilities {
    pub const FULL: Capabilities = Capabilities { upsample: true, depthwise: true };
    /// RoboDNN/tiny-dnn-like feature set (for the capability ablation).
    pub const LEGACY: Capabilities = Capabilities { upsample: false, depthwise: false };

    pub fn supports(&self, spec: &ModelSpec) -> bool {
        spec.layers.iter().all(|l| match l.op {
            LayerOp::Upsample { .. } => self.upsample,
            LayerOp::DepthwiseConv2d { .. } => self.depthwise,
            _ => true,
        })
    }
}

/// Exact activation used by tests needing scalar access.
pub fn activate(a: Activation, v: f32) -> f32 {
    norm_act::activate_exact(a, v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builder::tiny_cnn;

    #[test]
    fn runs_tiny_cnn() {
        let interp = NaiveInterp::new(tiny_cnn(7)).unwrap();
        let x = Tensor::filled(&[2, 8, 8, 3], 0.5);
        let out = interp.infer(&x).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape(), &[2, 10]);
        // softmax rows sum to 1
        for row in out[0].data().chunks_exact(10) {
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn batch_equals_singles() {
        let interp = NaiveInterp::new(tiny_cnn(8)).unwrap();
        let mut rng = crate::util::rng::SplitMix64::new(42);
        let x = Tensor::from_vec(&[3, 8, 8, 3], rng.uniform_vec(3 * 8 * 8 * 3));
        let full = interp.infer(&x).unwrap();
        for i in 0..3 {
            let one = interp.infer(&x.slice_batch(i, i + 1)).unwrap();
            assert!(one[0].max_abs_diff(&full[0].slice_batch(i, i + 1)) < 1e-6);
        }
    }

    #[test]
    fn capabilities_gate() {
        let spec = tiny_cnn(1);
        assert!(Capabilities::FULL.supports(&spec));
        assert!(Capabilities::LEGACY.supports(&spec)); // no upsample/dw in tiny
    }
}
