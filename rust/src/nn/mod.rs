//! Neural-network substrate: tensors, exact layers, the naive interpreter,
//! and the 4-wide §3.3 matvec kernels.
pub mod interp;
pub mod layers;
pub mod simd;
pub mod tensor;
