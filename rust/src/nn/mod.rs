//! Neural-network substrate: tensors, exact layers, the naive interpreter,
//! and the 4-wide §3.3 matvec kernels.
#[allow(missing_docs)]
pub mod interp;
#[allow(missing_docs)]
pub mod layers;
pub mod simd;
#[allow(missing_docs)]
pub mod tensor;
