//! Allocation-free layer kernels used by the optimized interpreter: each
//! writes into a caller-provided buffer and applies the fused epilogue
//! (activation + §3.5 post-affine) **in the store loop** — the paper's §3.4
//! fusion ("the activation function is applied before writing the result of
//! the operation into memory").
//!
//! Convolution additionally fuses a single-consumer following MaxPool into
//! the same store loop ([`conv2d_run`] with `pool`): each output pixel is
//! computed, activated, then max-merged straight into the pool cell, so the
//! conv intermediate never materializes in the arena.
//!
//! Since PR 7 the blocked paths are **width-generic**: every panel kernel
//! and lane epilogue is instantiated at 1/4/8/16 lanes (see
//! [`crate::nn::simd`] and [`crate::cpu`]), and the width baked into the
//! algo at lowering selects the instantiation via a four-way dispatch at
//! the top of [`conv2d_run`] / [`dense_run`]. The same entry points also
//! carry the lowering-planned intra-op `tasks` count: when > 1, the output
//! is partitioned into contiguous bands (conv output rows / pool rows /
//! batch items) executed on scoped threads against disjoint out and
//! scratch spans — banding is bitwise-neutral because every band runs the
//! identical per-pixel / per-item code, and tile vs. tail agreement is
//! pinned by `nn::simd`'s bit-equality properties.

use crate::approx;
use crate::compiler::artifact::{corrupt, ArtifactError, Decoder, Encoder, PanelStore};
use crate::model::spec::{same_pads, Activation, Padding};
use crate::nn::simd;

/// Fused store epilogue: activation (exact or §3.4 approximation) followed
/// by the optional folded-BN affine.
#[derive(Clone, Copy)]
pub struct Epilogue<'a> {
    /// Activation applied to every stored element.
    pub act: Activation,
    /// Use the §3.4 fast approximations for sigmoid/tanh stores.
    pub approx: bool,
    /// Folded-BN per-channel `(scale, shift)` applied after the activation.
    pub post: Option<(&'a [f32], &'a [f32])>,
}

impl<'a> Epilogue<'a> {
    /// Identity epilogue: linear activation, exact math, no post-affine.
    pub const NONE: Epilogue<'static> =
        Epilogue { act: Activation::Linear, approx: false, post: None };

    #[inline(always)]
    fn activate(&self, v: f32) -> f32 {
        match self.act {
            Activation::Linear => v,
            Activation::Relu => v.max(0.0),
            Activation::Relu6 => v.clamp(0.0, 6.0),
            Activation::LeakyRelu => {
                if v >= 0.0 {
                    v
                } else {
                    0.1 * v
                }
            }
            Activation::Sigmoid => {
                if self.approx {
                    approx::fast_sigmoid(v)
                } else {
                    1.0 / (1.0 + (-v).exp())
                }
            }
            Activation::Tanh => {
                if self.approx {
                    approx::fast_tanh(v)
                } else {
                    v.tanh()
                }
            }
        }
    }

    /// Apply to a channel vector in place.
    #[inline(always)]
    pub fn apply(&self, dst: &mut [f32]) {
        match self.post {
            None => {
                for v in dst.iter_mut() {
                    *v = self.activate(*v);
                }
            }
            Some((scale, shift)) => {
                for (c, v) in dst.iter_mut().enumerate() {
                    *v = self.activate(*v) * scale[c] + shift[c];
                }
            }
        }
    }

    /// Apply over a whole buffer of row-major `c`-channel vectors (the
    /// post-affine is channel-cyclic; a bare activation is elementwise and
    /// takes one whole-slice pass).
    pub fn apply_whole(&self, buf: &mut [f32], c: usize) {
        if self.post.is_none() {
            self.apply(buf);
        } else {
            for chunk in buf.chunks_mut(c) {
                self.apply(chunk);
            }
        }
    }

    /// The vectorized §3.4 epilogue: apply to one full `W`-lane store group
    /// whose first lane is channel `c0` (`c0 + W` must not exceed the real
    /// channel count — tail groups take [`Epilogue::apply_channels`]).
    /// One `act` dispatch per group instead of per element, and the
    /// activation approximations run their lane forms
    /// ([`approx::fast_tanh_w`] / [`approx::fast_sigmoid_w`]), which are
    /// bit-identical to the scalar functions per lane at every width — so
    /// the blocked store loops and the scalar reference epilogue can never
    /// drift, whatever instantiation the dispatch picked.
    #[inline(always)]
    pub fn apply_lanes_w<const W: usize>(&self, lanes: &mut [f32; W], c0: usize) {
        match self.act {
            Activation::Linear => {}
            Activation::Relu => {
                for v in lanes.iter_mut() {
                    *v = v.max(0.0);
                }
            }
            Activation::Relu6 => {
                for v in lanes.iter_mut() {
                    *v = v.clamp(0.0, 6.0);
                }
            }
            Activation::LeakyRelu => {
                for v in lanes.iter_mut() {
                    *v = if *v >= 0.0 { *v } else { 0.1 * *v };
                }
            }
            Activation::Sigmoid => {
                if self.approx {
                    approx::fast_sigmoid_w::<W>(lanes);
                } else {
                    for v in lanes.iter_mut() {
                        *v = 1.0 / (1.0 + (-*v).exp());
                    }
                }
            }
            Activation::Tanh => {
                if self.approx {
                    approx::fast_tanh_w::<W>(lanes);
                } else {
                    for v in lanes.iter_mut() {
                        *v = v.tanh();
                    }
                }
            }
        }
        if let Some((scale, shift)) = self.post {
            for (l, v) in lanes.iter_mut().enumerate() {
                *v = *v * scale[c0 + l] + shift[c0 + l];
            }
        }
    }

    /// The 4-lane (SSE-shaped) instantiation of [`Epilogue::apply_lanes_w`].
    #[inline(always)]
    pub fn apply_lanes(&self, lanes: &mut [f32; 4], c0: usize) {
        self.apply_lanes_w::<4>(lanes, c0)
    }

    /// Scalar epilogue over a channel sub-range whose first element is
    /// channel `c0` — the tail-group path of the blocked store loops
    /// (fewer than 4 real lanes left).
    #[inline(always)]
    pub fn apply_channels(&self, dst: &mut [f32], c0: usize) {
        match self.post {
            None => {
                for v in dst.iter_mut() {
                    *v = self.activate(*v);
                }
            }
            Some((scale, shift)) => {
                for (i, v) in dst.iter_mut().enumerate() {
                    *v = self.activate(*v) * scale[c0 + i] + shift[c0 + i];
                }
            }
        }
    }
}

/// Packed weight panels tagged with their storage element type — the §3.3
/// lowering's dtype decision materialized. Every variant shares the same
/// `[block][tap][lane]` panel layout (see [`simd::pack_conv_panels_we`]);
/// narrow variants are widened to f32 lane-by-lane inside the FMA stream,
/// so the accumulation *order* is identical across dtypes and only the
/// stored weight values differ.
#[derive(Clone)]
pub enum WeightPanels {
    /// Full-precision panels — the default, and the only storage
    /// `bit_exact()` permits.
    F32(PanelStore<f32>),
    /// bf16 panels (round-to-nearest-even at pack time), widened to f32 in
    /// the microkernel — half the weight bandwidth of `F32`.
    Bf16(PanelStore<u16>),
    /// Post-training per-output-channel i8 quantization: `data ≈ w /
    /// scales[o]`, accumulated in f32 from a **zero** start and dequantized
    /// in the store loop (`acc * scales[o] + bias[o]`) before the
    /// activation — a quarter of the weight bandwidth of `F32`.
    I8 {
        /// Quantized panels in the shared layout.
        data: PanelStore<i8>,
        /// Per-output-channel dequantization scales (`len == oc`).
        scales: Vec<f32>,
    },
}

impl WeightPanels {
    /// Pack conv HWIO weights (`taps = kh*kw*c` rows × `oc` columns) at
    /// `lanes` under `dtype`.
    pub fn pack_conv(
        kernel: &[f32],
        taps: usize,
        oc: usize,
        lanes: usize,
        dtype: simd::WeightDtype,
    ) -> WeightPanels {
        match dtype {
            simd::WeightDtype::F32 => {
                WeightPanels::F32(simd::pack_conv_panels_any(kernel, taps, oc, lanes).into())
            }
            simd::WeightDtype::Bf16 => {
                let bf: Vec<u16> = kernel.iter().map(|&v| simd::f32_to_bf16(v)).collect();
                WeightPanels::Bf16(simd::pack_conv_panels_any_e(&bf, taps, oc, lanes).into())
            }
            simd::WeightDtype::I8 => {
                let (q, scales) = simd::quantize_i8_per_channel(kernel, taps, oc);
                WeightPanels::I8 {
                    data: simd::pack_conv_panels_any_e(&q, taps, oc, lanes).into(),
                    scales,
                }
            }
        }
    }

    /// Pack dense `[in_dim, units]` weights at `lanes` under `dtype`.
    pub fn pack_dense(
        kernel: &[f32],
        in_dim: usize,
        out_dim: usize,
        lanes: usize,
        dtype: simd::WeightDtype,
    ) -> WeightPanels {
        match dtype {
            simd::WeightDtype::F32 => WeightPanels::F32(
                simd::pack_dense_panels_any(kernel, in_dim, out_dim, lanes).into(),
            ),
            simd::WeightDtype::Bf16 => {
                let bf: Vec<u16> = kernel.iter().map(|&v| simd::f32_to_bf16(v)).collect();
                WeightPanels::Bf16(
                    simd::pack_dense_panels_any_e(&bf, in_dim, out_dim, lanes).into(),
                )
            }
            simd::WeightDtype::I8 => {
                let (q, scales) = simd::quantize_i8_per_channel(kernel, in_dim, out_dim);
                WeightPanels::I8 {
                    data: simd::pack_dense_panels_any_e(&q, in_dim, out_dim, lanes).into(),
                    scales,
                }
            }
        }
    }

    /// The storage element type of the panels.
    pub fn dtype(&self) -> simd::WeightDtype {
        match self {
            WeightPanels::F32(_) => simd::WeightDtype::F32,
            WeightPanels::Bf16(_) => simd::WeightDtype::Bf16,
            WeightPanels::I8 { .. } => simd::WeightDtype::I8,
        }
    }

    /// Per-output-channel dequantization scales (i8 only).
    pub fn scales(&self) -> Option<&[f32]> {
        match self {
            WeightPanels::I8 { scales, .. } => Some(scales),
            _ => None,
        }
    }

    /// Bytes of packed weight storage one full pass streams (panel data
    /// plus the i8 scale vector) — the number the cost model and
    /// `PlanSummary` byte accounting price.
    pub fn weight_bytes(&self) -> usize {
        match self {
            WeightPanels::F32(p) => p.len() * 4,
            WeightPanels::Bf16(p) => p.len() * 2,
            WeightPanels::I8 { data, scales } => data.len() + scales.len() * 4,
        }
    }

    /// Packed panel element count (zero padding included, the i8 scale
    /// vector excluded — [`WeightPanels::weight_bytes`] prices that).
    pub fn elems(&self) -> usize {
        match self {
            WeightPanels::F32(p) => p.len(),
            WeightPanels::Bf16(p) => p.len(),
            WeightPanels::I8 { data, .. } => data.len(),
        }
    }

    /// Serialize to an artifact: a dtype tag, the panel array appended to
    /// the 64-byte-aligned blob, and (for i8) the scale vector inline.
    pub(crate) fn encode(&self, e: &mut Encoder) {
        match self {
            WeightPanels::F32(p) => {
                e.u8(0);
                e.blob_of::<f32>(p);
            }
            WeightPanels::Bf16(p) => {
                e.u8(1);
                e.blob_of::<u16>(p);
            }
            WeightPanels::I8 { data, scales } => {
                e.u8(2);
                e.blob_of::<i8>(data);
                e.vec_f32(scales);
            }
        }
    }

    /// Deserialize from an artifact: the panels come back as zero-copy
    /// windows into the mapped blob — no unpack, no quantization.
    pub(crate) fn decode(d: &mut Decoder) -> Result<WeightPanels, ArtifactError> {
        match d.u8()? {
            0 => Ok(WeightPanels::F32(d.blob_store::<f32>()?)),
            1 => Ok(WeightPanels::Bf16(d.blob_store::<u16>()?)),
            2 => {
                let data = d.blob_store::<i8>()?;
                let scales = d.vec_f32()?;
                Ok(WeightPanels::I8 { data, scales })
            }
            t => Err(corrupt(format!("invalid panel dtype tag {t}"))),
        }
    }
}

/// How one conv output pixel is computed — the §3.3 lowering decision,
/// made once per layer at compile time (see `ConvScheme` in
/// [`crate::compiler::program`]) and monomorphized into the kernel struct.
/// `Direct`/`Im2col` own [`simd::pack_conv_panels`] layouts. The algo is
/// **immutable at run time** — the im2col gather-row scratch is caller-
/// owned (one per worker), so a lowered conv is shareable across threads.
pub enum ConvAlgo {
    /// Scalar reference accumulation order — the bit-exact path, identical
    /// tap order to `nn::layers::conv::conv2d`.
    Generic {
        /// HWIO weights in the spec's layout, unpacked.
        kernel: Vec<f32>,
    },
    /// `lanes`-wide blocked panels read straight off the NHWC window (1×1
    /// kernels and VALID windows are always fully in bounds).
    Direct {
        /// [`simd::pack_conv_panels_w`]-layout panels of the HWIO weights,
        /// packed at `lanes` in the lowering-chosen storage dtype.
        panels: WeightPanels,
        /// Lane width the panels were packed at and the kernel runs at
        /// (1, 4, 8, or 16) — the §3.3 per-layer lowering decision.
        lanes: usize,
    },
    /// `lanes`-wide blocked panels over a gathered, zero-padded im2col
    /// row — one contiguous FMA stream per pixel regardless of border
    /// clipping. The row scratch (`GEMM_NR` rows of `kh*kw*c` for the
    /// batch-blocked path) is passed into [`conv2d_run`].
    Im2col {
        /// [`simd::pack_conv_panels_w`]-layout panels of the HWIO weights,
        /// packed at `lanes` in the lowering-chosen storage dtype.
        panels: WeightPanels,
        /// Lane width the panels were packed at and the kernel runs at.
        lanes: usize,
    },
}

/// How a Dense layer computes its output — the §3.3 + batch-blocking
/// lowering decision, made once per layer at compile time from
/// `CompileOptions::dense` plus the static in/out dims (see `DenseScheme`
/// in [`crate::compiler::program`]) and monomorphized into the kernel
/// struct. Immutable at run time: the rotated tail's doubled-x window is
/// caller-owned scratch, so a lowered dense is shareable across threads.
pub enum DenseAlgo {
    /// Scalar reference accumulation order — the bit-exact path, identical
    /// per output element to `nn::layers::dense::dense`.
    Generic {
        /// `[in_dim, units]` weights in the spec's layout, unpacked.
        kernel: Vec<f32>,
    },
    /// Batch-blocked register-tiled GEMM over [`simd::pack_dense_panels`]
    /// panels: every full `GEMM_NR`-item tile streams each weight panel
    /// once for 4 batch items; leftover items (and whole batches smaller
    /// than `GEMM_NR`, including the batch=1 serving bucket) run the
    /// per-item `tail` matvec.
    Gemm {
        /// [`simd::pack_dense_panels_w`]-layout panels of the weights,
        /// packed at `lanes` in the lowering-chosen storage dtype.
        panels: WeightPanels,
        /// Lane width of the packed panels and the tile kernel (1, 4, 8,
        /// or 16) — the §3.3 per-layer lowering decision.
        lanes: usize,
        /// Per-item matvec for batch items off the `GEMM_NR` grid. The
        /// rotated/broadcast tails store their own full-precision f32
        /// weights, so lowering only pairs them with `F32` panels.
        tail: DenseTail,
    },
}

/// The per-item matvec serving a GEMM-lowered dense layer's batch tail.
pub enum DenseTail {
    /// §3.3 Eq. 3 rotated diagonals (square layers inside the stack
    /// window); needs the `2n` doubled-x scratch passed to [`dense_run`].
    Rotated {
        /// [`simd::rotate_diagonals`] layout of the transposed weights.
        diag: Vec<f32>,
    },
    /// §3.3 Eq. 2 broadcast scheme (square layers).
    Broadcast {
        /// Transposed (`y = W x` orientation) weights, unpacked.
        w: Vec<f32>,
    },
    /// One pass over the packed panels (rectangular layers) — the same
    /// accumulation order as a 1-wide GEMM tile, so blocks and tail agree
    /// bit-for-bit.
    Panels,
}

impl ConvAlgo {
    /// Serialize the lowering decision and its weights to an artifact.
    pub(crate) fn encode(&self, e: &mut Encoder) {
        match self {
            ConvAlgo::Generic { kernel } => {
                e.u8(0);
                e.vec_f32(kernel);
            }
            ConvAlgo::Direct { panels, lanes } => {
                e.u8(1);
                panels.encode(e);
                e.usize(*lanes);
            }
            ConvAlgo::Im2col { panels, lanes } => {
                e.u8(2);
                panels.encode(e);
                e.usize(*lanes);
            }
        }
    }

    /// Deserialize from an artifact (panels map zero-copy).
    pub(crate) fn decode(d: &mut Decoder) -> Result<ConvAlgo, ArtifactError> {
        match d.u8()? {
            0 => Ok(ConvAlgo::Generic { kernel: d.vec_f32()? }),
            1 => {
                let panels = WeightPanels::decode(d)?;
                let lanes = d.usize()?;
                Ok(ConvAlgo::Direct { panels, lanes })
            }
            2 => {
                let panels = WeightPanels::decode(d)?;
                let lanes = d.usize()?;
                Ok(ConvAlgo::Im2col { panels, lanes })
            }
            t => Err(corrupt(format!("invalid conv algo tag {t}"))),
        }
    }
}

impl DenseAlgo {
    /// Serialize the lowering decision and its weights to an artifact.
    pub(crate) fn encode(&self, e: &mut Encoder) {
        match self {
            DenseAlgo::Generic { kernel } => {
                e.u8(0);
                e.vec_f32(kernel);
            }
            DenseAlgo::Gemm { panels, lanes, tail } => {
                e.u8(1);
                panels.encode(e);
                e.usize(*lanes);
                tail.encode(e);
            }
        }
    }

    /// Deserialize from an artifact (panels map zero-copy).
    pub(crate) fn decode(d: &mut Decoder) -> Result<DenseAlgo, ArtifactError> {
        match d.u8()? {
            0 => Ok(DenseAlgo::Generic { kernel: d.vec_f32()? }),
            1 => {
                let panels = WeightPanels::decode(d)?;
                let lanes = d.usize()?;
                let tail = DenseTail::decode(d)?;
                Ok(DenseAlgo::Gemm { panels, lanes, tail })
            }
            t => Err(corrupt(format!("invalid dense algo tag {t}"))),
        }
    }
}

impl DenseTail {
    /// Serialize the batch-tail matvec layout to an artifact.
    pub(crate) fn encode(&self, e: &mut Encoder) {
        match self {
            DenseTail::Rotated { diag } => {
                e.u8(0);
                e.vec_f32(diag);
            }
            DenseTail::Broadcast { w } => {
                e.u8(1);
                e.vec_f32(w);
            }
            DenseTail::Panels => e.u8(2),
        }
    }

    /// Deserialize from an artifact.
    pub(crate) fn decode(d: &mut Decoder) -> Result<DenseTail, ArtifactError> {
        match d.u8()? {
            0 => Ok(DenseTail::Rotated { diag: d.vec_f32()? }),
            1 => Ok(DenseTail::Broadcast { w: d.vec_f32()? }),
            2 => Ok(DenseTail::Panels),
            t => Err(corrupt(format!("invalid dense tail tag {t}"))),
        }
    }
}

/// Run `f` over `units` work units split into at most `tasks` contiguous
/// bands, each band owning a disjoint span of `out` (`out_per_unit`
/// elements per unit) and its own `scratch_per_task` stripe of `scratch` —
/// the intra-op split planned at lowering. `tasks == 1` (the default plan,
/// and every plan below the [`crate::compiler::cost`] threshold) runs `f`
/// inline with zero allocation or thread traffic; larger counts run the
/// extra bands on scoped threads while the first band stays on the caller's
/// thread. Bands never alias: `out` is carved with `split_at_mut` and every
/// band gets a private scratch stripe, so `f` only needs `Sync` captures.
///
/// `align` pins interior band boundaries to a unit grid. The batch-blocked
/// GEMM paths pass [`simd::GEMM_NR`] so a band never reassigns an item
/// between tile and tail relative to the sequential run — the rotated /
/// broadcast dense tails are *different algorithms* from the tile, so an
/// unaligned split would change bits, not just order. Per-pixel and
/// per-row bands pass 1 (every unit is computed identically).
fn run_bands<F>(
    tasks: usize,
    units: usize,
    align: usize,
    out_per_unit: usize,
    scratch_per_task: usize,
    scratch: &mut [f32],
    out: &mut [f32],
    f: F,
) where
    F: Fn(usize, usize, &mut [f32], &mut [f32]) + Sync,
{
    let groups = units / align.max(1);
    let tasks = tasks.clamp(1, units.max(1)).min(groups.max(1));
    if tasks == 1 {
        let n = scratch_per_task.min(scratch.len());
        f(0, units, &mut scratch[..n], out);
        return;
    }
    let mut jobs = Vec::with_capacity(tasks);
    let mut out_rest = out;
    let mut scr_rest = scratch;
    let mut u0 = 0usize;
    for t in 0..tasks {
        let u1 = if t + 1 == tasks { units } else { (groups * (t + 1) / tasks) * align };
        let (o, rest) = std::mem::take(&mut out_rest).split_at_mut((u1 - u0) * out_per_unit);
        out_rest = rest;
        let (s, rest) = std::mem::take(&mut scr_rest).split_at_mut(scratch_per_task);
        scr_rest = rest;
        jobs.push((u0, u1, s, o));
        u0 = u1;
    }
    std::thread::scope(|scope| {
        let f = &f;
        let mut jobs = jobs.into_iter();
        let first = jobs.next().expect("tasks >= 1");
        for (v0, v1, sv, ov) in jobs {
            scope.spawn(move || f(v0, v1, sv, ov));
        }
        let (u0, u1, s, o) = first;
        f(u0, u1, s, o);
    });
}

/// conv2d, NHWC × HWIO → NHWC, fused epilogue, optional §3.4 fused MaxPool.
///
/// Without `pool` this writes the conv output (epilogue applied in the
/// store loop). With `pool = Some((pkh, pkw, ps))` it writes the **pooled**
/// output instead: each conv pixel is computed into a scratch cell (len
/// `oc`), activated, and max-merged into its pool cell — the conv tensor
/// never materializes in memory, and conv pixels no pool window covers are
/// never computed. Pool windows must not overlap (`ps >= max(pkh, pkw)`,
/// the lowering's fusion gate), so no conv pixel is computed twice.
///
/// `scratch` holds `tasks` stripes of `cell_len` fused-pool cell elements
/// followed by the im2col gather rows (layout planned at lowering); all of
/// it is caller-owned, so `algo` is shared read-only across workers. The
/// blocked paths run at the lane width recorded in `algo` (a four-way
/// dispatch over the width-generic body), and `tasks > 1` splits the
/// output into row/item bands per [`run_bands`].
#[allow(clippy::too_many_arguments)]
pub fn conv2d_run(
    x: &[f32],
    (b, h, w, c): (usize, usize, usize, usize),
    algo: &ConvAlgo,
    (kh, kw, oc): (usize, usize, usize),
    bias: Option<&[f32]>,
    stride: usize,
    padding: Padding,
    ep: Epilogue,
    pool: Option<(usize, usize, usize)>,
    (cell_len, tasks): (usize, usize),
    scratch: &mut [f32],
    out: &mut [f32],
) {
    let lanes = match algo {
        ConvAlgo::Generic { .. } => 1,
        ConvAlgo::Direct { lanes, .. } | ConvAlgo::Im2col { lanes, .. } => *lanes,
    };
    match lanes {
        1 => conv2d_run_w::<1>(
            x, (b, h, w, c), algo, (kh, kw, oc), bias, stride, padding, ep, pool,
            (cell_len, tasks), scratch, out,
        ),
        8 => conv2d_run_w::<8>(
            x, (b, h, w, c), algo, (kh, kw, oc), bias, stride, padding, ep, pool,
            (cell_len, tasks), scratch, out,
        ),
        16 => conv2d_run_w::<16>(
            x, (b, h, w, c), algo, (kh, kw, oc), bias, stride, padding, ep, pool,
            (cell_len, tasks), scratch, out,
        ),
        _ => conv2d_run_w::<4>(
            x, (b, h, w, c), algo, (kh, kw, oc), bias, stride, padding, ep, pool,
            (cell_len, tasks), scratch, out,
        ),
    }
}

/// Width-generic [`conv2d_run`] body — one monomorphization per supported
/// lane width.
#[allow(clippy::too_many_arguments)]
fn conv2d_run_w<const W: usize>(
    x: &[f32],
    (b, h, w, c): (usize, usize, usize, usize),
    algo: &ConvAlgo,
    (kh, kw, oc): (usize, usize, usize),
    bias: Option<&[f32]>,
    stride: usize,
    padding: Padding,
    ep: Epilogue,
    pool: Option<(usize, usize, usize)>,
    (cell_len, tasks): (usize, usize),
    scratch: &mut [f32],
    out: &mut [f32],
) {
    let (pt, pl) = match padding {
        Padding::Same => (same_pads(h, kh, stride).0, same_pads(w, kw, stride).0),
        Padding::Valid => (0, 0),
    };
    let (oh, ow) = crate::model::spec::conv_out(h, w, kh, kw, stride, padding);
    let per_task = scratch.len() / tasks.max(1);
    match pool {
        None => {
            debug_assert_eq!(out.len(), b * oh * ow * oc);
            if let ConvAlgo::Im2col { panels, .. } = algo {
                if b >= simd::GEMM_NR {
                    // band over whole batch items on the GEMM_NR grid:
                    // each band keeps exactly the sequential run's
                    // tile/tail assignment for its item sub-range
                    let nr = simd::GEMM_NR;
                    run_bands(tasks, b, nr, oh * ow * oc, per_task, scratch, out, |n0, n1, s, o| {
                        im2col_batch_blocked_w::<W>(
                            &x[n0 * h * w * c..n1 * h * w * c],
                            (n1 - n0, h, w, c),
                            panels,
                            (kh, kw, oc),
                            bias,
                            (stride, pt, pl),
                            (oh, ow),
                            ep,
                            &mut s[cell_len..],
                            o,
                        );
                    });
                    return;
                }
            }
            // band over (item, output row) units
            run_bands(tasks, b * oh, 1, ow * oc, per_task, scratch, out, |u0, u1, s, o| {
                let row = &mut s[cell_len..];
                for u in u0..u1 {
                    let (n, oy) = (u / oh, u % oh);
                    for ox in 0..ow {
                        let dst = &mut o[((u - u0) * ow + ox) * oc..][..oc];
                        let y0 = (oy * stride) as isize - pt as isize;
                        let x0 = (ox * stride) as isize - pl as isize;
                        conv_pixel_w::<W>(
                            x,
                            (n, h, w, c),
                            algo,
                            (kh, kw, oc),
                            bias,
                            y0,
                            x0,
                            ep,
                            row,
                            dst,
                        );
                    }
                }
            });
        }
        Some((pkh, pkw, ps)) => {
            let (ph, pw) = ((oh - pkh) / ps + 1, (ow - pkw) / ps + 1);
            debug_assert_eq!(out.len(), b * ph * pw * oc);
            debug_assert!(cell_len >= oc);
            // band over (item, pool row) units
            run_bands(tasks, b * ph, 1, pw * oc, per_task, scratch, out, |u0, u1, s, o| {
                let (cell, row) = s.split_at_mut(cell_len);
                let cell = &mut cell[..oc];
                for u in u0..u1 {
                    let (n, py) = (u / ph, u % ph);
                    for px in 0..pw {
                        let dst = &mut o[((u - u0) * pw + px) * oc..][..oc];
                        dst.fill(f32::NEG_INFINITY);
                        for wy in 0..pkh {
                            for wx in 0..pkw {
                                let (oy, ox) = (py * ps + wy, px * ps + wx);
                                let y0 = (oy * stride) as isize - pt as isize;
                                let x0 = (ox * stride) as isize - pl as isize;
                                // compute → epilogue (inside the pixel's
                                // store loop) → max-merge: unfused order.
                                conv_pixel_w::<W>(
                                    x,
                                    (n, h, w, c),
                                    algo,
                                    (kh, kw, oc),
                                    bias,
                                    y0,
                                    x0,
                                    ep,
                                    row,
                                    cell,
                                );
                                for (d, &v) in dst.iter_mut().zip(cell.iter()) {
                                    if v > *d {
                                        *d = v;
                                    }
                                }
                            }
                        }
                    }
                }
            });
        }
    }
}

/// The batch-blocked im2col path: dtype dispatch over the panel storage
/// into the element-generic body.
#[allow(clippy::too_many_arguments)]
fn im2col_batch_blocked_w<const W: usize>(
    x: &[f32],
    dims: (usize, usize, usize, usize),
    panels: &WeightPanels,
    k: (usize, usize, usize),
    bias: Option<&[f32]>,
    sp: (usize, usize, usize),
    o: (usize, usize),
    ep: Epilogue,
    row: &mut [f32],
    out: &mut [f32],
) {
    match panels {
        WeightPanels::F32(p) => {
            im2col_batch_blocked_we::<W, f32>(x, dims, p, None, k, bias, sp, o, ep, row, out)
        }
        WeightPanels::Bf16(p) => {
            im2col_batch_blocked_we::<W, u16>(x, dims, p, None, k, bias, sp, o, ep, row, out)
        }
        WeightPanels::I8 { data, scales } => im2col_batch_blocked_we::<W, i8>(
            x,
            dims,
            data,
            Some(scales),
            k,
            bias,
            sp,
            o,
            ep,
            row,
            out,
        ),
    }
}

/// Element-generic batch-blocked im2col body: for each output pixel,
/// gather the `GEMM_NR` batch items' windows into consecutive rows of
/// `row`, then run one MR×NR register tile per output-channel block — each
/// weight panel is streamed once per NR items instead of once per item,
/// and every gathered row is reused across all output-channel blocks of
/// its tile. Leftover items run the per-item panel pass. `row` must hold
/// `GEMM_NR` im2col rows (`GEMM_NR * kh*kw*c`, planned at lowering).
/// `scales` is the i8 dequantization vector (accumulators start at zero
/// and the store loop fuses `acc * scale + bias`); `None` preloads bias.
#[allow(clippy::too_many_arguments)]
fn im2col_batch_blocked_we<const W: usize, E: simd::PanelElem>(
    x: &[f32],
    (b, h, w, c): (usize, usize, usize, usize),
    panels: &[E],
    scales: Option<&[f32]>,
    (kh, kw, oc): (usize, usize, usize),
    bias: Option<&[f32]>,
    (stride, pt, pl): (usize, usize, usize),
    (oh, ow): (usize, usize),
    ep: Epilogue,
    row: &mut [f32],
    out: &mut [f32],
) {
    let taps = kh * kw * c;
    debug_assert!(row.len() >= simd::GEMM_NR * taps);
    let blocks = oc.div_ceil(W);
    let full = b / simd::GEMM_NR * simd::GEMM_NR;
    for oy in 0..oh {
        for ox in 0..ow {
            let y0 = (oy * stride) as isize - pt as isize;
            let x0 = (ox * stride) as isize - pl as isize;
            for n0 in (0..full).step_by(simd::GEMM_NR) {
                for n in 0..simd::GEMM_NR {
                    gather_row(
                        x,
                        (n0 + n, h, w, c),
                        (kh, kw),
                        y0,
                        x0,
                        &mut row[n * taps..][..taps],
                    );
                }
                let x4 = &row[..simd::GEMM_NR * taps];
                for ob in 0..blocks {
                    let panel = &panels[ob * taps * W..][..taps * W];
                    let mut acc = [init_lanes_w::<W>(bias, scales, ob, oc); simd::GEMM_NR];
                    simd::gemm_fma_run_we::<W, E>(panel, x4, taps, &mut acc);
                    for (n, lanes) in acc.iter_mut().enumerate() {
                        let dst = &mut out[(((n0 + n) * oh + oy) * ow + ox) * oc..][..oc];
                        store_lanes_dq_w::<W>(lanes, ob, scales, bias, ep, dst);
                    }
                }
            }
            for n in full..b {
                let dst = &mut out[((n * oh + oy) * ow + ox) * oc..][..oc];
                gather_row(x, (n, h, w, c), (kh, kw), y0, x0, &mut row[..taps]);
                panel_row_pixel_we::<W, E>(panels, scales, &row[..taps], oc, bias, ep, dst);
            }
        }
    }
}

/// One output pixel's `oc` vector into `dst` (epilogue applied), by the
/// lowered algorithm. `(y0, x0)` is the window origin in input coordinates
/// (may be negative under SAME padding). `row` is the caller-owned im2col
/// gather scratch (at least `kh*kw*c` long for the im2col scheme, unused
/// otherwise). The blocked schemes run the epilogue lane-wise inside
/// [`store_lanes_w`]; the scalar `Generic` reference applies it per
/// element after the pixel — the order `bit_exact()` pins.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn conv_pixel_w<const W: usize>(
    x: &[f32],
    (n, h, w, c): (usize, usize, usize, usize),
    algo: &ConvAlgo,
    (kh, kw, oc): (usize, usize, usize),
    bias: Option<&[f32]>,
    y0: isize,
    x0: isize,
    ep: Epilogue,
    row: &mut [f32],
    dst: &mut [f32],
) {
    match algo {
        ConvAlgo::Generic { kernel } => {
            generic_pixel(x, (n, h, w, c), kernel, (kh, kw, oc), bias, y0, x0, dst);
            ep.apply(dst);
        }
        ConvAlgo::Direct { panels, .. } => {
            direct_pixel_w::<W>(x, (n, h, w, c), panels, (kh, kw, oc), bias, y0, x0, ep, dst)
        }
        ConvAlgo::Im2col { panels, .. } => {
            let taps = kh * kw * c;
            gather_row(x, (n, h, w, c), (kh, kw), y0, x0, &mut row[..taps]);
            panel_row_pixel_w::<W>(panels, &row[..taps], oc, bias, ep, dst)
        }
    }
}

/// Scalar reference order (the pre-SIMD `conv2d_into` body): bias, then
/// taps in (ky, kx, ci) order with the ReLU-sparsity skip — bit-identical
/// to the naive oracle per output channel.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn generic_pixel(
    x: &[f32],
    (n, h, w, c): (usize, usize, usize, usize),
    kernel: &[f32],
    (kh, kw, oc): (usize, usize, usize),
    bias: Option<&[f32]>,
    y0: isize,
    x0: isize,
    dst: &mut [f32],
) {
    match bias {
        Some(bs) => dst.copy_from_slice(bs),
        None => dst.fill(0.0),
    }
    for ky in 0..kh {
        let iy = y0 + ky as isize;
        if iy < 0 || iy as usize >= h {
            continue;
        }
        for kx in 0..kw {
            let ix = x0 + kx as isize;
            if ix < 0 || ix as usize >= w {
                continue;
            }
            let px = &x[((n * h + iy as usize) * w + ix as usize) * c..][..c];
            let kbase = (ky * kw + kx) * c * oc;
            for (ci, &xv) in px.iter().enumerate() {
                if xv == 0.0 {
                    continue; // ReLU-sparse inputs
                }
                let krow = &kernel[kbase + ci * oc..][..oc];
                for o in 0..oc {
                    dst[o] += xv * krow[o];
                }
            }
        }
    }
}

/// §3.3 blocked direct-window path: dtype dispatch over the panel storage
/// into the element-generic body.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn direct_pixel_w<const W: usize>(
    x: &[f32],
    dims: (usize, usize, usize, usize),
    panels: &WeightPanels,
    k: (usize, usize, usize),
    bias: Option<&[f32]>,
    y0: isize,
    x0: isize,
    ep: Epilogue,
    dst: &mut [f32],
) {
    match panels {
        WeightPanels::F32(p) => {
            direct_pixel_we::<W, f32>(x, dims, p, None, k, bias, y0, x0, ep, dst)
        }
        WeightPanels::Bf16(p) => {
            direct_pixel_we::<W, u16>(x, dims, p, None, k, bias, y0, x0, ep, dst)
        }
        WeightPanels::I8 { data, scales } => {
            direct_pixel_we::<W, i8>(x, dims, data, Some(scales), k, bias, y0, x0, ep, dst)
        }
    }
}

/// Element-generic direct-window body: per output-channel block of `W`,
/// the accumulators stay in registers across every in-bounds tap run (one
/// contiguous channel vector per (ky, kx)); the epilogue runs lane-wise in
/// the store. `scales` switches the accumulators to the i8 zero-start /
/// fused-dequant protocol.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn direct_pixel_we<const W: usize, E: simd::PanelElem>(
    x: &[f32],
    (n, h, w, c): (usize, usize, usize, usize),
    panels: &[E],
    scales: Option<&[f32]>,
    (kh, kw, oc): (usize, usize, usize),
    bias: Option<&[f32]>,
    y0: isize,
    x0: isize,
    ep: Epilogue,
    dst: &mut [f32],
) {
    let taps = kh * kw * c;
    let blocks = oc.div_ceil(W);
    for ob in 0..blocks {
        let panel = &panels[ob * taps * W..][..taps * W];
        let mut acc = init_lanes_w::<W>(bias, scales, ob, oc);
        for ky in 0..kh {
            let iy = y0 + ky as isize;
            if iy < 0 || iy as usize >= h {
                continue;
            }
            for kx in 0..kw {
                let ix = x0 + kx as isize;
                if ix < 0 || ix as usize >= w {
                    continue;
                }
                let px = &x[((n * h + iy as usize) * w + ix as usize) * c..][..c];
                let t0 = (ky * kw + kx) * c;
                simd::conv_fma_run_we::<W, E>(&panel[t0 * W..][..c * W], px, &mut acc);
            }
        }
        store_lanes_dq_w::<W>(&mut acc, ob, scales, bias, ep, dst);
    }
}

/// §3.3 blocked im2col row pass: dtype dispatch over the panel storage
/// into the element-generic body.
#[inline(always)]
fn panel_row_pixel_w<const W: usize>(
    panels: &WeightPanels,
    row: &[f32],
    oc: usize,
    bias: Option<&[f32]>,
    ep: Epilogue,
    dst: &mut [f32],
) {
    match panels {
        WeightPanels::F32(p) => panel_row_pixel_we::<W, f32>(p, None, row, oc, bias, ep, dst),
        WeightPanels::Bf16(p) => panel_row_pixel_we::<W, u16>(p, None, row, oc, bias, ep, dst),
        WeightPanels::I8 { data, scales } => {
            panel_row_pixel_we::<W, i8>(data, Some(scales), row, oc, bias, ep, dst)
        }
    }
}

/// Element-generic im2col row body: one dense FMA stream over the gathered
/// row, epilogue lane-wise in the store. Shared by the conv im2col scheme
/// and the dense GEMM batch tail (a dense layer *is* a 1-pixel im2col
/// conv).
#[inline(always)]
fn panel_row_pixel_we<const W: usize, E: simd::PanelElem>(
    panels: &[E],
    scales: Option<&[f32]>,
    row: &[f32],
    oc: usize,
    bias: Option<&[f32]>,
    ep: Epilogue,
    dst: &mut [f32],
) {
    let taps = row.len();
    let blocks = oc.div_ceil(W);
    for ob in 0..blocks {
        let panel = &panels[ob * taps * W..][..taps * W];
        let mut acc = init_lanes_w::<W>(bias, scales, ob, oc);
        simd::conv_fma_run_we::<W, E>(panel, row, &mut acc);
        store_lanes_dq_w::<W>(&mut acc, ob, scales, bias, ep, dst);
    }
}

/// Gather one output pixel's zero-padded window into a contiguous im2col
/// row: per kernel row, a single memcpy of the in-bounds kx span.
#[inline(always)]
fn gather_row(
    x: &[f32],
    (n, h, w, c): (usize, usize, usize, usize),
    (kh, kw): (usize, usize),
    y0: isize,
    x0: isize,
    row: &mut [f32],
) {
    debug_assert_eq!(row.len(), kh * kw * c);
    row.fill(0.0);
    let kx_lo = (-x0).max(0) as usize;
    let kx_hi = ((w as isize - x0).min(kw as isize)).max(0) as usize;
    if kx_lo >= kx_hi {
        return;
    }
    for ky in 0..kh {
        let iy = y0 + ky as isize;
        if iy < 0 || iy as usize >= h {
            continue;
        }
        let ix0 = (x0 + kx_lo as isize) as usize;
        let src = &x[((n * h + iy as usize) * w + ix0) * c..][..(kx_hi - kx_lo) * c];
        row[(ky * kw + kx_lo) * c..][..src.len()].copy_from_slice(src);
    }
}

/// Accumulator init for output-channel block `ob`: bias lanes, zeros past
/// `oc` (tail lanes are never stored).
#[inline(always)]
fn bias_lanes_w<const W: usize>(bias: Option<&[f32]>, ob: usize, oc: usize) -> [f32; W] {
    let mut acc = [0.0f32; W];
    if let Some(bs) = bias {
        for (l, a) in acc.iter_mut().enumerate() {
            let o = ob * W + l;
            if o < oc {
                *a = bs[o];
            }
        }
    }
    acc
}

/// Accumulator init under the dtype protocol: f32/bf16 panels preload the
/// bias ([`bias_lanes_w`]); i8 panels (`scales` present) start from zero —
/// the integer-weight accumulation must be scaled before the bias lands,
/// so both are fused into [`store_lanes_dq_w`] instead.
#[inline(always)]
fn init_lanes_w<const W: usize>(
    bias: Option<&[f32]>,
    scales: Option<&[f32]>,
    ob: usize,
    oc: usize,
) -> [f32; W] {
    if scales.is_some() {
        [0.0f32; W]
    } else {
        bias_lanes_w::<W>(bias, ob, oc)
    }
}

/// [`store_lanes_w`] with the i8 dequantization fused ahead of the
/// epilogue: when `scales` is present each real lane becomes
/// `acc * scales[o] + bias[o]` **before** the activation — the §3.4 fusion
/// extended one affine deeper, so the quantized path still takes exactly
/// one pass over the output vector.
#[inline(always)]
fn store_lanes_dq_w<const W: usize>(
    acc: &mut [f32; W],
    ob: usize,
    scales: Option<&[f32]>,
    bias: Option<&[f32]>,
    ep: Epilogue,
    dst: &mut [f32],
) {
    if let Some(sc) = scales {
        let o0 = ob * W;
        let real = W.min(dst.len() - o0);
        for (l, a) in acc.iter_mut().enumerate().take(real) {
            let o = o0 + l;
            *a = *a * sc[o] + bias.map_or(0.0, |bs| bs[o]);
        }
    }
    store_lanes_w::<W>(acc, ob, ep, dst)
}

/// Apply the §3.4 epilogue to block `ob`'s accumulators and store the real
/// lanes into the `oc`-length pixel vector: full groups take the `W`-lane
/// [`Epilogue::apply_lanes_w`] form, the final partial group (channel
/// count off the `W` grid) falls back to the scalar tail.
#[inline(always)]
fn store_lanes_w<const W: usize>(acc: &mut [f32; W], ob: usize, ep: Epilogue, dst: &mut [f32]) {
    let o0 = ob * W;
    let real = W.min(dst.len() - o0);
    if real == W {
        ep.apply_lanes_w::<W>(acc, o0);
        dst[o0..o0 + W].copy_from_slice(acc);
    } else {
        dst[o0..o0 + real].copy_from_slice(&acc[..real]);
        ep.apply_channels(&mut dst[o0..o0 + real], o0);
    }
}

/// Depthwise conv2d, NHWC × HWC → NHWC (one filter per channel), scalar
/// taps with the fused epilogue applied per output pixel.
#[allow(clippy::too_many_arguments)]
pub fn depthwise_conv2d_into(
    x: &[f32],
    (b, h, w, c): (usize, usize, usize, usize),
    kernel: &[f32],
    (kh, kw): (usize, usize),
    bias: Option<&[f32]>,
    stride: usize,
    padding: Padding,
    ep: Epilogue,
    out: &mut [f32],
) {
    let (pt, pl) = match padding {
        Padding::Same => (same_pads(h, kh, stride).0, same_pads(w, kw, stride).0),
        Padding::Valid => (0, 0),
    };
    let (oh, ow) = crate::model::spec::conv_out(h, w, kh, kw, stride, padding);
    for n in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let dst = &mut out[((n * oh + oy) * ow + ox) * c..][..c];
                match bias {
                    Some(bs) => dst.copy_from_slice(bs),
                    None => dst.fill(0.0),
                }
                let y0 = (oy * stride) as isize - pt as isize;
                let x0 = (ox * stride) as isize - pl as isize;
                for ky in 0..kh {
                    let iy = y0 + ky as isize;
                    if iy < 0 || iy as usize >= h {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = x0 + kx as isize;
                        if ix < 0 || ix as usize >= w {
                            continue;
                        }
                        let px = &x[((n * h + iy as usize) * w + ix as usize) * c..][..c];
                        let krow = &kernel[(ky * kw + kx) * c..][..c];
                        for ci in 0..c {
                            dst[ci] += px[ci] * krow[ci];
                        }
                    }
                }
                ep.apply(dst);
            }
        }
    }
}

/// Dense layer under any §3.3 scheme, batch-blocked by [`simd::GEMM_NR`]
/// when the lowering selected the GEMM path: every full tile holds a
/// `lanes`-output × 4-item accumulator block across one pass over each
/// packed panel, so the weight matrix is streamed once per NR items
/// instead of once per item (the per-item matvec re-reads all of it per
/// batch element); tail items — and whole batches below NR, including
/// batch=1 — fall back to the lowered per-item matvec. `scratch` holds
/// `tasks` stripes of the rotated tail's doubled-x window (len `2n` each,
/// empty otherwise); `tasks > 1` bands the batch items per [`run_bands`]
/// (band boundaries are bitwise-neutral because tile ≡ tail is pinned in
/// `nn::simd`). Epilogues run lane-wise in the store tile; the bit-exact
/// `Generic` algo keeps the scalar reference order end to end.
#[allow(clippy::too_many_arguments)]
pub fn dense_run(
    x: &[f32],
    (b, in_dim): (usize, usize),
    algo: &DenseAlgo,
    out_dim: usize,
    bias: Option<&[f32]>,
    ep: Epilogue,
    scratch: &mut [f32],
    tasks: usize,
    out: &mut [f32],
) {
    let lanes = match algo {
        DenseAlgo::Generic { .. } => 1,
        DenseAlgo::Gemm { lanes, .. } => *lanes,
    };
    match lanes {
        1 => dense_run_w::<1>(x, (b, in_dim), algo, out_dim, bias, ep, scratch, tasks, out),
        8 => dense_run_w::<8>(x, (b, in_dim), algo, out_dim, bias, ep, scratch, tasks, out),
        16 => dense_run_w::<16>(x, (b, in_dim), algo, out_dim, bias, ep, scratch, tasks, out),
        _ => dense_run_w::<4>(x, (b, in_dim), algo, out_dim, bias, ep, scratch, tasks, out),
    }
}

/// Width-generic [`dense_run`] body — bands the batch, then runs each band
/// through [`dense_band_w`].
#[allow(clippy::too_many_arguments)]
fn dense_run_w<const W: usize>(
    x: &[f32],
    (b, in_dim): (usize, usize),
    algo: &DenseAlgo,
    out_dim: usize,
    bias: Option<&[f32]>,
    ep: Epilogue,
    scratch: &mut [f32],
    tasks: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(x.len(), b * in_dim);
    debug_assert_eq!(out.len(), b * out_dim);
    let per_task = scratch.len() / tasks.max(1);
    run_bands(tasks, b, simd::GEMM_NR, out_dim, per_task, scratch, out, |n0, n1, s, o| {
        dense_band_w::<W>(
            &x[n0 * in_dim..n1 * in_dim],
            (n1 - n0, in_dim),
            algo,
            out_dim,
            bias,
            ep,
            s,
            o,
        );
    });
}

/// One contiguous band of batch items under the lowered dense scheme.
#[allow(clippy::too_many_arguments)]
fn dense_band_w<const W: usize>(
    x: &[f32],
    (b, in_dim): (usize, usize),
    algo: &DenseAlgo,
    out_dim: usize,
    bias: Option<&[f32]>,
    ep: Epilogue,
    scratch: &mut [f32],
    out: &mut [f32],
) {
    match algo {
        DenseAlgo::Generic { kernel } => {
            for n in 0..b {
                let xrow = &x[n * in_dim..][..in_dim];
                let dst = &mut out[n * out_dim..][..out_dim];
                dense_item(xrow, kernel, out_dim, bias, dst);
                ep.apply(dst);
            }
        }
        DenseAlgo::Gemm { panels, tail, .. } => {
            let full = b / simd::GEMM_NR * simd::GEMM_NR;
            match panels {
                WeightPanels::F32(p) => {
                    dense_gemm_tiles_we::<W, f32>(x, full, in_dim, p, None, out_dim, bias, ep, out)
                }
                WeightPanels::Bf16(p) => {
                    dense_gemm_tiles_we::<W, u16>(x, full, in_dim, p, None, out_dim, bias, ep, out)
                }
                WeightPanels::I8 { data, scales } => dense_gemm_tiles_we::<W, i8>(
                    x,
                    full,
                    in_dim,
                    data,
                    Some(scales),
                    out_dim,
                    bias,
                    ep,
                    out,
                ),
            }
            for n in full..b {
                let xrow = &x[n * in_dim..][..in_dim];
                let dst = &mut out[n * out_dim..][..out_dim];
                match tail {
                    DenseTail::Rotated { diag } => {
                        simd::matvec_rotated_with(diag, xrow, &mut scratch[..2 * in_dim], dst);
                        add_bias(dst, bias);
                        ep.apply(dst);
                    }
                    DenseTail::Broadcast { w } => {
                        simd::matvec_broadcast(w, xrow, dst);
                        add_bias(dst, bias);
                        ep.apply(dst);
                    }
                    DenseTail::Panels => {
                        panel_row_pixel_w::<W>(panels, xrow, out_dim, bias, ep, dst)
                    }
                }
            }
        }
    }
}

/// Element-generic dense GEMM tile loop: every full `GEMM_NR`-item tile
/// holds a `W`-output × NR-item accumulator block across one pass over
/// each packed panel. `scales` switches the accumulators to the i8
/// zero-start / fused-dequant protocol; f32 and bf16 preload the bias.
#[allow(clippy::too_many_arguments)]
fn dense_gemm_tiles_we<const W: usize, E: simd::PanelElem>(
    x: &[f32],
    full: usize,
    in_dim: usize,
    panels: &[E],
    scales: Option<&[f32]>,
    out_dim: usize,
    bias: Option<&[f32]>,
    ep: Epilogue,
    out: &mut [f32],
) {
    let blocks = out_dim.div_ceil(W);
    for n0 in (0..full).step_by(simd::GEMM_NR) {
        let x4 = &x[n0 * in_dim..][..simd::GEMM_NR * in_dim];
        for ob in 0..blocks {
            let panel = &panels[ob * in_dim * W..][..in_dim * W];
            let mut acc = [init_lanes_w::<W>(bias, scales, ob, out_dim); simd::GEMM_NR];
            simd::gemm_fma_run_we::<W, E>(panel, x4, in_dim, &mut acc);
            for (n, lanes) in acc.iter_mut().enumerate() {
                let dst = &mut out[(n0 + n) * out_dim..][..out_dim];
                store_lanes_dq_w::<W>(lanes, ob, scales, bias, ep, dst);
            }
        }
    }
}

/// One item's scalar reference dense: bias, then inputs in ascending order
/// with **no data-dependent skip** — `0·Inf` and `0·NaN` propagate per
/// IEEE 754 instead of being silently dropped, and the hot loop carries no
/// per-element branch (the old `xv == 0.0` shortcut cost a compare per
/// input and changed results under non-finite weights).
#[inline(always)]
fn dense_item(
    xrow: &[f32],
    kernel: &[f32],
    out_dim: usize,
    bias: Option<&[f32]>,
    dst: &mut [f32],
) {
    match bias {
        Some(bs) => dst.copy_from_slice(bs),
        None => dst.fill(0.0),
    }
    for (i, &xv) in xrow.iter().enumerate() {
        let krow = &kernel[i * out_dim..][..out_dim];
        for o in 0..out_dim {
            dst[o] += xv * krow[o];
        }
    }
}

/// `dst += bias`, the matvec tails' post-accumulation bias add.
#[inline(always)]
fn add_bias(dst: &mut [f32], bias: Option<&[f32]>) {
    if let Some(bs) = bias {
        for (v, &bv) in dst.iter_mut().zip(bs) {
            *v += bv;
        }
    }
}

/// Standalone NHWC max-pool (the unfused path; fused pools ride
/// [`conv2d_run`]). Window `(kh, kw)` at `stride`, no padding.
pub fn maxpool_into(
    x: &[f32],
    (b, h, w, c): (usize, usize, usize, usize),
    (kh, kw, stride): (usize, usize, usize),
    out: &mut [f32],
) {
    let (oh, ow) = ((h - kh) / stride + 1, (w - kw) / stride + 1);
    for n in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let dst = &mut out[((n * oh + oy) * ow + ox) * c..][..c];
                dst.fill(f32::NEG_INFINITY);
                for ky in 0..kh {
                    for kx in 0..kw {
                        let px = &x[((n * h + oy * stride + ky) * w + ox * stride + kx) * c..][..c];
                        for ci in 0..c {
                            if px[ci] > dst[ci] {
                                dst[ci] = px[ci];
                            }
                        }
                    }
                }
            }
        }
    }
}

/// NHWC average-pool: window `(kh, kw)` at `stride`, no padding.
pub fn avgpool_into(
    x: &[f32],
    (b, h, w, c): (usize, usize, usize, usize),
    (kh, kw, stride): (usize, usize, usize),
    out: &mut [f32],
) {
    let (oh, ow) = ((h - kh) / stride + 1, (w - kw) / stride + 1);
    let inv = 1.0 / (kh * kw) as f32;
    for n in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let dst = &mut out[((n * oh + oy) * ow + ox) * c..][..c];
                dst.fill(0.0);
                for ky in 0..kh {
                    for kx in 0..kw {
                        let px = &x[((n * h + oy * stride + ky) * w + ox * stride + kx) * c..][..c];
                        for ci in 0..c {
                            dst[ci] += px[ci];
                        }
                    }
                }
                for v in dst.iter_mut() {
                    *v *= inv;
                }
            }
        }
    }
}

/// Global average pool: NHWC → `[b, c]`, mean over every spatial position.
pub fn globalavgpool_into(x: &[f32], (b, h, w, c): (usize, usize, usize, usize), out: &mut [f32]) {
    let inv = 1.0 / (h * w) as f32;
    for n in 0..b {
        let dst = &mut out[n * c..][..c];
        dst.fill(0.0);
        for p in 0..h * w {
            let px = &x[(n * h * w + p) * c..][..c];
            for ci in 0..c {
                dst[ci] += px[ci];
            }
        }
        for v in dst.iter_mut() {
            *v *= inv;
        }
    }
}

/// Nearest-neighbour upsample by an integer `factor` in both spatial dims.
pub fn upsample_into(
    x: &[f32],
    (b, h, w, c): (usize, usize, usize, usize),
    factor: usize,
    out: &mut [f32],
) {
    let (oh, ow) = (h * factor, w * factor);
    for n in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let src = &x[((n * h + oy / factor) * w + ox / factor) * c..][..c];
                out[((n * oh + oy) * ow + ox) * c..][..c].copy_from_slice(src);
            }
        }
    }
}

/// Zero-pad the spatial dims by `pad = [top, bottom, left, right]`.
pub fn zeropad_into(
    x: &[f32],
    (b, h, w, c): (usize, usize, usize, usize),
    pad: [usize; 4],
    out: &mut [f32],
) {
    let [t, bo, l, r] = pad;
    let (oh, ow) = (h + t + bo, w + l + r);
    out.fill(0.0);
    for n in 0..b {
        for y in 0..h {
            for xx in 0..w {
                let src = &x[((n * h + y) * w + xx) * c..][..c];
                out[((n * oh + y + t) * ow + xx + l) * c..][..c].copy_from_slice(src);
            }
        }
    }
}

/// Per-channel affine (BN at exec time or standalone §3.5 affine).
pub fn affine_into(x: &[f32], c: usize, scale: &[f32], shift: &[f32], out: &mut [f32]) {
    out.copy_from_slice(x);
    affine_rows(out, c, scale, shift);
}

/// Per-channel affine applied in place (the §3.2 aliased-buffer path).
pub fn affine_rows(buf: &mut [f32], c: usize, scale: &[f32], shift: &[f32]) {
    for (i, v) in buf.iter_mut().enumerate() {
        let ci = i % c;
        *v = *v * scale[ci] + shift[ci];
    }
}

/// `dst += src`, elementwise (the in-place residual add).
pub fn add_assign(dst: &mut [f32], src: &[f32]) {
    for (v, &s) in dst.iter_mut().zip(src) {
        *v += s;
    }
}

/// Softmax over trailing axis; `approx` uses the §3.4 two-pass fast-exp.
pub fn softmax_into(x: &[f32], c: usize, approx_exp: bool, out: &mut [f32]) {
    out.copy_from_slice(x);
    softmax_rows(out, c, approx_exp);
}

/// In-place softmax over rows of length `c` (the §3.2 aliased-buffer path).
pub fn softmax_rows(buf: &mut [f32], c: usize, approx_exp: bool) {
    for row in buf.chunks_exact_mut(c) {
        if approx_exp {
            approx::fast_softmax_row(row);
        } else {
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0;
            for v in row.iter_mut() {
                *v = (*v - m).exp();
                sum += *v;
            }
            let inv = 1.0 / sum;
            for v in row.iter_mut() {
                *v *= inv;
            }
        }
    }
}

/// `out = a + b`, elementwise (the out-of-place residual add).
pub fn add_into(a: &[f32], b: &[f32], out: &mut [f32]) {
    for ((&x, &y), o) in a.iter().zip(b).zip(out.iter_mut()) {
        *o = x + y;
    }
}

/// Channel-axis concat of two NHWC buffers with `ca` and `cb` channels.
pub fn concat_into(a: &[f32], ca: usize, b: &[f32], cb: usize, out: &mut [f32]) {
    let pixels = a.len() / ca;
    debug_assert_eq!(b.len() / cb, pixels);
    for p in 0..pixels {
        out[p * (ca + cb)..][..ca].copy_from_slice(&a[p * ca..][..ca]);
        out[p * (ca + cb) + ca..][..cb].copy_from_slice(&b[p * cb..][..cb]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epilogue_fuses_act_and_affine() {
        let ep = Epilogue {
            act: Activation::Relu,
            approx: false,
            post: Some((&[2.0, 2.0], &[1.0, 1.0])),
        };
        let mut v = [-3.0f32, 4.0];
        ep.apply(&mut v);
        assert_eq!(v, [1.0, 9.0]); // relu then *2+1
    }

    fn algo_for(scheme: &str, kernel: &[f32], taps: usize, oc: usize, lanes: usize) -> ConvAlgo {
        algo_for_dtype(scheme, kernel, taps, oc, lanes, simd::WeightDtype::F32)
    }

    fn algo_for_dtype(
        scheme: &str,
        kernel: &[f32],
        taps: usize,
        oc: usize,
        lanes: usize,
        dtype: simd::WeightDtype,
    ) -> ConvAlgo {
        match scheme {
            "generic" => ConvAlgo::Generic { kernel: kernel.to_vec() },
            "direct" => ConvAlgo::Direct {
                panels: WeightPanels::pack_conv(kernel, taps, oc, lanes, dtype),
                lanes,
            },
            "im2col" => ConvAlgo::Im2col {
                panels: WeightPanels::pack_conv(kernel, taps, oc, lanes, dtype),
                lanes,
            },
            other => panic!("unknown scheme {other}"),
        }
    }

    #[test]
    fn conv_run_all_schemes_match_reference() {
        use crate::nn::layers::conv::conv2d;
        use crate::nn::tensor::Tensor;
        // channels deliberately not multiples of 4 (c=3, oc=5) so the
        // blocked paths exercise their padded tail lanes at every width.
        for (stride, padding) in
            [(1, Padding::Same), (2, Padding::Same), (1, Padding::Valid), (2, Padding::Valid)]
        {
            let mut rng = crate::util::rng::SplitMix64::new(3);
            let x = Tensor::from_vec(&[2, 5, 5, 3], rng.uniform_vec(2 * 5 * 5 * 3));
            let kernel = rng.uniform_vec(3 * 3 * 3 * 5);
            let bias = rng.uniform_vec(5);
            let r = conv2d(&x, &kernel, &[3, 3, 3, 5], Some(&bias), stride, padding);
            for scheme in ["generic", "direct", "im2col"] {
                for lanes in simd::LANE_WIDTHS {
                    let algo = algo_for(scheme, &kernel, 3 * 3 * 3, 5, lanes);
                    let mut scratch = vec![0.0; 3 * 3 * 3];
                    let mut out = vec![0.0; r.len()];
                    conv2d_run(
                        x.data(),
                        (2, 5, 5, 3),
                        &algo,
                        (3, 3, 5),
                        Some(&bias),
                        stride,
                        padding,
                        Epilogue::NONE,
                        None,
                        (0, 1),
                        &mut scratch,
                        &mut out,
                    );
                    let worst = r
                        .data()
                        .iter()
                        .zip(&out)
                        .map(|(a, b)| (a - b).abs())
                        .fold(0.0f32, f32::max);
                    assert!(worst < 1e-5, "{scheme} w{lanes} s{stride} {padding:?}: {worst}");
                }
            }
        }
    }

    #[test]
    fn fused_pool_matches_conv_then_maxpool() {
        use crate::nn::layers::conv::conv2d;
        use crate::nn::layers::pool::maxpool;
        use crate::nn::tensor::Tensor;
        let mut rng = crate::util::rng::SplitMix64::new(9);
        let x = Tensor::from_vec(&[1, 7, 7, 3], rng.uniform_vec(7 * 7 * 3));
        let kernel = rng.uniform_vec(3 * 3 * 3 * 5);
        let bias = rng.uniform_vec(5);
        let ep = Epilogue { act: Activation::Relu, approx: false, post: None };
        // reference: conv → relu → maxpool, all separate
        let mut conv_ref = conv2d(&x, &kernel, &[3, 3, 3, 5], Some(&bias), 1, Padding::Same);
        for v in conv_ref.data_mut() {
            *v = v.max(0.0);
        }
        let want = maxpool(&conv_ref, 2, 2, 2);
        for scheme in ["generic", "direct", "im2col"] {
            for lanes in simd::LANE_WIDTHS {
                let algo = algo_for(scheme, &kernel, 3 * 3 * 3, 5, lanes);
                // cell (5) + gather row (27) in one stripe
                let mut scratch = vec![0.0; 5 + 3 * 3 * 3];
                let mut out = vec![0.0; want.len()];
                conv2d_run(
                    x.data(),
                    (1, 7, 7, 3),
                    &algo,
                    (3, 3, 5),
                    Some(&bias),
                    1,
                    Padding::Same,
                    ep,
                    Some((2, 2, 2)),
                    (5, 1),
                    &mut scratch,
                    &mut out,
                );
                let worst = want
                    .data()
                    .iter()
                    .zip(&out)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(worst < 1e-5, "{scheme} w{lanes}: {worst}");
            }
        }
    }

    /// Batch ≥ GEMM_NR routes the im2col scheme through the batch-blocked
    /// tile path (plus a tail item at b=5); every scheme must still match
    /// the reference exactly as the per-item path does.
    #[test]
    fn conv_run_batch_blocked_matches_reference() {
        use crate::nn::layers::conv::conv2d;
        use crate::nn::tensor::Tensor;
        let b = 5; // one full GEMM tile + one tail item
        for (stride, padding) in [(1, Padding::Same), (2, Padding::Valid)] {
            let mut rng = crate::util::rng::SplitMix64::new(41);
            let x = Tensor::from_vec(&[b, 5, 5, 3], rng.uniform_vec(b * 5 * 5 * 3));
            let kernel = rng.uniform_vec(3 * 3 * 3 * 5);
            let bias = rng.uniform_vec(5);
            let r = conv2d(&x, &kernel, &[3, 3, 3, 5], Some(&bias), stride, padding);
            for scheme in ["generic", "direct", "im2col"] {
                for lanes in [1usize, 4, 8] {
                    let algo = algo_for(scheme, &kernel, 3 * 3 * 3, 5, lanes);
                    let mut scratch = vec![0.0; simd::GEMM_NR * 3 * 3 * 3];
                    let mut out = vec![0.0; r.len()];
                    conv2d_run(
                        x.data(),
                        (b, 5, 5, 3),
                        &algo,
                        (3, 3, 5),
                        Some(&bias),
                        stride,
                        padding,
                        Epilogue { act: Activation::Relu, approx: false, post: None },
                        None,
                        (0, 1),
                        &mut scratch,
                        &mut out,
                    );
                    let relu_ref: Vec<f32> = r.data().iter().map(|v| v.max(0.0)).collect();
                    let worst = relu_ref
                        .iter()
                        .zip(&out)
                        .map(|(a, c)| (a - c).abs())
                        .fold(0.0f32, f32::max);
                    assert!(worst < 1e-5, "{scheme} w{lanes} s{stride} {padding:?}: {worst}");
                }
            }
        }
    }

    #[test]
    fn dense_run_gemm_matches_reference_across_batches() {
        use crate::nn::layers::dense::dense as dense_ref;
        use crate::nn::tensor::Tensor;
        // rectangular dims off the 4-lane grid; batches hitting full
        // tiles, tails, and the all-tail batch < NR path
        let (in_dim, out_dim) = (10usize, 7usize);
        let mut rng = crate::util::rng::SplitMix64::new(5);
        let kernel = rng.uniform_vec(in_dim * out_dim);
        let bias = rng.uniform_vec(out_dim);
        for b in [1usize, 3, 4, 5, 8, 9] {
            let xv = rng.uniform_vec(b * in_dim);
            let x = Tensor::from_vec(&[b, in_dim], xv.clone());
            let want = dense_ref(&x, &kernel, &[in_dim, out_dim], Some(&bias));
            for lanes in simd::LANE_WIDTHS {
                let panels = WeightPanels::F32(
                    simd::pack_dense_panels_any(&kernel, in_dim, out_dim, lanes).into(),
                );
                for (label, algo) in [
                    ("generic", DenseAlgo::Generic { kernel: kernel.clone() }),
                    ("gemm", DenseAlgo::Gemm { panels, lanes, tail: DenseTail::Panels }),
                ] {
                    let mut out = vec![0.0; b * out_dim];
                    dense_run(
                        &xv,
                        (b, in_dim),
                        &algo,
                        out_dim,
                        Some(&bias),
                        Epilogue::NONE,
                        &mut [],
                        1,
                        &mut out,
                    );
                    let worst = want
                        .data()
                        .iter()
                        .zip(&out)
                        .map(|(a, c)| (a - c).abs())
                        .fold(0.0f32, f32::max);
                    assert!(worst < 1e-5, "{label} w{lanes} b={b}: {worst}");
                }
            }
        }
    }

    #[test]
    fn dense_run_square_tails_match_reference() {
        use crate::nn::layers::dense::dense as dense_ref;
        use crate::nn::tensor::Tensor;
        let n = 8usize;
        let mut rng = crate::util::rng::SplitMix64::new(7);
        let kernel = rng.uniform_vec(n * n);
        let bias = rng.uniform_vec(n);
        let panels = simd::pack_dense_panels(&kernel, n, n);
        // y = W x orientation for the matvec tails: W[i][j] = K[j][i]
        let mut wt = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                wt[i * n + j] = kernel[j * n + i];
            }
        }
        let diag = simd::rotate_diagonals(&wt, n);
        for b in [1usize, 3, 6] {
            let xv = rng.uniform_vec(b * n);
            let x = Tensor::from_vec(&[b, n], xv.clone());
            let want = dense_ref(&x, &kernel, &[n, n], Some(&bias));
            for (label, tail) in [
                ("rotated", DenseTail::Rotated { diag: diag.clone() }),
                ("broadcast", DenseTail::Broadcast { w: wt.clone() }),
            ] {
                let algo = DenseAlgo::Gemm {
                    panels: WeightPanels::F32(panels.clone().into()),
                    lanes: 4,
                    tail,
                };
                let mut scratch = vec![0.0f32; 2 * n];
                let mut out = vec![0.0; b * n];
                dense_run(
                    &xv,
                    (b, n),
                    &algo,
                    n,
                    Some(&bias),
                    Epilogue::NONE,
                    &mut scratch,
                    1,
                    &mut out,
                );
                let worst = want
                    .data()
                    .iter()
                    .zip(&out)
                    .map(|(a, c)| (a - c).abs())
                    .fold(0.0f32, f32::max);
                assert!(worst < 1e-4, "{label} b={b}: {worst}");
            }
        }
    }

    /// The §3.4 satellite property at the Epilogue level: the 4-lane store
    /// form is bit-identical to the scalar reference for every activation
    /// × approximation × post-affine combination.
    #[test]
    fn lane_epilogue_bit_identical_to_scalar() {
        let scale: Vec<f32> = (0..8).map(|i| 0.5 + 0.1 * i as f32).collect();
        let shift: Vec<f32> = (0..8).map(|i| -0.3 + 0.05 * i as f32).collect();
        let mut rng = crate::util::rng::SplitMix64::new(11);
        for act in [
            Activation::Linear,
            Activation::Relu,
            Activation::Relu6,
            Activation::LeakyRelu,
            Activation::Sigmoid,
            Activation::Tanh,
        ] {
            for approx_on in [false, true] {
                for with_post in [false, true] {
                    let post = if with_post {
                        Some((scale.as_slice(), shift.as_slice()))
                    } else {
                        None
                    };
                    let ep = Epilogue { act, approx: approx_on, post };
                    // values inside the approximations' working ranges
                    let vals: Vec<f32> = (0..8).map(|_| rng.next_uniform() * 4.0).collect();
                    let mut whole = vals.clone();
                    ep.apply(&mut whole);
                    for c0 in [0usize, 4] {
                        let mut lanes = [vals[c0], vals[c0 + 1], vals[c0 + 2], vals[c0 + 3]];
                        ep.apply_lanes(&mut lanes, c0);
                        for l in 0..4 {
                            assert_eq!(
                                lanes[l].to_bits(),
                                whole[c0 + l].to_bits(),
                                "{act:?} approx={approx_on} post={with_post} lane {l}"
                            );
                        }
                    }
                    // the wider store-group forms agree lane-for-lane too
                    let mut lanes8 = [0.0f32; 8];
                    lanes8.copy_from_slice(&vals);
                    ep.apply_lanes_w::<8>(&mut lanes8, 0);
                    for l in 0..8 {
                        assert_eq!(
                            lanes8[l].to_bits(),
                            whole[l].to_bits(),
                            "{act:?} approx={approx_on} post={with_post} w8 lane {l}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn dense_run_propagates_nonfinite_weights() {
        // A zero input against an Inf/NaN weight row must produce NaN in
        // every algo — the removed `xv == 0.0` skip silently dropped it.
        let (in_dim, out_dim) = (4usize, 3usize);
        let mut kernel = vec![0.5f32; in_dim * out_dim];
        kernel[0] = f32::INFINITY; // K[0][0]
        kernel[1] = f32::NAN; // K[0][1]
        let panels = WeightPanels::F32(simd::pack_dense_panels(&kernel, in_dim, out_dim).into());
        let x = [0.0f32, 1.0, -1.0, 0.5];
        for (label, algo) in [
            ("generic", DenseAlgo::Generic { kernel: kernel.clone() }),
            ("gemm", DenseAlgo::Gemm { panels, lanes: 4, tail: DenseTail::Panels }),
        ] {
            let mut out = [0.0f32; 3];
            dense_run(&x, (1, in_dim), &algo, out_dim, None, Epilogue::NONE, &mut [], 1, &mut out);
            assert!(out[0].is_nan(), "{label}: 0·Inf must be NaN, got {}", out[0]);
            assert!(out[1].is_nan(), "{label}: 0·NaN must be NaN, got {}", out[1]);
            assert!((out[2] - 0.25).abs() < 1e-6, "{label}: finite lane drifted");
        }
    }

    /// The intra-op satellite property: a banded run is **bitwise**
    /// identical to the sequential one for every conv scheme × lane width,
    /// both unfused and with the fused max-pool, including the
    /// batch-blocked im2col path whose bands re-tile their item sub-range.
    #[test]
    fn conv_parallel_split_bitwise_matches_sequential() {
        use crate::nn::tensor::Tensor;
        // two full GEMM_NR item groups + one tail item, so the blocked
        // im2col path really splits across bands on the NR grid
        let b = 9;
        let mut rng = crate::util::rng::SplitMix64::new(23);
        let x = Tensor::from_vec(&[b, 6, 6, 3], rng.uniform_vec(b * 6 * 6 * 3));
        let kernel = rng.uniform_vec(3 * 3 * 3 * 5);
        let bias = rng.uniform_vec(5);
        let ep = Epilogue { act: Activation::Tanh, approx: true, post: None };
        for scheme in ["generic", "direct", "im2col"] {
            for lanes in [1usize, 4, 8] {
                let algo = algo_for(scheme, &kernel, 3 * 3 * 3, 5, lanes);
                for pool in [None, Some((2, 2, 2))] {
                    let (cell_len, out_len) = match pool {
                        None => (0, b * 6 * 6 * 5),
                        Some(_) => (5, b * 3 * 3 * 5),
                    };
                    let stripe = cell_len + simd::GEMM_NR * 3 * 3 * 3;
                    let run = |tasks: usize| {
                        let mut scratch = vec![0.0; stripe * tasks];
                        let mut out = vec![0.0f32; out_len];
                        conv2d_run(
                            x.data(),
                            (b, 6, 6, 3),
                            &algo,
                            (3, 3, 5),
                            Some(&bias),
                            1,
                            Padding::Same,
                            ep,
                            pool,
                            (cell_len, tasks),
                            &mut scratch,
                            &mut out,
                        );
                        out
                    };
                    let seq = run(1);
                    for tasks in [2usize, 3, 4] {
                        let par = run(tasks);
                        for (i, (a, c)) in seq.iter().zip(&par).enumerate() {
                            assert_eq!(
                                a.to_bits(),
                                c.to_bits(),
                                "{scheme} w{lanes} pool={pool:?} tasks={tasks} elem {i}"
                            );
                        }
                    }
                }
            }
        }
    }

    /// Dense mirror of the intra-op property: item bands re-tile into
    /// their own full tiles + tails, and the result stays bit-identical to
    /// the sequential run for every algo/tail — including the rotated tail
    /// with its per-task doubled-x scratch stripes.
    #[test]
    fn dense_parallel_split_bitwise_matches_sequential() {
        let n = 8usize;
        let b = 9usize;
        let mut rng = crate::util::rng::SplitMix64::new(29);
        let kernel = rng.uniform_vec(n * n);
        let bias = rng.uniform_vec(n);
        let xv = rng.uniform_vec(b * n);
        let mut wt = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                wt[i * n + j] = kernel[j * n + i];
            }
        }
        let diag = simd::rotate_diagonals(&wt, n);
        let ep = Epilogue { act: Activation::Sigmoid, approx: true, post: None };
        for lanes in [1usize, 4, 8] {
            let panels =
                WeightPanels::F32(simd::pack_dense_panels_any(&kernel, n, n, lanes).into());
            let algos = [
                ("generic", DenseAlgo::Generic { kernel: kernel.clone() }),
                (
                    "gemm+panels",
                    DenseAlgo::Gemm { panels: panels.clone(), lanes, tail: DenseTail::Panels },
                ),
                (
                    "gemm+rotated",
                    DenseAlgo::Gemm {
                        panels: panels.clone(),
                        lanes,
                        tail: DenseTail::Rotated { diag: diag.clone() },
                    },
                ),
                (
                    "gemm+broadcast",
                    DenseAlgo::Gemm {
                        panels: panels.clone(),
                        lanes,
                        tail: DenseTail::Broadcast { w: wt.clone() },
                    },
                ),
            ];
            for (label, algo) in &algos {
                let run = |tasks: usize| {
                    let mut scratch = vec![0.0f32; 2 * n * tasks];
                    let mut out = vec![0.0f32; b * n];
                    dense_run(
                        &xv,
                        (b, n),
                        algo,
                        n,
                        Some(&bias),
                        ep,
                        &mut scratch,
                        tasks,
                        &mut out,
                    );
                    out
                };
                let seq = run(1);
                for tasks in [2usize, 4] {
                    let par = run(tasks);
                    for (i, (a, c)) in seq.iter().zip(&par).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            c.to_bits(),
                            "{label} w{lanes} tasks={tasks} elem {i}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn softmax_into_approx_close() {
        let x = [1.0f32, 2.0, 3.0, 0.5, 0.1, -1.0];
        let mut exact = [0.0; 6];
        let mut fast = [0.0; 6];
        softmax_into(&x, 3, false, &mut exact);
        softmax_into(&x, 3, true, &mut fast);
        for (a, b) in exact.iter().zip(&fast) {
            assert!((a - b).abs() < 0.05);
        }
    }

    /// The dtype axis at the kernel level: bf16 and i8 panels run the same
    /// blocked paths (direct, per-item im2col, batch-blocked im2col) and
    /// land within their per-dtype tolerance of the f32 reference — bf16
    /// tight (8-bit mantissa), i8 bounded by the per-channel scale.
    #[test]
    fn conv_narrow_dtypes_match_reference_within_tolerance() {
        use crate::nn::layers::conv::conv2d;
        use crate::nn::tensor::Tensor;
        let b = 5; // one full GEMM tile + a tail item for the im2col path
        let mut rng = crate::util::rng::SplitMix64::new(77);
        let x = Tensor::from_vec(&[b, 5, 5, 3], rng.uniform_vec(b * 5 * 5 * 3));
        let kernel = rng.uniform_vec(3 * 3 * 3 * 5);
        let bias = rng.uniform_vec(5);
        let r = conv2d(&x, &kernel, &[3, 3, 3, 5], Some(&bias), 1, Padding::Same);
        for dtype in [simd::WeightDtype::Bf16, simd::WeightDtype::I8] {
            // worst-case absolute bound: taps × per-weight storage error
            let tol = match dtype {
                simd::WeightDtype::I8 => 0.15,
                _ => 0.06,
            };
            for scheme in ["direct", "im2col"] {
                for lanes in [1usize, 4, 8] {
                    let algo = algo_for_dtype(scheme, &kernel, 3 * 3 * 3, 5, lanes, dtype);
                    let mut scratch = vec![0.0; simd::GEMM_NR * 3 * 3 * 3];
                    let mut out = vec![0.0; r.len()];
                    conv2d_run(
                        x.data(),
                        (b, 5, 5, 3),
                        &algo,
                        (3, 3, 5),
                        Some(&bias),
                        1,
                        Padding::Same,
                        Epilogue::NONE,
                        None,
                        (0, 1),
                        &mut scratch,
                        &mut out,
                    );
                    let worst = r
                        .data()
                        .iter()
                        .zip(&out)
                        .map(|(a, c)| (a - c).abs())
                        .fold(0.0f32, f32::max);
                    assert!(worst < tol, "{dtype} {scheme} w{lanes}: {worst}");
                }
            }
        }
    }

    #[test]
    fn dense_narrow_dtypes_match_reference_within_tolerance() {
        use crate::nn::layers::dense::dense as dense_ref;
        use crate::nn::tensor::Tensor;
        let (in_dim, out_dim) = (10usize, 7usize);
        let b = 5; // full tile + tail item
        let mut rng = crate::util::rng::SplitMix64::new(79);
        let kernel = rng.uniform_vec(in_dim * out_dim);
        let bias = rng.uniform_vec(out_dim);
        let xv = rng.uniform_vec(b * in_dim);
        let x = Tensor::from_vec(&[b, in_dim], xv.clone());
        let want = dense_ref(&x, &kernel, &[in_dim, out_dim], Some(&bias));
        for dtype in [simd::WeightDtype::Bf16, simd::WeightDtype::I8] {
            let tol = match dtype {
                simd::WeightDtype::I8 => 0.08,
                _ => 0.03,
            };
            for lanes in [1usize, 4, 8] {
                let panels = WeightPanels::pack_dense(&kernel, in_dim, out_dim, lanes, dtype);
                assert_eq!(panels.dtype(), dtype);
                let algo = DenseAlgo::Gemm { panels, lanes, tail: DenseTail::Panels };
                let mut out = vec![0.0; b * out_dim];
                dense_run(
                    &xv,
                    (b, in_dim),
                    &algo,
                    out_dim,
                    Some(&bias),
                    Epilogue::NONE,
                    &mut [],
                    1,
                    &mut out,
                );
                let worst = want
                    .data()
                    .iter()
                    .zip(&out)
                    .map(|(a, c)| (a - c).abs())
                    .fold(0.0f32, f32::max);
                assert!(worst < tol, "{dtype} w{lanes}: {worst}");
            }
        }
    }

    /// Narrow-dtype storage really shrinks: byte accounting of the packed
    /// panels is the per-dtype element size (+ the i8 scale vector).
    #[test]
    fn weight_panels_byte_accounting_tracks_dtype() {
        let mut rng = crate::util::rng::SplitMix64::new(83);
        let kernel = rng.uniform_vec(9 * 8);
        let f = WeightPanels::pack_conv(&kernel, 9, 8, 4, simd::WeightDtype::F32);
        let h = WeightPanels::pack_conv(&kernel, 9, 8, 4, simd::WeightDtype::Bf16);
        let q = WeightPanels::pack_conv(&kernel, 9, 8, 4, simd::WeightDtype::I8);
        assert_eq!(f.weight_bytes(), 9 * 8 * 4);
        assert_eq!(h.weight_bytes(), 9 * 8 * 2);
        assert_eq!(q.weight_bytes(), 9 * 8 + 8 * 4); // data + scales
        assert!(f.scales().is_none() && q.scales().unwrap().len() == 8);
    }

    #[test]
    fn concat_into_interleaves() {
        let a = [1.0f32, 2.0, 3.0, 4.0]; // 2 pixels × 2ch
        let b = [9.0f32, 8.0]; // 2 pixels × 1ch
        let mut out = [0.0; 6];
        concat_into(&a, 2, &b, 1, &mut out);
        assert_eq!(out, [1., 2., 9., 3., 4., 8.]);
    }
}
