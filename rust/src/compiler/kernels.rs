//! Allocation-free layer kernels used by the optimized interpreter: each
//! writes into a caller-provided buffer and applies the fused epilogue
//! (activation + §3.5 post-affine) **in the store loop** — the paper's §3.4
//! fusion ("the activation function is applied before writing the result of
//! the operation into memory").

use crate::approx;
use crate::model::spec::{same_pads, Activation, Padding};

/// Fused store epilogue: activation (exact or §3.4 approximation) followed
/// by the optional folded-BN affine.
#[derive(Clone, Copy)]
pub struct Epilogue<'a> {
    pub act: Activation,
    pub approx: bool,
    pub post: Option<(&'a [f32], &'a [f32])>, // (scale, shift) per channel
}

impl<'a> Epilogue<'a> {
    pub const NONE: Epilogue<'static> =
        Epilogue { act: Activation::Linear, approx: false, post: None };

    #[inline(always)]
    fn activate(&self, v: f32) -> f32 {
        match self.act {
            Activation::Linear => v,
            Activation::Relu => v.max(0.0),
            Activation::Relu6 => v.clamp(0.0, 6.0),
            Activation::LeakyRelu => {
                if v >= 0.0 {
                    v
                } else {
                    0.1 * v
                }
            }
            Activation::Sigmoid => {
                if self.approx {
                    approx::fast_sigmoid(v)
                } else {
                    1.0 / (1.0 + (-v).exp())
                }
            }
            Activation::Tanh => {
                if self.approx {
                    approx::fast_tanh(v)
                } else {
                    v.tanh()
                }
            }
        }
    }

    /// Apply to a channel vector in place.
    #[inline(always)]
    pub fn apply(&self, dst: &mut [f32]) {
        match self.post {
            None => {
                for v in dst.iter_mut() {
                    *v = self.activate(*v);
                }
            }
            Some((scale, shift)) => {
                for (c, v) in dst.iter_mut().enumerate() {
                    *v = self.activate(*v) * scale[c] + shift[c];
                }
            }
        }
    }

    /// Apply over a whole buffer of row-major `c`-channel vectors (the
    /// post-affine is channel-cyclic; a bare activation is elementwise and
    /// takes one whole-slice pass).
    pub fn apply_whole(&self, buf: &mut [f32], c: usize) {
        if self.post.is_none() {
            self.apply(buf);
        } else {
            for chunk in buf.chunks_mut(c) {
                self.apply(chunk);
            }
        }
    }
}

/// conv2d, NHWC × HWIO → NHWC, fused epilogue. Shapes are per the planner.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_into(
    x: &[f32],
    (b, h, w, c): (usize, usize, usize, usize),
    kernel: &[f32],
    (kh, kw, oc): (usize, usize, usize),
    bias: Option<&[f32]>,
    stride: usize,
    padding: Padding,
    ep: Epilogue,
    out: &mut [f32],
) {
    let (pt, pl) = match padding {
        Padding::Same => (same_pads(h, kh, stride).0, same_pads(w, kw, stride).0),
        Padding::Valid => (0, 0),
    };
    let (oh, ow) = crate::model::spec::conv_out(h, w, kh, kw, stride, padding);
    debug_assert_eq!(out.len(), b * oh * ow * oc);

    for n in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let dst = &mut out[((n * oh + oy) * ow + ox) * oc..][..oc];
                match bias {
                    Some(bs) => dst.copy_from_slice(bs),
                    None => dst.fill(0.0),
                }
                let y0 = (oy * stride) as isize - pt as isize;
                let x0 = (ox * stride) as isize - pl as isize;
                for ky in 0..kh {
                    let iy = y0 + ky as isize;
                    if iy < 0 || iy as usize >= h {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = x0 + kx as isize;
                        if ix < 0 || ix as usize >= w {
                            continue;
                        }
                        let px = &x[((n * h + iy as usize) * w + ix as usize) * c..][..c];
                        let kbase = (ky * kw + kx) * c * oc;
                        for (ci, &xv) in px.iter().enumerate() {
                            if xv == 0.0 {
                                continue; // ReLU-sparse inputs
                            }
                            let krow = &kernel[kbase + ci * oc..][..oc];
                            for o in 0..oc {
                                dst[o] += xv * krow[o];
                            }
                        }
                    }
                }
                ep.apply(dst);
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
pub fn depthwise_conv2d_into(
    x: &[f32],
    (b, h, w, c): (usize, usize, usize, usize),
    kernel: &[f32],
    (kh, kw): (usize, usize),
    bias: Option<&[f32]>,
    stride: usize,
    padding: Padding,
    ep: Epilogue,
    out: &mut [f32],
) {
    let (pt, pl) = match padding {
        Padding::Same => (same_pads(h, kh, stride).0, same_pads(w, kw, stride).0),
        Padding::Valid => (0, 0),
    };
    let (oh, ow) = crate::model::spec::conv_out(h, w, kh, kw, stride, padding);
    for n in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let dst = &mut out[((n * oh + oy) * ow + ox) * c..][..c];
                match bias {
                    Some(bs) => dst.copy_from_slice(bs),
                    None => dst.fill(0.0),
                }
                let y0 = (oy * stride) as isize - pt as isize;
                let x0 = (ox * stride) as isize - pl as isize;
                for ky in 0..kh {
                    let iy = y0 + ky as isize;
                    if iy < 0 || iy as usize >= h {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = x0 + kx as isize;
                        if ix < 0 || ix as usize >= w {
                            continue;
                        }
                        let px = &x[((n * h + iy as usize) * w + ix as usize) * c..][..c];
                        let krow = &kernel[(ky * kw + kx) * c..][..c];
                        for ci in 0..c {
                            dst[ci] += px[ci] * krow[ci];
                        }
                    }
                }
                ep.apply(dst);
            }
        }
    }
}

pub fn dense_into(
    x: &[f32],
    (b, in_dim): (usize, usize),
    kernel: &[f32],
    out_dim: usize,
    bias: Option<&[f32]>,
    ep: Epilogue,
    out: &mut [f32],
) {
    for n in 0..b {
        let xrow = &x[n * in_dim..][..in_dim];
        let dst = &mut out[n * out_dim..][..out_dim];
        match bias {
            Some(bs) => dst.copy_from_slice(bs),
            None => dst.fill(0.0),
        }
        for (i, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let krow = &kernel[i * out_dim..][..out_dim];
            for o in 0..out_dim {
                dst[o] += xv * krow[o];
            }
        }
        ep.apply(dst);
    }
}

pub fn maxpool_into(
    x: &[f32],
    (b, h, w, c): (usize, usize, usize, usize),
    (kh, kw, stride): (usize, usize, usize),
    out: &mut [f32],
) {
    let (oh, ow) = ((h - kh) / stride + 1, (w - kw) / stride + 1);
    for n in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let dst = &mut out[((n * oh + oy) * ow + ox) * c..][..c];
                dst.fill(f32::NEG_INFINITY);
                for ky in 0..kh {
                    for kx in 0..kw {
                        let px = &x[((n * h + oy * stride + ky) * w + ox * stride + kx) * c..][..c];
                        for ci in 0..c {
                            if px[ci] > dst[ci] {
                                dst[ci] = px[ci];
                            }
                        }
                    }
                }
            }
        }
    }
}

pub fn avgpool_into(
    x: &[f32],
    (b, h, w, c): (usize, usize, usize, usize),
    (kh, kw, stride): (usize, usize, usize),
    out: &mut [f32],
) {
    let (oh, ow) = ((h - kh) / stride + 1, (w - kw) / stride + 1);
    let inv = 1.0 / (kh * kw) as f32;
    for n in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let dst = &mut out[((n * oh + oy) * ow + ox) * c..][..c];
                dst.fill(0.0);
                for ky in 0..kh {
                    for kx in 0..kw {
                        let px = &x[((n * h + oy * stride + ky) * w + ox * stride + kx) * c..][..c];
                        for ci in 0..c {
                            dst[ci] += px[ci];
                        }
                    }
                }
                for v in dst.iter_mut() {
                    *v *= inv;
                }
            }
        }
    }
}

pub fn globalavgpool_into(x: &[f32], (b, h, w, c): (usize, usize, usize, usize), out: &mut [f32]) {
    let inv = 1.0 / (h * w) as f32;
    for n in 0..b {
        let dst = &mut out[n * c..][..c];
        dst.fill(0.0);
        for p in 0..h * w {
            let px = &x[(n * h * w + p) * c..][..c];
            for ci in 0..c {
                dst[ci] += px[ci];
            }
        }
        for v in dst.iter_mut() {
            *v *= inv;
        }
    }
}

pub fn upsample_into(
    x: &[f32],
    (b, h, w, c): (usize, usize, usize, usize),
    factor: usize,
    out: &mut [f32],
) {
    let (oh, ow) = (h * factor, w * factor);
    for n in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let src = &x[((n * h + oy / factor) * w + ox / factor) * c..][..c];
                out[((n * oh + oy) * ow + ox) * c..][..c].copy_from_slice(src);
            }
        }
    }
}

pub fn zeropad_into(
    x: &[f32],
    (b, h, w, c): (usize, usize, usize, usize),
    pad: [usize; 4],
    out: &mut [f32],
) {
    let [t, bo, l, r] = pad;
    let (oh, ow) = (h + t + bo, w + l + r);
    out.fill(0.0);
    for n in 0..b {
        for y in 0..h {
            for xx in 0..w {
                let src = &x[((n * h + y) * w + xx) * c..][..c];
                out[((n * oh + y + t) * ow + xx + l) * c..][..c].copy_from_slice(src);
            }
        }
    }
}

/// Per-channel affine (BN at exec time or standalone §3.5 affine).
pub fn affine_into(x: &[f32], c: usize, scale: &[f32], shift: &[f32], out: &mut [f32]) {
    out.copy_from_slice(x);
    affine_rows(out, c, scale, shift);
}

/// Per-channel affine applied in place (the §3.2 aliased-buffer path).
pub fn affine_rows(buf: &mut [f32], c: usize, scale: &[f32], shift: &[f32]) {
    for (i, v) in buf.iter_mut().enumerate() {
        let ci = i % c;
        *v = *v * scale[ci] + shift[ci];
    }
}

/// `dst += src`, elementwise (the in-place residual add).
pub fn add_assign(dst: &mut [f32], src: &[f32]) {
    for (v, &s) in dst.iter_mut().zip(src) {
        *v += s;
    }
}

/// Softmax over trailing axis; `approx` uses the §3.4 two-pass fast-exp.
pub fn softmax_into(x: &[f32], c: usize, approx_exp: bool, out: &mut [f32]) {
    out.copy_from_slice(x);
    softmax_rows(out, c, approx_exp);
}

/// In-place softmax over rows of length `c` (the §3.2 aliased-buffer path).
pub fn softmax_rows(buf: &mut [f32], c: usize, approx_exp: bool) {
    for row in buf.chunks_exact_mut(c) {
        if approx_exp {
            approx::fast_softmax_row(row);
        } else {
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0;
            for v in row.iter_mut() {
                *v = (*v - m).exp();
                sum += *v;
            }
            let inv = 1.0 / sum;
            for v in row.iter_mut() {
                *v *= inv;
            }
        }
    }
}

pub fn add_into(a: &[f32], b: &[f32], out: &mut [f32]) {
    for ((&x, &y), o) in a.iter().zip(b).zip(out.iter_mut()) {
        *o = x + y;
    }
}

pub fn concat_into(a: &[f32], ca: usize, b: &[f32], cb: usize, out: &mut [f32]) {
    let pixels = a.len() / ca;
    debug_assert_eq!(b.len() / cb, pixels);
    for p in 0..pixels {
        out[p * (ca + cb)..][..ca].copy_from_slice(&a[p * ca..][..ca]);
        out[p * (ca + cb) + ca..][..cb].copy_from_slice(&b[p * cb..][..cb]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epilogue_fuses_act_and_affine() {
        let ep = Epilogue {
            act: Activation::Relu,
            approx: false,
            post: Some((&[2.0, 2.0], &[1.0, 1.0])),
        };
        let mut v = [-3.0f32, 4.0];
        ep.apply(&mut v);
        assert_eq!(v, [1.0, 9.0]); // relu then *2+1
    }

    #[test]
    fn conv_into_matches_reference() {
        use crate::nn::layers::conv::conv2d;
        use crate::nn::tensor::Tensor;
        let mut rng = crate::util::rng::SplitMix64::new(3);
        let x = Tensor::from_vec(&[1, 5, 5, 3], rng.uniform_vec(75));
        let kernel = rng.uniform_vec(3 * 3 * 3 * 4);
        let bias = rng.uniform_vec(4);
        let r = conv2d(&x, &kernel, &[3, 3, 3, 4], Some(&bias), 1, Padding::Same);
        let mut out = vec![0.0; r.len()];
        conv2d_into(
            x.data(),
            (1, 5, 5, 3),
            &kernel,
            (3, 3, 4),
            Some(&bias),
            1,
            Padding::Same,
            Epilogue::NONE,
            &mut out,
        );
        let worst = r.data().iter().zip(&out).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(worst < 1e-5, "{worst}");
    }

    #[test]
    fn softmax_into_approx_close() {
        let x = [1.0f32, 2.0, 3.0, 0.5, 0.1, -1.0];
        let mut exact = [0.0; 6];
        let mut fast = [0.0; 6];
        softmax_into(&x, 3, false, &mut exact);
        softmax_into(&x, 3, true, &mut fast);
        for (a, b) in exact.iter().zip(&fast) {
            assert!((a - b).abs() < 0.05);
        }
    }

    #[test]
    fn concat_into_interleaves() {
        let a = [1.0f32, 2.0, 3.0, 4.0]; // 2 pixels × 2ch
        let b = [9.0f32, 8.0]; // 2 pixels × 1ch
        let mut out = [0.0; 6];
        concat_into(&a, 2, &b, 1, &mut out);
        assert_eq!(out, [1., 2., 9., 3., 4., 8.]);
    }
}
