//! The pre-resolved execution IR: lower a (folded) [`ModelSpec`] **once**
//! into a [`Program`] — a flat, topologically ordered list of steps whose
//! kernels are concrete structs with their weights pre-sliced out of the
//! blob (pre-transformed where profitable: folded BN scale/shift vectors,
//! §3.3 rotated-diagonal Dense layouts) and their input/output positions
//! pre-resolved as offsets into a single [`Arena`] laid out from the §3.2
//! [`memory::MemoryPlan`].
//!
//! This is the paper's core move applied to the interpreter tier: every
//! statically known property of the network — shapes, buffer addresses,
//! kernel variants, fused epilogues — is resolved at compile time, so
//! [`Program::run`] contains **no name lookups, no allocation and no
//! `LayerOp` dispatch** per inference (asserted by `tests/program_alloc.rs`
//! and the [`PlanSummary`] counters). The pipeline is:
//!
//! ```text
//! ModelSpec ──fuse::fold_batchnorm──► folded spec          (§3.5)
//!           ──memory::plan──────────► MemoryPlan           (§3.2)
//!           ──Program::lower────────► Vec<Step> + spans    (this module)
//!           ──Program::run──────────► kernels over &mut Arena
//! ```
//!
//! [`OptInterp`](crate::compiler::exec::OptInterp) is a thin engine shell
//! over a `Program` plus an [`ArenaPool`] (one arena per batch size, so
//! bucketed serving is allocation-free in steady state).

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::compiler::artifact::{corrupt, ArtifactError, Decoder, Encoder};
use crate::compiler::cost;
use crate::compiler::fuse;
use crate::compiler::kernels as k;
use crate::compiler::memory;
use crate::cpu;
use crate::model::spec::{Activation, Layer, LayerOp, ModelSpec, Padding};
use crate::nn::simd;
use crate::nn::tensor::Tensor;

/// How Dense layers are lowered (the §3.3 matrix–vector schemes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DenseScheme {
    /// Pick per layer by pricing every legal candidate with the §3.3
    /// Silvermont cost model ([`cost::dense_candidates`]) under
    /// [`CompileOptions::batch_hint`] and taking the argmin; the
    /// chosen tail and the decision trail land in the plan summary's
    /// [`cost::LoweringReport`]. Layers the model declines to price
    /// (zero MACs) fall back to the blocked-GEMM panels.
    Auto,
    /// Eq. 3: weights pre-rotated into stacked diagonals at lowering time;
    /// eligible square layers use [`simd::matvec_rotated`].
    Rotated,
    /// Eq. 2: broadcast scheme ([`simd::matvec_broadcast`]) on eligible
    /// square layers — the ablation baseline for the rotated layout.
    Broadcast,
    /// The generic fused kernel for every layer (also the bit-exact path:
    /// it accumulates in the same order as the naive oracle).
    Generic,
}

/// How Conv2d layers are lowered (the §3.3 conv→matvec core): which inner
/// loop computes each output pixel's channel vector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConvScheme {
    /// Pick per layer by pricing every legal candidate — direct, im2col,
    /// and generic, each with and without a fused max-pool — through the
    /// §3.3 Silvermont cost model ([`cost::conv_candidates`]) and taking
    /// the argmin among candidates matching the actual fusion decision.
    /// The full decision trail is recorded in the plan summary's
    /// [`cost::LoweringReport`]. If the model declines to price a layer
    /// (it does no MAC work), lowering falls back in order: the geometry
    /// rule (1×1 and VALID windows → [`ConvScheme::Direct`], padded
    /// multi-tap windows → [`ConvScheme::Im2col`]), then
    /// [`ConvScheme::Generic`].
    Auto,
    /// Lane-blocked FMA straight over the NHWC window
    /// ([`simd::pack_conv_panels_w`] layout at the selected width, border
    /// taps skipped).
    Direct,
    /// The same blocked FMA over a gathered, zero-padded im2col row — one
    /// contiguous stream per pixel regardless of border clipping.
    Im2col,
    /// The scalar reference loop (also the bit-exact path: it accumulates
    /// in the same order as the naive oracle).
    Generic,
}

/// Which SIMD lane widths the §3.3 blocked kernels may be lowered at.
///
/// `Auto` resolves at `Program::lower` time: an explicit
/// `COMPILED_NN_FORCE_LANES` env override wins, otherwise the widest width
/// the host CPU supports ([`cpu::auto_lanes`]) becomes the *ceiling* — the
/// cost model still prices every width up to it per layer and the argmin
/// decides (tail-dominated shapes legitimately prefer narrower lanes).
/// Every width is a portable instantiation of the same generic kernels, so
/// forcing a width on any host changes performance, never correctness.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LaneSelect {
    /// Ceiling = widest the host supports (env override respected).
    #[default]
    Auto,
    /// Force the scalar (1-lane) instantiations — the reference used by
    /// the differential fuzz legs and [`CompileOptions::bit_exact`].
    Scalar,
    /// Force 4-lane (SSE-shaped) kernels.
    W4,
    /// Force 8-lane (AVX2-shaped) kernels.
    W8,
    /// Force 16-lane (AVX-512-shaped) kernels.
    W16,
}

impl LaneSelect {
    /// The forced width, or `None` for `Auto`.
    pub fn width(self) -> Option<usize> {
        match self {
            LaneSelect::Auto => None,
            LaneSelect::Scalar => Some(1),
            LaneSelect::W4 => Some(4),
            LaneSelect::W8 => Some(8),
            LaneSelect::W16 => Some(16),
        }
    }
}

/// How `Auto` scheme selection turns candidate prices into a decision:
/// trust the §3.3 predicted cycles, or time the top candidates on the
/// actual machine and let the empirical argmin win. Measured tuning is the
/// first feedback loop into the cost model — its winners (and an
/// `overturned` flag wherever measurement disagreed with prediction) land
/// in each [`cost::LayerDecision`] and persist into cached artifacts, so
/// the one-time timing cost amortizes exactly like the rest of lowering.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TuneMode {
    /// Take the cost model's predicted-cycles argmin as-is (the default;
    /// lowering does no timing work).
    #[default]
    Predicted,
    /// Time the top predicted conv/dense candidates per layer on the real
    /// machine (one warmup + `reps` timed runs each, minimum wall time
    /// wins) and take the empirical argmin. Only `Auto` scheme selection
    /// measures — forced schemes and unpriced fallbacks are unaffected.
    Measured {
        /// Timed repetitions per candidate; the minimum is kept.
        reps: u32,
    },
}

/// Which of the paper's optimizations the lowering applies (each is an
/// ablation axis exercised by `benches/ablations.rs`).
///
/// The default options give the paper's full pipeline with cost-model
/// scheme selection; struct-update syntax overrides single axes:
///
/// ```
/// use compiled_nn::compiler::program::{CompileOptions, ConvScheme, DenseScheme};
///
/// let opts = CompileOptions::default();
/// assert_eq!(opts.conv, ConvScheme::Auto);
/// assert_eq!(opts.dense, DenseScheme::Auto);
///
/// // force one axis, keep the rest of the pipeline on
/// let forced = CompileOptions { conv: ConvScheme::Direct, ..opts };
/// assert!(forced.fuse_pool && forced.fold_bn);
///
/// // the bit-exact reference path pins everything to the oracle's order
/// assert_eq!(CompileOptions::bit_exact().dense, DenseScheme::Generic);
///
/// // weight storage defaults to full precision; bit-exact pins it there
/// use compiled_nn::nn::simd::WeightDtype;
/// assert_eq!(opts.weight_dtype, WeightDtype::F32);
/// assert_eq!(CompileOptions::bit_exact().weight_dtype, WeightDtype::F32);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CompileOptions {
    /// §3.5 batch-norm folding / fusion.
    pub fold_bn: bool,
    /// §3.4 fast activation approximations.
    pub approx: bool,
    /// §3.2 lifetime-based buffer reuse (false = one buffer per tensor).
    pub reuse_memory: bool,
    /// §3.3 Dense matvec scheme selection.
    pub dense: DenseScheme,
    /// §3.3 Conv2d kernel scheme selection.
    pub conv: ConvScheme,
    /// §3.4 operation merging: run a single-consumer MaxPool inside its
    /// producing conv's store loop (the conv intermediate never
    /// materializes in the arena).
    pub fuse_pool: bool,
    /// Batch size the `Auto` cost model assumes when pricing dense layers
    /// (full 4-item tiles run blocked GEMM, the remainder runs the matvec
    /// tail). Purely a *pricing* hint — the lowered program still executes
    /// any runtime batch; 1 matches the serving fast path.
    pub batch_hint: usize,
    /// SIMD lane-width ceiling for the §3.3 blocked kernels (see
    /// [`LaneSelect`]): the cost model prices every width up to it and the
    /// per-layer argmin decides. Width is a *performance* policy — every
    /// instantiation is portable and numerically identical per scheme.
    pub lanes: LaneSelect,
    /// Intra-op worker budget for a *single* [`Program::run`]: conv
    /// output-row bands and dense batch blocks split into at most this
    /// many tasks over disjoint arena/scratch spans. 1 (the default) keeps
    /// the zero-overhead sequential path; the cost model holds small
    /// layers at 1 task regardless ([`cost::parallel_tasks`]), so tiny
    /// nets never pay thread fan-out.
    pub intra_threads: usize,
    /// Storage element type for packed conv/dense weight panels (see
    /// [`simd::WeightDtype`]): `F32` (default) keeps full precision,
    /// `Bf16` halves weight bandwidth with round-to-nearest-even panels,
    /// `I8` post-training-quantizes per output channel and dequantizes in
    /// the store-loop epilogue. A narrow *request* is a per-layer ceiling,
    /// not a mandate — scalar-generic kernels, rotated/broadcast dense
    /// tails, and layers with nonfinite weights keep f32 storage, and the
    /// dtype actually emitted lands in each [`cost::LayerDecision`].
    pub weight_dtype: simd::WeightDtype,
    /// How `Auto` candidate prices become decisions: predicted-cycles
    /// argmin (default) or measured on the real machine (see [`TuneMode`]).
    pub tune: TuneMode,
}

impl Default for CompileOptions {
    fn default() -> Self {
        Self {
            fold_bn: true,
            approx: true,
            reuse_memory: true,
            dense: DenseScheme::Auto,
            conv: ConvScheme::Auto,
            fuse_pool: true,
            batch_hint: 1,
            lanes: LaneSelect::Auto,
            intra_threads: 1,
            weight_dtype: simd::WeightDtype::F32,
            tune: TuneMode::Predicted,
        }
    }
}

impl CompileOptions {
    /// Options under which the lowered program is **bit-identical** to the
    /// naive oracle: approximations off and every value-reassociating
    /// transform disabled (folding a BN into a *linear* producer re-orders
    /// multiplications; the matvec and blocked-conv schemes re-order or
    /// pad accumulation; pool fusion is off so the reference kernels run
    /// stand-alone). The §3.2 memory plan stays on — address assignment
    /// never changes math. Lanes pin to scalar and intra-op splitting to a
    /// single task, so the reference path is also scheduling-free.
    pub fn bit_exact() -> Self {
        Self {
            fold_bn: false,
            approx: false,
            reuse_memory: true,
            dense: DenseScheme::Generic,
            conv: ConvScheme::Generic,
            fuse_pool: false,
            batch_hint: 1,
            lanes: LaneSelect::Scalar,
            intra_threads: 1,
            weight_dtype: simd::WeightDtype::F32,
            tune: TuneMode::Predicted,
        }
    }

    /// The lane-width ceiling lowering prices candidates under: an explicit
    /// [`LaneSelect`] force wins, then the `COMPILED_NN_FORCE_LANES` env
    /// override, then the widest width the host CPU supports.
    pub fn max_lanes(&self) -> usize {
        self.lanes.width().unwrap_or_else(cpu::auto_lanes)
    }

    /// The fixed 32-byte encoding artifact headers store (and cache keys
    /// hash): every field at a pinned offset, reserved tail zeroed, no
    /// platform-dependent layout. Inverse of [`Self::from_canonical_bytes`].
    pub(crate) fn canonical_bytes(&self) -> [u8; 32] {
        let mut b = [0u8; 32];
        b[0] = self.fold_bn as u8;
        b[1] = self.approx as u8;
        b[2] = self.reuse_memory as u8;
        b[3] = match self.dense {
            DenseScheme::Auto => 0,
            DenseScheme::Rotated => 1,
            DenseScheme::Broadcast => 2,
            DenseScheme::Generic => 3,
        };
        b[4] = match self.conv {
            ConvScheme::Auto => 0,
            ConvScheme::Direct => 1,
            ConvScheme::Im2col => 2,
            ConvScheme::Generic => 3,
        };
        b[5] = self.fuse_pool as u8;
        b[6] = match self.lanes {
            LaneSelect::Auto => 0,
            LaneSelect::Scalar => 1,
            LaneSelect::W4 => 2,
            LaneSelect::W8 => 3,
            LaneSelect::W16 => 4,
        };
        b[7] = match self.weight_dtype {
            simd::WeightDtype::F32 => 0,
            simd::WeightDtype::Bf16 => 1,
            simd::WeightDtype::I8 => 2,
        };
        b[8..16].copy_from_slice(&(self.batch_hint as u64).to_ne_bytes());
        b[16..24].copy_from_slice(&(self.intra_threads as u64).to_ne_bytes());
        match self.tune {
            TuneMode::Predicted => b[24] = 0,
            TuneMode::Measured { reps } => {
                b[24] = 1;
                b[25..29].copy_from_slice(&reps.to_ne_bytes());
            }
        }
        b
    }

    /// Decode [`Self::canonical_bytes`]; `None` on any out-of-range
    /// discriminant (a corrupt or future-format artifact header).
    pub(crate) fn from_canonical_bytes(b: &[u8; 32]) -> Option<CompileOptions> {
        let flag = |v: u8| match v {
            0 => Some(false),
            1 => Some(true),
            _ => None,
        };
        Some(CompileOptions {
            fold_bn: flag(b[0])?,
            approx: flag(b[1])?,
            reuse_memory: flag(b[2])?,
            dense: match b[3] {
                0 => DenseScheme::Auto,
                1 => DenseScheme::Rotated,
                2 => DenseScheme::Broadcast,
                3 => DenseScheme::Generic,
                _ => return None,
            },
            conv: match b[4] {
                0 => ConvScheme::Auto,
                1 => ConvScheme::Direct,
                2 => ConvScheme::Im2col,
                3 => ConvScheme::Generic,
                _ => return None,
            },
            fuse_pool: flag(b[5])?,
            lanes: match b[6] {
                0 => LaneSelect::Auto,
                1 => LaneSelect::Scalar,
                2 => LaneSelect::W4,
                3 => LaneSelect::W8,
                4 => LaneSelect::W16,
                _ => return None,
            },
            weight_dtype: match b[7] {
                0 => simd::WeightDtype::F32,
                1 => simd::WeightDtype::Bf16,
                2 => simd::WeightDtype::I8,
                _ => return None,
            },
            batch_hint: u64::from_ne_bytes(b[8..16].try_into().ok()?) as usize,
            intra_threads: u64::from_ne_bytes(b[16..24].try_into().ok()?) as usize,
            tune: match b[24] {
                0 => TuneMode::Predicted,
                1 => TuneMode::Measured {
                    reps: u32::from_ne_bytes(b[25..29].try_into().ok()?),
                },
                _ => return None,
            },
        })
    }
}

/// A tensor's pre-resolved position in the arena, in **per-item** element
/// units: the owning buffer starts at `start * batch`, the tensor occupies
/// the first `elems * batch` elements of it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    /// Per-item element offset of the owning planned buffer.
    pub start: usize,
    /// Per-item element count of the tensor.
    pub elems: usize,
}

impl Span {
    #[inline]
    fn range(self, batch: usize) -> Range<usize> {
        self.start * batch..(self.start + self.elems) * batch
    }

    /// Concrete element range for a batch size (tests/diagnostics).
    pub fn arena_range(self, batch: usize) -> Range<usize> {
        self.range(batch)
    }
}

/// The single flat execution buffer a [`Program`] runs in, plus the
/// kernels' mutable scratch (im2col gather rows, fused-pool cells, rotated-
/// dense doubled-x windows). One allocation pair per (program, batch);
/// reusable across inferences and poolable across batch buckets.
///
/// Every mutable word of an inference lives here, which is what makes the
/// `Program` itself an immutable `Send + Sync` artifact: N workers share
/// one `Arc<Program>` and each owns its arena.
#[derive(Debug)]
pub struct Arena {
    data: Vec<f32>,
    /// Kernel-private scratch, laid out from the [`Scratch`] spans assigned
    /// at lowering (batch-independent sizes).
    scratch: Vec<f32>,
    batch: usize,
    item_elems: usize,
}

impl Arena {
    /// Batch size this arena was allocated for.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Backing-store size in bytes (the §3.2 working-set metric).
    pub fn bytes(&self) -> usize {
        (self.data.len() + self.scratch.len()) * std::mem::size_of::<f32>()
    }
}

/// A pool of arenas keyed by batch size. Bucketed serving flips between
/// batch sizes (1 vs 8 vs 32); pooling one arena per bucket makes the
/// steady state allocation-free instead of reallocating on every flip.
/// Serving buckets are **pinned** via [`ArenaPool::reserve`] and never
/// evicted; ad-hoc batch sizes beyond that are bounded — the smallest
/// unpinned arena is evicted instead of growing without bound.
#[derive(Debug, Default)]
pub struct ArenaPool {
    arenas: Vec<Arena>,
    /// Batch sizes pinned by [`ArenaPool::reserve`] (serving buckets).
    pinned: Vec<usize>,
}

/// Most *unpinned* arenas pooled at once; beyond it the smallest is
/// evicted (the big ones are the re-allocations worth avoiding).
const MAX_UNPINNED_ARENAS: usize = 4;

impl ArenaPool {
    /// An empty pool (no arenas, no pinned buckets).
    pub fn new() -> ArenaPool {
        ArenaPool::default()
    }

    /// Pre-size and pin the arena for a serving bucket. Pinned arenas are
    /// exempt from eviction, so bucketed serving stays allocation-free no
    /// matter how many buckets are advertised.
    pub fn reserve(&mut self, program: &Program, batch: usize) {
        if !self.pinned.contains(&batch) {
            self.pinned.push(batch);
        }
        let _ = self.get(program, batch);
    }

    /// The pooled arena for `batch`, created on first use. An arena only
    /// matches if its kernel-scratch size also fits the program — two
    /// lowerings of one spec can share `item_elems` yet differ in scratch
    /// (e.g. bit-exact vs default options), and handing one's arena to the
    /// other would hand its kernels an undersized scratch buffer.
    pub fn get(&mut self, program: &Program, batch: usize) -> &mut Arena {
        if let Some(i) = self.arenas.iter().position(|a| {
            a.batch == batch
                && a.item_elems == program.item_elems
                && a.scratch.len() == program.scratch_elems
        }) {
            return &mut self.arenas[i];
        }
        let unpinned =
            self.arenas.iter().filter(|a| !self.pinned.contains(&a.batch)).count();
        if unpinned >= MAX_UNPINNED_ARENAS && !self.pinned.contains(&batch) {
            let evict = self
                .arenas
                .iter()
                .enumerate()
                .filter(|(_, a)| !self.pinned.contains(&a.batch))
                .min_by_key(|(_, a)| a.data.len())
                .map(|(i, _)| i)
                .expect("unpinned arena exists");
            self.arenas.swap_remove(evict);
        }
        self.arenas.push(program.new_arena(batch));
        self.arenas.last_mut().expect("arena just pushed")
    }

    /// Total pooled bytes across batch sizes.
    pub fn bytes(&self) -> usize {
        self.arenas.iter().map(Arena::bytes).sum()
    }

    /// Number of pooled arenas (one per distinct batch size in use).
    pub fn len(&self) -> usize {
        self.arenas.len()
    }

    /// True when no arena has been created yet.
    pub fn is_empty(&self) -> bool {
        self.arenas.is_empty()
    }
}

/// A kernel's span in the arena's batch-independent scratch buffer,
/// assigned at lowering. Scratch is the only memory a kernel mutates
/// besides the arena itself, so handing it to the caller is what lets
/// `run` take `&self`.
#[derive(Debug, Clone, Copy, Default)]
struct Scratch {
    start: usize,
    len: usize,
}

impl Scratch {
    #[inline]
    fn slice<'a>(&self, scratch: &'a mut [f32]) -> &'a mut [f32] {
        &mut scratch[self.start..self.start + self.len]
    }
}

/// A pre-monomorphized kernel: a concrete struct holding its weights,
/// shapes and arena spans, resolved entirely at lowering time. `run` is
/// the only per-inference code — it must not allocate, look anything up by
/// name, or match on [`LayerOp`]. Kernels are immutable at run time (all
/// mutable state lives in the caller's [`Arena`]), which makes the whole
/// [`Program`] `Send + Sync` and shareable across worker threads.
trait Kernel: Send + Sync {
    fn run(&self, batch: usize, data: &mut [f32], scratch: &mut [f32]);
    /// Serialize this kernel (a type tag followed by its fields) into an
    /// artifact; [`decode_kernel`] is the exact inverse. Weight panels go
    /// to the 64-byte-aligned blob, everything else to the meta table.
    fn encode(&self, e: &mut Encoder);
}

/// One executed step. The human/test-readable labels live in
/// [`PlanSummary::steps`]; the step itself is just the kernel.
struct Step {
    kernel: Box<dyn Kernel>,
}

/// A model output: where it lives and its per-item shape.
#[derive(Debug, Clone)]
pub struct OutputSpec {
    /// Pre-resolved arena position of the output tensor.
    pub span: Span,
    /// Per-item output shape.
    pub shape: Vec<usize>,
}

/// Machine-checkable record of what the lowering produced; exposed through
/// [`Engine::plan_summary`](crate::engine::Engine::plan_summary) so tests
/// and benches can assert on the lowered form instead of re-deriving it.
#[derive(Debug, Clone, Default)]
pub struct PlanSummary {
    /// Model name.
    pub model: String,
    /// One label per emitted step, in execution order.
    pub steps: Vec<String>,
    /// Planned buffer count (the §3.2 reuse metric).
    pub buffers: usize,
    /// Arena elements per batch item (Σ buffer capacities).
    pub arena_item_elems: usize,
    /// Steps writing over their (dead) input buffer.
    pub in_place_steps: usize,
    /// Steps elided entirely (in-place flattens are pure reshapes).
    pub elided_steps: usize,
    /// BN layers removed by §3.5 folding.
    pub folded_bn: usize,
    /// Dense layers lowered to the batch-blocked GEMM microkernel (full
    /// `GEMM_NR`-item tiles; a per-item matvec serves the batch tail).
    pub gemm_dense: usize,
    /// Dense layers whose batch-tail matvec is the §3.3 rotated-diagonal
    /// scheme (also the batch=1 path).
    pub rotated_dense: usize,
    /// Dense layers whose batch-tail matvec is the §3.3 broadcast scheme.
    pub broadcast_dense: usize,
    /// GEMM-lowered dense layers whose batch tail re-walks the packed
    /// panels per item (rectangular / oversized layers).
    pub panel_tail_dense: usize,
    /// Conv layers lowered to the blocked direct-window scheme.
    pub direct_conv: usize,
    /// Conv layers lowered to the blocked im2col-row scheme.
    pub im2col_conv: usize,
    /// §3.4 MaxPools merged into their producing conv's store loop.
    pub fused_maxpool: usize,
    /// Weight elements copied/transformed out of the blob into kernels.
    pub weight_elems: usize,
    /// Resident packed-panel weight bytes per storage dtype (i8 scale
    /// vectors included) — the bandwidth metric the dtype refactor moves.
    pub weights_bytes: memory::WeightBytes,
    /// Conv/dense layers whose panels were post-training i8-quantized.
    pub quantized_layers: usize,
    /// Batch-independent per-arena scratch elements (im2col rows, fused-
    /// pool cells, rotated-dense windows; × intra-op tasks) — per worker,
    /// not per program.
    pub scratch_elems: usize,
    /// Widest SIMD lane width any blocked kernel was lowered at (0 when
    /// the program has no blocked conv/dense kernel).
    pub lane_width: usize,
    /// Largest intra-op task count any kernel was planned with (1 =
    /// everything runs sequentially).
    pub parallel_tasks: usize,
    /// The explainable §3.3 decision trail: every scheme candidate priced
    /// by the cost model, what was chosen per layer and why, plus the
    /// memory the plan committed to. Rendered by `compiled-nn explain`.
    pub report: cost::LoweringReport,
}

impl fmt::Display for PlanSummary {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "{}: {} steps ({} in-place, {} elided), {} buffers × {} arena elems/item, \
             {} BN folded, dense {} gemm (tails: {} rotated / {} broadcast / {} panels), \
             conv {} direct / {} im2col, {} maxpool fused, {} weight elems, \
             weights {}, {} quantized layers, \
             {} scratch elems/worker, w{} lanes × {} tasks",
            self.model,
            self.steps.len(),
            self.in_place_steps,
            self.elided_steps,
            self.buffers,
            self.arena_item_elems,
            self.folded_bn,
            self.gemm_dense,
            self.rotated_dense,
            self.broadcast_dense,
            self.panel_tail_dense,
            self.direct_conv,
            self.im2col_conv,
            self.fused_maxpool,
            self.weight_elems,
            self.weights_bytes,
            self.quantized_layers,
            self.scratch_elems,
            self.lane_width,
            self.parallel_tasks
        )?;
        for s in &self.steps {
            writeln!(f, "  {s}")?;
        }
        Ok(())
    }
}

/// Process-wide count of [`Program::lower`] calls — the counting hook the
/// serving tests/bench use to prove "lowered once per model, shared across
/// N workers" (not once per worker).
static LOWER_CALLS: AtomicU64 = AtomicU64::new(0);

/// How many times [`Program::lower`] has run in this process.
pub fn lower_count() -> u64 {
    LOWER_CALLS.load(Ordering::SeqCst)
}

/// The compiled execution program: everything `run` needs, nothing it has
/// to look up. Immutable after lowering (`run` takes `&self`; all mutable
/// state lives in the caller-owned [`Arena`]), so one `Arc<Program>` is
/// shared read-only across every worker serving the model.
pub struct Program {
    steps: Vec<Step>,
    outputs: Vec<OutputSpec>,
    input: Span,
    input_shape: Vec<usize>,
    item_elems: usize,
    /// Batch-independent scratch elements every arena carries for kernels.
    scratch_elems: usize,
    /// tensor name → span, for tests/diagnostics (never read by `run`).
    spans: BTreeMap<String, Span>,
    summary: PlanSummary,
    compile_ms: f64,
}

impl Program {
    /// Lower `spec` through fold → plan → kernel selection. This is the
    /// entire per-model compile cost of the optimized engine; everything
    /// it resolves is resolved exactly once.
    ///
    /// ```
    /// use compiled_nn::compiler::cost::DecisionReason;
    /// use compiled_nn::compiler::program::{CompileOptions, Program};
    /// use compiled_nn::model::builder::tiny_cnn;
    ///
    /// let program = Program::lower(&tiny_cnn(7), CompileOptions::default()).unwrap();
    /// let report = &program.summary().report;
    /// // default options: every conv/dense scheme came from the §3.3
    /// // cost model, and the report prices the whole net
    /// assert!(report
    ///     .decisions
    ///     .iter()
    ///     .filter(|d| !d.elided)
    ///     .all(|d| d.reason == DecisionReason::CostModel));
    /// assert!(report.predicted_total_cycles() > 0.0);
    /// ```
    pub fn lower(spec: &ModelSpec, opts: CompileOptions) -> Result<Program> {
        LOWER_CALLS.fetch_add(1, Ordering::SeqCst);
        let t0 = Instant::now();
        let bn_before = fuse::bn_count(spec);
        let folded =
            if opts.fold_bn { fuse::fold_batchnorm(spec) } else { spec.clone() };
        folded.validate()?;
        // §3.4 operation merging: single-consumer conv → MaxPool pairs run
        // as one kernel; the conv intermediate is elided from the §3.2 plan
        // (its buffer never exists, its input lives until the pool runs).
        // Fusibility is computed even with fusion off so the cost model can
        // price (and the report can show) both variants of each candidate.
        let fusible_pairs = fuse::fusible_maxpool_pairs(&folded);
        let pool_of: BTreeMap<String, String> =
            if opts.fuse_pool { fusible_pairs.clone() } else { BTreeMap::new() };
        let conv_of: BTreeMap<&str, &str> =
            pool_of.iter().map(|(c, p)| (p.as_str(), c.as_str())).collect();
        let elided: BTreeSet<String> = pool_of.keys().cloned().collect();
        let plan = memory::plan_elided(&folded, opts.reuse_memory, &elided)?;
        let shapes = folded.infer_shapes()?;

        // Arena layout: prefix-sum the planned buffer capacities so every
        // buffer becomes a fixed per-item offset.
        let mut offsets = Vec::with_capacity(plan.buffer_sizes.len());
        let mut item_elems = 0usize;
        for &s in &plan.buffer_sizes {
            offsets.push(item_elems);
            item_elems += s;
        }
        let span_of = |name: &str| -> Span {
            Span {
                start: offsets[plan.buffer_of[name]],
                elems: shapes[name].iter().product(),
            }
        };

        let mut summary = PlanSummary {
            model: spec.name.clone(),
            buffers: plan.buffer_sizes.len(),
            arena_item_elems: item_elems,
            folded_bn: bn_before - fuse::bn_count(&folded),
            report: cost::LoweringReport {
                model: spec.name.clone(),
                batch_hint: opts.batch_hint.max(1),
                ..cost::LoweringReport::default()
            },
            ..PlanSummary::default()
        };
        let mut spans = BTreeMap::new();
        spans.insert("input".to_string(), span_of("input"));
        let mut steps: Vec<Step> = Vec::with_capacity(folded.layers.len());
        // Kernel scratch planner: each kernel that needs mutable per-run
        // scratch (batch-independent) gets a span in the arena's scratch
        // buffer, so kernels stay immutable and the program shareable.
        let mut scratch_elems = 0usize;
        let mut alloc_scratch = |n: usize| -> Scratch {
            let s = Scratch { start: scratch_elems, len: n };
            scratch_elems += n;
            s
        };

        for l in &folded.layers {
            if let Some(pool) = pool_of.get(&l.name) {
                // §3.4: this conv runs inside its MaxPool consumer's store
                // loop; the fused kernel is emitted at the pool's position.
                summary
                    .steps
                    .push(format!("{}: conv2d (fused into `{pool}`)", l.name));
                continue;
            }
            if let (LayerOp::MaxPool { kh, kw, stride }, Some(&conv_name)) =
                (&l.op, conv_of.get(l.name.as_str()))
            {
                let dst = span_of(&l.name);
                spans.insert(l.name.clone(), dst);
                let conv = folded.layer(conv_name)?;
                let LayerOp::Conv2d { kh: ckh, kw: ckw, out_ch, stride: cs, padding, .. } =
                    &conv.op
                else {
                    bail!("fused pool `{}` producer `{conv_name}` is not a conv", l.name);
                };
                let src = span_of(&conv.inputs[0]);
                let cin = &shapes[&conv.inputs[0]];
                let cout = &shapes[conv_name];
                // The conv's own epilogue (activation + folded-BN affine)
                // runs per pixel *before* the max — the unfused order.
                let ep = ep_spec(&folded, conv, opts.approx, &mut summary)?;
                let (algo, bias, scheme, tasks) = lower_conv_weights(
                    &folded,
                    conv,
                    (cin[0], cin[1], cin[2]),
                    (cout[0], cout[1]),
                    ConvFusion { fusible: true, fused: true },
                    opts,
                    &mut summary,
                )?;
                summary.fused_maxpool += 1;
                // the pool layer itself emits no kernel — record that in
                // the decision trail
                summary.report.decisions.push(cost::LayerDecision {
                    layer: l.name.clone(),
                    op: l.op.name(),
                    candidates: Vec::new(),
                    chosen: "fused-into-conv",
                    lane_width: 0,
                    parallel_tasks: 0,
                    predicted_cycles: 0.0,
                    weight_dtype: simd::WeightDtype::F32,
                    weights_bytes: 0,
                    reason: cost::DecisionReason::CostModel,
                    fused_pool: true,
                    elided: true,
                    measured_cycles: None,
                    overturned: false,
                });
                let kind = format!(
                    "conv2d+maxpool[{ckh}x{ckw}x{}→{out_ch} s{cs}; pool {kh}x{kw} s{stride}]\
                     [{scheme}]{}",
                    cin[2],
                    ep.label()
                );
                summary.steps.push(format!("{}: {kind}", l.name));
                let cell_len = *out_ch;
                let row_len = conv_row_len(&algo, (*ckh, *ckw, cin[2]));
                steps.push(Step {
                    kernel: Box::new(ConvK {
                        src,
                        dst,
                        in_hwc: (cin[0], cin[1], cin[2]),
                        khw_oc: (*ckh, *ckw, *out_ch),
                        stride: *cs,
                        padding: *padding,
                        algo,
                        bias,
                        ep,
                        pool: Some((*kh, *kw, *stride)),
                        cell_len,
                        tasks,
                        scratch: alloc_scratch((cell_len + row_len) * tasks),
                    }),
                });
                continue;
            }
            let src = span_of(&l.inputs[0]);
            let dst = span_of(&l.name);
            spans.insert(l.name.clone(), dst);
            let in_shape = &shapes[&l.inputs[0]];
            let out_shape = &shapes[&l.name];
            let in_place = plan.buffer_of[&l.name] == plan.buffer_of[&l.inputs[0]];
            let hwc = |s: &[usize]| (s[0], s[1], s[2]);
            let ep = ep_spec(&folded, l, opts.approx, &mut summary)?;

            let (kernel, kind): (Box<dyn Kernel>, String) = match &l.op {
                LayerOp::Conv2d { kh, kw, out_ch, stride, padding, .. } => {
                    if in_place {
                        bail!("conv2d `{}` cannot run in place", l.name);
                    }
                    let (algo, bias, scheme, tasks) = lower_conv_weights(
                        &folded,
                        l,
                        (in_shape[0], in_shape[1], in_shape[2]),
                        (out_shape[0], out_shape[1]),
                        ConvFusion {
                            fusible: fusible_pairs.contains_key(&l.name),
                            fused: false,
                        },
                        opts,
                        &mut summary,
                    )?;
                    let kind = format!(
                        "conv2d[{kh}x{kw}x{}→{out_ch} s{stride}][{scheme}]{}",
                        in_shape[2],
                        ep.label()
                    );
                    let row_len = conv_row_len(&algo, (*kh, *kw, in_shape[2]));
                    (
                        Box::new(ConvK {
                            src,
                            dst,
                            in_hwc: hwc(in_shape),
                            khw_oc: (*kh, *kw, *out_ch),
                            stride: *stride,
                            padding: *padding,
                            algo,
                            bias,
                            ep,
                            pool: None,
                            cell_len: 0,
                            tasks,
                            scratch: alloc_scratch(row_len * tasks),
                        }),
                        kind,
                    )
                }
                LayerOp::DepthwiseConv2d { kh, kw, stride, padding, use_bias } => {
                    if in_place {
                        bail!("depthwise_conv2d `{}` cannot run in place", l.name);
                    }
                    let kernel = folded.weight(l, "kernel")?.to_vec();
                    let bias = if *use_bias {
                        Some(folded.weight(l, "bias")?.to_vec())
                    } else {
                        None
                    };
                    summary.weight_elems +=
                        kernel.len() + bias.as_ref().map_or(0, Vec::len);
                    let kind =
                        format!("dwconv[{kh}x{kw} s{stride}]{}", ep.label());
                    (
                        Box::new(DwConv2dK {
                            src,
                            dst,
                            in_hwc: hwc(in_shape),
                            khw: (*kh, *kw),
                            stride: *stride,
                            padding: *padding,
                            kernel,
                            bias,
                            ep,
                        }),
                        kind,
                    )
                }
                LayerOp::Dense { units } => {
                    if in_place {
                        bail!("dense `{}` cannot run in place", l.name);
                    }
                    let in_dim = in_shape[0];
                    let kernel = folded.weight(l, "kernel")?.to_vec();
                    let bias = folded.weight(l, "bias").ok().map(<[f32]>::to_vec);
                    // the kernel's own storage (raw kernel, padded panels,
                    // tail matvec layout) is accounted by lower_dense_algo
                    summary.weight_elems += bias.as_ref().map_or(0, Vec::len);
                    let (algo, scratch_len, label, tasks) =
                        lower_dense_algo(&l.name, kernel, in_dim, *units, opts, &mut summary);
                    let kind = format!("dense[{label} {in_dim}→{units}]{}", ep.label());
                    (
                        Box::new(DenseK {
                            src,
                            dst,
                            in_dim,
                            units: *units,
                            algo,
                            bias,
                            tasks,
                            scratch: alloc_scratch(scratch_len * tasks),
                            ep,
                        }),
                        kind,
                    )
                }
                LayerOp::BatchNorm { epsilon } => {
                    // Fold the four BN vectors into scale/shift once, with
                    // the exact expressions the naive oracle evaluates.
                    let c = *in_shape.last().expect("BN input has a channel axis");
                    let g = folded.weight(l, "gamma")?;
                    let be = folded.weight(l, "beta")?;
                    let m = folded.weight(l, "mean")?;
                    let v = folded.weight(l, "var")?;
                    let scale: Vec<f32> =
                        (0..c).map(|i| g[i] / (v[i] + epsilon).sqrt()).collect();
                    let shift: Vec<f32> =
                        (0..c).map(|i| be[i] - m[i] * scale[i]).collect();
                    summary.weight_elems += 2 * c;
                    let kind = format!("batchnorm[c={c}]");
                    if in_place {
                        (Box::new(AffineInPlaceK { dst, c, scale, shift }), kind)
                    } else {
                        (Box::new(AffineK { src, dst, c, scale, shift }), kind)
                    }
                }
                LayerOp::MaxPool { kh, kw, stride } => (
                    Box::new(MaxPoolK {
                        src,
                        dst,
                        in_hwc: hwc(in_shape),
                        khw_stride: (*kh, *kw, *stride),
                    }),
                    format!("maxpool[{kh}x{kw} s{stride}]"),
                ),
                LayerOp::AvgPool { kh, kw, stride } => (
                    Box::new(AvgPoolK {
                        src,
                        dst,
                        in_hwc: hwc(in_shape),
                        khw_stride: (*kh, *kw, *stride),
                    }),
                    format!("avgpool[{kh}x{kw} s{stride}]"),
                ),
                LayerOp::GlobalAvgPool => (
                    Box::new(GlobalAvgPoolK { src, dst, in_hwc: hwc(in_shape) }),
                    "globalavgpool".to_string(),
                ),
                LayerOp::Upsample { factor } => (
                    Box::new(UpsampleK {
                        src,
                        dst,
                        in_hwc: hwc(in_shape),
                        factor: *factor,
                    }),
                    format!("upsample[x{factor}]"),
                ),
                LayerOp::ZeroPad { pad } => (
                    Box::new(ZeroPadK { src, dst, in_hwc: hwc(in_shape), pad: *pad }),
                    format!("zeropad{pad:?}"),
                ),
                LayerOp::Activation => {
                    let c = *out_shape.last().expect("activation output non-scalar");
                    let kind = format!("activation[{}]", l.activation.name());
                    if in_place {
                        (Box::new(ActInPlaceK { dst, c, ep }), kind)
                    } else {
                        (Box::new(ActK { src, dst, c, ep }), kind)
                    }
                }
                LayerOp::Softmax => {
                    let c = *out_shape.last().expect("softmax output non-scalar");
                    let kind = if opts.approx {
                        format!("softmax[c={c} fast-exp]")
                    } else {
                        format!("softmax[c={c}]")
                    };
                    if in_place {
                        (
                            Box::new(SoftmaxInPlaceK { dst, c, approx: opts.approx }),
                            kind,
                        )
                    } else {
                        (
                            Box::new(SoftmaxK { src, dst, c, approx: opts.approx }),
                            kind,
                        )
                    }
                }
                LayerOp::Add => {
                    let other = span_of(&l.inputs[1]);
                    if in_place {
                        if plan.buffer_of[&l.inputs[1]] == plan.buffer_of[&l.name] {
                            bail!(
                                "add `{}` with both operands aliased is not plannable",
                                l.name
                            );
                        }
                        (Box::new(AddInPlaceK { dst, other }), "add".to_string())
                    } else {
                        if plan.buffer_of[&l.inputs[1]] == plan.buffer_of[&l.name] {
                            bail!(
                                "add `{}` output aliases its second operand",
                                l.name
                            );
                        }
                        (Box::new(AddK { a: src, b: other, dst }), "add".to_string())
                    }
                }
                LayerOp::Concat => {
                    if in_place {
                        bail!("concat `{}` cannot run in place", l.name);
                    }
                    let other = span_of(&l.inputs[1]);
                    if plan.buffer_of[&l.inputs[1]] == plan.buffer_of[&l.name] {
                        bail!("concat `{}` output aliases its second operand", l.name);
                    }
                    let ca = *in_shape.last().expect("concat input has channels");
                    let cb = *shapes[&l.inputs[1]]
                        .last()
                        .expect("concat input has channels");
                    (
                        Box::new(ConcatK { a: src, b: other, dst, ca, cb }),
                        format!("concat[{ca}+{cb}]"),
                    )
                }
                LayerOp::Flatten => {
                    if in_place {
                        // Pure reshape over the same buffer: no step at all.
                        summary.elided_steps += 1;
                        summary
                            .steps
                            .push(format!("{}: flatten (elided, in-place reshape)", l.name));
                        continue;
                    }
                    (Box::new(CopyK { src, dst }), "flatten[copy]".to_string())
                }
            };

            if in_place {
                summary.in_place_steps += 1;
                summary.steps.push(format!("{}: {kind} (in-place)", l.name));
            } else {
                summary.steps.push(format!("{}: {kind}", l.name));
            }
            steps.push(Step { kernel });
        }

        let outputs = folded
            .outputs
            .iter()
            .map(|o| OutputSpec { span: span_of(o), shape: shapes[o].clone() })
            .collect();

        summary.scratch_elems = scratch_elems;
        summary.parallel_tasks = summary.parallel_tasks.max(1);
        summary.report.arena_bytes = item_elems * std::mem::size_of::<f32>();
        summary.report.scratch_bytes = scratch_elems * std::mem::size_of::<f32>();
        Ok(Program {
            steps,
            outputs,
            input: span_of("input"),
            input_shape: folded.input_shape.clone(),
            item_elems,
            scratch_elems,
            spans,
            summary,
            compile_ms: t0.elapsed().as_secs_f64() * 1e3,
        })
    }

    /// Allocate a fresh arena sized for `batch` items (plus the program's
    /// batch-independent kernel scratch).
    pub fn new_arena(&self, batch: usize) -> Arena {
        Arena {
            data: vec![0.0; self.item_elems * batch],
            scratch: vec![0.0; self.scratch_elems],
            batch,
            item_elems: self.item_elems,
        }
    }

    /// Copy a `[B, ...item_shape]` input into its pre-resolved span.
    pub fn load_input(&self, arena: &mut Arena, input: &Tensor) {
        let r = self.input.range(arena.batch);
        assert_eq!(input.len(), r.len(), "input does not fill its arena span");
        arena.data[r].copy_from_slice(input.data());
    }

    /// Execute every step. The hot path: no allocation, no lookups, no
    /// per-layer dispatch beyond one virtual call per step. Takes `&self` —
    /// every mutable word (including kernel scratch) lives in the caller's
    /// arena, so any number of threads may run one program concurrently,
    /// each over its own `Arena`.
    pub fn run(&self, arena: &mut Arena) {
        debug_assert_eq!(arena.item_elems, self.item_elems, "arena from another program");
        debug_assert_eq!(arena.scratch.len(), self.scratch_elems, "arena scratch mismatch");
        let batch = arena.batch;
        let data = arena.data.as_mut_slice();
        let scratch = arena.scratch.as_mut_slice();
        for step in &self.steps {
            step.kernel.run(batch, data, scratch);
        }
    }

    /// Full inference over a caller-owned [`ArenaPool`]: validate the
    /// `[B, ...item]` shape, pick the pooled arena for `B`, load → run →
    /// read. This is the shared-serving entry point — `&self` only, so one
    /// `Arc<Program>` plus one pool per worker is a complete engine.
    pub fn infer_pooled(&self, input: &Tensor, pool: &mut ArenaPool) -> Result<Vec<Tensor>> {
        let ishape = input.shape();
        if ishape.len() < 2 || ishape[1..] != self.input_shape[..] {
            bail!("input shape {:?} does not match model {:?}", ishape, self.input_shape);
        }
        let arena = pool.get(self, ishape[0]);
        self.load_input(arena, input);
        self.run(arena);
        Ok(self.read_outputs(arena))
    }

    /// Copy the model outputs out of the arena as owned tensors (the only
    /// allocating part of inference, at the engine API boundary).
    pub fn read_outputs(&self, arena: &Arena) -> Vec<Tensor> {
        self.outputs
            .iter()
            .map(|o| {
                let mut shape = vec![arena.batch];
                shape.extend_from_slice(&o.shape);
                Tensor::from_slice(&shape, &arena.data[o.span.range(arena.batch)])
            })
            .collect()
    }

    /// Per-item HWC (or flat) input shape.
    pub fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }

    /// Arena elements per batch item (Σ planned buffer capacities).
    pub fn item_elems(&self) -> usize {
        self.item_elems
    }

    /// What was lowered, as counters + step labels.
    pub fn summary(&self) -> &PlanSummary {
        &self.summary
    }

    /// Lowering wall time in ms (the Rust-side share of "compile time").
    pub fn compile_ms(&self) -> f64 {
        self.compile_ms
    }

    /// Tensor name → arena span (tests/diagnostics only).
    pub fn spans(&self) -> &BTreeMap<String, Span> {
        &self.spans
    }

    /// Serialize everything `run` needs — spans, shapes, the full plan
    /// summary (report included), and every kernel — into an artifact
    /// encoder. [`Program::decode_body`] is the exact inverse.
    pub(crate) fn encode_body(&self, e: &mut Encoder) {
        enc_span(e, self.input);
        e.vec_usize(&self.input_shape);
        e.usize(self.item_elems);
        e.usize(self.scratch_elems);
        e.f64(self.compile_ms);
        e.usize(self.spans.len());
        for (name, s) in &self.spans {
            e.str(name);
            enc_span(e, *s);
        }
        e.usize(self.outputs.len());
        for o in &self.outputs {
            enc_span(e, o.span);
            e.vec_usize(&o.shape);
        }
        encode_summary(e, &self.summary);
        e.usize(self.steps.len());
        for s in &self.steps {
            s.kernel.encode(e);
        }
    }

    /// Rebuild a program from an artifact decoder: every kernel comes back
    /// as the same concrete struct with its weight panels borrowed
    /// zero-copy out of the mapping — no fold, no plan, no packing, no
    /// quantization. `compile_ms` initially carries the original lowering
    /// time; the artifact loader restamps it with the load wall time.
    pub(crate) fn decode_body(d: &mut Decoder) -> Result<Program, ArtifactError> {
        let input = dec_span(d)?;
        let input_shape = d.vec_usize()?;
        let item_elems = d.usize()?;
        let scratch_elems = d.usize()?;
        let compile_ms = d.f64()?;
        let n_spans = d.usize()?;
        let mut spans = BTreeMap::new();
        for _ in 0..n_spans {
            let name = d.string()?;
            spans.insert(name, dec_span(d)?);
        }
        let n_outputs = d.usize()?;
        let mut outputs = Vec::with_capacity(n_outputs.min(64));
        for _ in 0..n_outputs {
            outputs.push(OutputSpec { span: dec_span(d)?, shape: d.vec_usize()? });
        }
        let summary = decode_summary(d)?;
        let n_steps = d.usize()?;
        let mut steps = Vec::with_capacity(n_steps.min(1024));
        for _ in 0..n_steps {
            steps.push(Step { kernel: decode_kernel(d)? });
        }
        Ok(Program {
            steps,
            outputs,
            input,
            input_shape,
            item_elems,
            scratch_elems,
            spans,
            summary,
            compile_ms,
        })
    }

    /// Restamp the compile-time figure (the artifact loader records the
    /// load wall time here, so `compile_ms` always answers "what did it
    /// cost to make this program runnable in this process").
    pub(crate) fn set_compile_ms(&mut self, ms: f64) {
        self.compile_ms = ms;
    }
}

// ------------------------------------------------------- artifact codecs

fn enc_span(e: &mut Encoder, s: Span) {
    e.usize(s.start);
    e.usize(s.elems);
}

fn dec_span(d: &mut Decoder) -> Result<Span, ArtifactError> {
    Ok(Span { start: d.usize()?, elems: d.usize()? })
}

fn enc_scratch(e: &mut Encoder, s: Scratch) {
    e.usize(s.start);
    e.usize(s.len);
}

fn dec_scratch(d: &mut Decoder) -> Result<Scratch, ArtifactError> {
    Ok(Scratch { start: d.usize()?, len: d.usize()? })
}

fn enc_hwc(e: &mut Encoder, (h, w, c): (usize, usize, usize)) {
    e.usize(h);
    e.usize(w);
    e.usize(c);
}

fn dec_hwc(d: &mut Decoder) -> Result<(usize, usize, usize), ArtifactError> {
    Ok((d.usize()?, d.usize()?, d.usize()?))
}

fn act_code(a: Activation) -> u8 {
    match a {
        Activation::Linear => 0,
        Activation::Relu => 1,
        Activation::Relu6 => 2,
        Activation::LeakyRelu => 3,
        Activation::Sigmoid => 4,
        Activation::Tanh => 5,
    }
}

fn act_from(code: u8) -> Result<Activation, ArtifactError> {
    Ok(match code {
        0 => Activation::Linear,
        1 => Activation::Relu,
        2 => Activation::Relu6,
        3 => Activation::LeakyRelu,
        4 => Activation::Sigmoid,
        5 => Activation::Tanh,
        c => return Err(corrupt(format!("unknown activation code {c}"))),
    })
}

fn pad_code(p: Padding) -> u8 {
    match p {
        Padding::Same => 0,
        Padding::Valid => 1,
    }
}

fn pad_from(code: u8) -> Result<Padding, ArtifactError> {
    Ok(match code {
        0 => Padding::Same,
        1 => Padding::Valid,
        c => return Err(corrupt(format!("unknown padding code {c}"))),
    })
}

fn dtype_code(t: simd::WeightDtype) -> u8 {
    match t {
        simd::WeightDtype::F32 => 0,
        simd::WeightDtype::Bf16 => 1,
        simd::WeightDtype::I8 => 2,
    }
}

fn dtype_from(code: u8) -> Result<simd::WeightDtype, ArtifactError> {
    Ok(match code {
        0 => simd::WeightDtype::F32,
        1 => simd::WeightDtype::Bf16,
        2 => simd::WeightDtype::I8,
        c => return Err(corrupt(format!("unknown weight dtype code {c}"))),
    })
}

fn enc_ep(e: &mut Encoder, ep: &EpSpec) {
    e.u8(act_code(ep.act));
    e.bool(ep.approx);
    match &ep.post {
        None => e.bool(false),
        Some((s, h)) => {
            e.bool(true);
            e.vec_f32(s);
            e.vec_f32(h);
        }
    }
}

fn dec_ep(d: &mut Decoder) -> Result<EpSpec, ArtifactError> {
    let act = act_from(d.u8()?)?;
    let approx = d.bool()?;
    let post = if d.bool()? { Some((d.vec_f32()?, d.vec_f32()?)) } else { None };
    Ok(EpSpec { act, approx, post })
}

fn enc_opt_vec(e: &mut Encoder, v: &Option<Vec<f32>>) {
    e.opt_vec_f32(v.as_deref());
}

/// Decode a label through [`cost::intern_label`] back to the `&'static
/// str` the report types carry.
fn dec_label(d: &mut Decoder) -> Result<&'static str, ArtifactError> {
    let s = d.string()?;
    cost::intern_label(&s).ok_or_else(|| corrupt(format!("unknown label `{s}`")))
}

fn reason_code(r: cost::DecisionReason) -> u8 {
    match r {
        cost::DecisionReason::CostModel => 0,
        cost::DecisionReason::Forced => 1,
        cost::DecisionReason::Fallback => 2,
        cost::DecisionReason::Measured => 3,
    }
}

fn reason_from(code: u8) -> Result<cost::DecisionReason, ArtifactError> {
    Ok(match code {
        0 => cost::DecisionReason::CostModel,
        1 => cost::DecisionReason::Forced,
        2 => cost::DecisionReason::Fallback,
        3 => cost::DecisionReason::Measured,
        c => return Err(corrupt(format!("unknown decision reason code {c}"))),
    })
}

fn encode_summary(e: &mut Encoder, s: &PlanSummary) {
    e.str(&s.model);
    e.usize(s.steps.len());
    for st in &s.steps {
        e.str(st);
    }
    for v in [
        s.buffers,
        s.arena_item_elems,
        s.in_place_steps,
        s.elided_steps,
        s.folded_bn,
        s.gemm_dense,
        s.rotated_dense,
        s.broadcast_dense,
        s.panel_tail_dense,
        s.direct_conv,
        s.im2col_conv,
        s.fused_maxpool,
        s.weight_elems,
        s.weights_bytes.f32_bytes,
        s.weights_bytes.bf16_bytes,
        s.weights_bytes.i8_bytes,
        s.quantized_layers,
        s.scratch_elems,
        s.lane_width,
        s.parallel_tasks,
    ] {
        e.usize(v);
    }
    e.str(&s.report.model);
    e.usize(s.report.batch_hint);
    e.usize(s.report.arena_bytes);
    e.usize(s.report.scratch_bytes);
    e.usize(s.report.decisions.len());
    for dn in &s.report.decisions {
        e.str(&dn.layer);
        e.str(dn.op);
        e.usize(dn.candidates.len());
        for c in &dn.candidates {
            e.str(c.scheme);
            e.usize(c.lanes);
            e.f64(c.cycles);
            e.usize(c.weight_bytes);
            e.u8(dtype_code(c.dtype));
            e.bool(c.fused_pool);
        }
        e.str(dn.chosen);
        e.usize(dn.lane_width);
        e.usize(dn.parallel_tasks);
        e.f64(dn.predicted_cycles);
        e.u8(dtype_code(dn.weight_dtype));
        e.usize(dn.weights_bytes);
        e.u8(reason_code(dn.reason));
        e.bool(dn.fused_pool);
        e.bool(dn.elided);
        match dn.measured_cycles {
            None => e.bool(false),
            Some(v) => {
                e.bool(true);
                e.f64(v);
            }
        }
        e.bool(dn.overturned);
    }
}

fn decode_summary(d: &mut Decoder) -> Result<PlanSummary, ArtifactError> {
    let model = d.string()?;
    let n_steps = d.usize()?;
    let mut steps = Vec::with_capacity(n_steps.min(1024));
    for _ in 0..n_steps {
        steps.push(d.string()?);
    }
    let mut counters = [0usize; 20];
    for c in &mut counters {
        *c = d.usize()?;
    }
    let report_model = d.string()?;
    let batch_hint = d.usize()?;
    let arena_bytes = d.usize()?;
    let scratch_bytes = d.usize()?;
    let n_dec = d.usize()?;
    let mut decisions = Vec::with_capacity(n_dec.min(1024));
    for _ in 0..n_dec {
        let layer = d.string()?;
        let op = dec_label(d)?;
        let n_cand = d.usize()?;
        let mut candidates = Vec::with_capacity(n_cand.min(64));
        for _ in 0..n_cand {
            candidates.push(cost::CandidateCost {
                scheme: dec_label(d)?,
                lanes: d.usize()?,
                cycles: d.f64()?,
                weight_bytes: d.usize()?,
                dtype: dtype_from(d.u8()?)?,
                fused_pool: d.bool()?,
            });
        }
        decisions.push(cost::LayerDecision {
            layer,
            op,
            candidates,
            chosen: dec_label(d)?,
            lane_width: d.usize()?,
            parallel_tasks: d.usize()?,
            predicted_cycles: d.f64()?,
            weight_dtype: dtype_from(d.u8()?)?,
            weights_bytes: d.usize()?,
            reason: reason_from(d.u8()?)?,
            fused_pool: d.bool()?,
            elided: d.bool()?,
            measured_cycles: if d.bool()? { Some(d.f64()?) } else { None },
            overturned: d.bool()?,
        });
    }
    Ok(PlanSummary {
        model,
        steps,
        buffers: counters[0],
        arena_item_elems: counters[1],
        in_place_steps: counters[2],
        elided_steps: counters[3],
        folded_bn: counters[4],
        gemm_dense: counters[5],
        rotated_dense: counters[6],
        broadcast_dense: counters[7],
        panel_tail_dense: counters[8],
        direct_conv: counters[9],
        im2col_conv: counters[10],
        fused_maxpool: counters[11],
        weight_elems: counters[12],
        weights_bytes: memory::WeightBytes {
            f32_bytes: counters[13],
            bf16_bytes: counters[14],
            i8_bytes: counters[15],
        },
        quantized_layers: counters[16],
        scratch_elems: counters[17],
        lane_width: counters[18],
        parallel_tasks: counters[19],
        report: cost::LoweringReport {
            model: report_model,
            batch_hint,
            decisions,
            arena_bytes,
            scratch_bytes,
        },
    })
}

/// Kernel type tags for the artifact format; [`Kernel::encode`] writes
/// them, this match rebuilds the concrete struct. Order is part of the
/// format — changing it means bumping the artifact version.
fn decode_kernel(d: &mut Decoder) -> Result<Box<dyn Kernel>, ArtifactError> {
    Ok(match d.u8()? {
        1 => Box::new(ConvK {
            src: dec_span(d)?,
            dst: dec_span(d)?,
            in_hwc: dec_hwc(d)?,
            khw_oc: dec_hwc(d)?,
            stride: d.usize()?,
            padding: pad_from(d.u8()?)?,
            algo: k::ConvAlgo::decode(d)?,
            bias: d.opt_vec_f32()?,
            ep: dec_ep(d)?,
            pool: if d.bool()? { Some(dec_hwc(d)?) } else { None },
            cell_len: d.usize()?,
            tasks: d.usize()?,
            scratch: dec_scratch(d)?,
        }),
        2 => Box::new(DwConv2dK {
            src: dec_span(d)?,
            dst: dec_span(d)?,
            in_hwc: dec_hwc(d)?,
            khw: (d.usize()?, d.usize()?),
            stride: d.usize()?,
            padding: pad_from(d.u8()?)?,
            kernel: d.vec_f32()?,
            bias: d.opt_vec_f32()?,
            ep: dec_ep(d)?,
        }),
        3 => Box::new(DenseK {
            src: dec_span(d)?,
            dst: dec_span(d)?,
            in_dim: d.usize()?,
            units: d.usize()?,
            algo: k::DenseAlgo::decode(d)?,
            bias: d.opt_vec_f32()?,
            tasks: d.usize()?,
            scratch: dec_scratch(d)?,
            ep: dec_ep(d)?,
        }),
        4 => Box::new(AffineK {
            src: dec_span(d)?,
            dst: dec_span(d)?,
            c: d.usize()?,
            scale: d.vec_f32()?,
            shift: d.vec_f32()?,
        }),
        5 => Box::new(AffineInPlaceK {
            dst: dec_span(d)?,
            c: d.usize()?,
            scale: d.vec_f32()?,
            shift: d.vec_f32()?,
        }),
        6 => Box::new(MaxPoolK {
            src: dec_span(d)?,
            dst: dec_span(d)?,
            in_hwc: dec_hwc(d)?,
            khw_stride: dec_hwc(d)?,
        }),
        7 => Box::new(AvgPoolK {
            src: dec_span(d)?,
            dst: dec_span(d)?,
            in_hwc: dec_hwc(d)?,
            khw_stride: dec_hwc(d)?,
        }),
        8 => Box::new(GlobalAvgPoolK {
            src: dec_span(d)?,
            dst: dec_span(d)?,
            in_hwc: dec_hwc(d)?,
        }),
        9 => Box::new(UpsampleK {
            src: dec_span(d)?,
            dst: dec_span(d)?,
            in_hwc: dec_hwc(d)?,
            factor: d.usize()?,
        }),
        10 => Box::new(ZeroPadK {
            src: dec_span(d)?,
            dst: dec_span(d)?,
            in_hwc: dec_hwc(d)?,
            pad: [d.usize()?, d.usize()?, d.usize()?, d.usize()?],
        }),
        11 => Box::new(ActK {
            src: dec_span(d)?,
            dst: dec_span(d)?,
            c: d.usize()?,
            ep: dec_ep(d)?,
        }),
        12 => Box::new(ActInPlaceK { dst: dec_span(d)?, c: d.usize()?, ep: dec_ep(d)? }),
        13 => Box::new(SoftmaxK {
            src: dec_span(d)?,
            dst: dec_span(d)?,
            c: d.usize()?,
            approx: d.bool()?,
        }),
        14 => Box::new(SoftmaxInPlaceK {
            dst: dec_span(d)?,
            c: d.usize()?,
            approx: d.bool()?,
        }),
        15 => Box::new(AddK { a: dec_span(d)?, b: dec_span(d)?, dst: dec_span(d)? }),
        16 => Box::new(AddInPlaceK { dst: dec_span(d)?, other: dec_span(d)? }),
        17 => Box::new(ConcatK {
            a: dec_span(d)?,
            b: dec_span(d)?,
            dst: dec_span(d)?,
            ca: d.usize()?,
            cb: d.usize()?,
        }),
        18 => Box::new(CopyK { src: dec_span(d)?, dst: dec_span(d)? }),
        t => return Err(corrupt(format!("unknown kernel tag {t}"))),
    })
}

/// A layer's fused store epilogue (activation + §3.5 post-affine), with
/// the post-affine weight accounting. Shared by every lowering arm and the
/// fused conv+maxpool branch.
fn ep_spec(
    folded: &ModelSpec,
    l: &Layer,
    approx: bool,
    summary: &mut PlanSummary,
) -> Result<EpSpec> {
    let post = if l.post_scale {
        Some((
            folded.weight(l, "post_scale_w")?.to_vec(),
            folded.weight(l, "post_shift_w")?.to_vec(),
        ))
    } else {
        None
    };
    if let Some((s, h)) = &post {
        summary.weight_elems += s.len() + h.len();
    }
    Ok(EpSpec { act: l.activation, approx, post })
}

/// How a conv layer relates to a downstream max-pool at lowering time:
/// `fusible` = a single-consumer pool pair exists in the graph (the cost
/// model prices fused variants), `fused` = the §3.4 merge actually happens
/// (requires `fusible` and `CompileOptions::fuse_pool`).
#[derive(Clone, Copy)]
struct ConvFusion {
    fusible: bool,
    fused: bool,
}

/// Fetch a conv layer's kernel + bias out of the blob and lower them to
/// the selected §3.3 algo (weight accounting included). Shared by the
/// stand-alone Conv2d arm and the §3.4 fused conv+maxpool branch so the
/// two can never drift apart. `Auto` resolves by pricing every candidate
/// through [`cost::conv_candidates`] and taking the argmin among those
/// matching the fusion decision; the whole trail lands in the summary's
/// report.
fn lower_conv_weights(
    folded: &ModelSpec,
    conv: &Layer,
    (in_h, in_w, in_ch): (usize, usize, usize),
    (out_h, out_w): (usize, usize),
    fusion: ConvFusion,
    opts: CompileOptions,
    summary: &mut PlanSummary,
) -> Result<(k::ConvAlgo, Option<Vec<f32>>, &'static str, usize)> {
    let LayerOp::Conv2d { kh, kw, out_ch, use_bias, stride, padding, .. } = &conv.op
    else {
        bail!("`{}` is not a conv2d", conv.name);
    };
    let kernel = folded.weight(conv, "kernel")?.to_vec();
    let bias =
        if *use_bias { Some(folded.weight(conv, "bias")?.to_vec()) } else { None };
    summary.weight_elems += kernel.len() + bias.as_ref().map_or(0, Vec::len);
    let dims = cost::ConvDims {
        kh: *kh,
        kw: *kw,
        in_ch,
        out_ch: *out_ch,
        out_h,
        out_w,
        same_padding: *padding == Padding::Same,
    };
    let max_lanes = opts.max_lanes();
    // Narrow storage is a request, not a mandate: nonfinite weights pin
    // f32 panels (i8 quantization would silently zero a NaN and break the
    // oracle's NaN propagation), and the cost model only offers narrow
    // storage on the blocked schemes.
    let req_dtype = effective_weight_dtype(opts.weight_dtype, &kernel);
    let candidates = cost::conv_candidates_dt(&dims, fusion.fusible, max_lanes, req_dtype);
    let (mut resolved, mut lanes, mut reason) = match opts.conv {
        ConvScheme::Auto => match cost::pick(&candidates, fusion.fused) {
            Some(best) => (
                match best.scheme {
                    "direct" => ConvScheme::Direct,
                    "generic" => ConvScheme::Generic,
                    _ => ConvScheme::Im2col,
                },
                best.lanes,
                cost::DecisionReason::CostModel,
            ),
            // the model declined to price the layer: geometry rule first
            // (1×1/VALID → direct, padded multi-tap → im2col), generic only
            // if even that is ruled out — see `ConvScheme::Auto`
            None => (
                if (*kh == 1 && *kw == 1) || *padding == Padding::Valid {
                    ConvScheme::Direct
                } else {
                    ConvScheme::Im2col
                },
                fallback_lanes(max_lanes),
                cost::DecisionReason::Fallback,
            ),
        },
        forced => {
            let label = match forced {
                ConvScheme::Direct => "direct",
                ConvScheme::Generic => "generic",
                _ => "im2col",
            };
            (
                forced,
                forced_lanes(&candidates, label, fusion.fused, max_lanes),
                cost::DecisionReason::Forced,
            )
        }
    };
    // Measured tuning: only second-guess the cost model where it actually
    // decided (Auto + priced); forced schemes and geometry fallbacks stay.
    let mut measured_cycles = None;
    let mut overturned = false;
    if let TuneMode::Measured { reps } = opts.tune {
        if opts.conv == ConvScheme::Auto && reason == cost::DecisionReason::CostModel {
            if let Some(m) = measure_conv_candidates(
                &kernel,
                &dims,
                (in_h, in_w),
                *stride,
                *padding,
                &candidates,
                fusion.fused,
                req_dtype,
                reps,
            ) {
                overturned = m.scheme != resolved || m.lanes != lanes;
                resolved = m.scheme;
                lanes = m.lanes;
                measured_cycles = Some(m.ns);
                reason = cost::DecisionReason::Measured;
            }
        }
    }
    let (algo, scheme) = lower_conv_algo(
        resolved,
        kernel,
        (*kh, *kw, in_ch, *out_ch),
        lanes,
        req_dtype,
        summary,
    );
    let predicted = candidates
        .iter()
        .find(|c| {
            c.scheme == scheme && c.lanes == lanes && c.fused_pool == fusion.fused
        })
        .map_or(0.0, |c| c.cycles);
    let tasks =
        cost::parallel_tasks(predicted, opts.batch_hint.max(1), opts.intra_threads);
    if !matches!(algo, k::ConvAlgo::Generic { .. }) {
        summary.lane_width = summary.lane_width.max(lanes);
    }
    summary.parallel_tasks = summary.parallel_tasks.max(tasks);
    let (emitted_dtype, weights_bytes) = match &algo {
        k::ConvAlgo::Direct { panels, .. } | k::ConvAlgo::Im2col { panels, .. } => {
            (panels.dtype(), panels.weight_bytes())
        }
        k::ConvAlgo::Generic { kernel } => {
            (simd::WeightDtype::F32, kernel.len() * std::mem::size_of::<f32>())
        }
    };
    summary.weights_bytes.add(emitted_dtype, weights_bytes);
    if emitted_dtype == simd::WeightDtype::I8 {
        summary.quantized_layers += 1;
    }
    summary.report.decisions.push(cost::LayerDecision {
        layer: conv.name.clone(),
        op: conv.op.name(),
        candidates,
        chosen: scheme,
        lane_width: lanes,
        parallel_tasks: tasks,
        predicted_cycles: predicted,
        weight_dtype: emitted_dtype,
        weights_bytes,
        reason,
        fused_pool: fusion.fused,
        elided: false,
        measured_cycles,
        overturned,
    });
    Ok((algo, bias, scheme, tasks))
}

/// How many top predicted candidates measured tuning times per layer.
const MEASURE_TOP_K: usize = 3;

/// The empirical winner of a candidate timing run.
struct MeasuredPick<S> {
    scheme: S,
    lanes: usize,
    /// Best (minimum) wall nanoseconds over the timed repetitions.
    ns: f64,
}

/// Time a kernel: one untimed warmup (page in panels, settle dispatch),
/// then `reps` runs keeping the minimum wall time — the standard
/// least-noise estimator for short kernels.
fn time_kernel(kernel: &dyn Kernel, data: &mut [f32], scratch: &mut [f32], reps: u32) -> f64 {
    kernel.run(1, data, scratch);
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t = Instant::now();
        kernel.run(1, data, scratch);
        best = best.min(t.elapsed().as_secs_f64() * 1e9);
    }
    best
}

/// Build and time each of the top-K predicted conv candidates as a real
/// `ConvK` over synthetic batch-1 data, returning the empirical argmin.
/// Candidates are timed without the fused-pool epilogue (its store-loop
/// max cost is scheme-independent, and the pool geometry lives at the
/// fused call site); `None` when fewer than two distinct candidates exist
/// — there is nothing to overturn.
#[allow(clippy::too_many_arguments)]
fn measure_conv_candidates(
    kernel: &[f32],
    dims: &cost::ConvDims,
    (in_h, in_w): (usize, usize),
    stride: usize,
    padding: Padding,
    candidates: &[cost::CandidateCost],
    fused: bool,
    dtype: simd::WeightDtype,
    reps: u32,
) -> Option<MeasuredPick<ConvScheme>> {
    let mut top: Vec<&cost::CandidateCost> =
        candidates.iter().filter(|c| c.fused_pool == fused).collect();
    top.sort_by(|a, b| a.cycles.total_cmp(&b.cycles));
    top.dedup_by(|a, b| a.scheme == b.scheme && a.lanes == b.lanes);
    top.truncate(MEASURE_TOP_K);
    if top.len() < 2 {
        return None;
    }
    let in_elems = in_h * in_w * dims.in_ch;
    let out_elems = dims.out_h * dims.out_w * dims.out_ch;
    let mut rng = crate::util::rng::SplitMix64::new(0x7E57_AB1E);
    let mut data = rng.uniform_vec(in_elems);
    data.resize(in_elems + out_elems, 0.0);
    let mut best: Option<MeasuredPick<ConvScheme>> = None;
    for c in top {
        let scheme = match c.scheme {
            "direct" => ConvScheme::Direct,
            "generic" => ConvScheme::Generic,
            _ => ConvScheme::Im2col,
        };
        // throwaway summary: candidate builds must not pollute the real
        // lowering counters — only the winner is rebuilt for keeps
        let mut scratch_summary = PlanSummary::default();
        let (algo, _) = lower_conv_algo(
            scheme,
            kernel.to_vec(),
            (dims.kh, dims.kw, dims.in_ch, dims.out_ch),
            c.lanes,
            dtype,
            &mut scratch_summary,
        );
        let row_len = conv_row_len(&algo, (dims.kh, dims.kw, dims.in_ch));
        let probe = ConvK {
            src: Span { start: 0, elems: in_elems },
            dst: Span { start: in_elems, elems: out_elems },
            in_hwc: (in_h, in_w, dims.in_ch),
            khw_oc: (dims.kh, dims.kw, dims.out_ch),
            stride,
            padding,
            algo,
            bias: None,
            ep: EpSpec { act: Activation::Linear, approx: false, post: None },
            pool: None,
            cell_len: 0,
            tasks: 1,
            scratch: Scratch { start: 0, len: row_len },
        };
        let mut scratch = vec![0.0f32; row_len];
        let ns = time_kernel(&probe, &mut data, &mut scratch, reps);
        let better = match &best {
            None => true,
            Some(b) => ns < b.ns,
        };
        if better {
            best = Some(MeasuredPick { scheme, lanes: c.lanes, ns });
        }
    }
    best
}

/// The dense counterpart of [`measure_conv_candidates`]: rebuild each
/// top-K candidate's `DenseAlgo` (panels, tails and all) and time a real
/// `DenseK` over synthetic data at the pricing batch. Returns the
/// empirical argmin as a scheme label.
fn measure_dense_candidates(
    kernel: &[f32],
    in_dim: usize,
    units: usize,
    candidates: &[cost::CandidateCost],
    dtype: simd::WeightDtype,
    batch: usize,
    reps: u32,
) -> Option<MeasuredPick<&'static str>> {
    let mut top: Vec<&cost::CandidateCost> = candidates.iter().collect();
    top.sort_by(|a, b| a.cycles.total_cmp(&b.cycles));
    top.dedup_by(|a, b| a.scheme == b.scheme && a.lanes == b.lanes);
    top.truncate(MEASURE_TOP_K);
    if top.len() < 2 {
        return None;
    }
    let mut rng = crate::util::rng::SplitMix64::new(0x7E57_AB1E);
    let mut data = rng.uniform_vec(in_dim * batch);
    data.resize((in_dim + units) * batch, 0.0);
    let mut best: Option<MeasuredPick<&'static str>> = None;
    for c in top {
        // the estimator only lists legal candidates, so the square-only
        // tails can transpose unconditionally (same invariant Auto uses)
        let (algo, scratch_len) = match c.scheme {
            "generic" => (k::DenseAlgo::Generic { kernel: kernel.to_vec() }, 0),
            "gemm+rotated" => (
                k::DenseAlgo::Gemm {
                    panels: k::WeightPanels::pack_dense(
                        kernel,
                        in_dim,
                        units,
                        c.lanes,
                        simd::WeightDtype::F32,
                    ),
                    lanes: c.lanes,
                    tail: k::DenseTail::Rotated {
                        diag: simd::rotate_diagonals(&transpose(kernel, in_dim), in_dim),
                    },
                },
                2 * in_dim,
            ),
            "gemm+broadcast" => (
                k::DenseAlgo::Gemm {
                    panels: k::WeightPanels::pack_dense(
                        kernel,
                        in_dim,
                        units,
                        c.lanes,
                        simd::WeightDtype::F32,
                    ),
                    lanes: c.lanes,
                    tail: k::DenseTail::Broadcast { w: transpose(kernel, in_dim) },
                },
                0,
            ),
            _ => (
                k::DenseAlgo::Gemm {
                    panels: k::WeightPanels::pack_dense(kernel, in_dim, units, c.lanes, dtype),
                    lanes: c.lanes,
                    tail: k::DenseTail::Panels,
                },
                0,
            ),
        };
        let probe = DenseK {
            src: Span { start: 0, elems: in_dim },
            dst: Span { start: in_dim, elems: units },
            in_dim,
            units,
            algo,
            bias: None,
            tasks: 1,
            scratch: Scratch { start: 0, len: scratch_len },
            ep: EpSpec { act: Activation::Linear, approx: false, post: None },
        };
        let mut scratch = vec![0.0f32; scratch_len];
        let probe_ref: &dyn Kernel = &probe;
        probe_ref.run(batch, &mut data, &mut scratch);
        let mut ns = f64::INFINITY;
        for _ in 0..reps.max(1) {
            let t = Instant::now();
            probe_ref.run(batch, &mut data, &mut scratch);
            ns = ns.min(t.elapsed().as_secs_f64() * 1e9);
        }
        let better = match &best {
            None => true,
            Some(b) => ns < b.ns,
        };
        if better {
            best = Some(MeasuredPick { scheme: c.scheme, lanes: c.lanes, ns });
        }
    }
    best
}

/// Width lowering falls back to when the cost model declined to price a
/// layer (zero MAC work): the narrowest blocked width under the ceiling.
fn fallback_lanes(max_lanes: usize) -> usize {
    if max_lanes < 4 {
        max_lanes.max(1)
    } else {
        4
    }
}

/// Cheapest priced width for a *forced* scheme — the width axis stays
/// cost-model-driven even when the scheme does not. Ties keep the first
/// (narrowest) candidate, matching [`cost::pick`]; unpriced layers fall
/// back like [`fallback_lanes`] (scalar schemes always run at 1).
fn forced_lanes(
    candidates: &[cost::CandidateCost],
    scheme: &str,
    fused: bool,
    max_lanes: usize,
) -> usize {
    candidates
        .iter()
        .filter(|c| c.scheme == scheme && c.fused_pool == fused)
        .fold(None::<&cost::CandidateCost>, |best, c| match best {
            Some(b) if b.cycles <= c.cycles => Some(b),
            _ => Some(c),
        })
        .map_or_else(
            || if scheme == "generic" { 1 } else { fallback_lanes(max_lanes) },
            |c| c.lanes,
        )
}

/// The storage dtype a layer's weights can actually be lowered at: the
/// requested dtype, demoted to `F32` when the kernel holds nonfinite
/// values — i8 quantization would silently map NaN/Inf taps to 0 (Rust's
/// saturating `as` cast) and the per-channel max-abs scale itself goes
/// nonfinite, so narrow storage would break the oracle's NaN-propagation
/// semantics (`dense_nonfinite_weights_match_naive`).
fn effective_weight_dtype(req: simd::WeightDtype, kernel: &[f32]) -> simd::WeightDtype {
    if req != simd::WeightDtype::F32 && !kernel.iter().all(|w| w.is_finite()) {
        simd::WeightDtype::F32
    } else {
        req
    }
}

/// Pack a conv kernel for an already-resolved §3.3 scheme; returns the
/// algo plus its summary label. Scheme resolution (cost model, fallbacks)
/// happens in [`lower_conv_weights`] — by this point `Auto` has been
/// replaced by a concrete scheme. The blocked schemes store their panels
/// at `dtype`; the generic scheme always keeps the raw f32 kernel.
fn lower_conv_algo(
    scheme: ConvScheme,
    kernel: Vec<f32>,
    (kh, kw, c, oc): (usize, usize, usize, usize),
    lanes: usize,
    dtype: simd::WeightDtype,
    summary: &mut PlanSummary,
) -> (k::ConvAlgo, &'static str) {
    let taps = kh * kw * c;
    debug_assert_eq!(kernel.len(), taps * oc);
    debug_assert_ne!(scheme, ConvScheme::Auto, "Auto resolved by the caller");
    match scheme {
        ConvScheme::Direct => {
            summary.direct_conv += 1;
            (
                k::ConvAlgo::Direct {
                    panels: k::WeightPanels::pack_conv(&kernel, taps, oc, lanes, dtype),
                    lanes,
                },
                "direct",
            )
        }
        ConvScheme::Im2col => {
            summary.im2col_conv += 1;
            (
                k::ConvAlgo::Im2col {
                    panels: k::WeightPanels::pack_conv(&kernel, taps, oc, lanes, dtype),
                    lanes,
                },
                "im2col",
            )
        }
        _ => (k::ConvAlgo::Generic { kernel }, "generic"),
    }
}

/// Per-run scratch the lowered conv algo needs per worker: the im2col
/// scheme gathers `GEMM_NR` pixels' windows (one per batch item of a
/// register tile) into `kh*kw*c` rows; the other schemes read the arena
/// directly.
fn conv_row_len(algo: &k::ConvAlgo, (kh, kw, c): (usize, usize, usize)) -> usize {
    match algo {
        k::ConvAlgo::Im2col { .. } => simd::GEMM_NR * kh * kw * c,
        _ => 0,
    }
}

/// Pick the dense lowering for a layer's statically known dims and pack
/// the weights accordingly; returns the algo, its per-worker scratch need
/// (the rotated tail's doubled-x window) and the summary label.
/// `weight_elems` counts exactly what the lowered kernel retains (raw
/// kernel, zero-padded panels, plus the square tails' n² matvec layout),
/// so the summary reflects the real resident weight footprint.
///
/// `Auto` resolves by pricing every legal candidate through
/// [`cost::dense_candidates`] under `opts.batch_hint` and taking the
/// argmin (falling back to the GEMM panels if the model declines to price
/// the layer); forced schemes keep their legality fallbacks. `Generic`
/// stays the scalar bit-exact reference. Every other pick lowers to the
/// batch-blocked GEMM microkernel ([`simd::pack_dense_panels`] panels
/// packed once here, landing in the kernel's weights — never per-call
/// scratch) with the §3.3 matvec kept as the per-item batch-tail path:
/// square 4-lane-divisible layers can keep their rotated/broadcast matvec
/// (rotated additionally needs the bounded stack window), everything else
/// re-walks the packed panels one item at a time. The decision trail lands
/// in the summary's report.
fn lower_dense_algo(
    layer: &str,
    kernel: Vec<f32>,
    in_dim: usize,
    units: usize,
    opts: CompileOptions,
    summary: &mut PlanSummary,
) -> (k::DenseAlgo, usize, &'static str, usize) {
    #[derive(Clone, Copy)]
    enum Pick {
        Rotated,
        Broadcast,
        Panels,
        Generic,
    }
    let square = in_dim == units && units % 4 == 0;
    let rotatable = square && units <= simd::ROTATED_STACK_MAX;
    let max_lanes = opts.max_lanes();
    // as for conv: narrow storage only where the blocked GEMM consumes it,
    // and never over nonfinite weights
    let req_dtype = effective_weight_dtype(opts.weight_dtype, &kernel);
    let candidates = cost::dense_candidates_dt(
        &cost::DenseDims { in_dim, units },
        opts.batch_hint.max(1),
        simd::ROTATED_STACK_MAX,
        max_lanes,
        req_dtype,
    );
    let (mut pick, mut lanes, mut reason) = match opts.dense {
        DenseScheme::Generic => (Pick::Generic, 1, cost::DecisionReason::Forced),
        DenseScheme::Rotated => {
            let (p, label) = if rotatable {
                (Pick::Rotated, "gemm+rotated")
            } else {
                (Pick::Panels, "gemm+panels")
            };
            (
                p,
                forced_lanes(&candidates, label, false, max_lanes),
                cost::DecisionReason::Forced,
            )
        }
        DenseScheme::Broadcast => {
            let (p, label) = if square {
                (Pick::Broadcast, "gemm+broadcast")
            } else {
                (Pick::Panels, "gemm+panels")
            };
            (
                p,
                forced_lanes(&candidates, label, false, max_lanes),
                cost::DecisionReason::Forced,
            )
        }
        DenseScheme::Auto => match cost::pick(&candidates, false) {
            // the estimator only lists legal candidates, so the argmin
            // label maps straight onto a lowering
            Some(best) => (
                match best.scheme {
                    "gemm+rotated" => Pick::Rotated,
                    "gemm+broadcast" => Pick::Broadcast,
                    "generic" => Pick::Generic,
                    _ => Pick::Panels,
                },
                best.lanes,
                cost::DecisionReason::CostModel,
            ),
            // zero-MAC layer: the panels GEMM handles any shape
            None => (Pick::Panels, fallback_lanes(max_lanes), cost::DecisionReason::Fallback),
        },
    };
    // Measured tuning: only second-guess the cost model where it actually
    // decided (Auto + CostModel), never a forced scheme or a fallback.
    let mut measured_cycles = None;
    let mut overturned = false;
    if let TuneMode::Measured { reps } = opts.tune {
        if matches!(opts.dense, DenseScheme::Auto)
            && reason == cost::DecisionReason::CostModel
        {
            if let Some(m) = measure_dense_candidates(
                &kernel,
                in_dim,
                units,
                &candidates,
                req_dtype,
                opts.batch_hint.max(1),
                reps,
            ) {
                let cur_label = match pick {
                    Pick::Rotated => "gemm+rotated",
                    Pick::Broadcast => "gemm+broadcast",
                    Pick::Generic => "generic",
                    Pick::Panels => "gemm+panels",
                };
                overturned = m.scheme != cur_label || m.lanes != lanes;
                pick = match m.scheme {
                    "gemm+rotated" => Pick::Rotated,
                    "gemm+broadcast" => Pick::Broadcast,
                    "generic" => Pick::Generic,
                    _ => Pick::Panels,
                };
                lanes = m.lanes;
                measured_cycles = Some(m.ns);
                reason = cost::DecisionReason::Measured;
            }
        }
    }
    let (algo, scratch_len, label, emitted_dtype, weights_bytes) =
        if matches!(pick, Pick::Generic) {
            summary.weight_elems += kernel.len();
            let bytes = kernel.len() * std::mem::size_of::<f32>();
            (
                k::DenseAlgo::Generic { kernel },
                0,
                "generic",
                simd::WeightDtype::F32,
                bytes,
            )
        } else {
            // the rotated/broadcast matvec tails are f32 algorithms over
            // their own side layouts — pairing them with narrow GEMM panels
            // would store the same weights twice at different precisions,
            // so those picks pin the whole algo to f32 storage
            let store_dtype = match pick {
                Pick::Rotated | Pick::Broadcast => simd::WeightDtype::F32,
                _ => req_dtype,
            };
            let panels =
                k::WeightPanels::pack_dense(&kernel, in_dim, units, lanes, store_dtype);
            summary.weight_elems += panels.elems();
            summary.gemm_dense += 1;
            summary.lane_width = summary.lane_width.max(lanes);
            let mut bytes = panels.weight_bytes();
            let (tail, scratch_len, label) = match pick {
                Pick::Rotated => {
                    summary.rotated_dense += 1;
                    let diag =
                        simd::rotate_diagonals(&transpose(&kernel, in_dim), in_dim);
                    summary.weight_elems += diag.len();
                    bytes += diag.len() * std::mem::size_of::<f32>();
                    (k::DenseTail::Rotated { diag }, 2 * in_dim, "gemm+rotated")
                }
                Pick::Broadcast => {
                    summary.broadcast_dense += 1;
                    let w = transpose(&kernel, in_dim);
                    summary.weight_elems += w.len();
                    bytes += w.len() * std::mem::size_of::<f32>();
                    (k::DenseTail::Broadcast { w }, 0, "gemm+broadcast")
                }
                _ => {
                    summary.panel_tail_dense += 1;
                    (k::DenseTail::Panels, 0, "gemm+panels")
                }
            };
            (
                k::DenseAlgo::Gemm { panels, lanes, tail },
                scratch_len,
                label,
                store_dtype,
                bytes,
            )
        };
    summary.weights_bytes.add(emitted_dtype, weights_bytes);
    if emitted_dtype == simd::WeightDtype::I8 {
        summary.quantized_layers += 1;
    }
    let predicted = candidates
        .iter()
        .find(|c| c.scheme == label && c.lanes == lanes)
        .map_or(0.0, |c| c.cycles);
    let tasks =
        cost::parallel_tasks(predicted, opts.batch_hint.max(1), opts.intra_threads);
    summary.parallel_tasks = summary.parallel_tasks.max(tasks);
    summary.report.decisions.push(cost::LayerDecision {
        layer: layer.to_string(),
        op: "dense",
        candidates,
        chosen: label,
        lane_width: lanes,
        parallel_tasks: tasks,
        predicted_cycles: predicted,
        weight_dtype: emitted_dtype,
        weights_bytes,
        reason,
        fused_pool: false,
        elided: false,
        measured_cycles,
        overturned,
    });
    (algo, scratch_len, label, tasks)
}

/// Transpose a `[n, out]`-layout Dense kernel (`y[o] = Σ_i x[i] K[i][o]`)
/// into the row-major `y = W x` orientation the §3.3 matvec kernels use
/// (`W[i][j] = K[j][i]`). Square only; done once at lowering.
fn transpose(kernel: &[f32], n: usize) -> Vec<f32> {
    debug_assert_eq!(kernel.len(), n * n);
    let mut w = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..n {
            w[i * n + j] = kernel[j * n + i];
        }
    }
    w
}

/// Owned fused-epilogue spec (activation + §3.5 post-affine); borrowed into
/// a [`k::Epilogue`] per kernel invocation — no allocation, no lookup.
#[derive(Clone)]
struct EpSpec {
    act: Activation,
    approx: bool,
    post: Option<(Vec<f32>, Vec<f32>)>,
}

impl EpSpec {
    #[inline]
    fn epilogue(&self) -> k::Epilogue<'_> {
        k::Epilogue {
            act: self.act,
            approx: self.approx,
            post: self.post.as_ref().map(|(s, h)| (s.as_slice(), h.as_slice())),
        }
    }

    fn label(&self) -> String {
        let mut s = String::new();
        if self.act != Activation::Linear {
            s.push('+');
            s.push_str(self.act.name());
            if self.approx && matches!(self.act, Activation::Sigmoid | Activation::Tanh) {
                s.push('~');
            }
        }
        if self.post.is_some() {
            s.push_str("+affine");
        }
        s
    }
}

// ------------------------------------------------------------------ borrows

/// Disjoint (src, dst) borrow of two arena ranges. Lowering guarantees
/// out-of-place steps read and write different planned buffers.
fn src_dst(data: &mut [f32], src: Range<usize>, dst: Range<usize>) -> (&[f32], &mut [f32]) {
    debug_assert!(src.end <= dst.start || dst.end <= src.start, "overlapping spans");
    if src.start < dst.start {
        let (lo, hi) = data.split_at_mut(dst.start);
        let dlen = dst.end - dst.start;
        (&lo[src.start..src.end], &mut hi[..dlen])
    } else {
        let (lo, hi) = data.split_at_mut(src.start);
        let slen = src.end - src.start;
        (&hi[..slen], &mut lo[dst.start..dst.end])
    }
}

/// Disjoint (a, b, dst) borrow for binary steps. `a == b` (the same tensor
/// used twice, e.g. `add(x, x)`) is handled by returning the same slice.
fn srcs_dst(
    data: &mut [f32],
    a: Range<usize>,
    b: Range<usize>,
    dst: Range<usize>,
) -> (&[f32], &[f32], &mut [f32]) {
    if a == b {
        let (x, out) = src_dst(data, a, dst);
        return (x, x, out);
    }
    // Three pairwise-disjoint ranges in arbitrary order: peel slices off in
    // address order, then hand each range its piece.
    let mut tagged = [(a, 0u8), (b, 1), (dst, 2)];
    tagged.sort_by_key(|(r, _)| r.start);
    let (p0, rest) = data.split_at_mut(tagged[1].0.start);
    let (p1, p2) = rest.split_at_mut(tagged[2].0.start - tagged[1].0.start);
    let p0 = &mut p0[tagged[0].0.start..tagged[0].0.end];
    let p1 = &mut p1[..tagged[1].0.end - tagged[1].0.start];
    let p2 = &mut p2[..tagged[2].0.end - tagged[2].0.start];
    let mut srcs: [&[f32]; 2] = [&[], &[]];
    let mut out: Option<&mut [f32]> = None;
    for (piece, tag) in [(p0, tagged[0].1), (p1, tagged[1].1), (p2, tagged[2].1)] {
        match tag {
            0 => srcs[0] = piece,
            1 => srcs[1] = piece,
            _ => out = Some(piece),
        }
    }
    (srcs[0], srcs[1], out.expect("dst range present"))
}

// ------------------------------------------------------------------ kernels

/// Conv2d under any §3.3 scheme ([`k::ConvAlgo`] chosen at lowering), with
/// the §3.4 epilogue in the store loop and optionally a fused
/// single-consumer MaxPool. Its [`Scratch`] span holds `tasks` disjoint
/// stripes, each packing the per-pixel pool `cell` (first `cell_len`
/// elements) followed by the im2col gather row, so the conv intermediate
/// never exists in the arena, parallel bands never alias, and the kernel
/// never mutates itself.
struct ConvK {
    src: Span,
    dst: Span,
    in_hwc: (usize, usize, usize),
    khw_oc: (usize, usize, usize),
    stride: usize,
    padding: Padding,
    algo: k::ConvAlgo,
    bias: Option<Vec<f32>>,
    ep: EpSpec,
    pool: Option<(usize, usize, usize)>,
    cell_len: usize,
    /// Intra-op task budget planned by [`cost::parallel_tasks`].
    tasks: usize,
    scratch: Scratch,
}

impl Kernel for ConvK {
    fn run(&self, batch: usize, data: &mut [f32], scratch: &mut [f32]) {
        let (x, out) = src_dst(data, self.src.range(batch), self.dst.range(batch));
        let (h, w, c) = self.in_hwc;
        k::conv2d_run(
            x,
            (batch, h, w, c),
            &self.algo,
            self.khw_oc,
            self.bias.as_deref(),
            self.stride,
            self.padding,
            self.ep.epilogue(),
            self.pool,
            (self.cell_len, self.tasks),
            self.scratch.slice(scratch),
            out,
        );
    }

    fn encode(&self, e: &mut Encoder) {
        e.u8(1);
        enc_span(e, self.src);
        enc_span(e, self.dst);
        enc_hwc(e, self.in_hwc);
        enc_hwc(e, self.khw_oc);
        e.usize(self.stride);
        e.u8(pad_code(self.padding));
        self.algo.encode(e);
        enc_opt_vec(e, &self.bias);
        enc_ep(e, &self.ep);
        match self.pool {
            None => e.bool(false),
            Some(p) => {
                e.bool(true);
                enc_hwc(e, p);
            }
        }
        e.usize(self.cell_len);
        e.usize(self.tasks);
        enc_scratch(e, self.scratch);
    }
}

struct DwConv2dK {
    src: Span,
    dst: Span,
    in_hwc: (usize, usize, usize),
    khw: (usize, usize),
    stride: usize,
    padding: Padding,
    kernel: Vec<f32>,
    bias: Option<Vec<f32>>,
    ep: EpSpec,
}

impl Kernel for DwConv2dK {
    fn run(&self, batch: usize, data: &mut [f32], _scratch: &mut [f32]) {
        let (x, out) = src_dst(data, self.src.range(batch), self.dst.range(batch));
        let (h, w, c) = self.in_hwc;
        k::depthwise_conv2d_into(
            x,
            (batch, h, w, c),
            &self.kernel,
            self.khw,
            self.bias.as_deref(),
            self.stride,
            self.padding,
            self.ep.epilogue(),
            out,
        );
    }

    fn encode(&self, e: &mut Encoder) {
        e.u8(2);
        enc_span(e, self.src);
        enc_span(e, self.dst);
        enc_hwc(e, self.in_hwc);
        e.usize(self.khw.0);
        e.usize(self.khw.1);
        e.usize(self.stride);
        e.u8(pad_code(self.padding));
        e.vec_f32(&self.kernel);
        enc_opt_vec(e, &self.bias);
        enc_ep(e, &self.ep);
    }
}

/// Dense under any §3.3 scheme + batch blocking ([`k::DenseAlgo`] chosen
/// at lowering): full `GEMM_NR` batch tiles run the register-blocked GEMM
/// microkernel over panels packed once at lowering, tail items (and the
/// batch=1 serving bucket) run the lowered per-item matvec. The [`Scratch`]
/// span holds the rotated tail's doubled-x window — sized at lowering, so
/// `run` never allocates and the kernel never mutates itself.
struct DenseK {
    src: Span,
    dst: Span,
    in_dim: usize,
    units: usize,
    algo: k::DenseAlgo,
    bias: Option<Vec<f32>>,
    /// Intra-op task budget planned by [`cost::parallel_tasks`]; the
    /// [`Scratch`] span holds one rotated-tail window per task.
    tasks: usize,
    scratch: Scratch,
    ep: EpSpec,
}

impl Kernel for DenseK {
    fn run(&self, batch: usize, data: &mut [f32], scratch: &mut [f32]) {
        let (x, out) = src_dst(data, self.src.range(batch), self.dst.range(batch));
        k::dense_run(
            x,
            (batch, self.in_dim),
            &self.algo,
            self.units,
            self.bias.as_deref(),
            self.ep.epilogue(),
            self.scratch.slice(scratch),
            self.tasks,
            out,
        );
    }

    fn encode(&self, e: &mut Encoder) {
        e.u8(3);
        enc_span(e, self.src);
        enc_span(e, self.dst);
        e.usize(self.in_dim);
        e.usize(self.units);
        self.algo.encode(e);
        enc_opt_vec(e, &self.bias);
        e.usize(self.tasks);
        enc_scratch(e, self.scratch);
        enc_ep(e, &self.ep);
    }
}

/// BN lowered to its per-channel affine, scale/shift precomputed.
struct AffineK {
    src: Span,
    dst: Span,
    c: usize,
    scale: Vec<f32>,
    shift: Vec<f32>,
}

impl Kernel for AffineK {
    fn run(&self, batch: usize, data: &mut [f32], _scratch: &mut [f32]) {
        let (x, out) = src_dst(data, self.src.range(batch), self.dst.range(batch));
        k::affine_into(x, self.c, &self.scale, &self.shift, out);
    }

    fn encode(&self, e: &mut Encoder) {
        e.u8(4);
        enc_span(e, self.src);
        enc_span(e, self.dst);
        e.usize(self.c);
        e.vec_f32(&self.scale);
        e.vec_f32(&self.shift);
    }
}

struct AffineInPlaceK {
    dst: Span,
    c: usize,
    scale: Vec<f32>,
    shift: Vec<f32>,
}

impl Kernel for AffineInPlaceK {
    fn run(&self, batch: usize, data: &mut [f32], _scratch: &mut [f32]) {
        k::affine_rows(&mut data[self.dst.range(batch)], self.c, &self.scale, &self.shift);
    }

    fn encode(&self, e: &mut Encoder) {
        e.u8(5);
        enc_span(e, self.dst);
        e.usize(self.c);
        e.vec_f32(&self.scale);
        e.vec_f32(&self.shift);
    }
}

struct MaxPoolK {
    src: Span,
    dst: Span,
    in_hwc: (usize, usize, usize),
    khw_stride: (usize, usize, usize),
}

impl Kernel for MaxPoolK {
    fn run(&self, batch: usize, data: &mut [f32], _scratch: &mut [f32]) {
        let (x, out) = src_dst(data, self.src.range(batch), self.dst.range(batch));
        let (h, w, c) = self.in_hwc;
        k::maxpool_into(x, (batch, h, w, c), self.khw_stride, out);
    }

    fn encode(&self, e: &mut Encoder) {
        e.u8(6);
        enc_span(e, self.src);
        enc_span(e, self.dst);
        enc_hwc(e, self.in_hwc);
        enc_hwc(e, self.khw_stride);
    }
}

struct AvgPoolK {
    src: Span,
    dst: Span,
    in_hwc: (usize, usize, usize),
    khw_stride: (usize, usize, usize),
}

impl Kernel for AvgPoolK {
    fn run(&self, batch: usize, data: &mut [f32], _scratch: &mut [f32]) {
        let (x, out) = src_dst(data, self.src.range(batch), self.dst.range(batch));
        let (h, w, c) = self.in_hwc;
        k::avgpool_into(x, (batch, h, w, c), self.khw_stride, out);
    }

    fn encode(&self, e: &mut Encoder) {
        e.u8(7);
        enc_span(e, self.src);
        enc_span(e, self.dst);
        enc_hwc(e, self.in_hwc);
        enc_hwc(e, self.khw_stride);
    }
}

struct GlobalAvgPoolK {
    src: Span,
    dst: Span,
    in_hwc: (usize, usize, usize),
}

impl Kernel for GlobalAvgPoolK {
    fn run(&self, batch: usize, data: &mut [f32], _scratch: &mut [f32]) {
        let (x, out) = src_dst(data, self.src.range(batch), self.dst.range(batch));
        let (h, w, c) = self.in_hwc;
        k::globalavgpool_into(x, (batch, h, w, c), out);
    }

    fn encode(&self, e: &mut Encoder) {
        e.u8(8);
        enc_span(e, self.src);
        enc_span(e, self.dst);
        enc_hwc(e, self.in_hwc);
    }
}

struct UpsampleK {
    src: Span,
    dst: Span,
    in_hwc: (usize, usize, usize),
    factor: usize,
}

impl Kernel for UpsampleK {
    fn run(&self, batch: usize, data: &mut [f32], _scratch: &mut [f32]) {
        let (x, out) = src_dst(data, self.src.range(batch), self.dst.range(batch));
        let (h, w, c) = self.in_hwc;
        k::upsample_into(x, (batch, h, w, c), self.factor, out);
    }

    fn encode(&self, e: &mut Encoder) {
        e.u8(9);
        enc_span(e, self.src);
        enc_span(e, self.dst);
        enc_hwc(e, self.in_hwc);
        e.usize(self.factor);
    }
}

struct ZeroPadK {
    src: Span,
    dst: Span,
    in_hwc: (usize, usize, usize),
    pad: [usize; 4],
}

impl Kernel for ZeroPadK {
    fn run(&self, batch: usize, data: &mut [f32], _scratch: &mut [f32]) {
        let (x, out) = src_dst(data, self.src.range(batch), self.dst.range(batch));
        let (h, w, c) = self.in_hwc;
        k::zeropad_into(x, (batch, h, w, c), self.pad, out);
    }

    fn encode(&self, e: &mut Encoder) {
        e.u8(10);
        enc_span(e, self.src);
        enc_span(e, self.dst);
        enc_hwc(e, self.in_hwc);
        for p in self.pad {
            e.usize(p);
        }
    }
}

struct ActK {
    src: Span,
    dst: Span,
    c: usize,
    ep: EpSpec,
}

impl Kernel for ActK {
    fn run(&self, batch: usize, data: &mut [f32], _scratch: &mut [f32]) {
        let (x, out) = src_dst(data, self.src.range(batch), self.dst.range(batch));
        out.copy_from_slice(x);
        self.ep.epilogue().apply_whole(out, self.c);
    }

    fn encode(&self, e: &mut Encoder) {
        e.u8(11);
        enc_span(e, self.src);
        enc_span(e, self.dst);
        e.usize(self.c);
        enc_ep(e, &self.ep);
    }
}

struct ActInPlaceK {
    dst: Span,
    c: usize,
    ep: EpSpec,
}

impl Kernel for ActInPlaceK {
    fn run(&self, batch: usize, data: &mut [f32], _scratch: &mut [f32]) {
        let buf = &mut data[self.dst.range(batch)];
        self.ep.epilogue().apply_whole(buf, self.c);
    }

    fn encode(&self, e: &mut Encoder) {
        e.u8(12);
        enc_span(e, self.dst);
        e.usize(self.c);
        enc_ep(e, &self.ep);
    }
}

struct SoftmaxK {
    src: Span,
    dst: Span,
    c: usize,
    approx: bool,
}

impl Kernel for SoftmaxK {
    fn run(&self, batch: usize, data: &mut [f32], _scratch: &mut [f32]) {
        let (x, out) = src_dst(data, self.src.range(batch), self.dst.range(batch));
        k::softmax_into(x, self.c, self.approx, out);
    }

    fn encode(&self, e: &mut Encoder) {
        e.u8(13);
        enc_span(e, self.src);
        enc_span(e, self.dst);
        e.usize(self.c);
        e.bool(self.approx);
    }
}

struct SoftmaxInPlaceK {
    dst: Span,
    c: usize,
    approx: bool,
}

impl Kernel for SoftmaxInPlaceK {
    fn run(&self, batch: usize, data: &mut [f32], _scratch: &mut [f32]) {
        k::softmax_rows(&mut data[self.dst.range(batch)], self.c, self.approx);
    }

    fn encode(&self, e: &mut Encoder) {
        e.u8(14);
        enc_span(e, self.dst);
        e.usize(self.c);
        e.bool(self.approx);
    }
}

struct AddK {
    a: Span,
    b: Span,
    dst: Span,
}

impl Kernel for AddK {
    fn run(&self, batch: usize, data: &mut [f32], _scratch: &mut [f32]) {
        let (a, b, out) = srcs_dst(
            data,
            self.a.range(batch),
            self.b.range(batch),
            self.dst.range(batch),
        );
        k::add_into(a, b, out);
    }

    fn encode(&self, e: &mut Encoder) {
        e.u8(15);
        enc_span(e, self.a);
        enc_span(e, self.b);
        enc_span(e, self.dst);
    }
}

/// Residual add writing over its (dead) first operand — no copy of the
/// second operand, unlike the pre-`Program` interpreter.
struct AddInPlaceK {
    dst: Span,
    other: Span,
}

impl Kernel for AddInPlaceK {
    fn run(&self, batch: usize, data: &mut [f32], _scratch: &mut [f32]) {
        let (other, buf) = src_dst(data, self.other.range(batch), self.dst.range(batch));
        k::add_assign(buf, other);
    }

    fn encode(&self, e: &mut Encoder) {
        e.u8(16);
        enc_span(e, self.dst);
        enc_span(e, self.other);
    }
}

struct ConcatK {
    a: Span,
    b: Span,
    dst: Span,
    ca: usize,
    cb: usize,
}

impl Kernel for ConcatK {
    fn run(&self, batch: usize, data: &mut [f32], _scratch: &mut [f32]) {
        let (a, b, out) = srcs_dst(
            data,
            self.a.range(batch),
            self.b.range(batch),
            self.dst.range(batch),
        );
        k::concat_into(a, self.ca, b, self.cb, out);
    }

    fn encode(&self, e: &mut Encoder) {
        e.u8(17);
        enc_span(e, self.a);
        enc_span(e, self.b);
        enc_span(e, self.dst);
        e.usize(self.ca);
        e.usize(self.cb);
    }
}

/// Out-of-place flatten: a reshape across buffers is a straight copy.
struct CopyK {
    src: Span,
    dst: Span,
}

impl Kernel for CopyK {
    fn run(&self, batch: usize, data: &mut [f32], _scratch: &mut [f32]) {
        let (x, out) = src_dst(data, self.src.range(batch), self.dst.range(batch));
        out.copy_from_slice(x);
    }

    fn encode(&self, e: &mut Encoder) {
        e.u8(18);
        enc_span(e, self.src);
        enc_span(e, self.dst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builder::{random_chain, square_mlp, tiny_cnn};
    use crate::nn::interp::NaiveInterp;
    use crate::util::propcheck::check;
    use crate::util::rng::SplitMix64;

    fn run_program(spec: &ModelSpec, opts: CompileOptions, x: &Tensor) -> Vec<Tensor> {
        let p = Program::lower(spec, opts).unwrap();
        let mut arena = p.new_arena(x.shape()[0]);
        p.load_input(&mut arena, x);
        p.run(&mut arena);
        p.read_outputs(&arena)
    }

    #[test]
    fn lowered_tiny_cnn_matches_naive() {
        let spec = tiny_cnn(61);
        let mut rng = SplitMix64::new(3);
        let x = Tensor::from_vec(&[2, 8, 8, 3], rng.uniform_vec(2 * 8 * 8 * 3));
        let want = NaiveInterp::new(spec.clone()).unwrap().infer(&x).unwrap();
        let opts = CompileOptions { approx: false, ..CompileOptions::default() };
        let got = run_program(&spec, opts, &x);
        let d = want[0].max_abs_diff(&got[0]);
        assert!(d < 1e-4, "diff {d}");
    }

    #[test]
    fn bit_exact_options_are_bit_exact() {
        let spec = tiny_cnn(62);
        let mut rng = SplitMix64::new(4);
        let x = Tensor::from_vec(&[1, 8, 8, 3], rng.uniform_vec(8 * 8 * 3));
        let want = NaiveInterp::new(spec.clone()).unwrap().infer(&x).unwrap();
        let got = run_program(&spec, CompileOptions::bit_exact(), &x);
        assert_eq!(want[0].data(), got[0].data());
    }

    #[test]
    fn summary_counts_the_lowering() {
        let spec = tiny_cnn(63);
        let p = Program::lower(&spec, CompileOptions::default()).unwrap();
        let s = p.summary();
        assert_eq!(s.folded_bn, 1, "{s}");
        // conv+maxpool fuse into one step; dense, softmax survive; flatten
        // elides in place.
        assert!(s.steps.len() >= 4, "{s}");
        assert!(s.elided_steps >= 1, "{s}");
        assert!(s.weight_elems > 0 && s.arena_item_elems > 0, "{s}");
        // tiny_cnn's dense is 48→10 — rectangular, so it lowers to the
        // batch-blocked GEMM with the packed-panel tail, never rotated.
        assert_eq!(s.rotated_dense, 0, "{s}");
        assert_eq!(s.gemm_dense, 1, "{s}");
        assert_eq!(s.panel_tail_dense, 1, "{s}");
        // §3.4: the single-consumer maxpool merges into the conv, which is
        // 3×3 SAME → Auto picks the im2col scheme.
        assert_eq!(s.fused_maxpool, 1, "{s}");
        assert_eq!(s.im2col_conv, 1, "{s}");
        assert!(s.steps.iter().any(|l| l.contains("conv2d+maxpool")), "{s}");
    }

    #[test]
    fn auto_schemes_come_from_the_cost_model() {
        use crate::compiler::cost::DecisionReason;

        let spec = tiny_cnn(71);
        let p = Program::lower(&spec, CompileOptions::default()).unwrap();
        let r = &p.summary().report;
        assert_eq!(r.model, spec.name);
        assert_eq!(r.batch_hint, 1);
        assert!(r.predicted_total_cycles() > 0.0, "{r}");
        assert_eq!(r.arena_bytes, p.summary().arena_item_elems * 4, "{r}");
        // every emitted conv/dense decision is a genuine argmin over the
        // candidates matching its fusion flag
        for d in r.decisions.iter().filter(|d| !d.elided) {
            assert_eq!(d.reason, DecisionReason::CostModel, "{d:?}");
            let best = d
                .candidates
                .iter()
                .filter(|c| c.fused_pool == d.fused_pool)
                .fold(f64::INFINITY, |m, c| m.min(c.cycles));
            assert_eq!(d.predicted_cycles, best, "{d:?}");
        }
        let conv = r.decisions.iter().find(|d| d.op == "conv2d").unwrap();
        assert_eq!(conv.chosen, "im2col", "{conv:?}");
        assert!(conv.fused_pool, "{conv:?}");
        // fused candidates were priced alongside unfused ones
        assert!(conv.candidates.iter().any(|c| c.fused_pool));
        assert!(conv.candidates.iter().any(|c| !c.fused_pool));
        let dense = r.decisions.iter().find(|d| d.op == "dense").unwrap();
        assert_eq!(dense.chosen, "gemm+panels", "{dense:?}");
        // the merged-away maxpool shows up as an elided entry
        assert!(r.decisions.iter().any(|d| d.elided && d.fused_pool), "{r}");
        let table = r.render_table();
        assert!(table.contains("im2col") && table.contains("cost-model"), "{table}");

        // forcing schemes flips the recorded reason (bit-exact included)
        let be = Program::lower(&spec, CompileOptions::bit_exact()).unwrap();
        for d in be.summary().report.decisions.iter().filter(|d| !d.elided) {
            assert_eq!(d.chosen, "generic", "{d:?}");
            assert_eq!(d.reason, DecisionReason::Forced, "{d:?}");
        }

        // a full-tile batch hint is recorded and keeps choices on the grid
        let b8 = Program::lower(
            &spec,
            CompileOptions { batch_hint: 8, ..CompileOptions::default() },
        )
        .unwrap();
        assert_eq!(b8.summary().report.batch_hint, 8);
        assert_eq!(b8.summary().gemm_dense, 1, "{}", b8.summary());
    }

    #[test]
    fn conv_schemes_agree_and_are_counted() {
        let spec = tiny_cnn(67);
        let mut rng = SplitMix64::new(21);
        let x = Tensor::from_vec(&[2, 8, 8, 3], rng.uniform_vec(2 * 8 * 8 * 3));
        let want = NaiveInterp::new(spec.clone()).unwrap().infer(&x).unwrap();
        for fuse_pool in [false, true] {
            for scheme in
                [ConvScheme::Auto, ConvScheme::Direct, ConvScheme::Im2col, ConvScheme::Generic]
            {
                let opts = CompileOptions {
                    approx: false,
                    conv: scheme,
                    fuse_pool,
                    ..CompileOptions::default()
                };
                let p = Program::lower(&spec, opts).unwrap();
                let s = p.summary();
                match scheme {
                    ConvScheme::Direct => assert_eq!(s.direct_conv, 1, "{s}"),
                    // tiny_cnn's conv is 3×3 SAME → Auto resolves to im2col
                    ConvScheme::Im2col | ConvScheme::Auto => {
                        assert_eq!(s.im2col_conv, 1, "{s}")
                    }
                    ConvScheme::Generic => {
                        assert_eq!(s.direct_conv + s.im2col_conv, 0, "{s}")
                    }
                }
                assert_eq!(s.fused_maxpool, usize::from(fuse_pool), "{s}");
                let mut arena = p.new_arena(2);
                p.load_input(&mut arena, &x);
                p.run(&mut arena);
                let got = p.read_outputs(&arena);
                let d = want[0].max_abs_diff(&got[0]);
                assert!(d < 1e-4, "{scheme:?} fuse_pool={fuse_pool}: diff {d}");
            }
        }
    }

    #[test]
    fn overlapping_pool_windows_are_not_fused() {
        use crate::model::builder::Builder;

        // pool stride < window → fusing would recompute conv pixels; the
        // lowering must keep the two kernels separate (and stay correct).
        let mut b = Builder::new("overlap", &[6, 6, 2], 13);
        let c = b.conv2d("input", 3, 3, 1, Activation::Relu);
        let p = b.maxpool_with_stride(&c, 3, 1);
        let spec = b.finish(&[&p]);
        let prog = Program::lower(&spec, CompileOptions::default()).unwrap();
        assert_eq!(prog.summary().fused_maxpool, 0, "{}", prog.summary());

        let mut rng = SplitMix64::new(14);
        let x = Tensor::from_vec(&[1, 6, 6, 2], rng.uniform_vec(72));
        let want = NaiveInterp::new(spec.clone()).unwrap().infer(&x).unwrap();
        let mut arena = prog.new_arena(1);
        prog.load_input(&mut arena, &x);
        prog.run(&mut arena);
        let got = prog.read_outputs(&arena);
        assert!(want[0].max_abs_diff(&got[0]) < 1e-4);
    }

    #[test]
    fn dense_schemes_agree_and_are_counted() {
        let spec = square_mlp(9, 16, 2);
        let mut rng = SplitMix64::new(8);
        // batch 3 runs the all-tail matvec path, 8 runs two full GEMM
        // tiles, 9 runs tiles + a tail item
        for batch in [3usize, 8, 9] {
            let x = Tensor::from_vec(&[batch, 16], rng.uniform_vec(batch * 16));
            let want = NaiveInterp::new(spec.clone()).unwrap().infer(&x).unwrap();
            for scheme in [DenseScheme::Rotated, DenseScheme::Broadcast, DenseScheme::Generic] {
                let opts = CompileOptions {
                    approx: false,
                    dense: scheme,
                    ..CompileOptions::default()
                };
                let p = Program::lower(&spec, opts).unwrap();
                let s = p.summary();
                match scheme {
                    DenseScheme::Rotated => {
                        assert_eq!(s.rotated_dense, 3, "{s}");
                        assert_eq!(s.gemm_dense, 3, "{s}");
                    }
                    DenseScheme::Broadcast => {
                        assert_eq!(s.broadcast_dense, 3, "{s}");
                        assert_eq!(s.gemm_dense, 3, "{s}");
                    }
                    DenseScheme::Generic => {
                        assert_eq!(s.gemm_dense + s.rotated_dense + s.broadcast_dense, 0, "{s}")
                    }
                }
                let mut arena = p.new_arena(batch);
                p.load_input(&mut arena, &x);
                p.run(&mut arena);
                let got = p.read_outputs(&arena);
                let d = want[0].max_abs_diff(&got[0]);
                assert!(d < 1e-4, "{scheme:?} batch {batch}: diff {d}");
            }
        }
    }

    /// The bit-exact acceptance criterion at batch > 1: the Generic dense
    /// path runs per item in the oracle's exact accumulation order, so a
    /// batch of 5 (which would hit GEMM tiles + tail under any other
    /// scheme) stays bit-for-bit.
    #[test]
    fn bit_exact_options_are_bit_exact_batched() {
        let spec = tiny_cnn(69);
        let mut rng = SplitMix64::new(6);
        let x = Tensor::from_vec(&[5, 8, 8, 3], rng.uniform_vec(5 * 8 * 8 * 3));
        let want = NaiveInterp::new(spec.clone()).unwrap().infer(&x).unwrap();
        let got = run_program(&spec, CompileOptions::bit_exact(), &x);
        assert_eq!(want[0].data(), got[0].data());
    }

    /// Satellite regression: a weight row holding Inf/NaN multiplied by a
    /// zero input must produce NaN (0·Inf) in every engine — the removed
    /// `xv == 0.0` ReLU-sparsity skip silently dropped the row and
    /// returned finite values, diverging from the oracle.
    #[test]
    fn dense_nonfinite_weights_match_naive() {
        use crate::model::builder::Builder;

        let mut b = Builder::new("nonfinite", &[4], 77);
        let d = b.dense("input", 3, Activation::Linear);
        let mut spec = b.finish(&[&d]);
        let kref = spec.layers[0].weights["kernel"].clone();
        spec.weights[kref.offset] = f32::INFINITY; // K[0][0]
        spec.weights[kref.offset + 1] = f32::NAN; // K[0][1]
        let x = Tensor::from_vec(&[1, 4], vec![0.0, 1.0, -1.0, 0.5]);
        let want = NaiveInterp::new(spec.clone()).unwrap().infer(&x).unwrap();
        assert!(
            want[0].data()[0].is_nan() && want[0].data()[1].is_nan(),
            "oracle must propagate 0·Inf = NaN: {:?}",
            want[0].data()
        );
        for scheme in [DenseScheme::Generic, DenseScheme::Rotated, DenseScheme::Broadcast] {
            let opts =
                CompileOptions { approx: false, dense: scheme, ..CompileOptions::default() };
            let got = run_program(&spec, opts, &x);
            for (o, (w, g)) in want[0].data().iter().zip(got[0].data()).enumerate() {
                assert_eq!(w.is_nan(), g.is_nan(), "{scheme:?} out[{o}]: {w} vs {g}");
                if !w.is_nan() {
                    assert!((w - g).abs() < 1e-5, "{scheme:?} out[{o}]: {w} vs {g}");
                }
            }
        }
    }

    /// Deterministic coverage of every binary-op lowering path: out-of-place
    /// add (3-way disjoint borrow), duplicated-operand add (`x + x`),
    /// in-place add, and concat — checked bit-for-bit against the oracle.
    #[test]
    fn binary_lowerings_cover_all_borrow_paths() {
        use crate::model::builder::Builder;

        let mut b = Builder::new("residuals", &[4, 4, 2], 5);
        let a = b.conv2d("input", 2, 3, 1, Activation::Relu);
        let m1 = b.add(&a, "input"); // `a` lives on → out-of-place AddK
        let cat = b.concat(&m1, &a); // ConcatK (3-way srcs_dst)
        let m2 = b.add(&cat, &cat); // x + x while cat lives on → a == b path
        let m3 = b.add(&m2, &cat); // m2 dies here → AddInPlaceK
        let spec = b.finish(&[&m3]);

        let p = Program::lower(&spec, CompileOptions::bit_exact()).unwrap();
        let s = p.summary();
        assert_eq!(s.steps.iter().filter(|l| l.contains("add")).count(), 3, "{s}");
        assert!(s.steps.iter().any(|l| l.contains("add") && l.contains("in-place")), "{s}");
        assert!(s.steps.iter().any(|l| l.contains("concat")), "{s}");

        let mut rng = SplitMix64::new(17);
        let x = Tensor::from_vec(&[2, 4, 4, 2], rng.uniform_vec(2 * 4 * 4 * 2));
        let want = NaiveInterp::new(spec.clone()).unwrap().infer(&x).unwrap();
        let mut arena = p.new_arena(2);
        p.load_input(&mut arena, &x);
        p.run(&mut arena);
        let got = p.read_outputs(&arena);
        assert_eq!(want[0].data(), got[0].data());
    }

    /// The tentpole property: a lowered `Program` is an immutable
    /// `Send + Sync` artifact — N threads run the *same* program
    /// concurrently, each over its own pooled arena, and every one matches
    /// the oracle. (Pre-refactor, kernels carried owned scratch and `run`
    /// took `&mut self`, so this could not even compile.)
    #[test]
    fn shared_program_runs_concurrently_from_many_threads() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Program>();
        assert_send_sync::<Arena>();

        let spec = tiny_cnn(71);
        let mut rng = SplitMix64::new(31);
        let x = Tensor::from_vec(&[2, 8, 8, 3], rng.uniform_vec(2 * 8 * 8 * 3));
        let want = NaiveInterp::new(spec.clone()).unwrap().infer(&x).unwrap();
        let opts = CompileOptions { approx: false, ..CompileOptions::default() };
        let p = std::sync::Arc::new(Program::lower(&spec, opts).unwrap());

        let threads: Vec<_> = (0..4)
            .map(|_| {
                let p = p.clone();
                let x = x.clone();
                let want = want[0].clone();
                std::thread::spawn(move || {
                    let mut pool = ArenaPool::new();
                    for _ in 0..8 {
                        let got = p.infer_pooled(&x, &mut pool).unwrap();
                        let d = want.max_abs_diff(&got[0]);
                        assert!(d < 1e-4, "shared run diverged: {d}");
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
    }

    /// PR 7 tentpole: forcing a lane width changes only performance — every
    /// width is the same arithmetic per scheme, so forced-scalar and
    /// forced-8-lane lowerings stay within the existing tolerance of the
    /// oracle, and the decision trail records the width actually emitted.
    #[test]
    fn lane_force_is_recorded_and_still_correct() {
        let spec = tiny_cnn(74);
        let mut rng = SplitMix64::new(44);
        let x = Tensor::from_vec(&[3, 8, 8, 3], rng.uniform_vec(3 * 8 * 8 * 3));
        let want = NaiveInterp::new(spec.clone()).unwrap().infer(&x).unwrap();
        for (sel, width) in [
            (LaneSelect::Scalar, 1usize),
            (LaneSelect::W4, 4),
            (LaneSelect::W8, 8),
            (LaneSelect::W16, 16),
        ] {
            let opts =
                CompileOptions { approx: false, lanes: sel, ..CompileOptions::default() };
            let p = Program::lower(&spec, opts).unwrap();
            let s = p.summary();
            // a forced ceiling bounds every recorded width; forcing scalar
            // pins every kernel to 1 exactly
            for d in s.report.decisions.iter().filter(|d| !d.elided) {
                assert!(d.lane_width <= width, "{sel:?}: {d:?}");
                assert!(d.lane_width >= 1, "{sel:?}: {d:?}");
            }
            if width == 1 {
                assert_eq!(s.lane_width, 1, "{s}");
            } else {
                assert!(s.lane_width >= 4, "{s}");
            }
            let mut arena = p.new_arena(3);
            p.load_input(&mut arena, &x);
            p.run(&mut arena);
            let got = p.read_outputs(&arena);
            let d = want[0].max_abs_diff(&got[0]);
            assert!(d < 1e-4, "{sel:?}: diff {d}");
        }
    }

    /// PR 7 tentpole: intra-op banding is a pure partition of the same
    /// arithmetic over disjoint output/scratch spans, so the parallel
    /// lowering is **bitwise** identical to the sequential one — for every
    /// forced lane width, on a net big enough that the cost model actually
    /// plans multi-task kernels.
    #[test]
    fn intra_op_parallel_matches_sequential_bitwise() {
        use crate::model::builder::wide_cnn;

        let spec = wide_cnn(91);
        let mut rng = SplitMix64::new(41);
        let x = Tensor::from_vec(&[2, 32, 32, 8], rng.uniform_vec(2 * 32 * 32 * 8));
        for sel in [LaneSelect::Scalar, LaneSelect::W4, LaneSelect::W8] {
            let base = CompileOptions { lanes: sel, ..CompileOptions::default() };
            let seq = run_program(&spec, base, &x);
            let par_opts = CompileOptions { intra_threads: 4, ..base };
            let p = Program::lower(&spec, par_opts).unwrap();
            assert!(
                p.summary().parallel_tasks > 1,
                "{sel:?}: cost model kept everything sequential: {}",
                p.summary()
            );
            let mut arena = p.new_arena(2);
            p.load_input(&mut arena, &x);
            p.run(&mut arena);
            let par = p.read_outputs(&arena);
            let a: Vec<u32> = seq[0].data().iter().map(|v| v.to_bits()).collect();
            let b: Vec<u32> = par[0].data().iter().map(|v| v.to_bits()).collect();
            assert_eq!(a, b, "{sel:?}: parallel split changed bits");
        }
        // ...while a small net stays sequential under the same thread budget
        let tiny = tiny_cnn(75);
        let p = Program::lower(
            &tiny,
            CompileOptions { intra_threads: 4, ..CompileOptions::default() },
        )
        .unwrap();
        assert_eq!(p.summary().parallel_tasks, 1, "{}", p.summary());
    }

    /// Tentpole: requesting a narrow weight dtype re-stores every blocked
    /// kernel's panels at that dtype (byte accounting and decision trail
    /// included) while outputs stay within the dtype's documented accuracy
    /// band of the f32 lowering.
    #[test]
    fn narrow_weight_dtypes_lower_and_stay_close() {
        use crate::nn::simd::WeightDtype;

        let spec = tiny_cnn(81);
        let mut rng = SplitMix64::new(51);
        let x = Tensor::from_vec(&[2, 8, 8, 3], rng.uniform_vec(2 * 8 * 8 * 3));
        let base = CompileOptions { approx: false, ..CompileOptions::default() };
        let f32_prog = Program::lower(&spec, base).unwrap();
        let f32_bytes = f32_prog.summary().weights_bytes;
        assert!(f32_bytes.f32_bytes > 0, "{f32_bytes}");
        assert_eq!(f32_bytes.bf16_bytes + f32_bytes.i8_bytes, 0, "{f32_bytes}");
        assert_eq!(f32_prog.summary().quantized_layers, 0);
        let want = run_program(&spec, base, &x);

        for (dtype, tol) in [(WeightDtype::Bf16, 0.06), (WeightDtype::I8, 0.15)] {
            let opts = CompileOptions { weight_dtype: dtype, ..base };
            let p = Program::lower(&spec, opts).unwrap();
            let s = p.summary();
            // every blocked conv/dense stored narrow; nothing but the
            // narrow bucket grew
            assert!(s.weights_bytes.of(dtype) > 0, "{dtype}: {}", s.weights_bytes);
            assert!(
                s.weights_bytes.total() < f32_bytes.total(),
                "{dtype}: {} !< {}",
                s.weights_bytes,
                f32_bytes
            );
            assert_eq!(
                s.quantized_layers,
                usize::from(dtype == WeightDtype::I8) * 2,
                "{s}"
            );
            for d in s.report.decisions.iter().filter(|d| !d.elided) {
                assert_eq!(d.weight_dtype, dtype, "{d:?}");
                assert!(d.weights_bytes > 0, "{d:?}");
            }
            let mut arena = p.new_arena(2);
            p.load_input(&mut arena, &x);
            p.run(&mut arena);
            let got = p.read_outputs(&arena);
            let d = want[0].max_abs_diff(&got[0]);
            assert!(d < tol, "{dtype}: diff {d}");
            assert!(d > 0.0 || dtype == WeightDtype::Bf16, "{dtype}: suspiciously exact");
        }
    }

    /// Nonfinite weights demote a narrow request back to f32 storage —
    /// quantizing a NaN tap would silently zero it and break the oracle's
    /// NaN-propagation semantics.
    #[test]
    fn nonfinite_weights_pin_f32_storage_under_narrow_request() {
        use crate::model::builder::Builder;
        use crate::nn::simd::WeightDtype;

        let mut b = Builder::new("nonfinite-dt", &[8], 79);
        let d = b.dense("input", 8, Activation::Linear);
        let mut spec = b.finish(&[&d]);
        let kref = spec.layers[0].weights["kernel"].clone();
        spec.weights[kref.offset] = f32::NAN;
        let opts = CompileOptions {
            weight_dtype: WeightDtype::I8,
            ..CompileOptions::default()
        };
        let p = Program::lower(&spec, opts).unwrap();
        let s = p.summary();
        assert_eq!(s.quantized_layers, 0, "{s}");
        assert_eq!(s.weights_bytes.i8_bytes, 0, "{}", s.weights_bytes);
        assert!(s.weights_bytes.f32_bytes > 0, "{}", s.weights_bytes);
        let dec = s.report.decisions.iter().find(|d| d.op == "dense").unwrap();
        assert_eq!(dec.weight_dtype, WeightDtype::F32, "{dec:?}");
        // and the NaN still propagates at run time
        let x = Tensor::from_vec(&[1, 8], vec![0.0; 8]);
        let mut arena = p.new_arena(1);
        p.load_input(&mut arena, &x);
        p.run(&mut arena);
        let got = p.read_outputs(&arena);
        assert!(got[0].data()[0].is_nan(), "{:?}", got[0].data());
    }

    #[test]
    fn kernel_scratch_is_planned_per_program() {
        // default tiny_cnn lowering: fused conv+maxpool (per-pixel cell)
        // over the im2col scheme (gather row) — both need arena scratch
        let spec = tiny_cnn(72);
        let p = Program::lower(&spec, CompileOptions::default()).unwrap();
        assert!(p.summary().scratch_elems > 0, "{}", p.summary());
        // bit-exact: generic conv, no fusion, generic dense — no scratch
        let exact = Program::lower(&spec, CompileOptions::bit_exact()).unwrap();
        assert_eq!(exact.summary().scratch_elems, 0, "{}", exact.summary());
        // rotated dense carries its doubled-x window per layer
        let mlp = square_mlp(9, 16, 2);
        let p = Program::lower(&mlp, CompileOptions::default()).unwrap();
        assert!(p.summary().scratch_elems >= 2 * 16, "{}", p.summary());
    }

    #[test]
    fn lower_count_hook_counts_lowerings() {
        let spec = tiny_cnn(73);
        let before = lower_count();
        let _a = Program::lower(&spec, CompileOptions::default()).unwrap();
        let _b = Program::lower(&spec, CompileOptions::default()).unwrap();
        // other tests may lower concurrently — assert at least our two
        assert!(lower_count() >= before + 2);
    }

    #[test]
    fn arena_pool_reuses_per_batch() {
        let spec = tiny_cnn(64);
        let p = Program::lower(&spec, CompileOptions::default()).unwrap();
        let mut pool = ArenaPool::new();
        let b1 = pool.get(&p, 1).bytes();
        pool.get(&p, 4);
        assert_eq!(pool.len(), 2);
        let total = pool.bytes();
        // asking again for either batch creates nothing new
        pool.get(&p, 1);
        pool.get(&p, 4);
        assert_eq!(pool.len(), 2);
        assert_eq!(pool.bytes(), total);
        assert_eq!(pool.get(&p, 1).bytes(), b1);
    }

    #[test]
    fn arena_pool_is_bounded() {
        // cycling through many ad-hoc batch sizes must not grow the pool
        // without bound — the smallest unpinned arena is evicted past the
        // cap, and the largest (most expensive to re-create) stays.
        let spec = tiny_cnn(65);
        let p = Program::lower(&spec, CompileOptions::default()).unwrap();
        let mut pool = ArenaPool::new();
        for batch in 1..=10 {
            pool.get(&p, batch);
        }
        assert!(pool.len() <= MAX_UNPINNED_ARENAS, "{} arenas pooled", pool.len());
        let biggest = p.new_arena(10).bytes();
        assert!(pool.arenas.iter().any(|a| a.bytes() == biggest));
    }

    /// Interleaved serving across batch buckets must be allocation-stable:
    /// after the first pass per bucket, neither the pool length, nor the
    /// pooled byte total, nor any per-bucket arena size may grow again.
    #[test]
    fn interleaved_buckets_stabilize_after_first_pass() {
        let spec = tiny_cnn(68);
        let p = Program::lower(&spec, CompileOptions::default()).unwrap();
        let mut pool = ArenaPool::new();
        let buckets = [1usize, 3, 5];
        let mut rng = SplitMix64::new(19);

        let mut run = |pool: &mut ArenaPool, p: &Program, batch: usize| -> usize {
            let x = Tensor::from_vec(
                &[batch, 8, 8, 3],
                rng.uniform_vec(batch * 8 * 8 * 3),
            );
            let arena = pool.get(p, batch);
            p.load_input(arena, &x);
            p.run(arena);
            arena.bytes()
        };

        // first pass per bucket: each allocates its arena exactly once
        let first: Vec<usize> = buckets.iter().map(|&b| run(&mut pool, &p, b)).collect();
        let (len0, bytes0) = (pool.len(), pool.bytes());
        assert_eq!(len0, buckets.len());

        // interleave the buckets for several rounds: steady state
        for _ in 0..4 {
            for (i, &b) in buckets.iter().enumerate() {
                let per_bucket = run(&mut pool, &p, b);
                assert_eq!(per_bucket, first[i], "bucket {b} arena regrew");
            }
            assert_eq!(pool.len(), len0, "pool length grew in steady state");
            assert_eq!(pool.bytes(), bytes0, "pool bytes grew in steady state");
        }
    }

    #[test]
    fn reserved_buckets_are_never_evicted() {
        // a serving bucket set larger than the unpinned cap stays fully
        // pooled: ad-hoc sizes churn, pinned buckets never miss.
        let spec = tiny_cnn(66);
        let p = Program::lower(&spec, CompileOptions::default()).unwrap();
        let mut pool = ArenaPool::new();
        let buckets = [1usize, 2, 4, 8, 16, 32];
        for &b in &buckets {
            pool.reserve(&p, b);
        }
        let reserved = pool.bytes();
        for batch in 40..=60 {
            pool.get(&p, batch); // ad-hoc churn
        }
        for &b in &buckets {
            pool.get(&p, b);
        }
        assert!(pool.bytes() >= reserved);
        assert!(pool.len() <= buckets.len() + MAX_UNPINNED_ARENAS);
        for &b in &buckets {
            assert!(pool.arenas.iter().any(|a| a.batch == b), "bucket {b} evicted");
        }
    }

    /// §3.2 satellite: on randomized graphs, tensors with overlapping
    /// lifetimes must land in disjoint arena *ranges* (not just distinct
    /// buffer ids — this checks the flattened offsets the kernels use).
    #[test]
    fn property_overlapping_lifetimes_get_disjoint_arena_ranges() {
        check(
            "program_arena_disjoint",
            50,
            |r: &mut SplitMix64| random_chain(r),
            |spec| {
                // fold + pool fusion off so the lifetime analysis below
                // matches the lowered graph layer-for-layer (fused convs
                // have no span; the fuzz suite covers fused value parity)
                let opts = CompileOptions {
                    fold_bn: false,
                    fuse_pool: false,
                    ..CompileOptions::default()
                };
                let p = Program::lower(spec, opts).map_err(|e| e.to_string())?;
                // def/last-use indices, same convention as the §3.2 planner
                let mut def: BTreeMap<&str, usize> = BTreeMap::new();
                let mut last: BTreeMap<&str, usize> = BTreeMap::new();
                def.insert("input", 0);
                last.insert("input", 0);
                for (i, l) in spec.layers.iter().enumerate() {
                    def.insert(&l.name, i + 1);
                    last.insert(&l.name, i + 1);
                    for inp in &l.inputs {
                        last.insert(inp.as_str(), i + 1);
                    }
                }
                let eternal = spec.layers.len() + 1;
                for o in &spec.outputs {
                    last.insert(o.as_str(), eternal);
                }
                let names: Vec<&str> = def.keys().copied().collect();
                for (ai, &a) in names.iter().enumerate() {
                    for &b in &names[ai + 1..] {
                        let (da, la) = (def[a], last[a]);
                        let (db, lb) = (def[b], last[b]);
                        if la <= db || lb <= da {
                            continue; // lifetimes disjoint — sharing is legal
                        }
                        let ra = p.spans()[a].arena_range(1);
                        let rb = p.spans()[b].arena_range(1);
                        if ra.start < rb.end && rb.start < ra.end {
                            return Err(format!(
                                "`{a}` {ra:?} and `{b}` {rb:?} overlap while both live"
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn random_graphs_lower_and_match_naive() {
        check(
            "program_matches_naive",
            25,
            |r: &mut SplitMix64| (random_chain(r), r.next_u64()),
            |(spec, seed)| {
                let naive = NaiveInterp::new(spec.clone()).map_err(|e| e.to_string())?;
                let opts = CompileOptions { approx: false, ..CompileOptions::default() };
                let mut rng = SplitMix64::new(*seed);
                let n: usize = spec.input_shape.iter().product();
                let mut shape = vec![1usize];
                shape.extend_from_slice(&spec.input_shape);
                let x = Tensor::from_vec(&shape, rng.uniform_vec(n));
                let want = naive.infer(&x).map_err(|e| e.to_string())?;
                let got = run_program(spec, opts, &x);
                let d = want[0].max_abs_diff(&got[0]);
                if d < 1e-3 {
                    Ok(())
                } else {
                    Err(format!("max |Δ| = {d}"))
                }
            },
        );
    }
}
