//! Persistent compiled-artifact cache: serialize a lowered [`Program`] to a
//! versioned binary file and load it back by `mmap(2)`-ing the weight-panel
//! blob, so a warm process start skips §3.5 folding, §3.2 planning, §3.3
//! scheme selection and weight packing/quantization entirely — the
//! cold-start half of the paper's compile-once amortization argument.
//!
//! ## File layout
//!
//! All scalar fields are host-native byte order (an artifact is a *local*
//! cache entry, keyed by the host's CPU features — it is not a wire format).
//!
//! ```text
//! off  len  field
//!   0    8  magic  b"CNNPROG\0"
//!   8    4  format version (u32, currently 1)
//!  12    4  reserved (0)
//!  16    8  FNV-1a checksum over bytes[24..total_len]
//!  24    8  total_len (whole file, bytes)
//!  32    8  spec content hash (spec_content_hash)
//!  40    4  CPU feature bits at save time (bit0 avx2, bit1 avx512f)
//!  44    4  required_lanes — widest SIMD width any blocked kernel uses
//!  48   32  canonical CompileOptions bytes
//!  80    8  meta_len
//!  88    8  blob_off (64-byte aligned)
//!  96    8  blob_len
//! 104    …  meta: kernel/decision table (shapes, spans, small weights,
//!           the lowering report) — copied into owned memory at load
//! blob_off … 64-byte-aligned weight-panel blob: every packed conv/dense
//!           panel, each array 64-aligned — borrowed zero-copy out of the
//!           mapping by [`PanelStore`] at load
//! ```
//!
//! ## Invalidation
//!
//! The load path rejects (with a structured [`ArtifactError`], never a
//! panic): wrong magic, wrong format version, truncation, checksum
//! mismatch, and artifacts whose `required_lanes` exceed the lane ceiling
//! the *loading* host resolves for the stored options (an `Auto`-lowered
//! artifact from a wider machine; explicitly forced widths are portable by
//! construction and load anywhere). [`ProgramCache`] additionally compares
//! the spec content hash and the full `CompileOptions`, and silently
//! re-lowers (counting an invalidation) on any mismatch or load error.

use std::collections::BTreeMap;
use std::fmt;
use std::io;
use std::ops::Deref;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Instant;

use crate::compiler::program::{CompileOptions, Program};
use crate::cpu;
use crate::model::spec::ModelSpec;

/// Artifact file magic.
const MAGIC: &[u8; 8] = b"CNNPROG\0";
/// Current artifact format version. Bump on any layout change — older
/// files are rejected with [`ArtifactError::Version`] and re-lowered.
pub const FORMAT_VERSION: u32 = 1;
/// Header length in bytes (the fixed part before the meta table).
const HEADER_LEN: usize = 104;
/// Weight-panel blob alignment.
const BLOB_ALIGN: usize = 64;

// ------------------------------------------------------------------ errors

/// Structured reasons an artifact fails to load. Every variant is a clean
/// rejection: the caller (usually [`ProgramCache`]) falls back to
/// re-lowering; nothing panics and the mapped region is never interpreted
/// past a failed validation.
#[derive(Debug)]
pub enum ArtifactError {
    /// Filesystem error opening/reading/writing the artifact.
    Io(io::Error),
    /// The file does not start with the artifact magic.
    BadMagic,
    /// Format version mismatch.
    Version {
        /// Version stamped in the file.
        found: u32,
        /// Version this build reads.
        want: u32,
    },
    /// The file is shorter than its header claims.
    Truncated {
        /// Bytes the header (or fixed layout) requires.
        want: u64,
        /// Bytes actually present.
        have: u64,
    },
    /// The body checksum does not match the header.
    Checksum {
        /// Checksum stamped in the header.
        want: u64,
        /// Checksum of the bytes on disk.
        have: u64,
    },
    /// The artifact needs wider SIMD lanes than this host's ceiling for
    /// the stored options (an `Auto`-lowered artifact from a wider CPU).
    CpuMismatch {
        /// Widest lane width any blocked kernel in the artifact uses.
        required_lanes: u32,
        /// The lane ceiling the loading host resolves.
        ceiling: u32,
    },
    /// Structurally invalid meta/blob contents (bad tag, range out of
    /// bounds, unknown label, misaligned panel, …).
    Corrupt(String),
}

impl fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ArtifactError::Io(e) => write!(f, "artifact io: {e}"),
            ArtifactError::BadMagic => write!(f, "not a compiled-nn artifact (bad magic)"),
            ArtifactError::Version { found, want } => {
                write!(f, "artifact format v{found}, this build reads v{want}")
            }
            ArtifactError::Truncated { want, have } => {
                write!(f, "artifact truncated: need {want} bytes, have {have}")
            }
            ArtifactError::Checksum { want, have } => {
                write!(f, "artifact checksum mismatch: header {want:#018x}, body {have:#018x}")
            }
            ArtifactError::CpuMismatch { required_lanes, ceiling } => write!(
                f,
                "artifact needs {required_lanes}-lane kernels but this host's ceiling is \
                 {ceiling} for the stored options"
            ),
            ArtifactError::Corrupt(why) => write!(f, "artifact corrupt: {why}"),
        }
    }
}

impl std::error::Error for ArtifactError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ArtifactError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<io::Error> for ArtifactError {
    fn from(e: io::Error) -> Self {
        ArtifactError::Io(e)
    }
}

/// Shorthand for a [`ArtifactError::Corrupt`] failure.
pub(crate) fn corrupt(why: impl Into<String>) -> ArtifactError {
    ArtifactError::Corrupt(why.into())
}

// ------------------------------------------------------------------ hashing

/// Incremental FNV-1a 64 (the repo's offline-build hash of choice).
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    fn write_u64(&mut self, v: u64) {
        self.write(&v.to_ne_bytes());
    }

    /// Length-prefixed string, so `("ab","c")` ≠ `("a","bc")`.
    fn write_str(&mut self, s: &str) {
        self.write_u64(s.len() as u64);
        self.write(s.as_bytes());
    }

    fn finish(&self) -> u64 {
        self.0
    }
}

fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = Fnv::new();
    h.write(bytes);
    h.finish()
}

/// Content hash of a model spec: graph structure, hyper-parameters and the
/// raw f32 weight bits. Two specs hash equal iff lowering them under equal
/// options yields interchangeable programs — the spec half of the cache key.
pub fn spec_content_hash(spec: &ModelSpec) -> u64 {
    let mut h = Fnv::new();
    h.write_str(&spec.name);
    h.write_u64(spec.input_shape.len() as u64);
    for &d in &spec.input_shape {
        h.write_u64(d as u64);
    }
    h.write_u64(spec.seed);
    h.write_u64(spec.layers.len() as u64);
    for l in &spec.layers {
        h.write_str(&l.name);
        // the op's hyper-parameters via its canonical Debug form
        h.write_str(&format!("{:?}", l.op));
        h.write_u64(l.inputs.len() as u64);
        for i in &l.inputs {
            h.write_str(i);
        }
        h.write_u64(l.weights.len() as u64);
        for (k, w) in &l.weights {
            h.write_str(k);
            h.write_u64(w.offset as u64);
            h.write_u64(w.shape.len() as u64);
            for &d in &w.shape {
                h.write_u64(d as u64);
            }
        }
        h.write_str(l.activation.name());
        h.write_u64(l.post_scale as u64);
    }
    h.write_u64(spec.outputs.len() as u64);
    for o in &spec.outputs {
        h.write_str(o);
    }
    h.write_u64(spec.weights.len() as u64);
    for &w in &spec.weights {
        h.write(&w.to_bits().to_ne_bytes());
    }
    h.finish()
}

/// The host CPU feature bits stamped into artifact headers (diagnostic —
/// validity is decided by the lane-width check, not by exact feature
/// equality, because every lane width is a portable instantiation).
pub fn feature_bits() -> u32 {
    let f = cpu::Features::detect();
    (f.avx2 as u32) | ((f.avx512f as u32) << 1)
}

// ------------------------------------------------------------------ mapping

/// A read-only view of an artifact file: `mmap(2)` on unix (libc declared
/// by hand, in the spirit of `coordinator/poll.rs`), a heap read on other
/// targets or when the map fails. [`PanelStore::Mapped`] slices borrow
/// straight out of this, which is what makes artifact loads zero-copy for
/// the weight panels.
pub struct Mapping {
    ptr: *const u8,
    len: usize,
    backing: Backing,
}

enum Backing {
    /// A live `mmap` region; unmapped on drop.
    #[cfg(unix)]
    Mmap,
    /// Heap fallback: the file copied into an 8-byte-aligned buffer
    /// (`ptr` points into it; a `Vec`'s heap allocation never moves).
    Heap(#[allow(dead_code)] Vec<u64>),
}

// SAFETY: the region is read-only for the mapping's whole lifetime (mapped
// PROT_READ/MAP_PRIVATE, or a heap buffer nothing mutates after open).
unsafe impl Send for Mapping {}
unsafe impl Sync for Mapping {}

impl Mapping {
    /// Map `path` read-only (heap fallback on non-unix or mmap failure).
    pub fn open(path: &Path) -> io::Result<Mapping> {
        let mut file = std::fs::File::open(path)?;
        let len = file.metadata()?.len();
        if len > usize::MAX as u64 {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "artifact too large"));
        }
        let len = len as usize;
        #[cfg(unix)]
        if len > 0 {
            if let Some(ptr) = sys::map_readonly(&file, len) {
                return Ok(Mapping { ptr, len, backing: Backing::Mmap });
            }
        }
        // Fallback: copy into a u64 buffer so the base stays 8-byte
        // aligned (enough for every panel element type).
        let mut buf: Vec<u64> = vec![0; len.div_ceil(8)];
        {
            use std::io::Read;
            // SAFETY: the u64 buffer owns at least `len` initialized bytes.
            let bytes =
                unsafe { std::slice::from_raw_parts_mut(buf.as_mut_ptr() as *mut u8, len) };
            file.read_exact(bytes)?;
        }
        let ptr = buf.as_ptr() as *const u8;
        Ok(Mapping { ptr, len, backing: Backing::Heap(buf) })
    }

    /// Mapped length in bytes.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True for a zero-length mapping.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The whole mapped file as a byte slice.
    pub fn bytes(&self) -> &[u8] {
        // SAFETY: `ptr` covers `len` readable bytes for `self`'s lifetime.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

impl Drop for Mapping {
    fn drop(&mut self) {
        match self.backing {
            #[cfg(unix)]
            Backing::Mmap => sys::unmap(self.ptr, self.len),
            Backing::Heap(_) => {}
        }
    }
}

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_void};
    use std::os::unix::io::AsRawFd;

    const PROT_READ: c_int = 0x1;
    const MAP_PRIVATE: c_int = 0x2;

    #[cfg(target_os = "linux")]
    type Off = i64;
    #[cfg(not(target_os = "linux"))]
    type Off = i64; // off_t is 64-bit on every modern unix this builds on

    extern "C" {
        fn mmap(
            addr: *mut c_void,
            length: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: Off,
        ) -> *mut c_void;
        fn munmap(addr: *mut c_void, length: usize) -> c_int;
    }

    /// `mmap` the first `len` bytes of `file` read-only; `None` on failure
    /// (the caller falls back to a heap read).
    pub fn map_readonly(file: &std::fs::File, len: usize) -> Option<*const u8> {
        // SAFETY: len > 0 (checked by caller), fd is a live open file; a
        // PROT_READ/MAP_PRIVATE mapping has no aliasing hazards.
        let p = unsafe {
            mmap(std::ptr::null_mut(), len, PROT_READ, MAP_PRIVATE, file.as_raw_fd(), 0)
        };
        if p as usize == usize::MAX {
            None // MAP_FAILED
        } else {
            Some(p as *const u8)
        }
    }

    /// Unmap a region obtained from [`map_readonly`].
    pub fn unmap(ptr: *const u8, len: usize) {
        // SAFETY: (ptr, len) is exactly what mmap returned; errors are
        // unobservable at drop time and the region is gone either way.
        unsafe {
            let _ = munmap(ptr as *mut c_void, len);
        }
    }
}

// --------------------------------------------------------------- PanelStore

/// Backing storage for packed weight panels: either an owned `Vec` (fresh
/// lowering) or a zero-copy window into a mapped artifact. Kernels only
/// ever see `&[T]` through `Deref`, so the hot path is identical either
/// way; cloning a mapped store is an `Arc` bump, not a copy.
pub enum PanelStore<T: Copy + 'static> {
    /// Panels owned in heap memory (the fresh-lowering path).
    Owned(Vec<T>),
    /// Panels borrowed out of a mapped artifact file.
    Mapped {
        /// The artifact mapping the slice lives in (keeps it alive).
        map: Arc<Mapping>,
        /// Byte offset of the first element within the mapping.
        byte_off: usize,
        /// Element count.
        len: usize,
    },
}

impl<T: Copy + 'static> PanelStore<T> {
    /// A validated zero-copy window: bounds and element alignment are
    /// checked here, once, so `Deref` can be unconditional.
    pub(crate) fn mapped(
        map: Arc<Mapping>,
        byte_off: usize,
        len: usize,
    ) -> Result<PanelStore<T>, ArtifactError> {
        let size = std::mem::size_of::<T>();
        let byte_len =
            len.checked_mul(size).ok_or_else(|| corrupt("panel length overflows"))?;
        let end = byte_off
            .checked_add(byte_len)
            .ok_or_else(|| corrupt("panel offset overflows"))?;
        if end > map.len() {
            return Err(corrupt(format!(
                "panel [{byte_off}, {end}) exceeds mapping of {} bytes",
                map.len()
            )));
        }
        if (map.ptr as usize + byte_off) % std::mem::align_of::<T>() != 0 {
            return Err(corrupt("panel misaligned for its element type"));
        }
        Ok(PanelStore::Mapped { map, byte_off, len })
    }
}

impl<T: Copy + 'static> Deref for PanelStore<T> {
    type Target = [T];

    fn deref(&self) -> &[T] {
        match self {
            PanelStore::Owned(v) => v,
            PanelStore::Mapped { map, byte_off, len } => {
                // SAFETY: bounds and alignment validated at construction;
                // the mapping is read-only and outlives the slice via Arc;
                // panel element types (f32/u16/i8) accept any bit pattern.
                unsafe {
                    std::slice::from_raw_parts(map.ptr.add(*byte_off) as *const T, *len)
                }
            }
        }
    }
}

impl<T: Copy + 'static> Clone for PanelStore<T> {
    fn clone(&self) -> Self {
        match self {
            PanelStore::Owned(v) => PanelStore::Owned(v.clone()),
            PanelStore::Mapped { map, byte_off, len } => {
                PanelStore::Mapped { map: map.clone(), byte_off: *byte_off, len: *len }
            }
        }
    }
}

impl<T: Copy + 'static> From<Vec<T>> for PanelStore<T> {
    fn from(v: Vec<T>) -> Self {
        PanelStore::Owned(v)
    }
}

// ------------------------------------------------------------------ encoder

/// Byte-sink the serializer writes into: `meta` holds the kernel/decision
/// table (small, copied at load), `blob` holds the 64-byte-aligned weight
/// panels (mapped zero-copy at load). All multi-byte values host-native.
#[derive(Default)]
pub struct Encoder {
    pub(crate) meta: Vec<u8>,
    pub(crate) blob: Vec<u8>,
}

impl Encoder {
    pub(crate) fn new() -> Encoder {
        Encoder::default()
    }

    pub(crate) fn u8(&mut self, v: u8) {
        self.meta.push(v);
    }

    pub(crate) fn bool(&mut self, v: bool) {
        self.meta.push(v as u8);
    }

    pub(crate) fn u32(&mut self, v: u32) {
        self.meta.extend_from_slice(&v.to_ne_bytes());
    }

    pub(crate) fn u64(&mut self, v: u64) {
        self.meta.extend_from_slice(&v.to_ne_bytes());
    }

    pub(crate) fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    pub(crate) fn f64(&mut self, v: f64) {
        self.meta.extend_from_slice(&v.to_ne_bytes());
    }

    pub(crate) fn str(&mut self, s: &str) {
        self.usize(s.len());
        self.meta.extend_from_slice(s.as_bytes());
    }

    /// A small f32 vector stored inline in the meta table (biases, BN
    /// scale/shift, dense tails — everything that is not a packed panel).
    pub(crate) fn vec_f32(&mut self, v: &[f32]) {
        self.usize(v.len());
        // SAFETY: an f32 slice is plain bytes; format is host-native.
        let bytes =
            unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) };
        self.meta.extend_from_slice(bytes);
    }

    pub(crate) fn opt_vec_f32(&mut self, v: Option<&[f32]>) {
        match v {
            None => self.bool(false),
            Some(v) => {
                self.bool(true);
                self.vec_f32(v);
            }
        }
    }

    pub(crate) fn vec_usize(&mut self, v: &[usize]) {
        self.usize(v.len());
        for &x in v {
            self.usize(x);
        }
    }

    /// Append a panel array to the 64-byte-aligned blob and record its
    /// (offset, element count) in the meta table.
    pub(crate) fn blob_of<T: Copy>(&mut self, data: &[T]) {
        while self.blob.len() % BLOB_ALIGN != 0 {
            self.blob.push(0);
        }
        let off = self.blob.len();
        let size = std::mem::size_of_val(data);
        // SAFETY: panel element types are plain bytes; host-native format.
        let bytes = unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, size) };
        self.blob.extend_from_slice(bytes);
        self.usize(off);
        self.usize(data.len());
    }
}

// ------------------------------------------------------------------ decoder

/// Bounds-checked reader over a mapped artifact: a cursor through the meta
/// window plus the blob window panels are handed out of. Every read that
/// would cross a boundary returns [`ArtifactError::Corrupt`] — the decoder
/// never trusts a length field it has not ranged-checked.
pub struct Decoder {
    map: Arc<Mapping>,
    pos: usize,
    meta_end: usize,
    blob_start: usize,
    blob_len: usize,
}

impl Decoder {
    fn take(&mut self, n: usize) -> Result<&[u8], ArtifactError> {
        let end = self.pos.checked_add(n).ok_or_else(|| corrupt("meta cursor overflow"))?;
        if end > self.meta_end {
            return Err(corrupt("meta table truncated"));
        }
        let s = &self.map.bytes()[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8, ArtifactError> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn bool(&mut self) -> Result<bool, ArtifactError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => Err(corrupt(format!("invalid bool byte {b}"))),
        }
    }

    pub(crate) fn u32(&mut self) -> Result<u32, ArtifactError> {
        let b = self.take(4)?;
        Ok(u32::from_ne_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub(crate) fn u64(&mut self) -> Result<u64, ArtifactError> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_ne_bytes(a))
    }

    pub(crate) fn usize(&mut self) -> Result<usize, ArtifactError> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| corrupt("length exceeds usize"))
    }

    pub(crate) fn f64(&mut self) -> Result<f64, ArtifactError> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub(crate) fn string(&mut self) -> Result<String, ArtifactError> {
        let n = self.usize()?;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec()).map_err(|_| corrupt("non-utf8 string"))
    }

    pub(crate) fn vec_f32(&mut self) -> Result<Vec<f32>, ArtifactError> {
        let n = self.usize()?;
        let nbytes = n.checked_mul(4).ok_or_else(|| corrupt("f32 vec overflow"))?;
        let b = self.take(nbytes)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_ne_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub(crate) fn opt_vec_f32(&mut self) -> Result<Option<Vec<f32>>, ArtifactError> {
        if self.bool()? {
            Ok(Some(self.vec_f32()?))
        } else {
            Ok(None)
        }
    }

    pub(crate) fn vec_usize(&mut self) -> Result<Vec<usize>, ArtifactError> {
        let n = self.usize()?;
        if n > self.meta_end.saturating_sub(self.pos) / 8 {
            return Err(corrupt("usize vec longer than remaining meta"));
        }
        (0..n).map(|_| self.usize()).collect()
    }

    /// The zero-copy counterpart of [`Encoder::blob_of`]: read an (offset,
    /// element count) pair and hand back a validated window into the blob.
    pub(crate) fn blob_store<T: Copy + 'static>(
        &mut self,
    ) -> Result<PanelStore<T>, ArtifactError> {
        let off = self.usize()?;
        let len = self.usize()?;
        let byte_len = len
            .checked_mul(std::mem::size_of::<T>())
            .ok_or_else(|| corrupt("blob window overflows"))?;
        let end = off.checked_add(byte_len).ok_or_else(|| corrupt("blob window overflows"))?;
        if end > self.blob_len {
            return Err(corrupt(format!(
                "blob window [{off}, {end}) exceeds blob of {} bytes",
                self.blob_len
            )));
        }
        PanelStore::mapped(self.map.clone(), self.blob_start + off, len)
    }
}

// ------------------------------------------------------------- save / load

fn align_up(n: usize, a: usize) -> usize {
    n.div_ceil(a) * a
}

/// Everything the artifact header records about a saved program — returned
/// by [`load_program`] for cache validation and `compiled-nn inspect`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArtifactInfo {
    /// Format version stamped in the file.
    pub version: u32,
    /// [`spec_content_hash`] of the spec the program was lowered from.
    pub spec_hash: u64,
    /// CPU feature bits of the machine that saved the artifact.
    pub features: u32,
    /// Widest SIMD lane width any blocked kernel in the program uses.
    pub required_lanes: u32,
    /// The `CompileOptions` the program was lowered under.
    pub options: CompileOptions,
    /// Meta-table bytes (kernel/decision table).
    pub meta_bytes: u64,
    /// Weight-panel blob bytes (the zero-copy region).
    pub blob_bytes: u64,
    /// Whole-file length in bytes.
    pub total_bytes: u64,
}

/// Serialize a lowered program to `path` (atomic: written to a sibling
/// temp file, then renamed into place). `spec_hash` must be the
/// [`spec_content_hash`] of the spec `program` was lowered from and `opts`
/// the options it was lowered under — both land in the header and gate
/// future loads.
pub fn save_program(
    program: &Program,
    spec_hash: u64,
    opts: CompileOptions,
    path: &Path,
) -> Result<(), ArtifactError> {
    let mut e = Encoder::new();
    program.encode_body(&mut e);
    let Encoder { meta, blob } = e;
    let blob_off = align_up(HEADER_LEN + meta.len(), BLOB_ALIGN);
    let total = blob_off + blob.len();

    let mut out = Vec::with_capacity(total);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&FORMAT_VERSION.to_ne_bytes());
    out.extend_from_slice(&0u32.to_ne_bytes()); // reserved
    out.extend_from_slice(&0u64.to_ne_bytes()); // checksum, stamped below
    out.extend_from_slice(&(total as u64).to_ne_bytes());
    out.extend_from_slice(&spec_hash.to_ne_bytes());
    out.extend_from_slice(&feature_bits().to_ne_bytes());
    out.extend_from_slice(&(program.summary().lane_width as u32).to_ne_bytes());
    out.extend_from_slice(&opts.canonical_bytes());
    out.extend_from_slice(&(meta.len() as u64).to_ne_bytes());
    out.extend_from_slice(&(blob_off as u64).to_ne_bytes());
    out.extend_from_slice(&(blob.len() as u64).to_ne_bytes());
    debug_assert_eq!(out.len(), HEADER_LEN);
    out.extend_from_slice(&meta);
    out.resize(blob_off, 0);
    out.extend_from_slice(&blob);

    let sum = fnv64(&out[24..]);
    out[16..24].copy_from_slice(&sum.to_ne_bytes());

    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    std::fs::write(&tmp, &out)?;
    if let Err(e) = std::fs::rename(&tmp, path) {
        let _ = std::fs::remove_file(&tmp);
        return Err(ArtifactError::Io(e));
    }
    Ok(())
}

/// Load a program from an artifact file: validate the header (magic,
/// version, length, checksum, CPU lane ceiling), then decode the kernel
/// table with weight panels borrowed zero-copy out of the mapping. The
/// loaded program's `compile_ms` is the load wall time — the number the
/// cold-start bench compares against a fresh lowering.
pub fn load_program(path: &Path) -> Result<(Program, ArtifactInfo), ArtifactError> {
    let t0 = Instant::now();
    let map = Arc::new(Mapping::open(path)?);
    let b = map.bytes();
    if b.len() < HEADER_LEN {
        return Err(ArtifactError::Truncated { want: HEADER_LEN as u64, have: b.len() as u64 });
    }
    if &b[0..8] != MAGIC {
        return Err(ArtifactError::BadMagic);
    }
    let rd_u32 = |off: usize| u32::from_ne_bytes(b[off..off + 4].try_into().expect("4 bytes"));
    let rd_u64 = |off: usize| u64::from_ne_bytes(b[off..off + 8].try_into().expect("8 bytes"));
    let version = rd_u32(8);
    if version != FORMAT_VERSION {
        return Err(ArtifactError::Version { found: version, want: FORMAT_VERSION });
    }
    let total = rd_u64(24);
    if total > b.len() as u64 {
        return Err(ArtifactError::Truncated { want: total, have: b.len() as u64 });
    }
    if total < HEADER_LEN as u64 {
        return Err(corrupt("total_len shorter than the header"));
    }
    let want_sum = rd_u64(16);
    let have_sum = fnv64(&b[24..total as usize]);
    if want_sum != have_sum {
        return Err(ArtifactError::Checksum { want: want_sum, have: have_sum });
    }
    let spec_hash = rd_u64(32);
    let features = rd_u32(40);
    let required_lanes = rd_u32(44);
    let mut opt_bytes = [0u8; 32];
    opt_bytes.copy_from_slice(&b[48..80]);
    let options = CompileOptions::from_canonical_bytes(&opt_bytes)
        .ok_or_else(|| corrupt("invalid CompileOptions encoding"))?;
    // The lane ceiling is evaluated on the *loading* host: explicitly
    // forced widths are portable (kernels are generic instantiations) and
    // always pass; Auto-lowered artifacts from a wider machine fail here.
    let ceiling = options.max_lanes() as u32;
    if required_lanes > ceiling {
        return Err(ArtifactError::CpuMismatch { required_lanes, ceiling });
    }
    let meta_len = rd_u64(80) as usize;
    let blob_off = rd_u64(88) as usize;
    let blob_len = rd_u64(96) as usize;
    let meta_end = HEADER_LEN
        .checked_add(meta_len)
        .ok_or_else(|| corrupt("meta length overflows"))?;
    let blob_end = blob_off.checked_add(blob_len).ok_or_else(|| corrupt("blob overflows"))?;
    if meta_end > blob_off || blob_off % BLOB_ALIGN != 0 || blob_end as u64 != total {
        return Err(corrupt("meta/blob windows inconsistent with total_len"));
    }
    let mut d = Decoder {
        map: map.clone(),
        pos: HEADER_LEN,
        meta_end,
        blob_start: blob_off,
        blob_len,
    };
    let mut program = Program::decode_body(&mut d)?;
    program.set_compile_ms(t0.elapsed().as_secs_f64() * 1e3);
    let info = ArtifactInfo {
        version,
        spec_hash,
        features,
        required_lanes,
        options,
        meta_bytes: meta_len as u64,
        blob_bytes: blob_len as u64,
        total_bytes: total,
    };
    Ok((program, info))
}

// -------------------------------------------------------------------- cache

/// Cache hit/miss/invalidation counts, as surfaced in `ModelMetrics` and
/// `compiled-nn explain`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheCounters {
    /// Lowerings skipped because a valid artifact loaded.
    pub hits: u64,
    /// Lowerings that ran because no artifact existed yet.
    pub misses: u64,
    /// Artifacts rejected (version/feature/hash/options mismatch or any
    /// load error) and silently replaced by a re-lowering.
    pub invalidated: u64,
}

/// The on-disk program cache: keyed by (spec content hash, canonical
/// `CompileOptions`, lane ceiling, CPU feature bits), one artifact file per
/// key, atomic tmp+rename writes, silent re-lower on any mismatch. With no
/// directory configured every call is a plain [`Program::lower`] and no
/// counter moves.
pub struct ProgramCache {
    dir: Option<PathBuf>,
    hits: AtomicU64,
    misses: AtomicU64,
    invalidated: AtomicU64,
}

static GLOBAL_CACHE: OnceLock<ProgramCache> = OnceLock::new();

impl ProgramCache {
    /// A cache with no backing directory: `lower_or_load` always lowers.
    pub fn disabled() -> ProgramCache {
        ProgramCache {
            dir: None,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            invalidated: AtomicU64::new(0),
        }
    }

    /// A cache over `dir` (created on first save).
    pub fn with_dir(dir: PathBuf) -> ProgramCache {
        ProgramCache { dir: Some(dir), ..ProgramCache::disabled() }
    }

    /// The process-wide cache, configured from `COMPILED_NN_CACHE_DIR` at
    /// first use (the `cache_dir` serving-config key exports that same
    /// variable before the coordinator starts). Unset/empty → disabled.
    pub fn global() -> &'static ProgramCache {
        GLOBAL_CACHE.get_or_init(|| match std::env::var("COMPILED_NN_CACHE_DIR") {
            Ok(d) if !d.is_empty() => ProgramCache::with_dir(PathBuf::from(d)),
            _ => ProgramCache::disabled(),
        })
    }

    /// The backing directory, if caching is enabled.
    pub fn dir(&self) -> Option<&Path> {
        self.dir.as_deref()
    }

    /// Snapshot of the hit/miss/invalidation counters.
    pub fn counters(&self) -> CacheCounters {
        CacheCounters {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            invalidated: self.invalidated.load(Ordering::Relaxed),
        }
    }

    /// The artifact path for a (spec, options) pair under this cache's key
    /// scheme, or `None` when caching is disabled.
    pub fn key_path(&self, spec: &ModelSpec, opts: CompileOptions) -> Option<PathBuf> {
        let dir = self.dir.as_ref()?;
        let mut h = Fnv::new();
        h.write_u64(spec_content_hash(spec));
        h.write(&opts.canonical_bytes());
        h.write_u64(opts.max_lanes() as u64);
        h.write_u64(feature_bits() as u64);
        let key = h.finish();
        // model name kept for debuggability; key carries the semantics
        let name: String = spec
            .name
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
            .take(48)
            .collect();
        Some(dir.join(format!("{name}-{key:016x}.cnnprog")))
    }

    /// Load the cached artifact for (spec, opts) if a valid one exists,
    /// else lower and (best-effort) persist the result. This is the hook
    /// `OptInterp::new` sits on, so the coordinator's register and
    /// hot-swap paths consult the cache without knowing it exists.
    pub fn lower_or_load(
        &self,
        spec: &ModelSpec,
        opts: CompileOptions,
    ) -> anyhow::Result<Program> {
        let Some(path) = self.key_path(spec, opts) else {
            return Program::lower(spec, opts);
        };
        let spec_hash = spec_content_hash(spec);
        if path.exists() {
            match load_program(&path) {
                Ok((program, info))
                    if info.spec_hash == spec_hash && info.options == opts =>
                {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return Ok(program);
                }
                // key collision / stale header / any structured load error:
                // drop the entry and fall through to a fresh lowering
                Ok(_) | Err(_) => {
                    self.invalidated.fetch_add(1, Ordering::Relaxed);
                    let _ = std::fs::remove_file(&path);
                }
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let program = Program::lower(spec, opts)?;
        // persistence is best-effort: a read-only cache dir must never
        // fail an inference path
        let _ = save_program(&program, spec_hash, opts, &path);
        Ok(program)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::program::{LaneSelect, TuneMode};
    use crate::model::builder::{tiny_cnn, wide_cnn};
    use crate::nn::simd::WeightDtype;
    use crate::nn::tensor::Tensor;
    use crate::util::rng::SplitMix64;

    fn tmp_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "compiled-nn-artifact-test-{}-{tag}.cnnprog",
            std::process::id()
        ))
    }

    fn save_tiny(tag: &str, opts: CompileOptions) -> (PathBuf, Program, u64) {
        let spec = tiny_cnn(41);
        let program = Program::lower(&spec, opts).unwrap();
        let hash = spec_content_hash(&spec);
        let path = tmp_path(tag);
        save_program(&program, hash, opts, &path).unwrap();
        (path, program, hash)
    }

    fn infer_once(p: &Program, seed: u64) -> Vec<Tensor> {
        let mut rng = SplitMix64::new(seed);
        let x = Tensor::from_vec(&[2, 8, 8, 3], rng.uniform_vec(2 * 8 * 8 * 3));
        let mut pool = crate::compiler::program::ArenaPool::new();
        p.infer_pooled(&x, &mut pool).unwrap()
    }

    /// Re-stamp the body checksum after a test patches header-covered
    /// bytes, so the patched field (not the checksum) is what rejects.
    fn restamp(bytes: &mut [u8]) {
        let sum = fnv64(&bytes[24..]);
        bytes[16..24].copy_from_slice(&sum.to_ne_bytes());
    }

    #[test]
    fn round_trip_is_bitwise_identical() {
        let opts = CompileOptions::default();
        let (path, fresh, hash) = save_tiny("roundtrip", opts);
        let (loaded, info) = load_program(&path).unwrap();
        assert_eq!(info.spec_hash, hash);
        assert_eq!(info.options, opts);
        assert_eq!(info.version, FORMAT_VERSION);
        let a = infer_once(&fresh, 7);
        let b = infer_once(&loaded, 7);
        assert_eq!(a[0].data(), b[0].data(), "artifact-loaded program must be bit-identical");
        assert_eq!(fresh.summary().lane_width, loaded.summary().lane_width);
        assert_eq!(
            fresh.summary().report.decisions.len(),
            loaded.summary().report.decisions.len()
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn quantized_round_trip_is_bitwise_identical() {
        let opts =
            CompileOptions { weight_dtype: WeightDtype::I8, ..CompileOptions::default() };
        let (path, fresh, _) = save_tiny("roundtrip-i8", opts);
        let (loaded, info) = load_program(&path).unwrap();
        assert_eq!(info.options.weight_dtype, WeightDtype::I8);
        assert!(loaded.summary().quantized_layers > 0);
        let a = infer_once(&fresh, 9);
        let b = infer_once(&loaded, 9);
        assert_eq!(a[0].data(), b[0].data());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn truncated_artifact_is_rejected_cleanly() {
        let (path, _, _) = save_tiny("trunc", CompileOptions::default());
        let bytes = std::fs::read(&path).unwrap();
        for keep in [0usize, 10, HEADER_LEN - 1, HEADER_LEN + 5, bytes.len() - 1] {
            std::fs::write(&path, &bytes[..keep]).unwrap();
            let err = load_program(&path).unwrap_err();
            assert!(
                matches!(
                    err,
                    ArtifactError::Truncated { .. }
                        | ArtifactError::BadMagic
                        | ArtifactError::Checksum { .. }
                        | ArtifactError::Corrupt(_)
                ),
                "keep={keep}: {err}"
            );
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn bit_flips_fail_the_checksum() {
        let (path, _, _) = save_tiny("flip", CompileOptions::default());
        let bytes = std::fs::read(&path).unwrap();
        // flip one bit in the meta table and one deep in the blob
        for off in [HEADER_LEN + 3, bytes.len() - 5] {
            let mut bad = bytes.clone();
            bad[off] ^= 0x40;
            std::fs::write(&path, &bad).unwrap();
            let err = load_program(&path).unwrap_err();
            assert!(matches!(err, ArtifactError::Checksum { .. }), "off={off}: {err}");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn wrong_version_and_magic_are_structured_errors() {
        let (path, _, _) = save_tiny("ver", CompileOptions::default());
        let bytes = std::fs::read(&path).unwrap();

        let mut bad = bytes.clone();
        bad[8] = 99; // version is outside the checksum window
        std::fs::write(&path, &bad).unwrap();
        assert!(matches!(
            load_program(&path).unwrap_err(),
            ArtifactError::Version { found: 99, want: FORMAT_VERSION }
        ));

        let mut bad = bytes.clone();
        bad[0] = b'X';
        std::fs::write(&path, &bad).unwrap();
        assert!(matches!(load_program(&path).unwrap_err(), ArtifactError::BadMagic));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn wider_lane_requirement_is_a_cpu_mismatch() {
        // An Auto-lowered artifact claiming 64-lane kernels can never pass
        // any host's ceiling; the header field is checksummed, so restamp.
        let (path, _, _) = save_tiny("cpu", CompileOptions::default());
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[44..48].copy_from_slice(&64u32.to_ne_bytes());
        restamp(&mut bytes);
        std::fs::write(&path, &bytes).unwrap();
        assert!(matches!(
            load_program(&path).unwrap_err(),
            ArtifactError::CpuMismatch { required_lanes: 64, .. }
        ));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn forced_width_artifacts_load_under_any_ceiling() {
        // lanes pinned to W8: the ceiling is 8 on every host (portable
        // kernels), so the artifact loads regardless of detected features
        let opts = CompileOptions { lanes: LaneSelect::W8, ..CompileOptions::default() };
        let (path, fresh, _) = save_tiny("w8", opts);
        let (loaded, info) = load_program(&path).unwrap();
        assert!(info.required_lanes <= 8);
        assert_eq!(infer_once(&fresh, 3)[0].data(), infer_once(&loaded, 3)[0].data());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn cache_hits_misses_and_invalidations_are_counted() {
        let dir = std::env::temp_dir()
            .join(format!("compiled-nn-cache-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = ProgramCache::with_dir(dir.clone());
        let spec = tiny_cnn(42);
        let opts = CompileOptions::default();

        let before = crate::compiler::program::lower_count();
        let p1 = cache.lower_or_load(&spec, opts).unwrap();
        assert_eq!(cache.counters(), CacheCounters { hits: 0, misses: 1, invalidated: 0 });
        assert_eq!(crate::compiler::program::lower_count(), before + 1);

        // second build: artifact load, zero additional lowerings
        let p2 = cache.lower_or_load(&spec, opts).unwrap();
        assert_eq!(cache.counters().hits, 1);
        assert_eq!(crate::compiler::program::lower_count(), before + 1);
        assert_eq!(infer_once(&p1, 5)[0].data(), infer_once(&p2, 5)[0].data());

        // different options → a different key, not an invalidation
        let other =
            CompileOptions { weight_dtype: WeightDtype::Bf16, ..CompileOptions::default() };
        cache.lower_or_load(&spec, other).unwrap();
        assert_eq!(cache.counters(), CacheCounters { hits: 1, misses: 2, invalidated: 0 });

        // corrupt the entry on disk: silent re-lower + invalidation count
        let path = cache.key_path(&spec, opts).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let p3 = cache.lower_or_load(&spec, opts).unwrap();
        let c = cache.counters();
        assert_eq!((c.hits, c.misses, c.invalidated), (1, 3, 1));
        assert_eq!(infer_once(&p1, 5)[0].data(), infer_once(&p3, 5)[0].data());

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn disabled_cache_lowers_without_touching_counters() {
        let cache = ProgramCache::disabled();
        let spec = tiny_cnn(43);
        cache.lower_or_load(&spec, CompileOptions::default()).unwrap();
        assert_eq!(cache.counters(), CacheCounters::default());
        assert!(cache.key_path(&spec, CompileOptions::default()).is_none());
    }

    #[test]
    fn spec_hash_tracks_weights_and_structure() {
        let a = tiny_cnn(44);
        let b = tiny_cnn(44);
        assert_eq!(spec_content_hash(&a), spec_content_hash(&b));
        let c = tiny_cnn(45); // different seed → different weights
        assert_ne!(spec_content_hash(&a), spec_content_hash(&c));
        let w = wide_cnn(44);
        assert_ne!(spec_content_hash(&a), spec_content_hash(&w));
        let mut d = tiny_cnn(44);
        d.weights[0] += 1.0;
        assert_ne!(spec_content_hash(&a), spec_content_hash(&d));
    }

    #[test]
    fn canonical_options_round_trip() {
        for opts in [
            CompileOptions::default(),
            CompileOptions::bit_exact(),
            CompileOptions {
                weight_dtype: WeightDtype::I8,
                lanes: LaneSelect::W8,
                intra_threads: 4,
                batch_hint: 8,
                tune: TuneMode::Measured { reps: 17 },
                ..CompileOptions::default()
            },
        ] {
            let bytes = opts.canonical_bytes();
            assert_eq!(CompileOptions::from_canonical_bytes(&bytes), Some(opts));
        }
        // garbage discriminants decode to None, not a panic
        let mut bad = CompileOptions::default().canonical_bytes();
        bad[3] = 200;
        assert_eq!(CompileOptions::from_canonical_bytes(&bad), None);
    }

    #[test]
    fn measured_tuning_round_trips_and_reports() {
        let spec = wide_cnn(46);
        let opts = CompileOptions {
            tune: TuneMode::Measured { reps: 3 },
            ..CompileOptions::default()
        };
        let program = Program::lower(&spec, opts).unwrap();
        let decisions = &program.summary().report.decisions;
        assert!(
            decisions.iter().any(|d| d.measured_cycles.is_some()),
            "measured tuning must record wall times"
        );
        let path = tmp_path("measured");
        save_program(&program, spec_content_hash(&spec), opts, &path).unwrap();
        let (loaded, info) = load_program(&path).unwrap();
        assert_eq!(info.options.tune, TuneMode::Measured { reps: 3 });
        let ld = &loaded.summary().report.decisions;
        assert_eq!(decisions.len(), ld.len());
        for (a, b) in decisions.iter().zip(ld) {
            assert_eq!(a.measured_cycles.is_some(), b.measured_cycles.is_some());
            assert_eq!(a.overturned, b.overturned);
            assert_eq!(a.chosen, b.chosen);
        }
        let _ = std::fs::remove_file(&path);
    }
}
