//! §3.3 cost model: per-unit instruction/register estimates for the
//! generated code, using the paper's batching rule — values are grouped in
//! batches of `4 · (n_xmm − k)` elements, where k is the number of registers
//! reserved for weights/temporaries (k = 2 for the Eq. 3 rotated-diagonal
//! scheme, k = 3 for the Eq. 2 broadcast scheme).
//!
//! This is a *static* model (it needs no input), used by `compiled-nn
//! inspect` and by DESIGN.md's §Perf estimates; EXPERIMENTS.md compares its
//! predictions with the measured Eq. 2/Eq. 3 bench.
//!
//! Since PR 6 it is also the model that *drives lowering*: the
//! [`conv_candidates`] / [`dense_candidates`] estimators price every legal
//! kernel scheme for a layer in predicted Silvermont cycles (per-MAC
//! constants derived from [`super::silvermont`]'s instruction tables), and
//! `Program::lower` picks the argmin whenever a scheme is `Auto`. Every
//! decision — candidates considered, cycles predicted, scheme chosen, why —
//! is recorded in a [`LoweringReport`] carried on the plan summary,
//! rendered by the `explain` CLI subcommand and serialized into
//! `BENCH_ablations.json` where the ablations bench checks the predicted
//! ranking against measured wall-clock.

use std::fmt;

use anyhow::Result;

use crate::compiler::silvermont;
use crate::model::spec::{LayerOp, ModelSpec};
use crate::nn::simd::WeightDtype;
use crate::util::json::Json;

/// Registers available on the paper's target (x86-64 SSE: 16 XMM).
pub const N_XMM: usize = 16;
/// Lanes per register (4 × f32 in 128-bit XMM).
pub const LANES: usize = 4;

/// Sustained streaming bandwidth of the modelled core, in weight bytes per
/// cycle. Prices the PR 9 bytes-moved term: every candidate pays
/// `bytes_streamed_per_item / STREAM_BYTES_PER_CYCLE` cycles on top of its
/// compute estimate, so storing weights in a narrower dtype (bf16 halves,
/// i8 quarters the stream) shows up in the §3.3 argmin exactly where a
/// layer is bandwidth-bound. Deliberately generous (an L1-resident figure):
/// the term is a tie-breaker on compute-bound layers and only dominates
/// when the weight footprint genuinely streams.
pub const STREAM_BYTES_PER_CYCLE: f64 = 64.0;

/// Per-layer instruction/register estimates (the §3.3 batching-rule view,
/// independent of which kernel scheme lowering ends up choosing).
#[derive(Debug, Clone)]
pub struct UnitCost {
    /// Layer name from the model spec.
    pub layer: String,
    /// Operation name (`conv2d`, `dense`, …).
    pub op: &'static str,
    /// Multiply–accumulates in the unit.
    pub macs: usize,
    /// Elements the unit produces.
    pub out_elems: usize,
    /// Register batches per §3.3: Eq. 3 scheme (k = 2).
    pub batches_eq3: usize,
    /// Register batches with the Eq. 2 broadcast scheme (k = 3).
    pub batches_eq2: usize,
    /// Shuffle ops per output 4-block: Eq. 3 needs (n−1).
    pub shuffles_eq3: usize,
    /// Shuffle ops per output 4-block with Eq. 2: n (one per column).
    pub shuffles_eq2: usize,
}

/// Elements processed per batch for a given reserved-register count.
pub fn batch_elems(k: usize) -> usize {
    LANES * (N_XMM - k)
}

/// Walk the spec and produce one [`UnitCost`] row per layer (shapes are
/// inferred statically; errors only on malformed graphs).
pub fn analyze(spec: &ModelSpec) -> Result<Vec<UnitCost>> {
    let shapes = spec.infer_shapes()?;
    let mut out = Vec::new();
    for l in &spec.layers {
        let oshape = &shapes[&l.name];
        let out_elems: usize = oshape.iter().product();
        let in_shape = &shapes[&l.inputs[0]];
        let (macs, matvec_n) = match &l.op {
            LayerOp::Conv2d { kh, kw, .. } => {
                let c = *in_shape.last().unwrap();
                (out_elems * kh * kw * c, Some(kh * kw * c))
            }
            LayerOp::DepthwiseConv2d { kh, kw, .. } => (out_elems * kh * kw, None),
            LayerOp::Dense { units } => (in_shape[0] * units, Some(in_shape[0])),
            LayerOp::BatchNorm { .. } => (out_elems, None),
            LayerOp::Softmax => (out_elems * 2, None),
            _ => (0, None),
        };
        let div = |n: usize, d: usize| n.div_ceil(d.max(1));
        let (sh3, sh2) = match matvec_n {
            Some(n) => (n.saturating_sub(1), n),
            None => (0, 0),
        };
        out.push(UnitCost {
            layer: l.name.clone(),
            op: l.op.name(),
            macs,
            out_elems,
            batches_eq3: div(out_elems, batch_elems(2)),
            batches_eq2: div(out_elems, batch_elems(3)),
            shuffles_eq3: sh3,
            shuffles_eq2: sh2,
        });
    }
    Ok(out)
}

/// Total MACs of the network (for roofline-style comparisons).
pub fn total_macs(spec: &ModelSpec) -> usize {
    analyze(spec).map(|v| v.iter().map(|u| u.macs).sum()).unwrap_or(0)
}

/// Render the analysis as an aligned text table (inspect command).
pub fn render_table(costs: &[UnitCost]) -> String {
    let mut s = format!(
        "{:<16} {:<18} {:>12} {:>10} {:>9} {:>9} {:>9} {:>9}\n",
        "layer", "op", "macs", "out", "bat(Eq3)", "bat(Eq2)", "shuf3", "shuf2"
    );
    for c in costs {
        s.push_str(&format!(
            "{:<16} {:<18} {:>12} {:>10} {:>9} {:>9} {:>9} {:>9}\n",
            c.layer, c.op, c.macs, c.out_elems, c.batches_eq3, c.batches_eq2,
            c.shuffles_eq3, c.shuffles_eq2
        ));
    }
    let total: usize = costs.iter().map(|c| c.macs).sum();
    s.push_str(&format!("total MACs: {total}\n"));
    s
}

// ---------------------------------------------------------------------------
// Scheme auto-tuning: per-layer candidate pricing + the lowering report.
// ---------------------------------------------------------------------------

/// Static dimensions of a conv layer as seen by the scheme estimator.
#[derive(Debug, Clone, Copy)]
pub struct ConvDims {
    /// Kernel height.
    pub kh: usize,
    /// Kernel width.
    pub kw: usize,
    /// Input channels.
    pub in_ch: usize,
    /// Output channels.
    pub out_ch: usize,
    /// Output spatial height (post-stride).
    pub out_h: usize,
    /// Output spatial width (post-stride).
    pub out_w: usize,
    /// SAME padding (multi-tap rows need bounds checks; VALID does not).
    pub same_padding: bool,
}

/// Static dimensions of a dense layer as seen by the scheme estimator.
#[derive(Debug, Clone, Copy)]
pub struct DenseDims {
    /// Input features.
    pub in_dim: usize,
    /// Output units.
    pub units: usize,
}

/// One priced lowering candidate for a layer.
#[derive(Debug, Clone)]
pub struct CandidateCost {
    /// Scheme label, matching the plan-summary naming (`"im2col"`,
    /// `"gemm+rotated"`, …).
    pub scheme: &'static str,
    /// SIMD lane width this candidate's blocked kernels run at (1 for the
    /// scalar schemes). Since PR 7 every blocked scheme is priced at every
    /// width the host dispatch allows, so the argmin decides the width too.
    pub lanes: usize,
    /// Predicted cycles per inference item for this layer under the scheme.
    pub cycles: f64,
    /// Bytes of (possibly packed/padded) weights the scheme materializes.
    pub weight_bytes: usize,
    /// Storage dtype this candidate's weights would use. The scalar
    /// `generic` path and the rotated/broadcast dense tails always store
    /// f32 whatever the compile requested — their candidates say so, and
    /// their bytes terms are priced accordingly.
    pub dtype: WeightDtype,
    /// Whether this candidate fuses the downstream max-pool into its stores.
    pub fused_pool: bool,
}

/// Why a layer's scheme ended up chosen.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecisionReason {
    /// Argmin of the cost model over the legal candidates.
    CostModel,
    /// `CompileOptions` forced the scheme (including `bit_exact()`).
    Forced,
    /// The model declined to price the layer (no legal candidates / zero
    /// work); lowering fell back to the geometry rule, then generic.
    Fallback,
    /// `CompileOptions::tune` timed the top cost-model candidates on the
    /// real machine and the empirical argmin won (which may differ from the
    /// predicted pick — see [`LayerDecision::overturned`]).
    Measured,
}

impl DecisionReason {
    /// Short label for tables and JSON.
    pub fn label(self) -> &'static str {
        match self {
            DecisionReason::CostModel => "cost-model",
            DecisionReason::Forced => "forced",
            DecisionReason::Fallback => "fallback",
            DecisionReason::Measured => "measured",
        }
    }
}

/// One layer's record in the [`LoweringReport`].
#[derive(Debug, Clone)]
pub struct LayerDecision {
    /// Layer name.
    pub layer: String,
    /// Operation name (`conv2d`, `dense`, `maxpool`, …).
    pub op: &'static str,
    /// Every candidate that was priced (empty when forced without pricing
    /// or when the layer was elided into a neighbour).
    pub candidates: Vec<CandidateCost>,
    /// Label of the scheme lowering actually emitted.
    pub chosen: &'static str,
    /// SIMD lane width the emitted kernel runs at (1 = scalar).
    pub lane_width: usize,
    /// Intra-op tasks the kernel was planned with (1 = sequential).
    pub parallel_tasks: usize,
    /// Predicted cycles of the chosen scheme (0 when unpriced).
    pub predicted_cycles: f64,
    /// Storage dtype of the weights the emitted kernel actually carries
    /// (may be `F32` under a narrower request: generic/rotated/broadcast
    /// storage, nonfinite-weight fallback, or layers with no weights).
    pub weight_dtype: WeightDtype,
    /// Bytes of packed weight storage the emitted kernel owns (0 for
    /// weightless or elided layers).
    pub weights_bytes: usize,
    /// How the choice was made.
    pub reason: DecisionReason,
    /// The emitted kernel fuses a downstream max-pool.
    pub fused_pool: bool,
    /// The layer itself emits no kernel (e.g. a max-pool fused upstream).
    pub elided: bool,
    /// Wall-clock nanoseconds per item the *winning* candidate measured
    /// when `CompileOptions::tune` timed candidates on the real machine
    /// (`None` under predicted-only tuning).
    pub measured_cycles: Option<f64>,
    /// Measured tuning picked a different (scheme, lanes) than the cost
    /// model's predicted argmin would have — the §3.3 model was wrong on
    /// this machine for this layer.
    pub overturned: bool,
}

/// Map a scheme/op label back to its `&'static str` — the inverse the
/// artifact decoder needs to rebuild [`LayerDecision`]s (whose labels are
/// interned statics) from serialized bytes. Returns `None` for strings no
/// lowering ever emits, which the decoder treats as corruption.
pub fn intern_label(s: &str) -> Option<&'static str> {
    const KNOWN: &[&str] = &[
        // conv/dense scheme labels
        "direct",
        "im2col",
        "generic",
        "gemm+rotated",
        "gemm+broadcast",
        "gemm+panels",
        "fused-into-conv",
        // LayerOp::name() values
        "conv2d",
        "depthwise_conv2d",
        "dense",
        "batchnorm",
        "maxpool",
        "avgpool",
        "globalavgpool",
        "upsample",
        "zeropad",
        "activation",
        "softmax",
        "add",
        "concat",
        "flatten",
    ];
    KNOWN.iter().find(|&&k| k == s).copied()
}

/// The explainable artifact of one `Program::lower` run: what was priced,
/// what was chosen, and the memory the plan committed to.
#[derive(Debug, Clone, Default)]
pub struct LoweringReport {
    /// Model name.
    pub model: String,
    /// Batch size the dense pricing assumed (`CompileOptions::batch_hint`).
    pub batch_hint: usize,
    /// Per-layer decisions, in lowering order (conv/dense/elided-pool only).
    pub decisions: Vec<LayerDecision>,
    /// Arena bytes per inference item committed by the §3.2 plan.
    pub arena_bytes: usize,
    /// Kernel scratch bytes (im2col rows, rotated-matvec staging).
    pub scratch_bytes: usize,
}

impl LoweringReport {
    /// Sum of the chosen candidates' predicted cycles per inference item.
    pub fn predicted_total_cycles(&self) -> f64 {
        self.decisions.iter().map(|d| d.predicted_cycles).sum()
    }

    /// Render the report as an aligned text table (the `explain` command).
    pub fn render_table(&self) -> String {
        let mut s = format!(
            "lowering report — model {:?}, batch hint {}\n",
            self.model, self.batch_hint
        );
        s.push_str(&format!(
            "{:<16} {:<12} {:<16} {:<10} {:>14}  candidates (cycles)\n",
            "layer", "op", "chosen", "reason", "pred cycles"
        ));
        for d in &self.decisions {
            let cands = d
                .candidates
                .iter()
                .map(|c| {
                    let fused = if c.fused_pool { "+pool" } else { "" };
                    format!("{}/w{}{}={:.0}", c.scheme, c.lanes, fused, c.cycles)
                })
                .collect::<Vec<_>>()
                .join(" ");
            let mut chosen = if d.fused_pool {
                format!("{}+pool", d.chosen)
            } else {
                d.chosen.to_string()
            };
            if !d.elided {
                chosen.push_str(&format!(" w{}", d.lane_width));
                if d.parallel_tasks > 1 {
                    chosen.push_str(&format!(" x{}", d.parallel_tasks));
                }
                if d.weight_dtype != WeightDtype::F32 {
                    chosen.push_str(&format!(" {}", d.weight_dtype));
                }
                if d.overturned {
                    chosen.push_str(" (overturned)");
                }
            }
            s.push_str(&format!(
                "{:<16} {:<12} {:<16} {:<10} {:>14.0}  {}\n",
                d.layer,
                d.op,
                chosen,
                d.reason.label(),
                d.predicted_cycles,
                cands
            ));
        }
        s.push_str(&format!(
            "predicted total: {:.0} cycles/item · arena {} B/item · scratch {} B\n",
            self.predicted_total_cycles(),
            self.arena_bytes,
            self.scratch_bytes
        ));
        s
    }

    /// Serialize for `BENCH_ablations.json` (and anything else downstream).
    pub fn to_json(&self) -> Json {
        let mut root = std::collections::BTreeMap::new();
        root.insert("model".into(), Json::Str(self.model.clone()));
        root.insert("batch_hint".into(), Json::Num(self.batch_hint as f64));
        root.insert(
            "predicted_total_cycles".into(),
            Json::Num(self.predicted_total_cycles()),
        );
        root.insert("arena_bytes".into(), Json::Num(self.arena_bytes as f64));
        root.insert("scratch_bytes".into(), Json::Num(self.scratch_bytes as f64));
        let decisions = self
            .decisions
            .iter()
            .map(|d| {
                let mut m = std::collections::BTreeMap::new();
                m.insert("layer".into(), Json::Str(d.layer.clone()));
                m.insert("op".into(), Json::Str(d.op.into()));
                m.insert("chosen".into(), Json::Str(d.chosen.into()));
                m.insert("lane_width".into(), Json::Num(d.lane_width as f64));
                m.insert(
                    "parallel_tasks".into(),
                    Json::Num(d.parallel_tasks as f64),
                );
                m.insert("predicted_cycles".into(), Json::Num(d.predicted_cycles));
                m.insert("weight_dtype".into(), Json::Str(d.weight_dtype.label().into()));
                m.insert("weights_bytes".into(), Json::Num(d.weights_bytes as f64));
                m.insert("reason".into(), Json::Str(d.reason.label().into()));
                m.insert("fused_pool".into(), Json::Bool(d.fused_pool));
                m.insert("elided".into(), Json::Bool(d.elided));
                if let Some(ns) = d.measured_cycles {
                    m.insert("measured_ns".into(), Json::Num(ns));
                }
                m.insert("overturned".into(), Json::Bool(d.overturned));
                let cands = d
                    .candidates
                    .iter()
                    .map(|c| {
                        let mut cm = std::collections::BTreeMap::new();
                        cm.insert("scheme".into(), Json::Str(c.scheme.into()));
                        cm.insert("lanes".into(), Json::Num(c.lanes as f64));
                        cm.insert("cycles".into(), Json::Num(c.cycles));
                        cm.insert(
                            "weight_bytes".into(),
                            Json::Num(c.weight_bytes as f64),
                        );
                        cm.insert("dtype".into(), Json::Str(c.dtype.label().into()));
                        cm.insert("fused_pool".into(), Json::Bool(c.fused_pool));
                        Json::Obj(cm)
                    })
                    .collect();
                m.insert("candidates".into(), Json::Arr(cands));
                Json::Obj(m)
            })
            .collect();
        root.insert("decisions".into(), Json::Arr(decisions));
        Json::Obj(root)
    }
}

impl fmt::Display for LoweringReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.render_table())
    }
}

/// Output-column padding factor of the packed `lanes`-wide panels: a panel
/// pads `units` up to the next multiple of the lane width, and the padded
/// lanes cost real multiplies. Wider panels waste more on small channel
/// counts — the lever that lets the argmin keep 4-lane kernels on
/// tail-dominated shapes even when the host has AVX-512.
fn panel_waste(units: usize, lanes: usize) -> f64 {
    if units == 0 {
        return 1.0;
    }
    let lanes = lanes.max(1);
    (lanes * units.div_ceil(lanes)) as f64 / units as f64
}

/// Frequency/issue ramp of wider vector units, relative to the 4-lane
/// baseline: 256-bit ops retire slightly slower per lane-group on the
/// modelled cores and 512-bit ops pay license-based downclock. Applied
/// multiplicatively on top of the ideal `4/lanes` speedup.
fn lane_ramp(lanes: usize) -> f64 {
    match lanes {
        16 => 1.3,
        8 => 1.1,
        _ => 1.0,
    }
}

/// Per-MAC cycle constant of the blocked kernels at a given lane width:
/// the calibrated 4-lane constant scaled by the ideal `4/lanes` factor and
/// the [`lane_ramp`] surcharge. Width 1 prices the unvectorized reference
/// instantiation at the scalar constant.
pub fn simd_mac_cycles_w(lanes: usize) -> f64 {
    if lanes <= 1 {
        return silvermont::scalar_mac_cycles();
    }
    silvermont::simd_mac_cycles() * (4.0 / lanes as f64) * lane_ramp(lanes)
}

/// The blocked lane widths the estimator prices under a dispatch ceiling.
/// `max_lanes == 1` (forced scalar) restricts the blocked kernels to their
/// width-1 reference instantiation; otherwise every hardware width up to
/// the ceiling is a candidate, narrow first (strict-`<` argmin ties then
/// keep the narrower, lower-waste width).
fn blocked_widths(max_lanes: usize) -> &'static [usize] {
    match max_lanes {
        0 | 1 => &[1],
        2..=7 => &[4],
        8..=15 => &[4, 8],
        _ => &[4, 8, 16],
    }
}

/// Price every legal conv scheme for a layer. `fusible_pool` is true when
/// a downstream max-pool can legally fuse into this conv's stores; each
/// scheme is then priced both fused (no separate pool pass) and unfused
/// (a ~1 cycle/element pool sweep on top). Every blocked scheme is priced
/// at each lane width allowed by `max_lanes` (see [`blocked_widths`]) —
/// the argmin therefore decides scheme *and* width. Returns an empty vec
/// when the layer does no MAC work (the caller falls back to the geometry
/// rule — see `ConvScheme::Auto`).
pub fn conv_candidates(
    d: &ConvDims,
    fusible_pool: bool,
    max_lanes: usize,
) -> Vec<CandidateCost> {
    conv_candidates_dt(d, fusible_pool, max_lanes, WeightDtype::F32)
}

/// [`conv_candidates`] under a requested weight storage dtype: the blocked
/// schemes price their packed panels at the narrow element size (plus the
/// i8 scale vector) and pay the [`STREAM_BYTES_PER_CYCLE`] bytes-moved
/// term on what they actually stream per item; the scalar `generic` path
/// keeps raw f32 storage whatever was requested.
pub fn conv_candidates_dt(
    d: &ConvDims,
    fusible_pool: bool,
    max_lanes: usize,
    dtype: WeightDtype,
) -> Vec<CandidateCost> {
    let taps = d.kh * d.kw * d.in_ch;
    let out_pixels = d.out_h * d.out_w;
    let macs = (out_pixels * d.out_ch * taps) as f64;
    if macs == 0.0 {
        return Vec::new();
    }
    let out_elems = (out_pixels * d.out_ch) as f64;
    let raw_bytes = taps * d.out_ch * 4;
    let scale_bytes = if dtype == WeightDtype::I8 { d.out_ch * 4 } else { 0 };
    // SAME with a multi-tap kernel pays per-row bounds handling in the
    // inner loop; VALID and 1×1 kernels never leave bounds
    let multi_tap_same = d.same_padding && (d.kh > 1 || d.kw > 1);
    let direct_pen = if multi_tap_same { 0.5 } else { 0.0 };
    // im2col gathers each input patch element once per output pixel, then
    // all out_ch MACs reuse the gathered row → +1 load-cycle / out_ch
    let gather_pen = 1.0 / d.out_ch as f64;
    // the full panel set streams once per output pixel
    let mem = |bytes: usize| out_pixels as f64 * bytes as f64 / STREAM_BYTES_PER_CYCLE;
    let mut base: Vec<(&'static str, f64, usize, usize, WeightDtype)> = Vec::new();
    for scheme in ["im2col", "direct"] {
        let pen = if scheme == "im2col" { gather_pen } else { direct_pen };
        for &wl in blocked_widths(max_lanes) {
            let waste = panel_waste(d.out_ch, wl);
            // packed panels pad out_ch to the lane width; generic keeps
            // the raw kernel
            let packed_bytes =
                taps * wl * d.out_ch.div_ceil(wl) * dtype.bytes_per_elem() + scale_bytes;
            base.push((
                scheme,
                macs * waste * (simd_mac_cycles_w(wl) + pen) + mem(packed_bytes),
                packed_bytes,
                wl,
                dtype,
            ));
        }
    }
    base.push((
        "generic",
        macs * silvermont::scalar_mac_cycles() + mem(raw_bytes),
        raw_bytes,
        1,
        WeightDtype::F32,
    ));
    let mut out = Vec::new();
    for (scheme, cycles, weight_bytes, lanes, dtype) in base {
        if fusible_pool {
            // fused: the pool max happens in the conv's store loop — no
            // separate pass. Unfused: one ~1-cycle read/compare sweep over
            // every conv output element.
            out.push(CandidateCost {
                scheme,
                lanes,
                cycles,
                weight_bytes,
                dtype,
                fused_pool: true,
            });
            out.push(CandidateCost {
                scheme,
                lanes,
                cycles: cycles + out_elems,
                weight_bytes,
                dtype,
                fused_pool: false,
            });
        } else {
            out.push(CandidateCost {
                scheme,
                lanes,
                cycles,
                weight_bytes,
                dtype,
                fused_pool: false,
            });
        }
    }
    out
}

/// Price every legal dense scheme for a layer under a batch hint.
///
/// Full 4-item tiles always run the blocked GEMM panels; the `batch % 4`
/// tail runs the scheme's matvec. Per-item cycles average the two. The
/// rotated (Eq. 3) and broadcast (Eq. 2) tails are only legal on square
/// layers with `units % 4 == 0` (rotation additionally bounded by the
/// stack-staging limit the kernels enforce); `rotated_max` passes that
/// bound in (callers use `nn::simd::ROTATED_STACK_MAX`). The tile part of
/// every scheme is priced at each lane width allowed by `max_lanes`; the
/// rotated/broadcast tail matvecs are fixed 4-lane algorithms and keep
/// their calibrated constants. Returns an empty vec when the layer does no
/// MAC work.
pub fn dense_candidates(
    d: &DenseDims,
    batch_hint: usize,
    rotated_max: usize,
    max_lanes: usize,
) -> Vec<CandidateCost> {
    dense_candidates_dt(d, batch_hint, rotated_max, max_lanes, WeightDtype::F32)
}

/// [`dense_candidates`] under a requested weight storage dtype. Only the
/// pure-panel scheme can store narrow weights end to end: the rotated and
/// broadcast tails are f32 algorithms (their whole candidate keeps f32
/// storage, priced at f32 bytes), which is exactly how a narrow request
/// steers the argmin toward `gemm+panels` on bandwidth-bound layers — the
/// tie the f32 pricing kept for the first-listed rotated scheme breaks in
/// favour of the scheme that can actually shrink its stream.
pub fn dense_candidates_dt(
    d: &DenseDims,
    batch_hint: usize,
    rotated_max: usize,
    max_lanes: usize,
    dtype: WeightDtype,
) -> Vec<CandidateCost> {
    let macs = (d.in_dim * d.units) as f64;
    if macs == 0.0 {
        return Vec::new();
    }
    let batch = batch_hint.max(1);
    let tiles = (batch / LANES) * LANES;
    let tail = batch - tiles;
    let raw_bytes = d.in_dim * d.units * 4;
    let square = d.in_dim == d.units && d.units % LANES == 0;
    let rotatable = square && d.units <= rotated_max;
    // average tile + tail items under the batch hint
    let mix = |gemm_item: f64, tail_item: f64| -> f64 {
        (tiles as f64 * gemm_item + tail as f64 * tail_item) / batch as f64
    };
    let widths = blocked_widths(max_lanes);
    // per-item cycles when the item lands in a full GEMM tile, per width
    let gemm_item = |wl: usize| macs * panel_waste(d.units, wl) * simd_mac_cycles_w(wl);
    let packed_elems = |wl: usize| d.in_dim * wl * d.units.div_ceil(wl);
    let scale_bytes = if dtype == WeightDtype::I8 { d.units * 4 } else { 0 };
    let packed_dt = |wl: usize| packed_elems(wl) * dtype.bytes_per_elem() + scale_bytes;
    let packed_f32 = |wl: usize| packed_elems(wl) * 4;
    // bytes-moved per item: a full tile streams the panel set once per
    // LANES items; a tail item streams its matvec layout whole
    let mem = |tile_bytes: usize, tail_bytes: usize| -> f64 {
        mix(tile_bytes as f64 / LANES as f64, tail_bytes as f64) / STREAM_BYTES_PER_CYCLE
    };
    let mut out = Vec::new();
    if rotatable {
        for &wl in widths {
            out.push(CandidateCost {
                scheme: "gemm+rotated",
                lanes: wl,
                cycles: mix(gemm_item(wl), macs * silvermont::rotated_mac_cycles())
                    + mem(packed_f32(wl), raw_bytes),
                // f32 panels for the tiles + the rotated diagonal copy for
                // the tail
                weight_bytes: packed_f32(wl) + raw_bytes,
                dtype: WeightDtype::F32,
                fused_pool: false,
            });
        }
    }
    for &wl in widths {
        out.push(CandidateCost {
            scheme: "gemm+panels",
            lanes: wl,
            cycles: mix(gemm_item(wl), gemm_item(wl)) + mem(packed_dt(wl), packed_dt(wl)),
            weight_bytes: packed_dt(wl),
            dtype,
            fused_pool: false,
        });
    }
    if square {
        for &wl in widths {
            out.push(CandidateCost {
                scheme: "gemm+broadcast",
                lanes: wl,
                cycles: mix(gemm_item(wl), macs * silvermont::broadcast_mac_cycles())
                    + mem(packed_f32(wl), raw_bytes),
                weight_bytes: packed_f32(wl) + raw_bytes,
                dtype: WeightDtype::F32,
                fused_pool: false,
            });
        }
    }
    out.push(CandidateCost {
        scheme: "generic",
        lanes: 1,
        cycles: macs * silvermont::scalar_mac_cycles()
            + raw_bytes as f64 / STREAM_BYTES_PER_CYCLE,
        weight_bytes: raw_bytes,
        dtype: WeightDtype::F32,
        fused_pool: false,
    });
    out
}

// ---------------------------------------------------------------------------
// Intra-op parallelism threshold.
// ---------------------------------------------------------------------------

/// Minimum predicted cycles of per-layer work each intra-op task must
/// amortize before lowering splits a kernel across threads. Below this the
/// spawn/join overhead of a scoped thread (~µs) dominates the band itself,
/// so small nets stay single-threaded no matter how many threads the
/// caller offers — the batch-1 latency guard of the §3 pipeline.
pub const PARALLEL_MIN_CYCLES_PER_TASK: f64 = 100_000.0;

/// Cost-model-driven intra-op task count for one kernel: the number of
/// threads the caller offers (`intra_threads`), capped so every task keeps
/// at least [`PARALLEL_MIN_CYCLES_PER_TASK`] predicted cycles of work
/// (`cycles_per_item` × the batch hint). Unpriced layers
/// (`cycles_per_item == 0`) and single-thread callers always get 1.
pub fn parallel_tasks(cycles_per_item: f64, batch_hint: usize, intra_threads: usize) -> usize {
    if intra_threads <= 1 || cycles_per_item <= 0.0 {
        return 1;
    }
    let total = cycles_per_item * batch_hint.max(1) as f64;
    let affordable = (total / PARALLEL_MIN_CYCLES_PER_TASK) as usize;
    intra_threads.min(affordable.max(1))
}

/// Argmin over the candidates whose fused-pool flag matches the actual
/// fusion decision. Strict `<` keeps the *first listed* candidate on ties,
/// which is how the estimator encodes its preference order (im2col before
/// direct for convs, rotated before panels before broadcast for dense, and
/// within a scheme the narrower lane width before the wider one).
pub fn pick(cands: &[CandidateCost], fused: bool) -> Option<&CandidateCost> {
    cands
        .iter()
        .filter(|c| c.fused_pool == fused)
        .fold(None, |best: Option<&CandidateCost>, c| match best {
            Some(b) if b.cycles <= c.cycles => Some(b),
            _ => Some(c),
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builder::tiny_cnn;

    #[test]
    fn batch_rule_matches_paper() {
        // §3.3: "batches of up to 4·(n_xmm − k) elements … k usually 2"
        assert_eq!(batch_elems(2), 56);
        assert_eq!(batch_elems(3), 52);
    }

    #[test]
    fn eq3_needs_fewer_batches_and_shuffles() {
        let costs = analyze(&tiny_cnn(1)).unwrap();
        for c in &costs {
            assert!(c.batches_eq3 <= c.batches_eq2, "{c:?}");
            assert!(c.shuffles_eq3 <= c.shuffles_eq2, "{c:?}");
        }
    }

    #[test]
    fn conv_macs() {
        // tiny_cnn conv1: 8×8 out, 4 ch, 3×3×3 kernel = 64*4*27 MACs
        let costs = analyze(&tiny_cnn(1)).unwrap();
        let conv = costs.iter().find(|c| c.layer == "conv1").unwrap();
        assert_eq!(conv.macs, 8 * 8 * 4 * 27);
    }

    #[test]
    fn render_contains_total() {
        let t = render_table(&analyze(&tiny_cnn(1)).unwrap());
        assert!(t.contains("total MACs"));
    }

    // -- scheme estimator ---------------------------------------------------

    fn conv(kh: usize, kw: usize, ic: usize, oc: usize, oh: usize, ow: usize, same: bool) -> ConvDims {
        ConvDims { kh, kw, in_ch: ic, out_ch: oc, out_h: oh, out_w: ow, same_padding: same }
    }

    fn cycles_of(cands: &[CandidateCost], scheme: &str, fused: bool) -> f64 {
        cands
            .iter()
            .find(|c| c.scheme == scheme && c.fused_pool == fused)
            .unwrap_or_else(|| panic!("no {scheme} fused={fused} in {cands:?}"))
            .cycles
    }

    #[test]
    fn conv_estimator_reproduces_the_geometry_rule_on_the_lane_grid() {
        // 3×3 SAME with oc ≥ 4: im2col's amortized gather beats direct's
        // bounds-checked taps (tiny_cnn's conv)
        let c = conv_candidates(&conv(3, 3, 3, 4, 8, 8, true), false, 4);
        assert_eq!(pick(&c, false).unwrap().scheme, "im2col");
        // VALID and 1×1 kernels: direct wins strictly
        let c = conv_candidates(&conv(3, 3, 3, 4, 6, 6, false), false, 4);
        assert_eq!(pick(&c, false).unwrap().scheme, "direct");
        let c = conv_candidates(&conv(1, 1, 8, 4, 8, 8, true), false, 4);
        assert_eq!(pick(&c, false).unwrap().scheme, "direct");
        // generic is never the argmin when SIMD candidates exist
        for same in [false, true] {
            let c = conv_candidates(&conv(3, 3, 4, 8, 5, 5, same), false, 4);
            assert_ne!(pick(&c, false).unwrap().scheme, "generic");
        }
    }

    #[test]
    fn lane_width_choice_follows_tail_waste() {
        // oc = 32 fills 8- and 16-lane panels: the wider instantiation's
        // per-MAC advantage wins once the dispatch ceiling allows it
        let full = conv(3, 3, 8, 32, 16, 16, true);
        assert_eq!(pick(&conv_candidates(&full, false, 4), false).unwrap().lanes, 4);
        assert_eq!(pick(&conv_candidates(&full, false, 8), false).unwrap().lanes, 8);
        assert_eq!(pick(&conv_candidates(&full, false, 16), false).unwrap().lanes, 16);
        // oc = 4 is tail-dominated at 8 lanes (waste 2×): the argmin keeps
        // the 4-lane kernels even on a wide host — the ISSUE's §3.3 lever
        let tail = conv(3, 3, 3, 4, 8, 8, true);
        let c = conv_candidates(&tail, false, 16);
        let best = pick(&c, false).unwrap();
        assert_eq!((best.scheme, best.lanes), ("im2col", 4));
        // oc = 8 fills AVX2 but wastes half an AVX-512 panel: 8 wins at
        // ceiling 16 (ramp 1.3 < waste 2×)
        let mid = conv(3, 3, 4, 8, 8, 8, true);
        assert_eq!(pick(&conv_candidates(&mid, false, 16), false).unwrap().lanes, 8);
        // forced-scalar ceiling: only width-1 blocked candidates exist
        let c = conv_candidates(&full, false, 1);
        assert!(c.iter().all(|x| x.lanes == 1), "{c:?}");
        // dense mirrors conv: 512→128 GEMM prefers 8 lanes under AVX2
        let max = crate::nn::simd::ROTATED_STACK_MAX;
        let d = DenseDims { in_dim: 512, units: 128 };
        let best = pick(&dense_candidates(&d, 4, max, 8), false).unwrap();
        assert_eq!((best.scheme, best.lanes), ("gemm+panels", 8));
        assert_eq!(pick(&dense_candidates(&d, 4, max, 4), false).unwrap().lanes, 4);
    }

    #[test]
    fn fused_pool_is_never_pricier_than_unfused() {
        let c = conv_candidates(&conv(3, 3, 3, 4, 8, 8, true), true, 4);
        for scheme in ["im2col", "direct", "generic"] {
            assert!(cycles_of(&c, scheme, true) < cycles_of(&c, scheme, false), "{scheme}");
        }
        assert_eq!(pick(&c, true).unwrap().scheme, "im2col");
    }

    #[test]
    fn dense_estimator_matches_the_kernel_legality_rules() {
        let max = crate::nn::simd::ROTATED_STACK_MAX;
        // square, 4-aligned, small: rotation is strictly cheapest
        let c = dense_candidates(&DenseDims { in_dim: 16, units: 16 }, 1, max, 4);
        assert_eq!(pick(&c, false).unwrap().scheme, "gemm+rotated");
        // rectangular: rotation/broadcast illegal, panels beat generic
        let c = dense_candidates(&DenseDims { in_dim: 48, units: 10 }, 1, max, 4);
        assert!(c.iter().all(|x| x.scheme != "gemm+rotated"));
        assert!(c.iter().all(|x| x.scheme != "gemm+broadcast"));
        assert_eq!(pick(&c, false).unwrap().scheme, "gemm+panels");
        // square but over the rotation staging limit: panels win the tie
        // against broadcast (first-listed preference)
        let c = dense_candidates(&DenseDims { in_dim: max * 2, units: max * 2 }, 1, max, 4);
        assert!(c.iter().all(|x| x.scheme != "gemm+rotated"));
        assert_eq!(pick(&c, false).unwrap().scheme, "gemm+panels");
        // a full-tile batch hint prices everything at GEMM cost, so the
        // rotated tail advantage disappears for batch % 4 == 0
        let c4 = dense_candidates(&DenseDims { in_dim: 16, units: 16 }, 4, max, 4);
        assert_eq!(
            cycles_of(&c4, "gemm+rotated", false),
            cycles_of(&c4, "gemm+panels", false)
        );
        // degenerate single-unit head: padding waste makes scalar cheaper
        let c = dense_candidates(&DenseDims { in_dim: 64, units: 1 }, 1, max, 4);
        assert_eq!(pick(&c, false).unwrap().scheme, "generic");
    }

    #[test]
    fn parallel_threshold_keeps_small_nets_sequential() {
        // tiny_cnn-scale work (≈9k cycles) never splits, whatever the
        // thread budget
        assert_eq!(parallel_tasks(8640.0, 1, 4), 1);
        assert_eq!(parallel_tasks(8640.0, 1, 16), 1);
        // single-thread callers and unpriced layers never split
        assert_eq!(parallel_tasks(1.0e9, 8, 1), 1);
        assert_eq!(parallel_tasks(0.0, 8, 4), 1);
        // big conv work splits up to the thread budget
        assert_eq!(parallel_tasks(2.4e6, 1, 4), 4);
        // mid-size work is capped by per-task amortization, not threads
        assert_eq!(parallel_tasks(250_000.0, 1, 4), 2);
        // batch hint scales the work: 9k cycles × 64 items affords a split
        assert!(parallel_tasks(8640.0, 64, 4) > 1);
    }

    #[test]
    fn scheme_costs_are_monotone_in_every_dimension() {
        // growing any conv dimension must never make any candidate cheaper
        // (a pathological estimate would silently invert a choice)
        let base = conv(3, 3, 4, 8, 5, 7, true);
        let bigger = [
            conv(5, 3, 4, 8, 5, 7, true),
            conv(3, 5, 4, 8, 5, 7, true),
            conv(3, 3, 9, 8, 5, 7, true),
            conv(3, 3, 4, 12, 5, 7, true),
            conv(3, 3, 4, 8, 11, 7, true),
            conv(3, 3, 4, 8, 5, 13, true),
        ];
        let b = conv_candidates(&base, false, 4);
        for big in &bigger {
            let g = conv_candidates(big, false, 4);
            for scheme in ["im2col", "direct", "generic"] {
                assert!(
                    cycles_of(&g, scheme, false) >= cycles_of(&b, scheme, false),
                    "{scheme}: {big:?} priced below {base:?}"
                );
            }
        }
        // dense: cycles non-decreasing in in_dim and units for the two
        // always-legal schemes, across off-lane-grid sizes
        let max = crate::nn::simd::ROTATED_STACK_MAX;
        for batch in [1usize, 3, 4, 8] {
            for scheme in ["gemm+panels", "generic"] {
                let mut prev = 0.0;
                for units in 1..=24 {
                    let c = dense_candidates(&DenseDims { in_dim: 32, units }, batch, max, 4);
                    let now = cycles_of(&c, scheme, false);
                    assert!(now >= prev, "{scheme} units {units} batch {batch}");
                    prev = now;
                }
                let mut prev = 0.0;
                for in_dim in 1..=24 {
                    let c = dense_candidates(&DenseDims { in_dim, units: 10 }, batch, max, 4);
                    let now = cycles_of(&c, scheme, false);
                    assert!(now >= prev, "{scheme} in_dim {in_dim} batch {batch}");
                    prev = now;
                }
            }
        }
    }

    #[test]
    fn report_renders_and_serializes() {
        let report = LoweringReport {
            model: "t".into(),
            batch_hint: 1,
            decisions: vec![LayerDecision {
                layer: "conv1".into(),
                op: "conv2d",
                candidates: conv_candidates(&conv(3, 3, 3, 4, 8, 8, true), false, 4),
                chosen: "im2col",
                lane_width: 4,
                parallel_tasks: 1,
                predicted_cycles: 8640.0,
                weight_dtype: WeightDtype::Bf16,
                weights_bytes: 216,
                reason: DecisionReason::Measured,
                fused_pool: false,
                elided: false,
                measured_cycles: Some(1234.5),
                overturned: true,
            }],
            arena_bytes: 1024,
            scratch_bytes: 432,
        };
        let t = report.render_table();
        assert!(t.contains("conv1") && t.contains("measured"), "{t}");
        assert!(t.contains("predicted total"), "{t}");
        assert!(t.contains("w4 bf16"), "narrow dtype must show in the table: {t}");
        assert!(t.contains("(overturned)"), "{t}");
        let j = report.to_json().to_string();
        assert!(j.contains("\"decisions\"") && j.contains("\"im2col\""), "{j}");
        assert!(j.contains("\"lane_width\"") && j.contains("\"parallel_tasks\""), "{j}");
        assert!(j.contains("\"lanes\""), "{j}");
        assert!(j.contains("\"weight_dtype\"") && j.contains("\"bf16\""), "{j}");
        assert!(j.contains("\"weights_bytes\""), "{j}");
        assert!(j.contains("\"measured_ns\"") && j.contains("\"overturned\""), "{j}");
        assert_eq!(report.predicted_total_cycles(), 8640.0);
    }

    #[test]
    fn intern_label_round_trips_every_emitted_label() {
        for s in ["direct", "im2col", "generic", "gemm+rotated", "gemm+broadcast",
                  "gemm+panels", "fused-into-conv", "conv2d", "dense", "flatten"] {
            assert_eq!(intern_label(s), Some(s), "{s}");
        }
        assert_eq!(intern_label("no-such-scheme"), None);
    }

    /// The PR 9 pricing lever: a narrow weight dtype shrinks the
    /// bytes-moved term of the schemes that can store it, and leaves the
    /// f32-only schemes (generic, rotated/broadcast tails) untouched — so
    /// the argmin migrates to narrow-capable schemes exactly when the
    /// layer is bandwidth-bound.
    #[test]
    fn narrow_dtype_pricing_steers_the_argmin() {
        let max = crate::nn::simd::ROTATED_STACK_MAX;
        let d = DenseDims { in_dim: 256, units: 256 };
        // f32, full-tile batch: rotated and panels tie on compute and
        // bytes, and the first-listed rotated keeps the strict-< argmin
        let f = dense_candidates_dt(&d, 4, max, 4, WeightDtype::F32);
        assert_eq!(pick(&f, false).unwrap().scheme, "gemm+rotated");
        // i8: only the pure-panel candidate's stream narrows → flip
        let q = dense_candidates_dt(&d, 4, max, 4, WeightDtype::I8);
        let best = pick(&q, false).unwrap();
        assert_eq!((best.scheme, best.dtype), ("gemm+panels", WeightDtype::I8));
        assert!(
            cycles_of(&q, "gemm+panels", false) < cycles_of(&q, "gemm+rotated", false)
        );
        // narrowing never raises a price; panel schemes strictly drop,
        // f32-storage schemes are unchanged
        assert!(cycles_of(&q, "gemm+panels", false) < cycles_of(&f, "gemm+panels", false));
        assert_eq!(
            cycles_of(&q, "gemm+rotated", false),
            cycles_of(&f, "gemm+rotated", false)
        );
        assert_eq!(cycles_of(&q, "generic", false), cycles_of(&f, "generic", false));
        // conv: bf16 halves the packed panel bytes and the price follows
        let c = conv(3, 3, 8, 32, 16, 16, true);
        let cf = conv_candidates_dt(&c, false, 4, WeightDtype::F32);
        let cb = conv_candidates_dt(&c, false, 4, WeightDtype::Bf16);
        assert!(cycles_of(&cb, "im2col", false) < cycles_of(&cf, "im2col", false));
        let wb = |cands: &[CandidateCost], s: &str| {
            cands.iter().find(|x| x.scheme == s && !x.fused_pool).unwrap().weight_bytes
        };
        assert_eq!(wb(&cb, "im2col") * 2, wb(&cf, "im2col"));
        // the generic candidate stays f32 whatever was requested
        let gq = cb.iter().find(|x| x.scheme == "generic").unwrap();
        assert_eq!(gq.dtype, WeightDtype::F32);
    }
}
