//! §3.3 cost model: per-unit instruction/register estimates for the
//! generated code, using the paper's batching rule — values are grouped in
//! batches of `4 · (n_xmm − k)` elements, where k is the number of registers
//! reserved for weights/temporaries (k = 2 for the Eq. 3 rotated-diagonal
//! scheme, k = 3 for the Eq. 2 broadcast scheme).
//!
//! This is a *static* model (it needs no input), used by `compiled-nn
//! inspect` and by DESIGN.md's §Perf estimates; EXPERIMENTS.md compares its
//! predictions with the measured Eq. 2/Eq. 3 bench.

use anyhow::Result;

use crate::model::spec::{LayerOp, ModelSpec};

/// Registers available on the paper's target (x86-64 SSE: 16 XMM).
pub const N_XMM: usize = 16;
/// Lanes per register (4 × f32 in 128-bit XMM).
pub const LANES: usize = 4;

#[derive(Debug, Clone)]
pub struct UnitCost {
    pub layer: String,
    pub op: &'static str,
    /// Multiply–accumulates in the unit.
    pub macs: usize,
    pub out_elems: usize,
    /// Register batches per §3.3: Eq. 3 scheme (k = 2).
    pub batches_eq3: usize,
    /// Register batches with the Eq. 2 broadcast scheme (k = 3).
    pub batches_eq2: usize,
    /// Shuffle ops per output 4-block: Eq. 3 needs (n−1), Eq. 2 needs n.
    pub shuffles_eq3: usize,
    pub shuffles_eq2: usize,
}

/// Elements processed per batch for a given reserved-register count.
pub fn batch_elems(k: usize) -> usize {
    LANES * (N_XMM - k)
}

pub fn analyze(spec: &ModelSpec) -> Result<Vec<UnitCost>> {
    let shapes = spec.infer_shapes()?;
    let mut out = Vec::new();
    for l in &spec.layers {
        let oshape = &shapes[&l.name];
        let out_elems: usize = oshape.iter().product();
        let in_shape = &shapes[&l.inputs[0]];
        let (macs, matvec_n) = match &l.op {
            LayerOp::Conv2d { kh, kw, .. } => {
                let c = *in_shape.last().unwrap();
                (out_elems * kh * kw * c, Some(kh * kw * c))
            }
            LayerOp::DepthwiseConv2d { kh, kw, .. } => (out_elems * kh * kw, None),
            LayerOp::Dense { units } => (in_shape[0] * units, Some(in_shape[0])),
            LayerOp::BatchNorm { .. } => (out_elems, None),
            LayerOp::Softmax => (out_elems * 2, None),
            _ => (0, None),
        };
        let div = |n: usize, d: usize| n.div_ceil(d.max(1));
        let (sh3, sh2) = match matvec_n {
            Some(n) => (n.saturating_sub(1), n),
            None => (0, 0),
        };
        out.push(UnitCost {
            layer: l.name.clone(),
            op: l.op.name(),
            macs,
            out_elems,
            batches_eq3: div(out_elems, batch_elems(2)),
            batches_eq2: div(out_elems, batch_elems(3)),
            shuffles_eq3: sh3,
            shuffles_eq2: sh2,
        });
    }
    Ok(out)
}

/// Total MACs of the network (for roofline-style comparisons).
pub fn total_macs(spec: &ModelSpec) -> usize {
    analyze(spec).map(|v| v.iter().map(|u| u.macs).sum()).unwrap_or(0)
}

/// Render the analysis as an aligned text table (inspect command).
pub fn render_table(costs: &[UnitCost]) -> String {
    let mut s = format!(
        "{:<16} {:<18} {:>12} {:>10} {:>9} {:>9} {:>9} {:>9}\n",
        "layer", "op", "macs", "out", "bat(Eq3)", "bat(Eq2)", "shuf3", "shuf2"
    );
    for c in costs {
        s.push_str(&format!(
            "{:<16} {:<18} {:>12} {:>10} {:>9} {:>9} {:>9} {:>9}\n",
            c.layer, c.op, c.macs, c.out_elems, c.batches_eq3, c.batches_eq2,
            c.shuffles_eq3, c.shuffles_eq2
        ));
    }
    let total: usize = costs.iter().map(|c| c.macs).sum();
    s.push_str(&format!("total MACs: {total}\n"));
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builder::tiny_cnn;

    #[test]
    fn batch_rule_matches_paper() {
        // §3.3: "batches of up to 4·(n_xmm − k) elements … k usually 2"
        assert_eq!(batch_elems(2), 56);
        assert_eq!(batch_elems(3), 52);
    }

    #[test]
    fn eq3_needs_fewer_batches_and_shuffles() {
        let costs = analyze(&tiny_cnn(1)).unwrap();
        for c in &costs {
            assert!(c.batches_eq3 <= c.batches_eq2, "{c:?}");
            assert!(c.shuffles_eq3 <= c.shuffles_eq2, "{c:?}");
        }
    }

    #[test]
    fn conv_macs() {
        // tiny_cnn conv1: 8×8 out, 4 ch, 3×3×3 kernel = 64*4*27 MACs
        let costs = analyze(&tiny_cnn(1)).unwrap();
        let conv = costs.iter().find(|c| c.layer == "conv1").unwrap();
        assert_eq!(conv.macs, 8 * 8 * 4 * 27);
    }

    #[test]
    fn render_contains_total() {
        let t = render_table(&analyze(&tiny_cnn(1)).unwrap());
        assert!(t.contains("total MACs"));
    }
}
