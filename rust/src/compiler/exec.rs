//! `OptInterp` — the optimized interpreter engine, rebuilt as a thin shell
//! over the pre-resolved [`Program`] IR (see [`crate::compiler::program`]):
//! lowering happens once at construction (§3.5 fold → §3.2 plan → kernel
//! monomorphization), inference is `load input → Program::run → read
//! outputs` over a pooled [`Arena`](crate::compiler::program::Arena) per
//! batch size. This is the repo's analog of the optimized interpreter
//! libraries in Table 1 (TensorFlow Lite / RoboDNN) and the ablation
//! vehicle for the paper's individual design choices via [`CompileOptions`].
//!
//! The lowered program is held behind an `Arc`, and the engine opts into
//! the coordinator's shared-serving path ([`Engine::shareable`]): N workers
//! each get the same `Arc<Program>` plus their own [`ArenaPool`], so a
//! model is lowered once no matter how many threads serve it.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::compiler::artifact::ProgramCache;
use crate::compiler::program::{ArenaPool, PlanSummary, Program};
pub use crate::compiler::program::{CompileOptions, ConvScheme, DenseScheme, LaneSelect, TuneMode};
pub use crate::nn::simd::WeightDtype;
use crate::engine::{Engine, SharedInfer, WorkerScratch};
use crate::model::spec::ModelSpec;
use crate::nn::tensor::Tensor;

/// The optimized interpreter: an `Arc`-shared lowered [`Program`] plus a
/// per-engine [`ArenaPool`] (one pooled arena per batch size served).
pub struct OptInterp {
    program: Arc<Program>,
    pool: ArenaPool,
}

impl OptInterp {
    /// Lower `spec` under `opts` and wrap the program for inference. When
    /// the persistent artifact cache is enabled (`COMPILED_NN_CACHE_DIR`),
    /// a valid cached artifact is mmap-loaded instead of re-lowering —
    /// cold-start then skips fold, plan, pack, and quantization entirely.
    pub fn new(spec: &ModelSpec, opts: CompileOptions) -> Result<Self> {
        let program = ProgramCache::global().lower_or_load(spec, opts)?;
        Ok(Self { program: Arc::new(program), pool: ArenaPool::new() })
    }

    /// Wrap an already-lowered program.
    pub fn from_program(program: Program) -> Self {
        Self { program: Arc::new(program), pool: ArenaPool::new() }
    }

    /// The lowered program (its `summary()` carries the lowering report).
    pub fn program(&self) -> &Program {
        &self.program
    }

    /// Arena bytes currently pooled across batch sizes (ablation metric).
    pub fn arena_bytes(&self) -> usize {
        self.pool.bytes()
    }

    /// Run a `[B, ...]` input through the program over a pooled arena.
    pub fn infer(&mut self, input: &Tensor) -> Result<Vec<Tensor>> {
        self.program.infer_pooled(input, &mut self.pool)
    }
}

/// The shared-inference path: the immutable lowered [`Program`] *is* the
/// shared artifact; per-worker state is just an [`ArenaPool`].
impl SharedInfer for Program {
    fn new_scratch(&self, buckets: &[usize]) -> WorkerScratch {
        let mut pool = ArenaPool::new();
        for &b in buckets {
            pool.reserve(self, b);
        }
        WorkerScratch::new(pool)
    }

    fn infer_shared(&self, input: &Tensor, scratch: &mut WorkerScratch) -> Result<Vec<Tensor>> {
        let pool = scratch
            .get_mut::<ArenaPool>()
            .context("worker scratch is not an ArenaPool (scratch from another engine?)")?;
        self.infer_pooled(input, pool)
    }

    fn plan_summary(&self) -> Option<&PlanSummary> {
        Some(self.summary())
    }
}

impl Engine for OptInterp {
    fn name(&self) -> &str {
        "optimized"
    }

    fn infer(&mut self, input: &Tensor) -> Result<Vec<Tensor>> {
        OptInterp::infer(self, input)
    }

    fn supports(&self, spec: &ModelSpec) -> bool {
        crate::nn::interp::Capabilities::FULL.supports(spec)
    }

    fn compile_ms(&self) -> f64 {
        self.program.compile_ms()
    }

    fn memory_bytes(&self) -> Option<usize> {
        Some(self.arena_bytes())
    }

    fn prepare(&mut self, batch: usize) {
        // Pre-size AND pin the pooled arena for this batch bucket: pinned
        // arenas are never evicted, so every inference at a served bucket
        // size is allocation-free for the engine's lifetime.
        self.pool.reserve(&self.program, batch);
    }

    fn plan_summary(&self) -> Option<&PlanSummary> {
        Some(self.program.summary())
    }

    fn shareable(&self) -> Option<Arc<dyn SharedInfer>> {
        Some(self.program.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Engine;
    use crate::model::builder::tiny_cnn;
    use crate::nn::interp::NaiveInterp;
    use crate::util::rng::SplitMix64;

    fn input(batch: usize, seed: u64) -> Tensor {
        let mut rng = SplitMix64::new(seed);
        Tensor::from_vec(&[batch, 8, 8, 3], rng.uniform_vec(batch * 8 * 8 * 3))
    }

    #[test]
    fn matches_naive_exact_options() {
        let spec = tiny_cnn(21);
        let naive = NaiveInterp::new(spec.clone()).unwrap();
        let mut opt = OptInterp::new(
            &spec,
            CompileOptions { approx: false, ..CompileOptions::default() },
        )
        .unwrap();
        let x = input(2, 77);
        let a = naive.infer(&x).unwrap();
        let b = opt.infer(&x).unwrap();
        let d = a[0].max_abs_diff(&b[0]);
        assert!(d < 1e-4, "diff {d}");
    }

    #[test]
    fn bit_exact_options_match_naive_exactly() {
        let spec = tiny_cnn(29);
        let naive = NaiveInterp::new(spec.clone()).unwrap();
        let mut opt = OptInterp::new(&spec, CompileOptions::bit_exact()).unwrap();
        let x = input(2, 78);
        let a = naive.infer(&x).unwrap();
        let b = opt.infer(&x).unwrap();
        assert_eq!(a[0].data(), b[0].data());
    }

    #[test]
    fn approx_stays_close() {
        let spec = tiny_cnn(22);
        let naive = NaiveInterp::new(spec.clone()).unwrap();
        let mut opt = OptInterp::new(&spec, CompileOptions::default()).unwrap();
        let x = input(1, 5);
        let a = naive.infer(&x).unwrap();
        let b = opt.infer(&x).unwrap();
        // softmax on fast exp: bounded by the §3.4 error analysis
        assert!(a[0].max_abs_diff(&b[0]) < 0.05);
    }

    #[test]
    fn all_option_combos_run() {
        let spec = tiny_cnn(23);
        let x = input(1, 9);
        for fold in [false, true] {
            for approx in [false, true] {
                for reuse in [false, true] {
                    for fuse_pool in [false, true] {
                        for dense in [
                            DenseScheme::Auto,
                            DenseScheme::Rotated,
                            DenseScheme::Broadcast,
                            DenseScheme::Generic,
                        ] {
                            for conv in [
                                ConvScheme::Auto,
                                ConvScheme::Direct,
                                ConvScheme::Im2col,
                                ConvScheme::Generic,
                            ] {
                                for weight_dtype in [
                                    WeightDtype::F32,
                                    WeightDtype::Bf16,
                                    WeightDtype::I8,
                                ] {
                                    let mut e = OptInterp::new(
                                        &spec,
                                        CompileOptions {
                                            fold_bn: fold,
                                            approx,
                                            reuse_memory: reuse,
                                            dense,
                                            conv,
                                            fuse_pool,
                                            batch_hint: 1,
                                            lanes: LaneSelect::Auto,
                                            intra_threads: 1,
                                            weight_dtype,
                                            tune: TuneMode::Predicted,
                                        },
                                    )
                                    .unwrap();
                                    let out = e.infer(&x).unwrap();
                                    assert_eq!(out[0].shape(), &[1, 10]);
                                    let s: f32 = out[0].data().iter().sum();
                                    assert!(
                                        (s - 1.0).abs() < 1e-3,
                                        "fold={fold} approx={approx} dense={dense:?} \
                                         conv={conv:?} fuse_pool={fuse_pool} \
                                         dtype={weight_dtype}: {s}"
                                    );
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn memory_reuse_shrinks_arena() {
        let spec = tiny_cnn(24);
        let x = input(1, 10);
        let mut with = OptInterp::new(
            &spec,
            CompileOptions { reuse_memory: true, ..Default::default() },
        )
        .unwrap();
        let mut without = OptInterp::new(
            &spec,
            CompileOptions { reuse_memory: false, ..Default::default() },
        )
        .unwrap();
        with.infer(&x).unwrap();
        without.infer(&x).unwrap();
        assert!(with.arena_bytes() < without.arena_bytes());
        // and identical outputs
        let a = with.infer(&x).unwrap();
        let b = without.infer(&x).unwrap();
        assert!(a[0].max_abs_diff(&b[0]) < 1e-6);
    }

    #[test]
    fn repeated_inference_is_stable() {
        // arena reuse must not leak state between calls
        let spec = tiny_cnn(25);
        let mut e = OptInterp::new(&spec, CompileOptions::default()).unwrap();
        let x = input(1, 11);
        let a = e.infer(&x).unwrap();
        for _ in 0..3 {
            let b = e.infer(&x).unwrap();
            assert!(a[0].max_abs_diff(&b[0]) == 0.0);
        }
    }

    #[test]
    fn batch_switch_pools_arenas() {
        let spec = tiny_cnn(26);
        let mut e = OptInterp::new(&spec, CompileOptions::default()).unwrap();
        e.infer(&input(1, 1)).unwrap();
        let one = e.arena_bytes();
        let out = e.infer(&input(4, 2)).unwrap();
        assert_eq!(out[0].shape(), &[4, 10]);
        // both arenas stay pooled; flipping back allocates nothing new
        assert!(e.arena_bytes() > one);
        let both = e.arena_bytes();
        e.infer(&input(1, 3)).unwrap();
        e.infer(&input(4, 4)).unwrap();
        assert_eq!(e.arena_bytes(), both);
    }

    #[test]
    fn prepare_preallocates_buckets() {
        let spec = tiny_cnn(28);
        let mut e = OptInterp::new(&spec, CompileOptions::default()).unwrap();
        Engine::prepare(&mut e, 1);
        Engine::prepare(&mut e, 8);
        let before = e.arena_bytes();
        assert!(before > 0);
        e.infer(&input(8, 3)).unwrap();
        e.infer(&input(1, 4)).unwrap();
        assert_eq!(e.arena_bytes(), before, "prepared buckets must not regrow");
    }

    #[test]
    fn plan_summary_reports_lowering() {
        let spec = tiny_cnn(30);
        let e = OptInterp::new(&spec, CompileOptions::default()).unwrap();
        let s = Engine::plan_summary(&e).expect("optimized engine lowers a program");
        assert_eq!(s.folded_bn, 1, "{s}");
        assert!(s.steps.len() >= 4, "{s}");
        assert!(s.arena_item_elems > 0, "{s}");
    }

    #[test]
    fn plan_summary_counts_gemm_dense() {
        // the engine-facing proof that batched serving rides the GEMM
        // path: default options lower tiny_cnn's dense to the blocked
        // microkernel, bit-exact pins it back to the scalar reference
        let spec = tiny_cnn(31);
        let e = OptInterp::new(&spec, CompileOptions::default()).unwrap();
        let s = Engine::plan_summary(&e).expect("optimized engine lowers a program");
        assert_eq!(s.gemm_dense, 1, "{s}");
        let exact = OptInterp::new(&spec, CompileOptions::bit_exact()).unwrap();
        let s = Engine::plan_summary(&exact).expect("optimized engine lowers a program");
        assert_eq!(s.gemm_dense, 0, "{s}");
    }

    #[test]
    fn rejects_wrong_shape() {
        let spec = tiny_cnn(27);
        let mut e = OptInterp::new(&spec, CompileOptions::default()).unwrap();
        let bad = Tensor::zeros(&[1, 4, 4, 3]);
        assert!(e.infer(&bad).is_err());
    }
}
