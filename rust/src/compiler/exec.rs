//! `OptInterp` — the optimized interpreter engine: §3.5-folded graph, §3.2
//! planned arena with in-place reuse, §3.4 fused activation epilogues and
//! approximations. This is the repo's analog of the optimized interpreter
//! libraries in Table 1 (TensorFlow Lite / RoboDNN) and the ablation vehicle
//! for the paper's individual design choices.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::compiler::kernels as k;
use crate::compiler::memory::{self, MemoryPlan};
use crate::model::spec::{LayerOp, ModelSpec};
use crate::nn::tensor::Tensor;

/// Which of the paper's optimizations to apply (each is an ablation axis).
#[derive(Debug, Clone, Copy)]
pub struct CompileOptions {
    /// §3.5 batch-norm folding / fusion.
    pub fold_bn: bool,
    /// §3.4 fast activation approximations.
    pub approx: bool,
    /// §3.2 lifetime-based buffer reuse (false = one buffer per tensor).
    pub reuse_memory: bool,
}

impl Default for CompileOptions {
    fn default() -> Self {
        Self { fold_bn: true, approx: true, reuse_memory: true }
    }
}

/// The "compiled" execution plan: folded spec + buffer assignment + shapes.
pub struct CompiledPlan {
    pub spec: ModelSpec,
    pub plan: MemoryPlan,
    pub shapes: BTreeMap<String, Vec<usize>>,
    pub opts: CompileOptions,
    /// Graph-pass + planning time (the Rust-side share of "compilation
    /// time"; the PJRT share is measured by the runtime).
    pub compile_ms: f64,
}

pub fn compile(spec: &ModelSpec, opts: CompileOptions) -> Result<CompiledPlan> {
    let t0 = Instant::now();
    let spec = if opts.fold_bn {
        crate::compiler::fuse::fold_batchnorm(spec)
    } else {
        spec.clone()
    };
    spec.validate()?;
    let plan = memory::plan(&spec, opts.reuse_memory)?;
    let shapes = spec.infer_shapes()?;
    Ok(CompiledPlan {
        spec,
        plan,
        shapes,
        opts,
        compile_ms: t0.elapsed().as_secs_f64() * 1e3,
    })
}

pub struct OptInterp {
    c: CompiledPlan,
    arena: Vec<Vec<f32>>,
    batch: usize,
}

impl OptInterp {
    pub fn new(spec: &ModelSpec, opts: CompileOptions) -> Result<Self> {
        Ok(Self { c: compile(spec, opts)?, arena: Vec::new(), batch: 0 })
    }

    pub fn from_plan(c: CompiledPlan) -> Self {
        Self { c, arena: Vec::new(), batch: 0 }
    }

    pub fn plan(&self) -> &CompiledPlan {
        &self.c
    }

    /// Arena bytes currently allocated (ablation metric).
    pub fn arena_bytes(&self) -> usize {
        self.arena.iter().map(|b| b.len() * 4).sum()
    }

    fn ensure_arena(&mut self, batch: usize) {
        if batch == self.batch && !self.arena.is_empty() {
            return;
        }
        self.arena = self
            .c
            .plan
            .buffer_sizes
            .iter()
            .map(|s| vec![0.0f32; s * batch])
            .collect();
        self.batch = batch;
    }

    pub fn infer(&mut self, input: &Tensor) -> Result<Vec<Tensor>> {
        let ishape = input.shape();
        if ishape.len() < 2 || ishape[1..] != self.c.spec.input_shape[..] {
            bail!(
                "input shape {:?} does not match model {:?}",
                ishape,
                self.c.spec.input_shape
            );
        }
        let batch = ishape[0];
        self.ensure_arena(batch);
        let in_buf = self.c.plan.buffer_of["input"];
        self.arena[in_buf][..input.len()].copy_from_slice(input.data());

        for li in 0..self.c.spec.layers.len() {
            self.run_layer(li, batch)?;
        }

        let mut outs = Vec::new();
        for o in &self.c.spec.outputs {
            let buf = self.c.plan.buffer_of[o];
            let mut shape = vec![batch];
            shape.extend_from_slice(&self.c.shapes[o]);
            let n: usize = shape.iter().product();
            outs.push(Tensor::from_vec(&shape, self.arena[buf][..n].to_vec()));
        }
        Ok(outs)
    }

    fn run_layer(&mut self, li: usize, batch: usize) -> Result<()> {
        let l = &self.c.spec.layers[li];
        let spec = &self.c.spec;
        let out_id = self.c.plan.buffer_of[&l.name];
        let in_id = self.c.plan.buffer_of[&l.inputs[0]];
        let in_shape = &self.c.shapes[&l.inputs[0]];
        let out_shape = &self.c.shapes[&l.name];
        let in_n: usize = batch * in_shape.iter().product::<usize>();
        let out_n: usize = batch * out_shape.iter().product::<usize>();

        let post = if l.post_scale {
            Some((spec.weight(l, "post_scale_w")?, spec.weight(l, "post_shift_w")?))
        } else {
            None
        };
        let ep = k::Epilogue { act: l.activation, approx: self.c.opts.approx, post };

        // In-place path: input and output share a buffer (§3.2).
        if out_id == in_id {
            // SAFETY-free path: operate on the single buffer directly.
            let (scale_shift, c_last);
            match &l.op {
                LayerOp::BatchNorm { epsilon } => {
                    let c = *in_shape.last().unwrap();
                    let g = spec.weight(l, "gamma")?;
                    let be = spec.weight(l, "beta")?;
                    let m = spec.weight(l, "mean")?;
                    let v = spec.weight(l, "var")?;
                    let scale: Vec<f32> =
                        (0..c).map(|i| g[i] / (v[i] + epsilon).sqrt()).collect();
                    let shift: Vec<f32> = (0..c).map(|i| be[i] - m[i] * scale[i]).collect();
                    scale_shift = Some((scale, shift));
                    c_last = c;
                }
                _ => {
                    scale_shift = None;
                    c_last = *out_shape.last().unwrap();
                }
            }
            let approx = self.c.opts.approx;
            let second = match &l.op {
                LayerOp::Add => {
                    let b_id = self.c.plan.buffer_of[&l.inputs[1]];
                    if b_id == out_id {
                        bail!("add with both operands aliased is not plannable");
                    }
                    Some(self.arena[b_id][..out_n].to_vec())
                }
                _ => None,
            };
            let buf = &mut self.arena[out_id];
            match &l.op {
                LayerOp::BatchNorm { .. } => {
                    let (scale, shift) = scale_shift.unwrap();
                    for (i, v) in buf[..out_n].iter_mut().enumerate() {
                        let ci = i % c_last;
                        *v = *v * scale[ci] + shift[ci];
                    }
                }
                LayerOp::Activation => {
                    ep.apply_whole(&mut buf[..out_n], c_last);
                }
                LayerOp::Softmax => {
                    for row in buf[..out_n].chunks_exact_mut(c_last) {
                        if approx {
                            crate::approx::fast_softmax_row(row);
                        } else {
                            exact_softmax_row(row);
                        }
                    }
                }
                LayerOp::Add => {
                    let b = second.unwrap();
                    for (v, &bv) in buf[..out_n].iter_mut().zip(&b) {
                        *v += bv;
                    }
                }
                LayerOp::Flatten => {} // pure reshape — data already in place
                other => bail!("op {} cannot run in place", other.name()),
            }
            return Ok(());
        }

        // Out-of-place path: take the output buffer, read inputs from arena.
        let mut outbuf = std::mem::take(&mut self.arena[out_id]);
        let x = &self.arena[in_id][..in_n];
        let dims4 = |s: &[usize]| (batch, s[0], s[1], s[2]);
        match &l.op {
            LayerOp::Conv2d { kh, kw, out_ch, stride, padding, use_bias } => {
                let kernel = spec.weight(l, "kernel")?;
                let bias = if *use_bias { Some(spec.weight(l, "bias")?) } else { None };
                k::conv2d_into(
                    x,
                    dims4(in_shape),
                    kernel,
                    (*kh, *kw, *out_ch),
                    bias,
                    *stride,
                    *padding,
                    ep,
                    &mut outbuf[..out_n],
                );
            }
            LayerOp::DepthwiseConv2d { kh, kw, stride, padding, use_bias } => {
                let kernel = spec.weight(l, "kernel")?;
                let bias = if *use_bias { Some(spec.weight(l, "bias")?) } else { None };
                k::depthwise_conv2d_into(
                    x,
                    dims4(in_shape),
                    kernel,
                    (*kh, *kw),
                    bias,
                    *stride,
                    *padding,
                    ep,
                    &mut outbuf[..out_n],
                );
            }
            LayerOp::Dense { units } => {
                let kernel = spec.weight(l, "kernel")?;
                let bias = spec.weight(l, "bias").ok();
                k::dense_into(x, (batch, in_shape[0]), kernel, *units, bias, ep, &mut outbuf[..out_n]);
            }
            LayerOp::BatchNorm { epsilon } => {
                let c = *in_shape.last().unwrap();
                let g = spec.weight(l, "gamma")?;
                let be = spec.weight(l, "beta")?;
                let m = spec.weight(l, "mean")?;
                let v = spec.weight(l, "var")?;
                let scale: Vec<f32> = (0..c).map(|i| g[i] / (v[i] + epsilon).sqrt()).collect();
                let shift: Vec<f32> = (0..c).map(|i| be[i] - m[i] * scale[i]).collect();
                k::affine_into(x, c, &scale, &shift, &mut outbuf[..out_n]);
            }
            LayerOp::MaxPool { kh, kw, stride } => {
                k::maxpool_into(x, dims4(in_shape), (*kh, *kw, *stride), &mut outbuf[..out_n]);
            }
            LayerOp::AvgPool { kh, kw, stride } => {
                k::avgpool_into(x, dims4(in_shape), (*kh, *kw, *stride), &mut outbuf[..out_n]);
            }
            LayerOp::GlobalAvgPool => {
                k::globalavgpool_into(x, dims4(in_shape), &mut outbuf[..out_n]);
            }
            LayerOp::Upsample { factor } => {
                k::upsample_into(x, dims4(in_shape), *factor, &mut outbuf[..out_n]);
            }
            LayerOp::ZeroPad { pad } => {
                k::zeropad_into(x, dims4(in_shape), *pad, &mut outbuf[..out_n]);
            }
            LayerOp::Activation => {
                outbuf[..out_n].copy_from_slice(x);
                ep.apply_whole(&mut outbuf[..out_n], *out_shape.last().unwrap());
            }
            LayerOp::Softmax => {
                let c = *out_shape.last().unwrap();
                k::softmax_into(x, c, self.c.opts.approx, &mut outbuf[..out_n]);
            }
            LayerOp::Add => {
                let b_id = self.c.plan.buffer_of[&l.inputs[1]];
                let b = &self.arena[b_id][..out_n];
                k::add_into(x, b, &mut outbuf[..out_n]);
            }
            LayerOp::Concat => {
                let b_id = self.c.plan.buffer_of[&l.inputs[1]];
                let b_shape = &self.c.shapes[&l.inputs[1]];
                let (ca, cb) = (*in_shape.last().unwrap(), *b_shape.last().unwrap());
                let b_n: usize = batch * b_shape.iter().product::<usize>();
                let b = &self.arena[b_id][..b_n];
                k::concat_into(x, ca, b, cb, &mut outbuf[..out_n]);
            }
            LayerOp::Flatten => {
                outbuf[..out_n].copy_from_slice(x);
            }
        }
        // Standalone activation epilogue for ops that don't fuse internally
        // is already handled per-op above (conv/dw/dense fuse; others carry
        // Linear activation by construction, except `activation` layers).
        self.arena[out_id] = outbuf;
        Ok(())
    }
}

impl crate::engine::Engine for OptInterp {
    fn name(&self) -> &str {
        "optimized"
    }

    fn infer(&mut self, input: &Tensor) -> Result<Vec<Tensor>> {
        OptInterp::infer(self, input)
    }

    fn supports(&self, spec: &ModelSpec) -> bool {
        crate::nn::interp::Capabilities::FULL.supports(spec)
    }

    fn compile_ms(&self) -> f64 {
        self.c.compile_ms
    }

    fn memory_bytes(&self) -> Option<usize> {
        Some(self.arena_bytes())
    }
}

impl k::Epilogue<'_> {
    /// Apply over a whole buffer, channel-cyclic for the post-affine.
    pub fn apply_whole(&self, buf: &mut [f32], c: usize) {
        if self.post.is_none() {
            // activation only — channel-independent
            let ep = k::Epilogue { act: self.act, approx: self.approx, post: None };
            for chunk in buf.chunks_mut(c.max(1)) {
                ep.apply(chunk);
            }
        } else {
            for chunk in buf.chunks_mut(c) {
                self.apply(chunk);
            }
        }
    }
}

fn exact_softmax_row(row: &mut [f32]) {
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for v in row.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builder::tiny_cnn;
    use crate::nn::interp::NaiveInterp;
    use crate::util::rng::SplitMix64;

    fn input(batch: usize, seed: u64) -> Tensor {
        let mut rng = SplitMix64::new(seed);
        Tensor::from_vec(&[batch, 8, 8, 3], rng.uniform_vec(batch * 8 * 8 * 3))
    }

    #[test]
    fn matches_naive_exact_options() {
        let spec = tiny_cnn(21);
        let naive = NaiveInterp::new(spec.clone()).unwrap();
        let mut opt = OptInterp::new(
            &spec,
            CompileOptions { fold_bn: true, approx: false, reuse_memory: true },
        )
        .unwrap();
        let x = input(2, 77);
        let a = naive.infer(&x).unwrap();
        let b = opt.infer(&x).unwrap();
        let d = a[0].max_abs_diff(&b[0]);
        assert!(d < 1e-4, "diff {d}");
    }

    #[test]
    fn approx_stays_close() {
        let spec = tiny_cnn(22);
        let naive = NaiveInterp::new(spec.clone()).unwrap();
        let mut opt = OptInterp::new(&spec, CompileOptions::default()).unwrap();
        let x = input(1, 5);
        let a = naive.infer(&x).unwrap();
        let b = opt.infer(&x).unwrap();
        // softmax on fast exp: bounded by the §3.4 error analysis
        assert!(a[0].max_abs_diff(&b[0]) < 0.05);
    }

    #[test]
    fn all_option_combos_run() {
        let spec = tiny_cnn(23);
        let x = input(1, 9);
        for fold in [false, true] {
            for approx in [false, true] {
                for reuse in [false, true] {
                    let mut e = OptInterp::new(
                        &spec,
                        CompileOptions { fold_bn: fold, approx, reuse_memory: reuse },
                    )
                    .unwrap();
                    let out = e.infer(&x).unwrap();
                    assert_eq!(out[0].shape(), &[1, 10]);
                    let s: f32 = out[0].data().iter().sum();
                    assert!((s - 1.0).abs() < 1e-3, "fold={fold} approx={approx}: {s}");
                }
            }
        }
    }

    #[test]
    fn memory_reuse_shrinks_arena() {
        let spec = tiny_cnn(24);
        let x = input(1, 10);
        let mut with = OptInterp::new(
            &spec,
            CompileOptions { reuse_memory: true, ..Default::default() },
        )
        .unwrap();
        let mut without = OptInterp::new(
            &spec,
            CompileOptions { reuse_memory: false, ..Default::default() },
        )
        .unwrap();
        with.infer(&x).unwrap();
        without.infer(&x).unwrap();
        assert!(with.arena_bytes() < without.arena_bytes());
        // and identical outputs
        let a = with.infer(&x).unwrap();
        let b = without.infer(&x).unwrap();
        assert!(a[0].max_abs_diff(&b[0]) < 1e-6);
    }

    #[test]
    fn repeated_inference_is_stable() {
        // arena reuse must not leak state between calls
        let spec = tiny_cnn(25);
        let mut e = OptInterp::new(&spec, CompileOptions::default()).unwrap();
        let x = input(1, 11);
        let a = e.infer(&x).unwrap();
        for _ in 0..3 {
            let b = e.infer(&x).unwrap();
            assert!(a[0].max_abs_diff(&b[0]) == 0.0);
        }
    }

    #[test]
    fn batch_switch_reallocates() {
        let spec = tiny_cnn(26);
        let mut e = OptInterp::new(&spec, CompileOptions::default()).unwrap();
        e.infer(&input(1, 1)).unwrap();
        let out = e.infer(&input(4, 2)).unwrap();
        assert_eq!(out[0].shape(), &[4, 10]);
    }

    #[test]
    fn rejects_wrong_shape() {
        let spec = tiny_cnn(27);
        let mut e = OptInterp::new(&spec, CompileOptions::default()).unwrap();
        let bad = Tensor::zeros(&[1, 4, 4, 3]);
        assert!(e.infer(&bad).is_err());
    }
}
