//! §3.5 merging passes on the Rust side — the exact transformation
//! `python/compile/optimize.py` applies before AOT lowering, here feeding
//! the optimized interpreter. Integration tests check both sides agree.
//!
//! * BN after a *linear* conv/dwconv/dense → folded into kernel + bias.
//! * BN after a *nonlinear* producer → fused post-activation affine
//!   (`post_scale`), applied in the producer's store loop.
//! * Only single-consumer producers are folded (a second consumer would
//!   observe the un-normalized tensor).
//! * Conv → single-consumer MaxPool pairs are selected here
//!   ([`fusible_maxpool_pairs`]) for the §3.4 store-loop merge: the
//!   `Program` lowering runs the pool inside the conv kernel and the conv
//!   intermediate never materializes.

use std::collections::BTreeMap;

use crate::model::spec::{Activation, LayerOp, ModelSpec, WeightRef};

fn consumers(spec: &ModelSpec, name: &str) -> usize {
    spec.layers.iter().filter(|l| l.inputs.iter().any(|i| i == name)).count()
        + spec.outputs.iter().filter(|o| *o == name).count()
}

/// Append a tensor to the blob, returning its ref.
fn append(blob: &mut Vec<f32>, data: &[f32]) -> WeightRef {
    let offset = blob.len();
    blob.extend_from_slice(data);
    WeightRef { offset, shape: vec![data.len()] }
}

/// Fold every eligible batchnorm. Returns the rewritten spec; layer count
/// shrinks by the number of folded BNs and the blob may grow (materialized
/// biases / post-affine vectors).
pub fn fold_batchnorm(spec: &ModelSpec) -> ModelSpec {
    let mut out = spec.clone();
    let mut blob = std::mem::take(&mut out.weights);
    let mut removed: BTreeMap<String, String> = BTreeMap::new(); // bn -> producer

    // Pass 1: decide folds and rewrite producers. (Index loop: the body
    // mutates `out.layers[pi]` for other indices, so no iterator borrow.)
    let producer_names: Vec<String> = out.layers.iter().map(|l| l.name.clone()).collect();
    #[allow(clippy::needless_range_loop)]
    for bi in 0..out.layers.len() {
        let (op, name, input) = {
            let l = &out.layers[bi];
            (l.op.clone(), l.name.clone(), l.inputs[0].clone())
        };
        let eps = match op {
            LayerOp::BatchNorm { epsilon } => epsilon,
            _ => continue,
        };
        let Some(pi) = producer_names.iter().position(|n| *n == input) else {
            continue; // BN directly on the model input
        };
        let foldable = matches!(
            out.layers[pi].op,
            LayerOp::Conv2d { .. } | LayerOp::DepthwiseConv2d { .. } | LayerOp::Dense { .. }
        );
        if !foldable || out.layers[pi].post_scale {
            continue;
        }
        // `spec` (original) is fine for consumer counting: folding never
        // changes edges of un-removed layers.
        if consumers(spec, &input) != 1 {
            continue;
        }
        let (scale, shift) = {
            let bn = &out.layers[bi];
            // weight refs of BN point into the original blob region, which
            // is a prefix of `blob` (we only append), so read directly:
            let g = read(&blob, bn.weights.get("gamma").unwrap());
            let b = read(&blob, bn.weights.get("beta").unwrap());
            let m = read(&blob, bn.weights.get("mean").unwrap());
            let v = read(&blob, bn.weights.get("var").unwrap());
            let scale: Vec<f32> = (0..g.len()).map(|i| g[i] / (v[i] + eps).sqrt()).collect();
            let shift: Vec<f32> = (0..g.len()).map(|i| b[i] - m[i] * scale[i]).collect();
            (scale, shift)
        };

        let prod = &mut out.layers[pi];
        if prod.activation == Activation::Linear {
            // fold into weights
            let kref = prod.weights.get("kernel").unwrap().clone();
            let mut kernel = read(&blob, &kref).to_vec();
            match &prod.op {
                LayerOp::Conv2d { .. } => {
                    let oc = *kref.shape.last().unwrap();
                    for (i, v) in kernel.iter_mut().enumerate() {
                        *v *= scale[i % oc];
                    }
                }
                LayerOp::DepthwiseConv2d { .. } => {
                    // [kh, kw, C, 1] — channel axis is dim 2
                    let c = kref.shape[2];
                    for (i, v) in kernel.iter_mut().enumerate() {
                        *v *= scale[i % c];
                    }
                }
                LayerOp::Dense { .. } => {
                    let oc = kref.shape[1];
                    for (i, v) in kernel.iter_mut().enumerate() {
                        *v *= scale[i % oc];
                    }
                }
                _ => unreachable!(),
            }
            let new_kref = append(&mut blob, &kernel);
            prod.weights.insert(
                "kernel".into(),
                WeightRef { offset: new_kref.offset, shape: kref.shape.clone() },
            );
            let has_bias = prod.weights.contains_key("bias");
            if has_bias {
                let bref = prod.weights.get("bias").unwrap().clone();
                let bias = read(&blob, &bref);
                let new_bias: Vec<f32> = bias
                    .iter()
                    .enumerate()
                    .map(|(i, &b)| b * scale[i] + shift[i])
                    .collect();
                let nref = append(&mut blob, &new_bias);
                prod.weights.insert("bias".into(), nref);
            } else {
                let nref = append(&mut blob, &shift);
                prod.weights.insert("bias".into(), nref);
                set_use_bias(&mut prod.op);
            }
        } else {
            // §3.5: BN across the activation → post-activation affine.
            prod.post_scale = true;
            let sref = append(&mut blob, &scale);
            prod.weights.insert("post_scale_w".into(), sref);
            let href = append(&mut blob, &shift);
            prod.weights.insert("post_shift_w".into(), href);
        }
        removed.insert(name, input);
    }

    // Pass 2: drop folded BNs, rewire consumers and outputs.
    out.layers.retain(|l| !removed.contains_key(&l.name));
    for l in &mut out.layers {
        for i in &mut l.inputs {
            if let Some(rep) = removed.get(i) {
                *i = rep.clone();
            }
        }
    }
    for o in &mut out.outputs {
        if let Some(rep) = removed.get(o) {
            *o = rep.clone();
        }
    }
    out.weights = blob;
    out
}

fn read<'a>(blob: &'a [f32], r: &WeightRef) -> &'a [f32] {
    &blob[r.offset..r.offset + r.size()]
}

fn set_use_bias(op: &mut LayerOp) {
    match op {
        LayerOp::Conv2d { use_bias, .. } | LayerOp::DepthwiseConv2d { use_bias, .. } => {
            *use_bias = true
        }
        _ => {}
    }
}

/// §3.4 operation merging: conv → MaxPool pairs whose pool can run inside
/// the conv's store loop. Returns conv name → pool name. Requirements:
///
/// * the pool's input is a `Conv2d` with no other consumer (a second
///   consumer would need the un-pooled tensor materialized);
/// * pool windows do not overlap (`stride >= max(kh, kw)`), so no conv
///   pixel is computed twice;
/// * the pool layer carries no activation/affine of its own (those belong
///   to the conv's epilogue, which runs *before* the max — the unfused
///   order).
pub fn fusible_maxpool_pairs(spec: &ModelSpec) -> BTreeMap<String, String> {
    let mut pairs = BTreeMap::new();
    for l in &spec.layers {
        let LayerOp::MaxPool { kh, kw, stride } = l.op else {
            continue;
        };
        if stride < kh.max(kw) || l.activation != Activation::Linear || l.post_scale {
            continue;
        }
        let src = &l.inputs[0];
        let Some(producer) = spec.layers.iter().find(|p| &p.name == src) else {
            continue; // pooling the model input directly
        };
        if !matches!(producer.op, LayerOp::Conv2d { .. }) {
            continue;
        }
        if consumers(spec, src) != 1 {
            continue;
        }
        pairs.insert(src.clone(), l.name.clone());
    }
    pairs
}

/// Count of BN layers remaining (ablation metric).
pub fn bn_count(spec: &ModelSpec) -> usize {
    spec.layers
        .iter()
        .filter(|l| matches!(l.op, LayerOp::BatchNorm { .. }))
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builder::{tiny_cnn, Builder};
    use crate::nn::interp::NaiveInterp;
    use crate::nn::tensor::Tensor;
    use crate::util::rng::SplitMix64;

    fn run(spec: &ModelSpec, x: &Tensor) -> Tensor {
        NaiveInterp::new(spec.clone()).unwrap().infer(x).unwrap().remove(0)
    }

    #[test]
    fn fold_tiny_cnn_equivalent() {
        let spec = tiny_cnn(11);
        let folded = fold_batchnorm(&spec);
        assert_eq!(bn_count(&folded), 0);
        assert_eq!(folded.layers.len(), spec.layers.len() - 1);
        folded.validate().unwrap();
        let mut rng = SplitMix64::new(5);
        let x = Tensor::from_vec(&[2, 8, 8, 3], rng.uniform_vec(2 * 8 * 8 * 3));
        let a = run(&spec, &x);
        let b = run(&folded, &x);
        assert!(a.max_abs_diff(&b) < 1e-4, "diff {}", a.max_abs_diff(&b));
    }

    #[test]
    fn fold_across_activation_sets_post_scale() {
        // tiny_cnn's conv has ReLU → BN must become post_scale, not weights.
        let folded = fold_batchnorm(&tiny_cnn(3));
        let conv = folded.layer("conv1").unwrap();
        assert!(conv.post_scale);
        assert!(conv.weights.contains_key("post_scale_w"));
    }

    #[test]
    fn fold_linear_conv_into_weights() {
        let mut b = Builder::new("t", &[6, 6, 2], 9);
        let c = b.conv2d("input", 3, 3, 1, Activation::Linear);
        let bn = b.batchnorm(&c);
        let spec = b.finish(&[&bn]);
        let folded = fold_batchnorm(&spec);
        assert_eq!(folded.layers.len(), 1);
        assert!(!folded.layers[0].post_scale);
        let mut rng = SplitMix64::new(6);
        let x = Tensor::from_vec(&[1, 6, 6, 2], rng.uniform_vec(72));
        assert!(run(&spec, &x).max_abs_diff(&run(&folded, &x)) < 1e-4);
    }

    #[test]
    fn fold_skips_bn_on_input() {
        let mut b = Builder::new("t", &[4, 4, 2], 9);
        let bn = b.batchnorm("input");
        let c = b.conv2d(&bn, 2, 1, 1, Activation::Linear);
        let spec = b.finish(&[&c]);
        let folded = fold_batchnorm(&spec);
        assert_eq!(bn_count(&folded), 1); // nothing to fold into upstream
    }

    #[test]
    fn fold_agrees_with_python_on_real_model() {
        // c_bh has conv+relu→bn twice; skipped silently if artifacts absent
        // (integration tests cover it with the real files).
        let dir = std::path::Path::new("models");
        if !dir.join("c_bh.json").exists() {
            return;
        }
        let spec = crate::model::load::load_model(dir, "c_bh").unwrap();
        let folded = fold_batchnorm(&spec);
        assert_eq!(bn_count(&folded), 0);
        let mut rng = SplitMix64::new(1);
        let x = Tensor::from_vec(&[1, 32, 32, 1], rng.uniform_vec(32 * 32));
        let a = run(&spec, &x);
        let b = run(&folded, &x);
        assert!(a.max_abs_diff(&b) < 1e-4);
    }

    #[test]
    fn fold_is_idempotent() {
        let spec = tiny_cnn(31);
        let once = fold_batchnorm(&spec);
        let twice = fold_batchnorm(&once);
        assert_eq!(once.layers.len(), twice.layers.len());
        let mut rng = SplitMix64::new(9);
        let x = Tensor::from_vec(&[1, 8, 8, 3], rng.uniform_vec(8 * 8 * 3));
        assert!(run(&once, &x).max_abs_diff(&run(&twice, &x)) < 1e-6);
    }

    #[test]
    fn maxpool_pairs_require_single_consumer_conv() {
        // tiny_cnn after folding: conv (ReLU + post-affine) → maxpool,
        // single consumer → fusible.
        let folded = fold_batchnorm(&tiny_cnn(12));
        let pairs = fusible_maxpool_pairs(&folded);
        assert_eq!(pairs.len(), 1, "{pairs:?}");
        assert!(pairs.contains_key("conv1"), "{pairs:?}");

        // unfolded: the BN between conv and pool means the pool's input is
        // not a conv → nothing fusible.
        assert!(fusible_maxpool_pairs(&tiny_cnn(12)).is_empty());

        // a second consumer of the conv blocks fusion.
        let mut b = Builder::new("t", &[4, 4, 2], 7);
        let c = b.conv2d("input", 2, 3, 1, Activation::Relu);
        let p = b.maxpool(&c, 2);
        let spec = b.finish(&[&p, &c]); // conv is also a model output
        assert!(fusible_maxpool_pairs(&spec).is_empty());
    }

    #[test]
    fn property_fold_preserves_semantics_on_random_graphs() {
        use crate::util::propcheck::check;
        check("fold_semantics", 25, |r: &mut SplitMix64| {
            let mut b = Builder::new("rand", &[6, 6, 2], r.next_u64());
            let mut cur = "input".to_string();
            for _ in 0..2 + r.below(4) {
                match r.below(3) {
                    0 => {
                        let act = if r.below(2) == 0 { Activation::Relu } else { Activation::Linear };
                        cur = b.conv2d(&cur, 1 + r.below(4), 1 + 2 * r.below(2), 1, act);
                    }
                    1 => cur = b.batchnorm(&cur),
                    _ => {
                        let act = if r.below(2) == 0 { Activation::Tanh } else { Activation::Linear };
                        cur = b.conv2d(&cur, 2, 3, 1, act);
                    }
                }
            }
            let out = cur.clone();
            (b.finish(&[&out]), r.next_u64())
        }, |(spec, seed)| {
            let folded = fold_batchnorm(spec);
            folded.validate().map_err(|e| e.to_string())?;
            let mut rng = SplitMix64::new(*seed);
            let x = Tensor::from_vec(&[1, 6, 6, 2], rng.uniform_vec(72));
            let d = run(spec, &x).max_abs_diff(&run(&folded, &x));
            if d < 1e-3 { Ok(()) } else { Err(format!("diff {d}")) }
        });
    }
}
