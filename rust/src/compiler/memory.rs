//! §3.2 tensor-lifetime analysis and memory planning.
//!
//! "the inputs and outputs of all nodes are assigned to actual memory
//! locations, taking into account that tensors with overlapping lifetimes
//! must use different memory. At this stage, the individual layer compilers
//! can indicate whether they want any of their outputs to use the memory of
//! an input tensor that is not referenced afterwards."
//!
//! The planner works on element counts per batch item (shapes are static);
//! the executor scales by the batch size. Strategy: linear-scan over the
//! topologically-ordered layers with a free-list of retired buffers,
//! first-fit by size, plus explicit in-place aliasing for elementwise units.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;

use anyhow::Result;

use crate::model::spec::{LayerOp, ModelSpec};
use crate::nn::simd::WeightDtype;

/// Per-dtype byte accounting for the weight storage a lowered program
/// actually retains — the §3.3 dtype refactor's headline metric. Packed
/// panels land in the bucket of their storage dtype (i8 including the
/// dequantization scale vector); raw f32 side tables (generic kernels,
/// rotated/broadcast tail layouts, biases are *not* counted here — see
/// `PlanSummary::weight_elems` for the element view) stay in `f32_bytes`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WeightBytes {
    /// Bytes stored as full-precision f32.
    pub f32_bytes: usize,
    /// Bytes stored as bf16 panels.
    pub bf16_bytes: usize,
    /// Bytes stored as i8 panels (per-channel scales included).
    pub i8_bytes: usize,
}

impl WeightBytes {
    /// Add `bytes` to the bucket for `dtype`.
    pub fn add(&mut self, dtype: WeightDtype, bytes: usize) {
        match dtype {
            WeightDtype::F32 => self.f32_bytes += bytes,
            WeightDtype::Bf16 => self.bf16_bytes += bytes,
            WeightDtype::I8 => self.i8_bytes += bytes,
        }
    }

    /// Bytes in the bucket for `dtype`.
    pub fn of(&self, dtype: WeightDtype) -> usize {
        match dtype {
            WeightDtype::F32 => self.f32_bytes,
            WeightDtype::Bf16 => self.bf16_bytes,
            WeightDtype::I8 => self.i8_bytes,
        }
    }

    /// Total resident packed-weight bytes across dtypes.
    pub fn total(&self) -> usize {
        self.f32_bytes + self.bf16_bytes + self.i8_bytes
    }
}

impl fmt::Display for WeightBytes {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} B (f32 {} / bf16 {} / i8 {})",
            self.total(),
            self.f32_bytes,
            self.bf16_bytes,
            self.i8_bytes
        )
    }
}

/// Which layers may write their output over their (dead) first input.
pub fn can_run_in_place(op: &LayerOp) -> bool {
    matches!(
        op,
        LayerOp::BatchNorm { .. }
            | LayerOp::Activation
            | LayerOp::Softmax
            | LayerOp::Add
            | LayerOp::Flatten
    )
}

/// The §3.2 planner's result: every tensor's buffer assignment plus the
/// ablation counters (`naive_total`, `in_place_hits`).
#[derive(Debug, Clone)]
pub struct MemoryPlan {
    /// tensor name → buffer id ("input" included).
    pub buffer_of: BTreeMap<String, usize>,
    /// buffer id → capacity in f32 elements (per batch item).
    pub buffer_sizes: Vec<usize>,
    /// Σ tensor sizes (what a no-reuse allocator would use), for the ablation.
    pub naive_total: usize,
    /// Count of in-place aliases taken.
    pub in_place_hits: usize,
}

impl MemoryPlan {
    /// Peak arena footprint in elements (per batch item).
    pub fn peak_elements(&self) -> usize {
        self.buffer_sizes.iter().sum()
    }
}

/// Plan buffers for `spec`. `reuse = false` gives every tensor its own
/// buffer (the ablation baseline).
pub fn plan(spec: &ModelSpec, reuse: bool) -> Result<MemoryPlan> {
    plan_elided(spec, reuse, &BTreeSet::new())
}

/// Follow §3.4-elided producer edges to the tensor a consumer actually
/// reads (a fused conv's consumer reads the conv's *input*).
fn resolve<'a>(source_of: &BTreeMap<&'a str, &'a str>, name: &'a str) -> &'a str {
    let mut n = name;
    while let Some(&s) = source_of.get(n) {
        n = s;
    }
    n
}

/// [`plan`] with §3.4-fused intermediates elided: tensors in `elided` never
/// materialize (their single consumer runs the producer inside its own
/// store loop, reading the producer's input), so they get no buffer — and
/// their consumer extends the producer's *input* lifetime to the consumer's
/// position instead.
pub fn plan_elided(
    spec: &ModelSpec,
    reuse: bool,
    elided: &BTreeSet<String>,
) -> Result<MemoryPlan> {
    let shapes = spec.infer_shapes()?;
    let size_of = |name: &str| -> usize { shapes[name].iter().product() };

    // elided tensor → the tensor its consumer reads in its place.
    let source_of: BTreeMap<&str, &str> = spec
        .layers
        .iter()
        .filter(|l| elided.contains(&l.name))
        .map(|l| (l.name.as_str(), l.inputs[0].as_str()))
        .collect();

    // last use index per materialized tensor; outputs live forever.
    let mut last_use: BTreeMap<&str, usize> = BTreeMap::new();
    last_use.insert("input", 0);
    for (i, l) in spec.layers.iter().enumerate() {
        for inp in &l.inputs {
            last_use.insert(resolve(&source_of, inp.as_str()), i);
        }
    }
    let eternal = spec.layers.len();
    for o in &spec.outputs {
        last_use.insert(o.as_str(), eternal);
    }

    let mut buffer_of: BTreeMap<String, usize> = BTreeMap::new();
    let mut buffer_sizes: Vec<usize> = Vec::new();
    let mut free: Vec<usize> = Vec::new(); // retired buffer ids
    let mut in_place_hits = 0usize;

    // the model input owns buffer 0
    buffer_of.insert("input".into(), 0);
    buffer_sizes.push(size_of("input"));

    let mut naive_total = size_of("input");

    for (i, l) in spec.layers.iter().enumerate() {
        if elided.contains(&l.name) {
            continue; // never materializes: no buffer, nothing to retire
        }
        let need = size_of(&l.name);
        naive_total += need;
        if !reuse {
            buffer_of.insert(l.name.clone(), buffer_sizes.len());
            buffer_sizes.push(need);
            continue;
        }

        // 1) in-place: output overwrites first input if the unit allows it,
        //    the input dies here, and capacity suffices.
        let first = resolve(&source_of, l.inputs[0].as_str());
        let first_dead = last_use.get(first).copied() == Some(i);
        let mut assigned = None;
        if can_run_in_place(&l.op) && first_dead {
            let b = buffer_of[first];
            if buffer_sizes[b] >= need {
                assigned = Some(b);
                in_place_hits += 1;
            }
        }
        // 2) otherwise first-fit from the free list (grow smallest fit).
        let b = match assigned {
            Some(b) => b,
            None => {
                if let Some(pos) = free
                    .iter()
                    .position(|&f| buffer_sizes[f] >= need)
                    .or_else(|| if free.is_empty() { None } else { Some(0) })
                {
                    let id = free.remove(pos);
                    buffer_sizes[id] = buffer_sizes[id].max(need);
                    id
                } else {
                    buffer_sizes.push(need);
                    buffer_sizes.len() - 1
                }
            }
        };
        buffer_of.insert(l.name.clone(), b);

        // 3) retire buffers whose tensor dies at this layer (and wasn't
        //    just aliased to the new output).
        for inp in &l.inputs {
            let inp = resolve(&source_of, inp.as_str());
            if last_use.get(inp).copied() == Some(i) {
                let ib = buffer_of[inp];
                if ib != b && !free.contains(&ib) {
                    free.push(ib);
                }
            }
        }
    }

    Ok(MemoryPlan { buffer_of, buffer_sizes, naive_total, in_place_hits })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builder::{random_chain, tiny_cnn};
    use crate::util::propcheck::check;
    use crate::util::rng::SplitMix64;

    /// No two tensors with overlapping lifetimes may share a buffer — the
    /// §3.2 invariant, checked against an O(n²) oracle.
    fn overlap_free(spec: &ModelSpec, p: &MemoryPlan) -> Result<(), String> {
        // def index: input = before layer 0; layer i defines at i+1 "time".
        let mut def: BTreeMap<&str, usize> = BTreeMap::new();
        def.insert("input", 0);
        let mut last: BTreeMap<&str, usize> = BTreeMap::new();
        last.insert("input", 0);
        for (i, l) in spec.layers.iter().enumerate() {
            def.insert(&l.name, i + 1);
            last.insert(&l.name, i + 1);
            for inp in &l.inputs {
                last.insert(inp.as_str(), i + 1);
            }
        }
        let eternal = spec.layers.len() + 1;
        for o in &spec.outputs {
            last.insert(o.as_str(), eternal);
        }
        let names: Vec<&str> = def.keys().copied().collect();
        for (ai, &a) in names.iter().enumerate() {
            for &b in &names[ai + 1..] {
                if p.buffer_of[a] != p.buffer_of[b] {
                    continue;
                }
                // Sharing is legal iff lifetimes are disjoint, or b is the
                // in-place successor of a (def_b == last_a and unit allows
                // in-place). Conservatively allow def == last boundary.
                let (da, la) = (def[a], last[a]);
                let (db, lb) = (def[b], last[b]);
                let disjoint = la <= db || lb <= da;
                if !disjoint {
                    return Err(format!("`{a}` [{da},{la}] and `{b}` [{db},{lb}] share buffer {}", p.buffer_of[a]));
                }
            }
        }
        Ok(())
    }

    #[test]
    fn plan_tiny_reuses() {
        let spec = tiny_cnn(2);
        let p = plan(&spec, true).unwrap();
        assert!(p.peak_elements() < p.naive_total, "{p:?}");
        assert!(p.in_place_hits >= 1, "{p:?}"); // bn and softmax are in-place
        overlap_free(&spec, &p).unwrap();
    }

    #[test]
    fn plan_no_reuse_matches_naive() {
        let spec = tiny_cnn(2);
        let p = plan(&spec, false).unwrap();
        assert_eq!(p.peak_elements(), p.naive_total);
    }

    #[test]
    fn elided_intermediates_get_no_buffer_and_keep_input_alive() {
        use crate::model::builder::Builder;
        use crate::model::spec::Activation;
        let mut b = Builder::new("t", &[4, 4, 2], 5);
        let c = b.conv2d("input", 2, 3, 1, Activation::Relu);
        let p = b.maxpool(&c, 2);
        let spec = b.finish(&[&p]);
        let mut elided = BTreeSet::new();
        elided.insert(c.clone());
        let fused = plan_elided(&spec, true, &elided).unwrap();
        // the fused-away conv tensor owns no buffer …
        assert!(!fused.buffer_of.contains_key(&c), "{fused:?}");
        // … its consumer reads the conv's input, so the pool output must
        // not alias it …
        assert_ne!(fused.buffer_of[&p], fused.buffer_of["input"], "{fused:?}");
        // … and dropping the intermediate never grows the arena.
        let unfused = plan(&spec, true).unwrap();
        assert!(fused.peak_elements() <= unfused.peak_elements());
        assert!(fused.naive_total < unfused.naive_total);
    }

    #[test]
    fn property_no_overlapping_lifetimes_share_buffers() {
        check(
            "planner_no_overlap",
            60,
            |r: &mut SplitMix64| random_chain(r),
            |spec| {
                let p = plan(spec, true).map_err(|e| e.to_string())?;
                overlap_free(spec, &p)?;
                if p.peak_elements() > p.naive_total {
                    return Err("reuse plan larger than naive".into());
                }
                Ok(())
            },
        );
    }
}
