//! The paper's compiler: §3.5 merging passes (`fuse`), §3.2 lifetime/memory
//! planning (`memory`), §3.3 cost model (`cost`), fused allocation-free
//! kernels (`kernels`), the pre-resolved execution IR (`program`: spec →
//! fold → plan → lower → run), the optimized-interpreter engine shell
//! over it (`exec`), and the persistent compiled-artifact format + cache
//! (`artifact`: save/mmap-load a lowered program so cold-start skips
//! fold/plan/pack entirely).
pub mod artifact;
pub mod cost;
pub mod exec;
pub mod fuse;
pub mod kernels;
pub mod memory;
pub mod program;
pub mod silvermont;
