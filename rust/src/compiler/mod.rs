//! The paper's compiler: §3.5 merging passes (`fuse`), §3.2 lifetime/memory
//! planning (`memory`), §3.3 cost model (`cost`), fused allocation-free
//! kernels (`kernels`) and the optimized-interpreter engine (`exec`).
pub mod cost;
pub mod exec;
pub mod fuse;
pub mod kernels;
pub mod memory;
pub mod silvermont;
