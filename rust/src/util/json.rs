//! From-scratch JSON parser and serializer.
//!
//! The paper's library "includes a custom implementation of a JSON parser to
//! obtain the model architecture" (§3.1) — we do the same (serde is also
//! unavailable in the offline build environment). Supports the full JSON
//! grammar minus exotic number forms. Non-negative integer tokens parse as
//! [`Json::UInt`] and stay exact over the full u64 range (wire-protocol
//! request ids must not round through f64, which corrupts values ≥ 2^53);
//! everything else numeric is kept as f64, lossless for every
//! offset/shape/weight this repo serializes. `UInt` and `Num` compare
//! numerically equal, so callers never care which variant a token took.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone)]
pub enum Json {
    Null,
    Bool(bool),
    /// A non-negative integer kept exact (never rounded through f64): the
    /// parser produces this for bare digit runs that fit u64, and id-like
    /// fields serialize through it losslessly.
    UInt(u64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// `UInt` and `Num` are two spellings of "JSON number"; values equal when
/// the numbers are (everything else is structural). Keeps `parse(to_string
/// (v)) == v` even where serializing code built a `Num` and the re-parse
/// produced a `UInt`.
impl PartialEq for Json {
    fn eq(&self, other: &Json) -> bool {
        match (self, other) {
            (Json::Null, Json::Null) => true,
            (Json::Bool(a), Json::Bool(b)) => a == b,
            (Json::UInt(a), Json::UInt(b)) => a == b,
            (Json::Num(a), Json::Num(b)) => a == b,
            (Json::UInt(a), Json::Num(b)) | (Json::Num(b), Json::UInt(a)) => *b == *a as f64,
            (Json::Str(a), Json::Str(b)) => a == b,
            (Json::Arr(a), Json::Arr(b)) => a == b,
            (Json::Obj(a), Json::Obj(b)) => a == b,
            _ => false,
        }
    }
}

/// Parse error with byte offset for debugging malformed specs.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors (None on type mismatch) --------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            Json::UInt(n) => Some(*n as f64),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::UInt(n) => usize::try_from(*n).ok(),
            _ => self.as_f64().map(|n| n as usize),
        }
    }
    /// Exact u64 view: `UInt` verbatim; `Num` only when integral and in
    /// range (so `7.0` passes but `7.5`, negatives and `1e300` are
    /// rejected — the wire protocol refuses non-integral ids).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::UInt(n) => Some(*n),
            Json::Num(n) if n.fract() == 0.0 && *n >= 0.0 && *n < u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// `[1, 2, 3]` → `vec![1, 2, 3]`; None if any element is non-numeric.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // -- required-field accessors (anyhow context) -------------------------
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json field `{key}`"))
    }
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("field `{key}` is not a string"))
    }
    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("field `{key}` is not a number"))
    }
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("field `{key}` is not a number"))
    }
    pub fn req_u64(&self, key: &str) -> anyhow::Result<u64> {
        self.req(key)?
            .as_u64()
            .ok_or_else(|| anyhow::anyhow!("field `{key}` is not an unsigned integer"))
    }
    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("field `{key}` is not an array"))
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected eof"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte `{}`", c as char))),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            out.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("eof in string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("eof in escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs: JSON specs here never emit them,
                            // but handle the happy path for completeness.
                            if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    let hex2 = std::str::from_utf8(&self.b[self.i + 2..self.i + 6])
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    self.i += 6;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    out.push(char::from_u32(c).ok_or_else(|| self.err("bad surrogate"))?);
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                out.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // multi-byte UTF-8 passthrough
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("bad utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        // Bare digit runs that fit u64 stay exact; everything else
        // (signs, fractions, exponents, > u64 digits) goes through f64.
        if s.bytes().all(|b| b.is_ascii_digit()) {
            if let Ok(u) = s.parse::<u64>() {
                return Ok(Json::UInt(u));
            }
        }
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------- writer

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::UInt(n) => write!(f, "{n}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(
            Json::parse(r#""😀""#).unwrap(),
            Json::Str("😀".into())
        );
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"format":"nnspec-v1","layers":[{"name":"c1","op":"conv2d","stride":2}],"weights_len":123}"#;
        let v = Json::parse(src).unwrap();
        let printed = v.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), v);
    }

    #[test]
    fn usize_vec() {
        let v = Json::parse("[3, 3, 1, 8]").unwrap();
        assert_eq!(v.as_usize_vec().unwrap(), vec![3, 3, 1, 8]);
        assert!(Json::parse("[3, \"x\"]").unwrap().as_usize_vec().is_none());
    }

    #[test]
    fn u64_roundtrips_losslessly_at_the_2_53_boundary() {
        // f64 has 53 mantissa bits: 2^53 + 1 is the first unrepresentable
        // integer. Ids must survive parse → print → parse bit-exactly well
        // past it, all the way to u64::MAX.
        for v in [
            (1u64 << 53) - 1,
            1u64 << 53,
            (1u64 << 53) + 1,
            u64::MAX - 1,
            u64::MAX,
        ] {
            let line = Json::UInt(v).to_string();
            assert_eq!(line, v.to_string(), "integer formatting must be exact");
            let back = Json::parse(&line).unwrap();
            assert_eq!(back.as_u64(), Some(v), "u64 corrupted through the wire");
            assert_eq!(Json::parse(&back.to_string()).unwrap().as_u64(), Some(v));
        }
    }

    #[test]
    fn as_u64_rejects_non_integral_and_out_of_range() {
        assert_eq!(Json::parse("7.5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("-3").unwrap().as_u64(), None);
        assert_eq!(Json::parse("1e300").unwrap().as_u64(), None);
        // integral floats are accepted (7.0 is an integer id)
        assert_eq!(Json::Num(7.0).as_u64(), Some(7));
        assert_eq!(Json::parse("\"7\"").unwrap().as_u64(), None);
    }

    #[test]
    fn uint_and_num_compare_numerically() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::Num(42.0), Json::UInt(42));
        assert_ne!(Json::UInt(42), Json::Num(42.5));
        assert_eq!(
            Json::parse("[1,2]").unwrap(),
            Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)])
        );
    }

    #[test]
    fn req_errors_name_the_field() {
        let v = Json::parse("{\"a\":1}").unwrap();
        let e = v.req("missing").unwrap_err().to_string();
        assert!(e.contains("missing"), "{e}");
    }

    #[test]
    fn property_roundtrip_random_values() {
        use crate::util::propcheck::check;
        use crate::util::rng::SplitMix64;

        fn gen_value(r: &mut SplitMix64, depth: usize) -> Json {
            match if depth == 0 { r.below(4) } else { r.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(r.below(2) == 0),
                2 => Json::Num((r.next_uniform() as f64 * 1e6).round()),
                3 => {
                    let n = r.below(8);
                    Json::Str((0..n).map(|_| {
                        let c = r.below(128) as u8;
                        if c.is_ascii_graphic() || c == b' ' { c as char } else { 'x' }
                    }).collect())
                }
                4 => Json::Arr((0..r.below(4)).map(|_| gen_value(r, depth - 1)).collect()),
                _ => {
                    let mut m = BTreeMap::new();
                    for i in 0..r.below(4) {
                        m.insert(format!("k{i}"), gen_value(r, depth - 1));
                    }
                    Json::Obj(m)
                }
            }
        }

        check("json_roundtrip", 200, |r| gen_value(r, 3), |v| {
            let printed = v.to_string();
            let back = Json::parse(&printed).map_err(|e| e.to_string())?;
            if back == *v { Ok(()) } else { Err(format!("{printed} parsed differently")) }
        });
    }

    #[test]
    fn property_parser_never_panics_on_garbage() {
        use crate::util::propcheck::check;
        use crate::util::rng::SplitMix64;
        check("json_no_panic", 300, |r: &mut SplitMix64| {
            let n = r.below(40);
            (0..n).map(|_| r.below(256) as u8 as char).collect::<String>()
        }, |s| {
            let _ = Json::parse(s); // must return, never panic
            Ok(())
        });
    }
}
