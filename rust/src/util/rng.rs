//! From-scratch deterministic PRNG (the `rand` crate is unavailable in the
//! offline build).
//!
//! `SplitMix64` is bit-identical to `python/compile/testdata.py`, so golden
//! test inputs regenerate exactly on both sides of the artifact boundary.

/// SplitMix64 (Steele, Lea & Flood 2014). Full 2^64 period, passes BigCrush.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// f32 uniform in [-1, 1): top 24 bits / 2^23 − 1. Mirrors
    /// `testdata.splitmix_uniform` bit-for-bit (computed via f64 then cast).
    #[inline]
    pub fn next_uniform(&mut self) -> f32 {
        let top24 = (self.next_u64() >> 40) as f64;
        ((top24 / (1u64 << 23) as f64) - 1.0) as f32
    }

    /// `n` uniforms in [-1, 1).
    pub fn uniform_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.next_uniform()).collect()
    }

    /// Uniform usize in [0, bound) via Lemire's multiply-shift reduction
    /// (bias negligible for the bounds used in tests/benches).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        ((self.next_u64() as u128 * bound as u128) >> 64) as usize
    }

    /// f32 uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.next_uniform() * 0.5 + 0.5) * (hi - lo)
    }
}

/// The seed transformation used for golden inputs (matches aot.py).
pub fn golden_seed(model_seed: u64) -> u64 {
    model_seed ^ 0xDEAD_BEEF
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pinned_vectors_match_python() {
        // Anchors printed by python/compile/testdata.py (test_model.py pins
        // the same values on that side).
        let mut r = SplitMix64::new(1);
        assert_eq!(r.next_u64(), 0x910a_2dec_8902_5cc1);
        assert_eq!(r.next_u64(), 0xbeeb_8da1_658e_ec67);
        assert_eq!(r.next_u64(), 0xf893_a2ee_fb32_555e);
        assert_eq!(r.next_u64(), 0x71c1_8690_ee42_c90b);

        let mut r = SplitMix64::new(1);
        let expect = [0.13312304f32, 0.49156344, 0.9420054, -0.11128163];
        for e in expect {
            assert!((r.next_uniform() - e).abs() < 1e-7);
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = SplitMix64::new(99);
        for _ in 0..10_000 {
            let v = r.next_uniform();
            assert!((-1.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_in_bounds_and_spread() {
        let mut r = SplitMix64::new(7);
        let mut hits = [0usize; 10];
        for _ in 0..10_000 {
            hits[r.below(10)] += 1;
        }
        for h in hits {
            assert!(h > 700, "badly skewed: {hits:?}");
        }
    }

    #[test]
    fn deterministic() {
        let a: Vec<u64> = (0..8).map({ let mut r = SplitMix64::new(5); move |_| r.next_u64() }).collect();
        let b: Vec<u64> = (0..8).map({ let mut r = SplitMix64::new(5); move |_| r.next_u64() }).collect();
        assert_eq!(a, b);
    }
}
