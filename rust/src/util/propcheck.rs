//! Minimal property-based testing harness (`proptest` is unavailable in the
//! offline build).
//!
//! `check(name, cases, gen, prop)` runs `prop` over `cases` seeded random
//! inputs produced by `gen`. On failure it reports the seed and the debug
//! form of the failing case so the run can be reproduced exactly:
//!
//! ```text
//! property `planner_no_overlap` failed on case 37 (seed 0x9E37…):
//!   <Debug of case>
//! ```

use std::fmt::Debug;
use std::panic::{catch_unwind, AssertUnwindSafe};

use super::rng::SplitMix64;

/// Base seed; override with `PROPCHECK_SEED` to replay a failing run.
fn base_seed() -> u64 {
    std::env::var("PROPCHECK_SEED")
        .ok()
        .and_then(|s| u64::from_str_radix(s.trim_start_matches("0x"), 16).ok())
        .unwrap_or(0x5EED_CAFE_F00D_0001)
}

/// Run `prop` on `cases` generated inputs; panics with a reproducible report
/// on the first failure (either `Err` or an inner panic).
pub fn check<T, G, P>(name: &str, cases: usize, mut gen: G, prop: P)
where
    T: Debug,
    G: FnMut(&mut SplitMix64) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let seed0 = base_seed();
    for i in 0..cases {
        let case_seed = seed0.wrapping_add(i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = SplitMix64::new(case_seed);
        let case = gen(&mut rng);
        let outcome = catch_unwind(AssertUnwindSafe(|| prop(&case)));
        let failure = match outcome {
            Ok(Ok(())) => None,
            Ok(Err(msg)) => Some(msg),
            Err(p) => Some(format!(
                "panicked: {}",
                p.downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| p.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "<non-string panic>".into())
            )),
        };
        if let Some(msg) = failure {
            panic!(
                "property `{name}` failed on case {i} \
                 (replay: PROPCHECK_SEED={seed0:#x}):\n  case: {case:?}\n  {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially() {
        check("true", 50, |r| r.next_u64(), |_| Ok(()));
    }

    #[test]
    #[should_panic(expected = "property `fail_even`")]
    fn reports_failures() {
        check(
            "fail_even",
            50,
            |r| r.next_u64(),
            |v| if v % 2 == 0 { Err("even".into()) } else { Ok(()) },
        );
    }

    #[test]
    #[should_panic(expected = "panicked")]
    fn catches_panics() {
        check("panics", 5, |_| 0u32, |_| -> Result<(), String> { panic!("boom") });
    }
}
