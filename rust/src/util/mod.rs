//! From-scratch substrates: JSON, PRNG, property-testing (see DESIGN.md
//! substitution log — serde/rand/proptest are unavailable offline).
pub mod json;
pub mod propcheck;
pub mod rng;
