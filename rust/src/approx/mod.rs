//! §3.4 activation-function approximations — bit-identical algorithms to the
//! L1 Pallas kernels in `python/compile/kernels/activations.py`.
//!
//! SSE has no `exp`; the paper substitutes:
//!  * tanh — continued-fraction truncation (Eq. 5),
//!  * sigmoid — via tanh (Eq. 4),
//!  * exp — Schraudolph's IEEE-754 bit trick [14],
//!  * softmax — two passes over fast exp.
//!
//! These run in the optimized interpreter's fused store loops; `report()`
//! powers the `compiled-nn precision` command reproducing the paper's
//! precision discussion.

/// Schraudolph constants for f32 (same values as the Python kernel):
/// `i = A*x + (B - C)`, bits reinterpreted as f32.
pub const SCHRAUDOLPH_A: f32 = 8388608.0 / core::f32::consts::LN_2;
pub const SCHRAUDOLPH_B: f32 = 127.0 * 8388608.0;
pub const SCHRAUDOLPH_C: f32 = 366392.0;

/// Fast exp: one multiply, one float→int conversion, one add, one bitcast.
#[inline(always)]
pub fn fast_exp(x: f32) -> f32 {
    let i = (SCHRAUDOLPH_A * x + (SCHRAUDOLPH_B - SCHRAUDOLPH_C)) as i32;
    f32::from_bits(i as u32)
}

/// Fast tanh via the Eq. 5 rational approximation (4 continued-fraction
/// steps): numerator/denominator of degree-7/8 polynomials in x.
#[inline(always)]
pub fn fast_tanh(x: f32) -> f32 {
    let x2 = x * x;
    let num = (((36.0 * x2 + 6930.0) * x2 + 270270.0) * x2 + 2027025.0) * x;
    let den = (((x2 + 630.0) * x2 + 51975.0) * x2 + 945945.0) * x2 + 2027025.0;
    num / den
}

/// Fast sigmoid via Eq. 4: `(tanh(x/2) + 1) / 2`.
#[inline(always)]
pub fn fast_sigmoid(x: f32) -> f32 {
    (fast_tanh(0.5 * x) + 1.0) * 0.5
}

/// Width-generic lane form of [`fast_tanh`]: the Eq. 5 polynomials
/// evaluated lane-wise over one `W`-sized group — the vector form the §3.4
/// store-loop epilogues use, instantiated at every microkernel lane width
/// (`W ∈ {1, 4, 8, 16}`, see [`crate::nn::simd::LANE_WIDTHS`]). Every lane
/// performs exactly the scalar operation sequence through the same
/// separate num/den staging, so the result is **bit-identical** to
/// [`fast_tanh`] per lane at every width (asserted by
/// `lane_functions_bit_identical_to_scalar_over_working_ranges`).
#[inline(always)]
pub fn fast_tanh_w<const W: usize>(v: &mut [f32; W]) {
    let mut num = [0.0f32; W];
    let mut den = [0.0f32; W];
    for l in 0..W {
        let x = v[l];
        let x2 = x * x;
        num[l] = (((36.0 * x2 + 6930.0) * x2 + 270270.0) * x2 + 2027025.0) * x;
        den[l] = (((x2 + 630.0) * x2 + 51975.0) * x2 + 945945.0) * x2 + 2027025.0;
    }
    for l in 0..W {
        v[l] = num[l] / den[l];
    }
}

/// Width-generic lane form of [`fast_sigmoid`] (Eq. 4 over
/// [`fast_tanh_w`]); bit-identical to the scalar form per lane at every
/// width.
#[inline(always)]
pub fn fast_sigmoid_w<const W: usize>(v: &mut [f32; W]) {
    for x in v.iter_mut() {
        *x *= 0.5;
    }
    fast_tanh_w::<W>(v);
    for x in v.iter_mut() {
        *x = (*x + 1.0) * 0.5;
    }
}

/// 4-lane [`fast_tanh`] — the SSE-shaped instantiation of [`fast_tanh_w`].
#[inline(always)]
pub fn fast_tanh4(v: &mut [f32; 4]) {
    fast_tanh_w::<4>(v)
}

/// 8-lane (AVX2-shaped) [`fast_tanh_w`] instantiation.
#[inline(always)]
pub fn fast_tanh8(v: &mut [f32; 8]) {
    fast_tanh_w::<8>(v)
}

/// 16-lane (AVX-512-shaped) [`fast_tanh_w`] instantiation.
#[inline(always)]
pub fn fast_tanh16(v: &mut [f32; 16]) {
    fast_tanh_w::<16>(v)
}

/// 4-lane [`fast_sigmoid`] (Eq. 4 over [`fast_tanh4`]); bit-identical to
/// the scalar form per lane.
#[inline(always)]
pub fn fast_sigmoid4(v: &mut [f32; 4]) {
    fast_sigmoid_w::<4>(v)
}

/// 8-lane (AVX2-shaped) [`fast_sigmoid_w`] instantiation.
#[inline(always)]
pub fn fast_sigmoid8(v: &mut [f32; 8]) {
    fast_sigmoid_w::<8>(v)
}

/// 16-lane (AVX-512-shaped) [`fast_sigmoid_w`] instantiation.
#[inline(always)]
pub fn fast_sigmoid16(v: &mut [f32; 16]) {
    fast_sigmoid_w::<16>(v)
}

/// Two-pass fast softmax over a row (max-shifted; shift cancels in the
/// ratio, so this matches the paper's unshifted math for finite inputs).
pub fn fast_softmax_row(row: &mut [f32]) {
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for v in row.iter_mut() {
        *v = fast_exp(*v - m);
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// Max absolute / relative error of each approximation over its working
/// range — the numbers behind `compiled-nn precision`.
#[derive(Debug, Clone)]
pub struct PrecisionRow {
    pub name: &'static str,
    pub range: (f32, f32),
    pub max_abs_err: f64,
    pub mean_abs_err: f64,
    pub max_rel_err: f64,
}

pub fn report(samples: usize) -> Vec<PrecisionRow> {
    let eval = |name: &'static str, lo: f32, hi: f32, approx: fn(f32) -> f32, exact: fn(f32) -> f32| {
        let mut max_abs = 0f64;
        let mut sum_abs = 0f64;
        let mut max_rel = 0f64;
        for i in 0..samples {
            let x = lo + (hi - lo) * i as f32 / (samples - 1) as f32;
            let a = approx(x) as f64;
            let e = exact(x) as f64;
            let abs = (a - e).abs();
            max_abs = max_abs.max(abs);
            sum_abs += abs;
            if e.abs() > 1e-30 {
                max_rel = max_rel.max(abs / e.abs());
            }
        }
        PrecisionRow {
            name,
            range: (lo, hi),
            max_abs_err: max_abs,
            mean_abs_err: sum_abs / samples as f64,
            max_rel_err: max_rel,
        }
    };
    vec![
        eval("tanh (Eq. 5)", -4.0, 4.0, fast_tanh, f32::tanh),
        eval("sigmoid (Eq. 4)", -8.0, 8.0, fast_sigmoid, |x| 1.0 / (1.0 + (-x).exp())),
        eval("exp (Schraudolph)", -10.0, 10.0, fast_exp, f32::exp),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tanh_error_bound() {
        // Same bound the python tests assert (ref.TANH_MAX_ABS_ERR).
        let r = &report(4001)[0];
        assert!(r.max_abs_err < 1e-4, "{r:?}");
    }

    #[test]
    fn sigmoid_error_bound() {
        let r = &report(4001)[1];
        assert!(r.max_abs_err < 1e-4, "{r:?}");
    }

    #[test]
    fn exp_relative_error_bound() {
        // Schraudolph: ~3.95 % worst-case relative error.
        let r = &report(4001)[2];
        assert!(r.max_rel_err < 0.04, "{r:?}");
    }

    #[test]
    fn exp_matches_python_constants() {
        // pinned spot values cross-checked with the pallas kernel
        assert!((fast_exp(0.0) - 1.0).abs() < 0.03);
        assert!((fast_exp(1.0) - core::f32::consts::E).abs() / core::f32::consts::E < 0.04);
    }

    /// §3.4 satellite property: every lane-form width (scalar 1, SSE 4,
    /// AVX2 8, AVX-512 16) is bit-identical to the scalar functions —
    /// swept with the same linspace the error tables use, in W-lane groups
    /// over each approximation's working range.
    #[test]
    fn lane_functions_bit_identical_to_scalar_over_working_ranges() {
        fn sweep<const W: usize>(lo: f32, hi: f32, fw: fn(&mut [f32; W]), f1: fn(f32) -> f32) {
            let samples = 4000usize;
            for g in (0..samples).step_by(W) {
                let mut lanes = [0.0f32; W];
                for l in 0..W {
                    let i = (g + l).min(samples - 1);
                    lanes[l] = lo + (hi - lo) * i as f32 / (samples - 1) as f32;
                }
                let want = lanes.map(f1);
                fw(&mut lanes);
                for l in 0..W {
                    assert_eq!(
                        lanes[l].to_bits(),
                        want[l].to_bits(),
                        "W={W} lane {l}: {} vs {}",
                        lanes[l],
                        want[l]
                    );
                }
            }
        }
        sweep::<1>(-4.0, 4.0, fast_tanh_w::<1>, fast_tanh);
        sweep::<4>(-4.0, 4.0, fast_tanh4, fast_tanh);
        sweep::<8>(-4.0, 4.0, fast_tanh8, fast_tanh);
        sweep::<16>(-4.0, 4.0, fast_tanh16, fast_tanh);
        sweep::<1>(-8.0, 8.0, fast_sigmoid_w::<1>, fast_sigmoid);
        sweep::<4>(-8.0, 8.0, fast_sigmoid4, fast_sigmoid);
        sweep::<8>(-8.0, 8.0, fast_sigmoid8, fast_sigmoid);
        sweep::<16>(-8.0, 8.0, fast_sigmoid16, fast_sigmoid);
    }

    #[test]
    fn tanh_odd_symmetric() {
        for i in 0..100 {
            let x = -4.0 + i as f32 * 0.08;
            assert!((fast_tanh(x) + fast_tanh(-x)).abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_normalizes() {
        let mut row = [1.0f32, 2.0, 3.0, -1.0];
        fast_softmax_row(&mut row);
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        // ordering preserved
        assert!(row[2] > row[1] && row[1] > row[0] && row[0] > row[3]);
    }

    #[test]
    fn sigmoid_monotone() {
        let mut prev = f32::NEG_INFINITY;
        for i in 0..200 {
            let v = fast_sigmoid(-8.0 + i as f32 * 0.08);
            assert!(v >= prev - 1e-6);
            prev = v;
        }
    }
}
