//! # compiled-nn
//!
//! Reproduction of *“A JIT Compiler for Neural Network Inference”*
//! (Thielke & Hasselbring, RoboCup 2019) as a three-layer
//! Rust + JAX + Pallas stack: JAX/Pallas author the per-network compute and
//! AOT-lower it to HLO text; the Rust runtime PJRT-compiles artifacts at
//! model-registration time (the paper's runtime-JIT analog) and serves
//! inference; interpreter engines reproduce the paper's baselines.
//!
//! ## Engine registry
//!
//! All three execution paths implement the [`engine::Engine`] trait and are
//! constructed exclusively through the [`engine::EngineKind`] registry
//! ([`engine::build_engine`] for manifest-backed models,
//! [`engine::build_engine_from_spec`] for programmatic specs):
//!
//! * `naive` — [`nn::interp::NaiveInterp`], the exact scalar oracle,
//! * `optimized` — [`compiler::exec::OptInterp`], a thin shell over the
//!   pre-resolved [`compiler::program::Program`] IR (spec → §3.5 fold →
//!   §3.2 plan → lower → run; zero lookups/allocation per inference),
//! * `compiled` — `runtime::executor::CompiledEngine`, PJRT-compiled AOT
//!   artifacts. Only present with the `pjrt` cargo feature; plain builds
//!   report it unavailable and every caller (CLI, coordinator, tests,
//!   benches) degrades gracefully via [`engine::EngineKind::available`].
//!
//! See DESIGN.md for the full mapping, docs/ARCHITECTURE.md for the
//! pipeline walk-through, and EXPERIMENTS.md for results.
//!
//! The public surface of the documented core (`compiler`, `engine`,
//! `nn::simd`, `coordinator::server`) is doc-gated: `missing_docs` warns
//! here and CI denies warnings. Leaf modules still growing their surface
//! carry an explicit `allow` below until their docs land.
#![warn(missing_docs)]

#[allow(missing_docs)]
pub mod approx;
#[allow(missing_docs)]
pub mod bench;
pub mod compiler;
pub mod coordinator;
pub mod cpu;
pub mod engine;
#[allow(missing_docs)]
pub mod model;
pub mod nn;
#[allow(missing_docs)]
pub mod runtime;
#[allow(missing_docs)]
pub mod util;
