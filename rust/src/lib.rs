//! # compiled-nn
//!
//! Reproduction of *“A JIT Compiler for Neural Network Inference”*
//! (Thielke & Hasselbring, RoboCup 2019) as a three-layer
//! Rust + JAX + Pallas stack: JAX/Pallas author the per-network compute and
//! AOT-lower it to HLO text; the Rust runtime PJRT-compiles artifacts at
//! model-registration time (the paper's runtime-JIT analog) and serves
//! inference; interpreter engines reproduce the paper's baselines.
//!
//! ## Engine registry
//!
//! All three execution paths implement the [`engine::Engine`] trait and are
//! constructed exclusively through the [`engine::EngineKind`] registry
//! ([`engine::build_engine`] for manifest-backed models,
//! [`engine::build_engine_from_spec`] for programmatic specs):
//!
//! * `naive` — [`nn::interp::NaiveInterp`], the exact scalar oracle,
//! * `optimized` — [`compiler::exec::OptInterp`], a thin shell over the
//!   pre-resolved [`compiler::program::Program`] IR (spec → §3.5 fold →
//!   §3.2 plan → lower → run; zero lookups/allocation per inference),
//! * `compiled` — `runtime::executor::CompiledEngine`, PJRT-compiled AOT
//!   artifacts. Only present with the `pjrt` cargo feature; plain builds
//!   report it unavailable and every caller (CLI, coordinator, tests,
//!   benches) degrades gracefully via [`engine::EngineKind::available`].
//!
//! See DESIGN.md for the full mapping and EXPERIMENTS.md for results.
pub mod approx;
pub mod bench;
pub mod compiler;
pub mod coordinator;
pub mod engine;
pub mod model;
pub mod nn;
pub mod runtime;
pub mod util;
