//! # compiled-nn
//!
//! Reproduction of *“A JIT Compiler for Neural Network Inference”*
//! (Thielke & Hasselbring, RoboCup 2019) as a three-layer
//! Rust + JAX + Pallas stack: JAX/Pallas author the per-network compute and
//! AOT-lower it to HLO text; the Rust runtime PJRT-compiles artifacts at
//! model-registration time (the paper's runtime-JIT analog) and serves
//! inference; interpreter engines reproduce the paper's baselines.
//!
//! See DESIGN.md for the full mapping and EXPERIMENTS.md for results.
pub mod approx;
pub mod bench;
pub mod compiler;
pub mod coordinator;
pub mod model;
pub mod nn;
pub mod runtime;
pub mod util;
