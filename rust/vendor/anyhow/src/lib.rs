//! Minimal, offline-vendored reimplementation of the subset of the
//! [`anyhow`](https://docs.rs/anyhow) API this workspace uses. The build
//! environment has no crates.io access, so the real crate cannot be
//! resolved; this drop-in provides the same surface with the same
//! formatting semantics:
//!
//! * `Result<T>` / `Error` with a context chain,
//! * `Display` prints the outermost message, `{:#}` joins the chain with
//!   `": "`, `Debug` prints the message plus a `Caused by:` list,
//! * `Context::{context, with_context}` on `Result` and `Option`,
//! * `anyhow!`, `bail!`, `ensure!` macros,
//! * `From<E: std::error::Error>` so `?` converts foreign errors.
//!
//! Unlike the real crate the cause chain is captured as strings (no
//! downcasting), which is all this repository relies on.

use std::error::Error as StdError;
use std::fmt;

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-chain error. `chain[0]` is the outermost (most recent)
/// message; later entries are the causes, ending at the root error.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap a std error, capturing its `source()` chain as messages.
    pub fn new<E: StdError>(error: E) -> Self {
        let mut chain = vec![error.to_string()];
        let mut src = error.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }

    /// Push a new outermost context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause messages, outermost first (string analog of
    /// `anyhow::Error::chain`).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("error chain is never empty")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain joined like anyhow's alternate mode.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// Context extension for `Result` and `Option`, mirroring
/// `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a new outermost message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Lazily-evaluated variant of [`Context::context`].
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_is_outermost_alternate_is_chain() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading config")
            .context("starting up")
            .unwrap_err();
        assert_eq!(e.to_string(), "starting up");
        assert_eq!(format!("{e:#}"), "starting up: reading config: gone");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn run() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(run().unwrap_err().to_string(), "gone");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
    }

    #[test]
    fn macros_build_and_bail() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }

    #[test]
    fn debug_lists_causes() {
        let e = Error::msg("root").context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer") && dbg.contains("Caused by"), "{dbg}");
    }
}
