//! API-compatible **stub** of the `xla` (PJRT) bindings used by the
//! `compiled_nn` runtime. The offline build environment ships no XLA/PJRT
//! plugin, so this crate lets `--features pjrt` builds type-check and run
//! everywhere: [`PjRtClient::cpu`] fails with a descriptive error, which the
//! engine registry surfaces as "compiled engine unavailable on this host".
//!
//! Deployments with a real PJRT plugin replace this crate via a Cargo
//! `[patch]` entry pointing at actual bindings with the same surface:
//! client construction, HLO-text parse, compile, device buffers, execute.
//!
//! Every handle type carries an [`Infallible`] field, so instances can never
//! exist and the method bodies are statically unreachable — the stub can't
//! silently fake results.

use std::convert::Infallible;
use std::fmt;
use std::path::Path;

/// Error type matching the real bindings' `StdError` behavior.
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: &str) -> Self {
        Error { msg: msg.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// A PJRT device handle (never constructed by the stub).
pub struct PjRtDevice {
    _never: Infallible,
}

/// A PJRT client. [`PjRtClient::cpu`] always fails in the stub.
pub struct PjRtClient {
    _never: Infallible,
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::new(
            "PJRT plugin not available: this build links the offline `xla` \
             stub; patch in real xla/PJRT bindings to run the compiled engine",
        ))
    }

    pub fn platform_name(&self) -> String {
        let _ = &self._never;
        unreachable!("stub xla handles cannot exist")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        let _ = &self._never;
        unreachable!("stub xla handles cannot exist")
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        let _ = &self._never;
        unreachable!("stub xla handles cannot exist")
    }
}

/// Parsed HLO module (never constructed by the stub).
pub struct HloModuleProto {
    _never: Infallible,
}

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> Result<Self> {
        Err(Error::new("PJRT plugin not available: cannot parse HLO text in the stub"))
    }
}

pub struct XlaComputation {
    _never: Infallible,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        let _ = &proto._never;
        unreachable!("stub xla handles cannot exist")
    }
}

pub struct PjRtLoadedExecutable {
    _never: Infallible,
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        let _ = &self._never;
        unreachable!("stub xla handles cannot exist")
    }
}

pub struct PjRtBuffer {
    _never: Infallible,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        let _ = &self._never;
        unreachable!("stub xla handles cannot exist")
    }
}

pub struct Literal {
    _never: Infallible,
}

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        let _ = &self._never;
        unreachable!("stub xla handles cannot exist")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        let _ = &self._never;
        unreachable!("stub xla handles cannot exist")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_client_reports_missing_plugin() {
        let err = PjRtClient::cpu().err().expect("stub must not create clients");
        assert!(err.to_string().contains("PJRT plugin not available"), "{err}");
    }
}
