//! **Cold-start** — what the persistent artifact cache buys: for each
//! model × weight dtype, the wall time of a fresh `Program::lower` (fold →
//! plan → pack → quantize) against `load_program` mmap-loading the same
//! program from a serialized artifact, plus the measured-tuning axis
//! (`tune = Measured` vs the cost-model pick) on the GEMM-heavy net.
//!
//! Writes **BENCH_coldstart.json** with `load_vs_lower_speedup_<model>_
//! <dtype>` keys (CI grep-gates `load_vs_lower_speedup_wide_cnn_f32 > 1`
//! structurally) and `tune_predicted_ns` / `tune_measured_ns` per-item
//! inference times — the cross-PR record that deserialization stays
//! cheaper than re-lowering and that empirical tuning never ships a
//! slower program than the cost model alone.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use compiled_nn::compiler::artifact::{load_program, save_program, spec_content_hash};
use compiled_nn::compiler::exec::{CompileOptions, TuneMode, WeightDtype};
use compiled_nn::compiler::program::{ArenaPool, Program};
use compiled_nn::model::builder::{tiny_cnn, wide_cnn};
use compiled_nn::model::spec::ModelSpec;
use compiled_nn::nn::tensor::Tensor;
use compiled_nn::util::json::Json;
use compiled_nn::util::rng::SplitMix64;

/// Median wall milliseconds of `f` over `reps` runs (1 untimed warmup).
fn median_ms(reps: usize, mut f: impl FnMut()) -> f64 {
    f();
    let mut samples: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64() * 1e3
        })
        .collect();
    samples.sort_by(f64::total_cmp);
    samples[samples.len() / 2]
}

/// Mean wall nanoseconds per item of `program` over `iters` batch-8 runs.
fn per_item_ns(program: &Program, iters: usize) -> f64 {
    let batch = 8usize;
    let item: usize = program.input_shape().iter().product();
    let mut shape = vec![batch];
    shape.extend_from_slice(program.input_shape());
    let x = Tensor::from_vec(&shape, SplitMix64::new(7).uniform_vec(batch * item));
    let mut pool = ArenaPool::new();
    program.infer_pooled(&x, &mut pool).unwrap(); // warmup + arena alloc
    let t0 = Instant::now();
    for _ in 0..iters {
        program.infer_pooled(&x, &mut pool).unwrap();
    }
    t0.elapsed().as_nanos() as f64 / (iters * batch) as f64
}

struct ColdstartRow {
    model: &'static str,
    dtype: WeightDtype,
    lower_ms: f64,
    load_ms: f64,
}

fn coldstart_row(
    model: &'static str,
    spec: &ModelSpec,
    dtype: WeightDtype,
    dir: &Path,
) -> anyhow::Result<ColdstartRow> {
    let opts = CompileOptions { weight_dtype: dtype, ..CompileOptions::default() };
    let program = Program::lower(spec, opts)?;
    let path = dir.join(format!("{model}-{}.cnnprog", dtype.label()));
    save_program(&program, spec_content_hash(spec), opts, &path)?;

    let lower_ms = median_ms(9, || {
        let _ = Program::lower(spec, opts).unwrap();
    });
    let load_ms = median_ms(9, || {
        let _ = load_program(&path).unwrap();
    });

    // loaded and freshly-lowered programs must agree bitwise — a bench
    // that silently compared different programs would be meaningless
    let (loaded, _) = load_program(&path)?;
    let item: usize = spec.input_shape.iter().product();
    let mut shape = vec![1usize];
    shape.extend_from_slice(&spec.input_shape);
    let x = Tensor::from_vec(&shape, SplitMix64::new(3).uniform_vec(item));
    let a = program.infer_pooled(&x, &mut ArenaPool::new())?;
    let b = loaded.infer_pooled(&x, &mut ArenaPool::new())?;
    assert_eq!(a[0].data(), b[0].data(), "{model}/{}: load diverged", dtype.label());

    println!(
        "{model:<10} {:<5} lower {lower_ms:>8.3} ms   load {load_ms:>8.3} ms   speedup {:>6.1}x",
        dtype.label(),
        lower_ms / load_ms
    );
    Ok(ColdstartRow { model, dtype, lower_ms, load_ms })
}

fn main() -> anyhow::Result<()> {
    let dir = std::env::temp_dir().join(format!("cnn-coldstart-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;

    println!("== cold start: fresh lowering vs artifact mmap-load (median of 9)");
    let tiny = tiny_cnn(7);
    let wide = wide_cnn(7);
    let mut rows = Vec::new();
    for (name, spec) in [("tiny_cnn", &tiny), ("wide_cnn", &wide)] {
        for dtype in [WeightDtype::F32, WeightDtype::I8] {
            rows.push(coldstart_row(name, spec, dtype, &dir)?);
        }
    }

    // the tuning axis: cost-model pick vs empirically measured pick on the
    // GEMM-heavy net (where scheme choice actually moves throughput)
    println!("\n== tuning: cost-model pick vs measured pick (wide_cnn, batch 8)");
    let predicted = Program::lower(&wide, CompileOptions::default())?;
    let measured = Program::lower(
        &wide,
        CompileOptions { tune: TuneMode::Measured { reps: 3 }, ..CompileOptions::default() },
    )?;
    let overturned =
        measured.summary().report.decisions.iter().filter(|d| d.overturned).count();
    let tune_predicted_ns = per_item_ns(&predicted, 30);
    let tune_measured_ns = per_item_ns(&measured, 30);
    println!(
        "predicted {tune_predicted_ns:>10.0} ns/item   measured {tune_measured_ns:>10.0} \
         ns/item   ({overturned} decision(s) overturned)"
    );

    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("coldstart".to_string()));
    for r in &rows {
        let tag = format!("{}_{}", r.model, r.dtype.label());
        root.insert(format!("lower_ms_{tag}"), Json::Num(r.lower_ms));
        root.insert(format!("load_ms_{tag}"), Json::Num(r.load_ms));
        root.insert(
            format!("load_vs_lower_speedup_{tag}"),
            Json::Num(r.lower_ms / r.load_ms),
        );
    }
    root.insert("tune_predicted_ns".to_string(), Json::Num(tune_predicted_ns));
    root.insert("tune_measured_ns".to_string(), Json::Num(tune_measured_ns));
    root.insert("tune_overturned_layers".to_string(), Json::Num(overturned as f64));
    std::fs::write("BENCH_coldstart.json", format!("{}\n", Json::Obj(root)))?;
    println!("\nwrote BENCH_coldstart.json");
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
