//! **A-fusion / A-memory / A-matvec / A-conv** — ablations of the paper's
//! §3 design choices on the Program-backed optimized interpreter, isolating
//! each claim:
//!
//!   §3.5 BN folding:   fold_bn on/off        (latency)
//!   §3.4 approx act:   approx on/off          (latency; precision is in
//!                                              `compiled-nn precision`)
//!   §3.2 memory plan:  reuse_memory on/off    (arena bytes + latency)
//!   §3.3 matvec:       rotated / broadcast / generic Dense lowering
//!                      (latency on a square-dense MLP; runs without
//!                      artifacts, so CI exercises it too)
//!   §3.3/§3.4 conv:    direct / im2col / generic Conv2d lowering × pool
//!                      fusion on tiny_cnn (also artifact-less)
//!   PR 7 lanes/threads: forced SIMD lane widths (scalar/4/8) and the
//!                      intra-op band split at 1/2/4 threads on wide_cnn,
//!                      plus the tiny_cnn batch-1 overhead guard
//!   weight dtype:      f32 / bf16 / i8 weight storage on wide_cnn —
//!                      latency plus the lowering's per-dtype panel-byte
//!                      accounting (`weight_dtype` key in the JSON)
//!
//! Each variant is built through the engine registry (`EngineKind::Optimized`
//! with per-variant `EngineOptions`); the arena footprint is read through
//! `Engine::memory_bytes` and the lowering decisions through
//! `Engine::plan_summary`.
//!
//! Model ablations run on the nets that exercise each feature: c_bh
//! (BN + sigmoid), segmenter (softmax over 80×80), mobilenetv2 (34 BNs,
//! depthwise).
//!
//! Every run writes **BENCH_ablations.json** (per-variant ns/inference,
//! the cost model's predicted cycles per variant, the default tiny_cnn
//! lowering report, and a predicted-vs-measured ranking check), which CI
//! uploads as an artifact alongside BENCH_table1.json. See
//! docs/BENCHMARKS.md for the schema and how to read the ranking check.

use std::collections::BTreeMap;
use std::time::Duration;

use compiled_nn::bench::{bench_budget, black_box};
use compiled_nn::compiler::exec::{CompileOptions, ConvScheme, DenseScheme, LaneSelect, WeightDtype};
use compiled_nn::engine::{build_engine_from_spec, Engine, EngineKind, EngineOptions};
use compiled_nn::model::builder::{square_mlp, tiny_cnn, wide_cnn};
use compiled_nn::model::load::load_model;
use compiled_nn::nn::tensor::Tensor;
use compiled_nn::runtime::artifact::Manifest;
use compiled_nn::util::json::Json;
use compiled_nn::util::rng::{golden_seed, SplitMix64};

/// Predicted-cycle ratios at or below this are ties: the cost model's
/// resolution isn't fine enough to assert a measured ordering for them.
const TIE_BAND: f64 = 2.0;

/// Measurement slack for the ranking check: a predicted-slower variant may
/// measure up to this factor *faster* before the check flags a mismatch
/// (CI machines are noisy; the asserted pairs are predicted >2× apart).
const MEAS_TOL: f64 = 1.25;

/// One measured (case, variant) cell for the JSON report.
struct Cell {
    case: String,
    variant: String,
    ns: f64,
    /// Cost-model total for this variant's lowering (cycles/item), when
    /// the engine exposed a plan summary.
    predicted: Option<f64>,
}

fn main() -> anyhow::Result<()> {
    let mut cells: Vec<Cell> = Vec::new();
    let mut speedups: BTreeMap<String, f64> = BTreeMap::new();
    let lowering_report = conv_scheme_ablation(&mut cells)?;
    dense_scheme_ablation(&mut cells)?;
    lane_thread_ablation(&mut cells, &mut speedups)?;
    let weight_dtype = weight_dtype_ablation(&mut cells, &mut speedups)?;
    match Manifest::load_default() {
        Ok(m) => model_ablations(&m, &mut cells)?,
        Err(e) => eprintln!("(skipping model ablations: {e})"),
    }
    write_json(&cells, &speedups, lowering_report, weight_dtype)
}

/// §3.3 conv schemes × §3.4 pool fusion on the built-in tiny_cnn — the
/// paper's "conv core is a matvec, merge adjacent ops into the store loop"
/// claim, runnable on artifact-less CI. Expected: the fused SIMD path
/// beats the stand-alone scalar `generic` scheme. Returns the default
/// (cost-model Auto) variant's lowering report for the JSON output.
fn conv_scheme_ablation(cells: &mut Vec<Cell>) -> anyhow::Result<Option<Json>> {
    let budget = Duration::from_secs(2);
    let spec = tiny_cnn(91);
    let mut rng = SplitMix64::new(13);
    let x = Tensor::from_vec(&[1, 8, 8, 3], rng.uniform_vec(8 * 8 * 3));

    println!("== tiny_cnn — §3.3 conv lowering schemes × §3.4 pool fusion");
    let base = CompileOptions::default();
    let variants: [(&str, CompileOptions); 5] = [
        ("fused-auto (paper)", base),
        ("fused-direct", CompileOptions { conv: ConvScheme::Direct, ..base }),
        ("im2col-nofuse", CompileOptions { conv: ConvScheme::Im2col, fuse_pool: false, ..base }),
        ("direct-nofuse", CompileOptions { conv: ConvScheme::Direct, fuse_pool: false, ..base }),
        (
            "generic-nofuse",
            CompileOptions { conv: ConvScheme::Generic, fuse_pool: false, ..base },
        ),
    ];
    let mut fused_ms = 0.0;
    let mut generic_ms = 0.0;
    let mut report = None;
    for (label, compile) in variants {
        let opts = EngineOptions { compile, buckets: None };
        let mut e = build_engine_from_spec(EngineKind::Optimized, &spec, &opts)?;
        let lowered = e
            .plan_summary()
            .map(|s| {
                format!(
                    "{} direct / {} im2col, {} pool-fused",
                    s.direct_conv, s.im2col_conv, s.fused_maxpool
                )
            })
            .unwrap_or_default();
        let predicted = e.plan_summary().map(|s| s.report.predicted_total_cycles());
        if label.starts_with("fused-auto") {
            report = e.plan_summary().map(|s| s.report.to_json());
        }
        let r = bench_budget(&format!("tiny_cnn/{label}"), budget, 50, || {
            black_box(e.infer(&x).unwrap());
        });
        if label.starts_with("fused-auto") {
            fused_ms = r.mean_ms;
        }
        if label.starts_with("generic") {
            generic_ms = r.mean_ms;
        }
        println!(
            "{:<20} mean {:>9.5} ms  predicted {:>8.0} cyc  lowered: {lowered}  [{} iters]",
            label,
            r.mean_ms,
            predicted.unwrap_or(0.0),
            r.iters
        );
        cells.push(Cell {
            case: "tiny_cnn_conv".into(),
            variant: label.to_string(),
            ns: r.mean_ms * 1e6,
            predicted,
        });
    }
    println!(
        "fused SIMD vs scalar generic: ×{:.2} ({})\n",
        generic_ms / fused_ms,
        if fused_ms < generic_ms { "fused wins" } else { "REGRESSION: generic wins" }
    );
    Ok(report)
}

/// §3.3: the same square MLP lowered three ways. The rotated-diagonal
/// layout is the paper's Eq. 3 claim — it should at least match broadcast
/// (Eq. 2) by keeping x resident and dropping the broadcast temporary.
fn dense_scheme_ablation(cells: &mut Vec<Cell>) -> anyhow::Result<()> {
    let budget = Duration::from_secs(2);
    let spec = square_mlp(7, 256, 3);
    let mut rng = SplitMix64::new(11);
    let x = Tensor::from_vec(&[1, 256], rng.uniform_vec(256));

    println!("== square_mlp 256×256×4 — §3.3 Dense lowering schemes");
    let mut baseline = 0.0;
    for (label, scheme) in [
        ("rotated (Eq. 3)", DenseScheme::Rotated),
        ("broadcast (Eq. 2)", DenseScheme::Broadcast),
        ("generic", DenseScheme::Generic),
    ] {
        let opts = EngineOptions {
            compile: CompileOptions { dense: scheme, ..CompileOptions::default() },
            buckets: None,
        };
        let mut e = build_engine_from_spec(EngineKind::Optimized, &spec, &opts)?;
        let summary = e
            .plan_summary()
            .map(|s| format!("{} rotated / {} broadcast", s.rotated_dense, s.broadcast_dense))
            .unwrap_or_default();
        let predicted = e.plan_summary().map(|s| s.report.predicted_total_cycles());
        let r = bench_budget(&format!("square_mlp/{label}"), budget, 20, || {
            black_box(e.infer(&x).unwrap());
        });
        if baseline == 0.0 {
            baseline = r.mean_ms;
        }
        println!(
            "{:<20} mean {:>9.4} ms  (×{:>5.2} vs rotated)  lowered: {summary}  [{} iters]",
            label,
            r.mean_ms,
            r.mean_ms / baseline,
            r.iters
        );
        cells.push(Cell {
            case: "square_mlp_dense".into(),
            variant: label.to_string(),
            ns: r.mean_ms * 1e6,
            predicted,
        });
    }
    println!();
    Ok(())
}

/// PR 7: lane width × intra-op threads on wide_cnn (the 32×32×8 two-conv
/// net whose conv layers clear the cost model's parallel threshold), plus
/// the batch-1 tiny_cnn overhead check. Every lane width is portable —
/// the sweep shows what the autovectorizer realizes per width — and the
/// thread sweep measures the §3.2-planned band split. Speedup keys land
/// in BENCH_ablations.json so CI tracks the ≥1.8× 4-thread target and the
/// ≤5% small-net regression budget across PRs.
fn lane_thread_ablation(
    cells: &mut Vec<Cell>,
    speedups: &mut BTreeMap<String, f64>,
) -> anyhow::Result<()> {
    let budget = Duration::from_secs(2);
    let spec = wide_cnn(17);
    let mut rng = SplitMix64::new(23);
    let x = Tensor::from_vec(&[1, 32, 32, 8], rng.uniform_vec(32 * 32 * 8));
    let base = CompileOptions::default();
    let mut ns_of: BTreeMap<&str, f64> = BTreeMap::new();

    println!("== wide_cnn — SIMD lane width (forced) and intra-op threads");
    let variants: [(&str, CompileOptions); 6] = [
        ("lanes-scalar", CompileOptions { lanes: LaneSelect::Scalar, ..base }),
        ("lanes-4", CompileOptions { lanes: LaneSelect::W4, ..base }),
        ("lanes-8", CompileOptions { lanes: LaneSelect::W8, ..base }),
        ("threads-1", base),
        ("threads-2", CompileOptions { intra_threads: 2, ..base }),
        ("threads-4", CompileOptions { intra_threads: 4, ..base }),
    ];
    for (label, compile) in variants {
        let opts = EngineOptions { compile, buckets: None };
        let mut e = build_engine_from_spec(EngineKind::Optimized, &spec, &opts)?;
        let lowered = e
            .plan_summary()
            .map(|s| format!("w{} lanes × {} tasks", s.lane_width, s.parallel_tasks))
            .unwrap_or_default();
        let predicted = e.plan_summary().map(|s| s.report.predicted_total_cycles());
        let r = bench_budget(&format!("wide_cnn/{label}"), budget, 20, || {
            black_box(e.infer(&x).unwrap());
        });
        println!(
            "{:<14} mean {:>9.4} ms  lowered: {lowered}  [{} iters]",
            label, r.mean_ms, r.iters
        );
        ns_of.insert(label, r.mean_ms * 1e6);
        cells.push(Cell {
            case: "wide_cnn_lanes_threads".into(),
            variant: label.to_string(),
            ns: r.mean_ms * 1e6,
            predicted,
        });
    }
    speedups.insert(
        "speedup_w4_vs_scalar_wide_cnn".into(),
        ns_of["lanes-scalar"] / ns_of["lanes-4"],
    );
    speedups.insert("speedup_w8_vs_w4_wide_cnn".into(), ns_of["lanes-4"] / ns_of["lanes-8"]);
    speedups.insert(
        "speedup_threads2_vs_1_wide_cnn".into(),
        ns_of["threads-1"] / ns_of["threads-2"],
    );
    speedups.insert(
        "speedup_threads4_vs_1_wide_cnn".into(),
        ns_of["threads-1"] / ns_of["threads-4"],
    );
    println!(
        "4-thread split: ×{:.2} vs single-thread (target ≥1.8 on ≥4-core hosts)",
        ns_of["threads-1"] / ns_of["threads-4"]
    );

    // Small-net guard: tiny_cnn at batch 1 sits below the cost model's
    // 100k-cycle-per-task threshold, so a 4-thread budget must lower to a
    // single task and stay within the ≤5% latency budget of the default.
    let tiny = tiny_cnn(91);
    let mut rng = SplitMix64::new(29);
    let tx = Tensor::from_vec(&[1, 8, 8, 3], rng.uniform_vec(8 * 8 * 3));
    let mut tiny_ns = [0.0f64; 2];
    for (i, threads) in [1usize, 4].into_iter().enumerate() {
        let opts = EngineOptions {
            compile: CompileOptions { intra_threads: threads, ..base },
            buckets: None,
        };
        let mut e = build_engine_from_spec(EngineKind::Optimized, &tiny, &opts)?;
        let tasks = e.plan_summary().map(|s| s.parallel_tasks).unwrap_or(0);
        let r = bench_budget(&format!("tiny_cnn/b1/threads-{threads}"), budget, 50, || {
            black_box(e.infer(&tx).unwrap());
        });
        tiny_ns[i] = r.mean_ms * 1e6;
        cells.push(Cell {
            case: "tiny_cnn_batch1".into(),
            variant: format!("threads-{threads}"),
            ns: r.mean_ms * 1e6,
            predicted: None,
        });
        println!(
            "tiny_cnn b1 threads-{threads}: {:>9.5} ms ({tasks} planned tasks)",
            r.mean_ms
        );
    }
    speedups.insert("tiny_cnn_batch1_threads4_overhead".into(), tiny_ns[1] / tiny_ns[0]);
    println!(
        "tiny_cnn batch-1 overhead under a 4-thread budget: ×{:.3} (≤1.05 expected)\n",
        tiny_ns[1] / tiny_ns[0]
    );
    Ok(())
}

/// Dtype-generic weight pipeline: the same wide_cnn lowered with f32,
/// bf16, and i8 weight storage. Bytes come from the lowering's own
/// per-dtype `weights_bytes` accounting, so the JSON records what the
/// cost model actually priced: bf16 halves and i8 quarters the panel
/// traffic, which is where the speedup on bandwidth-bound shapes comes
/// from. Per-dtype speedup and bytes-vs-f32 land in BENCH_ablations.json
/// under the `weight_dtype` key (CI greps for it).
fn weight_dtype_ablation(
    cells: &mut Vec<Cell>,
    speedups: &mut BTreeMap<String, f64>,
) -> anyhow::Result<Json> {
    let budget = Duration::from_secs(2);
    let spec = wide_cnn(17);
    let mut rng = SplitMix64::new(31);
    let x = Tensor::from_vec(&[1, 32, 32, 8], rng.uniform_vec(32 * 32 * 8));
    let base = CompileOptions::default();

    println!("== wide_cnn — weight storage dtype (f32 / bf16 / i8 panels)");
    let mut ns_of: BTreeMap<&'static str, f64> = BTreeMap::new();
    let mut bytes_of: BTreeMap<&'static str, f64> = BTreeMap::new();
    let mut dtypes: BTreeMap<String, Json> = BTreeMap::new();
    for dtype in WeightDtype::ALL {
        let opts = EngineOptions {
            compile: CompileOptions { weight_dtype: dtype, ..base },
            buckets: None,
        };
        let mut e = build_engine_from_spec(EngineKind::Optimized, &spec, &opts)?;
        let (bytes, quantized) = e
            .plan_summary()
            .map(|s| (s.weights_bytes.total(), s.quantized_layers))
            .unwrap_or((0, 0));
        let predicted = e.plan_summary().map(|s| s.report.predicted_total_cycles());
        let label = dtype.label();
        let r = bench_budget(&format!("wide_cnn/weights-{label}"), budget, 20, || {
            black_box(e.infer(&x).unwrap());
        });
        println!(
            "weights-{:<5} mean {:>9.4} ms  weights {:>8} B  {} quantized layers  [{} iters]",
            label, r.mean_ms, bytes, quantized, r.iters
        );
        ns_of.insert(label, r.mean_ms * 1e6);
        bytes_of.insert(label, bytes as f64);
        let mut m = BTreeMap::new();
        m.insert("ns_per_inference".to_string(), Json::Num(r.mean_ms * 1e6));
        m.insert("weights_bytes".to_string(), Json::Num(bytes as f64));
        m.insert("quantized_layers".to_string(), Json::Num(quantized as f64));
        dtypes.insert(label.to_string(), Json::Obj(m));
        cells.push(Cell {
            case: "wide_cnn_weight_dtype".into(),
            variant: format!("weights-{label}"),
            ns: r.mean_ms * 1e6,
            predicted,
        });
    }
    for narrow in ["bf16", "i8"] {
        speedups.insert(
            format!("speedup_{narrow}_vs_f32_wide_cnn"),
            ns_of["f32"] / ns_of[narrow],
        );
        if let Some(Json::Obj(m)) = dtypes.get_mut(narrow) {
            m.insert(
                "bytes_vs_f32".to_string(),
                Json::Num(bytes_of[narrow] / bytes_of["f32"]),
            );
        }
        println!(
            "weights-{narrow}: ×{:.2} vs f32, {:.2}× the panel bytes",
            ns_of["f32"] / ns_of[narrow],
            bytes_of[narrow] / bytes_of["f32"]
        );
    }
    println!();
    Ok(Json::Obj(dtypes))
}

fn model_ablations(manifest: &Manifest, cells: &mut Vec<Cell>) -> anyhow::Result<()> {
    let budget = Duration::from_secs(2);

    for name in ["c_bh", "segmenter", "mobilenetv2"] {
        let entry = manifest.entry(name)?;
        // one spec parse per model, shared by all four variants
        let spec = load_model(&manifest.models_dir, name)?;
        let mut rng = SplitMix64::new(golden_seed(entry.seed));
        let mut shape = vec![1];
        shape.extend_from_slice(&entry.input_shape);
        let n: usize = shape.iter().product();
        let x = Tensor::from_vec(&shape, rng.uniform_vec(n));
        let min_iters = if entry.params > 1_000_000 { 3 } else { 20 };

        println!("\n== {name} ({} params)", entry.params);
        let base = CompileOptions::default();
        let variants: [(&str, CompileOptions); 4] = [
            ("all-on (paper)", base),
            ("no BN folding", CompileOptions { fold_bn: false, ..base }),
            ("exact activations", CompileOptions { approx: false, ..base }),
            ("no memory reuse", CompileOptions { reuse_memory: false, ..base }),
        ];
        let mut baseline = 0.0;
        for (label, compile) in variants {
            let opts = EngineOptions { compile, buckets: None };
            let mut e = build_engine_from_spec(EngineKind::Optimized, &spec, &opts)?;
            // touch once so arena exists for the bytes report
            e.infer(&x)?;
            let arena = e.memory_bytes().unwrap_or(0);
            let predicted = e.plan_summary().map(|s| s.report.predicted_total_cycles());
            let r = bench_budget(&format!("{name}/{label}"), budget, min_iters, || {
                black_box(e.infer(&x).unwrap());
            });
            if label.starts_with("all-on") {
                baseline = r.mean_ms;
            }
            println!(
                "{:<22} mean {:>9.3} ms  (×{:>5.2} vs all-on)  arena {:>10} B  [{} iters]",
                label,
                r.mean_ms,
                r.mean_ms / baseline,
                arena,
                r.iters
            );
            cells.push(Cell {
                case: name.to_string(),
                variant: label.to_string(),
                ns: r.mean_ms * 1e6,
                predicted,
            });
        }
    }
    println!("\n(expected: each paper optimization is a ≥1.0× win on latency; \
             memory reuse shrinks the arena; see EXPERIMENTS.md A-fusion/A-memory)");
    Ok(())
}

/// Predicted-vs-measured ranking validation: for each (SIMD, generic)
/// variant pair of one case, if the cost model predicts the generic
/// lowering slower by more than [`TIE_BAND`], the measurement must agree
/// in direction within [`MEAS_TOL`]. Pairs inside the tie band (or
/// missing predictions) assert nothing — the model prices schemes, not
/// machines, and close calls are expected to flip with cache effects.
fn ranking_check(cells: &[Cell]) -> Json {
    let pairs: [(&str, &str, &str); 2] = [
        ("tiny_cnn_conv", "im2col-nofuse", "generic-nofuse"),
        ("square_mlp_dense", "rotated (Eq. 3)", "generic"),
    ];
    let find =
        |case: &str, variant: &str| cells.iter().find(|c| c.case == case && c.variant == variant);
    let mut checks = Vec::new();
    for (case, simd, generic) in pairs {
        let (Some(s), Some(g)) = (find(case, simd), find(case, generic)) else { continue };
        let (Some(sp), Some(gp)) = (s.predicted, g.predicted) else { continue };
        let predicted_ratio = gp / sp;
        if predicted_ratio <= TIE_BAND {
            continue;
        }
        let measured_ratio = g.ns / s.ns;
        let ok = measured_ratio * MEAS_TOL >= 1.0;
        println!(
            "ranking {case}: predicted generic ×{predicted_ratio:.2} slower, \
             measured ×{measured_ratio:.2} → {}",
            if ok { "agrees" } else { "MISMATCH" }
        );
        let mut m = BTreeMap::new();
        m.insert("case".to_string(), Json::Str(case.to_string()));
        m.insert("simd_variant".to_string(), Json::Str(simd.to_string()));
        m.insert("generic_variant".to_string(), Json::Str(generic.to_string()));
        m.insert("predicted_ratio".to_string(), Json::Num(predicted_ratio));
        m.insert("measured_ratio".to_string(), Json::Num(measured_ratio));
        m.insert("ok".to_string(), Json::Bool(ok));
        checks.push(Json::Obj(m));
    }
    let mut root = BTreeMap::new();
    root.insert("tie_band".to_string(), Json::Num(TIE_BAND));
    root.insert("meas_tol".to_string(), Json::Num(MEAS_TOL));
    root.insert("checks".to_string(), Json::Arr(checks));
    Json::Obj(root)
}

/// Machine-readable results → BENCH_ablations.json (uploaded as a CI
/// artifact alongside BENCH_table1.json) so per-variant ns/inference is
/// comparable across PRs. Schema documented in docs/BENCHMARKS.md; CI
/// fails the ablations step if `lowering_report` is missing.
fn write_json(
    cells: &[Cell],
    speedups: &BTreeMap<String, f64>,
    lowering_report: Option<Json>,
    weight_dtype: Json,
) -> anyhow::Result<()> {
    let mut cases: BTreeMap<String, Json> = BTreeMap::new();
    let mut predicted: BTreeMap<String, Json> = BTreeMap::new();
    for c in cells {
        let entry = cases.entry(c.case.clone()).or_insert_with(|| Json::Obj(BTreeMap::new()));
        if let Json::Obj(m) = entry {
            m.insert(c.variant.clone(), Json::Num(c.ns));
        }
        if let Some(p) = c.predicted {
            let entry =
                predicted.entry(c.case.clone()).or_insert_with(|| Json::Obj(BTreeMap::new()));
            if let Json::Obj(m) = entry {
                m.insert(c.variant.clone(), Json::Num(p));
            }
        }
    }
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("ablations".to_string()));
    root.insert("unit".to_string(), Json::Str("ns_per_inference".to_string()));
    root.insert("cases".to_string(), Json::Obj(cases));
    root.insert("predicted_cycles".to_string(), Json::Obj(predicted));
    for (k, v) in speedups {
        root.insert(k.clone(), Json::Num(*v));
    }
    root.insert(
        "lowering_report".to_string(),
        lowering_report.unwrap_or(Json::Null),
    );
    root.insert("weight_dtype".to_string(), weight_dtype);
    root.insert("ranking_check".to_string(), ranking_check(cells));
    std::fs::write("BENCH_ablations.json", format!("{}\n", Json::Obj(root)))?;
    println!("wrote BENCH_ablations.json");
    Ok(())
}
