//! **A-fusion / A-memory / A-matvec / A-conv** — ablations of the paper's
//! §3 design choices on the Program-backed optimized interpreter, isolating
//! each claim:
//!
//!   §3.5 BN folding:   fold_bn on/off        (latency)
//!   §3.4 approx act:   approx on/off          (latency; precision is in
//!                                              `compiled-nn precision`)
//!   §3.2 memory plan:  reuse_memory on/off    (arena bytes + latency)
//!   §3.3 matvec:       rotated / broadcast / generic Dense lowering
//!                      (latency on a square-dense MLP; runs without
//!                      artifacts, so CI exercises it too)
//!   §3.3/§3.4 conv:    direct / im2col / generic Conv2d lowering × pool
//!                      fusion on tiny_cnn (also artifact-less)
//!
//! Each variant is built through the engine registry (`EngineKind::Optimized`
//! with per-variant `EngineOptions`); the arena footprint is read through
//! `Engine::memory_bytes` and the lowering decisions through
//! `Engine::plan_summary`.
//!
//! Model ablations run on the nets that exercise each feature: c_bh
//! (BN + sigmoid), segmenter (softmax over 80×80), mobilenetv2 (34 BNs,
//! depthwise).
//!
//! Every run writes **BENCH_ablations.json** (per-variant ns/inference),
//! which CI uploads as an artifact alongside BENCH_table1.json.

use std::collections::BTreeMap;
use std::time::Duration;

use compiled_nn::bench::{bench_budget, black_box};
use compiled_nn::compiler::exec::{CompileOptions, ConvScheme, DenseScheme};
use compiled_nn::engine::{build_engine_from_spec, Engine, EngineKind, EngineOptions};
use compiled_nn::model::builder::{square_mlp, tiny_cnn};
use compiled_nn::model::load::load_model;
use compiled_nn::nn::tensor::Tensor;
use compiled_nn::runtime::artifact::Manifest;
use compiled_nn::util::json::Json;
use compiled_nn::util::rng::{golden_seed, SplitMix64};

/// One measured (case, variant) cell for the JSON report.
struct Cell {
    case: String,
    variant: String,
    ns: f64,
}

fn main() -> anyhow::Result<()> {
    let mut cells: Vec<Cell> = Vec::new();
    conv_scheme_ablation(&mut cells)?;
    dense_scheme_ablation(&mut cells)?;
    match Manifest::load_default() {
        Ok(m) => model_ablations(&m, &mut cells)?,
        Err(e) => eprintln!("(skipping model ablations: {e})"),
    }
    write_json(&cells)
}

/// §3.3 conv schemes × §3.4 pool fusion on the built-in tiny_cnn — the
/// paper's "conv core is a matvec, merge adjacent ops into the store loop"
/// claim, runnable on artifact-less CI. Expected: the fused SIMD path
/// beats the stand-alone scalar `generic` scheme.
fn conv_scheme_ablation(cells: &mut Vec<Cell>) -> anyhow::Result<()> {
    let budget = Duration::from_secs(2);
    let spec = tiny_cnn(91);
    let mut rng = SplitMix64::new(13);
    let x = Tensor::from_vec(&[1, 8, 8, 3], rng.uniform_vec(8 * 8 * 3));

    println!("== tiny_cnn — §3.3 conv lowering schemes × §3.4 pool fusion");
    let base = CompileOptions::default();
    let variants: [(&str, CompileOptions); 5] = [
        ("fused-auto (paper)", base),
        ("fused-direct", CompileOptions { conv: ConvScheme::Direct, ..base }),
        ("im2col-nofuse", CompileOptions { conv: ConvScheme::Im2col, fuse_pool: false, ..base }),
        ("direct-nofuse", CompileOptions { conv: ConvScheme::Direct, fuse_pool: false, ..base }),
        (
            "generic-nofuse",
            CompileOptions { conv: ConvScheme::Generic, fuse_pool: false, ..base },
        ),
    ];
    let mut fused_ms = 0.0;
    let mut generic_ms = 0.0;
    for (label, compile) in variants {
        let opts = EngineOptions { compile, buckets: None };
        let mut e = build_engine_from_spec(EngineKind::Optimized, &spec, &opts)?;
        let lowered = e
            .plan_summary()
            .map(|s| {
                format!(
                    "{} direct / {} im2col, {} pool-fused",
                    s.direct_conv, s.im2col_conv, s.fused_maxpool
                )
            })
            .unwrap_or_default();
        let r = bench_budget(&format!("tiny_cnn/{label}"), budget, 50, || {
            black_box(e.infer(&x).unwrap());
        });
        if label.starts_with("fused-auto") {
            fused_ms = r.mean_ms;
        }
        if label.starts_with("generic") {
            generic_ms = r.mean_ms;
        }
        println!(
            "{:<20} mean {:>9.5} ms  lowered: {lowered}  [{} iters]",
            label, r.mean_ms, r.iters
        );
        cells.push(Cell {
            case: "tiny_cnn_conv".into(),
            variant: label.to_string(),
            ns: r.mean_ms * 1e6,
        });
    }
    println!(
        "fused SIMD vs scalar generic: ×{:.2} ({})\n",
        generic_ms / fused_ms,
        if fused_ms < generic_ms { "fused wins" } else { "REGRESSION: generic wins" }
    );
    Ok(())
}

/// §3.3: the same square MLP lowered three ways. The rotated-diagonal
/// layout is the paper's Eq. 3 claim — it should at least match broadcast
/// (Eq. 2) by keeping x resident and dropping the broadcast temporary.
fn dense_scheme_ablation(cells: &mut Vec<Cell>) -> anyhow::Result<()> {
    let budget = Duration::from_secs(2);
    let spec = square_mlp(7, 256, 3);
    let mut rng = SplitMix64::new(11);
    let x = Tensor::from_vec(&[1, 256], rng.uniform_vec(256));

    println!("== square_mlp 256×256×4 — §3.3 Dense lowering schemes");
    let mut baseline = 0.0;
    for (label, scheme) in [
        ("rotated (Eq. 3)", DenseScheme::Rotated),
        ("broadcast (Eq. 2)", DenseScheme::Broadcast),
        ("generic", DenseScheme::Generic),
    ] {
        let opts = EngineOptions {
            compile: CompileOptions { dense: scheme, ..CompileOptions::default() },
            buckets: None,
        };
        let mut e = build_engine_from_spec(EngineKind::Optimized, &spec, &opts)?;
        let summary = e
            .plan_summary()
            .map(|s| format!("{} rotated / {} broadcast", s.rotated_dense, s.broadcast_dense))
            .unwrap_or_default();
        let r = bench_budget(&format!("square_mlp/{label}"), budget, 20, || {
            black_box(e.infer(&x).unwrap());
        });
        if baseline == 0.0 {
            baseline = r.mean_ms;
        }
        println!(
            "{:<20} mean {:>9.4} ms  (×{:>5.2} vs rotated)  lowered: {summary}  [{} iters]",
            label,
            r.mean_ms,
            r.mean_ms / baseline,
            r.iters
        );
        cells.push(Cell {
            case: "square_mlp_dense".into(),
            variant: label.to_string(),
            ns: r.mean_ms * 1e6,
        });
    }
    println!();
    Ok(())
}

fn model_ablations(manifest: &Manifest, cells: &mut Vec<Cell>) -> anyhow::Result<()> {
    let budget = Duration::from_secs(2);

    for name in ["c_bh", "segmenter", "mobilenetv2"] {
        let entry = manifest.entry(name)?;
        // one spec parse per model, shared by all four variants
        let spec = load_model(&manifest.models_dir, name)?;
        let mut rng = SplitMix64::new(golden_seed(entry.seed));
        let mut shape = vec![1];
        shape.extend_from_slice(&entry.input_shape);
        let n: usize = shape.iter().product();
        let x = Tensor::from_vec(&shape, rng.uniform_vec(n));
        let min_iters = if entry.params > 1_000_000 { 3 } else { 20 };

        println!("\n== {name} ({} params)", entry.params);
        let base = CompileOptions::default();
        let variants: [(&str, CompileOptions); 4] = [
            ("all-on (paper)", base),
            ("no BN folding", CompileOptions { fold_bn: false, ..base }),
            ("exact activations", CompileOptions { approx: false, ..base }),
            ("no memory reuse", CompileOptions { reuse_memory: false, ..base }),
        ];
        let mut baseline = 0.0;
        for (label, compile) in variants {
            let opts = EngineOptions { compile, buckets: None };
            let mut e = build_engine_from_spec(EngineKind::Optimized, &spec, &opts)?;
            // touch once so arena exists for the bytes report
            e.infer(&x)?;
            let arena = e.memory_bytes().unwrap_or(0);
            let r = bench_budget(&format!("{name}/{label}"), budget, min_iters, || {
                black_box(e.infer(&x).unwrap());
            });
            if label.starts_with("all-on") {
                baseline = r.mean_ms;
            }
            println!(
                "{:<22} mean {:>9.3} ms  (×{:>5.2} vs all-on)  arena {:>10} B  [{} iters]",
                label,
                r.mean_ms,
                r.mean_ms / baseline,
                arena,
                r.iters
            );
            cells.push(Cell {
                case: name.to_string(),
                variant: label.to_string(),
                ns: r.mean_ms * 1e6,
            });
        }
    }
    println!("\n(expected: each paper optimization is a ≥1.0× win on latency; \
             memory reuse shrinks the arena; see EXPERIMENTS.md A-fusion/A-memory)");
    Ok(())
}

/// Machine-readable results → BENCH_ablations.json (uploaded as a CI
/// artifact alongside BENCH_table1.json) so per-variant ns/inference is
/// comparable across PRs.
fn write_json(cells: &[Cell]) -> anyhow::Result<()> {
    let mut cases: BTreeMap<String, Json> = BTreeMap::new();
    for c in cells {
        let entry = cases.entry(c.case.clone()).or_insert_with(|| Json::Obj(BTreeMap::new()));
        if let Json::Obj(m) = entry {
            m.insert(c.variant.clone(), Json::Num(c.ns));
        }
    }
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("ablations".to_string()));
    root.insert("unit".to_string(), Json::Str("ns_per_inference".to_string()));
    root.insert("cases".to_string(), Json::Obj(cases));
    std::fs::write("BENCH_ablations.json", format!("{}\n", Json::Obj(root)))?;
    println!("wrote BENCH_ablations.json");
    Ok(())
}
