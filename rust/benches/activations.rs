//! **A-activations** — §3.4 approximation speed: fast tanh/sigmoid/exp/
//! softmax vs their libm-exact counterparts over large buffers (the
//! activation pass of a real layer), plus the error table.

use compiled_nn::approx;
use compiled_nn::bench::{bench, black_box};
use compiled_nn::util::rng::SplitMix64;

fn main() {
    let n = 1 << 16;
    let mut rng = SplitMix64::new(7);
    let xs: Vec<f32> = (0..n).map(|_| rng.range(-6.0, 6.0)).collect();
    let mut out = vec![0.0f32; n];

    println!("{:<22} {:>12} {:>12} {:>8}", "function", "exact ms", "fast ms", "speedup");
    let cases: Vec<(&str, Box<dyn Fn(f32) -> f32>, Box<dyn Fn(f32) -> f32>)> = vec![
        ("tanh (Eq. 5)", Box::new(|v: f32| v.tanh()), Box::new(approx::fast_tanh)),
        (
            "sigmoid (Eq. 4)",
            Box::new(|v: f32| 1.0 / (1.0 + (-v).exp())),
            Box::new(approx::fast_sigmoid),
        ),
        ("exp (Schraudolph)", Box::new(|v: f32| v.exp()), Box::new(approx::fast_exp)),
    ];
    for (name, exact, fast) in cases {
        let re = bench(&format!("{name}/exact"), 2, 10, || {
            for (o, &v) in out.iter_mut().zip(&xs) {
                *o = exact(v);
            }
            black_box(&out);
        });
        let rf = bench(&format!("{name}/fast"), 2, 10, || {
            for (o, &v) in out.iter_mut().zip(&xs) {
                *o = fast(v);
            }
            black_box(&out);
        });
        println!(
            "{:<22} {:>12.3} {:>12.3} {:>8.2}×",
            name,
            re.mean_ms,
            rf.mean_ms,
            re.mean_ms / rf.mean_ms
        );
    }

    // softmax rows (the two-pass §3.4 structure)
    let c = 64;
    let mut buf = xs.clone();
    let re = bench("softmax/exact", 2, 10, || {
        buf.copy_from_slice(&xs);
        for row in buf.chunks_exact_mut(c) {
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut s = 0.0;
            for v in row.iter_mut() {
                *v = (*v - m).exp();
                s += *v;
            }
            for v in row.iter_mut() {
                *v /= s;
            }
        }
        black_box(&buf);
    });
    let rf = bench("softmax/fast", 2, 10, || {
        buf.copy_from_slice(&xs);
        for row in buf.chunks_exact_mut(c) {
            approx::fast_softmax_row(row);
        }
        black_box(&buf);
    });
    println!(
        "{:<22} {:>12.3} {:>12.3} {:>8.2}×",
        "softmax (two-pass)",
        re.mean_ms,
        rf.mean_ms,
        re.mean_ms / rf.mean_ms
    );

    println!("\nprecision (same numbers as `compiled-nn precision`):");
    for r in approx::report(4001) {
        println!(
            "  {:<20} max abs {:.3e}  mean abs {:.3e}  max rel {:.3e}",
            r.name, r.max_abs_err, r.mean_abs_err, r.max_rel_err
        );
    }
}
