//! **T1-compile** — the Compilation-Time row of Table 1: for each network,
//! time to go from artifact on disk to executable native code (HLO-text
//! parse + XLA:CPU codegen + weight upload), repeated to show variance,
//! plus the Rust-side graph-pass/planning cost for the interpreter engines.
//!
//! Paper anchor: 6.5 ms (C-HTWK) → 13 722 ms (VGG19) on the NAO — compile
//! cost grows superlinearly with model size; the same shape must hold here.

use compiled_nn::bench::bench;
use compiled_nn::compiler::exec::{compile, CompileOptions};
use compiled_nn::model::load::load_model;
use compiled_nn::runtime::artifact::Manifest;
use compiled_nn::runtime::executor::{CompiledModel, Runtime};

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load_default()?;
    let rt = Runtime::new()?;
    println!(
        "{:<14} {:>10} {:>12} {:>12} {:>12} {:>14} {:>14}",
        "model", "params", "parse ms", "codegen ms", "upload ms", "total ms", "plan(rs) ms"
    );
    for name in manifest.models.keys() {
        let entry = manifest.entry(name)?;
        // repeat full loads to average (3× keeps vgg19 tolerable)
        let reps = if entry.params > 10_000_000 { 2 } else { 3 };
        let mut parse = 0.0;
        let mut codegen = 0.0;
        let mut upload = 0.0;
        for _ in 0..reps {
            let m = CompiledModel::load_buckets(&rt, &manifest, entry, &[1])?;
            parse += m.timings[&1].parse_ms;
            codegen += m.timings[&1].compile_ms;
            upload += m.weights_upload_ms;
        }
        let (parse, codegen, upload) =
            (parse / reps as f64, codegen / reps as f64, upload / reps as f64);

        // Rust-side compile (fold + memory plan) for the optimized engine.
        let spec = load_model(&manifest.models_dir, name)?;
        let r = bench(&format!("{name}/plan"), 1, 5, || {
            let _ = compile(&spec, CompileOptions::default()).unwrap();
        });

        println!(
            "{:<14} {:>10} {:>12.1} {:>12.1} {:>12.1} {:>14.1} {:>14.3}",
            name,
            entry.params,
            parse,
            codegen,
            upload,
            parse + codegen + upload,
            r.mean_ms
        );
    }
    println!("\n(compile-time row of Table 1; paper: 6.5 ms → 13722 ms across the same size span)");
    Ok(())
}
