//! **T1-compile** — the Compilation-Time row of Table 1: for each network,
//! time to go from artifact on disk to executable native code (HLO-text
//! parse + XLA:CPU codegen + weight upload), repeated to show variance,
//! plus the Rust-side graph-pass/planning cost for the interpreter engines.
//!
//! The parse/codegen/upload split needs the PJRT runtime internals, so the
//! full report requires `--features pjrt`; a plain build still measures the
//! interpreter-side lowering cost — the full `Program::lower` pipeline
//! (§3.5 fold → §3.2 plan → kernel monomorphization + weight transforms) —
//! and says what it skipped.
//!
//! Paper anchor: 6.5 ms (C-HTWK) → 13 722 ms (VGG19) on the NAO — compile
//! cost grows superlinearly with model size; the same shape must hold here.

use std::collections::BTreeMap;

use compiled_nn::bench::bench;
use compiled_nn::compiler::program::{CompileOptions, Program};
use compiled_nn::model::load::load_model;
use compiled_nn::runtime::artifact::Manifest;

/// (parse ms, codegen ms, upload ms) per model, measured on ONE shared
/// PJRT client (client creation is expensive and per-process, not
/// per-model).
#[cfg(feature = "pjrt")]
fn pjrt_columns(manifest: &Manifest) -> anyhow::Result<BTreeMap<String, (f64, f64, f64)>> {
    use compiled_nn::runtime::executor::{CompiledModel, Runtime};

    let rt = Runtime::new()?;
    let mut out = BTreeMap::new();
    for name in manifest.models.keys() {
        let entry = manifest.entry(name)?;
        // repeat full loads to average (fewer reps keep vgg19 tolerable)
        let reps = if entry.params > 10_000_000 { 2 } else { 3 };
        let (mut parse, mut codegen, mut upload) = (0.0, 0.0, 0.0);
        for _ in 0..reps {
            let m = CompiledModel::load_buckets(&rt, manifest, entry, &[1])?;
            parse += m.timings[&1].parse_ms;
            codegen += m.timings[&1].compile_ms;
            upload += m.weights_upload_ms;
        }
        let reps = reps as f64;
        out.insert(name.clone(), (parse / reps, codegen / reps, upload / reps));
    }
    Ok(out)
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_columns(_manifest: &Manifest) -> anyhow::Result<BTreeMap<String, (f64, f64, f64)>> {
    anyhow::bail!("pjrt feature off")
}

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load_default()?;
    // A pjrt-enabled build failing here is a real problem (bad artifact,
    // missing plugin) — surface it instead of silently printing `-`.
    let pjrt_cols = match pjrt_columns(&manifest) {
        Ok(map) => Some(map),
        Err(e) => {
            if cfg!(feature = "pjrt") {
                eprintln!("PJRT columns unavailable: {e:#}");
            } else {
                println!("(pjrt feature off: PJRT parse/codegen/upload columns print as `-`)");
            }
            None
        }
    };
    println!(
        "{:<14} {:>10} {:>12} {:>12} {:>12} {:>14} {:>14}",
        "model", "params", "parse ms", "codegen ms", "upload ms", "total ms", "lower(rs) ms"
    );
    for name in manifest.models.keys() {
        let entry = manifest.entry(name)?;
        let cols = pjrt_cols.as_ref().and_then(|m| m.get(name));

        // Rust-side compile (fold + plan + lower) for the optimized engine.
        let spec = load_model(&manifest.models_dir, name)?;
        let r = bench(&format!("{name}/lower"), 1, 5, || {
            let _ = Program::lower(&spec, CompileOptions::default()).unwrap();
        });

        match cols {
            Some((parse, codegen, upload)) => println!(
                "{:<14} {:>10} {:>12.1} {:>12.1} {:>12.1} {:>14.1} {:>14.3}",
                name,
                entry.params,
                parse,
                codegen,
                upload,
                parse + codegen + upload,
                r.mean_ms
            ),
            None => println!(
                "{:<14} {:>10} {:>12} {:>12} {:>12} {:>14} {:>14.3}",
                name, entry.params, "-", "-", "-", "-", r.mean_ms
            ),
        }
    }
    println!("\n(compile-time row of Table 1; paper: 6.5 ms → 13722 ms across the same size span)");
    Ok(())
}
