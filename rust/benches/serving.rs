//! **SERVE-POOL** — the shared-`Program` worker-pool characterization: two
//! spec-registered models served over the event-loop TCP front end under
//! three axes:
//!
//! * **worker scaling** — `workers = 1` vs `workers = 4` at a fixed
//!   connection count. The paper's fixed lowered artifact makes concurrency
//!   cheap: scaling workers adds arenas, never a second lowering (asserted
//!   here via the `Program::lower` counting hook — exactly one per model
//!   per coordinator).
//! * **connection scaling** — 1 / 8 / 64 concurrent connections at
//!   `workers = 4`. The single-threaded readiness loop must multiplex 64
//!   sockets without collapsing; this is the axis the old
//!   thread-per-connection front end paid a thread apiece for.
//! * **overload** — pipelined bursts against a tiny `max_inflight` cap:
//!   measures the shed rate and the p99 of the requests that *were*
//!   admitted (load-shedding exists precisely to keep that p99 sane).
//!
//! Runs without the artifact manifest, so CI always produces
//! **BENCH_serving.json** (req/s + p50/p99 per worker count, per-connection
//! scaling, `shed_rate`, `p99_overload_ms`) — the cross-PR record of
//! whether the serving path scales with cores and connections and degrades
//! gracefully past saturation.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use compiled_nn::compiler::program::{lower_count, CompileOptions, Program};
use compiled_nn::coordinator::server::{Coordinator, CoordinatorConfig};
use compiled_nn::coordinator::tcp::{TcpClient, TcpOptions, TcpServer};
use compiled_nn::engine::EngineKind;
use compiled_nn::model::builder::Builder;
use compiled_nn::model::spec::{Activation, ModelSpec};
use compiled_nn::runtime::artifact::Manifest;
use compiled_nn::util::json::Json;
use compiled_nn::util::rng::SplitMix64;

/// Connections for the worker-scaling axis (half per model).
const CONNS: usize = 8;
/// Closed-loop measurement window per configuration.
const WINDOW: Duration = Duration::from_millis(2500);
/// Shorter window for the connection-scaling sweep (3 extra configs).
const CONN_WINDOW: Duration = Duration::from_millis(1500);

/// A serving-weight CNN (~6 MFLOP/item over a 512-float input): execution,
/// not wire framing, dominates — the regime where worker scaling shows.
fn serving_model(name: &str, seed: u64) -> ModelSpec {
    let mut b = Builder::new(name, &[8, 8, 8], seed);
    let c1 = b.conv2d("input", 48, 3, 1, Activation::Relu);
    let c2 = b.conv2d(&c1, 64, 3, 1, Activation::Relu);
    let p = b.maxpool(&c2, 2);
    let c3 = b.conv2d(&p, 96, 3, 1, Activation::Relu);
    let f = b.flatten(&c3);
    let d = b.dense(&f, 128, Activation::Relu);
    let head = b.dense(&d, 10, Activation::Linear);
    let s = b.softmax(&head);
    b.finish(&[&s])
}

struct RunResult {
    workers: usize,
    conns: usize,
    requests: u64,
    req_per_s: f64,
    p50_us: u64,
    p99_us: u64,
    lowers: u64,
}

fn coordinator_config(workers: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        max_wait: Duration::from_micros(300),
        queue_depth: 1024,
        engine: EngineKind::Optimized,
        workers,
        intra_threads: 1,
        weight_dtype: compiled_nn::nn::simd::WeightDtype::F32,
    }
}

/// Closed-loop run: `conns` connections issue request-reply round trips
/// for `window`, half per model.
fn run_config(workers: usize, conns: usize, window: Duration) -> anyhow::Result<RunResult> {
    let lowers_before = lower_count();
    let coord = Coordinator::start(Manifest::empty(), coordinator_config(workers))?;
    coord.register_spec(&serving_model("pool_a", 61), &[1, 2, 4, 8])?;
    coord.register_spec(&serving_model("pool_b", 62), &[1, 2, 4, 8])?;
    let lowers = lower_count() - lowers_before;
    let server = TcpServer::start(coord.clone(), "127.0.0.1:0")?;
    let addr = server.addr().to_string();

    let item = 8 * 8 * 8;
    let handles: Vec<_> = (0..conns)
        .map(|t| {
            let addr = addr.clone();
            std::thread::spawn(move || -> anyhow::Result<Vec<u64>> {
                let name = if t % 2 == 0 { "pool_a" } else { "pool_b" };
                let mut client = TcpClient::connect(&addr)?;
                let mut rng = SplitMix64::new(100 + t as u64);
                let input = rng.uniform_vec(item);
                // warmup outside the window
                client.infer(name, input.clone())?;
                let mut lat_us = Vec::with_capacity(4096);
                let deadline = Instant::now() + window;
                while Instant::now() < deadline {
                    let t0 = Instant::now();
                    client.infer(name, input.clone())?;
                    lat_us.push(t0.elapsed().as_micros() as u64);
                }
                Ok(lat_us)
            })
        })
        .collect();

    let mut lat_us: Vec<u64> = Vec::new();
    for h in handles {
        lat_us.extend(h.join().expect("client thread panicked")?);
    }
    drop(server);
    coord.shutdown();

    lat_us.sort_unstable();
    let n = lat_us.len();
    anyhow::ensure!(n > 0, "no requests completed inside the measurement window");
    let q = |p: f64| lat_us[((p * (n - 1) as f64).round() as usize).min(n - 1)];
    Ok(RunResult {
        workers,
        conns,
        requests: n as u64,
        req_per_s: n as f64 / window.as_secs_f64(),
        p50_us: q(0.5),
        p99_us: q(0.99),
        lowers,
    })
}

struct OverloadResult {
    sent: u64,
    oks: u64,
    sheds: u64,
    shed_rate: f64,
    p99_admitted_ms: f64,
}

/// Overload run: pipelined bursts (all requests written before any read)
/// against a small in-flight cap. Per burst we time the whole
/// write-everything/read-everything cycle and attribute it to every
/// *admitted* request in the burst — a conservative upper bound on each
/// one's latency, and exactly the number load-shedding is meant to bound.
fn run_overload() -> anyhow::Result<OverloadResult> {
    let coord = Coordinator::start(Manifest::empty(), coordinator_config(2))?;
    coord.register_spec(&serving_model("pool_a", 61), &[1, 2, 4, 8])?;
    let opts = TcpOptions { max_inflight: 8, slo_p99_ms: 0.0 };
    let server = TcpServer::start_with(coord.clone(), "127.0.0.1:0", opts)?;
    let addr = server.addr().to_string();

    let item = 8 * 8 * 8;
    let burst = 64usize;
    let mut client = TcpClient::connect(&addr)?;
    let mut rng = SplitMix64::new(4242);
    let input = rng.uniform_vec(item);
    client.infer("pool_a", input.clone())?; // warmup

    let (mut oks, mut sheds) = (0u64, 0u64);
    let mut admitted_ms: Vec<f64> = Vec::new();
    let deadline = Instant::now() + WINDOW;
    while Instant::now() < deadline {
        let t0 = Instant::now();
        for _ in 0..burst {
            client.send("pool_a", input.clone())?;
        }
        client.flush()?;
        let mut burst_oks = 0u64;
        for _ in 0..burst {
            let resp = client.recv()?;
            if resp.is_overloaded() {
                sheds += 1;
            } else {
                anyhow::ensure!(
                    matches!(resp, compiled_nn::coordinator::protocol::Response::Ok { .. }),
                    "overload burst produced a non-shed error: {resp:?}"
                );
                burst_oks += 1;
            }
        }
        oks += burst_oks;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        admitted_ms.resize(admitted_ms.len() + burst_oks as usize, ms);
    }
    drop(server);
    coord.shutdown();

    let sent = oks + sheds;
    anyhow::ensure!(sent > 0, "overload run completed no bursts");
    anyhow::ensure!(oks > 0, "overload run admitted nothing — cap too small");
    admitted_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = admitted_ms.len();
    let p99 = admitted_ms[((0.99 * (n - 1) as f64).round() as usize).min(n - 1)];
    Ok(OverloadResult {
        sent,
        oks,
        sheds,
        shed_rate: sheds as f64 / sent as f64,
        p99_admitted_ms: p99,
    })
}

fn main() -> anyhow::Result<()> {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "serving bench: 2 models × {CONNS} TCP connections, {:.1}s window, {cores} cores",
        WINDOW.as_secs_f64()
    );

    // The dense-GEMM acceptance proof: under the default options the
    // serving model's dense layers lower to the batch-blocked GEMM path,
    // so the batched buckets actually ride the amortized kernels.
    let probe = Program::lower(&serving_model("pool_a", 61), CompileOptions::default())?;
    let s = probe.summary().clone();
    assert!(s.gemm_dense >= 1, "serving model lowered without GEMM dense:\n{s}");
    println!(
        "dense lowering: {} gemm ({} rotated / {} broadcast / {} panel tails)",
        s.gemm_dense, s.rotated_dense, s.broadcast_dense, s.panel_tail_dense
    );
    println!(
        "{:>8} {:>6} {:>10} {:>12} {:>10} {:>10} {:>8}",
        "workers", "conns", "requests", "req/s", "p50 µs", "p99 µs", "lowers"
    );

    let mut results = Vec::new();
    for workers in [1usize, 4] {
        let r = run_config(workers, CONNS, WINDOW)?;
        // the counting-hook acceptance: one Program::lower per model, no
        // matter how many workers serve it
        assert_eq!(r.lowers, 2, "expected one lowering per model, got {}", r.lowers);
        println!(
            "{:>8} {:>6} {:>10} {:>12.0} {:>10} {:>10} {:>8}",
            r.workers, r.conns, r.requests, r.req_per_s, r.p50_us, r.p99_us, r.lowers
        );
        results.push(r);
    }
    let speedup = results[1].req_per_s / results[0].req_per_s.max(1e-9);
    println!(
        "workers=4 vs workers=1: {speedup:.2}× req/s \
         (shared Program: lowered once per model in both configs)"
    );
    if cores < 4 {
        println!("(note: only {cores} cores — pool scaling is capped by the host)");
    }

    // Connection scaling: the one readiness loop vs 1 / 8 / 64 sockets.
    let mut conn_results = Vec::new();
    for conns in [1usize, 8, 64] {
        let r = run_config(4, conns, CONN_WINDOW)?;
        assert_eq!(r.lowers, 2, "expected one lowering per model, got {}", r.lowers);
        println!(
            "{:>8} {:>6} {:>10} {:>12.0} {:>10} {:>10} {:>8}",
            r.workers, r.conns, r.requests, r.req_per_s, r.p50_us, r.p99_us, r.lowers
        );
        conn_results.push(r);
    }

    // Overload: shed rate + the p99 the admitted requests actually saw.
    let ovl = run_overload()?;
    println!(
        "overload (max_inflight 8, 64-deep pipelined bursts): {} sent, {} ok, {} shed \
         ({:.1}% shed rate), admitted p99 {:.2} ms",
        ovl.sent,
        ovl.oks,
        ovl.sheds,
        100.0 * ovl.shed_rate,
        ovl.p99_admitted_ms
    );

    write_json(&results, &conn_results, &ovl, speedup, s.gemm_dense)?;
    Ok(())
}

/// Machine-readable results → BENCH_serving.json (uploaded as a CI
/// artifact alongside BENCH_table1.json / BENCH_ablations.json).
fn write_json(
    results: &[RunResult],
    conn_results: &[RunResult],
    ovl: &OverloadResult,
    speedup: f64,
    gemm_dense: usize,
) -> anyhow::Result<()> {
    let run_obj = |r: &RunResult| {
        let mut m = BTreeMap::new();
        m.insert("requests".to_string(), Json::Num(r.requests as f64));
        m.insert("req_per_s".to_string(), Json::Num(r.req_per_s));
        m.insert("p50_us".to_string(), Json::Num(r.p50_us as f64));
        m.insert("p99_us".to_string(), Json::Num(r.p99_us as f64));
        m.insert("lower_calls".to_string(), Json::Num(r.lowers as f64));
        Json::Obj(m)
    };
    let mut configs: BTreeMap<String, Json> = BTreeMap::new();
    for r in results {
        configs.insert(format!("workers_{}", r.workers), run_obj(r));
    }
    let mut conn_scaling: BTreeMap<String, Json> = BTreeMap::new();
    for r in conn_results {
        conn_scaling.insert(format!("conns_{}", r.conns), run_obj(r));
    }
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("serving".to_string()));
    root.insert("models".to_string(), Json::Num(2.0));
    root.insert("connections".to_string(), Json::Num(CONNS as f64));
    root.insert(
        "cores".to_string(),
        Json::Num(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) as f64),
    );
    root.insert("configs".to_string(), Json::Obj(configs));
    root.insert("speedup_workers4_vs_1".to_string(), Json::Num(speedup));
    root.insert("gemm_dense_layers".to_string(), Json::Num(gemm_dense as f64));
    root.insert("conn_scaling".to_string(), Json::Obj(conn_scaling));
    root.insert("shed_rate".to_string(), Json::Num(ovl.shed_rate));
    root.insert("p99_overload_ms".to_string(), Json::Num(ovl.p99_admitted_ms));
    root.insert("overload_sent".to_string(), Json::Num(ovl.sent as f64));
    root.insert("overload_ok".to_string(), Json::Num(ovl.oks as f64));
    root.insert("overload_shed".to_string(), Json::Num(ovl.sheds as f64));
    std::fs::write("BENCH_serving.json", format!("{}\n", Json::Obj(root)))?;
    println!("wrote BENCH_serving.json");
    Ok(())
}
