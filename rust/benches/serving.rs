//! **SERVE-POOL** — the shared-`Program` worker-pool characterization: two
//! spec-registered models served over the TCP front end by M concurrent
//! connections, at `workers = 1` vs `workers = 4`. The paper's fixed
//! lowered artifact makes concurrency cheap: scaling workers adds arenas,
//! never a second lowering (asserted here via the `Program::lower` counting
//! hook — exactly one per model per coordinator).
//!
//! Runs without the artifact manifest, so CI always produces
//! **BENCH_serving.json** (req/s + p50/p99 per worker count, and the
//! workers=4 / workers=1 speedup) — the cross-PR record of whether the
//! serving path actually scales with cores.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use compiled_nn::compiler::program::{lower_count, CompileOptions, Program};
use compiled_nn::coordinator::server::{Coordinator, CoordinatorConfig};
use compiled_nn::coordinator::tcp::{TcpClient, TcpServer};
use compiled_nn::engine::EngineKind;
use compiled_nn::model::builder::Builder;
use compiled_nn::model::spec::{Activation, ModelSpec};
use compiled_nn::runtime::artifact::Manifest;
use compiled_nn::util::json::Json;
use compiled_nn::util::rng::SplitMix64;

/// Connections hammering the front end (half per model).
const CONNS: usize = 8;
/// Closed-loop measurement window per worker count.
const WINDOW: Duration = Duration::from_millis(2500);

/// A serving-weight CNN (~6 MFLOP/item over a 512-float input): execution,
/// not wire framing, dominates — the regime where worker scaling shows.
fn serving_model(name: &str, seed: u64) -> ModelSpec {
    let mut b = Builder::new(name, &[8, 8, 8], seed);
    let c1 = b.conv2d("input", 48, 3, 1, Activation::Relu);
    let c2 = b.conv2d(&c1, 64, 3, 1, Activation::Relu);
    let p = b.maxpool(&c2, 2);
    let c3 = b.conv2d(&p, 96, 3, 1, Activation::Relu);
    let f = b.flatten(&c3);
    let d = b.dense(&f, 128, Activation::Relu);
    let head = b.dense(&d, 10, Activation::Linear);
    let s = b.softmax(&head);
    b.finish(&[&s])
}

struct RunResult {
    workers: usize,
    requests: u64,
    req_per_s: f64,
    p50_us: u64,
    p99_us: u64,
    lowers: u64,
}

fn run_config(workers: usize) -> anyhow::Result<RunResult> {
    let lowers_before = lower_count();
    let cfg = CoordinatorConfig {
        max_wait: Duration::from_micros(300),
        queue_depth: 1024,
        engine: EngineKind::Optimized,
        workers,
    };
    let coord = Coordinator::start(Manifest::empty(), cfg)?;
    coord.register_spec(&serving_model("pool_a", 61), &[1, 2, 4, 8])?;
    coord.register_spec(&serving_model("pool_b", 62), &[1, 2, 4, 8])?;
    let lowers = lower_count() - lowers_before;
    let server = TcpServer::start(coord.clone(), "127.0.0.1:0")?;
    let addr = server.addr().to_string();

    let item = 8 * 8 * 8;
    let handles: Vec<_> = (0..CONNS)
        .map(|t| {
            let addr = addr.clone();
            std::thread::spawn(move || -> anyhow::Result<Vec<u64>> {
                let name = if t % 2 == 0 { "pool_a" } else { "pool_b" };
                let mut client = TcpClient::connect(&addr)?;
                let mut rng = SplitMix64::new(100 + t as u64);
                let input = rng.uniform_vec(item);
                // warmup outside the window
                client.infer(name, input.clone())?;
                let mut lat_us = Vec::with_capacity(4096);
                let deadline = Instant::now() + WINDOW;
                while Instant::now() < deadline {
                    let t0 = Instant::now();
                    client.infer(name, input.clone())?;
                    lat_us.push(t0.elapsed().as_micros() as u64);
                }
                Ok(lat_us)
            })
        })
        .collect();

    let mut lat_us: Vec<u64> = Vec::new();
    for h in handles {
        lat_us.extend(h.join().expect("client thread panicked")?);
    }
    drop(server);
    coord.shutdown();

    lat_us.sort_unstable();
    let n = lat_us.len();
    anyhow::ensure!(n > 0, "no requests completed inside the measurement window");
    let q = |p: f64| lat_us[((p * (n - 1) as f64).round() as usize).min(n - 1)];
    Ok(RunResult {
        workers,
        requests: n as u64,
        req_per_s: n as f64 / WINDOW.as_secs_f64(),
        p50_us: q(0.5),
        p99_us: q(0.99),
        lowers,
    })
}

fn main() -> anyhow::Result<()> {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "serving bench: 2 models × {CONNS} TCP connections, {:.1}s window, {cores} cores",
        WINDOW.as_secs_f64()
    );

    // The dense-GEMM acceptance proof: under the default options the
    // serving model's dense layers lower to the batch-blocked GEMM path,
    // so the batched buckets actually ride the amortized kernels.
    let probe = Program::lower(&serving_model("pool_a", 61), CompileOptions::default())?;
    let s = probe.summary().clone();
    assert!(s.gemm_dense >= 1, "serving model lowered without GEMM dense:\n{s}");
    println!(
        "dense lowering: {} gemm ({} rotated / {} broadcast / {} panel tails)",
        s.gemm_dense, s.rotated_dense, s.broadcast_dense, s.panel_tail_dense
    );
    println!(
        "{:>8} {:>10} {:>12} {:>10} {:>10} {:>8}",
        "workers", "requests", "req/s", "p50 µs", "p99 µs", "lowers"
    );

    let mut results = Vec::new();
    for workers in [1usize, 4] {
        let r = run_config(workers)?;
        // the counting-hook acceptance: one Program::lower per model, no
        // matter how many workers serve it
        assert_eq!(r.lowers, 2, "expected one lowering per model, got {}", r.lowers);
        println!(
            "{:>8} {:>10} {:>12.0} {:>10} {:>10} {:>8}",
            r.workers, r.requests, r.req_per_s, r.p50_us, r.p99_us, r.lowers
        );
        results.push(r);
    }
    let speedup = results[1].req_per_s / results[0].req_per_s.max(1e-9);
    println!(
        "workers=4 vs workers=1: {speedup:.2}× req/s \
         (shared Program: lowered once per model in both configs)"
    );
    if cores < 4 {
        println!("(note: only {cores} cores — pool scaling is capped by the host)");
    }
    write_json(&results, speedup, s.gemm_dense)?;
    Ok(())
}

/// Machine-readable results → BENCH_serving.json (uploaded as a CI
/// artifact alongside BENCH_table1.json / BENCH_ablations.json).
fn write_json(results: &[RunResult], speedup: f64, gemm_dense: usize) -> anyhow::Result<()> {
    let mut configs: BTreeMap<String, Json> = BTreeMap::new();
    for r in results {
        let mut m = BTreeMap::new();
        m.insert("requests".to_string(), Json::Num(r.requests as f64));
        m.insert("req_per_s".to_string(), Json::Num(r.req_per_s));
        m.insert("p50_us".to_string(), Json::Num(r.p50_us as f64));
        m.insert("p99_us".to_string(), Json::Num(r.p99_us as f64));
        m.insert("lower_calls".to_string(), Json::Num(r.lowers as f64));
        configs.insert(format!("workers_{}", r.workers), Json::Obj(m));
    }
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("serving".to_string()));
    root.insert("models".to_string(), Json::Num(2.0));
    root.insert("connections".to_string(), Json::Num(CONNS as f64));
    root.insert(
        "cores".to_string(),
        Json::Num(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) as f64),
    );
    root.insert("configs".to_string(), Json::Obj(configs));
    root.insert("speedup_workers4_vs_1".to_string(), Json::Num(speedup));
    root.insert("gemm_dense_layers".to_string(), Json::Num(gemm_dense as f64));
    std::fs::write("BENCH_serving.json", format!("{}\n", Json::Obj(root)))?;
    println!("wrote BENCH_serving.json");
    Ok(())
}
